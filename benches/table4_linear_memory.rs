//! Table 4 — linear-memory base optimizer (unfactored Adafactor).
//!
//! The paper's point: with Adafactor the optimizer state is already
//! sublinear, so LoRA cannot save memory (it even adds some); with a
//! LINEAR-memory optimizer LoRA's small trainable set wins at small r —
//! but FLORA overtakes it at r=256 (smaller constant) while beating it on
//! quality by 2–3 ROUGE everywhere.
//!
//! Run: cargo bench --bench table4_linear_memory [-- --quick | --steps N]

use flora::bench::paper::*;
use flora::config::TaskKind;
use flora::memory::{Dims, OptKind, StateRole};
use flora::opt::OptimizerKind;

fn main() {
    let args = BenchArgs::parse();
    let steps = args.steps.unwrap_or(if args.quick { 8 } else { 30 });
    let tau = if args.quick { 4 } else { 8 };
    let cells = table_grid();
    let dims = Dims::t5_small_sim();
    let title = format!(
        "Table 4 — linear-memory optimizer (unfactored Adafactor, sum \
         task, tau={tau}, {steps} steps)"
    );
    if args.require_artifacts() {
        let rt = shared_runtime(args.spec()).expect("runtime");
        let mut base = base_config(TaskKind::Sum, steps, tau);
        base.optimizer = OptimizerKind::AdafactorNoFactor;
        args.adjust(&mut base);
        let reports: Vec<_> = cells
            .iter()
            .map(|c| {
                eprintln!("[table4] {}", paper_label(c));
                run_cell(&base, c, &rt)
            })
            .collect();
        render_table(
            &title,
            "T5 60M",
            &dims,
            OptKind::AdafactorNoFactor,
            StateRole::Accumulation,
            &cells,
            &reports,
            "R1/R2/RL",
        )
        .print();
    } else {
        render_analytic_only(
            &title, "T5 60M", &dims, OptKind::AdafactorNoFactor,
            StateRole::Accumulation, &cells,
        )
        .print();
    }
    // the crossover check the paper calls out
    use flora::memory::{breakdown, Method};
    let state = |m: Method| {
        let b = breakdown(&dims, m, OptKind::AdafactorNoFactor, StateRole::Accumulation, 1, false);
        b.opt_state + b.method_state + b.extra_params
    };
    println!("\nchecks (paper §3.3):");
    println!(
        "  LoRA(8) beats FLORA(8) on memory : {}",
        if state(Method::Lora(8)) < state(Method::Flora(8)) { "OK" } else { "MISS" }
    );
    println!(
        "  FLORA(256) beats LoRA(256)       : {}",
        if state(Method::Flora(256)) < state(Method::Lora(256)) { "OK" } else { "MISS" }
    );
}
