//! Table 2 — momentum compression, training FROM SCRATCH (Algorithm 2).
//!
//! Methods: None / Naive / LoRA(r)×4 / FLORA(r)×4 with Adafactor base and
//! EMA momentum over gradients; FLORA keeps the momentum in the projected
//! space with κ-interval subspace transfer. κ defaults to 50 locally
//! (scaled from the paper's 1000 by the step-count ratio; Table 3 sweeps it).
//!
//! `-- --backend native --model lora-tiny` runs the WHOLE grid — LoRA
//! rows included — on the native transformer catalog, no XLA needed (the
//! bigram lm-small default has no LoRA entries, so those rows report ERR
//! under `--backend native` without the model override).
//!
//! Run: cargo bench --bench table2_momentum -- --backend native --model lora-tiny

use flora::bench::paper::*;
use flora::config::TaskKind;
use flora::memory::{Dims, OptKind, StateRole};

fn main() {
    let args = BenchArgs::parse();
    let steps = args.steps.unwrap_or(if args.quick { 12 } else { 60 });
    let cells = table_grid();
    // one runtime for the whole bench: sum+mt share the lm-small executables
    let rt = if args.require_artifacts() {
        Some(shared_runtime(args.spec()).expect("runtime"))
    } else {
        None
    };
    let role = StateRole::Momentum;
    let opt = OptKind::Adafactor;

    for (task, dims, label, metric) in [
        (TaskKind::Sum, Dims::t5_small_sim(), "T5 60M XSum-sim", "R1/R2/RL"),
        (TaskKind::Mt, Dims::gpt2_base_sim(), "GPT-2 110M IWSLT-sim", "BLEU"),
    ] {
        let title = format!("Table 2 — momentum ({label}, {steps} steps, kappa=50)");
        if let Some(rt) = &rt {
            let mut base = base_config(task, steps, 1); // tau=1 ⇒ momentum mode
            base.kappa = 50;
            args.adjust(&mut base);
            let reports: Vec<_> = cells
                .iter()
                .map(|c| {
                    eprintln!("[table2/{}] {}", task.name(), paper_label(c));
                    run_cell(&base, c, rt)
                })
                .collect();
            render_table(&title, label, &dims, opt, role, &cells, &reports, metric)
                .print();
        } else {
            render_analytic_only(&title, label, &dims, opt, role, &cells).print();
        }
    }
}
