/* serve_mirror.c — C mirror of the PR-6 multi-adapter serving decode hot
 * path (rust/src/model/decode.rs), used to seed the first
 * BENCH_serving.json trajectory point on machines where cargo is
 * unavailable (the build container). `cargo bench --bench serving`
 * reproduces the same batched-vs-sequential A/B on the real crate.
 *
 * What is mirrored, faithfully:
 *   - the exact GEMM sequence of one KV-cache greedy decode: a prefill
 *     chunk over the prompt, then one single-token chunk per generated
 *     token with a growing attention context t, per layer:
 *       fused QKV        [b*m, d] @ [d, 3d]
 *       LoRA corrections (xB)A per projection — 2 batched ops with
 *                        PER-PANEL operands (tensor/batched.rs
 *                        batched_matmul_ops), never materializing BA
 *       QK^T / P@V       b*h panels against the t-row cache
 *       Wo, W1, W2       + their (xB)A correction pairs
 *     plus the per-iteration tied-head logit row;
 *   - the band kernels (unrolled forms) + persistent-pool driver and
 *     PAR_MIN_FLOPS gate of rust/src/tensor/kernels.rs — band splits
 *     identical, so the batched path's better parallel engagement
 *     (b panels of work per dispatch vs 1) is captured honestly;
 *   - batch sizes 1 and 4 at rank 8 over the lora-* catalog grid with
 *     the serve defaults prompt_len = seq/2, max_new = seq/4.
 *
 * What is NOT mirrored (documented in docs/SERVING.md §6): softmax,
 * RMS-norm, GELU, embedding gathers, KV-cache append/view copies, the
 * argmax, and the batcher/registry bookkeeping — so absolute tokens/sec
 * here overstate the rust bench's full numbers. The batched/sequential
 * RATIO is the honest measurement: both variants omit the same work.
 *
 * Build & run:  gcc -O2 -pthread -o serve_mirror serve_mirror.c -lm
 *               ./serve_mirror 4          # parallelism (thread budget)
 */
#include <pthread.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

#define K_BLOCK 64
#define J_BLOCK 128
#define PAR_MIN_FLOPS (1 << 15)
#define MAX_THREADS 16
#define RANK 8

static int g_threads = 4;

/* ------------------------------------------------------------------ */
/* band kernels (the PR-5 unrolled forms — the production config)     */
/* ------------------------------------------------------------------ */

static void matmul_band(float *c, const float *a, const float *b, int n,
                        int k, int m) {
    for (int j0 = 0; j0 < m; j0 += J_BLOCK) {
        int j1 = j0 + J_BLOCK < m ? j0 + J_BLOCK : m;
        for (int k0 = 0; k0 < k; k0 += K_BLOCK) {
            int k1 = k0 + K_BLOCK < k ? k0 + K_BLOCK : k;
            for (int i = 0; i < n; i++) {
                const float *arow = a + (size_t)i * k;
                float *ctile = c + (size_t)i * m;
                int kk = k0;
                for (; kk + 4 <= k1; kk += 4) {
                    float a0 = arow[kk], a1 = arow[kk + 1];
                    float a2 = arow[kk + 2], a3 = arow[kk + 3];
                    const float *b0 = b + (size_t)kk * m;
                    const float *b1 = b + (size_t)(kk + 1) * m;
                    const float *b2 = b + (size_t)(kk + 2) * m;
                    const float *b3 = b + (size_t)(kk + 3) * m;
                    for (int j = j0; j < j1; j++) {
                        float acc = ctile[j];
                        acc += a0 * b0[j];
                        acc += a1 * b1[j];
                        acc += a2 * b2[j];
                        acc += a3 * b3[j];
                        ctile[j] = acc;
                    }
                }
                for (; kk < k1; kk++) {
                    float aik = arow[kk];
                    const float *brow = b + (size_t)kk * m;
                    for (int j = j0; j < j1; j++) ctile[j] += aik * brow[j];
                }
            }
        }
    }
}

static void nt_band(float *c, const float *a, const float *b, int n, int k,
                    int m, float alpha) {
    for (int j0 = 0; j0 < m; j0 += K_BLOCK) {
        int j1 = j0 + K_BLOCK < m ? j0 + K_BLOCK : m;
        for (int i = 0; i < n; i++) {
            const float *arow = a + (size_t)i * k;
            float *crow = c + (size_t)i * m;
            int j = j0;
            for (; j + 4 <= j1; j += 4) {
                const float *b0 = b + (size_t)j * k;
                const float *b1 = b + (size_t)(j + 1) * k;
                const float *b2 = b + (size_t)(j + 2) * k;
                const float *b3 = b + (size_t)(j + 3) * k;
                float acc0 = 0, acc1 = 0, acc2 = 0, acc3 = 0;
                for (int t = 0; t < k; t++) {
                    float x = arow[t];
                    acc0 += x * b0[t];
                    acc1 += x * b1[t];
                    acc2 += x * b2[t];
                    acc3 += x * b3[t];
                }
                crow[j] = acc0 * alpha;
                crow[j + 1] = acc1 * alpha;
                crow[j + 2] = acc2 * alpha;
                crow[j + 3] = acc3 * alpha;
            }
            for (; j < j1; j++) {
                const float *brow = b + (size_t)j * k;
                float acc = 0.0f;
                for (int t = 0; t < k; t++) acc += arow[t] * brow[t];
                crow[j] = acc * alpha;
            }
        }
    }
}

/* ------------------------------------------------------------------ */
/* ops: N (plain or per-panel-operand batched) and NT (panel-batched) */
/* ------------------------------------------------------------------ */

typedef enum { OP_N, OP_NT } OpKind;

typedef struct {
    OpKind kind;
    int batch; /* panels; per-panel B operands mirror batched_matmul_ops */
    int n, k, m;
    float *a, *b, *c;
} Op;

typedef struct {
    const Op *op;
    int first, count; /* band: rows for batch==1, panels otherwise */
} Band;

static void op_sizes(const Op *o, size_t *an, size_t *bn, size_t *cn) {
    *an = (size_t)o->n * o->k;
    *bn = o->kind == OP_NT ? (size_t)o->m * o->k : (size_t)o->k * o->m;
    *cn = (size_t)o->n * o->m;
}

static void run_band(const Band *bd) {
    const Op *o = bd->op;
    size_t an, bn, cn;
    op_sizes(o, &an, &bn, &cn);
    if (o->batch > 1) { /* bands are whole panels, per-panel operands */
        for (int p = bd->first; p < bd->first + bd->count; p++) {
            float *a = o->a + (size_t)p * an, *b = o->b + (size_t)p * bn,
                  *c = o->c + (size_t)p * cn;
            memset(c, 0, cn * sizeof(float));
            if (o->kind == OP_N) matmul_band(c, a, b, o->n, o->k, o->m);
            else nt_band(c, a, b, o->n, o->k, o->m, 1.0f);
        }
        return;
    }
    float *c = o->c + (size_t)bd->first * o->m;
    const float *a = o->a + (size_t)bd->first * o->k;
    if (o->kind == OP_N) {
        memset(c, 0, (size_t)bd->count * o->m * sizeof(float));
        matmul_band(c, a, o->b, bd->count, o->k, o->m);
    } else {
        nt_band(c, a, o->b, bd->count, o->k, o->m, 1.0f);
    }
}

static int op_rows(const Op *o) { return o->batch > 1 ? o->batch : o->n; }
static long op_flops(const Op *o) {
    return (long)o->n * o->k * o->m * (o->batch > 1 ? o->batch : 1);
}

/* persistent pool (mutex+condvar job board, caller computes band 0) */

static pthread_mutex_t pool_mu = PTHREAD_MUTEX_INITIALIZER;
static pthread_cond_t pool_cv = PTHREAD_COND_INITIALIZER;
static pthread_cond_t done_cv = PTHREAD_COND_INITIALIZER;
static Band pool_bands[MAX_THREADS];
static int pool_nbands = 0, pool_taken = 0, pool_done = 0;
static long pool_gen = 0;
static int pool_workers = 0, pool_shutdown = 0;

static void *pool_worker(void *arg) {
    (void)arg;
    long seen = 0;
    pthread_mutex_lock(&pool_mu);
    for (;;) {
        while (!pool_shutdown && (pool_gen == seen || pool_taken >= pool_nbands))
            pthread_cond_wait(&pool_cv, &pool_mu);
        if (pool_shutdown) break;
        seen = pool_gen;
        while (pool_taken < pool_nbands) {
            Band *bd = &pool_bands[pool_taken++];
            pthread_mutex_unlock(&pool_mu);
            run_band(bd);
            pthread_mutex_lock(&pool_mu);
            pool_done++;
            if (pool_done == pool_nbands) pthread_cond_signal(&done_cv);
        }
    }
    pthread_mutex_unlock(&pool_mu);
    return NULL;
}

static pthread_t pool_tids[MAX_THREADS];

static void pool_start(int workers) {
    pool_workers = workers;
    for (int i = 0; i < workers; i++)
        pthread_create(&pool_tids[i], NULL, pool_worker, NULL);
}

static void pool_stop(void) {
    pthread_mutex_lock(&pool_mu);
    pool_shutdown = 1;
    pthread_cond_broadcast(&pool_cv);
    pthread_mutex_unlock(&pool_mu);
    for (int i = 0; i < pool_workers; i++) pthread_join(pool_tids[i], NULL);
    pool_shutdown = 0;
    pool_workers = 0;
}

static void dispatch(const Op *o) {
    int rows = op_rows(o);
    int threads = g_threads < rows ? g_threads : rows;
    if (op_flops(o) < PAR_MIN_FLOPS || threads <= 1) {
        Band bd = {o, 0, rows};
        run_band(&bd);
        return;
    }
    int chunk = (rows + threads - 1) / threads;
    Band own = {o, 0, chunk < rows ? chunk : rows};
    pthread_mutex_lock(&pool_mu);
    pool_nbands = 0;
    for (int r0 = own.count; r0 < rows; r0 += chunk) {
        int take = chunk < rows - r0 ? chunk : rows - r0;
        pool_bands[pool_nbands++] = (Band){o, r0, take};
    }
    pool_taken = 0;
    pool_done = 0;
    pool_gen++;
    int nbands = pool_nbands;
    pthread_cond_broadcast(&pool_cv);
    pthread_mutex_unlock(&pool_mu);
    run_band(&own);
    pthread_mutex_lock(&pool_mu);
    while (pool_done < nbands) pthread_cond_wait(&done_cv, &pool_mu);
    pool_nbands = 0;
    pthread_mutex_unlock(&pool_mu);
}

/* ------------------------------------------------------------------ */
/* the serving decode GEMM mix                                        */
/* ------------------------------------------------------------------ */

typedef struct {
    const char *name;
    int vocab, seq, d, layers, heads, dff;
} Model;

/* the lora-* size grid of model/transformer.rs catalog_grid() */
static const Model MODELS[] = {
    {"lora-tiny", 64, 16, 32, 1, 2, 64},
    {"lora-small", 128, 32, 64, 2, 4, 128},
    {"lora-base", 256, 64, 128, 2, 4, 256},
};

typedef struct {
    Op ops[4096];
    int n;
} Mix;

static float *buf(size_t n) {
    float *p = malloc(n * sizeof(float));
    for (size_t i = 0; i < n; i++)
        p[i] = (float)((i * 2654435761u >> 8) & 1023) / 1024.0f - 0.5f;
    return p;
}

static void push(Mix *mx, OpKind kind, int batch, int n, int k, int m) {
    Op *o = &mx->ops[mx->n++];
    *o = (Op){kind, batch, n, k, m, NULL, NULL, NULL};
    size_t an, bn, cn;
    op_sizes(o, &an, &bn, &cn);
    o->a = buf((size_t)batch * an);
    o->b = buf((size_t)batch * bn);
    o->c = buf((size_t)batch * cn);
}

/* one decode chunk of m new tokens for b requests at total context t:
 * the GEMM sequence of decode.rs forward_chunk (adapted weights) */
static void push_chunk(Mix *mx, const Model *md, int b, int m, int t) {
    int d = md->d, f = md->dff, h = md->heads, dh = d / h;
    for (int l = 0; l < md->layers; l++) {
        push(mx, OP_N, 1, b * m, d, 3 * d); /* fused QKV */
        for (int p = 0; p < 3; p++) {       /* q/k/v (xB)A corrections */
            push(mx, OP_N, b, m, d, RANK);
            push(mx, OP_N, b, m, RANK, d);
        }
        push(mx, OP_NT, b * h, m, dh, t); /* Q @ cacheK^T */
        push(mx, OP_N, b * h, m, t, dh);  /* P @ cacheV   */
        push(mx, OP_N, 1, b * m, d, d);   /* Wo           */
        push(mx, OP_N, b, m, d, RANK);
        push(mx, OP_N, b, m, RANK, d);
        push(mx, OP_N, 1, b * m, d, f); /* W1 */
        push(mx, OP_N, b, m, d, RANK);
        push(mx, OP_N, b, m, RANK, f);
        push(mx, OP_N, 1, b * m, f, d); /* W2 */
        push(mx, OP_N, b, m, f, RANK);
        push(mx, OP_N, b, m, RANK, d);
    }
}

/* the whole greedy decode: prefill + one chunk per generated token,
 * with the tied-head logit row per iteration (drive() in decode.rs) */
static void build_decode(Mix *mx, const Model *md, int b, int prompt,
                         int max_new) {
    mx->n = 0;
    int s = prompt + max_new;
    push_chunk(mx, md, b, prompt, prompt);
    for (int i = prompt; i < s; i++) {
        push(mx, OP_NT, 1, b, md->d, md->vocab); /* logits */
        if (i + 1 < s) push_chunk(mx, md, b, 1, i + 1);
    }
}

static void free_mix(Mix *mx) {
    for (int i = 0; i < mx->n; i++) {
        free(mx->ops[i].a);
        free(mx->ops[i].b);
        free(mx->ops[i].c);
    }
}

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + ts.tv_nsec * 1e-9;
}

static void run_mix(const Mix *mx) {
    for (int i = 0; i < mx->n; i++) dispatch(&mx->ops[i]);
}

static int cmp_d(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

/* nearest-rank percentile, matching util::timing::Samples */
static double pctl(double *xs, int n, double p) {
    qsort(xs, n, sizeof(double), cmp_d);
    int rank = (int)((p / 100.0) * (n - 1) + 0.5);
    return xs[rank < n - 1 ? rank : n - 1];
}

#define MAX_ITERS 64

int main(int argc, char **argv) {
    g_threads = argc > 1 ? atoi(argv[1]) : 4;
    if (g_threads < 1) g_threads = 1;
    if (g_threads > MAX_THREADS) g_threads = MAX_THREADS;
    int iters = argc > 2 ? atoi(argv[2]) : 12;
    if (iters > MAX_ITERS) iters = MAX_ITERS;
    pool_start(g_threads - 1);
    printf("{\n  \"parallelism\": %d,\n  \"provenance\": \"c-mirror serve_mirror\",\n  \"sizes\": [\n",
           g_threads);
    int first_row = 1;
    for (size_t mi = 0; mi < sizeof(MODELS) / sizeof(MODELS[0]); mi++) {
        const Model *md = &MODELS[mi];
        int prompt = md->seq / 2, max_new = md->seq / 4;
        int s = prompt + max_new;
        static const int BS[] = {1, 4};
        for (size_t bi = 0; bi < 2; bi++) {
            int b = BS[bi];
            Mix batched, solo;
            build_decode(&batched, md, b, prompt, max_new);
            build_decode(&solo, md, 1, prompt, max_new);
            run_mix(&batched); /* warm */
            double lat[MAX_ITERS];
            double t0 = now_s();
            for (int it = 0; it < iters; it++) {
                double s0 = now_s();
                run_mix(&batched);
                lat[it] = now_s() - s0;
            }
            double mean_b = (now_s() - t0) / iters;
            run_mix(&solo); /* warm */
            t0 = now_s();
            for (int it = 0; it < iters; it++)
                for (int r = 0; r < b; r++) run_mix(&solo);
            double mean_s = (now_s() - t0) / iters;
            free_mix(&batched);
            free_mix(&solo);
            double gen = (double)(b * max_new);
            long kv = (long)md->layers * 2 * b * s * md->d * 4;
            printf("%s      {\"model\": \"%s/b%d\", \"base_model\": \"%s\", "
                   "\"batch\": %d, \"rank\": %d, \"prompt_len\": %d, "
                   "\"max_new\": %d, \"decode_tok_s\": %.1f, "
                   "\"seq_tok_s\": %.1f, \"batch_speedup\": %.3f, "
                   "\"p50_ms\": %.3f, \"p95_ms\": %.3f, \"kv_bytes\": %ld}",
                   first_row ? "" : ",\n", md->name, b, md->name, b,
                   RANK, prompt, max_new, gen / mean_b, gen / mean_s,
                   mean_s / mean_b, pctl(lat, iters, 50.0) * 1e3,
                   pctl(lat, iters, 95.0) * 1e3, kv);
            first_row = 0;
            fflush(stdout);
        }
    }
    printf("\n  ]\n}\n");
    pool_stop();
    return 0;
}
