/* ablation_mirror.c — C mirror of the PR-10 adaptive-rank compressor
 * grid (rust/src/opt/{flora,altlora,schedule}.rs), used to seed the
 * first BENCH_ablation.json trajectory point on machines where cargo is
 * unavailable (the build container). `cargo bench --bench ablation`
 * reproduces the same four-way comparison on the real crate.
 *
 * What is mirrored, faithfully:
 *   - the four compressor algebras, step for step, on one projectable
 *     matrix per catalog size (the layer-0 ffn/w1 shape [d, f]):
 *       flora-alg1  τ=4 shared-seed accumulation C += G Aᵀ, cycle-end
 *                   decompress-mean Ĝ = (C/τ) A, fresh seed per cycle;
 *       flora-alg2  τ=1 momentum-in-subspace M = βM + (1−β) G Aᵀ
 *                   (β = 0.9), κ=8 resample with transfer
 *                   M ← (M A_old) A_newᵀ, update Ĝ = M A;
 *       altlora     τ=4 dual sketches C += G Aᵀ and R += P G, cycle-end
 *                   alternating solve — A-step (P Pᵀ + εI) A₁ = r̄,
 *                   B-step B₁ (A₁ Aᵀ) = c̄ (both r×r, partial-pivot
 *                   elimination, ridge = 1e-4·mean|diag| + 1e-12, the
 *                   exact altlora.rs constants), Ĝ = B₁ A₁;
 *       adarank     flora-alg2 whose active rank follows halve-at:1 on
 *                   the κ-cycle clock (8 → 4 → 2 over 24 steps):
 *                   truncate the momentum columns FIRST (bit-exact
 *                   prefix), transfer at the sub-rank of the master
 *                   sampling law (first ra projection rows), EMA on the
 *                   live columns only, decompress scaled r0/ra —
 *                   the exact schedule.rs order;
 *   - the task: a synthetic quadratic over a rank-8 target
 *     (L(W) = ½·mean((W − W*)²), ∇L = W − W*, W* = U V normalized to
 *     unit RMS), so `final_loss` is a REAL measurement of each
 *     algebra's reconstruction quality under identical SGD steps —
 *     AltLoRA's solve is exact on rank ≤ r gradients and converges
 *     where Flora's fixed-projection read-back plateaus;
 *   - `method_state_bytes`, exactly: n·r·4 (alg1/alg2), (n·r + r·m)·4
 *     (altlora's dual sketch), n·r·4 master shape (adarank).
 *
 * What is NOT mirrored: the transformer forward/backward (gradients
 * here are the quadratic's, free to evaluate), the catalog/manifest
 * machinery, and rust bit-reproduction — projections are uniform with
 * second moment matched to rp's law (E[AᵀA] = I), not the same Gaussian
 * stream, so losses are statistically comparable, not bit-equal, to the
 * cargo-bench rows. Absolute steps/sec WILDLY overstate full training
 * (no model pass); the per-row RATIOS of time and the loss/state
 * columns are the honest measurement. `tok_s` is null: no tokens flow.
 *
 * Build & run:  gcc -O2 -o ablation_mirror ablation_mirror.c -lm
 *               ./ablation_mirror        # [iters]
 */
#include <math.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

#define R0 8
#define STEPS 24
#define TAU_ACC 4
#define KAPPA 8
#define BETA 0.9f
#define RIDGE_EPS 1e-4f

/* Per-row SGD lr, scaled for stability of each estimator's spectrum on
 * the quadratic: a fresh rank-r projection concentrates the update on
 * an r-dim subspace with gain ~m/r (master law: ~m/r0 per active
 * coordinate, times the r0/ra compensation), so accumulation rows take
 * lr ∝ r/m and momentum rows (damped by 1−β) lr ∝ r0/m; AltLoRA's
 * reconstruction is exact on this rank-r task (gain ~1), so it runs a
 * plain 0.3. Each row's lr is recorded in its output. The rust bench
 * rows likewise carry per-row proven-regime lrs. */
static float lr_of(int which, int m) {
    if (which == 2) return 0.3f;
    if (which == 0) return 0.5f * (float)R0 / (float)m;
    return 1.0f * (float)R0 / (float)m;
}

typedef struct {
    const char *name;
    int n, m; /* layer0/ffn/w1 = [d, f] of the catalog size */
} Size;

static const Size SIZES[] = {
    {"lora-tiny", 32, 64},
    {"lora-small", 64, 128},
    {"lora-base", 128, 256},
};

/* xorshift fill, uniform in ±0.8388608, deterministic per seed */
static void fill(float *x, size_t len, uint64_t seed) {
    uint64_t s = seed * 6364136223846793005ull + 1442695040888963407ull;
    for (size_t i = 0; i < len; i++) {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        x[i] = (float)((int64_t)(s >> 40) - (1 << 23)) * 1e-7f;
    }
}

/* projection rows with E[AᵀA] = I: uniform entries rescaled to
 * variance 1/R0 (rp::projection's second moment; distribution differs,
 * the algebra only needs the moment) */
static void fill_proj(float *a, size_t len, uint64_t seed) {
    fill(a, len, seed);
    float sd = sqrtf(1.0f / (float)R0) / sqrtf(0.8388608f * 0.8388608f / 3.0f);
    for (size_t i = 0; i < len; i++) a[i] *= sd;
}

/* C[n x p] = A[n x k] . B[k x p] */
static void mm(float *c, const float *a, const float *b, int n, int k, int p) {
    memset(c, 0, (size_t)n * p * sizeof(float));
    for (int i = 0; i < n; i++)
        for (int kk = 0; kk < k; kk++) {
            float aik = a[(size_t)i * k + kk];
            const float *bk = b + (size_t)kk * p;
            float *ci = c + (size_t)i * p;
            for (int j = 0; j < p; j++) ci[j] += aik * bk[j];
        }
}

/* C[n x p] = A[n x k] . B[p x k]ᵀ */
static void mmt(float *c, const float *a, const float *b, int n, int k, int p) {
    for (int i = 0; i < n; i++)
        for (int j = 0; j < p; j++) {
            float acc = 0.0f;
            const float *ai = a + (size_t)i * k;
            const float *bj = b + (size_t)j * k;
            for (int kk = 0; kk < k; kk++) acc += ai[kk] * bj[kk];
            c[(size_t)i * p + j] = acc;
        }
}

/* solve (S + εI) X = RHS in place of rhs, S r x r row-major, RHS r x k —
 * the solve_ridge port: ridge = RIDGE_EPS·mean|diag| + 1e-12, partial
 * pivoting, forward elimination + back substitution */
static int solve_ridge(const float *s_in, float *x, int r, int k) {
    float diag = 0.0f;
    for (int i = 0; i < r; i++) diag += fabsf(s_in[(size_t)i * r + i]);
    float ridge = RIDGE_EPS * diag / (float)r + 1e-12f;
    float *a = malloc((size_t)r * r * sizeof(float));
    for (int i = 0; i < r; i++)
        for (int j = 0; j < r; j++)
            a[(size_t)i * r + j] = s_in[(size_t)i * r + j] + (i == j ? ridge : 0.0f);
    for (int col = 0; col < r; col++) {
        int piv = col;
        float best = fabsf(a[(size_t)col * r + col]);
        for (int row = col + 1; row < r; row++) {
            float v = fabsf(a[(size_t)row * r + col]);
            if (v > best) { best = v; piv = row; }
        }
        if (best < 1e-20f) { free(a); return -1; }
        if (piv != col) {
            for (int j = 0; j < r; j++) {
                float t = a[(size_t)col * r + j];
                a[(size_t)col * r + j] = a[(size_t)piv * r + j];
                a[(size_t)piv * r + j] = t;
            }
            for (int j = 0; j < k; j++) {
                float t = x[(size_t)col * k + j];
                x[(size_t)col * k + j] = x[(size_t)piv * k + j];
                x[(size_t)piv * k + j] = t;
            }
        }
        float inv = 1.0f / a[(size_t)col * r + col];
        for (int row = col + 1; row < r; row++) {
            float f = a[(size_t)row * r + col] * inv;
            if (f == 0.0f) continue;
            for (int j = col; j < r; j++)
                a[(size_t)row * r + j] -= f * a[(size_t)col * r + j];
            for (int j = 0; j < k; j++)
                x[(size_t)row * k + j] -= f * x[(size_t)col * k + j];
        }
    }
    for (int col = r - 1; col >= 0; col--) {
        float inv = 1.0f / a[(size_t)col * r + col];
        for (int j = 0; j < k; j++) {
            float v = x[(size_t)col * k + j];
            for (int jj = col + 1; jj < r; jj++)
                v -= a[(size_t)col * r + jj] * x[(size_t)jj * k + j];
            x[(size_t)col * k + j] = v * inv;
        }
    }
    free(a);
    return 0;
}

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + ts.tv_nsec * 1e-9;
}

/* ½·mean((W − W*)²) */
static double loss_of(const float *w, const float *wstar, size_t len) {
    double acc = 0.0;
    for (size_t i = 0; i < len; i++) {
        double d = (double)w[i] - (double)wstar[i];
        acc += d * d;
    }
    return 0.5 * acc / (double)len;
}

/* halve-at:1 on the κ-cycle clock, clamped to >= 1 — schedule.rs */
static int rank_at(int cycle) {
    int r = R0 >> (cycle > 30 ? 30 : cycle);
    return r < 1 ? 1 : r;
}

typedef struct {
    const char *tag;
    int tau;
    const char *schedule;
    long state_bytes;
    double lr, final_loss, steps_per_sec;
} Row;

/* run one compressor on one size; scratch buffers are caller-allocated
 * at the largest size. `which`: 0 alg1, 1 alg2, 2 altlora, 3 adarank */
static Row run_one(const Size *sz, int which, uint64_t seed0, int iters) {
    int n = sz->n, m = sz->m;
    float lr = lr_of(which, m);
    size_t full = (size_t)n * m;
    float *wstar = malloc(full * sizeof(float));
    float *w = calloc(full, sizeof(float));
    float *g = malloc(full * sizeof(float));
    float *mom = calloc((size_t)n * R0, sizeof(float));
    float *acc = calloc((size_t)n * R0, sizeof(float));
    float *ralt = calloc((size_t)R0 * m, sizeof(float));
    float *proj = malloc((size_t)R0 * m * sizeof(float));
    float *proj2 = malloc((size_t)R0 * m * sizeof(float));
    float *probe = malloc((size_t)R0 * n * sizeof(float));
    float *ghat = malloc(full * sizeof(float));
    float *tmp_rr = malloc((size_t)R0 * R0 * sizeof(float));
    float *tmp_rm = malloc((size_t)R0 * m * sizeof(float));
    float *tmp_nr = malloc((size_t)n * R0 * sizeof(float));
    float *tmp_rn = malloc((size_t)R0 * n * sizeof(float));

    /* rank-8 target, unit RMS */
    {
        float *u = malloc((size_t)n * R0 * sizeof(float));
        float *v = malloc((size_t)R0 * m * sizeof(float));
        fill(u, (size_t)n * R0, seed0 + 1);
        fill(v, (size_t)R0 * m, seed0 + 2);
        mm(wstar, u, v, n, R0, m);
        double rms = 0.0;
        for (size_t i = 0; i < full; i++) rms += (double)wstar[i] * wstar[i];
        float s = (float)(1.0 / sqrt(rms / (double)full));
        for (size_t i = 0; i < full; i++) wstar[i] *= s;
        free(u);
        free(v);
    }

    double t0 = 0.0;
    int timed_steps = 0;
    for (int rep = 0; rep < iters + 1; rep++) {
        /* rep 0 is the measured trajectory (also warmup); later reps
         * re-run the same schedule purely for a stable clock */
        if (rep == 1) t0 = now_s();
        memset(w, 0, full * sizeof(float));
        memset(mom, 0, (size_t)n * R0 * sizeof(float));
        int ra = R0;
        for (int step = 0; step < STEPS; step++) {
            int cycle = step / KAPPA;
            /* accumulation rows resample every cycle (= every apply);
             * momentum rows advance their seed on the κ-cycle clock so
             * seed − 17 is always the previous subspace's seed */
            uint64_t seed = (which == 0 || which == 2)
                                ? seed0 + 131u * (uint64_t)step
                                : seed0 + 31u * (uint64_t)(which + 1) +
                                      17u * (uint64_t)cycle;
            for (size_t i = 0; i < full; i++) g[i] = w[i] - wstar[i];
            if (which == 0) {
                /* flora-alg1: τ shared-seed micros, decompress mean */
                fill_proj(proj, (size_t)R0 * m, seed);
                memset(acc, 0, (size_t)n * R0 * sizeof(float));
                for (int micro = 0; micro < TAU_ACC; micro++) {
                    mmt(tmp_nr, g, proj, n, m, R0);
                    for (size_t i = 0; i < (size_t)n * R0; i++) acc[i] += tmp_nr[i];
                }
                for (size_t i = 0; i < (size_t)n * R0; i++) acc[i] /= TAU_ACC;
                mm(ghat, acc, proj, n, R0, m);
            } else if (which == 2) {
                /* altlora: dual sketches + alternating r x r solves */
                fill_proj(proj, (size_t)R0 * m, seed);
                fill_proj(probe, (size_t)R0 * n, seed + 0xA17);
                memset(acc, 0, (size_t)n * R0 * sizeof(float));
                memset(ralt, 0, (size_t)R0 * m * sizeof(float));
                for (int micro = 0; micro < TAU_ACC; micro++) {
                    mmt(tmp_nr, g, proj, n, m, R0);
                    for (size_t i = 0; i < (size_t)n * R0; i++) acc[i] += tmp_nr[i];
                    mm(tmp_rm, probe, g, R0, n, m);
                    for (size_t i = 0; i < (size_t)R0 * m; i++) ralt[i] += tmp_rm[i];
                }
                for (size_t i = 0; i < (size_t)n * R0; i++) acc[i] /= TAU_ACC;
                for (size_t i = 0; i < (size_t)R0 * m; i++) ralt[i] /= TAU_ACC;
                /* A-step: (P Pᵀ + εI) A₁ = r̄ */
                mmt(tmp_rr, probe, probe, R0, n, R0);
                memcpy(tmp_rm, ralt, (size_t)R0 * m * sizeof(float));
                if (solve_ridge(tmp_rr, tmp_rm, R0, m)) goto fail;
                /* B-step: (A₁ Aᵀ)ᵀ B₁ᵀ = c̄ᵀ  ⇒ solve for B₁ᵀ [r x n] */
                mmt(tmp_rr, tmp_rm, proj, R0, m, R0);
                float *srt = malloc((size_t)R0 * R0 * sizeof(float));
                for (int i = 0; i < R0; i++)
                    for (int j = 0; j < R0; j++)
                        srt[(size_t)i * R0 + j] = tmp_rr[(size_t)j * R0 + i];
                for (int i = 0; i < R0; i++)
                    for (int j = 0; j < n; j++)
                        tmp_rn[(size_t)i * n + j] = acc[(size_t)j * R0 + i];
                int bad = solve_ridge(srt, tmp_rn, R0, n);
                free(srt);
                if (bad) goto fail;
                /* Ĝ = B₁ A₁ = (B₁ᵀ)ᵀ A₁ */
                for (int i = 0; i < n; i++)
                    for (int j = 0; j < R0; j++)
                        tmp_nr[(size_t)i * R0 + j] = tmp_rn[(size_t)j * n + i];
                mm(ghat, tmp_nr, tmp_rm, n, R0, m);
            } else {
                /* flora-alg2 / adarank: ranked momentum-in-subspace */
                int resample = step > 0 && step % KAPPA == 0;
                int ra_next = which == 3 ? rank_at(cycle) : R0;
                if (resample) {
                    if (ra_next < ra) /* truncate FIRST (schedule.rs) */
                        for (int i = 0; i < n; i++)
                            for (int j = ra_next; j < R0; j++)
                                mom[(size_t)i * R0 + j] = 0.0f;
                    ra = ra_next;
                    /* transfer M ← (M A_old) A_newᵀ at the active rank
                     * (mom rows are stride R0 — pack the live columns) */
                    fill_proj(proj2, (size_t)R0 * m, seed - 17u);
                    fill_proj(proj, (size_t)R0 * m, seed);
                    for (int i = 0; i < n; i++)
                        for (int j = 0; j < ra; j++)
                            tmp_nr[(size_t)i * ra + j] = mom[(size_t)i * R0 + j];
                    mm(ghat, tmp_nr, proj2, n, ra, m);
                    mmt(tmp_nr, ghat, proj, n, m, ra);
                    for (int i = 0; i < n; i++)
                        for (int j = 0; j < R0; j++)
                            mom[(size_t)i * R0 + j] =
                                j < ra ? tmp_nr[(size_t)i * ra + j] : 0.0f;
                } else {
                    fill_proj(proj, (size_t)R0 * m, seed);
                }
                /* EMA on the live columns, then Ĝ = (r0/ra)·M A */
                mmt(tmp_nr, g, proj, n, m, ra); /* first ra proj rows */
                for (int i = 0; i < n; i++)
                    for (int j = 0; j < ra; j++) {
                        size_t at = (size_t)i * R0 + j;
                        mom[at] = BETA * mom[at] +
                                  (1.0f - BETA) * tmp_nr[(size_t)i * ra + j];
                    }
                for (int i = 0; i < n; i++)
                    for (int j = 0; j < ra; j++)
                        tmp_nr[(size_t)i * ra + j] = mom[(size_t)i * R0 + j];
                mm(ghat, tmp_nr, proj, n, ra, m);
                float comp = (float)R0 / (float)ra;
                for (size_t i = 0; i < full; i++) ghat[i] *= comp;
            }
            for (size_t i = 0; i < full; i++) w[i] -= lr * ghat[i];
            if (rep >= 1) timed_steps++;
        }
    }
    double elapsed = now_s() - t0;

    Row out;
    out.tag = which == 0   ? "flora-alg1"
              : which == 1 ? "flora-alg2"
              : which == 2 ? "altlora"
                           : "adarank";
    out.tau = (which == 0 || which == 2) ? TAU_ACC : 1;
    out.schedule = which == 3 ? "halve-at:1" : "fixed";
    out.state_bytes = which == 2 ? 4L * (n * R0 + R0 * m) : 4L * n * R0;
    out.lr = lr;
    out.final_loss = loss_of(w, wstar, full);
    out.steps_per_sec = timed_steps > 0 ? timed_steps / elapsed : 0.0;
    goto done;
fail:
    fprintf(stderr, "solve collapse on %s which=%d\n", sz->name, which);
    exit(1);
done:
    free(wstar); free(w); free(g); free(mom); free(acc); free(ralt);
    free(proj); free(proj2); free(probe); free(ghat);
    free(tmp_rr); free(tmp_rm); free(tmp_nr); free(tmp_rn);
    return out;
}

int main(int argc, char **argv) {
    int iters = argc > 1 ? atoi(argv[1]) : 20;
    if (iters < 1) iters = 1;
    printf("{\n  \"provenance\": \"c-mirror ablation_mirror\",\n  \"sizes\": [\n");
    int first = 1;
    for (size_t si = 0; si < sizeof(SIZES) / sizeof(SIZES[0]); si++) {
        const Size *sz = &SIZES[si];
        for (int which = 0; which < 4; which++) {
            Row r = run_one(sz, which, 9000u + 100u * si, iters);
            printf("%s      {\"model\": \"%s/%s\", \"base_model\": \"%s\", "
                   "\"compressor\": \"%s\", \"rank\": %d, \"tau\": %d, "
                   "\"rank_schedule\": \"%s\", \"optimizer\": \"sgd\", \"lr\": %.6f, "
                   "\"steps\": %d, \"steps_per_sec\": %.3f, \"tok_s\": null, "
                   "\"method_state_bytes\": %ld, \"params_bytes\": %ld, "
                   "\"state_ratio\": %.6f, \"final_loss\": %.6f}",
                   first ? "" : ",\n", sz->name, r.tag, sz->name, r.tag, R0,
                   r.tau, r.schedule, r.lr, STEPS, r.steps_per_sec, r.state_bytes,
                   4L * sz->n * sz->m,
                   (double)r.state_bytes / (double)(4L * sz->n * sz->m),
                   r.final_loss);
            first = 0;
            fflush(stdout);
        }
    }
    printf("\n  ]\n}\n");
    return 0;
}
