/* dp_mirror.c — C mirror of the PR-7 data-parallel comms path
 * (rust/src/runtime/dp/), used to seed the first BENCH_dp.json
 * trajectory point on machines where cargo is unavailable (the build
 * container). `cargo bench --bench dp` reproduces the same
 * compressed-vs-full A/B on the real crate.
 *
 * What is mirrored, faithfully:
 *   - the per-parameter shard payloads of one dp data step over the
 *     exact lora-* catalog shapes (embed/pos, embed/tok, final_ln and
 *     per-layer attn wq/wk/wv/wo [d,d], ffn w1 [d,f] / w2 [f,d],
 *     ln scales — transformer.rs param_shapes), S = 4 shards, rank 8;
 *   - the COMPRESSED wire: each shard projects its attn/ffn gradients
 *     C_s = G_s A^T (n x r) before the exchange, the reducer sums the
 *     S payloads in fixed ascending shard order (one f32 accumulator
 *     per element, exactly like Matrix::reduce_sum), then decompresses
 *     ONCE: Ghat = (sum C) A / S;
 *   - the FULL wire baseline: fixed-order reduce of the raw n x m
 *     gradients, then one compress+decompress of the reduced gradient
 *     (the trainer's full-mode semantics — compression moves after the
 *     exchange, the optimizer math is unchanged);
 *   - the byte ledger: the same step_bytes formula as
 *     runtime/dp/reduce.rs — sent = 4*S*sum(n*r | n*m), so the
 *     compression ratio printed here is exactly the rust ledger's.
 *
 * What is NOT mirrored (documented in docs/DISTRIBUTED.md §6): the
 * forward/backward gradient computation, the optimizer step, and the
 * worker-pool scheduling — so absolute steps/sec here WILDLY overstate
 * the full cargo-bench figures (which pay tau * S forward/backwards per
 * step). The compressed/full RATIO of wire bytes and reduce+transform
 * time is the honest measurement: both variants omit the same work.
 *
 * Build & run:  gcc -O2 -o dp_mirror dp_mirror.c -lm
 *               ./dp_mirror            # [iters]
 */
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

#define SHARDS 4
#define RANK 8
#define MAX_PARAMS 32

typedef struct {
    const char *name;
    int vocab, seq, d, f, layers;
} Model;

static const Model MODELS[] = {
    {"lora-tiny", 64, 16, 32, 64, 1},
    {"lora-small", 128, 32, 64, 128, 2},
    {"lora-base", 256, 64, 128, 256, 2},
};

typedef struct {
    char name[32];
    int n, m;
    int projectable; /* attn/ or ffn/ — ships n x RANK when compressed */
} Shape;

/* transformer.rs param_shapes for one catalog model (sorted order does
 * not matter here — the reduce is per-parameter) */
static int model_shapes(const Model *md, Shape *out) {
    int k = 0;
    out[k] = (Shape){"embed/pos", 0, 0, 0};
    out[k].n = md->seq;
    out[k++].m = md->d;
    out[k] = (Shape){"embed/tok", 0, 0, 0};
    out[k].n = md->vocab;
    out[k++].m = md->d;
    out[k] = (Shape){"final_ln/scale", 1, 0, 0};
    out[k++].m = md->d;
    for (int l = 0; l < md->layers; l++) {
        static const char *sq[] = {"attn/wq", "attn/wk", "attn/wv", "attn/wo"};
        for (int i = 0; i < 4; i++) {
            snprintf(out[k].name, sizeof(out[k].name), "layer%d/%s", l, sq[i]);
            out[k].n = md->d;
            out[k].m = md->d;
            out[k++].projectable = 1;
        }
        snprintf(out[k].name, sizeof(out[k].name), "layer%d/ffn/w1", l);
        out[k].n = md->d;
        out[k].m = md->f;
        out[k++].projectable = 1;
        snprintf(out[k].name, sizeof(out[k].name), "layer%d/ffn/w2", l);
        out[k].n = md->f;
        out[k].m = md->d;
        out[k++].projectable = 1;
        snprintf(out[k].name, sizeof(out[k].name), "layer%d/ln1/scale", l);
        out[k].n = 1;
        out[k].m = md->d;
        out[k++].projectable = 0;
        snprintf(out[k].name, sizeof(out[k].name), "layer%d/ln2/scale", l);
        out[k].n = 1;
        out[k].m = md->d;
        out[k++].projectable = 0;
    }
    return k;
}

/* xorshift fill, deterministic per (param, shard) */
static void fill(float *x, size_t len, uint64_t seed) {
    uint64_t s = seed * 6364136223846793005ull + 1442695040888963407ull;
    for (size_t i = 0; i < len; i++) {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        x[i] = (float)((int64_t)(s >> 40) - (1 << 23)) * 1e-7f;
    }
}

/* C[n x r] += G[n x m] . A^T, A[r x m] (rp::compress) */
static void compress(float *c, const float *g, const float *a, int n, int m) {
    for (int i = 0; i < n; i++)
        for (int j = 0; j < RANK; j++) {
            float acc = 0.0f;
            const float *gi = g + (size_t)i * m;
            const float *aj = a + (size_t)j * m;
            for (int k = 0; k < m; k++) acc += gi[k] * aj[k];
            c[(size_t)i * RANK + j] = acc;
        }
}

/* Ghat[n x m] = C[n x r] . A / denom (rp::decompress) */
static void decompress(float *ghat, const float *c, const float *a, int n,
                       int m, float denom) {
    memset(ghat, 0, (size_t)n * m * sizeof(float));
    for (int i = 0; i < n; i++)
        for (int j = 0; j < RANK; j++) {
            float cij = c[(size_t)i * RANK + j] / denom;
            const float *aj = a + (size_t)j * m;
            float *gi = ghat + (size_t)i * m;
            for (int k = 0; k < m; k++) gi[k] += cij * aj[k];
        }
}

/* fixed ascending shard order, one f32 accumulator per element —
 * Matrix::reduce_sum */
static void reduce_fixed_order(float *dst, float *const srcs[SHARDS],
                               size_t len) {
    memset(dst, 0, len * sizeof(float));
    for (int s = 0; s < SHARDS; s++)
        for (size_t i = 0; i < len; i++) dst[i] += srcs[s][i];
}

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + ts.tv_nsec * 1e-9;
}

/* one data step's reduce+transform work in the given mode; returns a
 * checksum so the work cannot be optimized away */
static float step_once(const Shape *shapes, int nparams,
                       float *grads[MAX_PARAMS][SHARDS],
                       float *comp[MAX_PARAMS][SHARDS], float *proj[MAX_PARAMS],
                       float *red, float *ghat, int compressed) {
    float sink = 0.0f;
    for (int p = 0; p < nparams; p++) {
        const Shape *sh = &shapes[p];
        size_t full = (size_t)sh->n * sh->m;
        if (sh->projectable && compressed) {
            /* workers ship n x r; reduce compressed; decompress once */
            for (int s = 0; s < SHARDS; s++)
                compress(comp[p][s], grads[p][s], proj[p], sh->n, sh->m);
            reduce_fixed_order(red, comp[p], (size_t)sh->n * RANK);
            decompress(ghat, red, proj[p], sh->n, sh->m, (float)SHARDS);
        } else if (sh->projectable) {
            /* full wire: reduce raw grads, compress after the exchange */
            reduce_fixed_order(red, grads[p], full);
            compress(comp[p][0], red, proj[p], sh->n, sh->m);
            decompress(ghat, comp[p][0], proj[p], sh->n, sh->m,
                       (float)SHARDS);
        } else {
            reduce_fixed_order(red, grads[p], full);
            for (size_t i = 0; i < full; i++) ghat[i] = red[i] / SHARDS;
        }
        sink += ghat[0];
    }
    return sink;
}

int main(int argc, char **argv) {
    int iters = argc > 1 ? atoi(argv[1]) : 50;
    if (iters < 1) iters = 1;
    printf("{\n  \"parallelism\": 1,\n  \"provenance\": \"c-mirror dp_mirror\",\n  \"sizes\": [\n");
    int first_row = 1;
    float sink = 0.0f;
    for (size_t mi = 0; mi < sizeof(MODELS) / sizeof(MODELS[0]); mi++) {
        const Model *md = &MODELS[mi];
        Shape shapes[MAX_PARAMS];
        int nparams = model_shapes(md, shapes);
        size_t maxfull = 0;
        for (int p = 0; p < nparams; p++) {
            size_t full = (size_t)shapes[p].n * shapes[p].m;
            if (full > maxfull) maxfull = full;
        }
        static float *grads[MAX_PARAMS][SHARDS];
        static float *comp[MAX_PARAMS][SHARDS];
        static float *proj[MAX_PARAMS];
        for (int p = 0; p < nparams; p++) {
            size_t full = (size_t)shapes[p].n * shapes[p].m;
            for (int s = 0; s < SHARDS; s++) {
                grads[p][s] = malloc(full * sizeof(float));
                fill(grads[p][s], full, 1000u * mi + 10u * p + s);
                comp[p][s] = malloc((size_t)shapes[p].n * RANK * sizeof(float));
            }
            proj[p] = malloc((size_t)RANK * shapes[p].m * sizeof(float));
            fill(proj[p], (size_t)RANK * shapes[p].m, 777u + p);
        }
        float *red = malloc(maxfull * sizeof(float));
        float *ghat = malloc(maxfull * sizeof(float));

        /* the ledger's step_bytes formula, verbatim */
        long sent_comp = 0, sent_full = 0;
        for (int p = 0; p < nparams; p++) {
            long full = 4L * shapes[p].n * shapes[p].m;
            sent_full += SHARDS * full;
            sent_comp += SHARDS * (shapes[p].projectable
                                       ? 4L * shapes[p].n * RANK
                                       : full);
        }

        for (int mode = 1; mode >= 0; mode--) { /* compressed, then full */
            sink += step_once(shapes, nparams, grads, comp, proj, red, ghat,
                              mode); /* warm */
            double t0 = now_s();
            for (int it = 0; it < iters; it++)
                sink += step_once(shapes, nparams, grads, comp, proj, red,
                                  ghat, mode);
            double per_step = (now_s() - t0) / iters;
            long sent = mode ? sent_comp : sent_full;
            printf("%s      {\"model\": \"%s/%s\", \"base_model\": \"%s\", "
                   "\"workers\": 1, \"shards\": %d, \"rank\": %d, "
                   "\"reduce\": \"%s\", \"steps_per_sec\": %.3f, "
                   "\"per_step_sent_bytes\": %ld, "
                   "\"per_step_full_bytes\": %ld, \"comms_ratio\": %.6f, "
                   "\"final_loss\": null}",
                   first_row ? "" : ",\n", md->name,
                   mode ? "compressed" : "full", md->name, SHARDS, RANK,
                   mode ? "compressed" : "full", 1.0 / per_step, sent,
                   sent_full, (double)sent / (double)sent_full);
            first_row = 0;
            fflush(stdout);
        }

        for (int p = 0; p < nparams; p++) {
            for (int s = 0; s < SHARDS; s++) {
                free(grads[p][s]);
                free(comp[p][s]);
            }
            free(proj[p]);
        }
        free(red);
        free(ghat);
    }
    printf("\n  ]\n}\n");
    /* keep the checksum alive */
    fprintf(stderr, "checksum %.6f\n", (double)sink);
    return 0;
}
