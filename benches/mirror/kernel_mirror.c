/* kernel_mirror.c — C mirror of the rust tensor-kernel hot path, used to
 * measure the kernel-ladder PRs on machines where cargo is unavailable
 * (the build container). It seeds the BENCH_kernels.json trajectory
 * points; `cargo bench --bench micro_kernels -- --runtime scope|pool`
 * reproduces the same A/B on the real crate.
 *
 * Three variants, one per committed trajectory point:
 *   0  PR-4: spawn-per-call driver, unfused QKV, plain single-step loops
 *   1  PR-5: persistent pool, fused [d,3d] QKV, unrolled inner loops
 *   2  PR-9: pool + fused QKV + PACKED kernels (B-operand panel packed
 *      into a reused thread-local scratch so the inner loops are
 *      stride-1 on both operands) + the 4 backward-attention
 *      contractions fused into ONE dispatch (one latch instead of four)
 *
 * What is mirrored, faithfully:
 *   - the blocked band kernels of rust/src/tensor/kernels.rs in all
 *     three forms, same K_BLOCK/J_BLOCK (overridable with
 *     -DK_BLOCK=.. -DJ_BLOCK=.. for retuning sweeps) and the same
 *     PAR_MIN_FLOPS engagement gate;
 *   - the row-band parallel driver in both lifecycles: one pthread
 *     spawn+join per call (the thread::scope mirror) vs a persistent
 *     pool (mutex+condvar job board, caller computes band 0) — band
 *     splits identical to the rust code;
 *   - the per-step GEMM call sequence of the native transformer/ViT
 *     models (forward and forward+backward), including one dispatch per
 *     *batched* attention op exactly like tensor/batched.rs, with the
 *     unfused (3 GEMM) vs fused ([d,3d]) QKV layouts, and (variant 2)
 *     the panel-local fused backward-attention dispatch of
 *     model/blocks.rs.
 *
 * What is NOT mirrored (documented in docs/PERFORMANCE.md): elementwise
 * ops (softmax/RMS-norm/GELU — the fused attention-backward op here
 * runs its 4 GEMM contractions per panel but stands dprobs in for
 * dscores, omitting the row-local softmax VJP between them, so all
 * variants omit identical elementwise work), embedding gathers, and the
 * optimizer — so absolute tokens/sec here overstate the full-model
 * numbers the rust bench reports. The pre/post RATIO is the honest
 * measurement: both variants omit the same work.
 *
 * Build & run:  gcc -O2 -pthread -o kernel_mirror kernel_mirror.c -lm
 *               ./kernel_mirror 4          # parallelism (thread budget)
 */
#include <pthread.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

#ifndef K_BLOCK
#define K_BLOCK 64
#endif
#ifndef J_BLOCK
#define J_BLOCK 128
#endif
#define PAR_MIN_FLOPS (1 << 15)
#define MAX_THREADS 16

/* reused per-thread packing scratch: one K×J B-panel (the tn kernel
 * packs at most a K×K A-chunk, covered by the max below) */
#define PACK_CAP (K_BLOCK * (J_BLOCK > K_BLOCK ? J_BLOCK : K_BLOCK))
static _Thread_local float g_pack[PACK_CAP];

static int g_threads = 4;

/* ------------------------------------------------------------------ */
/* band kernels: plain (PR-4), unrolled (PR-5), packed (PR-9) forms   */
/* ------------------------------------------------------------------ */

static void matmul_band_plain(float *c, const float *a, const float *b,
                              int n, int k, int m) {
    for (int j0 = 0; j0 < m; j0 += J_BLOCK) {
        int j1 = j0 + J_BLOCK < m ? j0 + J_BLOCK : m;
        for (int k0 = 0; k0 < k; k0 += K_BLOCK) {
            int k1 = k0 + K_BLOCK < k ? k0 + K_BLOCK : k;
            for (int i = 0; i < n; i++) {
                const float *arow = a + (size_t)i * k;
                float *ctile = c + (size_t)i * m;
                for (int kk = k0; kk < k1; kk++) {
                    float aik = arow[kk];
                    const float *brow = b + (size_t)kk * m;
                    for (int j = j0; j < j1; j++) ctile[j] += aik * brow[j];
                }
            }
        }
    }
}

static void matmul_band_unroll(float *c, const float *a, const float *b,
                               int n, int k, int m) {
    for (int j0 = 0; j0 < m; j0 += J_BLOCK) {
        int j1 = j0 + J_BLOCK < m ? j0 + J_BLOCK : m;
        for (int k0 = 0; k0 < k; k0 += K_BLOCK) {
            int k1 = k0 + K_BLOCK < k ? k0 + K_BLOCK : k;
            for (int i = 0; i < n; i++) {
                const float *arow = a + (size_t)i * k;
                float *ctile = c + (size_t)i * m;
                int kk = k0;
                for (; kk + 4 <= k1; kk += 4) {
                    float a0 = arow[kk], a1 = arow[kk + 1];
                    float a2 = arow[kk + 2], a3 = arow[kk + 3];
                    const float *b0 = b + (size_t)kk * m;
                    const float *b1 = b + (size_t)(kk + 1) * m;
                    const float *b2 = b + (size_t)(kk + 2) * m;
                    const float *b3 = b + (size_t)(kk + 3) * m;
                    for (int j = j0; j < j1; j++) {
                        float acc = ctile[j];
                        acc += a0 * b0[j];
                        acc += a1 * b1[j];
                        acc += a2 * b2[j];
                        acc += a3 * b3[j];
                        ctile[j] = acc;
                    }
                }
                for (; kk < k1; kk++) {
                    float aik = arow[kk];
                    const float *brow = b + (size_t)kk * m;
                    for (int j = j0; j < j1; j++) ctile[j] += aik * brow[j];
                }
            }
        }
    }
}

/* PR-9: the K×J panel of B is copied into the contiguous reused
 * scratch, then the same 4-step chained accumulation runs stride-1 on
 * both operands. Packing only moves bytes; per-element ascending-k
 * accumulation (one f32 chain through C memory) is untouched, so the
 * result is raw-bits identical to the plain/unrolled forms. */
static void matmul_band_packed(float *c, const float *a, const float *b,
                               int n, int k, int m) {
    float *pack = g_pack;
    for (int j0 = 0; j0 < m; j0 += J_BLOCK) {
        int j1 = j0 + J_BLOCK < m ? j0 + J_BLOCK : m;
        int jw = j1 - j0;
        for (int k0 = 0; k0 < k; k0 += K_BLOCK) {
            int k1 = k0 + K_BLOCK < k ? k0 + K_BLOCK : k;
            int kh = k1 - k0;
            for (int kk = 0; kk < kh; kk++)
                memcpy(pack + (size_t)kk * jw,
                       b + (size_t)(k0 + kk) * m + j0, jw * sizeof(float));
            for (int i = 0; i < n; i++) {
                const float *arow = a + (size_t)i * k + k0;
                float *ctile = c + (size_t)i * m + j0;
                int kk = 0;
                for (; kk + 8 <= kh; kk += 8) {
                    float a0 = arow[kk], a1 = arow[kk + 1];
                    float a2 = arow[kk + 2], a3 = arow[kk + 3];
                    float a4 = arow[kk + 4], a5 = arow[kk + 5];
                    float a6 = arow[kk + 6], a7 = arow[kk + 7];
                    const float *b0 = pack + (size_t)kk * jw;
                    for (int j = 0; j < jw; j++) {
                        const float *bp = b0 + j;
                        float acc = ctile[j];
                        acc += a0 * bp[0];
                        acc += a1 * bp[(size_t)jw];
                        acc += a2 * bp[(size_t)2 * jw];
                        acc += a3 * bp[(size_t)3 * jw];
                        acc += a4 * bp[(size_t)4 * jw];
                        acc += a5 * bp[(size_t)5 * jw];
                        acc += a6 * bp[(size_t)6 * jw];
                        acc += a7 * bp[(size_t)7 * jw];
                        ctile[j] = acc;
                    }
                }
                for (; kk < kh; kk++) {
                    float aik = arow[kk];
                    const float *brow = pack + (size_t)kk * jw;
                    for (int j = 0; j < jw; j++) ctile[j] += aik * brow[j];
                }
            }
        }
    }
}

static void nt_band_plain(float *c, const float *a, const float *b, int n,
                          int k, int m, float alpha) {
    for (int j0 = 0; j0 < m; j0 += K_BLOCK) {
        int j1 = j0 + K_BLOCK < m ? j0 + K_BLOCK : m;
        for (int i = 0; i < n; i++) {
            const float *arow = a + (size_t)i * k;
            for (int j = j0; j < j1; j++) {
                const float *brow = b + (size_t)j * k;
                float acc = 0.0f;
                for (int t = 0; t < k; t++) acc += arow[t] * brow[t];
                c[(size_t)i * m + j] = acc * alpha;
            }
        }
    }
}

static void nt_band_unroll(float *c, const float *a, const float *b, int n,
                           int k, int m, float alpha) {
    for (int j0 = 0; j0 < m; j0 += K_BLOCK) {
        int j1 = j0 + K_BLOCK < m ? j0 + K_BLOCK : m;
        for (int i = 0; i < n; i++) {
            const float *arow = a + (size_t)i * k;
            float *crow = c + (size_t)i * m;
            int j = j0;
            for (; j + 4 <= j1; j += 4) {
                const float *b0 = b + (size_t)j * k;
                const float *b1 = b + (size_t)(j + 1) * k;
                const float *b2 = b + (size_t)(j + 2) * k;
                const float *b3 = b + (size_t)(j + 3) * k;
                float acc0 = 0, acc1 = 0, acc2 = 0, acc3 = 0;
                for (int t = 0; t < k; t++) {
                    float x = arow[t];
                    acc0 += x * b0[t];
                    acc1 += x * b1[t];
                    acc2 += x * b2[t];
                    acc3 += x * b3[t];
                }
                crow[j] = acc0 * alpha;
                crow[j + 1] = acc1 * alpha;
                crow[j + 2] = acc2 * alpha;
                crow[j + 3] = acc3 * alpha;
            }
            for (; j < j1; j++) {
                const float *brow = b + (size_t)j * k;
                float acc = 0.0f;
                for (int t = 0; t < k; t++) acc += arow[t] * brow[t];
                crow[j] = acc * alpha;
            }
        }
    }
}

/* PR-9: B rows of the j-tile are packed; k is blocked by J_BLOCK so the
 * packed tile fits the scratch, the 4 dot lanes chain their partials
 * through C (f32 store/load is exact — same rounding sequence as one
 * register chain), and alpha is applied in ONE final pass per j-tile
 * (the identical mul-by-alpha the naive form performs on each finished
 * dot). Raw-bits identical to the plain/unrolled forms. */
static void nt_band_packed(float *c, const float *a, const float *b, int n,
                           int k, int m, float alpha) {
    float *pack = g_pack;
    for (int j0 = 0; j0 < m; j0 += K_BLOCK) {
        int j1 = j0 + K_BLOCK < m ? j0 + K_BLOCK : m;
        int jt = j1 - j0;
        for (int k0 = 0; k0 < k; k0 += J_BLOCK) {
            int k1 = k0 + J_BLOCK < k ? k0 + J_BLOCK : k;
            int kw = k1 - k0;
            for (int jj = 0; jj < jt; jj++)
                memcpy(pack + (size_t)jj * kw,
                       b + (size_t)(j0 + jj) * k + k0, kw * sizeof(float));
            for (int i = 0; i < n; i++) {
                const float *arow = a + (size_t)i * k + k0;
                float *crow = c + (size_t)i * m + j0;
                int j = 0;
                for (; j + 8 <= jt; j += 8) {
                    const float *b0 = pack + (size_t)j * kw;
                    const float *b1 = b0 + kw, *b2 = b1 + kw, *b3 = b2 + kw;
                    const float *b4 = b3 + kw, *b5 = b4 + kw, *b6 = b5 + kw,
                                *b7 = b6 + kw;
                    float acc0 = 0, acc1 = 0, acc2 = 0, acc3 = 0;
                    float acc4 = 0, acc5 = 0, acc6 = 0, acc7 = 0;
                    if (k0 > 0) {
                        acc0 = crow[j], acc1 = crow[j + 1];
                        acc2 = crow[j + 2], acc3 = crow[j + 3];
                        acc4 = crow[j + 4], acc5 = crow[j + 5];
                        acc6 = crow[j + 6], acc7 = crow[j + 7];
                    }
                    for (int t = 0; t < kw; t++) {
                        float x = arow[t];
                        acc0 += x * b0[t];
                        acc1 += x * b1[t];
                        acc2 += x * b2[t];
                        acc3 += x * b3[t];
                        acc4 += x * b4[t];
                        acc5 += x * b5[t];
                        acc6 += x * b6[t];
                        acc7 += x * b7[t];
                    }
                    crow[j] = acc0, crow[j + 1] = acc1;
                    crow[j + 2] = acc2, crow[j + 3] = acc3;
                    crow[j + 4] = acc4, crow[j + 5] = acc5;
                    crow[j + 6] = acc6, crow[j + 7] = acc7;
                }
                for (; j + 4 <= jt; j += 4) {
                    const float *b0 = pack + (size_t)j * kw;
                    const float *b1 = b0 + kw, *b2 = b1 + kw, *b3 = b2 + kw;
                    float acc0 = 0, acc1 = 0, acc2 = 0, acc3 = 0;
                    if (k0 > 0) {
                        acc0 = crow[j], acc1 = crow[j + 1];
                        acc2 = crow[j + 2], acc3 = crow[j + 3];
                    }
                    for (int t = 0; t < kw; t++) {
                        float x = arow[t];
                        acc0 += x * b0[t];
                        acc1 += x * b1[t];
                        acc2 += x * b2[t];
                        acc3 += x * b3[t];
                    }
                    crow[j] = acc0, crow[j + 1] = acc1;
                    crow[j + 2] = acc2, crow[j + 3] = acc3;
                }
                for (; j < jt; j++) {
                    const float *brow = pack + (size_t)j * kw;
                    float acc = k0 > 0 ? crow[j] : 0.0f;
                    for (int t = 0; t < kw; t++) acc += arow[t] * brow[t];
                    crow[j] = acc;
                }
            }
        }
        for (int i = 0; i < n; i++) {
            float *crow = c + (size_t)i * m;
            for (int j = j0; j < j1; j++) crow[j] *= alpha;
        }
    }
}

static void tn_band_plain(float *c, const float *a, const float *b, int rows,
                          int acols, int m, int i0, int n) {
    for (int kk = 0; kk < rows; kk++) {
        const float *arow = a + (size_t)kk * acols;
        const float *brow = b + (size_t)kk * m;
        for (int i = 0; i < n; i++) {
            float aki = arow[i0 + i];
            float *crow = c + (size_t)i * m;
            for (int j = 0; j < m; j++) crow[j] += aki * brow[j];
        }
    }
}

static void tn_band_unroll(float *c, const float *a, const float *b, int rows,
                           int acols, int m, int i0, int n) {
    int kk = 0;
    for (; kk + 2 <= rows; kk += 2) {
        const float *ar0 = a + (size_t)kk * acols;
        const float *ar1 = a + (size_t)(kk + 1) * acols;
        const float *br0 = b + (size_t)kk * m;
        const float *br1 = b + (size_t)(kk + 1) * m;
        for (int i = 0; i < n; i++) {
            float a0 = ar0[i0 + i], a1 = ar1[i0 + i];
            float *crow = c + (size_t)i * m;
            for (int j = 0; j < m; j++) {
                float acc = crow[j];
                acc += a0 * br0[j];
                acc += a1 * br1[j];
                crow[j] = acc;
            }
        }
    }
    if (kk < rows) /* tail: at most one contraction row, plain form */
        tn_band_plain(c, a + (size_t)kk * acols, b + (size_t)kk * m,
                      rows - kk, acols, m, i0, n);
}

/* PR-9: the strided A-column chunk (stride acols between contraction
 * rows) is packed into contiguous rows of the scratch, then the 2-step
 * chained axpy runs from the pack. Contraction rows are consumed in the
 * same ascending order, chained through C memory, so the result is
 * raw-bits identical to the plain/unrolled forms. */
static void tn_band_packed(float *c, const float *a, const float *b, int rows,
                           int acols, int m, int i0, int n) {
    float *pack = g_pack;
    for (int r0 = 0; r0 < rows; r0 += K_BLOCK) {
        int r1 = r0 + K_BLOCK < rows ? r0 + K_BLOCK : rows;
        int rh = r1 - r0;
        for (int it = 0; it < n; it += K_BLOCK) {
            int i2 = it + K_BLOCK < n ? it + K_BLOCK : n;
            int iw = i2 - it;
            for (int rr = 0; rr < rh; rr++)
                memcpy(pack + (size_t)rr * iw,
                       a + (size_t)(r0 + rr) * acols + i0 + it,
                       iw * sizeof(float));
            for (int j0 = 0; j0 < m; j0 += J_BLOCK) {
                int j1 = j0 + J_BLOCK < m ? j0 + J_BLOCK : m;
                for (int i = 0; i < iw; i++) {
                    float *crow = c + (size_t)(it + i) * m;
                    int rr = 0;
                    for (; rr + 4 <= rh; rr += 4) {
                        float a0 = pack[(size_t)rr * iw + i];
                        float a1 = pack[(size_t)(rr + 1) * iw + i];
                        float a2 = pack[(size_t)(rr + 2) * iw + i];
                        float a3 = pack[(size_t)(rr + 3) * iw + i];
                        const float *br0 = b + (size_t)(r0 + rr) * m;
                        const float *br1 = br0 + m;
                        const float *br2 = br1 + m;
                        const float *br3 = br2 + m;
                        for (int j = j0; j < j1; j++) {
                            float acc = crow[j];
                            acc += a0 * br0[j];
                            acc += a1 * br1[j];
                            acc += a2 * br2[j];
                            acc += a3 * br3[j];
                            crow[j] = acc;
                        }
                    }
                    for (; rr < rh; rr++) {
                        float a0 = pack[(size_t)rr * iw + i];
                        const float *br = b + (size_t)(r0 + rr) * m;
                        for (int j = j0; j < j1; j++) crow[j] += a0 * br[j];
                    }
                }
            }
        }
    }
}

/* form: 0 = plain (PR-4), 1 = unrolled (PR-5), 2 = packed (PR-9) */
static void kern_n(int form, float *c, const float *a, const float *b, int n,
                   int k, int m) {
    if (form == 2) matmul_band_packed(c, a, b, n, k, m);
    else if (form == 1) matmul_band_unroll(c, a, b, n, k, m);
    else matmul_band_plain(c, a, b, n, k, m);
}

static void kern_nt(int form, float *c, const float *a, const float *b, int n,
                    int k, int m, float alpha) {
    if (form == 2) nt_band_packed(c, a, b, n, k, m, alpha);
    else if (form == 1) nt_band_unroll(c, a, b, n, k, m, alpha);
    else nt_band_plain(c, a, b, n, k, m, alpha);
}

static void kern_tn(int form, float *c, const float *a, const float *b,
                    int rows, int acols, int m, int i0, int n) {
    if (form == 2) tn_band_packed(c, a, b, rows, acols, m, i0, n);
    else if (form == 1) tn_band_unroll(c, a, b, rows, acols, m, i0, n);
    else tn_band_plain(c, a, b, rows, acols, m, i0, n);
}

/* ------------------------------------------------------------------ */
/* one GEMM "op": kind + shapes (+panel batch for the attention ops)  */
/* ------------------------------------------------------------------ */

/* OP_ATTN_BWD (PR-9) is the fused backward-attention dispatch of
 * model/blocks.rs: ONE submission whose per-panel body runs all four
 * backward contractions (dprobs = dctx·Vᵀ, dV = probsᵀ·dctx,
 * dQ = dS·K, dK = dSᵀ·Q) — one latch instead of four. The mirror
 * stands dprobs in for dscores (the softmax VJP between them is
 * elementwise and excluded from every variant, see header). Shapes are
 * carried as n=s, k=dh, m=s. */
typedef enum { OP_N, OP_NT, OP_TN, OP_ATTN_BWD } OpKind;

typedef struct {
    OpKind kind;
    int batch; /* 1 for plain matrix ops; b*h for batched attention ops */
    int n, k, m;
    float *a, *b, *c;
} Op;

typedef struct {
    const Op *op;
    int form;
    int first, count; /* band: rows for plain ops, panels for batched */
} Band;

/* operand element counts per kind: N: a n*k, b k*m, c n*m;
 * NT: b m*k; TN (n=rows, k=acols): a n*k, b n*m, c k*m;
 * ATTN_BWD (n=s, k=dh, m=s): a = dctx|probs|q|k, b = v,
 * c = dprobs|dv|dq|dk */
static void op_sizes(const Op *o, size_t *an, size_t *bn, size_t *cn) {
    if (o->kind == OP_ATTN_BWD) {
        size_t s = o->n, dh = o->k;
        *an = s * s + 3 * s * dh;
        *bn = s * dh;
        *cn = s * s + 3 * s * dh;
        return;
    }
    *an = (size_t)o->n * o->k;
    *bn = o->kind == OP_NT ? (size_t)o->m * o->k
          : o->kind == OP_TN ? (size_t)o->n * o->m
                             : (size_t)o->k * o->m;
    *cn = o->kind == OP_TN ? (size_t)o->k * o->m : (size_t)o->n * o->m;
}

/* the per-panel body of the fused backward-attention dispatch */
static void attn_bwd_panel(int form, const Op *o, float *a, float *b,
                           float *c) {
    int s = o->n, dh = o->k;
    float *dctx = a, *probs = a + (size_t)s * dh,
          *q = probs + (size_t)s * s, *kp = q + (size_t)s * dh;
    float *v = b;
    float *dprobs = c, *dv = dprobs + (size_t)s * s,
          *dq = dv + (size_t)s * dh, *dk = dq + (size_t)s * dh;
    kern_nt(form, dprobs, dctx, v, s, dh, s, 1.0f); /* dprobs = dctx·Vᵀ */
    memset(dv, 0, (size_t)s * dh * sizeof(float));
    kern_tn(form, dv, probs, dctx, s, s, dh, 0, s); /* dV = probsᵀ·dctx */
    memset(dq, 0, (size_t)s * dh * sizeof(float));
    kern_n(form, dq, dprobs, kp, s, s, dh); /* dQ = dS·K */
    memset(dk, 0, (size_t)s * dh * sizeof(float));
    kern_tn(form, dk, dprobs, q, s, s, dh, 0, s); /* dK = dSᵀ·Q */
}

static void run_band(const Band *bd) {
    const Op *o = bd->op;
    size_t an, bn, cn;
    op_sizes(o, &an, &bn, &cn);
    if (o->batch > 1) { /* bands are whole panels */
        for (int p = bd->first; p < bd->first + bd->count; p++) {
            float *a = o->a + (size_t)p * an, *b = o->b + (size_t)p * bn,
                  *c = o->c + (size_t)p * cn;
            switch (o->kind) {
            case OP_N:
                memset(c, 0, cn * sizeof(float));
                kern_n(bd->form, c, a, b, o->n, o->k, o->m);
                break;
            case OP_NT:
                kern_nt(bd->form, c, a, b, o->n, o->k, o->m, 1.0f);
                break;
            case OP_TN:
                memset(c, 0, cn * sizeof(float));
                kern_tn(bd->form, c, a, b, o->n, o->k, o->m, 0, o->k);
                break;
            case OP_ATTN_BWD:
                attn_bwd_panel(bd->form, o, a, b, c);
                break;
            }
        }
        return;
    }
    /* plain op: bands are output rows (TN bands are A-columns) */
    int first = bd->first, count = bd->count;
    switch (o->kind) {
    case OP_N: {
        float *c = o->c + (size_t)first * o->m;
        memset(c, 0, (size_t)count * o->m * sizeof(float));
        kern_n(bd->form, c, o->a + (size_t)first * o->k, o->b, count, o->k,
               o->m);
        break;
    }
    case OP_NT: {
        float *c = o->c + (size_t)first * o->m;
        kern_nt(bd->form, c, o->a + (size_t)first * o->k, o->b, count, o->k,
                o->m, 1.0f);
        break;
    }
    case OP_TN: {
        float *c = o->c + (size_t)first * o->m;
        memset(c, 0, (size_t)count * o->m * sizeof(float));
        kern_tn(bd->form, c, o->a, o->b, o->n, o->k, o->m, first, count);
        break;
    }
    default:
        break; /* ATTN_BWD is always batched */
    }
}

/* rows available for banding + the flop gate, mirroring par_rows */
static int op_rows(const Op *o) { return o->batch > 1 ? o->batch : (o->kind == OP_TN ? o->k : o->n); }
static long op_flops(const Op *o) {
    long f = (long)o->n * o->k * o->m;
    if (o->kind == OP_TN) f = (long)o->n * o->k * o->m; /* rows*acols*m */
    if (o->kind == OP_ATTN_BWD) f = 4L * o->n * o->k * o->m;
    return f * (o->batch > 1 ? o->batch : 1);
}

/* ------------------------------------------------------------------ */
/* driver 1: spawn-per-call (the thread::scope mirror)                */
/* ------------------------------------------------------------------ */

static void *band_thread(void *arg) {
    run_band((Band *)arg);
    return NULL;
}

static void dispatch_scope(const Op *o, int form) {
    int rows = op_rows(o);
    int threads = g_threads < rows ? g_threads : rows;
    if (op_flops(o) < PAR_MIN_FLOPS || threads <= 1) {
        Band bd = {o, form, 0, rows};
        run_band(&bd);
        return;
    }
    int chunk = (rows + threads - 1) / threads;
    pthread_t tids[MAX_THREADS];
    Band bands[MAX_THREADS];
    int nb = 0;
    for (int r0 = 0; r0 < rows; r0 += chunk) {
        int take = chunk < rows - r0 ? chunk : rows - r0;
        bands[nb] = (Band){o, form, r0, take};
        pthread_create(&tids[nb], NULL, band_thread, &bands[nb]);
        nb++;
    }
    for (int i = 0; i < nb; i++) pthread_join(tids[i], NULL);
}

/* ------------------------------------------------------------------ */
/* driver 2: persistent pool (mutex+condvar job board, caller works)  */
/* ------------------------------------------------------------------ */

static pthread_mutex_t pool_mu = PTHREAD_MUTEX_INITIALIZER;
static pthread_cond_t pool_cv = PTHREAD_COND_INITIALIZER;
static pthread_cond_t done_cv = PTHREAD_COND_INITIALIZER;
static Band pool_bands[MAX_THREADS];
static int pool_nbands = 0, pool_taken = 0, pool_done = 0;
static long pool_gen = 0;
static int pool_workers = 0, pool_shutdown = 0;

static void *pool_worker(void *arg) {
    (void)arg;
    long seen = 0;
    pthread_mutex_lock(&pool_mu);
    for (;;) {
        while (!pool_shutdown && (pool_gen == seen || pool_taken >= pool_nbands))
            pthread_cond_wait(&pool_cv, &pool_mu);
        if (pool_shutdown) break;
        seen = pool_gen;
        while (pool_taken < pool_nbands) {
            Band *bd = &pool_bands[pool_taken++];
            pthread_mutex_unlock(&pool_mu);
            run_band(bd);
            pthread_mutex_lock(&pool_mu);
            pool_done++;
            if (pool_done == pool_nbands) pthread_cond_signal(&done_cv);
        }
    }
    pthread_mutex_unlock(&pool_mu);
    return NULL;
}

static pthread_t pool_tids[MAX_THREADS];

static void pool_start(int workers) {
    pool_workers = workers;
    for (int i = 0; i < workers; i++)
        pthread_create(&pool_tids[i], NULL, pool_worker, NULL);
}

static void pool_stop(void) {
    pthread_mutex_lock(&pool_mu);
    pool_shutdown = 1;
    pthread_cond_broadcast(&pool_cv);
    pthread_mutex_unlock(&pool_mu);
    for (int i = 0; i < pool_workers; i++) pthread_join(pool_tids[i], NULL);
    pool_shutdown = 0;
    pool_workers = 0;
}

static void dispatch_pool(const Op *o, int form) {
    int rows = op_rows(o);
    int threads = g_threads < rows ? g_threads : rows;
    if (op_flops(o) < PAR_MIN_FLOPS || threads <= 1) {
        Band bd = {o, form, 0, rows};
        run_band(&bd);
        return;
    }
    int chunk = (rows + threads - 1) / threads;
    /* caller owns band 0; the rest go on the job board */
    Band own = {o, form, 0, chunk < rows ? chunk : rows};
    pthread_mutex_lock(&pool_mu);
    pool_nbands = 0;
    for (int r0 = own.count; r0 < rows; r0 += chunk) {
        int take = chunk < rows - r0 ? chunk : rows - r0;
        pool_bands[pool_nbands++] = (Band){o, form, r0, take};
    }
    pool_taken = 0;
    pool_done = 0;
    pool_gen++;
    int nbands = pool_nbands;
    pthread_cond_broadcast(&pool_cv);
    pthread_mutex_unlock(&pool_mu);
    run_band(&own);
    pthread_mutex_lock(&pool_mu);
    while (pool_done < nbands) pthread_cond_wait(&done_cv, &pool_mu);
    pool_nbands = 0;
    pthread_mutex_unlock(&pool_mu);
}

/* ------------------------------------------------------------------ */
/* model GEMM mixes                                                   */
/* ------------------------------------------------------------------ */

typedef struct {
    const char *name, *family;
    int vocab, seq, d, layers, heads, dff;
    int image, patch, channels, classes; /* vit only */
} Model;

static const Model MODELS[] = {
    {"lora-small", "lm", 128, 32, 64, 2, 4, 128, 0, 0, 0, 0},
    {"lora-base", "lm", 256, 64, 128, 2, 4, 256, 0, 0, 0, 0},
    {"vit-small", "vit", 0, 0, 64, 2, 4, 128, 16, 4, 3, 10},
};
#define BATCH 4

typedef struct {
    Op ops[512];
    int n;
} Mix;

static float *buf(size_t n) {
    float *p = malloc(n * sizeof(float));
    for (size_t i = 0; i < n; i++) p[i] = (float)((i * 2654435761u >> 8) & 1023) / 1024.0f - 0.5f;
    return p;
}

static void push(Mix *mx, OpKind kind, int batch, int n, int k, int m) {
    Op *o = &mx->ops[mx->n++];
    *o = (Op){kind, batch, n, k, m, NULL, NULL, NULL};
    size_t an, bn, cn;
    op_sizes(o, &an, &bn, &cn);
    o->a = buf((size_t)batch * an);
    o->b = buf((size_t)batch * bn);
    o->c = buf((size_t)batch * cn);
}

/* forward GEMM sequence for one step; fused toggles the QKV layout,
 * fusedattn collapses the 4 backward attention ops into one dispatch */
static void build_mix(Mix *mx, const Model *md, int fused, int fusedattn,
                      int backward) {
    mx->n = 0;
    int s = md->family[0] == 'v' ? (md->image / md->patch) * (md->image / md->patch) + 1
                                 : md->seq;
    int bs = BATCH * s, d = md->d, f = md->dff, h = md->heads, dh = d / h;
    int panels = BATCH * h;
    if (md->family[0] == 'v') { /* patch embedding */
        int np = s - 1, pd = md->channels * md->patch * md->patch;
        push(mx, OP_N, 1, BATCH * np, pd, d);
    }
    for (int l = 0; l < md->layers; l++) {
        if (fused) push(mx, OP_N, 1, bs, d, 3 * d);
        else for (int i = 0; i < 3; i++) push(mx, OP_N, 1, bs, d, d);
        push(mx, OP_NT, panels, s, dh, s); /* QK^T  */
        push(mx, OP_N, panels, s, s, dh);  /* P @ V */
        push(mx, OP_N, 1, bs, d, d);       /* Wo    */
        push(mx, OP_N, 1, bs, d, f);       /* W1    */
        push(mx, OP_N, 1, bs, f, d);       /* W2    */
    }
    if (md->family[0] == 'v') push(mx, OP_N, 1, BATCH, d, md->classes);
    else push(mx, OP_NT, 1, BATCH * md->seq / 2, d, md->vocab); /* tied head */
    if (!backward) return;
    /* backward contractions, reverse order (shapes are what matters) */
    if (md->family[0] == 'v') {
        push(mx, OP_TN, 1, BATCH, d, md->classes);  /* dW head  */
        push(mx, OP_NT, 1, BATCH, md->classes, d);  /* dfeats   */
    } else {
        int nex = BATCH * md->seq / 2;
        push(mx, OP_N, 1, nex, md->vocab, d);  /* dnf   */
        push(mx, OP_TN, 1, nex, md->vocab, d); /* demb  */
    }
    for (int l = 0; l < md->layers; l++) {
        push(mx, OP_TN, 1, bs, f, d);      /* dW2    */
        push(mx, OP_NT, 1, bs, d, f);      /* da     */
        push(mx, OP_TN, 1, bs, d, f);      /* dW1    */
        push(mx, OP_NT, 1, bs, f, d);      /* dn2    */
        push(mx, OP_TN, 1, bs, d, d);      /* dWo    */
        push(mx, OP_NT, 1, bs, d, d);      /* dctx   */
        if (fusedattn) {
            push(mx, OP_ATTN_BWD, panels, s, dh, s); /* dprobs|dV|dQ|dK */
        } else {
            push(mx, OP_NT, panels, s, dh, s); /* dprobs */
            push(mx, OP_TN, panels, s, s, dh); /* dV     */
            push(mx, OP_N, panels, s, s, dh);  /* dQ     */
            push(mx, OP_TN, panels, s, s, dh); /* dK     */
        }
        if (fused) {
            push(mx, OP_TN, 1, bs, d, 3 * d); /* dWqkv */
            push(mx, OP_NT, 1, bs, 3 * d, d); /* dn1   */
        } else {
            for (int i = 0; i < 3; i++) push(mx, OP_TN, 1, bs, d, d);
            for (int i = 0; i < 3; i++) push(mx, OP_NT, 1, bs, d, d);
        }
    }
    if (md->family[0] == 'v') {
        int np = s - 1, pd = md->channels * md->patch * md->patch;
        push(mx, OP_TN, 1, BATCH * np, pd, d); /* dPatchEmbed */
    }
}

static void free_mix(Mix *mx) {
    for (int i = 0; i < mx->n; i++) {
        free(mx->ops[i].a);
        free(mx->ops[i].b);
        free(mx->ops[i].c);
    }
}

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + ts.tv_nsec * 1e-9;
}

/* tokens/sec for one mix under one (driver, kernel-form) variant */
static double measure(const Mix *mx, int pool, int form, int tokens,
                      int iters) {
    void (*dispatch)(const Op *, int) = pool ? dispatch_pool : dispatch_scope;
    for (int i = 0; i < mx->n; i++) dispatch(&mx->ops[i], form); /* warm */
    double t0 = now_s();
    for (int it = 0; it < iters; it++)
        for (int i = 0; i < mx->n; i++) dispatch(&mx->ops[i], form);
    double dt = (now_s() - t0) / iters;
    return tokens / dt;
}

/* raw-bits check: every kernel form must agree exactly on a ragged
 * rectangle (the rust property tests do this against the naive oracle;
 * here the plain form IS the oracle) */
static int selfcheck(void) {
    int n = 37, k = 71, m = 53, bad = 0;
    float *a = buf((size_t)n * k), *b = buf((size_t)k * m);
    float *bt = buf((size_t)m * k);
    float *c0 = calloc((size_t)n * m, sizeof(float));
    float *c2 = calloc((size_t)n * m, sizeof(float));
    matmul_band_plain(c0, a, b, n, k, m);
    matmul_band_packed(c2, a, b, n, k, m);
    bad |= memcmp(c0, c2, (size_t)n * m * sizeof(float)) != 0;
    memset(c0, 0, (size_t)n * m * sizeof(float));
    memset(c2, 0, (size_t)n * m * sizeof(float));
    nt_band_plain(c0, a, bt, n, k, m, 0.125f);
    nt_band_packed(c2, a, bt, n, k, m, 0.125f);
    bad |= memcmp(c0, c2, (size_t)n * m * sizeof(float)) != 0;
    float *ct0 = calloc((size_t)k * m, sizeof(float));
    float *ct2 = calloc((size_t)k * m, sizeof(float));
    /* tn: a is rows×acols = n×k, band covers all k columns */
    tn_band_plain(ct0, a, b, n, k, m, 0, k);
    tn_band_packed(ct2, a, b, n, k, m, 0, k);
    bad |= memcmp(ct0, ct2, (size_t)k * m * sizeof(float)) != 0;
    free(a); free(b); free(bt); free(c0); free(c2); free(ct0); free(ct2);
    return bad;
}

int main(int argc, char **argv) {
    g_threads = argc > 1 ? atoi(argv[1]) : 4;
    if (g_threads < 1) g_threads = 1;
    if (g_threads > MAX_THREADS) g_threads = MAX_THREADS;
    int iters = argc > 2 ? atoi(argv[2]) : 12;
    if (selfcheck()) {
        fprintf(stderr, "FATAL: packed kernels diverge from plain oracle\n");
        return 1;
    }
    pool_start(g_threads - 1);
    printf("{\n  \"parallelism\": %d,\n  \"k_block\": %d,\n  \"j_block\": %d,\n  \"variants\": [\n",
           g_threads, K_BLOCK, J_BLOCK);
    for (int variant = 0; variant < 3; variant++) {
        /* variant 0: PR-4 (scope spawn, unfused, plain loops)
         * variant 1: PR-5 (pool, fused QKV, unrolled loops)
         * variant 2: PR-9 (pool, fused QKV, packed kernels, fused
         *            backward-attention dispatch)                   */
        int pool = variant >= 1, fused = variant >= 1;
        int form = variant, fusedattn = variant == 2;
        printf("    {\"runtime\": \"%s\", \"qkv\": \"%s\", \"kernels\": \"%s\", \"attn_bwd\": \"%s\", \"sizes\": [\n",
               pool ? "pool" : "scope", fused ? "fused" : "unfused",
               form == 2 ? "packed" : form == 1 ? "unrolled" : "plain",
               fusedattn ? "fused-dispatch" : "per-op");
        for (size_t mi = 0; mi < sizeof(MODELS) / sizeof(MODELS[0]); mi++) {
            const Model *md = &MODELS[mi];
            int s = md->family[0] == 'v'
                        ? (md->image / md->patch) * (md->image / md->patch) + 1
                        : md->seq;
            int tokens = BATCH * s;
            Mix fwd, both;
            build_mix(&fwd, md, fused, fusedattn, 0);
            build_mix(&both, md, fused, fusedattn, 1);
            double f = measure(&fwd, pool, form, tokens, iters);
            double fb = measure(&both, pool, form, tokens, iters);
            free_mix(&fwd);
            free_mix(&both);
            printf("      {\"model\": \"%s\", \"family\": \"%s\", "
                   "\"tokens_per_batch\": %d, \"forward_tok_s\": %.1f, "
                   "\"forward_backward_tok_s\": %.1f}%s\n",
                   md->name, md->family, tokens, f, fb,
                   mi + 1 < sizeof(MODELS) / sizeof(MODELS[0]) ? "," : "");
            fflush(stdout);
        }
        printf("    ]}%s\n", variant < 2 ? "," : "");
    }
    printf("  ]\n}\n");
    pool_stop();
    return 0;
}
