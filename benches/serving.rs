//! Multi-adapter serving throughput bench (not a paper table; grows the
//! serving trajectory) — APPENDS a snapshot to `BENCH_serving.json`.
//!
//! For every native LM catalog size (skipping `lora-base` under
//! `--quick`, same as micro_kernels) it decodes a fixed mixed-adapter
//! workload at batch sizes 1 and 4 through `model::decode::serve_greedy`
//! — the KV-cache greedy path with per-request `(xB)A` adapter
//! corrections — and reports:
//!
//!   * `decode_tok_s`   — generated tokens/sec for the batched call
//!   * `seq_tok_s`      — the same requests as b sequential single-
//!                        adapter calls (the bit-compare oracle path)
//!   * `batch_speedup`  — decode_tok_s / seq_tok_s (1.0 by construction
//!                        at b=1; the batching win at b=4)
//!   * `p50_ms`/`p95_ms`— per-batch decode latency percentiles
//!   * `kv_bytes`       — KV-cache footprint at this (b, s):
//!                        `n_layers * 2 * b * s * d_model * 4`
//!
//! Before timing, each size runs `runtime::serve::oracle_check` once at
//! the largest batch — a bit-identity tripwire, not a tolerance check —
//! and the bench exits non-zero on any mismatch, so a throughput number
//! can never be recorded for a wrong result.
//!
//! `BENCH_serving.json` is a schema-2 TRAJECTORY like BENCH_kernels.json
//! (append-only; see docs/SERVING.md §6 for the methodology and
//! docs/PERFORMANCE.md for the schema precedent).
//!
//! Run: cargo bench --bench serving [-- --quick --parallelism N]

use flora::bench::contract;
use flora::bench::paper::BenchArgs;
use flora::bench::time_it;
use flora::model::decode::serve_greedy;
use flora::model::TransformerConfig;
use flora::runtime::serve::oracle_check;
use flora::runtime::AdapterRegistry;
use flora::util::json::Json;

const RANK: usize = 8;
const BATCHES: [usize; 2] = [1, 4];

struct Cell {
    key: String,
    base_model: &'static str,
    batch: usize,
    prompt_len: usize,
    max_new: usize,
    decode_tok_s: f64,
    seq_tok_s: f64,
    p50_ms: f64,
    p95_ms: f64,
    kv_bytes: usize,
}

impl Cell {
    fn speedup(&self) -> f64 {
        if self.seq_tok_s > 0.0 {
            self.decode_tok_s / self.seq_tok_s
        } else {
            0.0
        }
    }
}

fn prompt_for(req: usize, prompt_len: usize, vocab: usize) -> Vec<i32> {
    (0..prompt_len).map(|j| ((3 + req + 2 * j) % vocab) as i32).collect()
}

fn measure(name: &'static str, cfg: TransformerConfig, iters: usize) -> Vec<Cell> {
    let base = cfg.init(0);
    let max_b = *BATCHES.iter().max().unwrap();
    let mut reg = AdapterRegistry::new(max_b);
    let names: Vec<String> = (0..max_b).map(|i| format!("adapter-{i}")).collect();
    for (i, n) in names.iter().enumerate() {
        reg.insert_synthetic(n, &cfg, &base, RANK, 1 + i as u64)
            .expect("synthetic adapter");
    }
    let adapters = reg.get_many(&names).expect("resident adapters");

    let prompt_len = (cfg.seq_len / 2).max(1);
    let max_new = (cfg.seq_len / 4).max(1);
    let s = prompt_len + max_new;
    let prompts: Vec<Vec<i32>> =
        (0..max_b).map(|i| prompt_for(i, prompt_len, cfg.vocab)).collect();

    // bit-identity tripwire before any timing: batched == sequential
    if let Err(e) = oracle_check(&cfg, &base, &adapters, &prompts, max_new) {
        eprintln!("[serving] {name}: oracle mismatch: {e}");
        std::process::exit(1);
    }

    let mut template = vec![0i32; max_b * s];
    for (bi, p) in prompts.iter().enumerate() {
        template[bi * s..bi * s + prompt_len].copy_from_slice(p);
    }

    let mut cells = Vec::new();
    for &b in &BATCHES {
        let ads = &adapters[..b];
        let tmpl = &template[..b * s];
        let batched = time_it(1, iters, || {
            let mut toks = tmpl.to_vec();
            serve_greedy(&cfg, &base, ads, &mut toks, s, prompt_len).unwrap();
            std::hint::black_box(&toks);
        });
        let sequential = time_it(1, iters, || {
            for bi in 0..b {
                let mut toks = tmpl[bi * s..(bi + 1) * s].to_vec();
                serve_greedy(&cfg, &base, &ads[bi..bi + 1], &mut toks, s, prompt_len)
                    .unwrap();
                std::hint::black_box(&toks);
            }
        });
        let gen = (b * max_new) as f64;
        cells.push(Cell {
            key: format!("{name}/b{b}"),
            base_model: name,
            batch: b,
            prompt_len,
            max_new,
            decode_tok_s: gen / batched.mean().max(1e-12),
            seq_tok_s: gen / sequential.mean().max(1e-12),
            p50_ms: batched.percentile(50.0) * 1e3,
            p95_ms: batched.percentile(95.0) * 1e3,
            kv_bytes: cfg.dims.n_layers * 2 * b * s * cfg.dims.d_model * 4,
        });
    }
    cells
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn round1(x: f64) -> Json {
    Json::Num((x * 10.0).round() / 10.0)
}

fn round3(x: f64) -> Json {
    Json::Num((x * 1000.0).round() / 1000.0)
}

fn snapshot_of(cells: &[Cell], args: &BenchArgs) -> Json {
    let sizes: Vec<Json> = cells
        .iter()
        .map(|c| {
            obj(vec![
                ("model", Json::Str(c.key.clone())),
                ("base_model", Json::Str(c.base_model.into())),
                ("batch", Json::Num(c.batch as f64)),
                ("rank", Json::Num(RANK as f64)),
                ("prompt_len", Json::Num(c.prompt_len as f64)),
                ("max_new", Json::Num(c.max_new as f64)),
                ("decode_tok_s", round1(c.decode_tok_s)),
                ("seq_tok_s", round1(c.seq_tok_s)),
                ("batch_speedup", round3(c.speedup())),
                ("p50_ms", round3(c.p50_ms)),
                ("p95_ms", round3(c.p95_ms)),
                ("kv_bytes", Json::Num(c.kv_bytes as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("unix_time", Json::Num(contract::unix_time_now() as f64)),
        ("parallelism", Json::Num(args.parallelism.threads() as f64)),
        ("quick", Json::Bool(args.quick)),
        ("provenance", Json::Str("cargo-bench serving".into())),
        ("sizes", Json::Arr(sizes)),
    ])
}

const COMMENT: &str = "Per-PR multi-adapter serving trajectory (decode tokens/sec + \
     per-batch latency percentiles). Entries are appended, never \
     rewritten; `cargo bench --bench serving` appends a fresh \
     cargo-bench snapshot. How to read this file: docs/SERVING.md.";

fn main() {
    let args = BenchArgs::parse();
    let iters = args.steps.unwrap_or(if args.quick { 4 } else { 12 });
    let mut cells = Vec::new();
    for (name, cfg) in TransformerConfig::catalog_grid() {
        if args.quick && name == "lora-base" {
            continue; // the CI smoke stays fast; full runs cover it
        }
        eprintln!("[serving] measuring {name} ...");
        cells.extend(measure(name, cfg, iters));
    }

    let mut table = flora::bench::Table::new(
        &format!(
            "serving decode throughput (rank {RANK}, parallelism {})",
            args.parallelism.threads()
        ),
        &["Size", "b", "decode tok/s", "seq tok/s", "speedup", "p50 ms", "p95 ms"],
    );
    for c in &cells {
        table.row(vec![
            c.key.clone(),
            format!("{}", c.batch),
            format!("{:.0}", c.decode_tok_s),
            format!("{:.0}", c.seq_tok_s),
            format!("{:.2}x", c.speedup()),
            format!("{:.2}", c.p50_ms),
            format!("{:.2}", c.p95_ms),
        ]);
    }
    table.print();

    let path = "BENCH_serving.json";
    match contract::append_to_file(path, "serving", COMMENT, snapshot_of(&cells, &args)) {
        Ok(()) => println!("\nappended snapshot to {path}"),
        Err(e) => {
            // growing the trajectory is this bench's one artifact; a
            // silent skip would let CI go green on a broken append
            eprintln!("could not append to {path}: {e}");
            std::process::exit(1);
        }
    }
}
