//! Figure 1 — the pilot study: LoRA vs LoRA(B) vs RP vs RRP vs full SGD on
//! a Fashion-MNIST-like task, loss curves per updater.
//!
//! Paper claim to reproduce: LoRA ≈ LoRA(B) ≈ RP plateau well above SGD;
//! RRP (resampled random projection, FLORA's core move) largely recovers
//! the SGD curve. Pure rust — no artifacts needed.
//!
//! Run: cargo bench --bench figure1_pilot [-- --steps N]

use flora::bench::{sparkline, Table};
use flora::data::images::ImageTask;
use flora::pilot::{run_pilot, Updater};

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let steps = argv
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| argv.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(400usize);
    // paper setup: square patched layer, r=8, eta=0.01; bench-sized at
    // 256x256 (the separation is rank-ratio-driven and r/d = 8/256 is
    // HARDER for RP/LoRA than the paper's 8/768)
    let (rank, lr, batch) = (4usize, 0.02f32, 32usize);
    println!("Figure 1 pilot: steps={steps} rank={rank} lr={lr} batch={batch}");
    let task = ImageTask::fashion_like(10, 784, 0.6, 0);
    let curves = run_pilot(&task, steps, batch, rank, lr, 0, false, false);
    // train_w0=false: W1 is the capacity bottleneck (see pilot::PilotNet)

    let mut table = Table::new(
        "Figure 1 — training loss by updater (lower is better)",
        &["Updater", "loss@25%", "loss@50%", "final loss", "train acc", "curve"],
    );
    let at = |xs: &[f32], frac: f64| -> f32 {
        let i = ((xs.len() as f64 * frac) as usize).min(xs.len() - 1);
        let lo = i.saturating_sub(5);
        let hi = (i + 5).min(xs.len());
        xs[lo..hi].iter().sum::<f32>() / (hi - lo) as f32
    };
    for c in &curves {
        table.row(vec![
            c.updater.name().to_string(),
            format!("{:.4}", at(&c.losses, 0.25)),
            format!("{:.4}", at(&c.losses, 0.5)),
            format!("{:.4}", at(&c.losses, 1.0)),
            format!("{:.2}", c.final_train_acc),
            sparkline(&c.losses, 40),
        ]);
    }
    table.print();

    // the paper's qualitative ordering, asserted so regressions are loud
    let f = |u: Updater| {
        curves
            .iter()
            .find(|c| c.updater == u)
            .map(|c| at(&c.losses, 1.0))
            .unwrap()
    };
    let (sgd, rrp, rp, lora, lora_b) = (
        f(Updater::Sgd),
        f(Updater::Rrp),
        f(Updater::Rp),
        f(Updater::Lora),
        f(Updater::LoraB),
    );
    println!("\nchecks (paper §2.3):");
    println!("  RRP ≈ SGD      : {rrp:.4} vs {sgd:.4} ({})", ok(rrp < sgd + 0.35));
    println!("  RP  ≫ SGD      : {rp:.4} vs {sgd:.4} ({})", ok(rp > sgd + 0.1));
    println!("  RRP < RP       : {rrp:.4} vs {rp:.4} ({})", ok(rrp < rp));
    println!("  LoRA ≈ LoRA(B) : {lora:.4} vs {lora_b:.4} ({})", ok((lora - lora_b).abs() < 0.7));
    println!("  RRP < LoRA     : {rrp:.4} vs {lora:.4} ({})", ok(rrp < lora));
}

fn ok(b: bool) -> &'static str {
    if b { "OK" } else { "MISS" }
}
