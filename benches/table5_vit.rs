//! Table 5 (Appendix C.1) — image classification with ViT on CIFAR-sim.
//!
//! Adam (full state) vs FLORA (compressed momentum + factored second
//! moment): the paper reports matched accuracy with 24–32% less training
//! memory. Accuracy is measured end-to-end — on the native `vit-tiny`
//! transformer (pure rust, no artifacts) with `-- --backend native`, or
//! on the vit-cifar AOT artifacts otherwise; the memory column is the
//! accountant at ViT-Base/Large scale either way.
//!
//! Run: cargo bench --bench table5_vit -- --backend native [--quick]

use flora::bench::paper::{shared_runtime, BenchArgs};
use flora::bench::Table;
use flora::config::{TaskKind, TrainConfig};
use flora::coordinator::{MethodSpec, Trainer};
use flora::memory::{breakdown, Dims, Method, OptKind, StateRole};
use flora::opt::OptimizerKind;
use flora::util::human;

fn vit_dims(d: u64, layers: u64, ff: u64) -> Dims {
    // accountant reuse: ViT encoder ~ LM without the big vocab embedding
    Dims { vocab: 1000, d_model: d, n_layers: layers, d_ff: ff, seq_len: 197, n_heads: d / 64 }
}

fn main() {
    let args = BenchArgs::parse();
    let steps = args.steps.unwrap_or(if args.quick { 10 } else { 60 });
    let mut table = Table::new(
        &format!("Table 5 — ViT on CIFAR-sim ({steps} steps)"),
        &["Model", "Optimizer", "Accuracy", "Mem (analytic)", "local state"],
    );
    let cases = [
        ("Base", MethodSpec::None, OptimizerKind::Adam, 0.003f32),
        ("Base", MethodSpec::Flora { rank: 16 }, OptimizerKind::Adafactor, 0.01),
    ];
    // measured rows: the native vit transformers need no artifacts;
    // `-- --model vit-small` sweeps the native size grid
    let default_model =
        if args.backend == "native" { "vit-tiny" } else { "vit-cifar" };
    let model = args.model.clone().unwrap_or_else(|| default_model.into());
    let model = model.as_str();
    if args.require_artifacts() {
        let rt = shared_runtime(args.spec()).expect("runtime");
        for (scale, method, opt, lr) in cases {
            eprintln!("[table5] {} {} on {}", scale, method.label(), model);
            let cfg = TrainConfig {
                model: model.into(),
                task: TaskKind::Vit,
                method,
                optimizer: opt,
                lr,
                steps,
                tau: 1,
                kappa: 50,
                batch: 4,
                seed: 0,
                eval_every: 0,
                eval_samples: 64,
                parallelism: args.parallelism,
            };
            let report = Trainer::with_runtime(cfg, rt.clone()).and_then(|mut t| t.run());
            // analytic memory at ViT-Base scale (86M)
            let dims = vit_dims(768, 12, 3072);
            let (m, okind) = match method {
                MethodSpec::None => (Method::None, OptKind::Adam),
                _ => (Method::Flora(256), OptKind::Adafactor),
            };
            let b = breakdown(&dims, m, okind, StateRole::Momentum, 32, false);
            match report {
                Ok(r) => table.row(vec![
                    scale.into(),
                    if method == MethodSpec::None {
                        "Adam".into()
                    } else {
                        "FLORA".into()
                    },
                    r.metric.map(|mv| mv.render()).unwrap_or_default(),
                    format!("{:.2} GiB", human::gib(b.total())),
                    human::bytes(r.total_state_bytes()),
                ]),
                Err(e) => table.row(vec![
                    scale.into(),
                    method.label(),
                    format!("ERR {e}"),
                    "-".into(),
                    "-".into(),
                ]),
            }
        }
    }
    // ViT-Base and ViT-Large analytic rows (the paper's 23.8% / 32.4% savings)
    for (label, d, l, ff) in
        [("Base(86M)", 768u64, 12u64, 3072u64), ("Large(307M)", 1024, 24, 4096)]
    {
        let dims = vit_dims(d, l, ff);
        let adam =
            breakdown(&dims, Method::None, OptKind::Adam, StateRole::Momentum, 32, false);
        let flora = breakdown(
            &dims, Method::Flora(256), OptKind::Adafactor, StateRole::Momentum, 32, false,
        );
        let saving = 100.0 * (1.0 - flora.total() as f64 / adam.total() as f64);
        table.row(vec![
            label.into(),
            "Adam→FLORA".into(),
            format!("saving {saving:.1}%"),
            format!(
                "{:.2} → {:.2} GiB",
                human::gib(adam.total()),
                human::gib(flora.total())
            ),
            "-".into(),
        ]);
    }
    table.print();
}
