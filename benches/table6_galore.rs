//! Table 6 (Appendix C.2) — FLORA vs GaLore on C4-sim LM pre-training.
//!
//! GaLore stores the SVD projection P on device and keeps Adam moments in
//! the projected space; FLORA regenerates its projection from a seed and
//! keeps a compressed first moment + factored second moment. The paper
//! reports FLORA ≤ GaLore on both perplexity and memory.
//!
//! Run: cargo bench --bench table6_galore [-- --quick | --steps N]

use flora::bench::paper::{shared_runtime, BenchArgs};
use flora::bench::Table;
use flora::config::{TaskKind, TrainConfig};
use flora::coordinator::{MethodSpec, Trainer};
use flora::memory::{breakdown, Dims, Method, OptKind, StateRole};
use flora::opt::OptimizerKind;
use flora::util::human;

fn main() {
    let args = BenchArgs::parse();
    let steps = args.steps.unwrap_or(if args.quick { 15 } else { 200 });
    let mut table = Table::new(
        &format!("Table 6 — FLORA vs GaLore (C4-sim LM, {steps} steps)"),
        &["Size", "Optimizer", "PPL", "final loss", "Mem (analytic)", "local state"],
    );
    // per-method tuned LRs (the paper tunes both; its FLORA lr is 3x
    // smaller than GaLore's suggested one — here the sweep favored these)
    let cases = [
        (MethodSpec::Galore { rank: 16 }, 0.01f32),
        (MethodSpec::Flora { rank: 32 }, 0.02),
    ];
    let mut quality = Vec::new();
    if args.require_artifacts() {
        let rt = shared_runtime(args.spec()).expect("runtime");
        for (method, lr) in cases {
            eprintln!("[table6] {}", method.label());
            let mut cfg = TrainConfig {
                model: "lm-small".into(),
                task: TaskKind::Lm,
                method,
                optimizer: OptimizerKind::Adafactor,
                lr,
                steps,
                tau: 1,
                kappa: 1000, // paper's momentum interval (Table 3 optimum)
                batch: 4,
                seed: 0,
                eval_every: 0,
                eval_samples: 64,
                ..Default::default()
            };
            if matches!(method, MethodSpec::Galore { .. }) {
                cfg.optimizer = OptimizerKind::Adam; // GaLore = Adam-in-subspace
            }
            args.adjust(&mut cfg);
            let report = Trainer::with_runtime(cfg, rt.clone()).and_then(|mut t| t.run());
            let dims = Dims::t5_small_sim();
            let (m, o) = match method {
                MethodSpec::Galore { .. } => (Method::Galore(128), OptKind::Adam),
                _ => (Method::Flora(128), OptKind::Adafactor),
            };
            let b = breakdown(&dims, m, o, StateRole::Momentum, 16, false);
            let mem = b.opt_state + b.method_state;
            match report {
                Ok(r) => {
                    let q =
                        r.metric.map(|mv| mv.quality()).unwrap_or(f64::MIN);
                    quality.push((method.label(), q));
                    table.row(vec![
                        "60M".into(),
                        method.label(),
                        r.metric.map(|mv| mv.render()).unwrap_or_default(),
                        format!("{:.3}", r.final_train_loss()),
                        human::bytes(mem),
                        human::bytes(r.total_state_bytes()),
                    ]);
                }
                Err(e) => table.row(vec![
                    "60M".into(),
                    method.label(),
                    format!("ERR {e}"),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]),
            }
        }
    }
    // analytic 350M/7B rows (paper's larger sizes)
    for (label, dims) in [
        (
            "350M",
            Dims {
                vocab: 32128,
                d_model: 1024,
                n_layers: 24,
                d_ff: 4096,
                seq_len: 512,
                n_heads: 16,
            },
        ),
        (
            "7B",
            Dims {
                vocab: 32000,
                d_model: 4096,
                n_layers: 32,
                d_ff: 11008,
                seq_len: 2048,
                n_heads: 32,
            },
        ),
    ] {
        let ga =
            breakdown(&dims, Method::Galore(256), OptKind::Adam, StateRole::Momentum, 16, false);
        let fl = breakdown(
            &dims, Method::Flora(256), OptKind::Adafactor, StateRole::Momentum, 16, false,
        );
        table.row(vec![
            label.into(),
            "GaLore vs FLORA".into(),
            "-".into(),
            "-".into(),
            format!(
                "{:.1} vs {:.1} GiB state",
                human::gib(ga.opt_state + ga.method_state),
                human::gib(fl.opt_state + fl.method_state)
            ),
            "-".into(),
        ]);
    }
    table.print();
    if quality.len() == 2 {
        println!(
            "\ncheck (paper Table 6): FLORA PPL <= GaLore PPL: {}",
            if quality[1].1 >= quality[0].1 { "OK" } else { "MISS" }
        );
    }
}
