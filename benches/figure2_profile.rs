//! Figure 2 (Appendix C.3) — memory-by-category timeline over 4 training
//! steps: vanilla Adam vs LoRA vs FLORA, plain and with activation
//! checkpointing + LOMO.
//!
//! Generated from the analytic accountant's phase model (validated against
//! the live PJRT ledger in rust/tests/integration.rs) and printed as ASCII
//! area charts per category, mirroring the paper's stacked plot.
//!
//! Run: cargo bench --bench figure2_profile

use flora::bench::Table;
use flora::memory::{
    figure2_timeline, timeline::timeline_peak, Dims, Method, OptKind,
};
use flora::util::human;

fn chart(events: &[flora::memory::TimelineEvent]) -> String {
    // one char column per event, height 8, stacked categories collapsed to
    // the total; categories reported separately in the table
    const H: usize = 8;
    let peak = events.iter().map(|e| e.total()).max().unwrap_or(1).max(1);
    let mut rows = vec![String::new(); H];
    for e in events {
        let h = ((e.total() as f64 / peak as f64) * H as f64).round() as usize;
        for (i, row) in rows.iter_mut().enumerate() {
            row.push(if H - i <= h { '█' } else { ' ' });
        }
    }
    rows.join("\n")
}

fn main() {
    let dims = Dims::t5_small_sim();
    let batch = 4;
    for (title, ac, lomo) in [
        ("Figure 2a — plain training (4 steps)", false, false),
        ("Figure 2b — with activation checkpointing + LOMO", true, true),
    ] {
        let mut table = Table::new(
            title,
            &["Method", "peak", "params", "opt state", "grads(max)", "acts(max)", "method state"],
        );
        for (label, method, opt) in [
            ("Adam", Method::None, OptKind::Adam),
            ("LoRA(128)", Method::Lora(128), OptKind::Adam),
            ("FLORA(128)", Method::Flora(128), OptKind::Adafactor),
        ] {
            let tl = figure2_timeline(&dims, method, opt, batch, 4, ac, lomo);
            let peak = timeline_peak(&tl);
            let gmax = tl.iter().map(|e| e.grads).max().unwrap_or(0);
            let amax = tl.iter().map(|e| e.activations).max().unwrap_or(0);
            table.row(vec![
                label.into(),
                human::bytes(peak),
                human::bytes(tl[0].params),
                human::bytes(tl[0].opt_state),
                human::bytes(gmax),
                human::bytes(amax),
                human::bytes(tl[0].method_state),
            ]);
            println!("\n{label} ({title}):\n{}", chart(&tl));
        }
        table.print();
    }
    println!(
        "\nchecks (paper Fig. 2): FLORA+LoRA opt-state negligible vs Adam; \
         AC+LOMO makes the profiles near-identical (state differences hidden \
         under activations)."
    );
}
