//! Kernel/attention throughput microbench (not a paper table; grows the
//! §Perf trajectory) — APPENDS a snapshot to `BENCH_kernels.json`.
//!
//! For every native catalog size (the `lora-*` LM grid and the `vit-*`
//! grid) it measures tokens/sec for:
//!
//!   * `forward`          — model loss only (`want_grad = false`)
//!   * `forward_backward` — loss + full manual gradient set
//!   * `flora_step`       — a complete FLORA Algorithm-2 training step
//!                          (rank 8, Adafactor base) through the Trainer
//!
//! and, as the PR-4 refactor's acceptance metric, the attention core's
//! forward+backward throughput on the batched GEMM path
//! (`model::blocks::attention_*` — since PR 9 the **packed**-panel
//! kernels, with the pool driver's fused single-submission backward
//! dispatch) against the retained pre-refactor scalar nests
//! (`model::blocks::reference`) — `attn_fwd_bwd_speedup` at lora-tiny
//! scale is the ≥5× gate.
//!
//! Before measuring anything, the bench runs its oracle tripwires —
//! packed kernels vs the naive serial references (raw bits, NaN/Inf
//! poisoned) and the pool-fused vs scope-unfused attention backward —
//! and EXITS 1 on any divergence: a throughput number from kernels that
//! changed results is worse than no number.
//!
//! `BENCH_kernels.json` is a schema-2 TRAJECTORY: a list of dated-by-PR
//! snapshots (see docs/PERFORMANCE.md for a worked reading example).
//! This bench parses the committed file, appends one `cargo-bench`
//! snapshot, and re-renders — it never rewrites history. `--runtime
//! scope` re-measures on the retained per-call `thread::scope` driver
//! for pool-vs-scope A/B pairs (results bit-identical, only time moves).
//!
//! Run: cargo bench --bench micro_kernels
//!        [-- --quick --parallelism N --runtime pool|scope]

use flora::bench::contract;
use flora::bench::paper::BenchArgs;
use flora::bench::time_it;
use flora::config::{TaskKind, TrainConfig};
use flora::coordinator::{MethodSpec, Trainer};
use flora::data::images::ImageTask;
use flora::model::blocks::{self, reference, BlockDims};
use flora::model::{TransformerConfig, VitConfig};
use flora::opt::OptimizerKind;
use flora::tensor::{KernelDriver, Matrix, Parallelism};
use flora::util::json::Json;
use flora::util::rng::Rng;

const BATCH: usize = 4;
const FLORA_RANK: usize = 8;

struct SizeResult {
    model: &'static str,
    family: &'static str,
    tokens_per_batch: usize,
    forward_tok_s: f64,
    forward_backward_tok_s: f64,
    flora_step_tok_s: f64,
    attn_scalar_tok_s: f64,
    attn_batched_tok_s: f64,
}

impl SizeResult {
    fn speedup(&self) -> f64 {
        if self.attn_scalar_tok_s > 0.0 {
            self.attn_batched_tok_s / self.attn_scalar_tok_s
        } else {
            0.0
        }
    }
}

fn tok_s(tokens: usize, mean_secs: f64) -> f64 {
    if mean_secs > 0.0 {
        tokens as f64 / mean_secs
    } else {
        0.0
    }
}

/// tokens/sec of one full FLORA momentum step via the Trainer (catalog
/// executable path, so decompression/transfer costs are included). The
/// thread budget must ride in the config: Trainer installs
/// `cfg.parallelism` process-wide, so leaving it at the default would
/// reset the budget the direct kernel measurements rely on.
fn flora_step_tok_s(
    model: &str,
    task: TaskKind,
    tokens: usize,
    steps: usize,
    parallelism: Parallelism,
) -> Result<f64, String> {
    let cfg = TrainConfig {
        model: model.into(),
        task,
        method: MethodSpec::Flora { rank: FLORA_RANK },
        optimizer: OptimizerKind::Adafactor,
        lr: 0.01,
        steps,
        tau: 1,
        kappa: 50,
        batch: BATCH,
        seed: 0,
        eval_every: 0,
        eval_samples: 8,
        parallelism,
        ..Default::default()
    };
    let report = Trainer::new(cfg, "native")
        .and_then(|mut t| t.run())
        .map_err(|e| format!("{model}: flora step failed: {e}"))?;
    // one step consumes `tokens` (= batch * seq) tokens
    Ok(report.steps_per_sec * tokens as f64)
}

/// Attention-core fwd+bwd tokens/sec: batched GEMM path vs the retained
/// scalar reference, on random activations at this size.
fn attention_pair(dims: BlockDims, b: usize, s: usize, iters: usize) -> (f64, f64) {
    let mut rng = Rng::new(42);
    let q = Matrix::gaussian(b * s, dims.d_model, 1.0, &mut rng);
    let k = Matrix::gaussian(b * s, dims.d_model, 1.0, &mut rng);
    let v = Matrix::gaussian(b * s, dims.d_model, 1.0, &mut rng);
    let dctx = Matrix::gaussian(b * s, dims.d_model, 1.0, &mut rng);
    let scalar = time_it(1, iters, || {
        let (ctx, probs) = reference::attention_forward(&q, &k, &v, dims, b, s, true);
        let grads = reference::attention_backward(&q, &k, &v, &probs, &dctx, dims, b, s);
        std::hint::black_box((ctx, grads));
    });
    let batched = time_it(1, iters, || {
        let (ctx, probs) = blocks::attention_forward(&q, &k, &v, dims, b, s, true);
        let grads = blocks::attention_backward(&q, &k, &v, &probs, &dctx, dims, b, s);
        std::hint::black_box((ctx, grads));
    });
    (tok_s(b * s, scalar.mean()), tok_s(b * s, batched.mean()))
}

/// Correctness gate ahead of any timing: the packed/pooled kernels and
/// the fused attention-backward dispatch must bit-match their retained
/// oracles. Returns the failure description; the caller exits 1.
fn oracle_tripwires(par: Parallelism) -> Result<(), String> {
    fn bits_equal(a: &Matrix, b: &Matrix) -> bool {
        a.shape() == b.shape()
            && a.data
                .iter()
                .zip(b.data.iter())
                .all(|(x, y)| x.to_bits() == y.to_bits())
    }
    // 1) packed GEMMs vs the naive serial oracles on a ragged
    //    NaN/Inf-poisoned rectangle, under the bench's own budget
    par.install();
    let mut rng = Rng::new(0xbe9c);
    let (n, k, m) = (67usize, 71usize, 131usize);
    let mut a = Matrix::gaussian(n, k, 1.0, &mut rng);
    let b = Matrix::gaussian(k, m, 1.0, &mut rng);
    let bt = Matrix::gaussian(m, k, 1.0, &mut rng);
    let b2 = Matrix::gaussian(n, m, 1.0, &mut rng);
    *a.at_mut(1, 2) = f32::NAN;
    *a.at_mut(4, 0) = f32::INFINITY;
    for (layout, got, want) in [
        ("matmul", a.matmul(&b), a.matmul_naive(&b)),
        ("matmul_nt", a.matmul_nt(&bt), a.matmul_nt_naive(&bt)),
        ("matmul_tn", a.matmul_tn(&b2), a.matmul_tn_naive(&b2)),
    ] {
        if !bits_equal(&got, &want) {
            return Err(format!(
                "{layout} ({n}x{k}x{m}) diverges from the naive oracle \
                 under {par:?}"
            ));
        }
    }
    // 2) pool-fused vs scope-unfused attention backward: the same
    //    gradients, raw bits, through both dispatch routes
    let dims = TransformerConfig::tiny().dims;
    let (bq, s) = (2usize, 7usize);
    let q = Matrix::gaussian(bq * s, dims.d_model, 1.0, &mut rng);
    let kk = Matrix::gaussian(bq * s, dims.d_model, 1.0, &mut rng);
    let v = Matrix::gaussian(bq * s, dims.d_model, 1.0, &mut rng);
    let mut dctx = Matrix::gaussian(bq * s, dims.d_model, 1.0, &mut rng);
    *dctx.at_mut(0, 0) = f32::NAN;
    let threads = par.threads().max(2);
    let run = |budget: Parallelism| {
        budget.install();
        let (_, probs) = blocks::attention_forward(&q, &kk, &v, dims, bq, s, true);
        blocks::attention_backward(&q, &kk, &v, &probs, &dctx, dims, bq, s)
    };
    let (dq_p, dk_p, dv_p) = run(Parallelism::new(threads));
    let (dq_s, dk_s, dv_s) = run(Parallelism::scoped(threads));
    for (name, p, sc) in
        [("dq", &dq_p, &dq_s), ("dk", &dk_p, &dk_s), ("dv", &dv_p, &dv_s)]
    {
        if !bits_equal(p, sc) {
            return Err(format!(
                "attention backward {name}: pool-fused dispatch diverges \
                 from the scope-unfused oracle"
            ));
        }
    }
    par.install();
    Ok(())
}

fn lm_toy_batch(vocab: usize, s: usize) -> (Vec<i32>, Vec<f32>) {
    let mut toks = vec![0i32; BATCH * s];
    let mut mask = vec![0.0f32; BATCH * s];
    for bi in 0..BATCH {
        for i in 0..s {
            toks[bi * s + i] = (5 + (bi + i) % (vocab - 5)) as i32;
            if i >= s / 2 {
                mask[bi * s + i] = 1.0;
            }
        }
    }
    (toks, mask)
}

fn measure_lm(
    model: &'static str,
    cfg: TransformerConfig,
    iters: usize,
    par: Parallelism,
) -> Result<SizeResult, String> {
    let params = cfg.init(0);
    let s = cfg.seq_len;
    let tokens = BATCH * s;
    let (toks, mask) = lm_toy_batch(cfg.vocab, s);
    let fwd = time_it(1, iters, || {
        let r = cfg.loss_and_grad(&params, &toks, &mask, BATCH, s, false);
        std::hint::black_box(r.unwrap());
    });
    let fwd_bwd = time_it(1, iters, || {
        let r = cfg.loss_and_grad(&params, &toks, &mask, BATCH, s, true);
        std::hint::black_box(r.unwrap());
    });
    let (attn_scalar, attn_batched) = attention_pair(cfg.dims, BATCH, s, iters * 4);
    Ok(SizeResult {
        model,
        family: "lm",
        tokens_per_batch: tokens,
        forward_tok_s: tok_s(tokens, fwd.mean()),
        forward_backward_tok_s: tok_s(tokens, fwd_bwd.mean()),
        flora_step_tok_s: flora_step_tok_s(model, TaskKind::Lm, tokens, iters, par)?,
        attn_scalar_tok_s: attn_scalar,
        attn_batched_tok_s: attn_batched,
    })
}

fn measure_vit(
    model: &'static str,
    cfg: VitConfig,
    iters: usize,
    par: Parallelism,
) -> Result<SizeResult, String> {
    let params = cfg.init(0);
    let tokens = BATCH * cfg.seq();
    let task =
        ImageTask::cifar_like(cfg.n_classes, cfg.image_size, cfg.channels, 0.25, 3);
    let mut cursor = 0u64;
    let (images, labels) = task.fill_flat(BATCH, 0, &mut cursor, 3);
    let fwd = time_it(1, iters, || {
        let r = cfg.loss_preds_grad(&params, &images, &labels, false);
        std::hint::black_box(r.unwrap());
    });
    let fwd_bwd = time_it(1, iters, || {
        let r = cfg.loss_preds_grad(&params, &images, &labels, true);
        std::hint::black_box(r.unwrap());
    });
    let (attn_scalar, attn_batched) =
        attention_pair(cfg.dims, BATCH, cfg.seq(), iters * 4);
    Ok(SizeResult {
        model,
        family: "vit",
        tokens_per_batch: tokens,
        forward_tok_s: tok_s(tokens, fwd.mean()),
        forward_backward_tok_s: tok_s(tokens, fwd_bwd.mean()),
        flora_step_tok_s: flora_step_tok_s(model, TaskKind::Vit, tokens, iters, par)?,
        attn_scalar_tok_s: attn_scalar,
        attn_batched_tok_s: attn_batched,
    })
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn round1(x: f64) -> Json {
    Json::Num((x * 10.0).round() / 10.0)
}

/// One schema-2 trajectory snapshot for this invocation.
fn snapshot_of(results: &[SizeResult], args: &BenchArgs) -> Json {
    let runtime = match args.parallelism.driver() {
        KernelDriver::Pool => "pool",
        KernelDriver::Scope => "scope",
    };
    let sizes: Vec<Json> = results
        .iter()
        .map(|r| {
            obj(vec![
                ("model", Json::Str(r.model.into())),
                ("family", Json::Str(r.family.into())),
                ("tokens_per_batch", Json::Num(r.tokens_per_batch as f64)),
                ("forward_tok_s", round1(r.forward_tok_s)),
                ("forward_backward_tok_s", round1(r.forward_backward_tok_s)),
                ("flora_step_tok_s", round1(r.flora_step_tok_s)),
                ("attn_fwd_bwd_scalar_tok_s", round1(r.attn_scalar_tok_s)),
                ("attn_fwd_bwd_batched_tok_s", round1(r.attn_batched_tok_s)),
                (
                    "attn_fwd_bwd_speedup",
                    Json::Num((r.speedup() * 100.0).round() / 100.0),
                ),
            ])
        })
        .collect();
    obj(vec![
        ("unix_time", Json::Num(contract::unix_time_now() as f64)),
        ("runtime", Json::Str(runtime.into())),
        ("parallelism", Json::Num(args.parallelism.threads() as f64)),
        ("quick", Json::Bool(args.quick)),
        ("provenance", Json::Str("cargo-bench micro_kernels".into())),
        ("sizes", Json::Arr(sizes)),
    ])
}

const COMMENT: &str = "Per-PR kernel-throughput trajectory (tokens/sec). Entries are \
     appended, never rewritten; `cargo bench --bench micro_kernels` \
     appends a fresh cargo-bench snapshot. How to read this file: \
     docs/PERFORMANCE.md.";

fn main() {
    let args = BenchArgs::parse();
    // correctness before throughput: any oracle divergence kills the run
    if let Err(e) = oracle_tripwires(args.parallelism) {
        eprintln!("[micro_kernels] ORACLE TRIPWIRE: {e}");
        std::process::exit(1);
    }
    let iters = args.steps.unwrap_or(if args.quick { 4 } else { 12 });
    let mut results = Vec::new();
    for (name, cfg) in TransformerConfig::catalog_grid() {
        if args.quick && name == "lora-base" {
            continue; // the CI smoke stays fast; full runs cover it
        }
        eprintln!("[micro_kernels] measuring {name} ...");
        results.push(measure_lm(name, cfg, iters, args.parallelism).unwrap_or_else(|e| {
            // a broken training path must FAIL the bench (CI smoke gate)
            eprintln!("[micro_kernels] {e}");
            std::process::exit(1);
        }));
    }
    for (name, cfg) in VitConfig::catalog_grid() {
        eprintln!("[micro_kernels] measuring {name} ...");
        results.push(measure_vit(name, cfg, iters, args.parallelism).unwrap_or_else(|e| {
            eprintln!("[micro_kernels] {e}");
            std::process::exit(1);
        }));
    }

    let mut table = flora::bench::Table::new(
        &format!(
            "kernel throughput (tokens/sec, batch {BATCH}, parallelism {}, runtime {:?})",
            args.parallelism.threads(),
            args.parallelism.driver()
        ),
        &["Model", "fwd", "fwd+bwd", "flora step", "attn scalar", "attn batched", "speedup"],
    );
    for r in &results {
        table.row(vec![
            r.model.to_string(),
            format!("{:.0}", r.forward_tok_s),
            format!("{:.0}", r.forward_backward_tok_s),
            format!("{:.0}", r.flora_step_tok_s),
            format!("{:.0}", r.attn_scalar_tok_s),
            format!("{:.0}", r.attn_batched_tok_s),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    table.print();

    // the refactor's headline number, measured against the packed/fused
    // batched path; not asserted (CI runners vary) but surfaced loudly
    // so a regression is visible in the log
    if let Some(tiny) = results.iter().find(|r| r.model == "lora-tiny") {
        let s = tiny.speedup();
        if s < 5.0 {
            eprintln!(
                "[micro_kernels] WARNING: lora-tiny attention fwd+bwd \
                 speedup {s:.2}x (packed batched path vs scalar nests) \
                 is below the 5x acceptance gate"
            );
        }
    }

    let path = "BENCH_kernels.json";
    match contract::append_to_file(path, "micro_kernels", COMMENT, snapshot_of(&results, &args)) {
        Ok(()) => println!("\nappended snapshot to {path}"),
        Err(e) => {
            // growing the trajectory is this bench's one artifact; a
            // silent skip would let CI go green on a broken append
            eprintln!("could not append to {path}: {e}");
            std::process::exit(1);
        }
    }
}
