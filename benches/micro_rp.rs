//! Microbenchmark — the rp hot path (not a paper table; feeds §Perf).
//!
//! Times the rust-substrate compress/decompress/transfer GEMMs across the
//! shapes the bench models use, plus projection regeneration from seed
//! (the "store the seed" trade: regeneration cost vs storing A).
//!
//! Run: cargo bench --bench micro_rp

use flora::bench::{report, time_it};
use flora::rp;
use flora::tensor::Matrix;
use flora::util::rng::Rng;

fn main() {
    let shapes = [(64usize, 64usize, 8usize), (256, 256, 16), (768, 768, 32), (2048, 512, 64)];
    for (n, m, r) in shapes {
        let mut rng = Rng::new(0);
        let g = Matrix::gaussian(n, m, 1.0, &mut rng);
        let a = rp::projection(1, r, m);
        let c = rp::compress(&g, &a);
        let a2 = rp::projection(2, r, m);

        let s = time_it(2, 10, || {
            std::hint::black_box(rp::projection(3, r, m));
        });
        report(&format!("projection from seed  [{r}x{m}]"), &s);
        let s = time_it(2, 10, || {
            std::hint::black_box(rp::compress(&g, &a));
        });
        report(&format!("compress    G[{n}x{m}] r={r}"), &s);
        let s = time_it(2, 10, || {
            std::hint::black_box(rp::decompress(&c, &a));
        });
        report(&format!("decompress  C[{n}x{r}] m={m}"), &s);
        let s = time_it(2, 10, || {
            std::hint::black_box(rp::transfer(&c, &a, &a2));
        });
        report(&format!("transfer    M[{n}x{r}]"), &s);
        println!();
    }
}
