//! Table 1 — gradient accumulation under compression.
//!
//! (a) T5-sim on XSum-sim (ROUGE), (b) GPT-2-sim on IWSLT-sim (BLEU).
//! Methods: None / Naive / LoRA(r)×4 / FLORA(r)×4, Adafactor base, τ-step
//! accumulation (Algorithm 1). Mem/ΔM columns are the analytic accountant
//! at the paper's model sizes; quality/loss are measured end-to-end on the
//! local artifacts through the full rust↔PJRT stack.
//!
//! Run: cargo bench --bench table1_accumulation [-- --quick | --steps N]

use flora::bench::paper::*;
use flora::config::TaskKind;
use flora::memory::{Dims, OptKind, StateRole};

fn main() {
    let args = BenchArgs::parse();
    let steps = args.steps.unwrap_or(if args.quick { 8 } else { 30 });
    let tau = if args.quick { 4 } else { 8 };
    let cells = table_grid();
    // one runtime for the whole bench: sum+mt share the lm-small executables
    let rt = if args.require_artifacts() {
        Some(shared_runtime(args.spec()).expect("runtime"))
    } else {
        None
    };
    let role = StateRole::Accumulation;
    let opt = OptKind::Adafactor;

    for (task, small_dims, big_dims, small_label, big_label, metric) in [
        (TaskKind::Sum, Dims::t5_small_sim(), Dims::t5_3b_sim(), "60M", "3B", "R1/R2/RL"),
        (TaskKind::Mt, Dims::gpt2_base_sim(), Dims::gpt2_xl_sim(), "110M", "1.5B", "BLEU"),
    ] {
        let title = format!(
            "Table 1{} — gradient accumulation ({}, tau={tau}, {} steps)",
            if task == TaskKind::Sum { 'a' } else { 'b' },
            task.name(),
            steps
        );
        if let Some(rt) = &rt {
            let mut base = base_config(task, steps, tau);
            args.adjust(&mut base);
            let reports: Vec<_> = cells
                .iter()
                .map(|c| {
                    eprintln!("[table1/{}] {}", task.name(), paper_label(c));
                    run_cell(&base, c, rt)
                })
                .collect();
            render_table(&title, small_label, &small_dims, opt, role, &cells, &reports, metric)
                .print();
        }
        render_analytic_only(
            &format!("Table 1 ({big_label} rows, analytic memory)"),
            big_label,
            &big_dims,
            opt,
            role,
            &cells,
        )
        .print();
    }
}
