//! Ablation (DESIGN.md design-choice list): does Algorithm 2's subspace
//! TRANSFER (M' = M A_old A_newᵀ on resample, the paper's §2.4 remedy #2)
//! actually matter, or is resampling alone enough?
//!
//! Compares FLORA(16) momentum with and without the transfer at an
//! aggressive resample interval (κ=5, so ~16 transfers over the run) where
//! the effect is visible; Naive momentum is the reference ceiling.
//!
//! Run: cargo bench --bench ablation_transfer [-- --steps N]

use flora::bench::paper::{base_config, shared_runtime, BenchArgs};
use flora::bench::Table;
use flora::config::TaskKind;
use flora::coordinator::{MethodSpec, Trainer};

fn main() {
    let args = BenchArgs::parse();
    if !args.require_artifacts() {
        return;
    }
    let rt = shared_runtime(args.spec()).expect("runtime");
    let steps = args.steps.unwrap_or(if args.quick { 20 } else { 80 });
    let mut table = Table::new(
        &format!("Ablation — Algorithm 2 subspace transfer (mt task, kappa=5, {steps} steps)"),
        &["Method", "BLEU", "final loss"],
    );
    let mut rows: Vec<(String, f64)> = Vec::new();
    for method in [
        MethodSpec::Naive,
        MethodSpec::Flora { rank: 16 },
        MethodSpec::FloraNoTransfer { rank: 16 },
    ] {
        eprintln!("[ablation] {}", method.label());
        let mut cfg = base_config(TaskKind::Mt, steps, 1);
        cfg.method = method;
        cfg.kappa = 5;
        args.adjust(&mut cfg);
        match Trainer::with_runtime(cfg, rt.clone()).and_then(|mut t| t.run()) {
            Ok(r) => {
                let q = r.metric.map(|m| m.quality()).unwrap_or(f64::MIN);
                rows.push((method.label(), q));
                table.row(vec![
                    method.label(),
                    r.metric.map(|m| m.render()).unwrap_or_default(),
                    format!("{:.3}", r.final_train_loss()),
                ]);
            }
            Err(e) => table.row(vec![method.label(), format!("ERR {e}"), "-".into()]),
        }
    }
    table.print();
    if rows.len() == 3 {
        println!(
            "\ncheck: transfer >= no-transfer under frequent resampling: {}",
            if rows[1].1 >= rows[2].1 - 0.5 { "OK" } else { "MISS" }
        );
    }
}
