//! Table 3 — the effect of κ (momentum resample interval) in FLORA.
//!
//! The paper sweeps κ ∈ {1, 10, 100, 1000, 10000} over a fixed run length
//! and finds quality peaks at an intermediate κ: too-frequent resampling
//! destroys the EMA history (κ=1 collapses), too-rare resampling caps the
//! overall update rank. We sweep the same RATIOS of κ to total steps.
//!
//! Run: cargo bench --bench table3_kappa [-- --quick | --steps N]

use flora::bench::paper::{base_config, shared_runtime, BenchArgs};
use flora::bench::Table;
use flora::config::TaskKind;
use flora::coordinator::MethodSpec;

fn main() {
    let args = BenchArgs::parse();
    if !args.require_artifacts() {
        return;
    }
    let rt = shared_runtime(args.spec()).expect("runtime");
    let steps = args.steps.unwrap_or(if args.quick { 20 } else { 80 });
    // paper: kappa in {1,10,100,1000,10000} over ~1 epoch; keep the same
    // log-spaced sweep relative to the run length
    let kappas = [1usize, 5, 20, 80, 1000];
    let mut table = Table::new(
        &format!("Table 3 — effect of kappa (FLORA momentum, sum task, {steps} steps)"),
        &["kappa", "R1/R2/RL", "final loss", "state bytes"],
    );
    let mut rows = Vec::new();
    for kappa in kappas {
        eprintln!("[table3] kappa={kappa}");
        let mut cfg = base_config(TaskKind::Sum, steps, 1);
        cfg.method = MethodSpec::Flora { rank: 16 };
        cfg.kappa = kappa;
        args.adjust(&mut cfg);
        let report = flora::coordinator::Trainer::with_runtime(cfg, rt.clone())
            .and_then(|mut t| t.run());
        match report {
            Ok(r) => {
                rows.push((kappa, r.metric.map(|m| m.quality()).unwrap_or(0.0)));
                table.row(vec![
                    kappa.to_string(),
                    r.metric.map(|m| m.render()).unwrap_or_default(),
                    format!("{:.3}", r.final_train_loss()),
                    r.total_state_bytes().to_string(),
                ]);
            }
            Err(e) => table.row(vec![
                kappa.to_string(),
                format!("ERR {e}"),
                "-".into(),
                "-".into(),
            ]),
        }
    }
    table.print();
    if let (Some(first), Some(best)) = (
        rows.first().map(|r| r.1),
        rows.iter().map(|r| r.1).max_by(|a, b| a.partial_cmp(b).unwrap()),
    ) {
        println!(
            "\ncheck (paper Table 3): intermediate kappa beats kappa=1: \
             {} ({best:.1} vs {first:.1})",
            if best > first { "OK" } else { "MISS" }
        );
    }
}
