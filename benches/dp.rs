//! Flora-compressed data-parallel training bench (not a paper table;
//! grows the dp trajectory) — APPENDS a snapshot to `BENCH_dp.json`.
//!
//! For every native LM catalog size (skipping `lora-base` under
//! `--quick`, same as serving/micro_kernels) it trains the dp tier in
//! both reduce modes and reports:
//!
//!   * `steps_per_sec`        — optimizer steps/sec for the whole
//!                              fan-out → reduce → step loop
//!   * `per_step_sent_bytes`  — ledger upload bytes of one data step in
//!                              the configured mode (exact, analytic)
//!   * `per_step_full_bytes`  — the same step under full-gradient
//!                              exchange
//!   * `comms_ratio`          — sent/full (~r/d for compressed at the
//!                              square attn/ffn shapes)
//!
//! Before timing, each size runs the W∈{1,2} bit-identity tripwire —
//! the same config at 1 and 2 workers must produce raw-bits-identical
//! loss curves and final parameters — and the bench exits non-zero on
//! any mismatch, so a throughput number can never be recorded for a
//! wrong trajectory.
//!
//! `BENCH_dp.json` is a schema-2 TRAJECTORY like BENCH_serving.json
//! (append-only; docs/DISTRIBUTED.md §6 has the methodology). The
//! seed point is a C mirror of the comms path
//! (`benches/mirror/dp_mirror.c`), provenance-tagged as such.
//!
//! Run: cargo bench --bench dp [-- --quick --workers N --parallelism N]

use flora::bench::contract;
use flora::bench::paper::BenchArgs;
use flora::config::DpConfig;
use flora::model::TransformerConfig;
use flora::runtime::dp::{DpTrainer, ReduceMode};
use flora::util::json::Json;

const SHARDS: usize = 4;
const RANK: usize = 8;

struct Cell {
    key: String,
    model: String,
    workers: usize,
    reduce: ReduceMode,
    steps_per_sec: f64,
    per_step_sent: u64,
    per_step_full: u64,
    ratio: f64,
    final_loss: f32,
}

fn dp_cfg(
    model: &str,
    workers: usize,
    steps: usize,
    reduce: ReduceMode,
    args: &BenchArgs,
) -> DpConfig {
    let mut cfg = DpConfig::default();
    cfg.train.model = model.to_string();
    cfg.train.steps = steps;
    cfg.train.workers = workers;
    cfg.train.parallelism = args.parallelism;
    cfg.shards = SHARDS;
    cfg.reduce = reduce;
    cfg
}

/// The W∈{1,2} raw-bits gate: run the same config at 1 and 2 workers
/// and demand identical loss curves + final params. Exit non-zero on
/// divergence — never record a number for a wrong trajectory.
fn tripwire(model: &str, args: &BenchArgs) {
    let steps = 3;
    let mut solo = DpTrainer::new(dp_cfg(model, 1, steps, ReduceMode::Compressed, args))
        .expect("dp trainer (W=1)");
    let mut duo = DpTrainer::new(dp_cfg(model, 2, steps, ReduceMode::Compressed, args))
        .expect("dp trainer (W=2)");
    let a = solo.run().expect("W=1 run");
    let b = duo.run().expect("W=2 run");
    let la: Vec<u32> = a.train_losses.iter().map(|x| x.to_bits()).collect();
    let lb: Vec<u32> = b.train_losses.iter().map(|x| x.to_bits()).collect();
    if la != lb {
        eprintln!("[dp] {model}: W=2 loss curve diverges from W=1");
        std::process::exit(1);
    }
    for (name, p) in solo.params() {
        let q = &duo.params()[name];
        let pb: Vec<u32> = p.data.iter().map(|x| x.to_bits()).collect();
        let qb: Vec<u32> = q.data.iter().map(|x| x.to_bits()).collect();
        if pb != qb {
            eprintln!("[dp] {model}: W=2 parameter {name} diverges from W=1");
            std::process::exit(1);
        }
    }
}

fn measure(model: &str, workers: usize, steps: usize, args: &BenchArgs) -> Vec<Cell> {
    let mut cells = Vec::new();
    for reduce in [ReduceMode::Compressed, ReduceMode::Full] {
        let mut tr = DpTrainer::new(dp_cfg(model, workers, steps, reduce, args))
            .expect("dp trainer");
        let report = tr.run().expect("dp run");
        let ledger = report.ledger;
        cells.push(Cell {
            key: format!("{model}/{reduce}"),
            model: model.to_string(),
            workers,
            reduce,
            steps_per_sec: report.steps_per_sec,
            per_step_sent: ledger.per_step_sent(),
            per_step_full: ledger.per_step_full(),
            ratio: ledger.ratio(),
            final_loss: report.train_losses.last().copied().unwrap_or(f32::NAN),
        });
    }
    cells
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn round3(x: f64) -> Json {
    Json::Num((x * 1000.0).round() / 1000.0)
}

fn round6(x: f64) -> Json {
    Json::Num((x * 1e6).round() / 1e6)
}

fn snapshot_of(cells: &[Cell], args: &BenchArgs) -> Json {
    let sizes: Vec<Json> = cells
        .iter()
        .map(|c| {
            obj(vec![
                ("model", Json::Str(c.key.clone())),
                ("base_model", Json::Str(c.model.clone())),
                ("workers", Json::Num(c.workers as f64)),
                ("shards", Json::Num(SHARDS as f64)),
                ("rank", Json::Num(RANK as f64)),
                ("reduce", Json::Str(c.reduce.name().into())),
                ("steps_per_sec", round3(c.steps_per_sec)),
                ("per_step_sent_bytes", Json::Num(c.per_step_sent as f64)),
                ("per_step_full_bytes", Json::Num(c.per_step_full as f64)),
                ("comms_ratio", round6(c.ratio)),
                ("final_loss", round6(c.final_loss as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("unix_time", Json::Num(contract::unix_time_now() as f64)),
        ("parallelism", Json::Num(args.parallelism.threads() as f64)),
        ("quick", Json::Bool(args.quick)),
        ("provenance", Json::Str("cargo-bench dp".into())),
        ("sizes", Json::Arr(sizes)),
    ])
}

const COMMENT: &str = "Per-PR data-parallel training trajectory (optimizer steps/sec \
     + exact comms bytes per data step, compressed vs full reduce). \
     Entries are appended, never rewritten; `cargo bench --bench dp` \
     appends a fresh cargo-bench snapshot after the W-invariance \
     tripwire. How to read this file: docs/DISTRIBUTED.md.";

fn main() {
    let args = BenchArgs::parse();
    let steps = args.steps.unwrap_or(if args.quick { 4 } else { 12 });
    let workers = args.workers.clamp(1, SHARDS);
    let mut cells = Vec::new();
    for (name, _) in TransformerConfig::catalog_grid() {
        if args.quick && name == "lora-base" {
            continue; // the CI smoke stays fast; full runs cover it
        }
        eprintln!("[dp] tripwire {name} (W=1 vs W=2) ...");
        tripwire(name, &args);
        eprintln!("[dp] measuring {name} at workers={workers} ...");
        cells.extend(measure(name, workers, steps, &args));
    }

    let mut table = flora::bench::Table::new(
        &format!(
            "dp training (shards {SHARDS}, rank {RANK}, workers {workers}, parallelism {})",
            args.parallelism.threads()
        ),
        &["Size/mode", "steps/s", "sent/step", "full/step", "ratio", "final loss"],
    );
    for c in &cells {
        table.row(vec![
            c.key.clone(),
            format!("{:.2}", c.steps_per_sec),
            flora::util::human::bytes(c.per_step_sent),
            flora::util::human::bytes(c.per_step_full),
            format!("{:.4}", c.ratio),
            format!("{:.4}", c.final_loss),
        ]);
    }
    table.print();

    let path = "BENCH_dp.json";
    match contract::append_to_file(path, "dp", COMMENT, snapshot_of(&cells, &args)) {
        Ok(()) => println!("\nappended snapshot to {path}"),
        Err(e) => {
            // growing the trajectory is this bench's one artifact; a
            // silent skip would let CI go green on a broken append
            eprintln!("could not append to {path}: {e}");
            std::process::exit(1);
        }
    }
}
