//! Adaptive-rank compressor ablation (not a paper table; grows the
//! compressor-grid trajectory) — APPENDS a snapshot to
//! `BENCH_ablation.json`.
//!
//! For every native LM catalog size (skipping `lora-base` under
//! `--quick`, same as dp/serving/micro_kernels) it trains the SAME
//! model under each compressor row and reports:
//!
//!   * `final_loss`          — last training loss (the quality axis)
//!   * `steps_per_sec`       — optimizer steps/sec through the fused
//!                             native catalog
//!   * `tok_s`               — tokens/sec (steps/s × τ × batch × seq)
//!   * `method_state_bytes`  — persistent compressor state (the memory
//!                             axis; sublinear vs `params_bytes` is the
//!                             paper's claim, zero for fused AltLoRA ViT
//!                             steps only)
//!   * `state_ratio`         — method/params bytes
//!
//! Rows: Flora Algorithm 1 (compressed accumulation, τ=4), Flora
//! Algorithm 2 (momentum-in-subspace, τ=1), AltLoRA
//! (alternating-projection reconstruction, τ=4) and AdaRank (scheduled
//! shrinking momentum subspace, halve-at:1 on a κ=8 cycle). All rows
//! share rank 8 and the paper's Adafactor base unless `--optimizer`
//! overrides it; learning rates follow the proven integration-matrix
//! regimes per (optimizer, mode).
//!
//! `BENCH_ablation.json` is a schema-2 TRAJECTORY like BENCH_dp.json
//! (append-only). The seed point is a C mirror of the compressor
//! algebra (`benches/mirror/ablation_mirror.c`), provenance-tagged as
//! such. How to read the file: docs/ARCHITECTURE.md (compressor grid).
//!
//! Run: cargo bench --bench ablation [-- --quick --parallelism N]

use flora::bench::contract;
use flora::bench::paper::BenchArgs;
use flora::config::{TaskKind, TrainConfig};
use flora::coordinator::{MethodSpec, Trainer};
use flora::model::TransformerConfig;
use flora::opt::{OptimizerKind, RankSchedule};
use flora::util::json::Json;

const RANK: usize = 8;

struct Row {
    tag: &'static str,
    method: MethodSpec,
    tau: usize,
    kappa: usize,
    schedule: RankSchedule,
    /// lr per optimizer, `OptimizerKind::ALL` order — the proven
    /// integration-matrix regimes for this row's mode.
    lrs: [f32; 4],
}

fn rows() -> Vec<Row> {
    let accum = [0.5, 0.02, 0.1, 0.1];
    let momentum = [1.0, 0.01, 0.05, 0.05];
    vec![
        Row {
            tag: "flora-alg1",
            method: MethodSpec::Flora { rank: RANK },
            tau: 4,
            kappa: 1000,
            schedule: RankSchedule::Fixed,
            lrs: accum,
        },
        Row {
            tag: "flora-alg2",
            method: MethodSpec::Flora { rank: RANK },
            tau: 1,
            kappa: 1000,
            schedule: RankSchedule::Fixed,
            lrs: momentum,
        },
        Row {
            tag: "altlora",
            method: MethodSpec::AltLora { rank: RANK },
            tau: 4,
            kappa: 1000,
            schedule: RankSchedule::Fixed,
            lrs: accum,
        },
        Row {
            tag: "adarank",
            method: MethodSpec::AdaRank { rank: RANK },
            tau: 1,
            kappa: 8, // short cycles so the shrink schedule actually bites
            schedule: RankSchedule::HalveAt { every: 1 },
            lrs: momentum,
        },
    ]
}

struct Cell {
    key: String,
    model: String,
    tag: &'static str,
    tau: usize,
    schedule: String,
    optimizer: OptimizerKind,
    lr: f32,
    steps_per_sec: f64,
    tok_s: f64,
    method_bytes: u64,
    params_bytes: u64,
    final_loss: f32,
}

fn measure(model: &str, seq_len: usize, row: &Row, steps: usize, args: &BenchArgs) -> Cell {
    let optimizer = args.optimizer.unwrap_or(OptimizerKind::Adafactor);
    let oi = OptimizerKind::ALL.iter().position(|o| *o == optimizer).unwrap();
    let cfg = TrainConfig {
        model: model.into(),
        task: TaskKind::Lm,
        method: row.method,
        optimizer,
        lr: row.lrs[oi],
        steps,
        tau: row.tau,
        kappa: row.kappa,
        batch: 4,
        seed: 0,
        eval_every: 0,
        eval_samples: 8,
        parallelism: args.parallelism,
        rank_schedule: row.schedule,
        ..TrainConfig::default()
    };
    let batch = cfg.batch;
    let lr = cfg.lr;
    let report = Trainer::native(cfg)
        .and_then(|mut t| t.run())
        .unwrap_or_else(|e| {
            eprintln!("[ablation] {model}/{}: {e}", row.tag);
            std::process::exit(1);
        });
    let bytes = |group: &str| {
        report
            .state_bytes
            .iter()
            .find(|(g, _)| g == group)
            .map(|(_, b)| *b)
            .unwrap_or(0)
    };
    Cell {
        key: format!("{model}/{}", row.tag),
        model: model.to_string(),
        tag: row.tag,
        tau: row.tau,
        schedule: row.schedule.name(),
        optimizer,
        lr,
        steps_per_sec: report.steps_per_sec,
        tok_s: report.steps_per_sec * (row.tau * batch * seq_len) as f64,
        method_bytes: bytes("method"),
        params_bytes: bytes("params"),
        final_loss: report.train_losses.last().copied().unwrap_or(f32::NAN),
    }
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn round3(x: f64) -> Json {
    Json::Num((x * 1000.0).round() / 1000.0)
}

fn round6(x: f64) -> Json {
    Json::Num((x * 1e6).round() / 1e6)
}

fn snapshot_of(cells: &[Cell], steps: usize, args: &BenchArgs) -> Json {
    let sizes: Vec<Json> = cells
        .iter()
        .map(|c| {
            let ratio = if c.params_bytes > 0 {
                c.method_bytes as f64 / c.params_bytes as f64
            } else {
                f64::NAN
            };
            obj(vec![
                ("model", Json::Str(c.key.clone())),
                ("base_model", Json::Str(c.model.clone())),
                ("compressor", Json::Str(c.tag.into())),
                ("rank", Json::Num(RANK as f64)),
                ("tau", Json::Num(c.tau as f64)),
                ("rank_schedule", Json::Str(c.schedule.clone())),
                ("optimizer", Json::Str(c.optimizer.to_string())),
                ("lr", round6(c.lr as f64)),
                ("steps", Json::Num(steps as f64)),
                ("steps_per_sec", round3(c.steps_per_sec)),
                ("tok_s", round3(c.tok_s)),
                ("method_state_bytes", Json::Num(c.method_bytes as f64)),
                ("params_bytes", Json::Num(c.params_bytes as f64)),
                ("state_ratio", round6(ratio)),
                ("final_loss", round6(c.final_loss as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("unix_time", Json::Num(contract::unix_time_now() as f64)),
        ("parallelism", Json::Num(args.parallelism.threads() as f64)),
        ("quick", Json::Bool(args.quick)),
        ("provenance", Json::Str("cargo-bench ablation".into())),
        ("sizes", Json::Arr(sizes)),
    ])
}

const COMMENT: &str = "Per-PR adaptive-rank compressor ablation trajectory (final loss, \
     steps/s, tok/s and persistent state bytes for Flora Alg-1/2 vs \
     AltLoRA vs AdaRank on the native LM size grid). Entries are \
     appended, never rewritten; `cargo bench --bench ablation` appends \
     a fresh cargo-bench snapshot. How to read this file: \
     docs/ARCHITECTURE.md (compressor grid).";

fn main() {
    let args = BenchArgs::parse();
    let steps = args.steps.unwrap_or(if args.quick { 4 } else { 30 });
    let mut cells = Vec::new();
    for (name, cfg) in TransformerConfig::catalog_grid() {
        if args.quick && name == "lora-base" {
            continue; // the CI smoke stays fast; full runs cover it
        }
        for row in rows() {
            eprintln!("[ablation] measuring {name}/{} ...", row.tag);
            cells.push(measure(name, cfg.seq_len, &row, steps, &args));
        }
    }

    let mut table = flora::bench::Table::new(
        &format!(
            "compressor ablation (rank {RANK}, {} steps, parallelism {})",
            steps,
            args.parallelism.threads()
        ),
        &["Size/compressor", "steps/s", "tok/s", "method state", "ratio", "final loss"],
    );
    for c in &cells {
        let ratio = if c.params_bytes > 0 {
            c.method_bytes as f64 / c.params_bytes as f64
        } else {
            f64::NAN
        };
        table.row(vec![
            c.key.clone(),
            format!("{:.2}", c.steps_per_sec),
            format!("{:.0}", c.tok_s),
            flora::util::human::bytes(c.method_bytes),
            format!("{:.4}", ratio),
            format!("{:.4}", c.final_loss),
        ]);
    }
    table.print();

    let path = "BENCH_ablation.json";
    match contract::append_to_file(path, "ablation", COMMENT, snapshot_of(&cells, steps, &args)) {
        Ok(()) => println!("\nappended snapshot to {path}"),
        Err(e) => {
            // growing the trajectory is this bench's one artifact; a
            // silent skip would let CI go green on a broken append
            eprintln!("could not append to {path}: {e}");
            std::process::exit(1);
        }
    }
}
