"""Tests for bench_diff.py — the CI bench gate (run via pytest).

Each test drives the script exactly as the workflow does: a subprocess
with a current file, an optional baseline file, and the gate flags.
Covers the no-baseline robustness fix (absent / empty / non-object
baselines must report "no baseline" and exit 0 in warn mode) and the
BENCH_BUDGETS.toml gate semantics (percent budgets, absolute floors,
exact dp byte metrics, and the c-mirror warn-only downgrade)."""

import json
import pathlib
import subprocess
import sys

SCRIPT = pathlib.Path(__file__).with_name("bench_diff.py")

BUDGETS = """
[kernels]
max_regression_pct = 50.0
gate_metrics = "forward_tok_s"

[kernels.floors.lora-tiny]
forward_tok_s = 100.0

[dp]
max_regression_pct = 70.0
gate_metrics = "steps_per_sec"
exact = "per_step_sent_bytes,comms_ratio"
"""


def run(args, cwd):
    return subprocess.run(
        [sys.executable, str(SCRIPT)] + [str(a) for a in args],
        capture_output=True,
        text=True,
        cwd=cwd,
    )


def snapshot(provenance, sizes, quick=True, parallelism=2, runtime="pool"):
    return {
        "provenance": provenance,
        "quick": quick,
        "parallelism": parallelism,
        "runtime": runtime,
        "sizes": sizes,
    }


def write_bench(path, trajectory, bench="micro_kernels"):
    path.write_text(
        json.dumps(
            {"bench": bench, "schema": 2, "comment": "t", "trajectory": trajectory}
        )
    )


def kernels_row(tok_s):
    return {"model": "lora-tiny", "forward_tok_s": tok_s}


def setup(tmp_path, base_tok, fresh_tok, base_prov="cargo-bench micro_kernels"):
    """Baseline with one snapshot; current = baseline + one appended."""
    base_snap = snapshot(base_prov, [kernels_row(base_tok)])
    fresh_snap = snapshot("cargo-bench micro_kernels", [kernels_row(fresh_tok)])
    baseline = tmp_path / "baseline.json"
    current = tmp_path / "BENCH_kernels.json"
    write_bench(baseline, [base_snap])
    write_bench(current, [base_snap, fresh_snap])
    budgets = tmp_path / "BENCH_BUDGETS.toml"
    budgets.write_text(BUDGETS)
    return current, baseline, budgets


# ---------- no-baseline robustness (the old script crashed here) ----------


def test_absent_baseline_warns_and_exits_zero(tmp_path):
    current, _, _ = setup(tmp_path, 1000, 900)
    r = run([current, tmp_path / "missing.json"], tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "no baseline" in r.stdout


def test_empty_baseline_file_warns_and_exits_zero(tmp_path):
    current, baseline, _ = setup(tmp_path, 1000, 900)
    baseline.write_text("")
    r = run([current, baseline], tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "no baseline" in r.stdout


def test_null_json_baseline_warns_and_exits_zero(tmp_path):
    """json.load returns None here — the old .get() crashed with
    AttributeError; now it is a clean 'no baseline'."""
    current, baseline, _ = setup(tmp_path, 1000, 900)
    baseline.write_text("null")
    r = run([current, baseline], tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "no baseline" in r.stdout
    assert "Traceback" not in r.stderr


def test_list_json_baseline_warns_and_exits_zero(tmp_path):
    current, baseline, _ = setup(tmp_path, 1000, 900)
    baseline.write_text("[1, 2]")
    r = run([current, baseline], tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "no baseline" in r.stdout


def test_gate_mode_fails_without_baseline(tmp_path):
    current, _, budgets = setup(tmp_path, 1000, 900)
    r = run(
        [current, tmp_path / "missing.json", "--gate", "--budgets", budgets],
        tmp_path,
    )
    assert r.returncode == 1, r.stdout + r.stderr


def test_no_appended_snapshot_warn_zero_gate_one(tmp_path):
    base_snap = snapshot("c-mirror/gemm-path (x)", [kernels_row(1000)])
    baseline = tmp_path / "baseline.json"
    current = tmp_path / "BENCH_kernels.json"
    write_bench(baseline, [base_snap])
    write_bench(current, [base_snap])  # bench appended nothing
    budgets = tmp_path / "BENCH_BUDGETS.toml"
    budgets.write_text(BUDGETS)
    r = run([current, baseline], tmp_path)
    assert r.returncode == 0
    assert "appended no snapshot" in r.stdout
    r = run([current, baseline, "--gate", "--budgets", budgets], tmp_path)
    assert r.returncode == 1


# ---------- percent regression budgets ----------


def test_gate_fails_on_regression_past_budget(tmp_path):
    current, baseline, budgets = setup(tmp_path, 1000, 400)  # -60% > 50%
    r = run([current, baseline, "--gate", "--budgets", budgets], tmp_path)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "GATE" in r.stdout
    assert "forward_tok_s" in r.stdout


def test_gate_passes_within_budget(tmp_path):
    current, baseline, budgets = setup(tmp_path, 1000, 700)  # -30% < 50%
    r = run([current, baseline, "--gate", "--budgets", budgets], tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr


def test_cmirror_baseline_is_warn_only_for_percent_budgets(tmp_path):
    current, baseline, budgets = setup(
        tmp_path, 50000, 400, base_prov="c-mirror/gemm-path (gcc -O2)"
    )
    r = run([current, baseline, "--gate", "--budgets", budgets], tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "warn-only per ROADMAP item 6" in r.stdout


def test_warn_mode_reports_violation_but_exits_zero(tmp_path):
    current, baseline, budgets = setup(tmp_path, 1000, 400)
    r = run([current, baseline, "--budgets", budgets], tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "GATE" in r.stdout  # reported...
    assert "warn mode never fails" in r.stdout  # ...but not fatal


# ---------- absolute floors ----------


def test_floor_on_quoted_slash_model_name_still_matches(tmp_path):
    """Serving/dp model ids carry slashes, so their floors tables are
    quoted in the TOML (`[serving.floors."lora-tiny/b1"]`); the reader
    must strip the quotes or the floor silently never fires."""
    budgets_text = (
        "[serving]\n"
        'gate_metrics = "decode_tok_s"\n'
        '[serving.floors."lora-tiny/b1"]\n'
        "decode_tok_s = 100.0\n"
    )
    row = {"model": "lora-tiny/b1", "decode_tok_s": 50.0}  # below floor
    base_snap = snapshot("c-mirror/serve-path (x)", [row])
    fresh_snap = snapshot("cargo-bench serving", [row])
    baseline = tmp_path / "baseline.json"
    current = tmp_path / "BENCH_serving.json"
    write_bench(baseline, [base_snap], bench="serving")
    write_bench(current, [base_snap, fresh_snap], bench="serving")
    budgets = tmp_path / "BENCH_BUDGETS.toml"
    budgets.write_text(budgets_text)
    r = run([current, baseline, "--gate", "--budgets", budgets], tmp_path)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "absolute" in r.stdout


def test_floor_violation_fails_even_with_cmirror_baseline(tmp_path):
    current, baseline, budgets = setup(
        tmp_path, 50000, 50, base_prov="c-mirror/gemm-path (gcc -O2)"
    )  # fresh 50 < floor 100
    r = run([current, baseline, "--gate", "--budgets", budgets], tmp_path)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "absolute" in r.stdout


# ---------- exact metrics (dp comms bytes) ----------


def dp_setup(tmp_path, base_bytes, fresh_bytes):
    row = lambda b: {
        "model": "lora-tiny/compressed",
        "steps_per_sec": 10.0,
        "per_step_sent_bytes": b,
        "comms_ratio": 0.41,
    }
    base_snap = snapshot("c-mirror/comms-path (x)", [row(base_bytes)])
    fresh_snap = snapshot("cargo-bench dp", [row(fresh_bytes)])
    baseline = tmp_path / "baseline.json"
    current = tmp_path / "BENCH_dp.json"
    write_bench(baseline, [base_snap], bench="dp")
    write_bench(current, [base_snap, fresh_snap], bench="dp")
    budgets = tmp_path / "BENCH_BUDGETS.toml"
    budgets.write_text(BUDGETS)
    return current, baseline, budgets


def test_exact_metric_mismatch_fails_even_for_cmirror(tmp_path):
    current, baseline, budgets = dp_setup(tmp_path, 71168, 71169)
    r = run([current, baseline, "--gate", "--budgets", budgets], tmp_path)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "exact metric" in r.stdout


def test_exact_metric_match_passes(tmp_path):
    current, baseline, budgets = dp_setup(tmp_path, 71168, 71168)
    r = run([current, baseline, "--gate", "--budgets", budgets], tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr


# ---------- ablation trajectory (warn-only: no [ablation] budgets yet) ----------


def ablation_row(tag, final_loss, tok_s):
    return {
        "model": f"lora-tiny/{tag}",
        "base_model": "lora-tiny",
        "compressor": tag,
        "rank": 8,
        "final_loss": final_loss,
        "tok_s": tok_s,
        "state_ratio": 0.125,
    }


def ablation_setup(tmp_path, base_loss, fresh_loss):
    """Mimics the committed file: a c-mirror seed (tok_s null — the
    mirror times the update algebra, not a token stream) plus one
    appended cargo-bench snapshot. No "runtime" key on purpose:
    ablation snapshots are single-driver, unlike kernels."""
    base_snap = {
        "provenance": "c-mirror/compressor-algebra (gcc -O2)",
        "quick": False,
        "parallelism": 1,
        "sizes": [ablation_row("altlora", base_loss, None)],
    }
    fresh_snap = {
        "provenance": "cargo-bench ablation",
        "quick": True,
        "parallelism": 2,
        "sizes": [ablation_row("altlora", fresh_loss, 5000.0)],
    }
    baseline = tmp_path / "baseline.json"
    current = tmp_path / "BENCH_ablation.json"
    write_bench(baseline, [base_snap], bench="ablation")
    write_bench(current, [base_snap, fresh_snap], bench="ablation")
    return current, baseline


def test_ablation_warn_only_diff_exits_zero_and_notes_provenance(tmp_path):
    """The workflow's ablation step passes no --gate/--budgets: any
    final-loss movement against the c-mirror seed must render in the
    summary table and exit 0."""
    current, baseline = ablation_setup(tmp_path, 0.000076, 0.31)
    r = run([current, baseline], tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "final_loss" in r.stdout
    assert "provenance differs" in r.stdout
    assert "Traceback" not in r.stderr


def test_ablation_null_tok_s_in_seed_is_skipped_not_diffed(tmp_path):
    """The c-mirror seed carries tok_s: null (unmeasured); the diff must
    skip that pair rather than crash or print a bogus delta row."""
    current, baseline = ablation_setup(tmp_path, 0.31, 0.31)
    r = run([current, baseline], tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr
    # no table cell for tok_s (the bare substring also sits in pytest's
    # tmp dir name, which the script echoes — match the cell form)
    assert "| tok_s |" not in r.stdout
    assert "| final_loss |" in r.stdout
    assert "Traceback" not in r.stderr


def test_ablation_gate_without_section_fails_loudly(tmp_path):
    """BENCH_BUDGETS.toml has no [ablation] section yet (ROADMAP item
    4); if someone flips the CI step to --gate before adding budgets it
    must fail, not silently pass."""
    current, baseline = ablation_setup(tmp_path, 0.31, 0.31)
    budgets = tmp_path / "BENCH_BUDGETS.toml"
    budgets.write_text(BUDGETS)
    r = run([current, baseline, "--gate", "--budgets", budgets], tmp_path)
    assert r.returncode == 1
    assert "no [ablation] section" in r.stdout


# ---------- misc ----------


def test_gate_requires_budgets_flag(tmp_path):
    current, baseline, _ = setup(tmp_path, 1000, 900)
    r = run([current, baseline, "--gate"], tmp_path)
    assert r.returncode == 1
    assert "--budgets" in r.stdout


def test_unknown_section_fails_gate(tmp_path):
    current, baseline, budgets = setup(tmp_path, 1000, 900)
    r = run(
        [current, baseline, "--gate", "--budgets", budgets, "--section", "nope"],
        tmp_path,
    )
    assert r.returncode == 1
    assert "no [nope] section" in r.stdout
