#!/usr/bin/env python3
"""Bench-trajectory diff + regression gate for CI (BENCH_kernels.json,
BENCH_serving.json, BENCH_dp.json — any schema-2 trajectory file).

Usage:
    bench_diff.py <current.json> [baseline.json]
                  [--gate] [--budgets BENCH_BUDGETS.toml] [--section NAME]

The benches APPEND one snapshot per invocation — and the CI smoke step
can invoke them more than once (pool and scope drivers) — so "the
committed baseline" cannot be recovered from the current file alone.
The workflow snapshots the committed file BEFORE the bench runs and
passes it as the second argument: the baseline is that file's last
entry, and the fresh measurement is chosen from the entries the bench
appended (preferring the pool driver, the production default).

Modes:

* warn (default): render the markdown comparison for the job summary
  and ALWAYS exit 0 — including when the baseline file is absent,
  empty, or unparsable ("no baseline", exit 0). Budget violations, if
  a budgets file is given, are printed as warnings.

* gate (--gate): enforce BENCH_BUDGETS.toml (docs/OPS.md §2) and exit
  1 on any violation — or on a missing baseline/fresh snapshot, since
  an ungateable run must not look green. Three budget kinds:

  - exact metrics: must match the baseline bit-for-bit whenever the
    model row carries them on both sides. They are analytic (the dp
    byte formulas), machine- and worker-count-independent, so they
    gate against EVERY baseline provenance, c-mirror included.
  - max_regression_pct over gate_metrics: enforced only for
    like-for-like pairs — baseline provenance starts with
    "cargo-bench" AND quick/parallelism agree. C-mirror baselines
    (ROADMAP item 6) and mismatched run shapes downgrade to warnings,
    printed loudly.
  - per-size floors: absolute tokens/sec minimums on the FRESH
    cargo-bench snapshot, enforced regardless of baseline — the
    catastrophic-collapse backstop that still bites while the
    committed baselines are c-mirror.
"""

import json
import sys


def fmt(x):
    if not isinstance(x, (int, float)):
        return str(x)
    # keep decimals on small metrics (the attention speedup gate lives
    # around 5.x — ':,.0f' would render baseline and fresh identically
    # while the delta column disagrees)
    return f"{x:,.2f}" if abs(x) < 100 else f"{x:,.0f}"


def load_trajectory(path):
    """Return (trajectory list | None, reason). Tolerates absent files,
    empty files, and JSON that parses to a non-object (null, a list) —
    the old version crashed with AttributeError on those."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return None, f"cannot read {path}: {e}"
    if not isinstance(doc, dict):
        return None, f"{path}: top level is {type(doc).__name__}, not an object"
    traj = doc.get("trajectory", [])
    if not isinstance(traj, list):
        return None, f"{path}: \"trajectory\" is not a list"
    return traj, None


def parse_budgets(path):
    """Mini TOML-subset reader (python3.10 has no tomllib; the repo's
    zero-dep rust parser is the reference — config/toml.rs). Returns
    {section_name: {key: value}} with sections kept un-flattened."""
    sections = {}
    current = None
    with open(path) as f:
        for raw in f:
            line = raw.split("#", 1)[0].strip()
            if not line:
                continue
            if line.startswith("[") and line.endswith("]"):
                # Segments with TOML-special chars (e.g. the "/" in
                # serving/dp model ids) are quoted in the file; strip the
                # quotes so floors lookups match the raw model names.
                current = line[1:-1].strip().replace('"', "")
                sections.setdefault(current, {})
                continue
            if "=" not in line or current is None:
                continue
            key, val = (p.strip() for p in line.split("=", 1))
            if val.startswith('"') and val.endswith('"'):
                sections[current][key] = val[1:-1]
            else:
                try:
                    sections[current][key] = float(val)
                except ValueError:
                    sections[current][key] = val
    return sections


def section_for(path, override):
    if override:
        return override
    name = path.rsplit("/", 1)[-1]
    if name.startswith("BENCH_") and name.endswith(".json"):
        return name[len("BENCH_"):-len(".json")]
    return name


def csv_list(value):
    return [p.strip() for p in str(value or "").split(",") if p.strip()]


def is_cargo_bench(snap):
    return str(snap.get("provenance", "")).startswith("cargo-bench")


def same_shape(a, b):
    return a.get("quick") == b.get("quick") and a.get(
        "parallelism"
    ) == b.get("parallelism")


def check_budgets(section, budgets, base, fresh):
    """Return (violations, warnings) — violation lines fail --gate."""
    violations, warnings = [], []
    cfg = budgets.get(section)
    if cfg is None:
        violations.append(
            f"budgets file has no [{section}] section — cannot gate"
        )
        return violations, warnings

    base_sizes = {s.get("model"): s for s in base.get("sizes", [])}
    exact = csv_list(cfg.get("exact"))
    gate_metrics = csv_list(cfg.get("gate_metrics"))
    max_pct = cfg.get("max_regression_pct")

    pct_enforced = is_cargo_bench(base) and same_shape(base, fresh)
    if gate_metrics and max_pct is not None and not pct_enforced:
        why = (
            "baseline provenance is not cargo-bench (c-mirror stays "
            "warn-only per ROADMAP item 6)"
            if not is_cargo_bench(base)
            else "baseline and fresh differ in quick/parallelism"
        )
        warnings.append(f"percent budgets downgraded to warnings: {why}")

    for row in fresh.get("sizes", []):
        model = row.get("model")
        b = base_sizes.get(model)
        # 1) exactness: analytic metrics must not move, ever
        if b is not None:
            for k in exact:
                if k in row and k in b and row[k] != b[k]:
                    violations.append(
                        f"{model} {k}: fresh {row[k]!r} != baseline "
                        f"{b[k]!r} (exact metric — analytic, must not move)"
                    )
        # 2) percent regression budget on throughput metrics
        if b is not None and max_pct is not None:
            for k in gate_metrics:
                old, new = b.get(k), row.get(k)
                if not isinstance(old, (int, float)) or not isinstance(
                    new, (int, float)
                ):
                    continue
                if old <= 0:
                    continue
                drop = (old - new) / old * 100
                if drop > max_pct:
                    line = (
                        f"{model} {k}: {fmt(new)} is {drop:.1f}% below "
                        f"baseline {fmt(old)} (budget {max_pct:.0f}%)"
                    )
                    (violations if pct_enforced else warnings).append(line)
        # 3) absolute floors on the fresh snapshot
        if is_cargo_bench(fresh):
            floors = budgets.get(f"{section}.floors.{model}", {})
            for k, floor in floors.items():
                new = row.get(k)
                if isinstance(new, (int, float)) and new < floor:
                    violations.append(
                        f"{model} {k}: {fmt(new)} is below the absolute "
                        f"floor {fmt(floor)} (catastrophic collapse)"
                    )
    return violations, warnings


def parse_args(argv):
    opts = {"gate": False, "budgets": None, "section": None}
    positional = []
    it = iter(argv)
    for a in it:
        if a == "--gate":
            opts["gate"] = True
        elif a == "--budgets":
            opts["budgets"] = next(it, None)
        elif a == "--section":
            opts["section"] = next(it, None)
        elif a.startswith("--"):
            print(f"bench diff: unknown flag {a}")
            sys.exit(2)
        else:
            positional.append(a)
    return positional, opts


def main():
    positional, opts = parse_args(sys.argv[1:])
    path = positional[0] if positional else "BENCH_kernels.json"
    baseline_path = positional[1] if len(positional) > 1 else None
    gate = opts["gate"]
    mode = "gate" if gate else "warn-only"

    def no_baseline(reason):
        print(f"bench diff: no baseline — {reason}")
        if gate:
            print("bench diff: GATE mode cannot pass without a baseline")
            sys.exit(1)
        sys.exit(0)

    traj, err = load_trajectory(path)
    if traj is None:
        print(f"bench diff: {err}")
        sys.exit(1 if gate else 0)

    if baseline_path:
        base_traj, err = load_trajectory(baseline_path)
        if err:
            no_baseline(err)
        if not base_traj:
            no_baseline(f"{baseline_path} has an empty trajectory")
        base = base_traj[-1]
        if traj[: len(base_traj)] == base_traj:
            appended = traj[len(base_traj):]
        else:
            # the current file's history does not extend the baseline
            # (e.g. a scratch checkout) — match appended entries by tag
            appended = [s for s in traj if is_cargo_bench(s)]
        if not appended:
            print("bench diff: the bench appended no snapshot, nothing to diff")
            sys.exit(1 if gate else 0)
        pool_runs = [s for s in appended if s.get("runtime") == "pool"]
        fresh = pool_runs[-1] if pool_runs else appended[-1]
    else:
        if len(traj) < 2:
            no_baseline(f"{path} has {len(traj)} trajectory entr(y/ies)")
        print("bench diff: no baseline file given — comparing the last two entries\n")
        fresh, base = traj[-1], traj[-2]

    print(f"### bench diff: {path} vs committed baseline ({mode})\n")
    for label, snap in [("baseline", base), ("fresh", fresh)]:
        print(
            f"- **{label}**: runtime={snap.get('runtime')} "
            f"parallelism={snap.get('parallelism')} quick={snap.get('quick')} "
            f"— {snap.get('provenance', 'no provenance')}"
        )
    if base.get("provenance", "").split()[0:1] != fresh.get("provenance", "").split()[0:1]:
        print(
            "\n> provenance differs — absolute numbers are NOT comparable "
            "(the mirror measures the GEMM path only); read deltas as "
            "directional at best.\n"
        )

    base_sizes = {s["model"]: s for s in base.get("sizes", [])}
    rows = []
    for s in fresh.get("sizes", []):
        b = base_sizes.get(s["model"])
        if not b:
            continue
        shared = [
            k
            for k, v in s.items()
            if isinstance(v, (int, float))
            and isinstance(b.get(k), (int, float))
            and k != "tokens_per_batch"
        ]
        for k in shared:
            old, new = b[k], s[k]
            delta = (new - old) / old * 100 if old else float("nan")
            flag = " ⚠️" if old and delta < -10 else ""
            rows.append((s["model"], k, fmt(old), fmt(new), f"{delta:+.1f}%{flag}"))
    if rows:
        print("\n| model | metric | baseline | fresh | delta |")
        print("|---|---|---:|---:|---:|")
        for r in rows:
            print("| " + " | ".join(r) + " |")
    else:
        print("\nno shared numeric fields between the two snapshots")

    if opts["budgets"] is None:
        if gate:
            print("\nbench diff: GATE mode needs --budgets BENCH_BUDGETS.toml")
            sys.exit(1)
        sys.exit(0)
    try:
        budgets = parse_budgets(opts["budgets"])
    except OSError as e:
        print(f"\nbench diff: cannot read budgets: {e}")
        sys.exit(1 if gate else 0)

    section = section_for(path, opts["section"])
    violations, warnings = check_budgets(section, budgets, base, fresh)
    print(f"\n#### budget check [{section}] ({mode})\n")
    for w in warnings:
        print(f"- warn: {w}")
    for v in violations:
        print(f"- **GATE**: {v}")
    if not violations and not warnings:
        print("- all budgets satisfied")
    if violations and gate:
        print(f"\nbench diff: {len(violations)} budget violation(s) — failing")
        sys.exit(1)
    if violations:
        print("\nbench diff: violations reported, warn mode never fails")
    sys.exit(0)


if __name__ == "__main__":
    main()
