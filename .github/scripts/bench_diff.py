#!/usr/bin/env python3
"""Warn-only bench-trajectory diff for CI (BENCH_kernels.json,
BENCH_serving.json — any schema-2 trajectory file).

Usage: bench_diff.py <current.json> [baseline.json]

The kernel microbench APPENDS one snapshot per invocation — and the CI
smoke step invokes it more than once (pool and scope drivers) — so "the
committed baseline" cannot be recovered from the current file alone.
The workflow therefore snapshots the committed file BEFORE the bench
runs and passes it as the second argument: the baseline is that file's
last entry, and the fresh measurement is chosen from the entries the
bench appended (preferring the pool driver, the production default).
With no baseline file the script falls back to the last two entries of
the current file and says so.

This script renders a markdown comparison (shared numeric fields, per
model) for the job summary. It NEVER fails the job: regressions on
shared CI runners are a signal to investigate, not a gate (the bench
binary itself exits non-zero on real errors, which is the failing
condition). Comparability caveats are printed loudly: entries can
differ in parallelism, --quick, runtime driver, and provenance (the
first committed points were measured with the C GEMM-path mirror,
benches/mirror/kernel_mirror.c, whose absolute numbers overstate
full-model throughput — see docs/PERFORMANCE.md).
"""

import json
import sys


def fmt(x):
    if not isinstance(x, (int, float)):
        return str(x)
    # keep decimals on small metrics (the attention speedup gate lives
    # around 5.x — ':,.0f' would render baseline and fresh identically
    # while the delta column disagrees)
    return f"{x:,.2f}" if abs(x) < 100 else f"{x:,.0f}"


def load_trajectory(path):
    try:
        with open(path) as f:
            return json.load(f).get("trajectory", [])
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench diff: cannot read {path}: {e}")
        return None


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_kernels.json"
    baseline_path = sys.argv[2] if len(sys.argv) > 2 else None
    traj = load_trajectory(path)
    if traj is None:
        return
    if baseline_path:
        base_traj = load_trajectory(baseline_path)
        if not base_traj:
            print("bench diff: empty/unreadable baseline, nothing to diff")
            return
        base = base_traj[-1]
        if traj[: len(base_traj)] == base_traj:
            appended = traj[len(base_traj):]
        else:
            # the bench starts a FRESH trajectory when the committed file
            # was unparsable/not schema-2 — fall back to matching the
            # appended entries by their provenance tag
            appended = [
                s
                for s in traj
                if s.get("provenance", "").startswith("cargo-bench")
            ]
        if not appended:
            print("bench diff: the bench appended no snapshot, nothing to diff")
            return
        pool_runs = [s for s in appended if s.get("runtime") == "pool"]
        fresh = pool_runs[-1] if pool_runs else appended[-1]
    else:
        if len(traj) < 2:
            print(f"bench diff: {len(traj)} trajectory entr(y/ies), nothing to diff")
            return
        print("bench diff: no baseline file given — comparing the last two entries\n")
        fresh, base = traj[-1], traj[-2]

    print(f"### bench diff: {path} vs committed baseline (warn-only)\n")
    for label, snap in [("baseline", base), ("fresh", fresh)]:
        print(
            f"- **{label}**: runtime={snap.get('runtime')} "
            f"parallelism={snap.get('parallelism')} quick={snap.get('quick')} "
            f"— {snap.get('provenance', 'no provenance')}"
        )
    if base.get("provenance", "").split()[0:1] != fresh.get("provenance", "").split()[0:1]:
        print(
            "\n> provenance differs — absolute numbers are NOT comparable "
            "(the mirror measures the GEMM path only); read deltas as "
            "directional at best.\n"
        )

    base_sizes = {s["model"]: s for s in base.get("sizes", [])}
    rows = []
    for s in fresh.get("sizes", []):
        b = base_sizes.get(s["model"])
        if not b:
            continue
        shared = [
            k
            for k, v in s.items()
            if isinstance(v, (int, float))
            and isinstance(b.get(k), (int, float))
            and k != "tokens_per_batch"
        ]
        for k in shared:
            old, new = b[k], s[k]
            delta = (new - old) / old * 100 if old else float("nan")
            flag = " ⚠️" if old and delta < -10 else ""
            rows.append((s["model"], k, fmt(old), fmt(new), f"{delta:+.1f}%{flag}"))
    if not rows:
        print("\nno shared numeric fields between the two snapshots")
        return
    print("\n| model | metric | baseline | fresh | delta |")
    print("|---|---|---:|---:|---:|")
    for r in rows:
        print("| " + " | ".join(r) + " |")


if __name__ == "__main__":
    main()
