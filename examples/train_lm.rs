//! End-to-end driver (the DESIGN.md §validation run): train the lm-base
//! model (~0.9M params, d=128, 4 layers) from scratch with FLORA-compressed
//! momentum (Algorithm 2) on the C4-sim corpus for a few hundred steps,
//! logging the loss curve; record the run in EXPERIMENTS.md.
//!
//! Run: cargo run --release --example train_lm [-- steps]

use flora::config::{TaskKind, TrainConfig};
use flora::coordinator::{MethodSpec, Trainer};
use flora::metrics;
use flora::opt::OptimizerKind;
use flora::util::human;

fn main() -> Result<(), String> {
    let steps = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200usize);
    let cfg = TrainConfig {
        model: "lm-base".into(),
        task: TaskKind::Lm,
        method: MethodSpec::Flora { rank: 16 },
        optimizer: OptimizerKind::Adafactor,
        lr: 0.03,
        steps,
        tau: 1, // momentum mode
        kappa: 50,
        batch: 4,
        seed: 0,
        eval_every: 25,
        eval_samples: 64,
        ..Default::default()
    };
    println!(
        "train_lm: lm-base (d=128, 4 layers) from scratch, FLORA(16) momentum, {steps} steps"
    );
    let mut trainer = Trainer::new(cfg, "artifacts")?;
    let report = trainer.run()?;

    println!("\nloss curve ({} steps):", report.train_losses.len());
    println!("  {}", flora::bench::sparkline(&report.train_losses, 64));
    for (s, l) in &report.eval_losses {
        println!("  step {s:>4}: val_loss {l:.4}  (ppl {:.1})", metrics::perplexity(*l as f64));
    }
    println!("\nfinal train loss: {:.4}", report.final_train_loss());
    println!("final metric    : PPL {}", report.metric.map(|m| m.render()).unwrap());
    println!("throughput      : {:.2} steps/s", report.steps_per_sec);
    println!("state bytes     : {}", human::bytes(report.total_state_bytes()));
    Ok(())
}
