//! Domain example: the paper's translation workload (Table 1b analogue).
//!
//! Trains GPT-2-sim (lm-small) on IWSLT-sim with FLORA(16) accumulation,
//! then greedy-decodes a few test sentences and prints them through the
//! synthetic-vocabulary tokenizer next to the references, with corpus BLEU.
//!
//! Run: cargo run --release --example translate

use flora::config::{TaskKind, TrainConfig};
use flora::coordinator::{MethodSpec, Trainer};
use flora::opt::OptimizerKind;
use flora::tokenizer::Tokenizer;

fn main() -> Result<(), String> {
    let cfg = TrainConfig {
        model: "lm-small".into(),
        task: TaskKind::Mt,
        method: MethodSpec::Flora { rank: 16 },
        optimizer: OptimizerKind::Adafactor,
        lr: 0.05,
        steps: 40,
        tau: 4,
        kappa: 1000,
        batch: 4,
        seed: 0,
        eval_every: 10,
        eval_samples: 32,
        ..Default::default()
    };
    println!("translate: FLORA(16) accumulation on IWSLT-sim (lm-small)");
    let mut trainer = Trainer::new(cfg, "artifacts")?;
    let report = trainer.run()?;
    println!(
        "trained: final loss {:.4}, BLEU {}",
        report.final_train_loss(),
        report.metric.map(|m| m.render()).unwrap()
    );

    // show a few decoded examples through the tokenizer
    let tok = Tokenizer::new(256);
    let examples = trainer.task.gen_examples(2, 3);
    println!("\nsample prompts and references:");
    for ex in &examples {
        println!("  src: {}", tok.decode(&ex.prompt));
        println!("  ref: {}", tok.decode(&ex.reference));
        println!();
    }
    Ok(())
}
