//! Quickstart: the smallest end-to-end FLORA workflow — XLA-free.
//!
//! Runs entirely on the NATIVE backend (the pure-rust executor over the
//! generated bigram-LM catalog): trains lm-tiny with FLORA
//! gradient-accumulation compression (Algorithm 1) for a handful of
//! cycles, prints the loss curve and the compressed-state memory ledger.
//! No artifacts, no PJRT, no network — `cargo run --example quickstart`
//! works on a bare machine.
//!
//! For the transformer/AOT path, build with `--features xla`, run
//! `make artifacts`, and pass `--backend xla` to the `flora train` CLI.

use flora::config::{TaskKind, TrainConfig};
use flora::coordinator::{MethodSpec, Trainer};
use flora::opt::OptimizerKind;
use flora::util::human;

fn main() -> Result<(), String> {
    let cfg = TrainConfig {
        model: "lm-tiny".into(),
        task: TaskKind::Sum,
        method: MethodSpec::Flora { rank: 4 },
        // the paper's base optimizer; the native catalog also executes
        // sgd, adam and adafactor_nofactor (--optimizer on the CLI)
        optimizer: OptimizerKind::Adafactor,
        lr: 0.5,
        steps: 12,   // 12 optimizer steps = 12 x tau microbatches
        tau: 4,      // Algorithm 1 accumulation length
        kappa: 1000,
        batch: 4,
        seed: 0,
        eval_every: 4,
        eval_samples: 16,
        ..Default::default()
    };
    println!(
        "quickstart: FLORA(4) + Adafactor gradient accumulation on \
         lm-tiny/sum (native backend)"
    );
    let mut trainer = Trainer::native(cfg)?;
    let report = trainer.run()?;

    println!("\nloss curve: {}", flora::bench::sparkline(&report.train_losses, 48));
    println!("first loss : {:.4}", report.train_losses.first().unwrap());
    println!("final loss : {:.4}", report.final_train_loss());
    println!("ROUGE      : {}", report.metric.map(|m| m.render()).unwrap());
    println!("\nstate ledger (the paper's point — look at [method]):");
    for (g, b) in &report.state_bytes {
        if *b > 0 {
            println!("  {g:<8} {}", human::bytes(*b));
        }
    }
    println!(
        "\nFLORA keeps the accumulator at rank 4: a naive accumulator would \
     need the full parameter size ({}).",
        human::bytes(report.state_bytes.iter().find(|(g, _)| g == "params").unwrap().1)
    );
    Ok(())
}
