//! The paper's memory story at every scale, from the analytic accountant:
//! Tables 1-2 Mem/ΔM columns for all four paper model sizes, plus the §5
//! GPT-3 projection ("r=256 state is ~2% of the original memory").
//!
//! Pure accounting — runs without artifacts.
//!
//! Run: cargo run --release --example memory_report

use flora::bench::Table;
use flora::memory::{breakdown, delta_m, Dims, Method, OptKind, StateRole};
use flora::util::human;

fn main() {
    let models = [
        ("T5-small (60M)", Dims::t5_small_sim()),
        ("GPT-2 base (110M)", Dims::gpt2_base_sim()),
        ("GPT-2-XL (1.5B)", Dims::gpt2_xl_sim()),
        ("T5-3B", Dims::t5_3b_sim()),
    ];
    for (name, dims) in &models {
        let mut t = Table::new(
            &format!("{name} — optimizer-adjacent state (Adafactor base)"),
            &["Method", "opt state", "method state", "LoRA extra", "ΔM vs None"],
        );
        for m in [
            Method::None,
            Method::Naive,
            Method::Lora(256),
            Method::Flora(256),
            Method::Galore(256),
        ] {
            let b = breakdown(dims, m, OptKind::Adafactor, StateRole::Accumulation, 1, false);
            let dm = delta_m(dims, m, OptKind::Adafactor, StateRole::Accumulation, 1);
            t.row(vec![
                m.label(),
                human::bytes(b.opt_state),
                human::bytes(b.method_state),
                human::bytes(b.extra_params),
                format!("{:+.3} GiB", dm as f64 / (1u64 << 30) as f64),
            ]);
        }
        t.print();
    }

    // §5 future-work estimate: GPT-3 175B
    let gpt3 = Dims {
        vocab: 50257, d_model: 12288, n_layers: 96, d_ff: 49152,
        seq_len: 2048, n_heads: 96,
    };
    let full: u64 = gpt3.param_count() * 4;
    let compressed: u64 = gpt3
        .params()
        .iter()
        .map(|e| if e.projectable { e.rows * 256 * 4 } else { e.numel() * 4 })
        .sum();
    println!(
        "\nGPT-3 projection (paper §5): params {} — naive accumulator {} vs \
         FLORA(256) {} = {:.2}% of original",
        human::params(gpt3.param_count()),
        human::bytes(full),
        human::bytes(compressed),
        100.0 * compressed as f64 / full as f64
    );
}
