//! Domain example: the paper's summarization workload (Table 1a analogue),
//! comparing FLORA against the LoRA baseline head-to-head at equal rank.
//!
//! Run: cargo run --release --example summarize

use flora::config::{TaskKind, TrainConfig};
use flora::coordinator::{MethodSpec, Trainer};
use flora::opt::OptimizerKind;
use flora::util::human;

fn run(method: MethodSpec, lr: f32) -> Result<(), String> {
    let cfg = TrainConfig {
        model: "lm-small".into(),
        task: TaskKind::Sum,
        method,
        optimizer: OptimizerKind::Adafactor,
        lr,
        steps: 30,
        tau: 4,
        kappa: 1000,
        batch: 4,
        seed: 0,
        eval_every: 0,
        eval_samples: 32,
        ..Default::default()
    };
    let mut trainer = Trainer::new(cfg, "artifacts")?;
    let report = trainer.run()?;
    println!(
        "{:<10} loss {:.4}  ROUGE {}  state {}",
        report.label,
        report.final_train_loss(),
        report.metric.map(|m| m.render()).unwrap(),
        human::bytes(report.total_state_bytes()),
    );
    Ok(())
}

fn main() -> Result<(), String> {
    println!("summarize: XSum-sim, FLORA(16) vs LoRA(16), tau=4 accumulation\n");
    run(MethodSpec::Flora { rank: 16 }, 0.05)?;
    run(MethodSpec::Lora { rank: 16 }, 0.2)?; // LoRA gets its tuned LR (§3.1)
    println!("\nexpected (paper Table 1a): FLORA beats LoRA at equal rank.");
    Ok(())
}
