"""FLORA method-layer correctness (Algorithms 1 and 2).

The crucial invariants:
  * flora accumulation == naive accumulation followed by one
    compress/decompress with the SAME projection (exact algebra, not approx);
  * as r -> m, flora's decompressed accumulator converges to the naive one
    (Theorem 2.4);
  * momentum transfer preserves the state in expectation;
  * per-parameter seeds are independent (derive_seed).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import flora
from compile.kernels import ref, rp

SHAPES = {
    "layer0/attn/wq": (16, 16),
    "layer0/ffn/w1": (16, 32),
    "embed/tok": (64, 16),  # not projectable
    "layer0/ln1/scale": (16,),  # not projectable
}


def _grads(seed):
    key = jax.random.PRNGKey(seed)
    out = {}
    for k, s in sorted(SHAPES.items()):
        key, sub = jax.random.split(key)
        out[k] = jax.random.normal(sub, s, jnp.float32)
    return out


class TestProjectable:
    def test_projectable_selection(self):
        names = flora.projectable_names(SHAPES)
        assert names == ["layer0/attn/wq", "layer0/ffn/w1"]


class TestAccumulation:
    def test_naive_accumulates_sum(self):
        acc = flora.NaiveAccumulation(SHAPES)
        st = acc.init_state()
        g1, g2 = _grads(0), _grads(1)
        st = acc.accumulate(st, g1, jnp.uint32(0))
        st = acc.accumulate(st, g2, jnp.uint32(0))
        mean = acc.mean_grads(st, jnp.uint32(0), 2.0)
        for k in SHAPES:
            np.testing.assert_allclose(
                mean[k], (g1[k] + g2[k]) / 2.0, rtol=1e-5, atol=1e-6
            )

    def test_flora_state_is_compressed(self):
        acc = flora.FloraAccumulation(SHAPES, rank=4)
        shapes = acc.state_shapes()
        assert shapes["acc/layer0/attn/wq"] == (16, 4)
        assert shapes["acc/layer0/ffn/w1"] == (16, 4)
        assert shapes["acc/embed/tok"] == (64, 16)  # full for non-projected

    def test_flora_equals_projected_naive(self):
        """C A == (Σ G) A^T A exactly when the same seed is used throughout —
        this is the paper's Eq. (19)=(20) identity."""
        r, seed = 8, jnp.uint32(99)
        acc = flora.FloraAccumulation(SHAPES, rank=r)
        st = acc.init_state()
        gs = [_grads(i) for i in range(3)]
        for g in gs:
            st = acc.accumulate(st, g, seed)
        mean = acc.mean_grads(st, seed, 3.0)
        for k in ["layer0/attn/wq", "layer0/ffn/w1"]:
            gsum = sum(g[k] for g in gs) / 3.0
            a = rp.project_normal(
                flora.derive_seed(seed, acc.index[k]), r, SHAPES[k][1]
            )
            want = ref.decompress(ref.compress(gsum, a), a)
            np.testing.assert_allclose(mean[k], want, rtol=1e-4, atol=1e-5)

    def test_flora_converges_to_naive_with_rank(self):
        """Reconstruction error decreases with r (Theorem 2.4 rate)."""
        g = _grads(0)
        errs = []
        for r in (4, 16, 64, 256):
            acc = flora.FloraAccumulation(SHAPES, rank=r)
            st = acc.init_state()
            st = acc.accumulate(st, g, jnp.uint32(0))
            mean = acc.mean_grads(st, jnp.uint32(0), 1.0)
            k = "layer0/ffn/w1"
            errs.append(float(jnp.linalg.norm(mean[k] - g[k])))
        assert errs[-1] < errs[0] * 0.6, errs

    def test_nonprojected_params_exact(self):
        acc = flora.FloraAccumulation(SHAPES, rank=4)
        st = acc.init_state()
        g = _grads(0)
        st = acc.accumulate(st, g, jnp.uint32(0))
        mean = acc.mean_grads(st, jnp.uint32(0), 1.0)
        np.testing.assert_allclose(mean["embed/tok"], g["embed/tok"], rtol=1e-6)
        np.testing.assert_allclose(
            mean["layer0/ln1/scale"], g["layer0/ln1/scale"], rtol=1e-6
        )


class TestMomentum:
    def test_naive_momentum_ema(self):
        mom = flora.NaiveMomentum(SHAPES, beta=0.9)
        st = mom.init_state()
        g = _grads(0)
        eff, st = mom.step(st, g, jnp.uint32(0), jnp.uint32(1), 0.0)
        for k in SHAPES:
            np.testing.assert_allclose(eff[k], 0.1 * g[k], rtol=1e-5)
        eff2, st = mom.step(st, g, jnp.uint32(0), jnp.uint32(1), 0.0)
        for k in SHAPES:
            np.testing.assert_allclose(eff2[k], 0.19 * g[k], rtol=1e-5)

    def test_flora_no_resample_keeps_subspace(self):
        """With resample=0 the same seed is reused; two identical gradients
        produce EMA behaviour inside one fixed subspace."""
        mom = flora.FloraMomentum(SHAPES, rank=8, beta=0.5)
        st = mom.init_state()
        g = _grads(0)
        eff1, st = mom.step(st, g, jnp.uint32(5), jnp.uint32(6), 0.0)
        eff2, st = mom.step(st, g, jnp.uint32(5), jnp.uint32(6), 0.0)
        k = "layer0/attn/wq"
        # eff = (1 - beta^t) * decompress(compress(g)) for constant g
        np.testing.assert_allclose(
            np.asarray(eff2[k]), np.asarray(eff1[k]) * 1.5, rtol=1e-3, atol=1e-6
        )

    def test_flora_resample_transfer_scale_converges_with_rank(self):
        """The transfer M A_old A_newᵀ distorts the norm by a factor that
        shrinks toward 1 as r grows (Thm 2.4: AᵀA -> I at rate 1/√r).
        Measured: ≈1.41 at r=m, ≈1.12 at r=4m — assert the trend + bounds."""
        m = 256
        ratios = []
        for r in (256, 1024):
            big = {"w/attn/wq": (64, m)}
            mom = flora.FloraMomentum(big, rank=r, beta=0.9)
            st = mom.init_state()
            g = {"w/attn/wq": jax.random.normal(jax.random.PRNGKey(0), (64, m))}
            _, st = mom.step(st, g, jnp.uint32(0), jnp.uint32(1), 0.0)
            norm_before = float(jnp.linalg.norm(st["mom/w/attn/wq"]))
            zero = {"w/attn/wq": jnp.zeros((64, m))}
            # resample step with zero grad: new M = beta * transfer(M)
            _, st2 = mom.step(st, zero, jnp.uint32(0), jnp.uint32(1), 1.0)
            norm_after = float(jnp.linalg.norm(st2["mom/w/attn/wq"])) / 0.9
            ratios.append(norm_after / norm_before)
        assert ratios[1] < ratios[0], ratios
        assert 0.9 < ratios[1] < 1.25, ratios

    def test_resample_changes_state_vs_no_resample(self):
        mom = flora.FloraMomentum(SHAPES, rank=4, beta=0.9)
        st = mom.init_state()
        g = _grads(0)
        _, st = mom.step(st, g, jnp.uint32(0), jnp.uint32(1), 0.0)
        _, st_keep = mom.step(st, g, jnp.uint32(0), jnp.uint32(1), 0.0)
        _, st_res = mom.step(st, g, jnp.uint32(0), jnp.uint32(1), 1.0)
        k = "mom/layer0/attn/wq"
        assert not np.allclose(st_keep[k], st_res[k])


class TestSeeds:
    def test_derive_seed_distinct_per_param(self):
        seeds = {int(flora.derive_seed(jnp.uint32(42), i)) for i in range(100)}
        assert len(seeds) == 100

    def test_derive_seed_deterministic(self):
        a = int(flora.derive_seed(jnp.uint32(7), 3))
        b = int(flora.derive_seed(jnp.uint32(7), 3))
        assert a == b

    def test_factory_raises_on_unknown(self):
        with pytest.raises(ValueError):
            flora.make_accumulation("galore", SHAPES, 4)
        with pytest.raises(ValueError):
            flora.make_momentum("rp", SHAPES, 4, 0.9)
