"""The AOT ABI: flat step functions must be consistent with their declared
specs, train end-to-end (loss decreases through the micro/update cycle), and
the emitted manifest must describe every artifact on disk."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, optimizers, steps

CFG = model.get_lm("lm-tiny")
BATCH = 4


def _make_args(in_specs, seed=0):
    key = jax.random.PRNGKey(seed)
    args = []
    for name, shape, dtype in in_specs:
        key, sub = jax.random.split(key)
        if dtype == "int32":
            args.append(
                jax.random.randint(sub, tuple(shape), 0, CFG.vocab).astype(
                    jnp.int32
                )
            )
        elif dtype == "uint32":
            args.append(jnp.zeros(tuple(shape), jnp.uint32))
        else:
            args.append(jnp.zeros(tuple(shape), jnp.float32))
    return args


class TestSpecConsistency:
    @pytest.mark.parametrize(
        "builder,kwargs",
        [
            (steps.build_lm_init, {}),
            (steps.build_lm_eval, {"batch": BATCH}),
            (steps.build_lm_greedy, {"batch": BATCH}),
            (steps.build_lm_micro, {"method": "flora", "rank": 4, "batch": BATCH}),
            (steps.build_lm_micro, {"method": "naive", "rank": 0, "batch": BATCH}),
        ],
    )
    def test_eval_shape_matches_specs(self, builder, kwargs):
        fn, in_specs, out_names = builder(CFG, **kwargs)
        arg_structs = [
            jax.ShapeDtypeStruct(tuple(s), jnp.dtype(d))
            for (_, s, d) in in_specs
        ]
        outs = jax.eval_shape(fn, *arg_structs)
        assert len(outs) == len(out_names)

    def test_update_specs(self):
        opt = optimizers.make_optimizer("adafactor")
        fn, in_specs, out_names = steps.build_lm_update(CFG, "flora", 4, opt)
        arg_structs = [
            jax.ShapeDtypeStruct(tuple(s), jnp.dtype(d))
            for (_, s, d) in in_specs
        ]
        outs = jax.eval_shape(fn, *arg_structs)
        assert len(outs) == len(out_names)
        # params out match params in
        n_params = len(CFG.param_shapes())
        for i in range(n_params):
            assert tuple(outs[i].shape) == tuple(in_specs[i][1])


class TestEndToEndTraining:
    """Run the full Algorithm-1 cycle in-process (jit, no PJRT round trip)
    and check the loss actually decreases on a learnable toy task."""

    def _toy_batch(self, key):
        # learnable structure: token i+1 = (token i + 1) % 16
        start = jax.random.randint(key, (BATCH, 1), 0, 16)
        seq = (start + jnp.arange(CFG.seq_len)[None, :]) % 16
        mask = jnp.ones((BATCH, CFG.seq_len), jnp.float32)
        return seq.astype(jnp.int32), mask

    @pytest.mark.parametrize("method,rank", [("naive", 0), ("flora", 8)])
    def test_accumulation_cycle_learns(self, method, rank):
        opt = optimizers.make_optimizer("adafactor")
        init_fn, _, _ = steps.build_lm_init(CFG)
        micro_fn, micro_specs, _ = steps.build_lm_micro(CFG, method, rank, BATCH)
        upd_fn, upd_specs, _ = steps.build_lm_update(CFG, method, rank, opt)
        eval_fn, _, _ = steps.build_lm_eval(CFG, BATCH)
        micro_j, upd_j, eval_j = jax.jit(micro_fn), jax.jit(upd_fn), jax.jit(eval_fn)

        params = list(init_fn(jnp.uint32(0)))
        n_p = len(params)
        acc_shapes = [s for (n, s, _) in micro_specs if n.startswith("acc/")]
        opt_shapes = [s for (n, s, _) in upd_specs if n.startswith("opt/")]
        acc = [jnp.zeros(s, jnp.float32) for s in acc_shapes]
        opt_state = [jnp.zeros(s, jnp.float32) for s in opt_shapes]

        key = jax.random.PRNGKey(0)
        tau = 4
        key, sub = jax.random.split(key)
        toks0, mask0 = self._toy_batch(sub)
        loss0 = float(eval_j(*params, toks0, mask0)[0])

        step = 0
        for cycle in range(6):
            seed = jnp.uint32(1000 + cycle)
            for _ in range(tau):
                key, sub = jax.random.split(key)
                toks, mask = self._toy_batch(sub)
                out = micro_j(*params, *acc, toks, mask, seed)
                acc = list(out[1:])
            out = upd_j(
                *params, *opt_state, *acc,
                seed, jnp.float32(tau), jnp.float32(0.05), jnp.float32(step),
            )
            params = list(out[:n_p])
            opt_state = list(out[n_p:])
            acc = [jnp.zeros_like(a) for a in acc]  # coordinator zeroes acc
            step += 1

        loss1 = float(eval_j(*params, toks0, mask0)[0])
        assert loss1 < loss0 - 0.1, (loss0, loss1)

    def test_momentum_step_learns(self):
        opt = optimizers.make_optimizer("adafactor")
        init_fn, _, _ = steps.build_lm_init(CFG)
        mom_fn, mom_specs, _ = steps.build_lm_momentum_step(
            CFG, "flora", 8, 0.9, opt, BATCH
        )
        eval_fn, _, _ = steps.build_lm_eval(CFG, BATCH)
        mom_j, eval_j = jax.jit(mom_fn), jax.jit(eval_fn)

        params = list(init_fn(jnp.uint32(0)))
        n_p = len(params)
        opt_shapes = [s for (n, s, _) in mom_specs if n.startswith("opt/")]
        mom_shapes = [s for (n, s, _) in mom_specs if n.startswith("mom/")]
        opt_state = [jnp.zeros(s, jnp.float32) for s in opt_shapes]
        mom_state = [jnp.zeros(s, jnp.float32) for s in mom_shapes]

        key = jax.random.PRNGKey(1)
        key, sub = jax.random.split(key)
        toks0, mask0 = self._toy_batch(sub)
        loss0 = float(eval_j(*params, toks0, mask0)[0])

        kappa, seed_cur, seed_next = 10, 0, 1
        for t in range(30):
            key, sub = jax.random.split(key)
            toks, mask = self._toy_batch(sub)
            resample = 1.0 if (t > 0 and t % kappa == 0) else 0.0
            out = mom_j(
                *params, *opt_state, *mom_state, toks, mask,
                jnp.uint32(seed_cur), jnp.uint32(seed_next),
                jnp.float32(resample), jnp.float32(0.05), jnp.float32(t),
            )
            params = list(out[1 : 1 + n_p])
            opt_state = list(out[1 + n_p : 1 + n_p + len(opt_state)])
            mom_state = list(out[1 + n_p + len(opt_state) :])
            if resample == 1.0:
                seed_cur, seed_next = seed_next, seed_next + 1
        loss1 = float(eval_j(*params, toks0, mask0)[0])
        assert loss1 < loss0 - 0.1, (loss0, loss1)


class TestManifest:
    MANIFEST = os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json"
    )

    @pytest.fixture(scope="class")
    def manifest(self):
        if not os.path.exists(self.MANIFEST):
            pytest.skip("artifacts not built (run `make artifacts`)")
        with open(self.MANIFEST) as f:
            return json.load(f)

    def test_every_executable_file_exists(self, manifest):
        d = os.path.dirname(self.MANIFEST)
        for name, e in manifest["executables"].items():
            assert os.path.exists(os.path.join(d, e["file"])), name

    def test_models_registered(self, manifest):
        for m in ("lm-tiny", "lm-small", "lm-base", "vit-cifar"):
            assert m in manifest["models"]

    def test_params_consistent_between_init_and_step(self, manifest):
        ex = manifest["executables"]
        init_outs = [o["name"] for o in ex["lm-tiny/init"]["outputs"]]
        micro_ins = [
            i["name"]
            for i in ex["lm-tiny/micro_flora_r4"]["inputs"]
            if i["name"].startswith("params/")
        ]
        assert init_outs == micro_ins

    def test_flora_acc_is_compressed_in_manifest(self, manifest):
        ex = manifest["executables"]["lm-small/micro_flora_r8"]
        accs = {
            i["name"]: i["shape"]
            for i in ex["inputs"]
            if i["name"].startswith("acc/")
        }
        assert accs["acc/layer0/attn/wq"] == [64, 8]
        assert accs["acc/embed/tok"] == [256, 64]  # naive for embeddings
