"""Pure-python checks that run even without jax installed (the CI python
job installs only pytest+numpy): the executable name scheme that binds the
rust coordinator to the AOT catalog, and the conftest skip lists."""

import importlib.util
import os
import re

# mirrors rust/src/coordinator/method.rs::MethodSpec and the native catalog
# in rust/src/runtime/native.rs
EXE_NAME = re.compile(
    r"^[a-z0-9-]+/("
    r"init|eval|greedy"
    r"|plain_step_[a-z0-9_]+"
    r"|micro_(naive|flora_r\d+)"
    r"|update_(naive|flora_r\d+)_[a-z0-9_]+"
    r"|mom_step_(naive|flora_(notransfer_)?r\d+)_[a-z0-9_]+"
    r"|galore_step_r\d+"
    r"|lora_r\d+_(init|micro|eval|greedy|update_[a-z0-9_]+|mom_step_[a-z0-9_]+)"
    r"|step_flora_r\d+_[a-z0-9_]+|step_[a-z0-9_]+"
    r")$"
)


def test_name_scheme_accepts_catalog_names():
    for name in [
        "lm-tiny/init",
        "lm-tiny/eval",
        "lm-tiny/greedy",
        "lm-small/plain_step_adafactor",
        "lm-small/plain_step_sgd",
        "lm-small/micro_naive",
        "lm-small/micro_flora_r8",
        "lm-small/update_flora_r8_adafactor",
        "lm-small/update_naive_sgd",
        "lm-small/mom_step_flora_r16_sgd",
        "lm-small/mom_step_flora_notransfer_r16_adafactor",
        "lm-base/galore_step_r16",
        "lm-small/lora_r32_micro",
        "vit-cifar/step_adam",
        "vit-cifar/step_flora_r16_adafactor",
    ]:
        assert EXE_NAME.match(name), name


def test_name_scheme_rejects_garbage():
    for name in [
        "lm-tiny/bogus",
        "lm tiny/init",
        "lm-tiny/micro_flora_rx",
        "LM-TINY/init",
        "lm-tiny/",
    ]:
        assert not EXE_NAME.match(name), name


def test_conftest_skip_lists_point_at_real_files():
    import conftest

    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for rel in conftest._JAX_TESTS:
        assert os.path.exists(os.path.join(here, rel)), rel


def test_this_module_never_skipped():
    # this file must stay importable without jax/hypothesis so the CI
    # python job always collects at least one test
    assert importlib.util.find_spec("re") is not None
