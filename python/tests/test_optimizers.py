"""From-scratch optimizer correctness: closed-form single steps, state
shapes, descent behaviour, and the factored/unfactored Adafactor relation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import optimizers


def _params():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, (8, 12)),
        "b": jax.random.normal(k2, (12,)),
    }


def _grads():
    key = jax.random.PRNGKey(1)
    k1, k2 = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, (8, 12)),
        "b": jax.random.normal(k2, (12,)),
    }


class TestSgd:
    def test_exact_update(self):
        opt = optimizers.Sgd()
        p, g = _params(), _grads()
        s = opt.init(p)
        p2, _ = opt.update(p, g, s, 0.1, 0)
        for k in p:
            np.testing.assert_allclose(p2[k], p[k] - 0.1 * g[k], rtol=1e-6)


class TestAdam:
    def test_first_step_closed_form(self):
        """After one step from zero state, Adam moves by ~lr*sign(g)."""
        opt = optimizers.Adam()
        p, g = _params(), _grads()
        s = opt.init(p)
        p2, s2 = opt.update(p, g, s, 1e-3, 0)
        for k in p:
            # mhat = g, vhat = g^2  =>  update = lr * g/(|g|+eps) ≈ lr*sign(g)
            want = p[k] - 1e-3 * g[k] / (jnp.abs(g[k]) + 1e-8)
            np.testing.assert_allclose(p2[k], want, rtol=1e-4, atol=1e-7)

    def test_state_slots(self):
        opt = optimizers.Adam()
        p = _params()
        s = opt.init(p)
        assert set(s) == {"w/m", "w/v", "b/m", "b/v"}
        assert s["w/m"].shape == (8, 12)

    def test_moments_track_gradient(self):
        opt = optimizers.Adam(b1=0.9, b2=0.999)
        p, g = _params(), _grads()
        s = opt.init(p)
        _, s2 = opt.update(p, g, s, 1e-3, 0)
        np.testing.assert_allclose(s2["w/m"], 0.1 * g["w"], rtol=1e-5)
        np.testing.assert_allclose(s2["w/v"], 0.001 * g["w"] ** 2, rtol=1e-4)


class TestAdafactor:
    def test_factored_state_is_sublinear(self):
        opt = optimizers.Adafactor(factored=True)
        p = _params()
        s = opt.init(p)
        assert s["w/vr"].shape == (8,)
        assert s["w/vc"].shape == (12,)
        assert s["b/v"].shape == (12,)  # vectors keep full second moment

    def test_unfactored_state_is_linear(self):
        opt = optimizers.Adafactor(factored=False)
        p = _params()
        s = opt.init(p)
        assert s["w/v"].shape == (8, 12)

    def test_descends_quadratic(self):
        """Adafactor minimizes ||W - W*||^2 steadily."""
        opt = optimizers.Adafactor(factored=True)
        target = jax.random.normal(jax.random.PRNGKey(3), (8, 12))
        # start away from zero: Adafactor's parameter-scale-relative step
        # (max(eps2, RMS(w))) is intentionally tiny at w == 0.
        p = {"w": 0.5 * jnp.ones((8, 12))}
        s = opt.init(p)
        losses = []
        for t in range(200):
            g = {"w": 2 * (p["w"] - target)}
            losses.append(float(jnp.sum((p["w"] - target) ** 2)))
            p, s = opt.update(p, g, s, 0.1, t)
        assert losses[-1] < 0.2 * losses[0]

    def test_factored_approximates_unfactored_rank1(self):
        """For a rank-1 |g| the factored second moment is exact, so both
        variants produce the same first update."""
        u = jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (8, 1))) + 0.5
        v = jnp.abs(jax.random.normal(jax.random.PRNGKey(5), (1, 12))) + 0.5
        g = {"w": u * v}
        p = {"w": jnp.ones((8, 12))}
        f = optimizers.Adafactor(factored=True)
        n = optimizers.Adafactor(factored=False)
        pf, _ = f.update(p, g, f.init(p), 0.01, 0)
        pn, _ = n.update(p, g, n.init(p), 0.01, 0)
        np.testing.assert_allclose(pf["w"], pn["w"], rtol=1e-3)

    def test_update_clipping_bounds_step(self):
        """RMS of the (pre-scale) update never exceeds clip threshold."""
        opt = optimizers.Adafactor(factored=True, clip_threshold=1.0)
        g = {"w": 1000.0 * jnp.ones((8, 12))}
        p = {"w": jnp.ones((8, 12))}
        p2, _ = opt.update(p, g, opt.init(p), 1.0, 0)
        step = jnp.abs(p2["w"] - p["w"])
        # lr * scale * clipped_u, scale = rms(p)=1 => |step| <= lr * ~1
        assert float(step.max()) <= 1.5

    def test_beta2_schedule(self):
        opt = optimizers.Adafactor()
        assert float(opt._beta2(0)) == 0.0
        assert 0.8 < float(opt._beta2(100)) < 1.0


class TestRegistry:
    @pytest.mark.parametrize(
        "name", ["sgd", "adam", "adafactor", "adafactor_nofactor"]
    )
    def test_make(self, name):
        opt = optimizers.make_optimizer(name)
        assert opt.name == name

    def test_unknown_raises(self):
        with pytest.raises(ValueError):
            optimizers.make_optimizer("adamw")
