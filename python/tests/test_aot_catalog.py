"""The AOT catalog as a whole: every entry must abstract-eval against its
declared specs (this is what guarantees `make artifacts` cannot emit a
manifest that the rust runtime rejects), and lowering must preserve arity
(the keep_unused contract — regression test for the 78-vs-75-buffers bug)."""

import re

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model, optimizers, steps


@pytest.fixture(scope="module")
def catalog():
    return aot.build_catalog("/tmp/unused-aot-out")


def test_catalog_is_large_and_named_consistently(catalog):
    names = set(catalog.entries)
    assert len(names) > 80
    # every executable's model prefix is a registered model
    for n in names:
        model_name = n.split("/")[0]
        assert model_name in catalog.models, n


def test_every_entry_abstract_evals(catalog):
    for name, (fn, in_specs, out_names, _) in sorted(catalog.entries.items()):
        args = [
            jax.ShapeDtypeStruct(tuple(s), jnp.dtype(d))
            for (_, s, d) in in_specs
        ]
        outs = jax.eval_shape(fn, *args)
        assert len(outs) == len(out_names), name


def test_input_names_unique_per_entry(catalog):
    for name, (_, in_specs, out_names, _) in catalog.entries.items():
        in_names = [n for (n, _, _) in in_specs]
        assert len(in_names) == len(set(in_names)), name
        assert len(out_names) == len(set(out_names)), name


def test_lowering_preserves_arity_keep_unused():
    """The naive momentum step ignores its seed trio; the lowered HLO must
    STILL declare them as parameters (rust supplies every manifest input)."""
    cfg = model.get_lm("lm-tiny")
    opt = optimizers.make_optimizer("adafactor")
    fn, in_specs, _ = steps.build_lm_momentum_step(cfg, "naive", 0, 0.9, opt, 4)
    args = [
        jax.ShapeDtypeStruct(tuple(s), jnp.dtype(d)) for (_, s, d) in in_specs
    ]
    text = aot.to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args))
    # ENTRY computation signature: count parameter(...) declarations
    entry = text.split("ENTRY")[1]
    n_params = len(re.findall(r"parameter\(\d+\)", entry))
    assert n_params == len(in_specs), (n_params, len(in_specs))


def test_flora_momentum_declares_seed_trio(catalog):
    _, in_specs, _, _ = catalog.entries["lm-tiny/mom_step_flora_r4_adafactor"]
    names = [n for (n, _, _) in in_specs]
    for s in ("seed_cur", "seed_next", "resample", "lr", "step"):
        assert s in names


def test_galore_entry_has_projection_state(catalog):
    _, in_specs, _, _ = catalog.entries["lm-tiny/galore_step_r4"]
    names = [n for (n, _, _) in in_specs]
    assert any(n.startswith("proj/") for n in names)
    assert any(n.startswith("m/") for n in names)
    assert "refresh" in names
