"""L1 correctness: Pallas rp kernels vs the pure-jnp oracle (ref.py).

Includes a hypothesis sweep over shapes (including non-power-of-two and
single-block-collapse cases) and VJP checks against jax.grad of the oracle.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref, rp

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


TOL = dict(rtol=1e-5, atol=1e-5)


class TestMatmulKernels:
    @pytest.mark.parametrize(
        "n,m,r",
        [(8, 16, 4), (64, 64, 8), (256, 512, 32), (100, 96, 8), (1, 7, 3)],
    )
    def test_matmul_nt_matches_ref(self, n, m, r):
        x, y = _rand(0, n, m), _rand(1, r, m)
        np.testing.assert_allclose(
            rp.matmul_nt(x, y), ref.matmul_nt(x, y), **TOL
        )

    @pytest.mark.parametrize(
        "n,m,r",
        [(8, 16, 4), (64, 64, 8), (256, 512, 32), (100, 96, 8), (1, 7, 3)],
    )
    def test_matmul_nn_matches_ref(self, n, m, r):
        x, y = _rand(2, n, r), _rand(3, r, m)
        np.testing.assert_allclose(
            rp.matmul_nn(x, y), ref.matmul_nn(x, y), **TOL
        )

    @hypothesis.given(
        n=st.integers(1, 96), m=st.integers(1, 96), r=st.integers(1, 16),
        seed=st.integers(0, 2**16),
    )
    def test_matmul_nt_hypothesis(self, n, m, r, seed):
        x, y = _rand(seed, n, m), _rand(seed + 1, r, m)
        np.testing.assert_allclose(
            rp.matmul_nt(x, y), ref.matmul_nt(x, y), **TOL
        )

    @hypothesis.given(
        n=st.integers(1, 96), m=st.integers(1, 96), r=st.integers(1, 16),
        seed=st.integers(0, 2**16),
    )
    def test_compress_accumulate_hypothesis(self, n, m, r, seed):
        c = _rand(seed, n, r)
        g = _rand(seed + 1, n, m)
        a = _rand(seed + 2, r, m)
        np.testing.assert_allclose(
            rp.compress_accumulate(c, g, a),
            ref.compress_accumulate(c, g, a),
            **TOL,
        )

    def test_blocked_path_exercised(self):
        """Shapes larger than one block so the grid actually iterates.
        Looser tolerance: the m-axis sweep reassociates the reduction."""
        n, m, r = 512, 1024, 16  # grid = (2, 2) with default blocks
        g, a = _rand(7, n, m), _rand(8, r, m)
        blk = dict(rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(rp.compress(g, a), ref.compress(g, a), **blk)
        c = _rand(9, n, r)
        np.testing.assert_allclose(
            rp.decompress(c, a), ref.decompress(c, a), **blk
        )


class TestVjps:
    def test_matmul_nt_vjp(self):
        x, y = _rand(0, 16, 24), _rand(1, 4, 24)

        def f_k(x, y):
            return jnp.sum(jnp.sin(rp.matmul_nt(x, y)))

        def f_r(x, y):
            return jnp.sum(jnp.sin(ref.matmul_nt(x, y)))

        gk = jax.grad(f_k, argnums=(0, 1))(x, y)
        gr = jax.grad(f_r, argnums=(0, 1))(x, y)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, **TOL)

    def test_matmul_nn_vjp(self):
        x, y = _rand(2, 16, 4), _rand(3, 4, 24)

        def f_k(x, y):
            return jnp.sum(jnp.tanh(rp.matmul_nn(x, y)))

        def f_r(x, y):
            return jnp.sum(jnp.tanh(ref.matmul_nn(x, y)))

        gk = jax.grad(f_k, argnums=(0, 1))(x, y)
        gr = jax.grad(f_r, argnums=(0, 1))(x, y)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, **TOL)

    def test_compress_accumulate_vjp(self):
        c, g, a = _rand(4, 8, 4), _rand(5, 8, 12), _rand(6, 4, 12)

        def f_k(c, g, a):
            return jnp.sum(rp.compress_accumulate(c, g, a) ** 2)

        def f_r(c, g, a):
            return jnp.sum(ref.compress_accumulate(c, g, a) ** 2)

        gk = jax.grad(f_k, argnums=(0, 1, 2))(c, g, a)
        gr = jax.grad(f_r, argnums=(0, 1, 2))(c, g, a)
        for x, y in zip(gk, gr):
            np.testing.assert_allclose(x, y, **TOL)


class TestFloraOps:
    def test_transfer_matches_ref(self):
        m_c, a_old, a_new = _rand(0, 32, 8), _rand(1, 8, 48), _rand(2, 8, 48)
        np.testing.assert_allclose(
            rp.transfer(m_c, a_old, a_new),
            ref.transfer(m_c, a_old, a_new),
            **TOL,
        )

    def test_project_normal_deterministic(self):
        a1 = rp.project_normal(jnp.uint32(42), 8, 64)
        a2 = rp.project_normal(jnp.uint32(42), 8, 64)
        np.testing.assert_array_equal(a1, a2)
        a3 = rp.project_normal(jnp.uint32(43), 8, 64)
        assert not np.allclose(a1, a3)

    def test_project_normal_scale(self):
        """A ~ N(0, 1/r): E[A^T A] = I (Theorem 2.4 normalization)."""
        r, m = 512, 16
        a = rp.project_normal(jnp.uint32(0), r, m)
        ata = np.asarray(a.T @ a)
        np.testing.assert_allclose(ata, np.eye(m), atol=0.2)

    def test_jl_norm_preservation(self):
        """Lemma 2.3: projection approximately preserves row norms."""
        n, m, r = 64, 256, 128
        g = np.asarray(_rand(0, n, m))
        a = np.asarray(rp.project_normal(jnp.uint32(1), r, m))
        c = g @ a.T
        ratio = np.linalg.norm(c, axis=1) / np.linalg.norm(g, axis=1)
        assert np.all(ratio > 0.7) and np.all(ratio < 1.3)

    def test_compress_decompress_unbiased(self):
        """E_A[G A^T A] = G — averaged over many seeds the reconstruction
        converges to the original gradient (§2.3 Decompression)."""
        n, m, r = 8, 16, 64
        g = np.asarray(_rand(0, n, m))
        acc = np.zeros_like(g)
        trials = 200
        for s in range(trials):
            a = np.asarray(rp.project_normal(jnp.uint32(s), r, m))
            acc += np.asarray(ref.decompress(ref.compress(g, a), a))
        err = np.abs(acc / trials - g).max()
        assert err < 0.15, err
