"""L2 model correctness: shapes, loss behaviour, init determinism, greedy
decode semantics, param-count agreement with the config (the rust memory
accountant relies on it)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers, model, vit
from compile.layers import LMConfig


@pytest.fixture(scope="module")
def cfg():
    return model.get_lm("lm-tiny")


@pytest.fixture(scope="module")
def params(cfg):
    return layers.init_lm(cfg, jnp.uint32(0))


class TestLMForward:
    def test_logits_shape(self, cfg, params):
        toks = jnp.zeros((2, cfg.seq_len), jnp.int32)
        logits = layers.lm_forward(params, toks, cfg)
        assert logits.shape == (2, cfg.seq_len, cfg.vocab)

    def test_loss_finite_and_near_uniform_at_init(self, cfg, params):
        key = jax.random.PRNGKey(0)
        toks = jax.random.randint(key, (4, cfg.seq_len), 0, cfg.vocab)
        mask = jnp.ones((4, cfg.seq_len), jnp.float32)
        loss = layers.lm_loss(params, toks, mask, cfg)
        assert jnp.isfinite(loss)
        # at init the model is near-uniform: loss ≈ log(vocab)
        assert abs(float(loss) - np.log(cfg.vocab)) < 1.0

    def test_mask_zeroes_loss_contribution(self, cfg, params):
        key = jax.random.PRNGKey(1)
        toks = jax.random.randint(key, (2, cfg.seq_len), 0, cfg.vocab)
        mask0 = jnp.zeros((2, cfg.seq_len), jnp.float32)
        mask0 = mask0.at[:, : cfg.seq_len // 2].set(1.0)
        l_half = layers.lm_loss(params, toks, mask0, cfg)
        # fully-masked rows must not contribute: compare against manual calc
        logits = layers.lm_forward(params, toks, cfg)[:, :-1]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, toks[:, 1:, None], axis=-1)[..., 0]
        m = mask0[:, 1:]
        want = jnp.sum(nll * m) / jnp.sum(m)
        np.testing.assert_allclose(float(l_half), float(want), rtol=1e-5)

    def test_causality(self, cfg, params):
        """Changing a future token must not change past logits."""
        key = jax.random.PRNGKey(2)
        toks = jax.random.randint(key, (1, cfg.seq_len), 0, cfg.vocab)
        toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % cfg.vocab)
        l1 = layers.lm_forward(params, toks, cfg)
        l2 = layers.lm_forward(params, toks2, cfg)
        np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], atol=1e-5)

    def test_init_deterministic(self, cfg):
        p1 = layers.init_lm(cfg, jnp.uint32(7))
        p2 = layers.init_lm(cfg, jnp.uint32(7))
        for k in p1:
            np.testing.assert_array_equal(p1[k], p2[k])
        p3 = layers.init_lm(cfg, jnp.uint32(8))
        assert any(not np.allclose(p1[k], p3[k]) for k in p1)

    def test_param_count_matches_config(self, cfg, params):
        actual = sum(int(np.prod(v.shape)) for v in params.values())
        assert actual == cfg.param_count()


class TestGreedyDecode:
    def test_prompt_preserved(self, cfg, params):
        key = jax.random.PRNGKey(3)
        toks = jax.random.randint(key, (2, cfg.seq_len), 1, cfg.vocab)
        out = layers.lm_greedy_decode(params, toks, jnp.int32(8), cfg)
        np.testing.assert_array_equal(out[:, :8], toks[:, :8])

    def test_deterministic(self, cfg, params):
        key = jax.random.PRNGKey(4)
        toks = jax.random.randint(key, (2, cfg.seq_len), 1, cfg.vocab)
        o1 = layers.lm_greedy_decode(params, toks, jnp.int32(4), cfg)
        o2 = layers.lm_greedy_decode(params, toks, jnp.int32(4), cfg)
        np.testing.assert_array_equal(o1, o2)

    def test_matches_stepwise_argmax(self, cfg, params):
        """The fori_loop decode equals a python-loop reference decode."""
        key = jax.random.PRNGKey(5)
        toks = jax.random.randint(key, (1, cfg.seq_len), 1, cfg.vocab)
        plen = 4
        want = np.asarray(toks).copy()
        for i in range(1, cfg.seq_len):
            if i < plen:
                continue
            logits = layers.lm_forward(params, jnp.asarray(want), cfg)
            want[0, i] = int(jnp.argmax(logits[0, i - 1]))
        got = layers.lm_greedy_decode(params, toks, jnp.int32(plen), cfg)
        np.testing.assert_array_equal(np.asarray(got), want)


class TestViT:
    def test_shapes_and_loss(self):
        cfg = model.get_vit("vit-tiny")
        params = vit.init_vit(cfg, jnp.uint32(0))
        key = jax.random.PRNGKey(0)
        imgs = jax.random.normal(
            key, (3, cfg.image_size, cfg.image_size, cfg.channels)
        )
        labels = jnp.array([0, 1, 2], jnp.int32)
        logits = vit.vit_forward(params, imgs, cfg)
        assert logits.shape == (3, cfg.n_classes)
        loss = vit.vit_loss(params, imgs, labels, cfg)
        assert jnp.isfinite(loss)
        assert abs(float(loss) - np.log(cfg.n_classes)) < 1.0

    def test_patchify_roundtrip_content(self):
        cfg = model.get_vit("vit-tiny")
        imgs = jnp.arange(
            1 * cfg.image_size * cfg.image_size * cfg.channels, dtype=jnp.float32
        ).reshape(1, cfg.image_size, cfg.image_size, cfg.channels)
        patches = vit._patchify(imgs, cfg)
        assert patches.shape == (1, cfg.n_patches, cfg.patch_dim)
        # first patch = top-left patch_size x patch_size block
        p = cfg.patch_size
        want = np.asarray(imgs[0, :p, :p, :]).reshape(-1)
        np.testing.assert_array_equal(np.asarray(patches[0, 0]), want)

    def test_param_count_matches_config(self):
        cfg = model.get_vit("vit-tiny")
        params = vit.init_vit(cfg, jnp.uint32(0))
        actual = sum(int(np.prod(v.shape)) for v in params.values())
        assert actual == cfg.param_count()


class TestProjectablePredicate:
    def test_lm_projectable_set(self):
        cfg = model.get_lm("lm-tiny")
        shapes = cfg.param_shapes()
        proj = [
            k for k, s in shapes.items() if layers.is_projectable(k, len(s))
        ]
        # 6 matrices per layer (4 attn + 2 ffn), nothing else
        assert len(proj) == 6 * cfg.n_layers
        assert all(("attn/" in k or "ffn/" in k) for k in proj)
        assert "embed/tok" not in proj and "final_ln/scale" not in proj
