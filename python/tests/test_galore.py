"""GaLore baseline: the SVD→subspace-iteration substitution must actually
approximate the top-r left singular subspace (checked against numpy SVD),
and the optimizer must descend."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import galore


def _principal_angle_err(q, u):
    """max principal angle (as 1 - min singular value of Q^T U)."""
    s = np.linalg.svd(np.asarray(q).T @ np.asarray(u), compute_uv=False)
    return 1.0 - float(s.min())


class TestSubspaceIteration:
    def test_orthonormalize(self):
        y = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
        q = galore._orthonormalize(y)
        np.testing.assert_allclose(
            np.asarray(q.T @ q), np.eye(8), atol=1e-3
        )

    def test_matches_numpy_svd_subspace(self):
        """On a matrix with a decaying spectrum the iteration recovers the
        top-r left singular subspace."""
        rng = np.random.default_rng(0)
        n, m, r = 48, 96, 6
        u, _ = np.linalg.qr(rng.standard_normal((n, n)))
        v, _ = np.linalg.qr(rng.standard_normal((m, m)))
        s = np.zeros((n, m))
        np.fill_diagonal(s, 10.0 * 0.5 ** np.arange(min(n, m)))
        g = jnp.asarray((u @ s @ v.T).astype(np.float32))
        q = galore.topk_left_singular(g, r, jnp.uint32(0))
        u_true = np.linalg.svd(np.asarray(g))[0][:, :r]
        assert _principal_angle_err(q, u_true) < 0.05

    def test_projection_reduces_reconstruction_error_vs_random(self):
        """Top-r projection captures more gradient energy than a random one
        of the same rank (this is GaLore's whole premise)."""
        rng = np.random.default_rng(1)
        n, m, r = 32, 64, 4
        low = rng.standard_normal((n, r)) @ rng.standard_normal((r, m))
        g = jnp.asarray((low + 0.05 * rng.standard_normal((n, m))).astype(np.float32))
        p = galore.topk_left_singular(g, r, jnp.uint32(0))
        recon = p @ (p.T @ g)
        err_svd = float(jnp.linalg.norm(recon - g))
        prand = galore._orthonormalize(
            jax.random.normal(jax.random.PRNGKey(2), (n, r))
        )
        err_rand = float(jnp.linalg.norm(prand @ (prand.T @ g) - g))
        assert err_svd < 0.5 * err_rand


class TestGaLoreStep:
    SHAPES = {"l/attn/wq": (16, 24), "l/ln1/scale": (16,)}

    def test_state_shapes(self):
        gl = galore.GaLore(self.SHAPES, rank=4)
        s = gl.state_shapes()
        assert s["proj/l/attn/wq"] == (16, 4)
        assert s["m/l/attn/wq"] == (4, 24)
        assert s["m/l/ln1/scale"] == (16,)

    def test_descends_quadratic(self):
        gl = galore.GaLore(self.SHAPES, rank=8, galore_scale=1.0)
        target = {
            "l/attn/wq": jax.random.normal(jax.random.PRNGKey(0), (16, 24)),
            "l/ln1/scale": jax.random.normal(jax.random.PRNGKey(1), (16,)),
        }
        params = {k: jnp.zeros(s) for k, s in self.SHAPES.items()}
        state = gl.init_state()
        first = None
        for t in range(80):
            grads = {k: 2 * (params[k] - target[k]) for k in params}
            refresh = 1.0 if t % 20 == 0 else 0.0
            params, state = gl.step(
                params, grads, state, 0.02, t, jnp.uint32(t), refresh
            )
            loss = sum(
                float(jnp.sum((params[k] - target[k]) ** 2)) for k in params
            )
            if first is None:
                first = loss
        assert loss < 0.3 * first

    def test_refresh_zero_keeps_projection(self):
        gl = galore.GaLore(self.SHAPES, rank=4)
        params = {k: jnp.ones(s) for k, s in self.SHAPES.items()}
        grads = {
            k: jax.random.normal(jax.random.PRNGKey(3), s)
            for k, s in self.SHAPES.items()
        }
        state = gl.init_state()
        _, s1 = gl.step(params, grads, state, 0.01, 0, jnp.uint32(0), 1.0)
        _, s2 = gl.step(params, grads, s1, 0.01, 1, jnp.uint32(9), 0.0)
        np.testing.assert_array_equal(
            np.asarray(s1["proj/l/attn/wq"]), np.asarray(s2["proj/l/attn/wq"])
        )
