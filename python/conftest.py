import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

# Auto-skip test modules whose optional heavy deps are missing: CI runs the
# python mirror without jax (and possibly without hypothesis), and the
# jax-dependent parity suites must not rot the collection step there. The
# pure-python tests (test_env.py) always run, so pytest never exits with
# "no tests collected".
collect_ignore = []

_JAX_TESTS = [
    "tests/test_aot_catalog.py",
    "tests/test_flora.py",
    "tests/test_galore.py",
    "tests/test_kernels.py",
    "tests/test_models.py",
    "tests/test_optimizers.py",
    "tests/test_steps_abi.py",
]

if importlib.util.find_spec("jax") is None:
    collect_ignore += _JAX_TESTS
elif importlib.util.find_spec("hypothesis") is None:
    # test_kernels additionally needs hypothesis for its shape sweep
    collect_ignore += ["tests/test_kernels.py"]
