"""Pure-jnp oracle for the Pallas rp kernels.

Every function here is the mathematically obvious implementation of the
corresponding kernel in ``rp.py``. ``python/tests/test_kernels.py`` asserts
allclose between the two across a hypothesis-driven shape/dtype sweep, and
the VJPs are checked against ``jax.grad`` of these references.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_nt(x: jax.Array, y: jax.Array) -> jax.Array:
    return x @ y.T


def matmul_nn(x: jax.Array, y: jax.Array) -> jax.Array:
    return x @ y


def compress(g: jax.Array, a: jax.Array) -> jax.Array:
    return g @ a.T


def compress_accumulate(c: jax.Array, g: jax.Array, a: jax.Array) -> jax.Array:
    return c + g @ a.T


def decompress(c: jax.Array, a: jax.Array) -> jax.Array:
    return c @ a


def transfer(m_c: jax.Array, a_old: jax.Array, a_new: jax.Array) -> jax.Array:
    return m_c @ a_old @ a_new.T


def project_normal(seed, r: int, m: int, dtype=jnp.float32) -> jax.Array:
    key = jax.random.PRNGKey(jnp.asarray(seed, jnp.uint32))
    return jax.random.normal(key, (r, m), dtype=dtype) / jnp.sqrt(
        jnp.asarray(r, dtype)
    )
