"""L1 — Pallas kernels for FLORA's random-projection hot path.

FLORA's compute hot-spot is three GEMM-shaped operations applied to every
2-D weight gradient on every micro-step (paper §2.4, Algorithms 1–2):

  compress   : C += G @ A^T          (G: [n, m], A: [r, m]  -> C: [n, r])
  decompress : Ghat = (1/r) C @ A    (C: [n, r], A: [r, m]  -> Ghat: [n, m])
  transfer   : M' = (1/r) M @ A_old @ A_new^T   (subspace hand-off, Alg. 2 l.13)

These are written as Pallas kernels tiled for TPU VMEM (BlockSpec expresses
the HBM<->VMEM schedule; the reduction axis is the innermost sequential grid
dimension so the output block stays resident while input slabs stream).
On this image they MUST run with ``interpret=True`` — real TPU lowering emits
a Mosaic custom-call the CPU PJRT plugin cannot execute (see DESIGN.md
§Hardware-Adaptation for the TPU mapping / MXU+VMEM estimates).

Every kernel is wrapped in ``jax.custom_vjp`` so it can sit under
``jax.grad`` inside the L2 training step (pallas_call itself has no
reverse-mode rule). The VJPs of these linear maps are again rp ops, so the
backward pass reuses the same kernels.

Correctness oracle: ``kernels/ref.py`` (pure jnp), enforced by
``python/tests/test_kernels.py`` including hypothesis shape/dtype sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "compress",
    "compress_accumulate",
    "decompress",
    "transfer",
    "project_normal",
    "matmul_nt",
    "matmul_nn",
]

# Interpret mode is mandatory on CPU PJRT (see module docstring). Kept as a
# module switch so a real-TPU build can flip it off in one place.
INTERPRET = True

# Default VMEM tile sizes. On TPU these would be multiples of the (8, 128)
# register tile / 128x128 MXU; under interpret mode they only shape the grid.
# Small problems (n, m below one block) collapse to a single grid step, which
# lowers to a single fused dot — no interpret-mode loop overhead.
BLOCK_N = 256
BLOCK_M = 512
BLOCK_R = 512  # r is never tiled: n*r output block stays VMEM-resident


def _grid_dim(size: int, block: int) -> tuple[int, int]:
    """Return (num_blocks, block) clamping block to size (single-step grid
    when the problem fits in one tile)."""
    if size <= block:
        return 1, size
    # pallas requires even division under our BlockSpecs; fall back to a
    # single block when the tile does not divide the axis. All shapes used
    # by the AOT path are powers of two, so this is the rare path.
    if size % block != 0:
        return 1, size
    return size // block, block


# ---------------------------------------------------------------------------
# matmul_nt: out[n, r] = x[n, m] @ y[r, m]^T  (the "compress" GEMM shape)
# ---------------------------------------------------------------------------


def _mm_nt_kernel(x_ref, y_ref, o_ref):
    """One grid step: o[bn, r] += x[bn, bm] @ y[r, bm]^T.

    Grid = (n / bn, m / bm); the m axis (index 1) is the reduction and runs
    innermost/sequential, so o_ref stays resident in VMEM across the sweep —
    this is the threadblock-accumulator idiom mapped to BlockSpec.
    """
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _matmul_nt_impl(x: jax.Array, y: jax.Array) -> jax.Array:
    n, m = x.shape
    r, m2 = y.shape
    assert m == m2, f"contraction mismatch: {x.shape} vs {y.shape}"
    gn, bn = _grid_dim(n, BLOCK_N)
    gm, bm = _grid_dim(m, BLOCK_M)
    return pl.pallas_call(
        _mm_nt_kernel,
        grid=(gn, gm),
        in_specs=[
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((r, bm), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, r), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r), x.dtype),
        interpret=INTERPRET,
    )(x, y)


@jax.custom_vjp
def matmul_nt(x: jax.Array, y: jax.Array) -> jax.Array:
    """``x @ y.T`` as a Pallas kernel with a custom VJP."""
    return _matmul_nt_impl(x, y)


def _matmul_nt_fwd(x, y):
    return _matmul_nt_impl(x, y), (x, y)


def _matmul_nt_bwd(res, g):
    x, y = res
    # d/dx (x y^T) . g = g @ y ; d/dy = g^T @ x
    return _matmul_nn_impl(g, y), _matmul_nn_impl(g.T, x)


matmul_nt.defvjp(_matmul_nt_fwd, _matmul_nt_bwd)


# ---------------------------------------------------------------------------
# matmul_nn: out[n, m] = x[n, r] @ y[r, m]  (the "decompress" GEMM shape)
# ---------------------------------------------------------------------------


def _mm_nn_kernel(x_ref, y_ref, o_ref):
    """One grid step: o[bn, bm] = x[bn, r] @ y[r, bm]. r is not tiled, so
    there is no reduction sweep — each output block is produced in one shot
    (r <= BLOCK_R always holds for FLORA ranks)."""
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _matmul_nn_impl(x: jax.Array, y: jax.Array) -> jax.Array:
    n, r = x.shape
    r2, m = y.shape
    assert r == r2, f"contraction mismatch: {x.shape} vs {y.shape}"
    gn, bn = _grid_dim(n, BLOCK_N)
    gm, bm = _grid_dim(m, BLOCK_M)
    return pl.pallas_call(
        _mm_nn_kernel,
        grid=(gn, gm),
        in_specs=[
            pl.BlockSpec((bn, r), lambda i, j: (i, 0)),
            pl.BlockSpec((r, bm), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=INTERPRET,
    )(x, y)


@jax.custom_vjp
def matmul_nn(x: jax.Array, y: jax.Array) -> jax.Array:
    """``x @ y`` as a Pallas kernel with a custom VJP."""
    return _matmul_nn_impl(x, y)


def _matmul_nn_fwd(x, y):
    return _matmul_nn_impl(x, y), (x, y)


def _matmul_nn_bwd(res, g):
    x, y = res
    # d/dx (x y) . g = g @ y^T ; d/dy = x^T @ g
    return _matmul_nt_impl(g, y), _matmul_nn_impl(x.T, g)


matmul_nn.defvjp(_matmul_nn_fwd, _matmul_nn_bwd)


# ---------------------------------------------------------------------------
# Fused compress-accumulate: C' = C + G @ A^T  (Algorithm 1, line 9)
# ---------------------------------------------------------------------------


def _compress_acc_kernel(c_ref, g_ref, a_ref, o_ref):
    """o[bn, r] = c[bn, r] (on the first reduction step) + g[bn, bm] @ a[r, bm]^T
    accumulated across the m sweep. Fusing the += saves one full pass over C
    per micro-step versus compress-then-add."""
    @pl.when(pl.program_id(1) == 0)
    def _seed():
        o_ref[...] = c_ref[...]

    o_ref[...] += jax.lax.dot_general(
        g_ref[...],
        a_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _compress_accumulate_impl(c, g, a):
    n, m = g.shape
    r = a.shape[0]
    gn, bn = _grid_dim(n, BLOCK_N)
    gm, bm = _grid_dim(m, BLOCK_M)
    return pl.pallas_call(
        _compress_acc_kernel,
        grid=(gn, gm),
        in_specs=[
            pl.BlockSpec((bn, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((r, bm), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, r), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r), g.dtype),
        interpret=INTERPRET,
    )(c, g, a)


@jax.custom_vjp
def compress_accumulate(c: jax.Array, g: jax.Array, a: jax.Array) -> jax.Array:
    """Fused ``c + g @ a.T`` (Algorithm 1 line 9). Shapes: c [n,r], g [n,m],
    a [r,m] -> [n,r]."""
    return _compress_accumulate_impl(c, g, a)


def _ca_fwd(c, g, a):
    return _compress_accumulate_impl(c, g, a), (g, a)


def _ca_bwd(res, t):
    g, a = res
    return t, _matmul_nn_impl(t, a), _matmul_nn_impl(t.T, g)


compress_accumulate.defvjp(_ca_fwd, _ca_bwd)


# ---------------------------------------------------------------------------
# Public FLORA ops
# ---------------------------------------------------------------------------


def compress(g: jax.Array, a: jax.Array) -> jax.Array:
    """Down-project a gradient: ``g @ a.T`` ([n,m] x [r,m] -> [n,r])."""
    return matmul_nt(g, a)


def decompress(c: jax.Array, a: jax.Array) -> jax.Array:
    """Up-project a compressed state: ``c @ a`` ([n,r] x [r,m] -> [n,m]).

    Note: the 1/r normalization of Theorem 2.4 is folded into the sampling
    scale of :func:`project_normal` (A ~ N(0, 1/r)), matching Algorithms 1–2,
    so no extra scaling happens here.
    """
    return matmul_nn(c, a)


def transfer(m_c: jax.Array, a_old: jax.Array, a_new: jax.Array) -> jax.Array:
    """Move compressed momentum between subspaces: ``m_c @ a_old @ a_new.T``
    (Algorithm 2 line 13). Shapes: [n,r] x [r,m] x [r,m] -> [n,r].

    Composed as decompress-then-compress; the intermediate [n,m] exists only
    inside the step's live range (XLA frees it immediately), preserving the
    O(nr) *state* bound — the paper makes the same trade (its Alg. 2 line 13
    materializes M A_old A'^T the same way).
    """
    return matmul_nt(matmul_nn(m_c, a_old), a_new)


def project_normal(seed, r: int, m: int, dtype=jnp.float32) -> jax.Array:
    """Regenerate the projection matrix A ~ N(0, 1/r)^{r x m} from a u32 seed.

    This is the paper's memory trick (§2.4 "we may store the random seed"):
    A is never part of the optimizer state — only the seed crosses the
    rust<->XLA boundary, and threefry lowers to plain HLO.
    """
    key = jax.random.PRNGKey(jnp.asarray(seed, jnp.uint32))
    return jax.random.normal(key, (r, m), dtype=dtype) / jnp.sqrt(
        jnp.asarray(r, dtype)
    )
