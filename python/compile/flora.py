"""L2 — the FLORA method layer (paper Algorithms 1 and 2) plus the Naive
full-state baselines, expressed as pure functions over flat state dicts.

Two state machines:

  Accumulation (Algorithm 1) — driven by the rust coordinator's τ-cycle:
      micro:  C_W ← C_W + G_W A_W^T      (A_W regenerated from the cycle seed)
      update: Ĝ_W = C_W A_W / τ  → base-optimizer step; coordinator then
              zeroes C and resamples the seed.

  Momentum (Algorithm 2) — driven by the coordinator's κ-interval:
      every step: M ← β·T(M) + (1−β)·G A'^T, yield M A' to the base
      optimizer; T is the subspace transfer M A_old A_new^T when the
      coordinator raises the resample flag, identity otherwise.

"Naive" variants keep the *full-size* accumulator / momentum — these are the
paper's upper-quality, linear-memory baselines and share all surrounding
code so any quality gap is attributable to the compression alone.

Projection matrices never exist in state: only u32 seeds cross the AOT
boundary (see kernels.rp.project_normal).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .kernels import rp

Params = dict
State = dict


def projectable_names(params_or_shapes: dict) -> list:
    """Sorted names of parameters that get the compression treatment."""
    out = []
    for name, v in sorted(params_or_shapes.items()):
        shape = v if isinstance(v, tuple) else tuple(v.shape)
        if layers.is_projectable(name, len(shape)):
            out.append(name)
    return out


# ---------------------------------------------------------------------------
# Per-parameter seed derivation.
#
# The coordinator hands over ONE u32 seed per cycle / interval; each weight
# matrix must get an *independent* projection (Algorithm 1 line 3: "an
# independent random seed"). We derive per-parameter seeds by hashing the
# parameter index into the seed — stable across micro/update executables
# because both iterate the same sorted name list.
# ---------------------------------------------------------------------------


def derive_seed(base_seed, index: int):
    """Cheap integer hash mixing (Knuth multiplicative); runs inside XLA."""
    s = jnp.asarray(base_seed, jnp.uint32)
    return s * jnp.uint32(2654435761) + jnp.uint32(index * 40503 + 1)


def _proj(base_seed, index: int, r: int, m: int) -> jax.Array:
    return rp.project_normal(derive_seed(base_seed, index), r, m)


# ---------------------------------------------------------------------------
# Accumulation methods (Algorithm 1 + naive baseline)
# ---------------------------------------------------------------------------


class NaiveAccumulation:
    """Full-size gradient accumulator: C has the shape of W for every W."""

    name = "naive"

    def __init__(self, param_shapes: dict):
        self.param_shapes = dict(sorted(param_shapes.items()))

    def state_shapes(self) -> dict:
        return {f"acc/{k}": tuple(s) for k, s in self.param_shapes.items()}

    def init_state(self) -> State:
        return {
            k: jnp.zeros(s, jnp.float32) for k, s in self.state_shapes().items()
        }

    def accumulate(self, state: State, grads: Params, seed) -> State:
        return {f"acc/{k}": state[f"acc/{k}"] + grads[k] for k in grads}

    def mean_grads(self, state: State, seed, tau) -> Params:
        inv = 1.0 / jnp.asarray(tau, jnp.float32)
        return {k: state[f"acc/{k}"] * inv for k in self.param_shapes}


class FloraAccumulation:
    """Algorithm 1: compressed accumulator C_W ∈ R^{n×r} for projectable
    weights, full-size for the rest (embeddings, norms — paper §3.1)."""

    name = "flora"

    def __init__(self, param_shapes: dict, rank: int):
        self.param_shapes = dict(sorted(param_shapes.items()))
        self.rank = rank
        self.projected = set(projectable_names(self.param_shapes))
        # stable per-parameter indices for seed derivation
        self.index = {k: i for i, k in enumerate(sorted(self.param_shapes))}

    def state_shapes(self) -> dict:
        out = {}
        for k, s in self.param_shapes.items():
            if k in self.projected:
                out[f"acc/{k}"] = (s[0], self.rank)
            else:
                out[f"acc/{k}"] = tuple(s)
        return out

    def init_state(self) -> State:
        return {
            k: jnp.zeros(s, jnp.float32) for k, s in self.state_shapes().items()
        }

    def accumulate(self, state: State, grads: Params, seed) -> State:
        """C ← C + G A^T (fused Pallas kernel) for projectable weights."""
        new = {}
        for k, g in grads.items():
            c = state[f"acc/{k}"]
            if k in self.projected:
                a = _proj(seed, self.index[k], self.rank, g.shape[1])
                new[f"acc/{k}"] = rp.compress_accumulate(c, g, a)
            else:
                new[f"acc/{k}"] = c + g
        return new

    def mean_grads(self, state: State, seed, tau) -> Params:
        """Ĝ = C A / τ — decompression with the SAME seed the cycle used."""
        inv = 1.0 / jnp.asarray(tau, jnp.float32)
        out = {}
        for k, s in self.param_shapes.items():
            c = state[f"acc/{k}"]
            if k in self.projected:
                a = _proj(seed, self.index[k], self.rank, s[1])
                out[k] = rp.decompress(c, a) * inv
            else:
                out[k] = c * inv
        return out


# ---------------------------------------------------------------------------
# Momentum methods (Algorithm 2 + naive EMA baseline)
# ---------------------------------------------------------------------------


class NaiveMomentum:
    """Full-size EMA of gradients; the quality upper bound for Table 2."""

    name = "naive"

    def __init__(self, param_shapes: dict, beta: float = 0.9):
        self.param_shapes = dict(sorted(param_shapes.items()))
        self.beta = beta

    def state_shapes(self) -> dict:
        return {f"mom/{k}": tuple(s) for k, s in self.param_shapes.items()}

    def init_state(self) -> State:
        return {
            k: jnp.zeros(s, jnp.float32) for k, s in self.state_shapes().items()
        }

    def step(self, state, grads, seed_cur, seed_next, resample):
        """Returns (effective_grads, new_state); seeds/flag unused here but
        kept for ABI parity with FloraMomentum."""
        new, eff = {}, {}
        for k, g in grads.items():
            m = self.beta * state[f"mom/{k}"] + (1 - self.beta) * g
            new[f"mom/{k}"] = m
            eff[k] = m
        return eff, new


class FloraMomentum:
    """Algorithm 2: compressed momentum M ∈ R^{n×r} with κ-interval subspace
    transfer. The resample decision/κ counting lives in the RUST coordinator;
    this function just obeys the ``resample`` flag (0.0 or 1.0 scalar).

    ``transfer=False`` is the ablation of the paper's second remedy (§2.4):
    on resample the old momentum is kept VERBATIM in the new subspace
    coordinates (i.e. silently reinterpreted), so the historical EMA is
    distorted instead of moved — benches/ablation_transfer.rs measures how
    much the transfer actually buys.
    """

    name = "flora"

    def __init__(self, param_shapes: dict, rank: int, beta: float = 0.9,
                 transfer: bool = True):
        self.transfer = transfer
        self.param_shapes = dict(sorted(param_shapes.items()))
        self.rank = rank
        self.beta = beta
        self.projected = set(projectable_names(self.param_shapes))
        self.index = {k: i for i, k in enumerate(sorted(self.param_shapes))}
        if not transfer:
            self.name = "flora_notransfer"

    def state_shapes(self) -> dict:
        out = {}
        for k, s in self.param_shapes.items():
            if k in self.projected:
                out[f"mom/{k}"] = (s[0], self.rank)
            else:
                out[f"mom/{k}"] = tuple(s)
        return out

    def init_state(self) -> State:
        return {
            k: jnp.zeros(s, jnp.float32) for k, s in self.state_shapes().items()
        }

    def step(self, state, grads, seed_cur, seed_next, resample):
        """One Algorithm-2 step.

        resample: f32 scalar ∈ {0.0, 1.0}. When 1.0, the active projection
        becomes A(seed_next) and M is transferred M A_cur A_next^T first
        (lines 11–13); when 0.0, A(seed_cur) stays active (lines 15–17).
        Both branches lower into the graph and are blended by `select` —
        branch-free HLO, negligible at these state sizes.
        """
        new, eff = {}, {}
        for k, g in grads.items():
            m = state[f"mom/{k}"]
            if k in self.projected:
                mdim = g.shape[1]
                a_cur = _proj(seed_cur, self.index[k], self.rank, mdim)
                a_next = _proj(seed_next, self.index[k], self.rank, mdim)
                if self.transfer:
                    m_moved = rp.transfer(m, a_cur, a_next)
                else:
                    m_moved = m  # ablation: keep raw coordinates
                m_prev = resample * m_moved + (1.0 - resample) * m
                a_active_c = resample * a_next + (1.0 - resample) * a_cur
                m_new = self.beta * m_prev + (1 - self.beta) * rp.compress(
                    g, a_active_c
                )
                eff[k] = rp.decompress(m_new, a_active_c)
            else:
                m_new = self.beta * m + (1 - self.beta) * g
                eff[k] = m_new
            new[f"mom/{k}"] = m_new
        return eff, new


def make_accumulation(method: str, param_shapes: dict, rank: int):
    if method == "naive":
        return NaiveAccumulation(param_shapes)
    if method == "flora":
        return FloraAccumulation(param_shapes, rank)
    raise ValueError(f"unknown accumulation method {method!r}")


def make_momentum(method: str, param_shapes: dict, rank: int, beta: float):
    if method == "naive":
        return NaiveMomentum(param_shapes, beta)
    if method == "flora":
        return FloraMomentum(param_shapes, rank, beta)
    if method == "flora_notransfer":
        return FloraMomentum(param_shapes, rank, beta, transfer=False)
    raise ValueError(f"unknown momentum method {method!r}")
