"""L2 — model-config registry shared by aot.py and the tests.

Every artifact bundle is built against one of these named configurations;
the names are part of the rust-side ABI (manifest `model` field, bench
configs in rust/src/config). Sizes are chosen so the full bench suite runs
on the 1-core CPU PJRT backend in minutes; the paper's 60M/110M/1.5B/3B
rows are mapped onto these via the analytic memory accountant (DESIGN.md §4).
"""

from __future__ import annotations

from .layers import LMConfig
from .vit import ViTConfig


def lm_configs() -> dict:
    return {
        # test-size config: exercised by pytest and rust integration tests
        "lm-tiny": LMConfig(
            vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64,
            seq_len=32, name="lm-tiny",
        ),
        # shared bench model: "T5-small-sim" / "GPT-2-sim" / "C4-sim".
        # The sum/mt/c4 tasks differ only in DATA (rust data/ substrate);
        # one weight/executable bundle serves Tables 1-4 and 6.
        "lm-small": LMConfig(
            vocab=256, d_model=64, n_layers=2, n_heads=4, d_ff=256,
            seq_len=64, name="lm-small",
        ),
        # end-to-end example model (examples/train_lm.rs): ~0.9M params
        "lm-base": LMConfig(
            vocab=512, d_model=128, n_layers=4, n_heads=4, d_ff=512,
            seq_len=128, name="lm-base",
        ),
    }


def vit_configs() -> dict:
    return {
        "vit-tiny": ViTConfig(
            image_size=16, patch_size=4, d_model=32, n_layers=2, n_heads=2,
            d_ff=64, n_classes=10, name="vit-tiny",
        ),
        # Table-5 "ViT-sim": synthetic CIFAR-like 16x16x3, 20 classes
        "vit-cifar": ViTConfig(
            image_size=16, patch_size=4, d_model=64, n_layers=2, n_heads=4,
            d_ff=256, n_classes=20, name="vit-cifar",
        ),
    }


def get_lm(name: str) -> LMConfig:
    return lm_configs()[name]


def get_vit(name: str) -> ViTConfig:
    return vit_configs()[name]
