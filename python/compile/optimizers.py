"""L2 — optimizers, implemented from scratch (optax is not available in this
image, and the reproduction mandate is to build substrates ourselves).

All optimizers share one calling convention so the method layer (flora.py /
lora.py / galore.py / steps.py) can compose them:

    state  = opt.init(params)                     # dict[str, jax.Array]
    params, state = opt.update(params, grads, state, lr, step)

``state`` keys are ``{param_name}/{slot}`` — flat, sorted-key-deterministic,
which is exactly how the AOT boundary serializes them into the manifest.

Implemented:
  * ``Sgd``                — plain SGD (pilot cross-checks).
  * ``Adam``               — Kingma & Ba 2015, bias-corrected.
  * ``Adafactor``          — Shazeer & Stern 2018, factored second moment
                             (the paper's base optimizer, §3.1). Sublinear
                             state: O(n+m) per matrix.
  * ``Adafactor(factored=False)`` — the paper's Table-4 "linear-memory
                             optimizer" ablation: full second moment.

Momentum is deliberately NOT part of these classes: the paper treats
momentum/accumulation as *separate state that FLORA compresses* (Algorithms
1–2); the composition lives in flora.py / steps.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Params = dict
State = dict


def _rms(x: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.mean(jnp.square(x)))


class Sgd:
    """Plain SGD. Stateless."""

    name = "sgd"

    def init(self, params: Params) -> State:
        return {}

    def update(self, params, grads, state, lr, step):
        new = {k: params[k] - lr * grads[k] for k in params}
        return new, state

    def state_slots(self, pname: str, shape) -> list:
        return []


class Adam:
    """Adam with bias correction. State: m, v full-size (2x model memory —
    the paper's motivating example of linear-memory optimizer state)."""

    name = "adam"

    def __init__(self, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
        self.b1, self.b2, self.eps = b1, b2, eps

    def init(self, params: Params) -> State:
        s: State = {}
        for k, v in params.items():
            s[f"{k}/m"] = jnp.zeros_like(v)
            s[f"{k}/v"] = jnp.zeros_like(v)
        return s

    def update(self, params, grads, state, lr, step):
        new_p, new_s = {}, {}
        t = jnp.asarray(step, jnp.float32) + 1.0
        for k in params:
            g = grads[k]
            m = self.b1 * state[f"{k}/m"] + (1 - self.b1) * g
            v = self.b2 * state[f"{k}/v"] + (1 - self.b2) * jnp.square(g)
            mhat = m / (1 - self.b1**t)
            vhat = v / (1 - self.b2**t)
            new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + self.eps)
            new_s[f"{k}/m"] = m
            new_s[f"{k}/v"] = v
        return new_p, new_s

    def state_slots(self, pname: str, shape) -> list:
        return [(f"{pname}/m", shape), (f"{pname}/v", shape)]


class Adafactor:
    """Adafactor (Shazeer & Stern 2018) with external learning rate
    (``relative_step=False``), update clipping d=1.0, and no built-in
    momentum — matching how the paper drives it.

    ``factored=True``: matrices keep row/col second-moment vectors
    (O(n+m)); vectors keep a full second moment.
    ``factored=False``: every parameter keeps a full second moment — the
    Table-4 "optimizer with linear memory" variant.
    """

    name = "adafactor"

    def __init__(
        self,
        factored: bool = True,
        eps1: float = 1e-30,
        eps2: float = 1e-3,
        clip_threshold: float = 1.0,
        decay_exponent: float = 0.8,
    ):
        self.factored = factored
        self.eps1 = eps1
        self.eps2 = eps2
        self.clip = clip_threshold
        self.decay_exponent = decay_exponent
        if not factored:
            self.name = "adafactor_nofactor"

    def _beta2(self, step):
        t = jnp.asarray(step, jnp.float32) + 1.0
        return 1.0 - jnp.power(t, -self.decay_exponent)

    def _is_factored(self, shape) -> bool:
        return self.factored and len(shape) == 2

    def init(self, params: Params) -> State:
        s: State = {}
        for k, v in params.items():
            if self._is_factored(v.shape):
                s[f"{k}/vr"] = jnp.zeros((v.shape[0],), jnp.float32)
                s[f"{k}/vc"] = jnp.zeros((v.shape[1],), jnp.float32)
            else:
                s[f"{k}/v"] = jnp.zeros_like(v)
        return s

    def update(self, params, grads, state, lr, step):
        new_p, new_s = {}, {}
        b2 = self._beta2(step)
        for k in params:
            g = grads[k]
            g2 = jnp.square(g) + self.eps1
            if self._is_factored(g.shape):
                vr = b2 * state[f"{k}/vr"] + (1 - b2) * jnp.mean(g2, axis=1)
                vc = b2 * state[f"{k}/vc"] + (1 - b2) * jnp.mean(g2, axis=0)
                # reconstruct \hat v = vr vc^T / mean(vr)
                denom = jnp.maximum(jnp.mean(vr), self.eps1)
                u = g / (
                    jnp.sqrt(vr / denom)[:, None] * jnp.sqrt(vc)[None, :]
                )
                new_s[f"{k}/vr"] = vr
                new_s[f"{k}/vc"] = vc
            else:
                v = b2 * state[f"{k}/v"] + (1 - b2) * g2
                u = g / jnp.sqrt(v)
                new_s[f"{k}/v"] = v
            # update clipping: u /= max(1, RMS(u)/d)
            u = u / jnp.maximum(1.0, _rms(u) / self.clip)
            # parameter-scale-relative step (eps2 floor), as in the paper's
            # official implementation with external lr.
            scale = jnp.maximum(self.eps2, _rms(params[k]))
            new_p[k] = params[k] - lr * scale * u
        return new_p, new_s

    def state_slots(self, pname: str, shape) -> list:
        if self._is_factored(shape):
            return [
                (f"{pname}/vr", (shape[0],)),
                (f"{pname}/vc", (shape[1],)),
            ]
        return [(f"{pname}/v", tuple(shape))]


def make_optimizer(name: str):
    """Registry used by aot.py config strings."""
    if name == "sgd":
        return Sgd()
    if name == "adam":
        return Adam()
    if name == "adafactor":
        return Adafactor(factored=True)
    if name == "adafactor_nofactor":
        return Adafactor(factored=False)
    raise ValueError(f"unknown optimizer {name!r}")
