"""L2 — builders for the AOT-lowered step functions.

Each builder returns ``(fn, in_specs, out_names)`` where ``fn`` takes/returns
*flat tuples of arrays* in sorted-name order — the exact ABI the rust
runtime reconstructs from artifacts/manifest.json. All composition of
model × method × optimizer happens here; aot.py only lowers what these
builders hand it.

Flat ABI convention (mirrored by rust/src/runtime/manifest.rs):
    inputs  = [*params(sorted), *opt_state(sorted), *method_state(sorted),
               *batch, *scalars]
    outputs = tuple in the order given by ``out_names``
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from . import flora, galore as galore_mod, layers, lora as lora_mod, vit as vit_mod
from .layers import LMConfig
from .vit import ViTConfig

# ---------------------------------------------------------------------------
# Flat <-> dict packing
# ---------------------------------------------------------------------------


class Packer:
    """Bidirectional flat-tuple <-> name-dict mapping for one tensor group."""

    def __init__(self, shapes: dict, group: str):
        self.group = group
        self.names = sorted(shapes)
        self.shapes = {k: tuple(shapes[k]) for k in self.names}

    def unpack(self, flat) -> dict:
        assert len(flat) == len(self.names), (
            f"{self.group}: got {len(flat)} arrays, want {len(self.names)}"
        )
        return dict(zip(self.names, flat))

    def pack(self, d: dict) -> tuple:
        return tuple(d[k] for k in self.names)

    def specs(self, dtype=jnp.float32) -> list:
        """[(qualified_name, shape, dtype_str)] for the manifest. A group of
        "" means the keys are already fully qualified (method-state dicts
        carry their own acc// mom/ prefixes)."""
        prefix = f"{self.group}/" if self.group else ""
        return [
            (f"{prefix}{k}", self.shapes[k], str(jnp.dtype(dtype)))
            for k in self.names
        ]


def _scalar_spec(name: str, dtype) -> tuple:
    return (name, (), str(jnp.dtype(dtype)))


def _lm_batch_specs(cfg: LMConfig, batch: int) -> list:
    return [
        ("batch/tokens", (batch, cfg.seq_len), "int32"),
        ("batch/mask", (batch, cfg.seq_len), "float32"),
    ]


# ---------------------------------------------------------------------------
# LM: init / eval / greedy
# ---------------------------------------------------------------------------


def build_lm_init(cfg: LMConfig):
    pk = Packer(cfg.param_shapes(), "params")

    def fn(seed):
        return pk.pack(layers.init_lm(cfg, seed))

    in_specs = [_scalar_spec("seed", jnp.uint32)]
    return fn, in_specs, [n for (n, _, _) in pk.specs()]


def build_lm_eval(cfg: LMConfig, batch: int):
    pk = Packer(cfg.param_shapes(), "params")

    def fn(*args):
        params = pk.unpack(args[: len(pk.names)])
        tokens, mask = args[len(pk.names) :]
        return (layers.lm_loss(params, tokens, mask, cfg),)

    in_specs = pk.specs() + _lm_batch_specs(cfg, batch)
    return fn, in_specs, ["loss"]


def build_lm_greedy(cfg: LMConfig, batch: int):
    pk = Packer(cfg.param_shapes(), "params")

    def fn(*args):
        params = pk.unpack(args[: len(pk.names)])
        tokens, prompt_len = args[len(pk.names) :]
        return (layers.lm_greedy_decode(params, tokens, prompt_len, cfg),)

    in_specs = (
        pk.specs()
        + [("batch/tokens", (batch, cfg.seq_len), "int32")]
        + [_scalar_spec("prompt_len", jnp.int32)]
    )
    return fn, in_specs, ["tokens"]


# ---------------------------------------------------------------------------
# LM: accumulation micro / update (Algorithm 1), plain step (method "none")
# ---------------------------------------------------------------------------


def build_lm_micro(cfg: LMConfig, method: str, rank: int, batch: int):
    """micro: grads of one microbatch, folded into the accumulator."""
    pk = Packer(cfg.param_shapes(), "params")
    acc = flora.make_accumulation(method, cfg.param_shapes(), rank)
    ak = Packer(acc.state_shapes(), "")

    def fn(*args):
        i = 0
        params = pk.unpack(args[i : i + len(pk.names)]); i += len(pk.names)
        state = ak.unpack(args[i : i + len(ak.names)]); i += len(ak.names)
        tokens, mask, seed = args[i], args[i + 1], args[i + 2]
        loss, grads = jax.value_and_grad(layers.lm_loss)(
            params, tokens, mask, cfg
        )
        new_state = acc.accumulate(state, grads, seed)
        return (loss, *ak.pack(new_state))

    in_specs = (
        pk.specs()
        + ak.specs()
        + _lm_batch_specs(cfg, batch)
        + [_scalar_spec("seed", jnp.uint32)]
    )
    out_names = ["loss"] + [n for (n, _, _) in ak.specs()]
    return fn, in_specs, out_names


def build_lm_update(cfg: LMConfig, method: str, rank: int, optimizer):
    """update: decompress the accumulator mean and apply the base optimizer."""
    pk = Packer(cfg.param_shapes(), "params")
    acc = flora.make_accumulation(method, cfg.param_shapes(), rank)
    ak = Packer(acc.state_shapes(), "")
    shapes_params = {
        k: jnp.zeros(s, jnp.float32) for k, s in cfg.param_shapes().items()
    }
    ok = Packer(
        {k: v.shape for k, v in optimizer.init(shapes_params).items()}, "opt"
    )

    def fn(*args):
        i = 0
        params = pk.unpack(args[i : i + len(pk.names)]); i += len(pk.names)
        opt_state = ok.unpack(args[i : i + len(ok.names)]); i += len(ok.names)
        state = ak.unpack(args[i : i + len(ak.names)]); i += len(ak.names)
        seed, tau, lr, step = args[i : i + 4]
        grads = acc.mean_grads(state, seed, tau)
        new_params, new_opt = optimizer.update(params, grads, opt_state, lr, step)
        return (*pk.pack(new_params), *ok.pack(new_opt))

    in_specs = (
        pk.specs()
        + ok.specs()
        + ak.specs()
        + [
            _scalar_spec("seed", jnp.uint32),
            _scalar_spec("tau", jnp.float32),
            _scalar_spec("lr", jnp.float32),
            _scalar_spec("step", jnp.float32),
        ]
    )
    out_names = [n for (n, _, _) in pk.specs()] + [n for (n, _, _) in ok.specs()]
    return fn, in_specs, out_names


def build_lm_plain_step(cfg: LMConfig, optimizer, batch: int):
    """method "none": no accumulation/momentum — grad + optimizer, fused."""
    pk = Packer(cfg.param_shapes(), "params")
    shapes_params = {
        k: jnp.zeros(s, jnp.float32) for k, s in cfg.param_shapes().items()
    }
    ok = Packer(
        {k: v.shape for k, v in optimizer.init(shapes_params).items()}, "opt"
    )

    def fn(*args):
        i = 0
        params = pk.unpack(args[i : i + len(pk.names)]); i += len(pk.names)
        opt_state = ok.unpack(args[i : i + len(ok.names)]); i += len(ok.names)
        tokens, mask, lr, step = args[i : i + 4]
        loss, grads = jax.value_and_grad(layers.lm_loss)(
            params, tokens, mask, cfg
        )
        new_params, new_opt = optimizer.update(params, grads, opt_state, lr, step)
        return (loss, *pk.pack(new_params), *ok.pack(new_opt))

    in_specs = (
        pk.specs()
        + ok.specs()
        + _lm_batch_specs(cfg, batch)
        + [_scalar_spec("lr", jnp.float32), _scalar_spec("step", jnp.float32)]
    )
    out_names = (
        ["loss"]
        + [n for (n, _, _) in pk.specs()]
        + [n for (n, _, _) in ok.specs()]
    )
    return fn, in_specs, out_names


# ---------------------------------------------------------------------------
# LM: fused momentum step (Algorithm 2)
# ---------------------------------------------------------------------------


def build_lm_momentum_step(
    cfg: LMConfig, method: str, rank: int, beta: float, optimizer, batch: int
):
    pk = Packer(cfg.param_shapes(), "params")
    mom = flora.make_momentum(method, cfg.param_shapes(), rank, beta)
    mk = Packer(mom.state_shapes(), "")
    shapes_params = {
        k: jnp.zeros(s, jnp.float32) for k, s in cfg.param_shapes().items()
    }
    ok = Packer(
        {k: v.shape for k, v in optimizer.init(shapes_params).items()}, "opt"
    )

    def fn(*args):
        i = 0
        params = pk.unpack(args[i : i + len(pk.names)]); i += len(pk.names)
        opt_state = ok.unpack(args[i : i + len(ok.names)]); i += len(ok.names)
        mstate = mk.unpack(args[i : i + len(mk.names)]); i += len(mk.names)
        tokens, mask, seed_cur, seed_next, resample, lr, step = args[i : i + 7]
        loss, grads = jax.value_and_grad(layers.lm_loss)(
            params, tokens, mask, cfg
        )
        eff, new_m = mom.step(mstate, grads, seed_cur, seed_next, resample)
        new_params, new_opt = optimizer.update(params, eff, opt_state, lr, step)
        return (loss, *pk.pack(new_params), *ok.pack(new_opt), *mk.pack(new_m))

    in_specs = (
        pk.specs()
        + ok.specs()
        + mk.specs()
        + _lm_batch_specs(cfg, batch)
        + [
            _scalar_spec("seed_cur", jnp.uint32),
            _scalar_spec("seed_next", jnp.uint32),
            _scalar_spec("resample", jnp.float32),
            _scalar_spec("lr", jnp.float32),
            _scalar_spec("step", jnp.float32),
        ]
    )
    out_names = (
        ["loss"]
        + [n for (n, _, _) in pk.specs()]
        + [n for (n, _, _) in ok.specs()]
        + [n for (n, _, _) in mk.specs()]
    )
    return fn, in_specs, out_names


# ---------------------------------------------------------------------------
# LM: LoRA (frozen base + trainable patches)
# ---------------------------------------------------------------------------


def build_lora_init(cfg: LMConfig, rank: int):
    pk = Packer(cfg.param_shapes(), "base")
    adapter = lora_mod.LoraAdapter(cfg.param_shapes(), rank)
    tk = Packer(adapter.trainable_shapes(), "train")

    def fn(*args):
        base = pk.unpack(args[: len(pk.names)])
        seed = args[len(pk.names)]
        return tk.pack(adapter.init_trainable(base, seed))

    in_specs = pk.specs() + [_scalar_spec("seed", jnp.uint32)]
    return fn, in_specs, [n for (n, _, _) in tk.specs()]


def _lora_loss(adapter, cfg):
    def loss_fn(trainable, base, tokens, mask):
        eff = adapter.merge(base, trainable)
        return layers.lm_loss(eff, tokens, mask, cfg)

    return loss_fn


def build_lora_micro(cfg: LMConfig, rank: int, batch: int):
    """LoRA with naive (full) accumulation over its small trainable set."""
    pk = Packer(cfg.param_shapes(), "base")
    adapter = lora_mod.LoraAdapter(cfg.param_shapes(), rank)
    tk = Packer(adapter.trainable_shapes(), "train")
    acc = flora.NaiveAccumulation(adapter.trainable_shapes())
    ak = Packer(acc.state_shapes(), "")
    loss_fn = _lora_loss(adapter, cfg)

    def fn(*args):
        i = 0
        base = pk.unpack(args[i : i + len(pk.names)]); i += len(pk.names)
        train = tk.unpack(args[i : i + len(tk.names)]); i += len(tk.names)
        state = ak.unpack(args[i : i + len(ak.names)]); i += len(ak.names)
        tokens, mask = args[i], args[i + 1]
        loss, grads = jax.value_and_grad(loss_fn)(train, base, tokens, mask)
        new_state = acc.accumulate(state, grads, jnp.uint32(0))
        return (loss, *ak.pack(new_state))

    in_specs = pk.specs() + tk.specs() + ak.specs() + _lm_batch_specs(cfg, batch)
    out_names = ["loss"] + [n for (n, _, _) in ak.specs()]
    return fn, in_specs, out_names


def build_lora_update(cfg: LMConfig, rank: int, optimizer):
    adapter = lora_mod.LoraAdapter(cfg.param_shapes(), rank)
    tk = Packer(adapter.trainable_shapes(), "train")
    acc = flora.NaiveAccumulation(adapter.trainable_shapes())
    ak = Packer(acc.state_shapes(), "")
    zeros = {
        k: jnp.zeros(s, jnp.float32)
        for k, s in adapter.trainable_shapes().items()
    }
    ok = Packer({k: v.shape for k, v in optimizer.init(zeros).items()}, "opt")

    def fn(*args):
        i = 0
        train = tk.unpack(args[i : i + len(tk.names)]); i += len(tk.names)
        opt_state = ok.unpack(args[i : i + len(ok.names)]); i += len(ok.names)
        state = ak.unpack(args[i : i + len(ak.names)]); i += len(ak.names)
        tau, lr, step = args[i : i + 3]
        grads = acc.mean_grads(state, jnp.uint32(0), tau)
        new_train, new_opt = optimizer.update(train, grads, opt_state, lr, step)
        return (*tk.pack(new_train), *ok.pack(new_opt))

    in_specs = (
        tk.specs()
        + ok.specs()
        + ak.specs()
        + [
            _scalar_spec("tau", jnp.float32),
            _scalar_spec("lr", jnp.float32),
            _scalar_spec("step", jnp.float32),
        ]
    )
    out_names = [n for (n, _, _) in tk.specs()] + [n for (n, _, _) in ok.specs()]
    return fn, in_specs, out_names


def build_lora_momentum_step(
    cfg: LMConfig, rank: int, beta: float, optimizer, batch: int
):
    """LoRA trained from scratch with (naive, small) momentum — Table 2 rows."""
    pk = Packer(cfg.param_shapes(), "base")
    adapter = lora_mod.LoraAdapter(cfg.param_shapes(), rank)
    tk = Packer(adapter.trainable_shapes(), "train")
    mom = flora.NaiveMomentum(adapter.trainable_shapes(), beta)
    mk = Packer(mom.state_shapes(), "")
    zeros = {
        k: jnp.zeros(s, jnp.float32)
        for k, s in adapter.trainable_shapes().items()
    }
    ok = Packer({k: v.shape for k, v in optimizer.init(zeros).items()}, "opt")
    loss_fn = _lora_loss(adapter, cfg)

    def fn(*args):
        i = 0
        base = pk.unpack(args[i : i + len(pk.names)]); i += len(pk.names)
        train = tk.unpack(args[i : i + len(tk.names)]); i += len(tk.names)
        opt_state = ok.unpack(args[i : i + len(ok.names)]); i += len(ok.names)
        mstate = mk.unpack(args[i : i + len(mk.names)]); i += len(mk.names)
        tokens, mask, lr, step = args[i : i + 4]
        loss, grads = jax.value_and_grad(loss_fn)(train, base, tokens, mask)
        eff, new_m = mom.step(mstate, grads, jnp.uint32(0), jnp.uint32(0), 0.0)
        new_train, new_opt = optimizer.update(train, eff, opt_state, lr, step)
        return (loss, *tk.pack(new_train), *ok.pack(new_opt), *mk.pack(new_m))

    in_specs = (
        pk.specs()
        + tk.specs()
        + ok.specs()
        + mk.specs()
        + _lm_batch_specs(cfg, batch)
        + [_scalar_spec("lr", jnp.float32), _scalar_spec("step", jnp.float32)]
    )
    out_names = (
        ["loss"]
        + [n for (n, _, _) in tk.specs()]
        + [n for (n, _, _) in ok.specs()]
        + [n for (n, _, _) in mk.specs()]
    )
    return fn, in_specs, out_names


def build_lora_eval(cfg: LMConfig, rank: int, batch: int):
    pk = Packer(cfg.param_shapes(), "base")
    adapter = lora_mod.LoraAdapter(cfg.param_shapes(), rank)
    tk = Packer(adapter.trainable_shapes(), "train")

    def fn(*args):
        i = 0
        base = pk.unpack(args[i : i + len(pk.names)]); i += len(pk.names)
        train = tk.unpack(args[i : i + len(tk.names)]); i += len(tk.names)
        tokens, mask = args[i], args[i + 1]
        eff = adapter.merge(base, train)
        return (layers.lm_loss(eff, tokens, mask, cfg),)

    in_specs = pk.specs() + tk.specs() + _lm_batch_specs(cfg, batch)
    return fn, in_specs, ["loss"]


def build_lora_greedy(cfg: LMConfig, rank: int, batch: int):
    pk = Packer(cfg.param_shapes(), "base")
    adapter = lora_mod.LoraAdapter(cfg.param_shapes(), rank)
    tk = Packer(adapter.trainable_shapes(), "train")

    def fn(*args):
        i = 0
        base = pk.unpack(args[i : i + len(pk.names)]); i += len(pk.names)
        train = tk.unpack(args[i : i + len(tk.names)]); i += len(tk.names)
        tokens, prompt_len = args[i], args[i + 1]
        eff = adapter.merge(base, train)
        return (layers.lm_greedy_decode(eff, tokens, prompt_len, cfg),)

    in_specs = (
        pk.specs()
        + tk.specs()
        + [("batch/tokens", (batch, cfg.seq_len), "int32")]
        + [_scalar_spec("prompt_len", jnp.int32)]
    )
    return fn, in_specs, ["tokens"]


# ---------------------------------------------------------------------------
# ViT (Table 5)
# ---------------------------------------------------------------------------


def _vit_batch_specs(cfg: ViTConfig, batch: int) -> list:
    return [
        (
            "batch/images",
            (batch, cfg.image_size, cfg.image_size, cfg.channels),
            "float32",
        ),
        ("batch/labels", (batch,), "int32"),
    ]


def build_vit_init(cfg: ViTConfig):
    pk = Packer(cfg.param_shapes(), "params")

    def fn(seed):
        return pk.pack(vit_mod.init_vit(cfg, seed))

    return fn, [_scalar_spec("seed", jnp.uint32)], [n for (n, _, _) in pk.specs()]


def build_vit_eval(cfg: ViTConfig, batch: int):
    pk = Packer(cfg.param_shapes(), "params")

    def fn(*args):
        params = pk.unpack(args[: len(pk.names)])
        images, labels = args[len(pk.names) :]
        loss = vit_mod.vit_loss(params, images, labels, cfg)
        preds = vit_mod.vit_predict(params, images, cfg)
        return (loss, preds)

    in_specs = pk.specs() + _vit_batch_specs(cfg, batch)
    return fn, in_specs, ["loss", "preds"]


def build_vit_step(cfg: ViTConfig, method: str, rank: int, beta: float,
                   optimizer, batch: int):
    """ViT training step: method "none" = plain optimizer (Adam row of
    Table 5); "flora" = Algorithm-2 compressed momentum + the optimizer."""
    pk = Packer(cfg.param_shapes(), "params")
    zeros = {k: jnp.zeros(s, jnp.float32) for k, s in cfg.param_shapes().items()}
    ok = Packer({k: v.shape for k, v in optimizer.init(zeros).items()}, "opt")
    use_mom = method == "flora"
    mom = (
        flora.make_momentum("flora", cfg.param_shapes(), rank, beta)
        if use_mom
        else None
    )
    mk = Packer(mom.state_shapes(), "") if use_mom else None

    def fn(*args):
        i = 0
        params = pk.unpack(args[i : i + len(pk.names)]); i += len(pk.names)
        opt_state = ok.unpack(args[i : i + len(ok.names)]); i += len(ok.names)
        mstate = None
        if use_mom:
            mstate = mk.unpack(args[i : i + len(mk.names)]); i += len(mk.names)
        images, labels = args[i], args[i + 1]; i += 2
        if use_mom:
            seed_cur, seed_next, resample, lr, step = args[i : i + 5]
        else:
            lr, step = args[i : i + 2]
        loss, grads = jax.value_and_grad(vit_mod.vit_loss)(
            params, images, labels, cfg
        )
        if use_mom:
            eff, new_m = mom.step(mstate, grads, seed_cur, seed_next, resample)
        else:
            eff, new_m = grads, None
        new_params, new_opt = optimizer.update(params, eff, opt_state, lr, step)
        out = (loss, *pk.pack(new_params), *ok.pack(new_opt))
        if use_mom:
            out = out + tuple(mk.pack(new_m))
        return out

    in_specs = pk.specs() + ok.specs()
    if use_mom:
        in_specs += mk.specs()
    in_specs += _vit_batch_specs(cfg, batch)
    if use_mom:
        in_specs += [
            _scalar_spec("seed_cur", jnp.uint32),
            _scalar_spec("seed_next", jnp.uint32),
            _scalar_spec("resample", jnp.float32),
        ]
    in_specs += [_scalar_spec("lr", jnp.float32), _scalar_spec("step", jnp.float32)]
    out_names = (
        ["loss"]
        + [n for (n, _, _) in pk.specs()]
        + [n for (n, _, _) in ok.specs()]
        + ([n for (n, _, _) in mk.specs()] if use_mom else [])
    )
    return fn, in_specs, out_names


# ---------------------------------------------------------------------------
# GaLore (Table 6)
# ---------------------------------------------------------------------------


def build_galore_step(cfg: LMConfig, rank: int, batch: int):
    pk = Packer(cfg.param_shapes(), "params")
    gl = galore_mod.GaLore(cfg.param_shapes(), rank)
    gk = Packer(gl.state_shapes(), "")

    def fn(*args):
        i = 0
        params = pk.unpack(args[i : i + len(pk.names)]); i += len(pk.names)
        state = gk.unpack(args[i : i + len(gk.names)]); i += len(gk.names)
        tokens, mask, seed, refresh, lr, step = args[i : i + 6]
        loss, grads = jax.value_and_grad(layers.lm_loss)(
            params, tokens, mask, cfg
        )
        new_params, new_state = gl.step(
            params, grads, state, lr, step, seed, refresh
        )
        return (loss, *pk.pack(new_params), *gk.pack(new_state))

    in_specs = (
        pk.specs()
        + gk.specs()
        + _lm_batch_specs(cfg, batch)
        + [
            _scalar_spec("seed", jnp.uint32),
            _scalar_spec("refresh", jnp.float32),
            _scalar_spec("lr", jnp.float32),
            _scalar_spec("step", jnp.float32),
        ]
    )
    out_names = (
        ["loss"]
        + [n for (n, _, _) in pk.specs()]
        + [n for (n, _, _) in gk.specs()]
    )
    return fn, in_specs, out_names
