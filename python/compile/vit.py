"""L2 — Vision Transformer for the Table-5 (Appendix C.1) image experiment.

A compact ViT (Dosovitskiy et al., 2020): patchify → linear embed → [CLS] +
learned positions → pre-norm encoder blocks (bidirectional attention) →
classification head. Reuses the parameter-naming convention of layers.py so
``is_projectable`` (attn/ffn matrices) applies unchanged and FLORA/Adam can
be composed by the same steps.py builders.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers

Params = dict


class ViTConfig:
    def __init__(
        self,
        image_size: int = 16,
        patch_size: int = 4,
        channels: int = 3,
        d_model: int = 64,
        n_layers: int = 2,
        n_heads: int = 4,
        d_ff: int = 256,
        n_classes: int = 20,
        name: str = "vit",
    ):
        assert image_size % patch_size == 0
        assert d_model % n_heads == 0
        self.image_size = image_size
        self.patch_size = patch_size
        self.channels = channels
        self.d_model = d_model
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.d_ff = d_ff
        self.n_classes = n_classes
        self.name = name

    @property
    def n_patches(self) -> int:
        return (self.image_size // self.patch_size) ** 2

    @property
    def patch_dim(self) -> int:
        return self.channels * self.patch_size**2

    def param_shapes(self) -> dict:
        d, f = self.d_model, self.d_ff
        shapes = {
            "embed/patch": (self.patch_dim, d),
            "embed/pos": (self.n_patches + 1, d),
            "embed/cls": (1, d),
            "head/w": (d, self.n_classes),
            "final_ln/scale": (d,),
        }
        for l in range(self.n_layers):
            p = f"layer{l}"
            shapes[f"{p}/attn/wq"] = (d, d)
            shapes[f"{p}/attn/wk"] = (d, d)
            shapes[f"{p}/attn/wv"] = (d, d)
            shapes[f"{p}/attn/wo"] = (d, d)
            shapes[f"{p}/ffn/w1"] = (d, f)
            shapes[f"{p}/ffn/w2"] = (f, d)
            shapes[f"{p}/ln1/scale"] = (d,)
            shapes[f"{p}/ln2/scale"] = (d,)
        return shapes

    def param_count(self) -> int:
        return sum(
            int(jnp.prod(jnp.asarray(s))) for s in self.param_shapes().values()
        )

    def to_json_dict(self) -> dict:
        return {
            "kind": "vit",
            "image_size": self.image_size,
            "patch_size": self.patch_size,
            "channels": self.channels,
            "d_model": self.d_model,
            "n_layers": self.n_layers,
            "n_heads": self.n_heads,
            "d_ff": self.d_ff,
            "n_classes": self.n_classes,
            "name": self.name,
        }


def init_vit(cfg: ViTConfig, seed) -> Params:
    key = jax.random.PRNGKey(jnp.asarray(seed, jnp.uint32))
    shapes = cfg.param_shapes()
    keys = jax.random.split(key, len(shapes))
    params: Params = {}
    for (name, shape), k in zip(sorted(shapes.items()), keys):
        if name.endswith("/scale"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name in ("embed/pos", "embed/cls"):
            params[name] = jax.random.normal(k, shape, jnp.float32) * 0.02
        else:
            params[name] = jax.random.normal(k, shape, jnp.float32) / math.sqrt(
                shape[0]
            )
    return params


def _patchify(images: jax.Array, cfg: ViTConfig) -> jax.Array:
    """images [B, H, W, C] -> [B, n_patches, patch_dim]."""
    b, h, w, c = images.shape
    p = cfg.patch_size
    x = images.reshape(b, h // p, p, w // p, p, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, cfg.n_patches, cfg.patch_dim)


def _encoder_attention(params, prefix, x, cfg):
    """Bidirectional multi-head attention (no causal mask)."""
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h

    def split(name):
        w = params[f"{prefix}/attn/{name}"]
        return (x @ w).reshape(b, s, h, dh).transpose(0, 2, 1, 3)

    q, k, v = split("wq"), split("wk"), split("wv")
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(dh)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, d)
    return ctx @ params[f"{prefix}/attn/wo"]


def vit_forward(params: Params, images: jax.Array, cfg: ViTConfig) -> jax.Array:
    """images [B, H, W, C] f32 -> logits [B, n_classes]."""
    x = _patchify(images, cfg) @ params["embed/patch"]
    b = x.shape[0]
    cls = jnp.broadcast_to(params["embed/cls"], (b, 1, cfg.d_model))
    x = jnp.concatenate([cls, x], axis=1) + params["embed/pos"][None]
    for l in range(cfg.n_layers):
        p = f"layer{l}"
        x = x + _encoder_attention(
            params, p, layers.rms_norm(x, params[f"{p}/ln1/scale"]), cfg
        )
        x = x + layers.ffn(params, p, layers.rms_norm(x, params[f"{p}/ln2/scale"]))
    x = layers.rms_norm(x, params["final_ln/scale"])
    return x[:, 0] @ params["head/w"]


def vit_loss(
    params: Params, images: jax.Array, labels: jax.Array, cfg: ViTConfig
) -> jax.Array:
    """Cross-entropy over classes. labels [B] i32."""
    logits = vit_forward(params, images, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def vit_predict(params: Params, images: jax.Array, cfg: ViTConfig) -> jax.Array:
    return jnp.argmax(vit_forward(params, images, cfg), axis=-1).astype(jnp.int32)
