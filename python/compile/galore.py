"""L2 — GaLore baseline (Zhao et al., 2024) for the Table-6 comparison.

GaLore projects each 2-D gradient onto the top-r *singular* subspace
(P = top-r left singular vectors of G, recomputed every κ steps and STORED —
this stored P is exactly the memory overhead Table 6 observes vs FLORA),
runs Adam in the projected space (moments ∈ R^{r×m}), and up-projects the
update: ΔW = lr · P · adam_update(Pᵀ G).

SUBSTITUTION (documented in DESIGN.md §4): the reference implementation
computes P via LAPACK SVD. jax 0.8's CPU SVD lowers to an FFI custom-call
that xla_extension 0.5.1 (the version the rust ``xla`` crate links) cannot
execute, so we compute the same subspace with *randomized subspace
iteration* + Newton–Schulz orthonormalization — pure GEMMs, fully portable
HLO. ``python/tests/test_galore.py`` validates the subspace against
numpy.linalg.svd (principal-angle error) so the substitution is checked,
not assumed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers

Params = dict
State = dict

# Power/subspace iterations; 4 suffices for gradient spectra (validated).
_POWER_ITERS = 4


def _orthonormalize(y: jax.Array) -> jax.Array:
    """Orthonormalize the columns of y [n, r] with modified Gram–Schmidt.

    r is small (≤ 64 in every artifact config) and static, so the python
    loop unrolls into O(r²) small HLO ops — still SVD/QR-free (the
    constraint; see module docstring) and, unlike Newton–Schulz, robust to
    the ill-conditioned bases produced by fast-decaying gradient spectra.
    """
    r = y.shape[1]
    cols = []
    for j in range(r):
        v = y[:, j]
        for q in cols:
            v = v - jnp.dot(q, v) * q
        cols.append(v / (jnp.linalg.norm(v) + 1e-12))
    return jnp.stack(cols, axis=1)


def topk_left_singular(g: jax.Array, r: int, seed) -> jax.Array:
    """Approximate top-r left singular vectors of g [n, m] by randomized
    subspace iteration: Q ← orth((G Gᵀ)^q G Ω)."""
    key = jax.random.PRNGKey(jnp.asarray(seed, jnp.uint32))
    omega = jax.random.normal(key, (g.shape[1], r), g.dtype)
    y = g @ omega  # [n, r]
    q = _orthonormalize(y)

    def body(_, q):
        return _orthonormalize(g @ (g.T @ q))

    return jax.lax.fori_loop(0, _POWER_ITERS, body, q)


class GaLore:
    """GaLore method state over a flat param dict.

    State per projectable W [n, m]:
        proj/W : P [n, r]      (stored projection — GaLore's overhead)
        m/W, v/W : [r, m]      (Adam moments in the projected space)
    Non-projectable params get full-size Adam moments.
    """

    name = "galore"

    def __init__(
        self,
        param_shapes: dict,
        rank: int,
        b1: float = 0.9,
        b2: float = 0.999,
        eps: float = 1e-8,
        galore_scale: float = 0.25,
    ):
        self.param_shapes = dict(sorted(param_shapes.items()))
        self.rank = rank
        self.b1, self.b2, self.eps = b1, b2, eps
        # GaLore's alpha: down-weights the projected update (their paper's
        # default 0.25 for pre-training).
        self.scale = galore_scale
        self.projected = [
            k
            for k in self.param_shapes
            if layers.is_projectable(k, len(self.param_shapes[k]))
        ]

    def state_shapes(self) -> dict:
        out = {}
        for k, s in self.param_shapes.items():
            if k in self.projected:
                n, m = s
                out[f"proj/{k}"] = (n, self.rank)
                out[f"m/{k}"] = (self.rank, m)
                out[f"v/{k}"] = (self.rank, m)
            else:
                out[f"m/{k}"] = tuple(s)
                out[f"v/{k}"] = tuple(s)
        return out

    def init_state(self) -> State:
        return {
            k: jnp.zeros(s, jnp.float32) for k, s in self.state_shapes().items()
        }

    def step(self, params, grads, state, lr, step, seed, refresh):
        """One GaLore training step.

        refresh: f32 scalar ∈ {0.0, 1.0}; when 1.0 the projection P is
        recomputed from the current gradient (subspace iteration), when 0.0
        the stored P is reused. The rust coordinator raises the flag every
        κ steps (including step 0, when P is still zero).
        """
        new_p, new_s = {}, {}
        t = jnp.asarray(step, jnp.float32) + 1.0
        for k in self.param_shapes:
            g = grads[k]
            if k in self.projected:
                p_old = state[f"proj/{k}"]
                p_new = topk_left_singular(g, self.rank, seed)
                p = refresh * p_new + (1.0 - refresh) * p_old
                g_low = p.T @ g  # [r, m]
                m = self.b1 * state[f"m/{k}"] + (1 - self.b1) * g_low
                v = self.b2 * state[f"v/{k}"] + (1 - self.b2) * jnp.square(
                    g_low
                )
                mhat = m / (1 - self.b1**t)
                vhat = v / (1 - self.b2**t)
                upd = p @ (mhat / (jnp.sqrt(vhat) + self.eps))
                new_p[k] = params[k] - lr * self.scale * upd
                new_s[f"proj/{k}"] = p
                new_s[f"m/{k}"] = m
                new_s[f"v/{k}"] = v
            else:
                m = self.b1 * state[f"m/{k}"] + (1 - self.b1) * g
                v = self.b2 * state[f"v/{k}"] + (1 - self.b2) * jnp.square(g)
                mhat = m / (1 - self.b1**t)
                vhat = v / (1 - self.b2**t)
                new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + self.eps)
                new_s[f"m/{k}"] = m
                new_s[f"v/{k}"] = v
        return new_p, new_s
