"""L2 — transformer building blocks, written directly in jnp.

Parameters are plain ``dict[str, jax.Array]`` with ``/``-separated names.
The AOT boundary flattens them in sorted-name order (see ``aot.py``), which
is what the rust runtime's manifest records — so naming is part of the ABI.

The 2-D weights of attention and feed-forward blocks are the ones FLORA /
LoRA / GaLore act on (paper §3.1: "we apply the projections to attention and
feed-forward layers only, while following the naive procedure for other
layers"); :func:`is_projectable` encodes that rule in one place.
"""

from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

Params = dict  # name -> jax.Array

# Substrings marking the weights the paper compresses. ln/bias/embedding are
# handled "naively" (full-size state) by every method.
_PROJECTABLE_MARKERS = ("attn/", "ffn/")


def is_projectable(name: str, arr_ndim: int) -> bool:
    """True if this parameter gets the random-projection treatment."""
    return arr_ndim == 2 and any(m in name for m in _PROJECTABLE_MARKERS)


# ---------------------------------------------------------------------------
# Initializers (used inside the AOT ``init`` executable, seeded)
# ---------------------------------------------------------------------------


def _dense_init(key, n_in: int, n_out: int) -> jax.Array:
    """LeCun-normal, the T5/ViT default for kernel matrices."""
    scale = 1.0 / math.sqrt(n_in)
    return jax.random.normal(key, (n_in, n_out), jnp.float32) * scale


def _embed_init(key, vocab: int, dim: int) -> jax.Array:
    return jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02


# ---------------------------------------------------------------------------
# Transformer decoder stack (pre-norm, learned positions, tied LM head)
# ---------------------------------------------------------------------------


class LMConfig:
    """Decoder-only prefix-LM configuration.

    The paper's T5/GPT-2 workloads are both mapped onto this architecture
    (GPT-2 *is* this; T5's seq2seq task is expressed as a prefix LM — see
    DESIGN.md §4). ``param_count`` is used by the memory accountant and must
    agree with the actual init (asserted in tests).
    """

    def __init__(
        self,
        vocab: int = 256,
        d_model: int = 64,
        n_layers: int = 2,
        n_heads: int = 4,
        d_ff: int = 256,
        seq_len: int = 64,
        name: str = "lm",
    ):
        assert d_model % n_heads == 0
        self.vocab = vocab
        self.d_model = d_model
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.d_ff = d_ff
        self.seq_len = seq_len
        self.name = name

    def param_shapes(self) -> dict:
        """name -> shape, in the exact set produced by :func:`init_lm`."""
        d, f = self.d_model, self.d_ff
        shapes = {
            "embed/tok": (self.vocab, d),
            "embed/pos": (self.seq_len, d),
            "final_ln/scale": (d,),
        }
        for l in range(self.n_layers):
            p = f"layer{l}"
            shapes[f"{p}/attn/wq"] = (d, d)
            shapes[f"{p}/attn/wk"] = (d, d)
            shapes[f"{p}/attn/wv"] = (d, d)
            shapes[f"{p}/attn/wo"] = (d, d)
            shapes[f"{p}/ffn/w1"] = (d, f)
            shapes[f"{p}/ffn/w2"] = (f, d)
            shapes[f"{p}/ln1/scale"] = (d,)
            shapes[f"{p}/ln2/scale"] = (d,)
        return shapes

    def param_count(self) -> int:
        return sum(
            int(jnp.prod(jnp.asarray(s))) for s in self.param_shapes().values()
        )

    def to_json_dict(self) -> dict:
        return {
            "kind": "lm",
            "vocab": self.vocab,
            "d_model": self.d_model,
            "n_layers": self.n_layers,
            "n_heads": self.n_heads,
            "d_ff": self.d_ff,
            "seq_len": self.seq_len,
            "name": self.name,
        }


def init_lm(cfg: LMConfig, seed) -> Params:
    """Initialize all LM parameters from a scalar u32 seed (runs inside the
    AOT ``init`` executable — rust never constructs weights)."""
    key = jax.random.PRNGKey(jnp.asarray(seed, jnp.uint32))
    shapes = cfg.param_shapes()
    keys = jax.random.split(key, len(shapes))
    params: Params = {}
    for (name, shape), k in zip(sorted(shapes.items()), keys):
        if name.endswith("/scale"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name == "embed/tok" or name == "embed/pos":
            params[name] = _embed_init(k, shape[0], shape[1])
        else:
            params[name] = _dense_init(k, shape[0], shape[1])
    return params


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def causal_attention(
    params: Params, prefix: str, x: jax.Array, cfg: LMConfig
) -> jax.Array:
    """Multi-head causal self-attention. x: [B, S, d]."""
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h

    def split(name):
        w = params[f"{prefix}/attn/{name}"]
        return (x @ w).reshape(b, s, h, dh).transpose(0, 2, 1, 3)

    q, k, v = split("wq"), split("wk"), split("wv")
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(dh)
    causal = jnp.tril(jnp.ones((s, s), jnp.float32))
    scores = jnp.where(causal[None, None] > 0, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, d)
    return ctx @ params[f"{prefix}/attn/wo"]


def ffn(params: Params, prefix: str, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ params[f"{prefix}/ffn/w1"])
    return h @ params[f"{prefix}/ffn/w2"]


def lm_forward(params: Params, tokens: jax.Array, cfg: LMConfig) -> jax.Array:
    """tokens [B, S] i32 -> logits [B, S, V]. Pre-norm blocks, tied head."""
    b, s = tokens.shape
    x = params["embed/tok"][tokens] + params["embed/pos"][None, :s]
    for l in range(cfg.n_layers):
        p = f"layer{l}"
        x = x + causal_attention(
            params, p, rms_norm(x, params[f"{p}/ln1/scale"]), cfg
        )
        x = x + ffn(params, p, rms_norm(x, params[f"{p}/ln2/scale"]))
    x = rms_norm(x, params["final_ln/scale"])
    return x @ params["embed/tok"].T


def lm_loss(
    params: Params, tokens: jax.Array, mask: jax.Array, cfg: LMConfig
) -> jax.Array:
    """Masked next-token cross-entropy.

    tokens: [B, S] i32; mask: [B, S] f32, 1.0 on positions whose *prediction*
    counts (prefix-LM: the target segment). Loss at position i predicts
    token i+1, so logits/mask are shifted accordingly.
    """
    logits = lm_forward(params, tokens, cfg)  # [B, S, V]
    logits = logits[:, :-1]
    targets = tokens[:, 1:]
    m = mask[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(m), 1.0)
    return jnp.sum(nll * m) / denom


def lm_greedy_decode(
    params: Params, tokens: jax.Array, prompt_len: jax.Array, cfg: LMConfig
) -> jax.Array:
    """Greedy autoregressive decode, entirely inside XLA.

    tokens: [B, S] i32, positions >= prompt_len are ignored/overwritten.
    prompt_len: scalar i32 (same prompt length across the batch — the rust
    batcher pads prompts to a common length per batch).
    Recomputes the full forward per position (no KV cache); S is small in
    every artifact config, and this keeps the executable stateless.
    """
    s = tokens.shape[1]

    def body(i, toks):
        logits = lm_forward(params, toks, cfg)  # [B, S, V]
        nxt = jnp.argmax(logits[:, i - 1], axis=-1).astype(toks.dtype)
        keep = i < prompt_len  # don't overwrite prompt positions
        cur = toks[:, i]
        val = jnp.where(keep, cur, nxt)
        return toks.at[:, i].set(val)

    return jax.lax.fori_loop(1, s, body, tokens)
