"""AOT driver: lower every (model × method × optimizer) step function to HLO
TEXT and write artifacts/manifest.json describing the flat ABI.

This is the ONLY entry point where python runs; after ``make artifacts`` the
rust binary is self-contained. Interchange is HLO **text**, not
``.serialize()`` protos — jax ≥ 0.5 emits 64-bit instruction ids that the
xla crate's XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids
(see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--only PREFIX] [--list]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_registry
from . import optimizers, steps

# Ranks per model width: chosen as the same *fractions* of d_model the paper
# sweeps (8..256 of 512 ≈ 1/64..1/2). lm-small has d=64 -> 4..32.
BENCH_RANKS = [4, 8, 16, 32]
BETA = 0.9  # momentum decay, paper's EMA example
BATCH = 4  # physical batch for bench/test configs (paper Table 2 uses 4)


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _arg_structs(in_specs):
    out = []
    for _, shape, dtype in in_specs:
        out.append(jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype)))
    return out


class Catalog:
    """Collects executables to lower, then emits files + manifest."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries = {}  # name -> (fn, in_specs, out_names, model_name)
        self.models = {}

    def add_model(self, cfg):
        self.models[cfg.name] = cfg.to_json_dict()

    def add(self, name: str, built, model_name: str):
        fn, in_specs, out_names = built
        assert name not in self.entries, f"duplicate executable {name}"
        self.entries[name] = (fn, in_specs, out_names, model_name)

    def emit(self, only: str | None = None, list_only: bool = False) -> None:
        os.makedirs(self.out_dir, exist_ok=True)
        manifest = {"version": 1, "models": self.models, "executables": {}}
        t_total = time.time()
        for name in sorted(self.entries):
            fn, in_specs, out_names, model_name = self.entries[name]
            fname = name.replace("/", "__") + ".hlo.txt"
            if list_only:
                print(name)
                continue
            selected = only is None or name.startswith(only)
            path = os.path.join(self.out_dir, fname)
            args = _arg_structs(in_specs)
            # output shapes from abstract eval (cheap; also validates fn)
            out_shapes = jax.eval_shape(fn, *args)
            assert len(out_shapes) == len(out_names), (
                f"{name}: {len(out_shapes)} outputs vs {len(out_names)} names"
            )
            if selected:
                t0 = time.time()
                # keep_unused=True: the manifest ABI promises EVERY declared
                # input is a real parameter — without it XLA drops args the
                # graph doesn't read (e.g. the seed trio in naive-momentum
                # steps, frozen base weights in lora init) and the rust-side
                # buffer count no longer matches.
                text = to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args))
                with open(path, "w") as f:
                    f.write(text)
                print(
                    f"[aot] {name}: {len(text) / 1024:.0f} KiB "
                    f"({time.time() - t0:.1f}s)",
                    flush=True,
                )
            manifest["executables"][name] = {
                "file": fname,
                "model": model_name,
                "inputs": [
                    {"name": n, "shape": list(s), "dtype": d}
                    for (n, s, d) in in_specs
                ],
                "outputs": [
                    {
                        "name": n,
                        "shape": [int(x) for x in o.shape],
                        "dtype": str(o.dtype),
                    }
                    for n, o in zip(out_names, out_shapes)
                ],
            }
        if not list_only:
            with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1, sort_keys=True)
            print(
                f"[aot] wrote {len(manifest['executables'])} executables in "
                f"{time.time() - t_total:.0f}s -> {self.out_dir}/manifest.json"
            )


def _add_lm_bundle(cat, cfg, ranks, *, lora=True, momentum=True, galore=False,
                   nofactor=False):
    """The full executable family for one LM config."""
    m = cfg.name
    adafactor = optimizers.make_optimizer("adafactor")
    cat.add_model(cfg)
    cat.add(f"{m}/init", steps.build_lm_init(cfg), m)
    cat.add(f"{m}/eval", steps.build_lm_eval(cfg, BATCH), m)
    cat.add(f"{m}/greedy", steps.build_lm_greedy(cfg, BATCH), m)
    # -- accumulation (Algorithm 1) --
    cat.add(f"{m}/micro_naive", steps.build_lm_micro(cfg, "naive", 0, BATCH), m)
    cat.add(
        f"{m}/update_naive_adafactor",
        steps.build_lm_update(cfg, "naive", 0, adafactor),
        m,
    )
    cat.add(
        f"{m}/plain_step_adafactor",
        steps.build_lm_plain_step(cfg, adafactor, BATCH),
        m,
    )
    for r in ranks:
        cat.add(
            f"{m}/micro_flora_r{r}",
            steps.build_lm_micro(cfg, "flora", r, BATCH),
            m,
        )
        cat.add(
            f"{m}/update_flora_r{r}_adafactor",
            steps.build_lm_update(cfg, "flora", r, adafactor),
            m,
        )
    # -- momentum (Algorithm 2) --
    if momentum:
        cat.add(
            f"{m}/mom_step_naive_adafactor",
            steps.build_lm_momentum_step(cfg, "naive", 0, BETA, adafactor, BATCH),
            m,
        )
        for r in ranks:
            cat.add(
                f"{m}/mom_step_flora_r{r}_adafactor",
                steps.build_lm_momentum_step(
                    cfg, "flora", r, BETA, adafactor, BATCH
                ),
                m,
            )
        # ablation of Algorithm 2's subspace transfer (one rank suffices)
        r_ab = ranks[len(ranks) // 2]
        cat.add(
            f"{m}/mom_step_flora_notransfer_r{r_ab}_adafactor",
            steps.build_lm_momentum_step(
                cfg, "flora_notransfer", r_ab, BETA, adafactor, BATCH
            ),
            m,
        )
    # -- Table 4: linear-memory base optimizer (unfactored Adafactor) --
    if nofactor:
        nof = optimizers.make_optimizer("adafactor_nofactor")
        cat.add(
            f"{m}/update_naive_adafactor_nofactor",
            steps.build_lm_update(cfg, "naive", 0, nof),
            m,
        )
        cat.add(
            f"{m}/plain_step_adafactor_nofactor",
            steps.build_lm_plain_step(cfg, nof, BATCH),
            m,
        )
        for r in ranks:
            cat.add(
                f"{m}/update_flora_r{r}_adafactor_nofactor",
                steps.build_lm_update(cfg, "flora", r, nof),
                m,
            )
    # -- LoRA baseline --
    if lora:
        for r in ranks:
            cat.add(f"{m}/lora_r{r}_init", steps.build_lora_init(cfg, r), m)
            cat.add(
                f"{m}/lora_r{r}_micro", steps.build_lora_micro(cfg, r, BATCH), m
            )
            cat.add(
                f"{m}/lora_r{r}_update_adafactor",
                steps.build_lora_update(cfg, r, adafactor),
                m,
            )
            cat.add(
                f"{m}/lora_r{r}_eval", steps.build_lora_eval(cfg, r, BATCH), m
            )
            cat.add(
                f"{m}/lora_r{r}_greedy",
                steps.build_lora_greedy(cfg, r, BATCH),
                m,
            )
            if momentum:
                cat.add(
                    f"{m}/lora_r{r}_mom_step_adafactor",
                    steps.build_lora_momentum_step(
                        cfg, r, BETA, adafactor, BATCH
                    ),
                    m,
                )
            if nofactor:
                nof = optimizers.make_optimizer("adafactor_nofactor")
                cat.add(
                    f"{m}/lora_r{r}_update_adafactor_nofactor",
                    steps.build_lora_update(cfg, r, nof),
                    m,
                )
    # -- GaLore comparison (Table 6) --
    if galore:
        galore_rank = ranks[-2] if len(ranks) >= 2 else ranks[-1]
        for r in (galore_rank,):  # single rank, as in the paper's per-size rows
            cat.add(
                f"{m}/galore_step_r{r}", steps.build_galore_step(cfg, r, BATCH), m
            )


def _add_vit_bundle(cat, cfg, rank: int):
    m = cfg.name
    adam = optimizers.make_optimizer("adam")
    adafactor = optimizers.make_optimizer("adafactor")
    cat.add_model(cfg)
    cat.add(f"{m}/init", steps.build_vit_init(cfg), m)
    cat.add(f"{m}/eval", steps.build_vit_eval(cfg, BATCH), m)
    cat.add(
        f"{m}/step_adam",
        steps.build_vit_step(cfg, "none", 0, BETA, adam, BATCH),
        m,
    )
    cat.add(
        f"{m}/step_flora_r{rank}_adafactor",
        steps.build_vit_step(cfg, "flora", rank, BETA, adafactor, BATCH),
        m,
    )


def build_catalog(out_dir: str) -> Catalog:
    cat = Catalog(out_dir)
    lms = model_registry.lm_configs()
    vits = model_registry.vit_configs()
    # tiny: rust integration tests + pytest numerics; full method family at r=4
    _add_lm_bundle(
        cat, lms["lm-tiny"], ranks=[4], lora=True, momentum=True,
        galore=True, nofactor=True,
    )
    # bench model behind Tables 1-4 and 6
    _add_lm_bundle(
        cat, lms["lm-small"], ranks=BENCH_RANKS, lora=True, momentum=True,
        galore=True, nofactor=True,
    )
    # end-to-end example model (examples/train_lm.rs): flora-only bundle
    _add_lm_bundle(
        cat, lms["lm-base"], ranks=[16], lora=False, momentum=True,
        galore=False, nofactor=False,
    )
    _add_vit_bundle(cat, vits["vit-tiny"], rank=4)
    _add_vit_bundle(cat, vits["vit-cifar"], rank=16)
    return cat


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower only names with prefix")
    ap.add_argument("--list", action="store_true", help="list catalog and exit")
    args = ap.parse_args()
    cat = build_catalog(args.out_dir)
    cat.emit(only=args.only, list_only=args.list)


if __name__ == "__main__":
    main()
