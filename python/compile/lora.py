"""L2 — LoRA baseline (Hu et al., 2022), as the paper runs it in §3.

For every projectable weight W ∈ R^{n×m} we add trainable B ∈ R^{n×r}
(zero-init) and A ∈ R^{r×m} (Gaussian-init); the forward uses W + BA and
only {A, B} (plus the naively-handled vectors/embeddings) receive gradients
and optimizer state. W itself is frozen — exactly the setting Tables 1–4
compare against.

The gradient of the patched forward w.r.t. A and B is taken by autodiff on
the materialized W + BA (the paper's Eq. 3–4 note the same Jacobian path —
and this is precisely why LoRA does *not* save back-prop memory, §2.4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers

Params = dict


class LoraAdapter:
    """Bookkeeping for the LoRA parameterization of a base model."""

    def __init__(self, param_shapes: dict, rank: int, alpha: float | None = None):
        self.param_shapes = dict(sorted(param_shapes.items()))
        self.rank = rank
        # Standard LoRA scaling alpha/r; alpha defaults to r (scale 1), which
        # is what the paper's dynamics analysis (Thm 2.1) assumes.
        self.alpha = float(alpha if alpha is not None else rank)
        self.projected = [
            k
            for k in self.param_shapes
            if layers.is_projectable(k, len(self.param_shapes[k]))
        ]
        # Vectors / embeddings stay trainable ("naive procedure", §3.1).
        self.passthrough = [
            k for k in self.param_shapes if k not in self.projected
        ]

    @property
    def scale(self) -> float:
        return self.alpha / self.rank

    def trainable_shapes(self) -> dict:
        """Shapes of the LoRA-trainable parameter set."""
        out = {}
        for k in self.projected:
            n, m = self.param_shapes[k]
            out[f"lora_B/{k}"] = (n, self.rank)
            out[f"lora_A/{k}"] = (self.rank, m)
        for k in self.passthrough:
            out[k] = tuple(self.param_shapes[k])
        return out

    def init_trainable(self, base_params: Params, seed) -> Params:
        """B = 0, A ~ N(0, 1/r); passthrough params start at the base value
        (they continue training from the checkpoint)."""
        key = jax.random.PRNGKey(jnp.asarray(seed, jnp.uint32))
        out: Params = {}
        keys = jax.random.split(key, max(len(self.projected), 1))
        for k, kk in zip(self.projected, keys):
            n, m = self.param_shapes[k]
            out[f"lora_B/{k}"] = jnp.zeros((n, self.rank), jnp.float32)
            out[f"lora_A/{k}"] = jax.random.normal(
                kk, (self.rank, m), jnp.float32
            ) / jnp.sqrt(jnp.asarray(self.rank, jnp.float32))
        for k in self.passthrough:
            out[k] = base_params[k]
        return out

    def merge(self, base_params: Params, trainable: Params) -> Params:
        """Effective full parameter set: W + (alpha/r) B A on projected
        weights, trainable values on passthrough ones."""
        eff = {}
        for k in self.param_shapes:
            if k in self.projected:
                b = trainable[f"lora_B/{k}"]
                a = trainable[f"lora_A/{k}"]
                eff[k] = base_params[k] + self.scale * (b @ a)
            else:
                eff[k] = trainable[k]
        return eff

    def extra_param_count(self) -> int:
        """Number of additional scalars LoRA introduces (the memory
        accountant's Δ for LoRA: patches + their optimizer state live on
        top of the frozen model)."""
        total = 0
        for k in self.projected:
            n, m = self.param_shapes[k]
            total += self.rank * (n + m)
        return total
