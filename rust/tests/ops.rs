//! Ops-hardening sweep (PR 8): the versioned bench contract against the
//! COMMITTED trajectory files and a gallery of corrupt fixtures, the
//! config-validation error matrix (exact messages — these are the ops
//! API), checkpoint corruption robustness (truncation and bit flips
//! must fail loudly with path-bearing errors on both the resume and the
//! serving hot-load paths), and `flora doctor` end-to-end.
//!
//! Registered explicitly in Cargo.toml (`autotests = false`).

use std::collections::BTreeMap;
use std::collections::HashSet;
use std::path::PathBuf;

use flora::bench::contract::{self, BenchFile, ContractError};
use flora::config::{DpConfig, ServeConfig, TaskKind, TrainConfig};
use flora::coordinator::{AccumSeeds, MethodSpec, MomentumSeeds, Trainer};
use flora::doctor::{self, DoctorConfig};
use flora::opt::OptimizerKind;
use flora::runtime::AdapterRegistry;
use flora::tensor::Parallelism;
use flora::util::json::{self, Json};

/// Path of a committed repo artifact, independent of the test cwd.
fn repo_path(name: &str) -> String {
    format!("{}/{}", env!("CARGO_MANIFEST_DIR"), name)
}

/// Fresh scratch directory per test (tests share one process).
fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("flora-ops-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ---------------------------------------------------------------------------
// satellite 1 — bench contract: committed files + negative fixtures
// ---------------------------------------------------------------------------

/// Every committed trajectory must satisfy the contract through the
/// exact code path CI and `flora doctor` use, and carry the bench name
/// the binaries will demand on the next append.
#[test]
fn committed_bench_files_satisfy_the_contract() {
    for (file, bench) in contract::COMMITTED_FILES {
        let path = repo_path(file);
        let f = BenchFile::load(&path).unwrap_or_else(|e| panic!("{e}"));
        assert_eq!(f.bench, bench, "{file}: bench name");
        assert_eq!(f.schema, Some(contract::SCHEMA_VERSION), "{file}: schema");
        assert!(!f.trajectory.is_empty(), "{file}: empty trajectory");
        for (i, snap) in f.trajectory.iter().enumerate() {
            assert!(
                snap.provenance.as_deref().is_some_and(|p| !p.is_empty()),
                "{file}: trajectory[{i}] has no provenance"
            );
            assert!(!snap.sizes.is_empty(), "{file}: trajectory[{i}] has no sizes");
        }
    }
}

/// The dp seed stamps `"final_loss": null` (= unmeasured). The typed
/// reader must map it to `None` rather than reject or zero it.
#[test]
fn null_metrics_read_as_unmeasured_not_errors() {
    let f = BenchFile::load(&repo_path("BENCH_dp.json")).unwrap();
    let has_null = f
        .trajectory
        .iter()
        .flat_map(|s| &s.sizes)
        .any(|row| row.metrics.get("final_loss") == Some(&None));
    assert!(has_null, "BENCH_dp.json lost its null final_loss sentinel");
}

fn fixture(schema: &str, snaps: &str) -> String {
    format!(
        r#"{{"bench": "micro_kernels", "schema": {schema}, "comment": "t",
            "trajectory": [{snaps}]}}"#
    )
}

const SNAP_OK: &str = r#"{"pr": 9, "provenance": "cargo-bench t",
    "sizes": [{"model": "m", "tok_s": 1.0}]}"#;

/// Each corruption class produces its own variant AND its own message —
/// asserted pairwise-distinct so a CI log always names the real fault.
#[test]
fn corrupt_fixtures_fail_with_distinct_diagnoses() {
    let mut messages: Vec<String> = Vec::new();
    let mut check = |err: ContractError, variant: &str, needle: &str| {
        let msg = err.to_string();
        assert!(msg.contains(needle), "{variant}: {msg:?} lacks {needle:?}");
        assert!(msg.contains("f.json"), "{variant}: {msg:?} lacks the path");
        messages.push(msg);
    };

    // truncated JSON (the way a killed bench or a bad merge corrupts it)
    let text = fixture("2", SNAP_OK);
    let err = BenchFile::parse("f.json", &text[..text.len() / 2]).unwrap_err();
    assert!(matches!(err, ContractError::Parse { .. }), "{err}");
    check(err, "truncated", "invalid JSON");

    // future schema version
    let f = BenchFile::parse("f.json", &fixture("3", SNAP_OK)).unwrap();
    let err = f.validate("f.json").unwrap_err();
    assert!(matches!(err, ContractError::UnknownSchema { found: Some(3), .. }), "{err}");
    check(err, "schema 3", "unsupported schema version 3");

    // schema field missing entirely (pre-contract file)
    let text = r#"{"bench": "micro_kernels", "trajectory": []}"#;
    let err = BenchFile::parse("f.json", text).unwrap().validate("f.json").unwrap_err();
    assert!(matches!(err, ContractError::UnknownSchema { found: None, .. }), "{err}");
    check(err, "schema missing", "unsupported schema version none");

    // snapshot with no provenance tag
    let snap = r#"{"pr": 9, "sizes": [{"model": "m", "tok_s": 1.0}]}"#;
    let err = BenchFile::parse("f.json", &fixture("2", snap))
        .unwrap()
        .validate("f.json")
        .unwrap_err();
    assert!(matches!(err, ContractError::MissingProvenance { index: 0, .. }), "{err}");
    check(err, "no provenance", "no provenance tag");

    // pr going backwards (a trajectory is append-only)
    let snaps = format!("{SNAP_OK}, {}", SNAP_OK.replace("\"pr\": 9", "\"pr\": 4"));
    let err = BenchFile::parse("f.json", &fixture("2", &snaps))
        .unwrap()
        .validate("f.json")
        .unwrap_err();
    assert!(
        matches!(err, ContractError::NonMonotonic { field: "pr", index: 1, .. }),
        "{err}"
    );
    check(err, "non-monotonic", "goes backwards");

    // negative metric (all trajectory metrics are magnitudes)
    let snap = SNAP_OK.replace("1.0", "-1.0");
    let err = BenchFile::parse("f.json", &fixture("2", &snap))
        .unwrap()
        .validate("f.json")
        .unwrap_err();
    assert!(
        matches!(
            err,
            ContractError::BadMetric { fault: contract::MetricFault::Negative, .. }
        ),
        "{err}"
    );
    check(err, "negative", "negative");

    // NaN metric — only constructible in memory (JSON text has no NaN;
    // the renderer would launder it to null, which is exactly why the
    // append path validates the typed document first)
    let mut row = BTreeMap::new();
    row.insert("model".to_string(), Json::Str("m".into()));
    row.insert("tok_s".to_string(), Json::Num(f64::NAN));
    let mut snap = BTreeMap::new();
    snap.insert("provenance".to_string(), Json::Str("cargo-bench t".into()));
    snap.insert("sizes".to_string(), Json::Arr(vec![Json::Obj(row)]));
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("micro_kernels".into()));
    root.insert("schema".to_string(), Json::Num(2.0));
    root.insert("trajectory".to_string(), Json::Arr(vec![Json::Obj(snap)]));
    let err = BenchFile::from_json("f.json", &Json::Obj(root))
        .unwrap()
        .validate("f.json")
        .unwrap_err();
    assert!(
        matches!(
            err,
            ContractError::BadMetric { fault: contract::MetricFault::NonFinite, .. }
        ),
        "{err}"
    );
    check(err, "nan", "NaN");

    let distinct: HashSet<&String> = messages.iter().collect();
    assert_eq!(distinct.len(), messages.len(), "duplicate diagnoses: {messages:#?}");
}

/// Round-trip through the shared append path: create, extend, reload —
/// and the reloaded file still passes against the committed contract.
#[test]
fn append_round_trips_through_the_contract() {
    let dir = tmp_dir("append");
    let path = dir.join("BENCH_rt.json");
    let path = path.to_str().unwrap();
    let snap = |pr: u64, tok: f64| {
        json::parse(&format!(
            r#"{{"pr": {pr}, "unix_time": {}, "provenance": "cargo-bench rt",
                 "quick": false, "sizes": [{{"model": "m", "tok_s": {tok}}}]}}"#,
            1700000000 + pr
        ))
        .unwrap()
    };
    contract::append_to_file(path, "rt", "round-trip", snap(1, 10.0)).unwrap();
    contract::append_to_file(path, "rt", "round-trip", snap(2, 11.0)).unwrap();
    let f = BenchFile::load(path).unwrap();
    assert_eq!(f.trajectory.len(), 2);
    assert_eq!(f.trajectory[1].pr, Some(2));
    assert_eq!(f.trajectory[1].sizes[0].metrics["tok_s"], Some(11.0));

    // a regressed pr stamp must be refused before the file is touched
    let before = std::fs::read_to_string(path).unwrap();
    let err = contract::append_to_file(path, "rt", "round-trip", snap(0, 12.0)).unwrap_err();
    assert!(err.contains("goes backwards"), "{err}");
    assert_eq!(std::fs::read_to_string(path).unwrap(), before, "file changed on refusal");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// satellite 2 — config-validation error matrix (exact messages)
// ---------------------------------------------------------------------------

fn dp_cfg(mutate: impl FnOnce(&mut DpConfig)) -> DpConfig {
    let mut cfg = DpConfig::default();
    mutate(&mut cfg);
    cfg
}

/// The rejection messages ARE the ops interface — runbooks and CI logs
/// quote them — so they are pinned with exact equality, not contains().
#[test]
fn config_rejections_carry_exact_actionable_messages() {
    // dp: more workers than shards would idle
    let err = dp_cfg(|c| {
        c.train.workers = 8;
        c.shards = 4;
    })
    .validate()
    .unwrap_err();
    assert_eq!(
        err,
        "workers (8) exceeds shards (4) — extra workers would idle; \
         lower --workers or raise --shards"
    );

    // dp: workers x parallelism overflowing the process pool budget
    let err = dp_cfg(|c| {
        c.train.workers = 16;
        c.train.parallelism = Parallelism::new(8);
        c.shards = 16;
    })
    .validate()
    .unwrap_err();
    assert_eq!(
        err,
        "workers (16) x parallelism (8) = 128 exceeds the pool budget of 64 \
         threads — lower one of them"
    );

    // dp: only Flora gradients have a compressed wire format
    let err = dp_cfg(|c| c.train.method = MethodSpec::Lora { rank: 8 })
        .validate()
        .unwrap_err();
    assert_eq!(
        err,
        "train-dp exchanges Flora-compressed gradients; method Lora { rank: 8 } has no \
         compressed wire format (use --method flora --rank R)"
    );

    // dp: the adaptive-rank compressor grid has no wire format — the
    // rejection names the compressor, its source file and the right tier
    let err = dp_cfg(|c| c.train.method = MethodSpec::AltLora { rank: 8 })
        .validate()
        .unwrap_err();
    assert_eq!(
        err,
        "train-dp exchanges Flora-compressed gradients; compressor altlora is \
         single-process only (rust/src/opt/altlora.rs) — drop --compressor or \
         use `flora train`"
    );
    let err = dp_cfg(|c| c.train.method = MethodSpec::AdaRank { rank: 4 })
        .validate()
        .unwrap_err();
    assert_eq!(
        err,
        "train-dp exchanges Flora-compressed gradients; compressor adarank is \
         single-process only (rust/src/opt/schedule.rs) — drop --compressor or \
         use `flora train`"
    );

    // dp: only the LM corpus is sharded
    let err = dp_cfg(|c| c.train.task = TaskKind::Sum).validate().unwrap_err();
    assert_eq!(
        err,
        "train-dp shards the C4-sim LM corpus; task Sum is not sharded \
         (use the lora-* models / lm task)"
    );

    // serve: a zero batch ceiling would deadlock the batcher
    let err = ServeConfig::from_toml_str("serve.max_batch = 0").unwrap_err();
    assert_eq!(err, "serve.max_batch: must be >= 1");

    // train: multi-worker requests belong to the dp tier
    let cfg = TrainConfig { workers: 2, ..TrainConfig::default() };
    assert_eq!(
        cfg.reject_multi_worker().unwrap_err(),
        "train is the single-process trainer; --workers 2 is the \
         data-parallel tier — use `flora train-dp` (docs/DISTRIBUTED.md)"
    );
    // and one worker stays fine
    TrainConfig::default().reject_multi_worker().unwrap();
}

// ---------------------------------------------------------------------------
// satellite 3 — checkpoint corruption robustness
// ---------------------------------------------------------------------------

fn smoke_cfg(model: &str, method: MethodSpec) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        task: TaskKind::Lm,
        method,
        optimizer: OptimizerKind::Sgd,
        lr: 0.1,
        steps: 2,
        tau: 1,
        kappa: 4,
        batch: 2,
        seed: 0,
        eval_every: 0,
        eval_samples: 4,
        ..TrainConfig::default()
    }
}

/// Truncate a saved checkpoint mid-payload: `resume_from` must fail
/// loudly, and the error must carry both the path and the checksum
/// diagnosis (not a garbled-parse artifact of reading half a file).
#[test]
fn truncated_checkpoint_fails_resume_with_path_and_checksum() {
    let dir = tmp_dir("ckpt-trunc");
    let path = dir.join("train.ckpt");
    let path_s = path.to_str().unwrap();
    let base = smoke_cfg("lm-tiny", MethodSpec::Flora { rank: 4 });
    let mut t1 = Trainer::native(base.clone()).unwrap();
    t1.run().unwrap();
    t1.save_checkpoint(path_s).unwrap();

    let bytes = std::fs::read(&path).unwrap();
    assert!(bytes.len() > 64, "checkpoint suspiciously small");
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();

    let mut t2 = Trainer::native(base).unwrap();
    let err = t2.resume_from(path_s).unwrap_err();
    assert!(err.contains(path_s), "no path in: {err}");
    assert!(err.contains("checksum mismatch"), "no diagnosis in: {err}");
    assert!(err.contains("truncated or corrupted"), "no cause hint in: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Flip ONE bit in the weight payload: the FNV checksum must catch it on
/// the serving hot-load path — silently serving a corrupted adapter is
/// the worst failure mode this tier has.
#[test]
fn bit_flipped_checkpoint_fails_hot_load_with_path() {
    let dir = tmp_dir("ckpt-flip");
    let path = dir.join("adapter.ckpt");
    let path_s = path.to_str().unwrap();
    let mut tr = Trainer::native(smoke_cfg("lora-tiny", MethodSpec::Lora { rank: 4 })).unwrap();
    tr.run().unwrap();
    tr.save_checkpoint(path_s).unwrap();

    // sanity: the pristine file hot-loads at the trained rank
    let mut reg = AdapterRegistry::new(2);
    assert_eq!(reg.load_checkpoint("good", path_s).unwrap(), 4);

    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2; // well past the header, inside weights
    bytes[mid] ^= 0x10;
    std::fs::write(&path, &bytes).unwrap();
    let err = reg.load_checkpoint("bad", path_s).unwrap_err();
    assert!(err.contains(path_s), "no path in: {err}");
    assert!(err.contains("checksum mismatch"), "no diagnosis in: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// A checkpoint saved by a future (or past) format version must be
/// refused with the version spelled out, not misparsed.
#[test]
fn old_format_version_is_refused_on_resume() {
    let dir = tmp_dir("ckpt-ver");
    let path = dir.join("old.ckpt");
    let path_s = path.to_str().unwrap();
    let base = smoke_cfg("lm-tiny", MethodSpec::Flora { rank: 4 });
    let mut t1 = Trainer::native(base.clone()).unwrap();
    t1.run().unwrap();
    t1.save_checkpoint(path_s).unwrap();

    let mut bytes = std::fs::read(&path).unwrap();
    bytes[8..12].copy_from_slice(&1u32.to_le_bytes()); // version 2 -> 1
    std::fs::write(&path, &bytes).unwrap();
    let err = Trainer::native(base).unwrap().resume_from(path_s).unwrap_err();
    assert!(err.contains("format version 1"), "{err}");
    assert!(err.contains(path_s), "no path in: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Resume-after-truncation still works end to end when the file is
/// intact — the robustness guards must not break the happy path.
#[test]
fn intact_checkpoint_still_resumes_and_trains() {
    let dir = tmp_dir("ckpt-ok");
    let path = dir.join("ok.ckpt");
    let path_s = path.to_str().unwrap();
    let base = smoke_cfg("lm-tiny", MethodSpec::Flora { rank: 4 });
    let mut t1 = Trainer::native(base.clone()).unwrap();
    t1.run().unwrap();
    t1.save_checkpoint(path_s).unwrap();

    let mut t2 = Trainer::native(base).unwrap();
    t2.resume_from(path_s).unwrap();
    let mut accum = AccumSeeds::new(0);
    let mut momentum = MomentumSeeds::new(0, 4);
    let loss = t2.train_step(&mut accum, &mut momentum).unwrap();
    assert!(loss.is_finite(), "post-resume step produced {loss}");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// tentpole — `flora doctor` end to end
// ---------------------------------------------------------------------------

/// Healthy checkout: every check passes. Then corrupt ONE committed
/// artifact copy: only the matching contract check flips, the report
/// goes unhealthy, and the receipt names the failing check — the
/// machine-readable promise CI relies on.
#[test]
fn doctor_passes_when_healthy_and_names_the_corrupt_artifact() {
    let dir = tmp_dir("doctor");
    for (file, _) in contract::COMMITTED_FILES {
        std::fs::copy(repo_path(file), dir.join(file)).unwrap();
    }
    std::fs::copy(repo_path("BENCH_BUDGETS.toml"), dir.join("BENCH_BUDGETS.toml")).unwrap();
    let cfg = DoctorConfig {
        quick: true,
        parallelism: Parallelism::new(2),
        bench_dir: dir.to_str().unwrap().to_string(),
    };

    let report = doctor::run(&cfg);
    assert!(report.ok(), "healthy doctor failed: {:?}", report.failed_names());
    assert!(report.checks.len() >= 10, "expected a full check sweep");
    let receipt = report.receipt();
    assert_eq!(receipt.get("ok"), Some(&Json::Bool(true)));

    // truncate one trajectory copy and re-run
    let victim = dir.join("BENCH_dp.json");
    let text = std::fs::read_to_string(&victim).unwrap();
    std::fs::write(&victim, &text[..text.len() / 2]).unwrap();
    let report = doctor::run(&cfg);
    assert!(!report.ok());
    assert_eq!(report.failed_names(), vec!["bench-contract:BENCH_dp.json".to_string()]);
    let receipt = report.receipt();
    assert_eq!(receipt.get("ok"), Some(&Json::Bool(false)));
    let rendered = receipt.render();
    assert!(rendered.contains("bench-contract:BENCH_dp.json"), "{rendered}");
    assert!(rendered.contains("invalid JSON"), "{rendered}");
    // the receipt itself must be valid JSON for the harness to consume
    json::parse(&rendered).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
