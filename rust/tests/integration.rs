//! Integration tests, in two tiers:
//!
//!   * **native** (`native_*`, always run) — the coordinator end-to-end
//!     through the pure-rust `NativeBackend`: Plain and Algorithm-1
//!     accumulation modes, plus momentum resampling, GaLore, generation
//!     metrics, determinism and checkpoint resume. No artifacts, no XLA.
//!   * **artifacts** (require the `xla` feature AND `make artifacts`;
//!     skip cleanly otherwise) — the full L3→L2→L1 stack: PJRT compile,
//!     the manifest ABI, LoRA/ViT paths, and the accountant-vs-ledger
//!     reconciliation.

use flora::config::{TaskKind, TrainConfig};
use flora::coordinator::{MethodSpec, Trainer};
use flora::memory::{self, Dims, OptKind, StateRole};
use flora::runtime::Manifest;

const ARTIFACTS: &str = "artifacts";

fn have_artifacts() -> bool {
    cfg!(feature = "xla")
        && std::path::Path::new(ARTIFACTS).join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!(
                "skipping: needs a --features xla build plus `make artifacts`"
            );
            return;
        }
    };
}

// ---------------------------------------------------------------------
// native backend — always runs
// ---------------------------------------------------------------------

/// lm-tiny on the native catalog: bigram LM, vocab 64, SGD base optimizer.
fn native_cfg(
    method: MethodSpec,
    task: TaskKind,
    tau: usize,
    steps: usize,
) -> TrainConfig {
    TrainConfig {
        model: "lm-tiny".into(),
        task,
        method,
        optimizer: "sgd".into(),
        lr: 0.5,
        steps,
        tau,
        kappa: 4,
        batch: 4,
        seed: 0,
        eval_every: 0,
        eval_samples: 8,
    }
}

#[test]
fn native_plain_mode_trains_end_to_end() {
    let mut tr =
        Trainer::native(native_cfg(MethodSpec::None, TaskKind::Sum, 1, 40))
            .unwrap();
    let report = tr.run().unwrap();
    let early = report.train_losses[0];
    let late = report.final_train_loss();
    assert!(early.is_finite() && late.is_finite());
    // init is near-uniform over vocab 64
    assert!((early - (64f32).ln()).abs() < 0.5, "init loss {early}");
    assert!(late < early, "plain/native did not descend: {early} -> {late}");
    assert!(report.metric.is_some());
}

#[test]
fn native_accumulation_cycle_trains_and_sizes_state() {
    let mut tr = Trainer::native(native_cfg(
        MethodSpec::Flora { rank: 8 },
        TaskKind::Sum,
        4,
        10,
    ))
    .unwrap();
    let report = tr.run().unwrap();
    assert!(
        report.final_train_loss() < report.train_losses[0],
        "accumulation/native did not descend"
    );
    // the whole point: the accumulator is [vocab, r] f32, not [vocab, vocab]
    let method_b = report
        .state_bytes
        .iter()
        .find(|(g, _)| g == "method")
        .map(|(_, b)| *b)
        .unwrap();
    assert_eq!(method_b, 64 * 8 * 4);
    let params_b = report
        .state_bytes
        .iter()
        .find(|(g, _)| g == "params")
        .map(|(_, b)| *b)
        .unwrap();
    assert!(method_b < params_b / 4);
}

#[test]
fn native_momentum_resampling_runs() {
    let mut c = native_cfg(MethodSpec::Flora { rank: 8 }, TaskKind::Mt, 1, 12);
    c.kappa = 3; // several resample + transfer events over the run
    c.lr = 0.3;
    let mut tr = Trainer::native(c).unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_train_loss().is_finite());
    assert!(report.final_train_loss() < report.train_losses[0] + 0.1);
}

#[test]
fn native_naive_and_flora_land_in_same_regime() {
    let run = |method: MethodSpec| {
        let mut tr =
            Trainer::native(native_cfg(method, TaskKind::Sum, 4, 8)).unwrap();
        tr.run().unwrap().final_train_loss()
    };
    let naive = run(MethodSpec::Naive);
    let flora = run(MethodSpec::Flora { rank: 32 });
    let init_loss = (64f32).ln();
    assert!(naive < init_loss, "naive stuck at {naive}");
    assert!(flora < init_loss, "flora stuck at {flora}");
    assert!((naive - flora).abs() < 1.0, "naive={naive} flora={flora}");
}

#[test]
fn native_galore_descends() {
    let mut c = native_cfg(MethodSpec::Galore { rank: 8 }, TaskKind::Lm, 1, 12);
    c.lr = 0.05; // Adam-in-subspace steps are ~unit-scale
    c.kappa = 4;
    let mut tr = Trainer::native(c).unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_train_loss().is_finite());
    assert!(report.final_train_loss() < report.train_losses[0] + 0.1);
}

#[test]
fn native_generation_metric_in_range() {
    let mut tr =
        Trainer::native(native_cfg(MethodSpec::None, TaskKind::Sum, 1, 2))
            .unwrap();
    tr.init().unwrap();
    let m = tr.eval_metric(8).unwrap();
    let q = m.quality();
    assert!((0.0..=300.0).contains(&q), "rouge sum out of range: {q}");
}

#[test]
fn native_deterministic_given_seed() {
    fn run(seed: u64) -> Vec<f32> {
        let mut c = native_cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Sum, 2, 6);
        c.seed = seed;
        let mut tr = Trainer::native(c).unwrap();
        tr.run().unwrap().train_losses
    }
    assert_eq!(run(7), run(7));
    assert_ne!(run(7), run(8));
}

#[test]
fn native_checkpoint_roundtrip_resumes_identically() {
    // train 3 steps, checkpoint, train 2 more; vs resume-from-checkpoint
    // and train the same 2 — losses must match exactly (plain mode uses
    // neither seed schedule, so the schedules need no re-advancing).
    let base = native_cfg(MethodSpec::None, TaskKind::Sum, 1, 3);
    let path = std::env::temp_dir().join("flora_native_ckpt.bin");
    let path_s = path.to_str().unwrap();

    let mut t1 = Trainer::native(base.clone()).unwrap();
    t1.run().unwrap();
    t1.save_checkpoint(path_s).unwrap();
    let mut accum = flora::coordinator::AccumSeeds::new(0);
    let mut mom = flora::coordinator::MomentumSeeds::new(0, base.kappa);
    let cont: Vec<f32> = (0..2)
        .map(|_| t1.train_step(&mut accum, &mut mom).unwrap())
        .collect();

    let mut t2 = Trainer::native(base).unwrap();
    t2.resume_from(path_s).unwrap();
    let mut accum2 = flora::coordinator::AccumSeeds::new(0);
    let mut mom2 = flora::coordinator::MomentumSeeds::new(0, 4);
    let resumed: Vec<f32> = (0..2)
        .map(|_| t2.train_step(&mut accum2, &mut mom2).unwrap())
        .collect();
    assert_eq!(cont, resumed);
    std::fs::remove_file(&path).ok();
}

#[test]
fn native_manifest_covers_lm_models() {
    let m = flora::runtime::native_manifest();
    for model in ["lm-tiny", "lm-small", "lm-base"] {
        assert!(m.models.contains_key(model), "missing model {model}");
        for exe in [
            "init",
            "eval",
            "greedy",
            "plain_step_sgd",
            "micro_flora_r8",
            "update_flora_r8_sgd",
            "mom_step_flora_r8_sgd",
            "mom_step_flora_notransfer_r8_sgd",
            "galore_step_r8",
            "micro_naive",
            "update_naive_sgd",
        ] {
            m.executable(&format!("{model}/{exe}")).unwrap();
        }
    }
}

// ---------------------------------------------------------------------
// artifacts (PJRT) tier — skips without `--features xla` + artifacts
// ---------------------------------------------------------------------

fn cfg(method: MethodSpec, task: TaskKind, tau: usize, steps: usize) -> TrainConfig {
    TrainConfig {
        model: "lm-tiny".into(),
        task,
        method,
        optimizer: "adafactor".into(),
        lr: 0.05,
        steps,
        tau,
        kappa: 5,
        batch: 4,
        seed: 0,
        eval_every: 0,
        eval_samples: 8,
    }
}

#[test]
fn manifest_loads_and_covers_models() {
    require_artifacts!();
    let m = Manifest::load(ARTIFACTS).unwrap();
    for model in ["lm-tiny", "lm-small", "lm-base", "vit-tiny", "vit-cifar"] {
        assert!(m.models.contains_key(model), "missing model {model}");
    }
    // every file the manifest references exists on disk
    for (name, e) in &m.executables {
        assert!(e.file.exists(), "{name}: missing {}", e.file.display());
    }
}

#[test]
fn flora_accumulation_cycle_learns() {
    require_artifacts!();
    let mut tr =
        Trainer::new(cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Sum, 4, 10), ARTIFACTS)
            .unwrap();
    let report = tr.run().unwrap();
    let early = report.train_losses[0];
    let late = report.final_train_loss();
    assert!(late < early, "loss did not decrease: {early} -> {late}");
    assert!(report.metric.is_some());
}

#[test]
fn naive_and_flora_track_each_other_at_high_rank() {
    require_artifacts!();
    // r=4 on d=32 is 1/8th rank; losses won't match naive exactly but must
    // land in the same regime (both well below the init loss ~ log 64)
    let mut naive =
        Trainer::new(cfg(MethodSpec::Naive, TaskKind::Sum, 4, 8), ARTIFACTS).unwrap();
    let rn = naive.run().unwrap();
    let mut fl = Trainer::new(
        cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Sum, 4, 8),
        ARTIFACTS,
    )
    .unwrap();
    let rf = fl.run().unwrap();
    let init_loss = (64f32).ln();
    assert!(rn.final_train_loss() < init_loss);
    assert!(rf.final_train_loss() < init_loss);
    assert!((rn.final_train_loss() - rf.final_train_loss()).abs() < 1.0);
}

#[test]
fn momentum_mode_with_resampling_learns() {
    require_artifacts!();
    // kappa=5 over 12 steps → two resample events actually exercised
    let mut tr = Trainer::new(
        cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Mt, 1, 12),
        ARTIFACTS,
    )
    .unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_train_loss() < report.train_losses[0] + 0.1);
}

#[test]
fn lora_trains_only_patches() {
    require_artifacts!();
    let mut tr = Trainer::new(
        cfg(MethodSpec::Lora { rank: 4 }, TaskKind::Sum, 2, 6),
        ARTIFACTS,
    )
    .unwrap();
    let report = tr.run().unwrap();
    // train group exists and is small relative to params
    let train_b = report
        .state_bytes
        .iter()
        .find(|(g, _)| g == "train")
        .map(|(_, b)| *b)
        .unwrap_or(0);
    let params_b = report
        .state_bytes
        .iter()
        .find(|(g, _)| g == "params")
        .map(|(_, b)| *b)
        .unwrap();
    assert!(train_b > 0, "lora trainable group missing");
    assert!(train_b < params_b, "patches should be smaller than the model");
}

#[test]
fn galore_step_runs_and_descends() {
    require_artifacts!();
    let mut c = cfg(MethodSpec::Galore { rank: 4 }, TaskKind::Lm, 1, 10);
    c.lr = 0.01;
    c.kappa = 5;
    let mut tr = Trainer::new(c, ARTIFACTS).unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_train_loss() < report.train_losses[0]);
    if let Some(m) = report.metric {
        // perplexity must be finite and below vocab-uniform (64)
        assert!(m.quality() > -64.0);
    }
}

#[test]
fn state_bytes_match_analytic_accountant() {
    require_artifacts!();
    // the live ledger's "method" group for flora(4) on lm-tiny must equal
    // the accountant's method_state prediction exactly
    let mut tr = Trainer::new(
        cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Sum, 4, 1),
        ARTIFACTS,
    )
    .unwrap();
    tr.init().unwrap();
    let live = tr.state().group_bytes("method");
    let dims = Dims::lm_tiny();
    let predicted = memory::breakdown(
        &dims,
        memory::Method::Flora(4),
        OptKind::Adafactor,
        StateRole::Accumulation,
        4,
        false,
    )
    .method_state;
    assert_eq!(live, predicted, "live={live} predicted={predicted}");
    // params group must equal params bytes
    let live_params = tr.state().group_bytes("params");
    assert_eq!(live_params, dims.param_count() * memory::F32);
}

#[test]
fn opt_state_bytes_match_accountant_adafactor() {
    require_artifacts!();
    let mut tr =
        Trainer::new(cfg(MethodSpec::Naive, TaskKind::Sum, 4, 1), ARTIFACTS).unwrap();
    tr.init().unwrap();
    let live = tr.state().group_bytes("opt");
    let predicted = memory::breakdown(
        &Dims::lm_tiny(),
        memory::Method::Naive,
        OptKind::Adafactor,
        StateRole::Accumulation,
        4,
        false,
    )
    .opt_state;
    assert_eq!(live, predicted);
}

#[test]
fn generation_metrics_in_range() {
    require_artifacts!();
    let mut tr = Trainer::new(
        cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Sum, 1, 2),
        ARTIFACTS,
    )
    .unwrap();
    tr.init().unwrap();
    let m = tr.eval_metric(8).unwrap();
    let q = m.quality();
    assert!((0.0..=300.0).contains(&q), "rouge sum out of range: {q}");
}

#[test]
fn deterministic_given_seed() {
    require_artifacts!();
    let run = |seed: u64| {
        let mut c = cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Sum, 2, 4);
        c.seed = seed;
        let mut tr = Trainer::new(c, ARTIFACTS).unwrap();
        tr.run().unwrap().train_losses
    };
    assert_eq!(run(7), run(7));
    assert_ne!(run(7), run(8));
}

#[test]
fn vit_adam_and_flora_both_train() {
    require_artifacts!();
    for (method, opt) in [
        (MethodSpec::None, "adam"),
        (MethodSpec::Flora { rank: 4 }, "adafactor"),
    ] {
        let c = TrainConfig {
            model: "vit-tiny".into(),
            task: TaskKind::Vit,
            method,
            optimizer: opt.into(),
            lr: 0.01,
            steps: 6,
            tau: 1,
            kappa: 100,
            batch: 4,
            seed: 0,
            eval_every: 0,
            eval_samples: 16,
        };
        let mut tr = Trainer::new(c, ARTIFACTS).unwrap();
        let report = tr.run().unwrap();
        assert!(
            report.final_train_loss() < report.train_losses[0] + 0.2,
            "{} failed to descend",
            method.label()
        );
    }
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    require_artifacts!();
    // train 3 steps, checkpoint, train 2 more; vs resume-from-checkpoint
    // and train the same 2 — losses must match exactly (determinism incl.
    // data cursor and step counters).
    let base = cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Sum, 1, 3);
    let path = std::env::temp_dir().join("flora_it_ckpt.bin");
    let path_s = path.to_str().unwrap();

    let mut t1 = Trainer::new(base.clone(), ARTIFACTS).unwrap();
    t1.run().unwrap();
    t1.save_checkpoint(path_s).unwrap();
    let mut accum = flora::coordinator::AccumSeeds::new(999);
    let mut mom = flora::coordinator::MomentumSeeds::new(
        flora::util::rng::derive_seed(base.seed, 0xE3A),
        base.kappa,
    );
    // advance the momentum schedule to the checkpoint step
    for _ in 0..t1.steps_done() {
        mom.tick();
    }
    let cont: Vec<f32> = (0..2)
        .map(|_| t1.train_step(&mut accum, &mut mom).unwrap())
        .collect();

    let mut t2 = Trainer::new(base.clone(), ARTIFACTS).unwrap();
    t2.resume_from(path_s).unwrap();
    let mut accum2 = flora::coordinator::AccumSeeds::new(999);
    let mut mom2 = flora::coordinator::MomentumSeeds::new(
        flora::util::rng::derive_seed(base.seed, 0xE3A),
        base.kappa,
    );
    for _ in 0..t2.steps_done() {
        mom2.tick();
    }
    let resumed: Vec<f32> = (0..2)
        .map(|_| t2.train_step(&mut accum2, &mut mom2).unwrap())
        .collect();
    assert_eq!(cont, resumed);
    std::fs::remove_file(&path).ok();
}

#[test]
fn ablation_notransfer_executable_runs() {
    require_artifacts!();
    let mut c = cfg(MethodSpec::FloraNoTransfer { rank: 4 }, TaskKind::Mt, 1, 8);
    c.kappa = 3; // force transfers
    if Trainer::new(c.clone(), ARTIFACTS).is_err() {
        eprintln!("skipping: ablation artifacts not built yet");
        return;
    }
    let mut tr = Trainer::new(c, ARTIFACTS).unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_train_loss().is_finite());
}
