//! Integration tests, in two tiers:
//!
//!   * **native** (`native_*`, always run) — the coordinator end-to-end
//!     through the pure-rust `NativeBackend`: Plain and Algorithm-1
//!     accumulation modes, plus momentum resampling, GaLore, generation
//!     metrics, determinism and checkpoint resume. No artifacts, no XLA.
//!   * **artifacts** (require the `xla` feature AND `make artifacts`;
//!     skip cleanly otherwise) — the full L3→L2→L1 stack: PJRT compile,
//!     the manifest ABI, LoRA/ViT paths, and the accountant-vs-ledger
//!     reconciliation.

use flora::config::{TaskKind, TrainConfig};
use flora::coordinator::{MethodSpec, Trainer};
use flora::memory::{self, Dims, OptKind, StateRole};
use flora::opt::OptimizerKind;
use flora::runtime::{Manifest, StateGroup};

const ARTIFACTS: &str = "artifacts";

fn have_artifacts() -> bool {
    cfg!(feature = "xla")
        && std::path::Path::new(ARTIFACTS).join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!(
                "skipping: needs a --features xla build plus `make artifacts`"
            );
            return;
        }
    };
}

// ---------------------------------------------------------------------
// native backend — always runs
// ---------------------------------------------------------------------

/// lm-tiny on the native catalog: bigram LM, vocab 64, SGD base optimizer
/// (the optimizer×mode matrix test sweeps the other base optimizers).
fn native_cfg(
    method: MethodSpec,
    task: TaskKind,
    tau: usize,
    steps: usize,
) -> TrainConfig {
    TrainConfig {
        model: "lm-tiny".into(),
        task,
        method,
        optimizer: OptimizerKind::Sgd,
        lr: 0.5,
        steps,
        tau,
        kappa: 4,
        batch: 4,
        seed: 0,
        eval_every: 0,
        eval_samples: 8,
        ..Default::default()
    }
}

#[test]
fn native_plain_mode_trains_end_to_end() {
    let mut tr =
        Trainer::native(native_cfg(MethodSpec::None, TaskKind::Sum, 1, 40))
            .unwrap();
    let report = tr.run().unwrap();
    let early = report.train_losses[0];
    let late = report.final_train_loss();
    assert!(early.is_finite() && late.is_finite());
    // init is near-uniform over vocab 64
    assert!((early - (64f32).ln()).abs() < 0.5, "init loss {early}");
    assert!(late < early, "plain/native did not descend: {early} -> {late}");
    assert!(report.metric.is_some());
}

#[test]
fn native_accumulation_cycle_trains_and_sizes_state() {
    let mut tr = Trainer::native(native_cfg(
        MethodSpec::Flora { rank: 8 },
        TaskKind::Sum,
        4,
        10,
    ))
    .unwrap();
    let report = tr.run().unwrap();
    assert!(
        report.final_train_loss() < report.train_losses[0],
        "accumulation/native did not descend"
    );
    // the whole point: the accumulator is [vocab, r] f32, not [vocab, vocab]
    let method_b = report
        .state_bytes
        .iter()
        .find(|(g, _)| g == "method")
        .map(|(_, b)| *b)
        .unwrap();
    assert_eq!(method_b, 64 * 8 * 4);
    let params_b = report
        .state_bytes
        .iter()
        .find(|(g, _)| g == "params")
        .map(|(_, b)| *b)
        .unwrap();
    assert!(method_b < params_b / 4);
}

#[test]
fn native_momentum_resampling_runs() {
    let mut c = native_cfg(MethodSpec::Flora { rank: 8 }, TaskKind::Mt, 1, 12);
    c.kappa = 3; // several resample + transfer events over the run
    c.lr = 0.3;
    let mut tr = Trainer::native(c).unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_train_loss().is_finite());
    assert!(report.final_train_loss() < report.train_losses[0] + 0.1);
}

#[test]
fn native_naive_and_flora_land_in_same_regime() {
    let run = |method: MethodSpec| {
        let mut tr =
            Trainer::native(native_cfg(method, TaskKind::Sum, 4, 8)).unwrap();
        tr.run().unwrap().final_train_loss()
    };
    let naive = run(MethodSpec::Naive);
    let flora = run(MethodSpec::Flora { rank: 32 });
    let init_loss = (64f32).ln();
    assert!(naive < init_loss, "naive stuck at {naive}");
    assert!(flora < init_loss, "flora stuck at {flora}");
    assert!((naive - flora).abs() < 1.0, "naive={naive} flora={flora}");
}

#[test]
fn native_galore_descends() {
    let mut c = native_cfg(MethodSpec::Galore { rank: 8 }, TaskKind::Lm, 1, 12);
    c.lr = 0.05; // Adam-in-subspace steps are ~unit-scale
    c.kappa = 4;
    let mut tr = Trainer::native(c).unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_train_loss().is_finite());
    assert!(report.final_train_loss() < report.train_losses[0] + 0.1);
}

#[test]
fn native_generation_metric_in_range() {
    let mut tr =
        Trainer::native(native_cfg(MethodSpec::None, TaskKind::Sum, 1, 2))
            .unwrap();
    tr.init().unwrap();
    let m = tr.eval_metric(8).unwrap();
    let q = m.quality();
    assert!((0.0..=300.0).contains(&q), "rouge sum out of range: {q}");
}

#[test]
fn native_deterministic_given_seed() {
    fn run(seed: u64) -> Vec<f32> {
        let mut c = native_cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Sum, 2, 6);
        c.seed = seed;
        let mut tr = Trainer::native(c).unwrap();
        tr.run().unwrap().train_losses
    }
    assert_eq!(run(7), run(7));
    assert_ne!(run(7), run(8));
}

#[test]
fn native_checkpoint_roundtrip_resumes_identically() {
    // train 3 steps, checkpoint, train 2 more; vs resume-from-checkpoint
    // and train the same 2 — losses must match exactly (plain mode uses
    // neither seed schedule, so the schedules need no re-advancing).
    let base = native_cfg(MethodSpec::None, TaskKind::Sum, 1, 3);
    let path = std::env::temp_dir().join("flora_native_ckpt.bin");
    let path_s = path.to_str().unwrap();

    let mut t1 = Trainer::native(base.clone()).unwrap();
    t1.run().unwrap();
    t1.save_checkpoint(path_s).unwrap();
    let mut accum = flora::coordinator::AccumSeeds::new(0);
    let mut mom = flora::coordinator::MomentumSeeds::new(0, base.kappa);
    let cont: Vec<f32> = (0..2)
        .map(|_| t1.train_step(&mut accum, &mut mom).unwrap())
        .collect();

    let mut t2 = Trainer::native(base).unwrap();
    t2.resume_from(path_s).unwrap();
    let mut accum2 = flora::coordinator::AccumSeeds::new(0);
    let mut mom2 = flora::coordinator::MomentumSeeds::new(0, 4);
    let resumed: Vec<f32> = (0..2)
        .map(|_| t2.train_step(&mut accum2, &mut mom2).unwrap())
        .collect();
    assert_eq!(cont, resumed);
    std::fs::remove_file(&path).ok();
}

#[test]
fn native_manifest_covers_lm_models() {
    let m = flora::runtime::native_manifest();
    for model in ["lm-tiny", "lm-small", "lm-base"] {
        assert!(m.models.contains_key(model), "missing model {model}");
        for exe in [
            "init",
            "eval",
            "greedy",
            "plain_step_sgd",
            "micro_flora_r8",
            "update_flora_r8_sgd",
            "mom_step_flora_r8_sgd",
            "mom_step_flora_notransfer_r8_sgd",
            "galore_step_r8",
            "micro_naive",
            "update_naive_sgd",
        ] {
            m.executable(&format!("{model}/{exe}")).unwrap();
        }
        // every base optimizer has the full plain/update/momentum surface
        for opt in OptimizerKind::ALL {
            for exe in [
                format!("plain_step_{opt}"),
                format!("update_flora_r8_{opt}"),
                format!("update_naive_{opt}"),
                format!("mom_step_flora_r8_{opt}"),
                format!("mom_step_naive_{opt}"),
            ] {
                m.executable(&format!("{model}/{exe}")).unwrap();
            }
        }
    }
}

/// A learning rate in each base optimizer's stable regime on the bigram
/// table (SGD steps scale with the raw gradient; Adam steps are ~lr per
/// coordinate; Adafactor steps are parameter-scale-relative). Momentum
/// mode feeds the base optimizer the small EMA direction, so Adam and
/// SGD get retuned there.
fn native_lr(opt: OptimizerKind, momentum: bool) -> f32 {
    match (opt, momentum) {
        (OptimizerKind::Sgd, false) => 0.5,
        (OptimizerKind::Sgd, true) => 1.0,
        (OptimizerKind::Adam, false) => 0.05,
        (OptimizerKind::Adam, true) => 0.02,
        (_, false) => 0.2, // adafactor / adafactor_nofactor
        (_, true) => 0.1,
    }
}

use flora::model::testutil::smoothed_drop;

/// The acceptance matrix: every base optimizer trains lm-tiny end-to-end
/// in plain, accumulation (τ>1) and momentum modes on the native backend,
/// deterministically — two identical runs produce bit-identical loss
/// curves that start at the uniform-init loss ln(64) and descend.
/// (Momentum runs at the paper's large-κ regime; the aggressive-κ
/// transfer path is exercised by the bounded-resample test below.)
#[test]
fn native_optimizer_mode_matrix_trains_deterministically() {
    for opt in OptimizerKind::ALL {
        for (mode, method, tau, steps, margin) in [
            ("plain", MethodSpec::None, 1, 30, 0.03f32),
            ("accumulation", MethodSpec::Flora { rank: 8 }, 4, 30, 0.03),
            ("momentum", MethodSpec::Flora { rank: 8 }, 1, 40, 0.02),
        ] {
            let momentum = mode == "momentum";
            let mut c = native_cfg(method, TaskKind::Sum, tau, steps);
            c.optimizer = opt;
            c.lr = native_lr(opt, momentum);
            c.kappa = 1000;
            let run = || {
                let mut tr = Trainer::native(c.clone()).unwrap();
                tr.run().unwrap().train_losses
            };
            let a = run();
            let b = run();
            assert_eq!(a, b, "{opt}/{mode}: nondeterministic losses");
            assert!(
                a.iter().all(|l| l.is_finite()),
                "{opt}/{mode}: non-finite loss in {a:?}"
            );
            let (head, drop) = smoothed_drop(&a, 5);
            assert!(
                (head - (64f32).ln()).abs() < 0.5,
                "{opt}/{mode}: early losses {head} far from ln(64)"
            );
            assert!(
                drop > margin,
                "{opt}/{mode}: no descent (smoothed drop {drop}, want > {margin})"
            );
        }
    }
}

/// Aggressive-κ momentum: every base optimizer survives several subspace
/// resample+transfer events deterministically with bounded loss. (At rank
/// 8/64 each JL transfer perturbs the EMA norm, so short horizons + a
/// bound — not strict descent — is the right contract here; the paper
/// itself runs κ=1000.)
#[test]
fn native_momentum_resampling_every_optimizer_bounded() {
    for opt in OptimizerKind::ALL {
        let mut c = native_cfg(MethodSpec::Flora { rank: 8 }, TaskKind::Mt, 1, 12);
        c.optimizer = opt;
        c.lr = match opt {
            OptimizerKind::Sgd => 0.3,
            OptimizerKind::Adam => 0.02,
            _ => 0.05,
        };
        c.kappa = 4; // resample+transfer at steps 4 and 8
        let run = || {
            let mut tr = Trainer::native(c.clone()).unwrap();
            tr.run().unwrap().train_losses
        };
        let a = run();
        assert_eq!(a, run(), "{opt}: nondeterministic under resampling");
        assert!(a.iter().all(|l| l.is_finite()), "{opt}: non-finite {a:?}");
        let first = a[0];
        let last = *a.last().unwrap();
        assert!(
            last < first + 0.5,
            "{opt}: loss blew up under transfers ({first} -> {last})"
        );
    }
}

/// Checkpoint round-trip over the Adam and Adafactor opt-state groups:
/// save → resume in a fresh trainer → the next steps produce bit-identical
/// losses (the m/v and vr/vc moments must survive the trip exactly).
#[test]
fn native_checkpoint_roundtrip_adam_and_adafactor_opt_state() {
    for opt in [OptimizerKind::Adam, OptimizerKind::Adafactor] {
        let mut base = native_cfg(MethodSpec::None, TaskKind::Sum, 1, 3);
        base.optimizer = opt;
        base.lr = native_lr(opt, false);
        let path = std::env::temp_dir()
            .join(format!("flora_native_ckpt_{opt}.bin"));
        let path_s = path.to_str().unwrap();

        let mut t1 = Trainer::native(base.clone()).unwrap();
        t1.run().unwrap();
        // three steps in: the optimizer moments are non-zero and saved
        assert!(
            t1.state().group_bytes(StateGroup::Opt) > 0,
            "{opt}: no opt state group"
        );
        t1.save_checkpoint(path_s).unwrap();
        let mut accum = flora::coordinator::AccumSeeds::new(0);
        let mut mom = flora::coordinator::MomentumSeeds::new(0, base.kappa);
        let cont: Vec<f32> = (0..2)
            .map(|_| t1.train_step(&mut accum, &mut mom).unwrap())
            .collect();

        let mut t2 = Trainer::native(base).unwrap();
        t2.resume_from(path_s).unwrap();
        assert!(
            t2.state().group_bytes(StateGroup::Opt) > 0,
            "{opt}: opt state missing after resume"
        );
        let mut accum2 = flora::coordinator::AccumSeeds::new(0);
        let mut mom2 = flora::coordinator::MomentumSeeds::new(0, 4);
        let resumed: Vec<f32> = (0..2)
            .map(|_| t2.train_step(&mut accum2, &mut mom2).unwrap())
            .collect();
        assert_eq!(cont, resumed, "{opt}: resumed losses diverge");
        std::fs::remove_file(&path).ok();
    }
}

/// Adafactor's opt group must be sublinear in the parameter count
/// (factored vr/vc vectors), while Adam's is 2x the parameters.
#[test]
fn native_opt_state_footprints_match_the_paper() {
    let sized = |opt: OptimizerKind| {
        let mut c = native_cfg(MethodSpec::None, TaskKind::Sum, 1, 1);
        c.optimizer = opt;
        let mut tr = Trainer::native(c).unwrap();
        tr.init().unwrap();
        (
            tr.state().group_bytes(StateGroup::Opt),
            tr.state().group_bytes(StateGroup::Params),
        )
    };
    let (adam_opt, params) = sized(OptimizerKind::Adam);
    assert_eq!(adam_opt, 2 * params, "adam keeps full m+v");
    let (af_opt, params) = sized(OptimizerKind::Adafactor);
    assert_eq!(af_opt, 2 * 64 * 4, "adafactor keeps vr+vc vectors");
    assert!(af_opt < params / 16, "factored state must be sublinear");
    let (sgd_opt, _) = sized(OptimizerKind::Sgd);
    assert_eq!(sgd_opt, 0, "sgd is stateless");
}

// ---------------------------------------------------------------------
// native transformer tier (lora-tiny / vit-tiny) — always runs
// ---------------------------------------------------------------------

/// lora-tiny on the native catalog: 1-layer causal transformer with
/// manual backward (vocab 64, seq 16, d 32).
fn tf_cfg(
    method: MethodSpec,
    task: TaskKind,
    tau: usize,
    steps: usize,
) -> TrainConfig {
    TrainConfig {
        model: "lora-tiny".into(),
        task,
        method,
        optimizer: OptimizerKind::Sgd,
        lr: 1.0,
        steps,
        tau,
        kappa: 1000,
        batch: 4,
        seed: 0,
        eval_every: 0,
        eval_samples: 8,
        ..Default::default()
    }
}

/// Stable learning rates for the transformer (gradients are much smaller
/// than the bigram table's: activations are RMS-normalized and the tied
/// embeddings start at sigma 0.02).
fn tf_lr(opt: OptimizerKind, momentum: bool) -> f32 {
    match (opt, momentum) {
        (OptimizerKind::Sgd, false) => 0.5,
        (OptimizerKind::Sgd, true) => 1.0,
        (OptimizerKind::Adam, false) => 0.02,
        (OptimizerKind::Adam, true) => 0.01,
        (_, false) => 0.1, // adafactor / adafactor_nofactor
        (_, true) => 0.05,
    }
}

/// The transformer acceptance matrix (ISSUE 3): every base optimizer
/// trains lora-tiny end-to-end in plain, accumulation (τ>1) and momentum
/// modes on the native backend, deterministically — two identical runs
/// produce bit-identical loss curves that start at the uniform-init loss
/// ln(64) and descend.
#[test]
fn native_transformer_optimizer_mode_matrix_trains_deterministically() {
    for opt in OptimizerKind::ALL {
        for (mode, method, tau, steps, margin) in [
            ("plain", MethodSpec::None, 1, 40, 0.02f32),
            ("accumulation", MethodSpec::Flora { rank: 8 }, 4, 30, 0.02),
            ("momentum", MethodSpec::Flora { rank: 8 }, 1, 40, 0.01),
        ] {
            let momentum = mode == "momentum";
            let mut c = tf_cfg(method, TaskKind::Lm, tau, steps);
            c.optimizer = opt;
            c.lr = tf_lr(opt, momentum);
            let run = || {
                let mut tr = Trainer::native(c.clone()).unwrap();
                tr.run().unwrap().train_losses
            };
            let a = run();
            let b = run();
            assert_eq!(a, b, "{opt}/{mode}: nondeterministic losses");
            assert!(
                a.iter().all(|l| l.is_finite()),
                "{opt}/{mode}: non-finite loss in {a:?}"
            );
            let head: f32 = a[..5].iter().sum::<f32>() / 5.0;
            let tail: f32 =
                a[a.len() - 5..].iter().sum::<f32>() / 5.0;
            // the mean of the FIRST FIVE losses sits near the uniform-init
            // loss (fast optimizers already move within those steps, so
            // this is looser than the bigram matrix's bound)
            assert!(
                (head - (64f32).ln()).abs() < 0.8,
                "{opt}/{mode}: early losses {head} far from ln(64)"
            );
            assert!(
                head - tail > margin,
                "{opt}/{mode}: no descent (drop {}, want > {margin})",
                head - tail
            );
        }
    }
}

/// The size grid trains end-to-end natively: `lora-small` and
/// `vit-small` (ISSUE 4 acceptance) plus `lora-base` descend with finite
/// losses through the same catalog surface as the tiny sizes.
#[test]
fn native_size_grid_trains_end_to_end() {
    for (model, vocab, steps, check_descent) in
        [("lora-small", 128usize, 16usize, true), ("lora-base", 256, 6, false)]
    {
        let mut c = tf_cfg(MethodSpec::Flora { rank: 8 }, TaskKind::Lm, 1, steps);
        c.model = model.into();
        c.lr = tf_lr(OptimizerKind::Sgd, true);
        let mut tr = Trainer::native(c).unwrap();
        let losses = tr.run().unwrap().train_losses;
        assert!(
            losses.iter().all(|l| l.is_finite()),
            "{model}: non-finite loss in {losses:?}"
        );
        let vocab_ln = (vocab as f32).ln();
        assert!(
            (losses[0] - vocab_ln).abs() < 0.8,
            "{model}: first loss {} far from ln(vocab) {vocab_ln}",
            losses[0]
        );
        if check_descent {
            let head: f32 = losses[..4].iter().sum::<f32>() / 4.0;
            let tail: f32 =
                losses[losses.len() - 4..].iter().sum::<f32>() / 4.0;
            assert!(tail < head, "{model}: no descent in {losses:?}");
        }
    }
    let c = TrainConfig {
        model: "vit-small".into(),
        task: TaskKind::Vit,
        method: MethodSpec::Flora { rank: 8 },
        optimizer: OptimizerKind::Adafactor,
        lr: 0.05,
        steps: 10,
        tau: 1,
        kappa: 100,
        batch: 4,
        seed: 0,
        eval_every: 0,
        eval_samples: 8,
        ..Default::default()
    };
    let mut tr = Trainer::native(c).unwrap();
    let losses = tr.run().unwrap().train_losses;
    assert!(losses.iter().all(|l| l.is_finite()), "vit-small: {losses:?}");
    assert!(
        *losses.last().unwrap() < losses[0] + 0.05,
        "vit-small diverged: {losses:?}"
    );
}

/// `--parallelism 1` vs `2` (and an oversubscribed 4) must be
/// bit-identical end-to-end: the kernels' row-parallel path never
/// reassociates floating point, so whole training runs — transformer
/// attention included — reproduce exactly. The CI test matrix invokes
/// this test once per FLORA_TEST_PARALLELISM value.
#[test]
fn native_parallelism_determinism_end_to_end() {
    use flora::tensor::Parallelism;
    let threads: usize = std::env::var("FLORA_TEST_PARALLELISM")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    // the budget travels in the config — Trainer installs it, exactly
    // the path `flora train --parallelism N` exercises
    let run = |threads: usize| {
        let mut c =
            tf_cfg(MethodSpec::Flora { rank: 8 }, TaskKind::Lm, 1, 8);
        c.model = "lora-small".into();
        c.parallelism = Parallelism::new(threads);
        let mut tr = Trainer::native(c).unwrap();
        tr.run().unwrap().train_losses
    };
    let serial = run(1);
    let parallel = run(threads);
    assert_eq!(
        serial, parallel,
        "parallelism {threads} changed the loss curve"
    );
}

/// Pool-reuse regression: two full trainer lifecycles in one process
/// must share ONE warm worker pool — the second run spawns no new
/// threads (grow-only resize), both complete without deadlock, and the
/// loss curves are identical (same config, same seed, warm vs cold
/// pool). Guards the PR-5 lifecycle contract of
/// `tensor::Parallelism::install` / `Trainer::with_runtime`.
#[test]
fn native_pool_reused_across_trainer_lifecycles() {
    use flora::tensor::Parallelism;
    let run = || {
        let mut c = tf_cfg(MethodSpec::Flora { rank: 8 }, TaskKind::Lm, 1, 6);
        c.model = "lora-small".into();
        c.parallelism = Parallelism::new(3);
        let mut tr = Trainer::native(c).unwrap();
        tr.run().unwrap().train_losses
    };
    let first = run();
    assert!(
        Parallelism::pool_workers() >= 2,
        "trainer construction should have started the pool \
         (got {} workers)",
        Parallelism::pool_workers()
    );
    for lifecycle in 0..3 {
        let again = run();
        assert_eq!(first, again, "warm-pool lifecycle {lifecycle} diverged");
    }
    // the leak bound: pool growth is capped by the LARGEST budget any
    // test in this binary installs — 4 from the determinism test's
    // default, or FLORA_TEST_PARALLELISM when the CI matrix raises it —
    // minus the calling thread, no matter how many trainer lifecycles
    // ran. A per-lifecycle thread leak would blow past this
    // immediately. (Other tests may run concurrently and legitimately
    // grow the pool within the cap, so the bound — not run-to-run
    // equality — is the invariant.)
    let max_budget = std::env::var("FLORA_TEST_PARALLELISM")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(4)
        .max(4);
    assert!(
        Parallelism::pool_workers() <= max_budget - 1,
        "pool grew past the max-budget cap: {} workers (cap {})",
        Parallelism::pool_workers(),
        max_budget - 1
    );
    // restore the binary's serial default without tearing the pool down
    Parallelism::single().install();
}

/// PR-9 pack-scratch regression: the packed GEMM kernels pack the
/// strided operand's panel into a thread-local, grow-only scratch
/// buffer ([`flora::tensor::pack_scratch_allocs`] counts every
/// grow). Two full trainer lifecycles must REUSE that scratch — after a
/// warm run, an identical run adds (nearly) zero new allocations. A
/// per-call-reallocation regression would add one per band-kernel call,
/// i.e. thousands over even a short run. The counter is process-global
/// across threads, so the bound leaves slack for concurrently running
/// tests warming their own threads' scratches, instead of demanding an
/// exact zero.
#[test]
fn native_pack_scratch_reused_across_trainer_lifecycles() {
    use flora::tensor::{pack_scratch_allocs, Parallelism};
    let run = || {
        let mut c = tf_cfg(MethodSpec::Flora { rank: 8 }, TaskKind::Lm, 1, 4);
        c.model = "lora-small".into();
        c.parallelism = Parallelism::new(3);
        let mut tr = Trainer::native(c).unwrap();
        tr.run().unwrap().train_losses
    };
    let first = run();
    let second = run(); // second pass fully warms every pool thread
    let c0 = pack_scratch_allocs();
    let third = run();
    let grew = pack_scratch_allocs() - c0;
    assert_eq!(first, second, "warm-pool lifecycle diverged");
    assert_eq!(first, third, "third lifecycle diverged");
    assert!(
        grew <= 16,
        "pack scratch grew {grew} times during a warm trainer lifecycle — \
         the reuse contract is broken (per-call allocation?)"
    );
    Parallelism::single().install();
}

/// FLORA accumulation keeps the method state compressed on every
/// projectable (attention/MLP) matrix and full-size on the naive ones —
/// the live ledger must match the model-shape arithmetic exactly.
#[test]
fn native_transformer_accumulation_state_is_compressed() {
    let rank = 8usize;
    let mut tr = Trainer::native(tf_cfg(
        MethodSpec::Flora { rank },
        TaskKind::Lm,
        4,
        6,
    ))
    .unwrap();
    let report = tr.run().unwrap();
    assert!(
        report.final_train_loss() < report.train_losses[0],
        "accumulation did not descend"
    );
    let cfg = flora::model::TransformerConfig::tiny();
    let expected: u64 = cfg
        .param_shapes()
        .iter()
        .map(|(name, sh)| {
            let floats = if flora::model::is_projectable(name) {
                sh[0] * rank
            } else {
                sh[0] * sh[1]
            };
            4 * floats as u64
        })
        .sum();
    let method_b = report
        .state_bytes
        .iter()
        .find(|(g, _)| g == "method")
        .map(|(_, b)| *b)
        .unwrap();
    assert_eq!(method_b, expected);
    let params_b = report
        .state_bytes
        .iter()
        .find(|(g, _)| g == "params")
        .map(|(_, b)| *b)
        .unwrap();
    assert_eq!(params_b, 4 * cfg.param_count() as u64);
    assert!(method_b < params_b, "compressed acc not smaller than params");
}

/// The LoRA baseline runs natively: frozen base + trainable patches, the
/// patch group smaller than the model, loss finite and descending.
#[test]
fn native_transformer_lora_trains_only_patches() {
    let mut c = tf_cfg(MethodSpec::Lora { rank: 4 }, TaskKind::Lm, 1, 30);
    c.optimizer = OptimizerKind::Adafactor;
    c.lr = 0.1;
    let mut tr = Trainer::native(c).unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_train_loss().is_finite());
    assert!(
        report.final_train_loss() < report.train_losses[0],
        "lora did not descend: {} -> {}",
        report.train_losses[0],
        report.final_train_loss()
    );
    let train_b = report
        .state_bytes
        .iter()
        .find(|(g, _)| g == "train")
        .map(|(_, b)| *b)
        .unwrap_or(0);
    let params_b = report
        .state_bytes
        .iter()
        .find(|(g, _)| g == "params")
        .map(|(_, b)| *b)
        .unwrap();
    assert!(train_b > 0, "lora trainable group missing");
    assert!(train_b < params_b, "patches should be smaller than the model");
}

/// Flora momentum mode exercises the per-parameter κ-resample transfers
/// on real attention-shaped gradients without blowing up.
#[test]
fn native_transformer_momentum_resampling_bounded() {
    for opt in [OptimizerKind::Sgd, OptimizerKind::Adafactor] {
        let mut c = tf_cfg(MethodSpec::Flora { rank: 8 }, TaskKind::Mt, 1, 12);
        c.optimizer = opt;
        c.lr = match opt {
            OptimizerKind::Sgd => 0.5,
            _ => 0.05,
        };
        c.kappa = 4; // resample + transfer at steps 4 and 8
        let run = || {
            let mut tr = Trainer::native(c.clone()).unwrap();
            tr.run().unwrap().train_losses
        };
        let a = run();
        assert_eq!(a, run(), "{opt}: nondeterministic under resampling");
        assert!(a.iter().all(|l| l.is_finite()), "{opt}: non-finite {a:?}");
        let first = a[0];
        let last = *a.last().unwrap();
        assert!(
            last < first + 0.5,
            "{opt}: loss blew up under transfers ({first} -> {last})"
        );
    }
}

/// GaLore on the transformer: Adam-in-subspace on projectable matrices,
/// full Adam elsewhere, with κ-interval projection refreshes.
#[test]
fn native_transformer_galore_descends() {
    let mut c = tf_cfg(MethodSpec::Galore { rank: 8 }, TaskKind::Lm, 1, 12);
    c.lr = 0.01;
    c.kappa = 4;
    let mut tr = Trainer::native(c).unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_train_loss().is_finite());
    assert!(report.final_train_loss() < report.train_losses[0] + 0.1);
}

/// Greedy generation metrics run natively on the transformer too.
#[test]
fn native_transformer_generation_metric_in_range() {
    let mut tr =
        Trainer::native(tf_cfg(MethodSpec::None, TaskKind::Sum, 1, 2)).unwrap();
    tr.init().unwrap();
    let m = tr.eval_metric(8).unwrap();
    let q = m.quality();
    assert!((0.0..=300.0).contains(&q), "rouge sum out of range: {q}");
}

/// Checkpoint round-trip through the multi-matrix state groups: resume
/// must reproduce bit-identical losses (params + per-parameter Adam
/// moments all survive).
#[test]
fn native_transformer_checkpoint_roundtrip() {
    let mut base = tf_cfg(MethodSpec::None, TaskKind::Lm, 1, 3);
    base.optimizer = OptimizerKind::Adam;
    base.lr = tf_lr(OptimizerKind::Adam, false);
    let path = std::env::temp_dir().join("flora_native_tf_ckpt.bin");
    let path_s = path.to_str().unwrap();

    let mut t1 = Trainer::native(base.clone()).unwrap();
    t1.run().unwrap();
    t1.save_checkpoint(path_s).unwrap();
    let mut accum = flora::coordinator::AccumSeeds::new(0);
    let mut mom = flora::coordinator::MomentumSeeds::new(0, base.kappa);
    let cont: Vec<f32> = (0..2)
        .map(|_| t1.train_step(&mut accum, &mut mom).unwrap())
        .collect();

    let mut t2 = Trainer::native(base).unwrap();
    t2.resume_from(path_s).unwrap();
    let mut accum2 = flora::coordinator::AccumSeeds::new(0);
    let mut mom2 = flora::coordinator::MomentumSeeds::new(0, 1000);
    let resumed: Vec<f32> = (0..2)
        .map(|_| t2.train_step(&mut accum2, &mut mom2).unwrap())
        .collect();
    assert_eq!(cont, resumed);
    std::fs::remove_file(&path).ok();
}

/// vit-tiny trains natively in both Table-5 configurations (plain Adam
/// and FLORA momentum over Adafactor) and reports a real accuracy.
#[test]
fn native_vit_adam_and_flora_both_train() {
    for (method, opt, lr) in [
        (MethodSpec::None, OptimizerKind::Adam, 0.01f32),
        (MethodSpec::Flora { rank: 8 }, OptimizerKind::Adafactor, 0.05),
    ] {
        let c = TrainConfig {
            model: "vit-tiny".into(),
            task: TaskKind::Vit,
            method,
            optimizer: opt,
            lr,
            steps: 12,
            tau: 1,
            kappa: 100,
            batch: 4,
            seed: 0,
            eval_every: 0,
            eval_samples: 16,
            ..Default::default()
        };
        let run = || {
            let mut tr = Trainer::native(c.clone()).unwrap();
            tr.run().unwrap()
        };
        let report = run();
        assert!(
            report.final_train_loss() < report.train_losses[0] + 0.2,
            "{} failed to descend",
            method.label()
        );
        match report.metric {
            Some(flora::coordinator::MetricValue::Accuracy(acc)) => {
                assert!((0.0..=1.0).contains(&acc), "accuracy {acc}");
            }
            other => panic!("vit metric should be accuracy, got {other:?}"),
        }
        // deterministic end to end
        assert_eq!(report.train_losses, run().train_losses);
    }
}

// ---------------------------------------------------------------------
// artifacts (PJRT) tier — skips without `--features xla` + artifacts
// ---------------------------------------------------------------------

fn cfg(method: MethodSpec, task: TaskKind, tau: usize, steps: usize) -> TrainConfig {
    TrainConfig {
        model: "lm-tiny".into(),
        task,
        method,
        optimizer: OptimizerKind::Adafactor,
        lr: 0.05,
        steps,
        tau,
        kappa: 5,
        batch: 4,
        seed: 0,
        eval_every: 0,
        eval_samples: 8,
        ..Default::default()
    }
}

#[test]
fn manifest_loads_and_covers_models() {
    require_artifacts!();
    let m = Manifest::load(ARTIFACTS).unwrap();
    for model in ["lm-tiny", "lm-small", "lm-base", "vit-tiny", "vit-cifar"] {
        assert!(m.models.contains_key(model), "missing model {model}");
    }
    // every file the manifest references exists on disk
    for (name, e) in &m.executables {
        assert!(e.file.exists(), "{name}: missing {}", e.file.display());
    }
}

#[test]
fn flora_accumulation_cycle_learns() {
    require_artifacts!();
    let mut tr =
        Trainer::new(cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Sum, 4, 10), ARTIFACTS)
            .unwrap();
    let report = tr.run().unwrap();
    let early = report.train_losses[0];
    let late = report.final_train_loss();
    assert!(late < early, "loss did not decrease: {early} -> {late}");
    assert!(report.metric.is_some());
}

#[test]
fn naive_and_flora_track_each_other_at_high_rank() {
    require_artifacts!();
    // r=4 on d=32 is 1/8th rank; losses won't match naive exactly but must
    // land in the same regime (both well below the init loss ~ log 64)
    let mut naive =
        Trainer::new(cfg(MethodSpec::Naive, TaskKind::Sum, 4, 8), ARTIFACTS).unwrap();
    let rn = naive.run().unwrap();
    let mut fl = Trainer::new(
        cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Sum, 4, 8),
        ARTIFACTS,
    )
    .unwrap();
    let rf = fl.run().unwrap();
    let init_loss = (64f32).ln();
    assert!(rn.final_train_loss() < init_loss);
    assert!(rf.final_train_loss() < init_loss);
    assert!((rn.final_train_loss() - rf.final_train_loss()).abs() < 1.0);
}

#[test]
fn momentum_mode_with_resampling_learns() {
    require_artifacts!();
    // kappa=5 over 12 steps → two resample events actually exercised
    let mut tr = Trainer::new(
        cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Mt, 1, 12),
        ARTIFACTS,
    )
    .unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_train_loss() < report.train_losses[0] + 0.1);
}

#[test]
fn lora_trains_only_patches() {
    require_artifacts!();
    let mut tr = Trainer::new(
        cfg(MethodSpec::Lora { rank: 4 }, TaskKind::Sum, 2, 6),
        ARTIFACTS,
    )
    .unwrap();
    let report = tr.run().unwrap();
    // train group exists and is small relative to params
    let train_b = report
        .state_bytes
        .iter()
        .find(|(g, _)| g == "train")
        .map(|(_, b)| *b)
        .unwrap_or(0);
    let params_b = report
        .state_bytes
        .iter()
        .find(|(g, _)| g == "params")
        .map(|(_, b)| *b)
        .unwrap();
    assert!(train_b > 0, "lora trainable group missing");
    assert!(train_b < params_b, "patches should be smaller than the model");
}

#[test]
fn galore_step_runs_and_descends() {
    require_artifacts!();
    let mut c = cfg(MethodSpec::Galore { rank: 4 }, TaskKind::Lm, 1, 10);
    c.lr = 0.01;
    c.kappa = 5;
    let mut tr = Trainer::new(c, ARTIFACTS).unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_train_loss() < report.train_losses[0]);
    if let Some(m) = report.metric {
        // perplexity must be finite and below vocab-uniform (64)
        assert!(m.quality() > -64.0);
    }
}

#[test]
fn state_bytes_match_analytic_accountant() {
    require_artifacts!();
    // the live ledger's "method" group for flora(4) on lm-tiny must equal
    // the accountant's method_state prediction exactly
    let mut tr = Trainer::new(
        cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Sum, 4, 1),
        ARTIFACTS,
    )
    .unwrap();
    tr.init().unwrap();
    let live = tr.state().group_bytes(StateGroup::Method);
    let dims = Dims::lm_tiny();
    let predicted = memory::breakdown(
        &dims,
        memory::Method::Flora(4),
        OptKind::Adafactor,
        StateRole::Accumulation,
        4,
        false,
    )
    .method_state;
    assert_eq!(live, predicted, "live={live} predicted={predicted}");
    // params group must equal params bytes
    let live_params = tr.state().group_bytes(StateGroup::Params);
    assert_eq!(live_params, dims.param_count() * memory::F32);
}

#[test]
fn opt_state_bytes_match_accountant_adafactor() {
    require_artifacts!();
    let mut tr =
        Trainer::new(cfg(MethodSpec::Naive, TaskKind::Sum, 4, 1), ARTIFACTS).unwrap();
    tr.init().unwrap();
    let live = tr.state().group_bytes(StateGroup::Opt);
    let predicted = memory::breakdown(
        &Dims::lm_tiny(),
        memory::Method::Naive,
        OptKind::Adafactor,
        StateRole::Accumulation,
        4,
        false,
    )
    .opt_state;
    assert_eq!(live, predicted);
}

#[test]
fn generation_metrics_in_range() {
    require_artifacts!();
    let mut tr = Trainer::new(
        cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Sum, 1, 2),
        ARTIFACTS,
    )
    .unwrap();
    tr.init().unwrap();
    let m = tr.eval_metric(8).unwrap();
    let q = m.quality();
    assert!((0.0..=300.0).contains(&q), "rouge sum out of range: {q}");
}

#[test]
fn deterministic_given_seed() {
    require_artifacts!();
    let run = |seed: u64| {
        let mut c = cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Sum, 2, 4);
        c.seed = seed;
        let mut tr = Trainer::new(c, ARTIFACTS).unwrap();
        tr.run().unwrap().train_losses
    };
    assert_eq!(run(7), run(7));
    assert_ne!(run(7), run(8));
}

#[test]
fn vit_adam_and_flora_both_train() {
    require_artifacts!();
    for (method, opt) in [
        (MethodSpec::None, OptimizerKind::Adam),
        (MethodSpec::Flora { rank: 4 }, OptimizerKind::Adafactor),
    ] {
        let c = TrainConfig {
            model: "vit-tiny".into(),
            task: TaskKind::Vit,
            method,
            optimizer: opt,
            lr: 0.01,
            steps: 6,
            tau: 1,
            kappa: 100,
            batch: 4,
            seed: 0,
            eval_every: 0,
            eval_samples: 16,
            ..Default::default()
        };
        let mut tr = Trainer::new(c, ARTIFACTS).unwrap();
        let report = tr.run().unwrap();
        assert!(
            report.final_train_loss() < report.train_losses[0] + 0.2,
            "{} failed to descend",
            method.label()
        );
    }
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    require_artifacts!();
    // train 3 steps, checkpoint, train 2 more; vs resume-from-checkpoint
    // and train the same 2 — losses must match exactly (determinism incl.
    // data cursor and step counters).
    let base = cfg(MethodSpec::Flora { rank: 4 }, TaskKind::Sum, 1, 3);
    let path = std::env::temp_dir().join("flora_it_ckpt.bin");
    let path_s = path.to_str().unwrap();

    let mut t1 = Trainer::new(base.clone(), ARTIFACTS).unwrap();
    t1.run().unwrap();
    t1.save_checkpoint(path_s).unwrap();
    let mut accum = flora::coordinator::AccumSeeds::new(999);
    let mut mom = flora::coordinator::MomentumSeeds::new(
        flora::util::rng::derive_seed(base.seed, 0xE3A),
        base.kappa,
    );
    // advance the momentum schedule to the checkpoint step
    for _ in 0..t1.steps_done() {
        mom.tick();
    }
    let cont: Vec<f32> = (0..2)
        .map(|_| t1.train_step(&mut accum, &mut mom).unwrap())
        .collect();

    let mut t2 = Trainer::new(base.clone(), ARTIFACTS).unwrap();
    t2.resume_from(path_s).unwrap();
    let mut accum2 = flora::coordinator::AccumSeeds::new(999);
    let mut mom2 = flora::coordinator::MomentumSeeds::new(
        flora::util::rng::derive_seed(base.seed, 0xE3A),
        base.kappa,
    );
    for _ in 0..t2.steps_done() {
        mom2.tick();
    }
    let resumed: Vec<f32> = (0..2)
        .map(|_| t2.train_step(&mut accum2, &mut mom2).unwrap())
        .collect();
    assert_eq!(cont, resumed);
    std::fs::remove_file(&path).ok();
}

#[test]
fn ablation_notransfer_executable_runs() {
    require_artifacts!();
    let mut c = cfg(MethodSpec::FloraNoTransfer { rank: 4 }, TaskKind::Mt, 1, 8);
    c.kappa = 3; // force transfers
    if Trainer::new(c.clone(), ARTIFACTS).is_err() {
        eprintln!("skipping: ablation artifacts not built yet");
        return;
    }
    let mut tr = Trainer::new(c, ARTIFACTS).unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_train_loss().is_finite());
}

// ---------------------------------------------------------------------
// serving tier (native, always runs): KV-cache decode + multi-adapter
// batching — the `flora serve` subsystem end-to-end
// ---------------------------------------------------------------------

use flora::model::{AdapterParams, LoraAdapter, ParamSet, TransformerConfig};
use flora::runtime::serve::oracle_check;
use flora::runtime::{AdapterRegistry, BatchPolicy, Server};
use flora::util::rng::{derive_seed, Rng};

/// A synthetic serving adapter: LoRA-initialized trainables with a small
/// distinct gaussian B (B = 0 at init would collapse every adapter onto
/// the base model and the heterogeneity tests would test nothing).
fn serving_adapter(
    cfg: &TransformerConfig,
    base: &ParamSet,
    rank: usize,
    seed: u64,
) -> AdapterParams {
    let ad = LoraAdapter::new(cfg.param_shapes(), rank);
    let mut train = ad.init_trainable(base, seed);
    let names: Vec<String> =
        train.keys().filter(|n| n.starts_with("lora_B/")).cloned().collect();
    for (i, name) in names.iter().enumerate() {
        let m = train.get_mut(name).unwrap();
        let mut rng = Rng::new(derive_seed(seed ^ 0x5e21, i as u64));
        rng.fill_gaussian(&mut m.data, 0.05);
    }
    AdapterParams::from_trainable(&train).unwrap()
}

fn serving_prompt(cfg: &TransformerConfig, req: usize, len: usize) -> Vec<i32> {
    (0..len).map(|j| ((3 + req + 2 * j) % cfg.vocab) as i32).collect()
}

/// KV-cache greedy decode is token-for-token equal to the existing
/// full-recompute greedy across the whole lora size grid (the regression
/// gate for the serving decode engine). Equality is at the TOKEN level by
/// design: the KV path's attention over a compacted cache can flip the
/// sign of exact zeros, which argmax (strict `>`) cannot observe — see
/// model::decode's module docs for the full argument.
#[test]
fn native_serving_kv_greedy_matches_full_recompute_across_grid() {
    for (name, cfg) in TransformerConfig::catalog_grid() {
        let params = cfg.init(7);
        let s = cfg.seq_len;
        let rows = 2;
        for prompt_len in [1, (s / 2).max(1), s - 1] {
            let mut template = vec![0i32; rows * s];
            for bi in 0..rows {
                template[bi * s..bi * s + prompt_len]
                    .copy_from_slice(&serving_prompt(&cfg, bi, prompt_len));
            }
            let mut full = template.clone();
            let mut kv = template;
            cfg.greedy(&params, &mut full, rows, s, prompt_len).unwrap();
            cfg.greedy_kv(&params, &mut kv, rows, s, prompt_len).unwrap();
            assert_eq!(
                full, kv,
                "{name}: KV-cache greedy diverged from full recompute \
                 (prompt_len {prompt_len})"
            );
        }
    }
}

/// One batched forward over B requests with B DISTINCT adapters is
/// bit-identical to B sequential single-adapter forwards — including an
/// adapter poisoned with NaN/Inf, per the kernel-oracle convention
/// (`oracle_check` compares prefill activations via `to_bits` and greedy
/// streams token-for-token, erroring on any divergence).
#[test]
fn native_serving_batched_adapters_bit_match_sequential_oracle() {
    for (name, cfg) in TransformerConfig::catalog_grid() {
        if name == "lora-base" {
            continue; // tiny + small keep the suite fast; bench covers base
        }
        let base = cfg.init(11);
        let mut adapters: Vec<AdapterParams> = (0..2)
            .map(|i| serving_adapter(&cfg, &base, 4, 100 + i))
            .collect();
        {
            // heterogeneity includes non-finite values: a poisoned B must
            // stay confined to its own request panel, bit-exactly
            let ad = LoraAdapter::new(cfg.param_shapes(), 4);
            let mut train = ad.init_trainable(&base, 300);
            let b = train.get_mut("lora_B/layer0/attn/wq").unwrap();
            *b.at_mut(0, 0) = f32::NAN;
            *b.at_mut(1, 1) = f32::INFINITY;
            adapters.push(AdapterParams::from_trainable(&train).unwrap());
        }
        let refs: Vec<&AdapterParams> = adapters.iter().collect();
        let prompt_len = (cfg.seq_len / 2).max(1);
        let max_new = (cfg.seq_len / 4).max(1);
        let prompts: Vec<Vec<i32>> =
            (0..refs.len()).map(|i| serving_prompt(&cfg, i, prompt_len)).collect();
        oracle_check(&cfg, &base, &refs, &prompts, max_new)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

/// The full serving stack across layers: train a real LoRA adapter with
/// the Trainer, save a checkpoint, hot-load it into the AdapterRegistry
/// next to synthetic adapters, and answer a mixed-adapter workload whose
/// served tokens bit-match the sequential oracle.
#[test]
fn native_serving_hot_loads_trained_checkpoint_and_serves() {
    let mut c = tf_cfg(MethodSpec::Lora { rank: 4 }, TaskKind::Lm, 1, 3);
    c.lr = tf_lr(OptimizerKind::Sgd, false);
    let mut tr = Trainer::native(c).unwrap();
    tr.run().unwrap();
    let path = std::env::temp_dir().join("flora_serve_hotload_ckpt.bin");
    let path_s = path.to_str().unwrap();
    tr.save_checkpoint(path_s).unwrap();

    let cfg = TransformerConfig::catalog_grid()
        .into_iter()
        .find(|(n, _)| *n == "lora-tiny")
        .unwrap()
        .1;
    let base = cfg.init(0);
    let mut registry = AdapterRegistry::new(3);
    for i in 0..2u64 {
        let ad = serving_adapter(&cfg, &base, 4, 40 + i);
        registry
            .insert(
                &format!("adapter-{i}"),
                ad,
                flora::runtime::AdapterProvenance::Synthetic { seed: 40 + i },
            )
            .unwrap();
    }
    let rank = registry.load_checkpoint("tuned", path_s).unwrap();
    assert_eq!(rank, 4, "hot-loaded adapter rank");
    std::fs::remove_file(&path).ok();

    let prompt_len = cfg.seq_len / 2;
    let max_new = cfg.seq_len / 4;
    let policy = BatchPolicy { max_batch: 4, max_wait_ms: 50 };
    let mut srv = Server::new(cfg, base.clone(), registry, policy);
    let names = ["adapter-0", "tuned", "adapter-1", "tuned"];
    for (i, n) in names.iter().enumerate() {
        srv.submit(n, serving_prompt(&cfg, i, prompt_len), max_new, 0)
            .unwrap();
    }
    srv.drain(0).unwrap();
    let responses = srv.take_responses();
    assert_eq!(responses.len(), names.len(), "every request answered");

    // the served tokens must bit-match a fresh sequential-oracle rerun
    let want_names: Vec<String> =
        responses.iter().map(|r| r.adapter.clone()).collect();
    let adapters = srv.registry.get_many(&want_names).unwrap();
    let prompts: Vec<Vec<i32>> =
        responses.iter().map(|r| r.tokens[..prompt_len].to_vec()).collect();
    let solo = oracle_check(&cfg, &base, &adapters, &prompts, max_new).unwrap();
    for (r, want) in responses.iter().zip(&solo) {
        assert_eq!(&r.tokens, want, "req {} vs sequential oracle", r.id);
        assert!(r.batch_size >= 1 && r.batch_size <= 4);
    }
}

// ---------------------------------------------------------------------
// data-parallel tier (native, always runs): Flora-compressed gradient
// exchange — the `flora train-dp` subsystem end-to-end
// ---------------------------------------------------------------------

use flora::config::DpConfig;
use flora::runtime::dp::{step_bytes, DpTrainer, GradFault, ReduceMode};

/// Shared dp test config: one kernel thread per worker so that
/// `workers ≤ 4` stays inside the pool budget the other tests in this
/// binary install (the warm-pool lifecycle test caps pool growth).
fn dp_test_cfg(
    model: &str,
    opt: OptimizerKind,
    workers: usize,
    tau: usize,
    steps: usize,
    reduce: ReduceMode,
) -> DpConfig {
    use flora::tensor::Parallelism;
    let mut cfg = DpConfig::default();
    cfg.train.model = model.to_string();
    cfg.train.optimizer = opt;
    cfg.train.workers = workers;
    cfg.train.tau = tau;
    cfg.train.steps = steps;
    cfg.train.kappa = 2; // momentum runs resample within a short test
    cfg.train.parallelism = Parallelism::single();
    cfg.shards = 4;
    cfg.reduce = reduce;
    cfg
}

fn dp_run(cfg: DpConfig) -> (Vec<u32>, Vec<(String, Vec<u32>)>) {
    let mut tr = DpTrainer::new(cfg).unwrap();
    let report = tr.run().unwrap();
    let losses = report.train_losses.iter().map(|x| x.to_bits()).collect();
    let params = tr
        .params()
        .iter()
        .map(|(n, p)| {
            (n.clone(), p.data.iter().map(|x| x.to_bits()).collect())
        })
        .collect();
    (losses, params)
}

/// THE dp acceptance gate: the same config trained at W ∈ {1, 2, 4}
/// produces raw-bits-identical loss curves and final parameters, across
/// two base optimizers, both Flora modes (Algorithm-1 accumulation with
/// τ > 1 and Algorithm-2 momentum with κ-resampling inside the run),
/// and two catalog sizes.
#[test]
fn native_dp_bit_identity_across_worker_counts() {
    let combos: [(&str, OptimizerKind, usize, usize); 3] = [
        // Algorithm 1: τ = 2 micro-steps share a cycle seed
        ("lora-tiny", OptimizerKind::Sgd, 2, 4),
        // Algorithm 2: momentum-in-subspace, κ = 2 resamples mid-run
        ("lora-tiny", OptimizerKind::Adafactor, 1, 4),
        ("lora-small", OptimizerKind::Sgd, 1, 2),
    ];
    for (model, opt, tau, steps) in combos {
        let (base_losses, base_params) =
            dp_run(dp_test_cfg(model, opt, 1, tau, steps, ReduceMode::Compressed));
        assert!(
            base_losses.iter().all(|b| f32::from_bits(*b).is_finite()),
            "{model}/{opt:?}: non-finite loss at W=1"
        );
        for workers in [2usize, 4] {
            let (losses, params) = dp_run(dp_test_cfg(
                model,
                opt,
                workers,
                tau,
                steps,
                ReduceMode::Compressed,
            ));
            assert_eq!(
                losses, base_losses,
                "{model}/{opt:?} tau={tau}: loss curve diverged at W={workers}"
            );
            assert_eq!(
                params, base_params,
                "{model}/{opt:?} tau={tau}: final params diverged at W={workers}"
            );
        }
    }
}

/// A shard poisoned with NaN/Inf must SURFACE in the trained parameters
/// — never be averaged away or laundered by a skip — and must do so
/// raw-bits-identically at every worker count (the fault targets a
/// shard slot, which is the W-independent unit).
#[test]
fn native_dp_poisoned_shard_propagates_identically() {
    let fault = || GradFault {
        shard: 1,
        param: "layer0/attn/wq".to_string(),
    };
    let run = |workers: usize| {
        let mut tr = DpTrainer::new(dp_test_cfg(
            "lora-tiny",
            OptimizerKind::Sgd,
            workers,
            1,
            2,
            ReduceMode::Compressed,
        ))
        .unwrap();
        tr.inject_fault(fault());
        let report = tr.run().unwrap();
        let losses: Vec<u32> =
            report.train_losses.iter().map(|x| x.to_bits()).collect();
        let wq: Vec<u32> =
            tr.params()["layer0/attn/wq"].data.iter().map(|x| x.to_bits()).collect();
        (losses, wq)
    };
    let (l1, wq1) = run(1);
    let (l2, wq2) = run(2);
    assert_eq!(l1, l2, "poisoned loss curve diverged across worker counts");
    assert_eq!(wq1, wq2, "poisoned params diverged across worker counts");
    assert!(
        wq1.iter().any(|b| !f32::from_bits(*b).is_finite()),
        "the poison was averaged away — NaN/Inf must survive the reduce"
    );
}

/// The CommsLedger matches the analytic `step_bytes` formula EXACTLY
/// (integer bytes, `==` not tolerance) at catalog rank: compressed mode
/// ships rank-r states for attn/ffn params, full mode ships everything.
#[test]
fn native_dp_comms_ledger_matches_analytic_ratio() {
    let shapes = TransformerConfig::tiny().param_shapes();
    for (reduce, steps, tau) in
        [(ReduceMode::Compressed, 2, 1), (ReduceMode::Full, 1, 2)]
    {
        let cfg =
            dp_test_cfg("lora-tiny", OptimizerKind::Sgd, 2, tau, steps, reduce);
        let rank = cfg.rank();
        let shards = cfg.shards;
        let mut tr = DpTrainer::new(cfg).unwrap();
        let report = tr.run().unwrap();
        let data_steps = (steps * tau) as u64;
        let sent = step_bytes(&shapes, rank, shards, reduce);
        let full = step_bytes(&shapes, rank, shards, ReduceMode::Full);
        assert_eq!(report.ledger.steps, data_steps);
        assert_eq!(report.ledger.bytes_sent, data_steps * sent);
        assert_eq!(report.ledger.bytes_full, data_steps * full);
        match reduce {
            ReduceMode::Compressed => assert!(
                report.ledger.bytes_sent < report.ledger.bytes_full,
                "compressed mode must shrink the wire"
            ),
            ReduceMode::Full => assert_eq!(
                report.ledger.bytes_sent, report.ledger.bytes_full,
                "full mode ships everything"
            ),
        }
    }
}

/// Compressed reduce is exact up to float reassociation relative to the
/// full-gradient wire: `Σ_s (G_s Aᵀ) = (Σ_s G_s) Aᵀ` in real
/// arithmetic, so one optimizer step under either mode lands within
/// float-noise of the other (the modes are NOT bit-equal — the
/// summation order differs — which is exactly why both exist as an
/// A/B).
#[test]
fn native_dp_full_reduce_matches_compressed_within_tolerance() {
    let run = |reduce: ReduceMode| {
        let mut tr = DpTrainer::new(dp_test_cfg(
            "lora-tiny",
            OptimizerKind::Sgd,
            2,
            1,
            1,
            reduce,
        ))
        .unwrap();
        tr.run().unwrap();
        tr.params().clone()
    };
    let comp = run(ReduceMode::Compressed);
    let full = run(ReduceMode::Full);
    for (name, p) in &comp {
        let q = &full[name];
        for (i, (a, b)) in p.data.iter().zip(&q.data).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + a.abs()),
                "{name}[{i}]: compressed {a} vs full {b}"
            );
        }
    }
}
