//! Property-style tests over the pure-rust substrates (hand-rolled
//! generators — proptest isn't in the offline vendor set; util::rng::Rng
//! drives randomized cases with fixed seeds so failures are reproducible).

use flora::data::seq2seq::{MtTask, SumTask};
use flora::metrics::{bleu_corpus, rouge_corpus, token_accuracy};
use flora::rp;
use flora::tensor::Matrix;
use flora::util::json;
use flora::util::rng::Rng;

fn rand_seq(rng: &mut Rng, max_len: usize, vocab: i32) -> Vec<i32> {
    let len = 1 + rng.next_below(max_len);
    (0..len).map(|_| rng.next_below(vocab as usize) as i32).collect()
}

// ---------------------------------------------------------------------
// metrics invariants
// ---------------------------------------------------------------------

#[test]
fn prop_rouge_bounded_and_symmetric_identity() {
    let mut rng = Rng::new(1);
    for _ in 0..200 {
        let a = rand_seq(&mut rng, 24, 50);
        let b = rand_seq(&mut rng, 24, 50);
        let s = rouge_corpus(&[(a.clone(), b.clone())]);
        for v in [s.rouge1, s.rouge2, s.rouge_l] {
            assert!((0.0..=100.0).contains(&v));
        }
        // identity scores 100 on R1/RL
        let id = rouge_corpus(&[(a.clone(), a.clone())]);
        assert!((id.rouge1 - 100.0).abs() < 1e-9);
        assert!((id.rouge_l - 100.0).abs() < 1e-9);
        // F1 is symmetric in (hyp, ref) for R1 (same clipped overlap)
        let fwd = rouge_corpus(&[(a.clone(), b.clone())]).rouge1;
        let rev = rouge_corpus(&[(b, a)]).rouge1;
        assert!((fwd - rev).abs() < 1e-9);
    }
}

#[test]
fn prop_bleu_bounded_and_maximal_on_identity() {
    let mut rng = Rng::new(2);
    for _ in 0..200 {
        let a = rand_seq(&mut rng, 24, 50);
        let b = rand_seq(&mut rng, 24, 50);
        let s = bleu_corpus(&[(a.clone(), b.clone())]).score;
        assert!((0.0..=100.0).contains(&s));
        let id = bleu_corpus(&[(a.clone(), a.clone())]).score;
        assert!(id >= s - 1e-9, "identity must not score below a mismatch");
    }
}

#[test]
fn prop_token_accuracy_bounds() {
    let mut rng = Rng::new(3);
    for _ in 0..200 {
        let a = rand_seq(&mut rng, 16, 8);
        let b = rand_seq(&mut rng, 16, 8);
        let acc = token_accuracy(&a, &b);
        assert!((0.0..=1.0).contains(&acc));
        assert_eq!(token_accuracy(&a, &a), 1.0);
    }
}

// ---------------------------------------------------------------------
// rp invariants (linearity, unbiasedness scaling)
// ---------------------------------------------------------------------

#[test]
fn prop_compress_is_linear() {
    let mut rng = Rng::new(4);
    for trial in 0..20 {
        let (n, m, r) = (
            2 + rng.next_below(16),
            2 + rng.next_below(32),
            1 + rng.next_below(8),
        );
        let g1 = Matrix::gaussian(n, m, 1.0, &mut rng);
        let g2 = Matrix::gaussian(n, m, 1.0, &mut rng);
        let a = rp::projection(trial as u64, r, m);
        let lhs = rp::compress(&(&g1 + &g2), &a);
        let rhs = &rp::compress(&g1, &a) + &rp::compress(&g2, &a);
        assert!(lhs.allclose(&rhs, 1e-4), "shape ({n},{m},{r})");
    }
}

#[test]
fn prop_compress_decompress_scales_with_rank() {
    // mean reconstruction error must be non-increasing as r doubles
    let mut rng = Rng::new(5);
    let g = Matrix::gaussian(12, 48, 1.0, &mut rng);
    let mut last = f32::INFINITY;
    for r in [2usize, 8, 32, 128, 512] {
        // average over seeds to beat sampling noise
        let mut err = 0.0f32;
        for s in 0..8 {
            let rec = rp::project_gradient(&g, 100 + s, r);
            err += (&rec - &g).frobenius_norm();
        }
        err /= 8.0;
        assert!(err <= last * 1.15, "r={r}: err {err} after {last}");
        last = err;
    }
}

#[test]
fn prop_projection_rows_near_unit_norm_scaled() {
    // A ~ N(0, 1/r): each row has expected squared norm m/r
    let mut rng = Rng::new(6);
    for _ in 0..10 {
        let r = 4 + rng.next_below(32);
        let m = 16 + rng.next_below(128);
        let a = rp::projection(rng.next_u64(), r, m);
        let want = (m as f32 / r as f32).sqrt();
        for i in 0..r {
            let norm: f32 = a.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!(
                norm > 0.3 * want && norm < 2.5 * want,
                "row {i}: norm={norm} want~{want}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// blocked/parallel GEMM kernels vs the retained naive references
// ---------------------------------------------------------------------

use flora::tensor::Parallelism;

#[test]
fn prop_blocked_matmuls_bit_match_naive_on_random_rectangles() {
    // random rectangular shapes, including ones straddling the kernel
    // block sizes; the blocked kernels accumulate each element's k-terms
    // in the same ascending order as the naive triple loop, so the
    // comparison is EXACT (tolerance 0), not ULP-scaled
    let mut rng = Rng::new(20);
    for trial in 0..24 {
        let (n, k, m) = if trial < 18 {
            (
                1 + rng.next_below(40),
                1 + rng.next_below(40),
                1 + rng.next_below(40),
            )
        } else {
            // force the k/j blocking paths (> 64 / > 128)
            (
                60 + rng.next_below(90),
                60 + rng.next_below(90),
                100 + rng.next_below(80),
            )
        };
        let a = Matrix::gaussian(n, k, 1.0, &mut rng);
        let b = Matrix::gaussian(k, m, 1.0, &mut rng);
        assert!(
            a.matmul(&b).allclose(&a.matmul_naive(&b), 0.0),
            "matmul ({n},{k},{m})"
        );
        let bt = Matrix::gaussian(m, k, 1.0, &mut rng);
        assert!(
            a.matmul_nt(&bt).allclose(&a.matmul_nt_naive(&bt), 0.0),
            "matmul_nt ({n},{k},{m})"
        );
        let b2 = Matrix::gaussian(n, m, 1.0, &mut rng);
        assert!(
            a.matmul_tn(&b2).allclose(&a.matmul_tn_naive(&b2), 0.0),
            "matmul_tn ({n},{k},{m})"
        );
    }
}

#[test]
fn prop_parallel_matmuls_bit_match_serial() {
    // the row-parallel path must be bit-identical to serial at every
    // thread budget (each output row is owned by one thread running the
    // identical kernel). Safe to flip the global mid-test-suite for the
    // same reason: other tests' results cannot change either.
    let mut rng = Rng::new(21);
    // big enough to clear the parallel-engagement threshold
    let a = Matrix::gaussian(150, 90, 1.0, &mut rng);
    let b = Matrix::gaussian(90, 120, 1.0, &mut rng);
    let bt = Matrix::gaussian(120, 90, 1.0, &mut rng);
    let b2 = Matrix::gaussian(150, 110, 1.0, &mut rng);
    let before = Parallelism::current();
    Parallelism::single().install();
    let (serial, serial_nt, serial_tn) =
        (a.matmul(&b), a.matmul_nt(&bt), a.matmul_tn(&b2));
    for threads in [2usize, 3, 7] {
        Parallelism::new(threads).install();
        assert!(a.matmul(&b).allclose(&serial, 0.0), "threads={threads}");
        assert!(
            a.matmul_nt(&bt).allclose(&serial_nt, 0.0),
            "nt threads={threads}"
        );
        assert!(
            a.matmul_tn(&b2).allclose(&serial_tn, 0.0),
            "tn threads={threads}"
        );
    }
    before.install();
}

#[test]
fn prop_blocked_kernels_propagate_nan_and_inf() {
    // the PR-1 regression, re-run against the blocked/parallel kernels at
    // sizes that exercise the blocking: a zero row times a NaN/Inf column
    // must stay non-finite (0 * NaN = NaN; no zero-skip fast paths)
    let (n, k, m) = (70usize, 130usize, 150usize);
    let mut a = Matrix::zeros(n, k);
    *a.at_mut(0, k - 1) = 1.0; // row 0 hits the NaN row of b with weight 1
    let mut b = Matrix::zeros(k, m);
    for j in 0..m {
        *b.at_mut(k - 1, j) = f32::NAN;
    }
    let c = a.matmul(&b);
    assert!(c.row(0).iter().all(|x| x.is_nan()), "NaN row laundered");
    // row 1 of a is all zero, but 0 * NaN in the contraction is NaN
    assert!(c.row(1).iter().all(|x| x.is_nan()), "0*NaN must stay NaN");

    let mut binf = Matrix::zeros(k, m);
    *binf.at_mut(0, 0) = f32::INFINITY;
    let cinf = a.matmul(&binf);
    assert!(cinf.at(1, 0).is_nan(), "0*inf must be NaN");
    assert_eq!(cinf.at(1, 1), 0.0);

    // same contractions through the nt/tn kernels
    let bnan = Matrix::from_fn(3, k, |_, j| if j == 0 { f32::NAN } else { 1.0 });
    let cnt = a.matmul_nt(&bnan);
    assert!(cnt.data.iter().all(|x| x.is_nan()));
    let annan = Matrix::from_fn(n, 3, |i, _| if i == 0 { f32::NAN } else { 0.0 });
    let ctn = annan.matmul_tn(&Matrix::from_fn(n, m, |_, _| 1.0));
    assert!(ctn.data.iter().all(|x| x.is_nan()));
}

#[test]
fn prop_pool_vs_scope_vs_naive_bit_match_on_random_rectangles() {
    // the PR-5 worker pool against the retained thread::scope driver
    // against the naive serial oracles: all three must agree EXACTLY on
    // random rectangles big enough to clear the parallel-engagement
    // threshold, at several thread budgets. Comparison is on the raw f32
    // BITS (allclose treats NaN != NaN, and the poisoned trials below
    // must check that non-finite values propagate identically too).
    fn bits_equal(a: &Matrix, b: &Matrix) -> bool {
        a.shape() == b.shape()
            && a.data
                .iter()
                .zip(b.data.iter())
                .all(|(x, y)| x.to_bits() == y.to_bits())
    }
    let mut rng = Rng::new(22);
    for trial in 0..6 {
        let (n, k, m) = (
            40 + rng.next_below(120),
            40 + rng.next_below(120),
            40 + rng.next_below(120),
        );
        let mut a = Matrix::gaussian(n, k, 1.0, &mut rng);
        let b = Matrix::gaussian(k, m, 1.0, &mut rng);
        let bt = Matrix::gaussian(m, k, 1.0, &mut rng);
        let b2 = Matrix::gaussian(n, m, 1.0, &mut rng);
        if trial >= 4 {
            // poison with non-finite values: NaN/Inf must propagate
            // identically through every driver (no zero-skips anywhere)
            *a.at_mut(0, k / 2) = f32::NAN;
            *a.at_mut(n / 2, 0) = f32::INFINITY;
        }
        let before = Parallelism::current();
        let (naive, naive_nt, naive_tn) =
            (a.matmul_naive(&b), a.matmul_nt_naive(&bt), a.matmul_tn_naive(&b2));
        for budget in [
            Parallelism::new(2),
            Parallelism::scoped(2),
            Parallelism::new(5),
            Parallelism::scoped(5),
        ] {
            budget.install();
            assert!(
                bits_equal(&a.matmul(&b), &naive),
                "matmul {budget:?} ({n},{k},{m}) trial {trial}"
            );
            assert!(
                bits_equal(&a.matmul_nt(&bt), &naive_nt),
                "matmul_nt {budget:?} ({n},{k},{m}) trial {trial}"
            );
            assert!(
                bits_equal(&a.matmul_tn(&b2), &naive_tn),
                "matmul_tn {budget:?} ({n},{k},{m}) trial {trial}"
            );
        }
        before.install();
    }
}

#[test]
fn prop_packed_kernels_bit_match_naive_at_block_boundaries() {
    // PR 9 packs the strided operand's K×J panel into a reused scratch
    // buffer; the pack is a pure memory copy and the per-element
    // ascending-k accumulation order is unchanged, so the packed kernels
    // must stay EXACT against the naive oracles — checked here on ragged
    // shapes straddling the K_BLOCK=64 / J_BLOCK=128 edges (partial
    // final panels, single-row/col slivers), with NaN/Inf poison, under
    // the serial, pooled, and scoped drivers.
    fn bits_equal(a: &Matrix, b: &Matrix) -> bool {
        a.shape() == b.shape()
            && a.data
                .iter()
                .zip(b.data.iter())
                .all(|(x, y)| x.to_bits() == y.to_bits())
    }
    let mut rng = Rng::new(23);
    let edges = [1usize, 3, 63, 64, 65, 127, 128, 129];
    let before = Parallelism::current();
    for trial in 0..10 {
        let n = edges[rng.next_below(edges.len())];
        let k = edges[rng.next_below(edges.len())];
        let m = edges[rng.next_below(edges.len())];
        let mut a = Matrix::gaussian(n, k, 1.0, &mut rng);
        let b = Matrix::gaussian(k, m, 1.0, &mut rng);
        let bt = Matrix::gaussian(m, k, 1.0, &mut rng);
        let b2 = Matrix::gaussian(n, m, 1.0, &mut rng);
        if trial % 2 == 1 {
            *a.at_mut(rng.next_below(n), rng.next_below(k)) = f32::NAN;
            *a.at_mut(rng.next_below(n), rng.next_below(k)) = f32::INFINITY;
        }
        let (naive, naive_nt, naive_tn) =
            (a.matmul_naive(&b), a.matmul_nt_naive(&bt), a.matmul_tn_naive(&b2));
        for budget in
            [Parallelism::single(), Parallelism::new(3), Parallelism::scoped(3)]
        {
            budget.install();
            assert!(
                bits_equal(&a.matmul(&b), &naive),
                "matmul {budget:?} ({n},{k},{m}) trial {trial}"
            );
            assert!(
                bits_equal(&a.matmul_nt(&bt), &naive_nt),
                "matmul_nt {budget:?} ({n},{k},{m}) trial {trial}"
            );
            assert!(
                bits_equal(&a.matmul_tn(&b2), &naive_tn),
                "matmul_tn {budget:?} ({n},{k},{m}) trial {trial}"
            );
        }
    }
    before.install();
}

#[test]
fn prop_parallel_elementwise_passes_bit_match_serial() {
    // PR 9 bands the row-local elementwise passes (softmax, rms-norm and
    // its VJP) onto the same pool as the GEMMs. The band split cannot
    // change any element's arithmetic — each output row is computed by
    // exactly one thread running the identical per-row body — so every
    // thread budget and driver must reproduce the serial result raw-bits,
    // NaN/Inf included.
    use flora::tensor::{rms_norm_rows, rms_norm_rows_vjp, softmax_rows};
    fn bits_equal(a: &Matrix, b: &Matrix) -> bool {
        a.shape() == b.shape()
            && a.data
                .iter()
                .zip(b.data.iter())
                .all(|(x, y)| x.to_bits() == y.to_bits())
    }
    let mut rng = Rng::new(24);
    // big enough to clear the engagement threshold for elementwise work
    let mut x = Matrix::gaussian(300, 96, 1.0, &mut rng);
    *x.at_mut(5, 7) = f32::NAN;
    *x.at_mut(11, 0) = f32::INFINITY;
    let scale = Matrix::gaussian(1, 96, 1.0, &mut rng);
    let dy = Matrix::gaussian(300, 96, 1.0, &mut rng);
    let before = Parallelism::current();
    Parallelism::single().install();
    let sm = softmax_rows(&x);
    let rn = rms_norm_rows(&x, &scale);
    let (dx, dscale) = rms_norm_rows_vjp(&x, &scale, &dy);
    for budget in
        [Parallelism::new(2), Parallelism::new(5), Parallelism::scoped(3)]
    {
        budget.install();
        assert!(bits_equal(&softmax_rows(&x), &sm), "softmax {budget:?}");
        assert!(bits_equal(&rms_norm_rows(&x, &scale), &rn), "rms {budget:?}");
        let (dx2, dscale2) = rms_norm_rows_vjp(&x, &scale, &dy);
        assert!(bits_equal(&dx2, &dx), "rms vjp dx {budget:?}");
        assert!(bits_equal(&dscale2, &dscale), "rms vjp dscale {budget:?}");
    }
    before.install();
}

// ---------------------------------------------------------------------
// data-task invariants
// ---------------------------------------------------------------------

#[test]
fn prop_sum_task_masks_align_with_sep() {
    let t = SumTask::new(256, 64, 9);
    let mut b = flora::data::LmBatch::zeros(8, 64);
    let mut cur = 0;
    for split in 0..3u64 {
        t.fill_batch(&mut b, split, &mut cur);
        for row in 0..8 {
            let toks = b.row_tokens(row);
            let mask = &b.mask[row * 64..(row + 1) * 64];
            let sep = toks.iter().position(|&x| x == 2).unwrap();
            // nothing before/at SEP is masked-in
            assert!(mask[..=sep].iter().all(|&m| m == 0.0));
            // the masked-in span is contiguous right after SEP
            let first = mask.iter().position(|&m| m > 0.0).unwrap();
            assert_eq!(first, sep + 1);
        }
    }
}

#[test]
fn prop_mt_translate_deterministic_and_length_preserving() {
    let t = MtTask::new(256, 64, 10);
    let mut rng = Rng::new(11);
    for _ in 0..100 {
        let src: Vec<i32> =
            (0..1 + rng.next_below(20)).map(|_| 4 + rng.next_below(100) as i32).collect();
        let t1 = t.translate(&src);
        let t2 = t.translate(&src);
        assert_eq!(t1, t2);
        assert_eq!(t1.len(), src.len());
    }
}

// ---------------------------------------------------------------------
// json parser round-trip-ish fuzz
// ---------------------------------------------------------------------

#[test]
fn prop_json_never_panics_on_ascii_noise() {
    let mut rng = Rng::new(12);
    for _ in 0..500 {
        let len = rng.next_below(40);
        let doc: String = (0..len)
            .map(|_| {
                let chars = b"{}[]\",:0123456789.eE+-truefalsnl \t";
                chars[rng.next_below(chars.len())] as char
            })
            .collect();
        let _ = json::parse(&doc); // must return, never panic
    }
}

#[test]
fn prop_json_roundtrips_generated_numbers() {
    let mut rng = Rng::new(13);
    for _ in 0..200 {
        let x = (rng.next_f64() - 0.5) * 1e6;
        let doc = format!("{{\"v\": {x}}}");
        let v = json::parse(&doc).unwrap();
        let got = v.get("v").unwrap().as_f64().unwrap();
        assert!((got - x).abs() < 1e-6 * x.abs().max(1.0));
    }
}

// ---------------------------------------------------------------------
// optimizer math (flora::opt) invariants
// ---------------------------------------------------------------------

use flora::opt::{Adafactor, Adam, BaseOptimizer, FloraCompressor, Sgd};

fn randn_mat(seed: u64, n: usize, m: usize) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::gaussian(n, m, 1.0, &mut rng)
}

#[test]
fn prop_adam_bias_correction_makes_first_step_scale_invariant() {
    // t=1: m̂ = g and v̂ = g², so Δw = -lr·g/(|g|+eps) ≈ -lr·sign(g)
    // whatever the raw gradient magnitude — the signature of a correct
    // bias correction (without it the first step would be ~√(1-β2)·lr).
    let adam = Adam::new();
    for &scale in &[1e-3f32, 1.0, 1e3] {
        let mut w = Matrix::zeros(4, 6);
        let g = Matrix::from_fn(4, 6, |i, j| {
            scale * if (i + j) % 2 == 0 { 1.0 } else { -1.0 }
        });
        let mut st = adam.init_state(4, 6);
        adam.update(&mut w, &g, &mut st, 0.01, 0.0).unwrap();
        for (x, gg) in w.data.iter().zip(g.data.iter()) {
            assert!(
                (x.abs() - 0.01).abs() < 1e-4,
                "scale {scale}: |Δ| = {} != lr", x.abs()
            );
            assert!(x * gg < 0.0, "scale {scale}: moved with the gradient");
        }
    }
}

#[test]
fn prop_adam_constant_gradient_limit_is_sign_sgd() {
    // with a constant gradient, m̂ → g and v̂ → g² as t grows, so the
    // per-step displacement converges to exactly lr·sign(g)
    let adam = Adam::new();
    let g = Matrix::from_fn(3, 5, |i, j| if (i * 5 + j) % 3 == 0 { 0.25 } else { -2.0 });
    let mut w = Matrix::zeros(3, 5);
    let mut st = adam.init_state(3, 5);
    for s in 0..99 {
        adam.update(&mut w, &g, &mut st, 0.01, s as f32).unwrap();
    }
    let prev = w.clone();
    adam.update(&mut w, &g, &mut st, 0.01, 99.0).unwrap();
    for ((x, p), gg) in w.data.iter().zip(prev.data.iter()).zip(g.data.iter()) {
        let delta = x - p;
        assert!(
            (delta.abs() - 0.01).abs() < 1e-4,
            "late-step |Δ| = {} != lr", delta.abs()
        );
        assert!(delta * gg < 0.0);
    }
}

#[test]
fn prop_adafactor_factored_matches_full_on_rank1_gradients() {
    // G = u vᵀ ⇒ G² factors exactly, so the factored second moment
    // vr·vcᵀ/mean(vr) equals the full one and both variants take the
    // SAME step (paper §3.1: Adafactor loses nothing on rank-1 updates).
    let factored = Adafactor::new();
    let full = Adafactor::unfactored();
    for trial in 0..20u64 {
        let (n, m) = (12, 9);
        let u = randn_mat(100 + trial, n, 1);
        let v = randn_mat(200 + trial, 1, m);
        let g = Matrix::from_fn(n, m, |i, j| u.at(i, 0) * v.at(0, j));
        let w0 = randn_mat(300 + trial, n, m);

        let mut wf = w0.clone();
        let mut sf = factored.init_state(n, m);
        factored.update(&mut wf, &g, &mut sf, 0.1, 0.0).unwrap();

        let mut wu = w0.clone();
        let mut su = full.init_state(n, m);
        full.update(&mut wu, &g, &mut su, 0.1, 0.0).unwrap();

        assert!(
            wf.allclose(&wu, 1e-4),
            "trial {trial}: factored and full steps diverge"
        );
        // the reconstructed v̂ agrees with the full second moment too
        let vhat = factored.second_moment(&sf).unwrap();
        let vfull = full.second_moment(&su).unwrap();
        assert!(vhat.allclose(&vfull, 1e-4), "trial {trial}: v̂ mismatch");
    }
}

#[test]
fn prop_adafactor_factored_only_approximates_higher_rank() {
    // sanity check on the previous test's power: for a generic (full
    // rank) gradient the factored estimate is NOT exact
    let factored = Adafactor::new();
    let full = Adafactor::unfactored();
    let g = randn_mat(7, 12, 9);
    let mut sf = factored.init_state(12, 9);
    let mut su = full.init_state(12, 9);
    let mut wf = Matrix::zeros(12, 9);
    let mut wu = Matrix::zeros(12, 9);
    factored.update(&mut wf, &g, &mut sf, 0.1, 0.0).unwrap();
    full.update(&mut wu, &g, &mut su, 0.1, 0.0).unwrap();
    let vhat = factored.second_moment(&sf).unwrap();
    let vfull = full.second_moment(&su).unwrap();
    assert!(!vhat.allclose(&vfull, 1e-4), "rank-1 approx exact on full-rank g?");
}

#[test]
fn prop_flora_compressor_accumulation_is_sum_of_compressions() {
    // Algorithm 1's τ-cycle: the compressor's running accumulator must be
    // EXACTLY the sum of the per-micro compressions (linearity is what
    // makes the shared-seed cycle equal one big-batch compression)
    let comp = FloraCompressor::new(Sgd, 8);
    let seed = 4242u64;
    let (n, m) = (16, 48);
    let mut acc = Matrix::zeros(n, 8);
    let mut want = Matrix::zeros(n, 8);
    let a = rp::projection(seed, 8, m);
    for k in 0..6u64 {
        let g = randn_mat(500 + k, n, m);
        comp.accumulate(&mut acc, &g, seed);
        want.add_scaled_inplace(&rp::compress(&g, &a), 1.0);
    }
    assert!(acc.allclose(&want, 1e-4));

    // and the cycle-end update with an SGD base equals the manual
    // decompress-mean-step
    let mut w = randn_mat(9, n, m);
    let mut manual = w.clone();
    comp.apply_accumulated(&mut w, &acc, &mut Vec::new(), seed, 6.0, 0.2, 0.0)
        .unwrap();
    manual.add_scaled_inplace(&rp::decompress(&acc, &a).scale(1.0 / 6.0), -0.2);
    assert!(w.allclose(&manual, 1e-5));
}

#[test]
fn prop_flora_compressor_momentum_composes_with_any_base() {
    // the same tick applied over different base optimizers must keep the
    // SAME momentum state (the EMA lives upstream of the base optimizer)
    let g = randn_mat(21, 16, 48);
    let tick = flora::opt::SubspaceTick {
        seed_cur: 5,
        seed_next: 6,
        resample: false,
        transfer: true,
    };
    let run = |base: Box<dyn BaseOptimizer>| {
        let comp = FloraCompressor::new(base, 8);
        let mut w = randn_mat(22, 16, 48);
        let mut mom = Matrix::zeros(16, 8);
        let mut st = comp.base().init_state(16, 48);
        comp.momentum_step(&mut w, &mut mom, &mut st, &g, tick, 0.1, 0.0)
            .unwrap();
        (w, mom)
    };
    let (w_sgd, mom_sgd) = run(Box::new(Sgd));
    let (w_adam, mom_adam) = run(Box::new(Adam::new()));
    assert!(mom_sgd.allclose(&mom_adam, 0.0), "EMA depends on the base?");
    // but the parameter step differs (sgd scales with |g|, adam is ~lr)
    assert!(!w_sgd.allclose(&w_adam, 1e-5));
}

// ---------------------------------------------------------------------
// adaptive-rank schedule (flora::opt::schedule) invariants
// ---------------------------------------------------------------------

use flora::opt::{
    migrate, migrate_in_place, reclaimed_bytes, RankSchedule, RankedTick,
    ScheduledFlora, SubspaceTick,
};

#[test]
fn prop_rank_migration_prefix_is_bit_exact_and_bytes_are_analytic() {
    // a shrink never rewrites a surviving coordinate: the kept
    // [n, r_new] block is a raw-bits prefix copy of the old state, and
    // the reclaimed bytes follow (r_old − r_new)·n·4 exactly, for EVERY
    // (n, r_old, r_new). The shape-stable in-place twin must agree on
    // both counts and zero the dead columns outright.
    let mut dims = Rng::new(909);
    for trial in 0..25u64 {
        let n = 1 + dims.next_below(24);
        let r_old = 1 + dims.next_below(16);
        let state = randn_mat(1000 + trial, n, r_old);
        for r_new in 1..=r_old {
            let (kept, freed) = migrate(&state, r_new).unwrap();
            assert_eq!(kept.shape(), (n, r_new));
            assert_eq!(freed, ((r_old - r_new) * n * 4) as u64);
            assert_eq!(freed, reclaimed_bytes(n, r_old, r_new));
            for i in 0..n {
                for j in 0..r_new {
                    assert_eq!(
                        kept.at(i, j).to_bits(),
                        state.at(i, j).to_bits(),
                        "trial {trial}: ({i},{j}) rewritten at {r_old}->{r_new}"
                    );
                }
            }
            let mut stable = state.clone();
            assert_eq!(migrate_in_place(&mut stable, r_old, r_new), freed);
            for i in 0..n {
                for j in 0..r_old {
                    if j < r_new {
                        assert_eq!(
                            stable.at(i, j).to_bits(),
                            state.at(i, j).to_bits(),
                            "trial {trial}: in-place rewrote ({i},{j})"
                        );
                    } else {
                        assert_eq!(stable.at(i, j), 0.0, "trial {trial}: ({i},{j})");
                    }
                }
            }
        }
        assert!(migrate(&state, 0).is_err());
        assert!(migrate(&state, r_old + 1).is_err());
    }
}

#[test]
fn prop_rank_schedule_parses_back_monotone_and_clamped() {
    // every spellable schedule roundtrips through name(), and rank_at is
    // monotone nonincreasing in the cycle, clamped to 1..=r0
    let mut rng = Rng::new(77);
    for _ in 0..40 {
        let every = 1 + rng.next_below(49);
        let r0 = 1 + rng.next_below(32);
        for spec in [
            format!("linear-decay:{every}"),
            format!("halve-at:{every}"),
            "fixed".to_string(),
        ] {
            let sched = RankSchedule::parse(&spec).unwrap();
            assert_eq!(sched.name(), spec);
            let mut last = r0;
            for cycle in 0..100 {
                let r = sched.rank_at(r0, cycle);
                assert!(r >= 1 && r <= r0, "{spec} r0={r0} cycle {cycle}: {r}");
                assert!(r <= last, "{spec} grew at cycle {cycle}");
                last = r;
            }
        }
    }
}

#[test]
fn prop_scheduled_flora_shrink_step_matches_manual_subrank_algebra() {
    // one shrinking resample step, replayed by hand: truncate the
    // momentum FIRST (bit-exact prefix), transfer the survivors between
    // the sub-rank projections of the MASTER sampling law, EMA in the
    // new subspace, then decompress with the r0/ra compensation. Pins
    // both the operation order and the unbiasedness scaling.
    let (r0, ra, n, m) = (8usize, 4usize, 16usize, 48usize);
    let sched = ScheduledFlora::new(
        FloraCompressor::new(Sgd, r0),
        RankSchedule::HalveAt { every: 1 },
    );
    let beta = sched.flora().beta();
    let tick = RankedTick {
        sub: SubspaceTick { seed_cur: 31, seed_next: 32, resample: true, transfer: true },
        rank_cur: r0,
        rank_next: ra,
    };
    let g = randn_mat(40, n, m);
    let m0 = randn_mat(41, n, r0).scale(0.1);
    let w0 = randn_mat(42, n, m);

    let mut w = w0.clone();
    let mut mom = m0.clone();
    let mut st = Vec::new();
    let freed = sched
        .momentum_step(&mut w, &mut mom, &mut st, &g, tick, 0.2, 0.0)
        .unwrap();
    assert_eq!(freed, reclaimed_bytes(n, r0, ra));

    let a_old = rp::projection_sub(31, ra, r0, m);
    let a_new = rp::projection_sub(32, ra, r0, m);
    let (trunc, _) = migrate(&m0, ra).unwrap();
    let mut ema = rp::transfer(&trunc, &a_old, &a_new).scale(beta);
    ema.add_scaled_inplace(&rp::compress(&g, &a_new), 1.0 - beta);
    for i in 0..n {
        for j in 0..r0 {
            if j < ra {
                assert_eq!(
                    mom.at(i, j).to_bits(),
                    ema.at(i, j).to_bits(),
                    "active momentum ({i},{j}) off the manual algebra"
                );
            } else {
                assert_eq!(mom.at(i, j), 0.0, "dead column ({i},{j}) not zeroed");
            }
        }
    }
    let mut manual = w0.clone();
    manual.add_scaled_inplace(
        &rp::decompress(&ema, &a_new).scale(r0 as f32 / ra as f32),
        -0.2,
    );
    assert!(w.allclose(&manual, 1e-5), "parameter step off the manual algebra");
}

#[test]
fn prop_scheduled_flora_compression_stays_linear_after_a_shrink() {
    // from zero momentum a ranked step is (1−β)·compress_sub(g): still
    // LINEAR in the gradient even across a mid-cycle shrinking resample
    // — the accumulate-linearity that keeps Algorithm 1's shared-seed
    // cycle argument valid at every active rank.
    let sched = ScheduledFlora::new(
        FloraCompressor::new(Sgd, 8),
        RankSchedule::LinearDecay { every: 1 },
    );
    let tick = RankedTick {
        sub: SubspaceTick { seed_cur: 51, seed_next: 52, resample: true, transfer: true },
        rank_cur: 8,
        rank_next: 5,
    };
    let step_mom = |g: &Matrix| {
        let mut w = randn_mat(60, 16, 48);
        let mut mom = Matrix::zeros(16, 8);
        let mut st = Vec::new();
        sched.momentum_step(&mut w, &mut mom, &mut st, g, tick, 0.1, 0.0).unwrap();
        mom
    };
    let g1 = randn_mat(61, 16, 48);
    let g2 = randn_mat(62, 16, 48);
    let mut gsum = g1.clone();
    gsum.add_scaled_inplace(&g2, 1.0);
    let mut want = step_mom(&g1);
    want.add_scaled_inplace(&step_mom(&g2), 1.0);
    assert!(
        step_mom(&gsum).allclose(&want, 1e-4),
        "post-shrink compression is not linear in the gradient"
    );
}

#[test]
fn prop_scheduled_flora_shrunk_ema_composes_with_any_base() {
    // the ranked EMA lives upstream of the base optimizer, exactly like
    // the full-rank one: the momentum reached through a shrinking
    // resample must be identical under SGD and Adam bases, and both must
    // book the same reclaimed bytes.
    let g = randn_mat(71, 16, 48);
    let tick = RankedTick {
        sub: SubspaceTick { seed_cur: 81, seed_next: 82, resample: true, transfer: true },
        rank_cur: 8,
        rank_next: 4,
    };
    let run = |base: Box<dyn BaseOptimizer>| {
        let sched = ScheduledFlora::new(
            FloraCompressor::new(base, 8),
            RankSchedule::HalveAt { every: 1 },
        );
        let mut w = randn_mat(72, 16, 48);
        let mut mom = randn_mat(73, 16, 8).scale(0.1);
        let mut st = sched.flora().base().init_state(16, 48);
        let freed = sched
            .momentum_step(&mut w, &mut mom, &mut st, &g, tick, 0.1, 0.0)
            .unwrap();
        (w, mom, freed)
    };
    let (w_sgd, mom_sgd, freed_sgd) = run(Box::new(Sgd));
    let (w_adam, mom_adam, freed_adam) = run(Box::new(Adam::new()));
    assert_eq!(freed_sgd, freed_adam);
    assert_eq!(freed_sgd, reclaimed_bytes(16, 8, 4));
    assert!(mom_sgd.allclose(&mom_adam, 0.0), "ranked EMA depends on the base?");
    assert!(!w_sgd.allclose(&w_adam, 1e-5));
}
