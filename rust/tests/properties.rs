//! Property-style tests over the pure-rust substrates (hand-rolled
//! generators — proptest isn't in the offline vendor set; util::rng::Rng
//! drives randomized cases with fixed seeds so failures are reproducible).

use flora::data::seq2seq::{MtTask, SumTask};
use flora::metrics::{bleu_corpus, rouge_corpus, token_accuracy};
use flora::rp;
use flora::tensor::Matrix;
use flora::util::json;
use flora::util::rng::Rng;

fn rand_seq(rng: &mut Rng, max_len: usize, vocab: i32) -> Vec<i32> {
    let len = 1 + rng.next_below(max_len);
    (0..len).map(|_| rng.next_below(vocab as usize) as i32).collect()
}

// ---------------------------------------------------------------------
// metrics invariants
// ---------------------------------------------------------------------

#[test]
fn prop_rouge_bounded_and_symmetric_identity() {
    let mut rng = Rng::new(1);
    for _ in 0..200 {
        let a = rand_seq(&mut rng, 24, 50);
        let b = rand_seq(&mut rng, 24, 50);
        let s = rouge_corpus(&[(a.clone(), b.clone())]);
        for v in [s.rouge1, s.rouge2, s.rouge_l] {
            assert!((0.0..=100.0).contains(&v));
        }
        // identity scores 100 on R1/RL
        let id = rouge_corpus(&[(a.clone(), a.clone())]);
        assert!((id.rouge1 - 100.0).abs() < 1e-9);
        assert!((id.rouge_l - 100.0).abs() < 1e-9);
        // F1 is symmetric in (hyp, ref) for R1 (same clipped overlap)
        let fwd = rouge_corpus(&[(a.clone(), b.clone())]).rouge1;
        let rev = rouge_corpus(&[(b, a)]).rouge1;
        assert!((fwd - rev).abs() < 1e-9);
    }
}

#[test]
fn prop_bleu_bounded_and_maximal_on_identity() {
    let mut rng = Rng::new(2);
    for _ in 0..200 {
        let a = rand_seq(&mut rng, 24, 50);
        let b = rand_seq(&mut rng, 24, 50);
        let s = bleu_corpus(&[(a.clone(), b.clone())]).score;
        assert!((0.0..=100.0).contains(&s));
        let id = bleu_corpus(&[(a.clone(), a.clone())]).score;
        assert!(id >= s - 1e-9, "identity must not score below a mismatch");
    }
}

#[test]
fn prop_token_accuracy_bounds() {
    let mut rng = Rng::new(3);
    for _ in 0..200 {
        let a = rand_seq(&mut rng, 16, 8);
        let b = rand_seq(&mut rng, 16, 8);
        let acc = token_accuracy(&a, &b);
        assert!((0.0..=1.0).contains(&acc));
        assert_eq!(token_accuracy(&a, &a), 1.0);
    }
}

// ---------------------------------------------------------------------
// rp invariants (linearity, unbiasedness scaling)
// ---------------------------------------------------------------------

#[test]
fn prop_compress_is_linear() {
    let mut rng = Rng::new(4);
    for trial in 0..20 {
        let (n, m, r) = (
            2 + rng.next_below(16),
            2 + rng.next_below(32),
            1 + rng.next_below(8),
        );
        let g1 = Matrix::gaussian(n, m, 1.0, &mut rng);
        let g2 = Matrix::gaussian(n, m, 1.0, &mut rng);
        let a = rp::projection(trial as u64, r, m);
        let lhs = rp::compress(&(&g1 + &g2), &a);
        let rhs = &rp::compress(&g1, &a) + &rp::compress(&g2, &a);
        assert!(lhs.allclose(&rhs, 1e-4), "shape ({n},{m},{r})");
    }
}

#[test]
fn prop_compress_decompress_scales_with_rank() {
    // mean reconstruction error must be non-increasing as r doubles
    let mut rng = Rng::new(5);
    let g = Matrix::gaussian(12, 48, 1.0, &mut rng);
    let mut last = f32::INFINITY;
    for r in [2usize, 8, 32, 128, 512] {
        // average over seeds to beat sampling noise
        let mut err = 0.0f32;
        for s in 0..8 {
            let rec = rp::project_gradient(&g, 100 + s, r);
            err += (&rec - &g).frobenius_norm();
        }
        err /= 8.0;
        assert!(err <= last * 1.15, "r={r}: err {err} after {last}");
        last = err;
    }
}

#[test]
fn prop_projection_rows_near_unit_norm_scaled() {
    // A ~ N(0, 1/r): each row has expected squared norm m/r
    let mut rng = Rng::new(6);
    for _ in 0..10 {
        let r = 4 + rng.next_below(32);
        let m = 16 + rng.next_below(128);
        let a = rp::projection(rng.next_u64(), r, m);
        let want = (m as f32 / r as f32).sqrt();
        for i in 0..r {
            let norm: f32 = a.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!(
                norm > 0.3 * want && norm < 2.5 * want,
                "row {i}: norm={norm} want~{want}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// data-task invariants
// ---------------------------------------------------------------------

#[test]
fn prop_sum_task_masks_align_with_sep() {
    let t = SumTask::new(256, 64, 9);
    let mut b = flora::data::LmBatch::zeros(8, 64);
    let mut cur = 0;
    for split in 0..3u64 {
        t.fill_batch(&mut b, split, &mut cur);
        for row in 0..8 {
            let toks = b.row_tokens(row);
            let mask = &b.mask[row * 64..(row + 1) * 64];
            let sep = toks.iter().position(|&x| x == 2).unwrap();
            // nothing before/at SEP is masked-in
            assert!(mask[..=sep].iter().all(|&m| m == 0.0));
            // the masked-in span is contiguous right after SEP
            let first = mask.iter().position(|&m| m > 0.0).unwrap();
            assert_eq!(first, sep + 1);
        }
    }
}

#[test]
fn prop_mt_translate_deterministic_and_length_preserving() {
    let t = MtTask::new(256, 64, 10);
    let mut rng = Rng::new(11);
    for _ in 0..100 {
        let src: Vec<i32> =
            (0..1 + rng.next_below(20)).map(|_| 4 + rng.next_below(100) as i32).collect();
        let t1 = t.translate(&src);
        let t2 = t.translate(&src);
        assert_eq!(t1, t2);
        assert_eq!(t1.len(), src.len());
    }
}

// ---------------------------------------------------------------------
// json parser round-trip-ish fuzz
// ---------------------------------------------------------------------

#[test]
fn prop_json_never_panics_on_ascii_noise() {
    let mut rng = Rng::new(12);
    for _ in 0..500 {
        let len = rng.next_below(40);
        let doc: String = (0..len)
            .map(|_| {
                let chars = b"{}[]\",:0123456789.eE+-truefalsnl \t";
                chars[rng.next_below(chars.len())] as char
            })
            .collect();
        let _ = json::parse(&doc); // must return, never panic
    }
}

#[test]
fn prop_json_roundtrips_generated_numbers() {
    let mut rng = Rng::new(13);
    for _ in 0..200 {
        let x = (rng.next_f64() - 0.5) * 1e6;
        let doc = format!("{{\"v\": {x}}}");
        let v = json::parse(&doc).unwrap();
        let got = v.get("v").unwrap().as_f64().unwrap();
        assert!((got - x).abs() < 1e-6 * x.abs().max(1.0));
    }
}
