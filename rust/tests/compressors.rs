//! The compressor conformance harness (ISSUE 10): ONE table-driven
//! matrix that every gradient compressor — present and future — must
//! pass, covering EVERY base optimizer on both catalog families:
//!
//!   * **descent** — the smoothed head→tail loss drop clears a per-cell
//!     margin (or, for cells outside an established tuning regime, the
//!     loss stays bounded — never a silent skip, the contract is in the
//!     table);
//!   * **bit-determinism** — two identical runs produce raw-bits-equal
//!     loss curves (W=1; the dp tier's W-invariance test extends this);
//!   * **checkpoint round-trip** — train, save, train 2 more vs resume
//!     in a fresh trainer and train the same 2: bit-identical losses
//!     (method/opt state and the step counter all survive the trip);
//!   * **sublinear state bytes** — the method group is strictly smaller
//!     than the parameter group (the gradient-compression claim itself),
//!     and present exactly when the compressor keeps persistent state.
//!
//! Rows: Flora Algorithm 1 (compressed accumulation, τ>1) and
//! Algorithm 2 (momentum-in-subspace, τ=1) — retroactively covered by
//! the same assertions — plus the adaptive-rank grid's AltLoRA
//! (alternating-projection reconstruction) and AdaRank (scheduled
//! momentum subspace). Columns: sgd / adam / adafactor /
//! adafactor_nofactor. Families: lora-tiny (LM task) and vit-tiny
//! (image task, fused τ=1 steps).

use flora::config::{TaskKind, TrainConfig};
use flora::coordinator::{AccumSeeds, MethodSpec, MomentumSeeds, Trainer};
use flora::model::testutil::{assert_bits_equal, smoothed_drop};
use flora::opt::OptimizerKind;
use flora::util::rng::derive_seed;

/// One conformance cell: a compressor configuration to sweep across
/// every base optimizer on one model family.
struct Cell {
    tag: &'static str,
    method: MethodSpec,
    tau: usize,
    steps: usize,
    /// smoothed-drop margin per optimizer (same order as
    /// `OptimizerKind::ALL`); `None` = bounded contract (the loss must
    /// stay within +0.25 of its head — used for cells outside an
    /// established tuning regime, mirroring the aggressive-κ tests)
    margins: [Option<f32>; 4],
    /// lr per optimizer, same order as `OptimizerKind::ALL`
    lrs: [f32; 4],
    /// does this compressor keep persistent method-group state?
    has_method_state: bool,
}

fn lr_of(cell: &Cell, opt: OptimizerKind) -> f32 {
    let i = OptimizerKind::ALL.iter().position(|o| *o == opt).unwrap();
    cell.lrs[i]
}

fn margin_of(cell: &Cell, opt: OptimizerKind) -> Option<f32> {
    let i = OptimizerKind::ALL.iter().position(|o| *o == opt).unwrap();
    cell.margins[i]
}

/// lora-tiny rows. The Flora lrs/margins are the integration matrix's
/// proven regimes (rust/tests/integration.rs `tf_lr`); AltLoRA
/// reconstructs the cycle-mean gradient more faithfully than the fixed
/// projection, so it shares the accumulation regime; AdaRank at the
/// default fixed schedule is bit-equivalent to Flora momentum
/// (rust/src/opt/schedule.rs) and shares that regime.
fn lm_cells() -> Vec<Cell> {
    vec![
        Cell {
            tag: "flora-alg1",
            method: MethodSpec::Flora { rank: 8 },
            tau: 4,
            steps: 30,
            margins: [Some(0.02); 4],
            lrs: [0.5, 0.02, 0.1, 0.1],
            has_method_state: true,
        },
        Cell {
            tag: "flora-alg2",
            method: MethodSpec::Flora { rank: 8 },
            tau: 1,
            steps: 40,
            margins: [Some(0.01); 4],
            lrs: [1.0, 0.01, 0.05, 0.05],
            has_method_state: true,
        },
        Cell {
            tag: "altlora",
            method: MethodSpec::AltLora { rank: 8 },
            tau: 4,
            steps: 30,
            margins: [Some(0.01); 4],
            lrs: [0.5, 0.02, 0.1, 0.1],
            has_method_state: true,
        },
        Cell {
            tag: "adarank",
            method: MethodSpec::AdaRank { rank: 8 },
            tau: 1,
            steps: 40,
            margins: [Some(0.01); 4],
            lrs: [1.0, 0.01, 0.05, 0.05],
            has_method_state: true,
        },
    ]
}

/// vit-tiny rows (fused τ=1 steps). Adam/Adafactor margins follow the
/// Table-5 regimes; SGD on the ViT family has no established tuning in
/// the repo, so those cells carry the bounded contract — still fully
/// covered for determinism, checkpointing and state bytes.
fn vit_cells() -> Vec<Cell> {
    let margins = [None, Some(0.01), Some(0.005), Some(0.005)];
    let lrs = [0.1, 0.01, 0.02, 0.02];
    vec![
        Cell {
            tag: "flora-alg2",
            method: MethodSpec::Flora { rank: 8 },
            tau: 1,
            steps: 24,
            margins,
            lrs,
            has_method_state: true,
        },
        Cell {
            tag: "altlora",
            method: MethodSpec::AltLora { rank: 8 },
            tau: 1,
            steps: 24,
            margins,
            lrs,
            // the fused ViT AltLoRA step re-derives its sketches from
            // the step seed — no persistent method state at all
            has_method_state: false,
        },
        Cell {
            tag: "adarank",
            method: MethodSpec::AdaRank { rank: 8 },
            tau: 1,
            steps: 24,
            margins,
            lrs,
            has_method_state: true,
        },
    ]
}

fn cell_cfg(model: &str, task: TaskKind, cell: &Cell, opt: OptimizerKind) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        task,
        method: cell.method,
        optimizer: opt,
        lr: lr_of(cell, opt),
        steps: cell.steps,
        tau: cell.tau,
        kappa: 1000, // the paper's regime; aggressive-κ is covered elsewhere
        batch: 4,
        seed: 0,
        eval_every: 0,
        eval_samples: 8,
        ..Default::default()
    }
}

/// The four conformance assertions for one (model, cell, optimizer).
fn conformance(model: &str, task: TaskKind, cell: &Cell, opt: OptimizerKind) {
    let label = format!("{model}/{}/{opt}", cell.tag);
    let cfg = cell_cfg(model, task, cell, opt);

    // 1+2: descent and bit-determinism over two identical full runs
    let run = || {
        let mut tr = Trainer::native(cfg.clone()).unwrap();
        tr.run().unwrap()
    };
    let report = run();
    let losses = &report.train_losses;
    assert!(
        losses.iter().all(|l| l.is_finite()),
        "{label}: non-finite loss in {losses:?}"
    );
    assert_bits_equal(&label, losses, &run().train_losses);
    let (head, drop) = smoothed_drop(losses, 5);
    match margin_of(cell, opt) {
        Some(margin) => assert!(
            drop > margin,
            "{label}: no descent (smoothed drop {drop}, want > {margin})"
        ),
        None => assert!(
            drop > -0.25,
            "{label}: loss blew up (head {head}, smoothed drop {drop})"
        ),
    }

    // 3: sublinear method-state bytes
    let bytes = |group: &str| {
        report
            .state_bytes
            .iter()
            .find(|(g, _)| g == group)
            .map(|(_, b)| *b)
            .unwrap_or(0)
    };
    let (method_b, params_b) = (bytes("method"), bytes("params"));
    assert!(params_b > 0, "{label}: empty params group");
    assert!(
        method_b < params_b,
        "{label}: method state {method_b} not sublinear vs params {params_b}"
    );
    if cell.has_method_state {
        assert!(method_b > 0, "{label}: compressor kept no method state");
    } else {
        assert_eq!(method_b, 0, "{label}: unexpected persistent method state");
    }

    // 4: checkpoint round-trip — 3 steps, save, 2 more vs resume + 2.
    // The external seed schedules mirror Trainer::run's construction and
    // are advanced to the checkpoint step on both sides.
    let mut short = cfg.clone();
    short.steps = 3;
    let path = std::env::temp_dir().join(format!(
        "flora_conformance_{}_{}_{}.bin",
        model, cell.tag, opt
    ));
    let path_s = path.to_str().unwrap();
    let schedules = |done: usize| {
        let mut accum = AccumSeeds::new(derive_seed(short.seed, 0xACC));
        let mut mom =
            MomentumSeeds::new(derive_seed(short.seed, 0xE3A), short.kappa);
        for _ in 0..done {
            accum.advance();
            mom.tick();
        }
        (accum, mom)
    };
    let mut t1 = Trainer::native(short.clone()).unwrap();
    t1.run().unwrap();
    t1.save_checkpoint(path_s).unwrap();
    let (mut accum, mut mom) = schedules(t1.steps_done());
    let cont: Vec<f32> = (0..2)
        .map(|_| t1.train_step(&mut accum, &mut mom).unwrap())
        .collect();
    let mut t2 = Trainer::native(short).unwrap();
    t2.resume_from(path_s).unwrap();
    assert_eq!(t2.steps_done(), 3, "{label}: step counter lost in transit");
    let (mut accum2, mut mom2) = schedules(t2.steps_done());
    let resumed: Vec<f32> = (0..2)
        .map(|_| t2.train_step(&mut accum2, &mut mom2).unwrap())
        .collect();
    assert_bits_equal(&format!("{label}: checkpoint resume"), &cont, &resumed);
    std::fs::remove_file(&path).ok();
}

// One test per (family, compressor) row so the matrix parallelizes
// under the default cargo-test scheduler and a failure names its row.

fn lm_row(tag: &str) {
    let cell = lm_cells().into_iter().find(|c| c.tag == tag).unwrap();
    for opt in OptimizerKind::ALL {
        conformance("lora-tiny", TaskKind::Lm, &cell, opt);
    }
}

fn vit_row(tag: &str) {
    let cell = vit_cells().into_iter().find(|c| c.tag == tag).unwrap();
    for opt in OptimizerKind::ALL {
        conformance("vit-tiny", TaskKind::Vit, &cell, opt);
    }
}

#[test]
fn conformance_lm_flora_alg1() {
    lm_row("flora-alg1");
}

#[test]
fn conformance_lm_flora_alg2() {
    lm_row("flora-alg2");
}

#[test]
fn conformance_lm_altlora() {
    lm_row("altlora");
}

#[test]
fn conformance_lm_adarank() {
    lm_row("adarank");
}

#[test]
fn conformance_vit_flora_alg2() {
    vit_row("flora-alg2");
}

#[test]
fn conformance_vit_altlora() {
    vit_row("altlora");
}

#[test]
fn conformance_vit_adarank() {
    vit_row("adarank");
}

/// AdaRank under the default fixed schedule IS Flora Algorithm 2: the
/// two loss curves must match in raw bits across every base optimizer
/// (the exec-level twin of the `ScheduledFlora` unit equivalence).
#[test]
fn conformance_adarank_fixed_schedule_bit_matches_flora_momentum() {
    for opt in OptimizerKind::ALL {
        let run = |method: MethodSpec| {
            let cell = Cell {
                tag: "equiv",
                method,
                tau: 1,
                steps: 8,
                margins: [None; 4],
                lrs: [1.0, 0.01, 0.05, 0.05],
                has_method_state: true,
            };
            let cfg = cell_cfg("lora-tiny", TaskKind::Lm, &cell, opt);
            let mut tr = Trainer::native(cfg).unwrap();
            tr.run().unwrap().train_losses
        };
        let flora = run(MethodSpec::Flora { rank: 8 });
        let ada = run(MethodSpec::AdaRank { rank: 8 });
        assert_bits_equal(&format!("adarank-vs-flora/{opt}"), &flora, &ada);
    }
}
