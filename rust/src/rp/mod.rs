//! Rust reference implementation of FLORA's random-projection operations.
//!
//! Mirrors `python/compile/kernels/rp.py` (compress / decompress / transfer /
//! seeded projection) so the *algorithm* can be validated and benchmarked
//! without the XLA runtime, and powers the Figure-1 pilot's RP/RRP updaters.
//! Distributional — not bitwise — parity with the JAX side: the projection
//! entries come from this crate's RNG, N(0, 1/r), exactly the Algorithm-1/2
//! sampling law.

use crate::tensor::Matrix;
use crate::util::rng::{derive_seed, Rng};

/// Generate the projection matrix A ∈ R^{r×m}, entries N(0, 1/r), from a
/// seed — the paper's "store the seed, regenerate the matrix" trick.
pub fn projection(seed: u64, r: usize, m: usize) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::gaussian(r, m, (1.0 / r as f32).sqrt(), &mut rng)
}

/// Per-parameter independent seed (same role as flora.derive_seed).
pub fn param_seed(base: u64, index: usize) -> u64 {
    derive_seed(base, index as u64)
}

/// The first `r_active` rows of the rank-`r_master` projection for this
/// seed, at the MASTER sampling law N(0, 1/r_master). Because
/// [`Matrix::gaussian`] draws row-major from one sequential stream,
/// `projection_sub(seed, ra, r0, m)` is a bit-exact prefix of
/// `projection_sub(seed, r0, r0, m)` — the property adaptive-rank
/// truncation (opt::schedule) relies on. `projection_sub(s, r, r, m)`
/// equals `projection(s, r, m)`.
pub fn projection_sub(seed: u64, r_active: usize, r_master: usize, m: usize) -> Matrix {
    debug_assert!(r_active <= r_master);
    let mut rng = Rng::new(seed);
    Matrix::gaussian(r_active, m, (1.0 / r_master.max(1) as f32).sqrt(), &mut rng)
}

/// Down-project a gradient: C = G Aᵀ ([n,m] → [n,r]).
pub fn compress(g: &Matrix, a: &Matrix) -> Matrix {
    g.matmul_nt(a)
}

/// Fused accumulate: C += G Aᵀ (Algorithm 1 line 9).
pub fn compress_accumulate(c: &mut Matrix, g: &Matrix, a: &Matrix) {
    let delta = g.matmul_nt(a);
    c.add_scaled_inplace(&delta, 1.0);
}

/// Up-project: Ĝ = C A ([n,r] → [n,m]).
pub fn decompress(c: &Matrix, a: &Matrix) -> Matrix {
    c.matmul(a)
}

/// Subspace hand-off for EMA state: M' = M A_old A_newᵀ (Algorithm 2 l.13).
pub fn transfer(m: &Matrix, a_old: &Matrix, a_new: &Matrix) -> Matrix {
    compress(&decompress(m, a_old), a_new)
}

/// One full compress→decompress round trip with a fresh seed: the RP update
/// of Eq. (20), used by the pilot study.
pub fn project_gradient(g: &Matrix, seed: u64, r: usize) -> Matrix {
    let a = projection(seed, r, g.cols);
    decompress(&compress(g, &a), &a)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randn(seed: u64, n: usize, m: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::gaussian(n, m, 1.0, &mut rng)
    }

    #[test]
    fn projection_deterministic() {
        let a = projection(42, 8, 32);
        let b = projection(42, 8, 32);
        assert!(a.allclose(&b, 0.0));
        let c = projection(43, 8, 32);
        assert!(!a.allclose(&c, 1e-3));
    }

    #[test]
    fn projection_scale_theorem_2_4() {
        // E[AᵀA] = I with elementwise deviation shrinking in r
        let m = 12;
        let mut devs = Vec::new();
        for r in [32usize, 512] {
            let a = projection(7, r, m);
            let ata = a.matmul_tn(&a);
            let mut dev = 0.0f32;
            for i in 0..m {
                for j in 0..m {
                    let want = if i == j { 1.0 } else { 0.0 };
                    dev = dev.max((ata.at(i, j) - want).abs());
                }
            }
            devs.push(dev);
        }
        assert!(devs[1] < devs[0], "{devs:?}");
        assert!(devs[1] < 0.2, "{devs:?}");
    }

    #[test]
    fn jl_norm_preservation() {
        // Lemma 2.3: row norms approximately preserved by compression
        let g = randn(0, 32, 128);
        let a = projection(1, 64, 128);
        let c = compress(&g, &a);
        for i in 0..g.rows {
            let ng: f32 = g.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            let nc: f32 = c.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            let ratio = nc / ng;
            assert!(ratio > 0.55 && ratio < 1.45, "row {i}: {ratio}");
        }
    }

    #[test]
    fn compress_accumulate_matches_separate_ops() {
        let g1 = randn(2, 8, 24);
        let g2 = randn(3, 8, 24);
        let a = projection(4, 4, 24);
        let mut c = Matrix::zeros(8, 4);
        compress_accumulate(&mut c, &g1, &a);
        compress_accumulate(&mut c, &g2, &a);
        let want = &compress(&g1, &a) + &compress(&g2, &a);
        assert!(c.allclose(&want, 1e-5));
    }

    #[test]
    fn decompression_unbiased_over_seeds() {
        // E_A[G AᵀA] = G: average reconstruction over many seeds converges
        let g = randn(5, 6, 10);
        let mut acc = Matrix::zeros(6, 10);
        let trials = 300;
        for s in 0..trials {
            let rec = project_gradient(&g, 1000 + s, 64);
            acc.add_scaled_inplace(&rec, 1.0 / trials as f32);
        }
        let err = (&acc - &g).max_abs();
        assert!(err < 0.25, "err={err}");
    }

    #[test]
    fn transfer_preserves_energy_roughly() {
        let m_state = randn(6, 64, 64); // n=64 x r=64 compressed state
        let a_old = projection(8, 64, 64);
        let a_new = projection(9, 64, 64);
        let moved = transfer(&m_state, &a_old, &a_new);
        let ratio = moved.frobenius_norm() / m_state.frobenius_norm();
        assert!(ratio > 0.5 && ratio < 2.2, "ratio={ratio}");
    }

    #[test]
    fn projection_sub_is_bit_exact_prefix_of_master() {
        // adaptive-rank truncation depends on this: the rank-ra projection
        // IS the first ra rows of the rank-r0 projection, bit for bit
        let full = projection_sub(31, 16, 16, 24);
        for ra in [1usize, 4, 9, 16] {
            let sub = projection_sub(31, ra, 16, 24);
            assert_eq!(sub.shape(), (ra, 24));
            for i in 0..ra {
                for j in 0..24 {
                    assert_eq!(
                        sub.at(i, j).to_bits(),
                        full.at(i, j).to_bits(),
                        "ra={ra} ({i},{j})"
                    );
                }
            }
        }
        // and at ra == r0 it is exactly the Algorithm-1/2 projection
        let a = projection(31, 16, 24);
        assert!(full.allclose(&a, 0.0));
    }

    #[test]
    fn param_seeds_distinct() {
        let mut set = std::collections::HashSet::new();
        for i in 0..256 {
            set.insert(param_seed(99, i));
        }
        assert_eq!(set.len(), 256);
    }

    #[test]
    fn rank_controls_reconstruction_error() {
        let g = randn(10, 16, 64);
        let e_small = (&project_gradient(&g, 11, 4) - &g).frobenius_norm();
        let e_large = (&project_gradient(&g, 11, 256) - &g).frobenius_norm();
        assert!(e_large < e_small, "{e_small} vs {e_large}");
    }

    #[test]
    fn projection_deterministic_across_rank_grid() {
        // the "store the seed, regenerate the matrix" trick requires exact
        // reproducibility at every rank the catalog uses
        for r in [4usize, 16, 64] {
            let a = projection(1234, r, 96);
            let b = projection(1234, r, 96);
            assert!(a.allclose(&b, 0.0), "r={r}");
            assert_eq!(a.shape(), (r, 96));
        }
    }

    #[test]
    fn roundtrip_error_within_jl_envelope() {
        // compress→decompress relative error concentrates near sqrt(m/r)
        // (JL-style bound); assert a 3x envelope and monotone decrease in r
        let g = randn(21, 16, 64);
        let gn = g.frobenius_norm();
        let mut last = f32::INFINITY;
        for r in [4usize, 16, 64] {
            let trials = 10u64;
            let mut err = 0.0f32;
            for s in 0..trials {
                err += (&project_gradient(&g, 500 + s, r) - &g)
                    .frobenius_norm();
            }
            let rel = err / trials as f32 / gn;
            let envelope = 3.0 * (64.0f32 / r as f32).sqrt();
            assert!(rel < envelope, "r={r}: rel err {rel} vs {envelope}");
            assert!(rel < last * 1.05, "r={r}: {rel} after {last}");
            last = rel;
        }
    }

    #[test]
    fn accumulate_equals_sum_of_compressions() {
        // Algorithm 1's fused accumulate must be EXACTLY the sum of the
        // per-micro-batch compressions (linearity is what makes the
        // shared-seed cycle correct)
        let a = projection(77, 16, 40);
        let mut c = Matrix::zeros(12, 16);
        let mut want = Matrix::zeros(12, 16);
        for k in 0..5u64 {
            let g = randn(100 + k, 12, 40);
            compress_accumulate(&mut c, &g, &a);
            want.add_scaled_inplace(&compress(&g, &a), 1.0);
        }
        assert!(c.allclose(&want, 1e-4));
    }
}
