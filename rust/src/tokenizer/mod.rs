//! Synthetic-vocabulary tokenizer.
//!
//! The data substrate works in token ids; this module gives those ids a
//! human-readable surface form (CV-syllable pseudo-words) so the examples
//! can print model inputs/outputs, and provides encode/decode round-trips
//! used by the CLI's inspection commands. It deliberately mirrors a real
//! tokenizer's API (encode / decode / vocab_size / specials).

use crate::data::special;

#[derive(Clone)]
pub struct Tokenizer {
    vocab: Vec<String>,
    lookup: std::collections::HashMap<String, i32>,
}

const ONSETS: [&str; 14] =
    ["k", "s", "t", "n", "h", "m", "r", "g", "z", "d", "b", "p", "v", "l"];
const NUCLEI: [&str; 5] = ["a", "e", "i", "o", "u"];

impl Tokenizer {
    /// Deterministic vocabulary of `size` entries: ids 0..4 are the shared
    /// specials, the rest are distinct pseudo-words ("ka", "kela", ...).
    pub fn new(size: usize) -> Self {
        assert!(size > special::CONTENT0 as usize);
        let mut vocab = vec![
            "<pad>".to_string(),
            "<bos>".to_string(),
            "<sep>".to_string(),
            "<eos>".to_string(),
        ];
        let mut n = 0usize;
        'outer: loop {
            // 1-syllable words first, then 2-syllable, then 3
            let syllables = n / (ONSETS.len() * NUCLEI.len()) + 1;
            let mut idx = n;
            let mut w = String::new();
            for _ in 0..syllables {
                w.push_str(ONSETS[idx % ONSETS.len()]);
                idx /= ONSETS.len();
                w.push_str(NUCLEI[idx % NUCLEI.len()]);
                idx /= NUCLEI.len();
            }
            if !vocab.contains(&w) {
                vocab.push(w);
                if vocab.len() == size {
                    break 'outer;
                }
            }
            n += 1;
        }
        let lookup = vocab
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as i32))
            .collect();
        Self { vocab, lookup }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Token id -> surface form.
    pub fn word(&self, id: i32) -> &str {
        self.vocab
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("<unk>")
    }

    /// Render a token sequence, eliding padding.
    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter(|&&id| id != special::PAD)
            .map(|&id| self.word(id))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Whitespace-split encode; unknown words error.
    pub fn encode(&self, text: &str) -> Result<Vec<i32>, String> {
        text.split_whitespace()
            .map(|w| {
                self.lookup
                    .get(w)
                    .copied()
                    .ok_or_else(|| format!("unknown word {w:?}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_is_exactly_requested_size_and_unique() {
        for size in [16usize, 64, 256, 512] {
            let t = Tokenizer::new(size);
            assert_eq!(t.vocab_size(), size);
            let set: std::collections::HashSet<_> = t.vocab.iter().collect();
            assert_eq!(set.len(), size, "duplicates at size {size}");
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = Tokenizer::new(256);
        let ids = vec![1, 10, 42, 200, 3];
        let text = t.decode(&ids);
        let back = t.encode(&text).unwrap();
        assert_eq!(ids, back);
    }

    #[test]
    fn decode_elides_padding() {
        let t = Tokenizer::new(64);
        let s = t.decode(&[1, 5, 0, 0, 0]);
        assert!(!s.contains("<pad>"));
        assert!(s.starts_with("<bos>"));
    }

    #[test]
    fn specials_fixed() {
        let t = Tokenizer::new(64);
        assert_eq!(t.word(special::PAD), "<pad>");
        assert_eq!(t.word(special::BOS), "<bos>");
        assert_eq!(t.word(special::SEP), "<sep>");
        assert_eq!(t.word(special::EOS), "<eos>");
    }

    #[test]
    fn unknown_word_errors() {
        let t = Tokenizer::new(64);
        assert!(t.encode("definitely_not_a_word").is_err());
    }

    #[test]
    fn deterministic() {
        let a = Tokenizer::new(128);
        let b = Tokenizer::new(128);
        assert_eq!(a.vocab, b.vocab);
    }
}
