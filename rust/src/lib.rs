//! # flora — FLORA: Low-Rank Adapters Are Secretly Gradient Compressors
//!
//! Full-system reproduction of Hao, Cao & Mou (ICML 2024) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: Algorithm-1 τ-cycle
//!   accumulation scheduling, Algorithm-2 κ-interval momentum resampling,
//!   seed lifecycles, training/eval loops, metrics, the analytic memory
//!   accountant behind every Mem/ΔM column, and the pure-rust pilot study.
//!   The optimizer math itself lives in [`opt`]: a [`opt::BaseOptimizer`]
//!   trait with SGD/Adam/Adafactor implementations plus the
//!   [`opt::FloraCompressor`] that composes any of them with the seeded
//!   random-projection algebra in [`rp`].
//! * **L2** — JAX models + optimizers + methods (python/compile/*),
//!   AOT-lowered once to HLO text.
//! * **L1** — Pallas kernels for the compress/decompress/transfer hot path
//!   (python/compile/kernels/rp.py).
//!
//! Python never runs at inference/training time. The coordinator drives
//! executables through the `runtime::Backend` boundary over
//! backend-neutral tensors: the default build ships the pure-rust
//! **native** backend (generated catalog covering the bigram LMs AND the
//! [`model`] transformer size grids — causal LMs with LoRA adapters plus
//! ViTs, all with manual backward passes on the cache-blocked,
//! optionally row-parallel GEMM kernels in [`tensor`]
//! ([`tensor::Parallelism`] over a persistent worker pool; bit-identical
//! at every thread count — docs/PERFORMANCE.md is the tuning guide) — so
//! it builds and tests on a bare machine, zero dependencies), and the
//! original PJRT path that loads the AOT artifacts lives behind the
//! `xla` cargo feature.
//!
//! See README.md for the backend matrix, DESIGN.md for the system
//! inventory and EXPERIMENTS.md for the paper-vs-measured record.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod doctor;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod opt;
pub mod pilot;
pub mod rp;
pub mod runtime;
pub mod tensor;
pub mod tokenizer;
pub mod util;
