//! The training orchestrator: drives the AOT executables through the
//! paper's state machines.
//!
//! Modes (derived from config — see `Mode::of`):
//!   * `Plain`        — method None: fused grad+optimizer step per batch.
//!   * `Accumulation` — Algorithm 1: τ micro-steps share one projection
//!     seed, then decompress + base-optimizer update, zero the accumulator,
//!     resample (AccumSeeds).
//!   * `Momentum`     — Algorithm 2: fused step each batch; the κ-interval
//!     seed rotation + transfer flag comes from MomentumSeeds.
//!   * `Galore`       — GaLore baseline: fused Adam-in-subspace step with a
//!     κ-interval projection refresh.
//!   * `VitStep`      — Table-5 image runs (plain or flora-momentum).
//!
//! The trainer never interprets tensor *contents* — it moves typed state
//! groups between executables per the manifest ABI, with every input and
//! output routed BY NAME through `runtime::{Route, StepIo, StepOutputs}`
//! (no positional `outs[i]` indexing, no stringly-typed group tags), so it
//! is backend-agnostic: the same state machines drive the native pure-rust
//! executor and the PJRT/XLA artifacts.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use super::method::MethodSpec;
use super::report::{MetricValue, RunReport};
use super::seeds::{AccumSeeds, MomentumSeeds};
use super::task::{Task, TEST, TRAIN, VAL};
use crate::config::{TaskKind, TrainConfig};
use crate::metrics;
use crate::runtime::{
    scalar_f32, scalar_i32, scalar_u32, tensor_i32, Executable, Route,
    Runtime, ScalarKey, StateGroup, StateStore, StepIo, StepOutputs,
    TensorSpec,
};
use crate::util::rng::derive_seed;
use crate::util::timing::Timer;
use crate::{debug, info};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Mode {
    Plain,
    Accumulation,
    Momentum,
    Galore,
    VitStep,
}

impl Mode {
    fn of(cfg: &TrainConfig) -> Mode {
        if cfg.task == TaskKind::Vit {
            return Mode::VitStep;
        }
        match cfg.method {
            MethodSpec::Galore { .. } => Mode::Galore,
            MethodSpec::None => Mode::Plain,
            // the compressor grid pins its mode regardless of tau:
            // AltLoRA only has the dual-sketch accumulate/apply algebra
            // (τ=1 is a one-micro cycle), AdaRank only the ranked
            // momentum subspace.
            MethodSpec::AltLora { .. } => Mode::Accumulation,
            MethodSpec::AdaRank { .. } => Mode::Momentum,
            _ => {
                if cfg.tau > 1 {
                    Mode::Accumulation
                } else {
                    Mode::Momentum
                }
            }
        }
    }
}

pub struct Trainer {
    pub cfg: TrainConfig,
    /// shared so a bench harness can reuse one PJRT client + compile cache
    /// across its whole run grid (EXPERIMENTS.md §Perf: ~15s saved per run)
    pub rt: Rc<RefCell<Runtime>>,
    pub task: Task,
    state: StateStore,
    mode: Mode,
    cursor: u64,
    step: usize,
    last_loss: f32,
}

impl Trainer {
    /// Build a trainer over a backend spec: `"native"` selects the
    /// pure-rust executor; anything else is an artifacts directory for the
    /// PJRT backend (`xla` feature).
    pub fn new(cfg: TrainConfig, backend_spec: &str) -> Result<Self, String> {
        let rt = Rc::new(RefCell::new(Runtime::from_spec(backend_spec)?));
        Self::with_runtime(cfg, rt)
    }

    /// Trainer over the native backend: no artifacts, no XLA.
    pub fn native(cfg: TrainConfig) -> Result<Self, String> {
        Self::new(cfg, "native")
    }

    /// Build a trainer over an existing runtime, sharing its PJRT client
    /// and executable cache (the bench harness runs 10+ cells per table;
    /// recompiling per cell would dominate wallclock).
    pub fn with_runtime(
        cfg: TrainConfig,
        rt: Rc<RefCell<Runtime>>,
    ) -> Result<Self, String> {
        // every construction path funnels here, so the config's kernel
        // thread budget always takes effect — no launcher has to remember
        // to install it. install() also (eagerly) starts or grows the
        // persistent kernel worker pool, so thread spawn happens at
        // trainer construction, never inside a timed step, and repeated
        // trainer lifecycles in one process reuse the same warm pool
        // (grow-only resize — see tensor::Parallelism::install). Safe as
        // a process-wide side effect: results are bit-identical at every
        // setting and for either driver (tensor::Parallelism).
        cfg.parallelism.install();
        let (model, ledger) = {
            let rt = rt.borrow();
            (rt.manifest.model(&cfg.model)?.clone(), rt.ledger.clone())
        };
        let task = Task::new(cfg.task, &model, derive_seed(cfg.seed, 0xDA7A))?;
        let mode = Mode::of(&cfg);
        // fail fast if the catalog lacks this combination
        let _ = Self::main_exe_name(&cfg, mode)?;
        Ok(Self {
            cfg,
            rt,
            task,
            state: StateStore::new(Some(ledger)),
            mode,
            cursor: 0,
            step: 0,
            last_loss: f32::NAN,
        })
    }

    fn main_exe_name(cfg: &TrainConfig, mode: Mode) -> Result<String, String> {
        let m = &cfg.model;
        let opt = cfg.optimizer;
        let missing = |what: &str| {
            format!("method {:?} has no {what} executable", cfg.method)
        };
        Ok(match mode {
            Mode::Plain => MethodSpec::plain_step_exe(m, opt),
            Mode::Accumulation => {
                cfg.method.micro_exe(m).ok_or_else(|| missing("micro"))?
            }
            Mode::Momentum => cfg
                .method
                .momentum_exe(m, opt)
                .ok_or_else(|| missing("momentum"))?,
            Mode::Galore => {
                cfg.method.galore_exe(m).ok_or_else(|| missing("galore"))?
            }
            Mode::VitStep => cfg.method.vit_step_exe(m, opt),
        })
    }

    // ------------------------------------------------------------------
    // initialization
    // ------------------------------------------------------------------

    /// Initialize params + all state groups declared by the mode's execs.
    pub fn init(&mut self) -> Result<(), String> {
        // params from the seeded init executable
        let init = self
            .rt
            .borrow_mut()
            .load(&self.cfg.method.init_exe(&self.cfg.model))?;
        let outs = init.run(&[scalar_u32(self.cfg.seed as u32)])?;
        self.state.put(StateGroup::Params, init.info.outputs.clone(), outs);

        if let Some(name) = self.cfg.method.lora_init_exe(&self.cfg.model) {
            let lora_init = self.rt.borrow_mut().load(&name)?;
            let mut inputs = self.state.collect(&[StateGroup::Params])?;
            inputs.push(scalar_u32(derive_seed(self.cfg.seed, 1) as u32));
            let outs = lora_init.run(&inputs)?;
            self.state
                .put(StateGroup::Train, lora_init.info.outputs.clone(), outs);
        }

        // opt + method-state zeros, shapes from the mode's executables
        let mut opt_specs: Vec<TensorSpec> = Vec::new();
        let mut method_specs: Vec<TensorSpec> = Vec::new();
        let mut exes = vec![Self::main_exe_name(&self.cfg, self.mode)?];
        if self.mode == Mode::Accumulation {
            if let Some(u) = self
                .cfg
                .method
                .update_exe(&self.cfg.model, self.cfg.optimizer)
            {
                exes.push(u);
            }
        }
        for name in exes {
            let e = self.rt.borrow_mut().load(&name)?;
            for t in &e.info.inputs {
                let route = Route::of(&t.name)
                    .map_err(|err| format!("{name}: {err}"))?;
                match route {
                    Route::State(StateGroup::Opt)
                        if !opt_specs.iter().any(|s| s.name == t.name) =>
                    {
                        opt_specs.push(t.clone())
                    }
                    Route::State(StateGroup::Method)
                        if !method_specs.iter().any(|s| s.name == t.name) =>
                    {
                        method_specs.push(t.clone())
                    }
                    _ => {}
                }
            }
        }
        if !opt_specs.is_empty() {
            self.state.put_zeros(StateGroup::Opt, opt_specs)?;
        }
        if !method_specs.is_empty() {
            self.state.put_zeros(StateGroup::Method, method_specs)?;
        }
        debug!(
            "state initialized: {} bytes total",
            self.state.total_bytes()
        );
        Ok(())
    }

    // ------------------------------------------------------------------
    // ABI plumbing
    // ------------------------------------------------------------------

    /// Run an executable on a `StepIo` and route outputs back into state
    /// groups by name. Returns the loss if the executable produces one.
    fn run_step(
        &mut self,
        exe: &Executable,
        io: &StepIo,
    ) -> Result<Option<f32>, String> {
        let inputs = io.inputs_for(&exe.info, &self.state)?;
        let outs = StepOutputs::of(&exe.info, exe.run(&inputs)?)?;
        let loss = outs.loss()?;
        outs.absorb_into(&mut self.state)?;
        Ok(loss)
    }

    // ------------------------------------------------------------------
    // training
    // ------------------------------------------------------------------

    /// Run one optimizer step (which is τ micro-batches in accumulation
    /// mode). Returns the training loss of the last batch consumed.
    pub fn train_step(
        &mut self,
        accum_seeds: &mut AccumSeeds,
        mom_seeds: &mut MomentumSeeds,
    ) -> Result<f32, String> {
        let lr = self.cfg.lr;
        let step = self.step;
        let mut loss = f32::NAN;
        match self.mode {
            Mode::Plain => {
                let exe = self
                    .rt
                    .borrow_mut()
                    .load(&Self::main_exe_name(&self.cfg, self.mode)?)?;
                let batch =
                    self.task.next_batch(self.cfg.batch, TRAIN, &mut self.cursor)?;
                let io = StepIo::new().lr_step(lr, step).batch(batch);
                loss = self
                    .run_step(&exe, &io)?
                    .ok_or("plain step produced no loss")?;
            }
            Mode::Accumulation => {
                let micro = self
                    .rt
                    .borrow_mut()
                    .load(&Self::main_exe_name(&self.cfg, self.mode)?)?;
                let seed = accum_seeds.current();
                for _ in 0..self.cfg.tau {
                    let batch = self.task.next_batch(
                        self.cfg.batch,
                        TRAIN,
                        &mut self.cursor,
                    )?;
                    let io = StepIo::new().seed(seed).batch(batch);
                    loss = self
                        .run_step(&micro, &io)?
                        .ok_or("micro step produced no loss")?;
                }
                let update_name = self
                    .cfg
                    .method
                    .update_exe(&self.cfg.model, self.cfg.optimizer)
                    .ok_or("accumulation mode without update exe")?;
                let update = self.rt.borrow_mut().load(&update_name)?;
                let io = StepIo::new()
                    .lr_step(lr, step)
                    .seed(seed)
                    .scalar(ScalarKey::Tau, scalar_f32(self.cfg.tau as f32));
                self.run_step(&update, &io)?;
                // end of cycle: zero the accumulator, resample (Alg. 1)
                self.state.zero(StateGroup::Method)?;
                accum_seeds.advance();
            }
            Mode::Momentum | Mode::VitStep => {
                let exe = self
                    .rt
                    .borrow_mut()
                    .load(&Self::main_exe_name(&self.cfg, self.mode)?)?;
                let batch =
                    self.task.next_batch(self.cfg.batch, TRAIN, &mut self.cursor)?;
                let mut io = StepIo::new().lr_step(lr, step).batch(batch);
                // flora/naive momentum steps consume the seed trio; plain
                // vit-adam steps don't — provide only what the ABI wants
                if StepIo::wants(&exe.info, ScalarKey::SeedCur) {
                    let tick = mom_seeds.tick();
                    io = io
                        .scalar(ScalarKey::SeedCur, scalar_u32(tick.seed_cur))
                        .scalar(ScalarKey::SeedNext, scalar_u32(tick.seed_next))
                        .scalar(ScalarKey::Resample, scalar_f32(tick.resample));
                }
                // adarank steps additionally consume the scheduled active
                // ranks: rank_cur is the rank the momentum lived at going
                // into this step, rank_next the schedule's rank for the
                // cycle this step lands in (they differ exactly on
                // shrinking resample boundaries).
                if StepIo::wants(&exe.info, ScalarKey::RankCur) {
                    let r0 = self.cfg.method.rank().unwrap_or(0);
                    let kappa = self.cfg.kappa.max(1);
                    let sched = self.cfg.rank_schedule;
                    let cur = sched.rank_at(r0, step.saturating_sub(1) / kappa);
                    let next = sched.rank_at(r0, step / kappa);
                    io = io
                        .scalar(ScalarKey::RankCur, scalar_f32(cur as f32))
                        .scalar(ScalarKey::RankNext, scalar_f32(next as f32));
                }
                loss = self
                    .run_step(&exe, &io)?
                    .ok_or("momentum step produced no loss")?;
            }
            Mode::Galore => {
                let exe = self
                    .rt
                    .borrow_mut()
                    .load(&Self::main_exe_name(&self.cfg, self.mode)?)?;
                let batch =
                    self.task.next_batch(self.cfg.batch, TRAIN, &mut self.cursor)?;
                let refresh = step % self.cfg.kappa == 0;
                let interval = (step / self.cfg.kappa) as u64;
                let io = StepIo::new()
                    .lr_step(lr, step)
                    .seed(derive_seed(self.cfg.seed, interval) as u32)
                    .scalar(
                        ScalarKey::Refresh,
                        scalar_f32(if refresh { 1.0 } else { 0.0 }),
                    )
                    .batch(batch);
                loss = self
                    .run_step(&exe, &io)?
                    .ok_or("galore step produced no loss")?;
            }
        }
        self.step += 1;
        self.last_loss = loss;
        Ok(loss)
    }

    // ------------------------------------------------------------------
    // evaluation
    // ------------------------------------------------------------------

    /// Mean eval loss over `n_batches` from a data split.
    pub fn eval_loss(&mut self, split: u64, n_batches: usize) -> Result<f32, String> {
        let exe = self
            .rt
            .borrow_mut()
            .load(&self.cfg.method.eval_exe(&self.cfg.model))?;
        let mut cursor = 0u64;
        let mut total = 0.0f32;
        for _ in 0..n_batches {
            let batch = self.task.next_batch(self.cfg.batch, split, &mut cursor)?;
            let io = StepIo::new().batch(batch);
            let inputs = io.inputs_for(&exe.info, &self.state)?;
            let outs = StepOutputs::of(&exe.info, exe.run(&inputs)?)?;
            total += outs
                .named("loss")?
                .first_f32()
                .map_err(|e| format!("eval loss: {e}"))?;
        }
        Ok(total / n_batches as f32)
    }

    /// Greedy-decode generation metric on the test split (ROUGE or BLEU for
    /// the sequence tasks, accuracy for ViT, perplexity for LM).
    pub fn eval_metric(&mut self, n_samples: usize) -> Result<MetricValue, String> {
        match self.task.kind() {
            TaskKind::Lm => {
                let loss =
                    self.eval_loss(TEST, (n_samples / self.cfg.batch).max(1))?;
                Ok(MetricValue::Perplexity(metrics::perplexity(loss as f64)))
            }
            TaskKind::Vit => self.eval_vit_accuracy(n_samples),
            TaskKind::Sum | TaskKind::Mt => self.eval_generation(n_samples),
        }
    }

    fn eval_vit_accuracy(&mut self, n_samples: usize) -> Result<MetricValue, String> {
        let exe = self
            .rt
            .borrow_mut()
            .load(&self.cfg.method.eval_exe(&self.cfg.model))?;
        let mut cursor = 0u64;
        let mut hits = 0usize;
        let mut total = 0usize;
        for _ in 0..(n_samples / self.cfg.batch).max(1) {
            let batch = self.task.next_batch(self.cfg.batch, TEST, &mut cursor)?;
            let labels = batch
                .get("batch/labels")
                .ok_or("vit eval batch missing batch/labels")?
                .to_i32_vec()
                .map_err(|e| format!("labels: {e}"))?;
            let io = StepIo::new().batch(batch);
            let inputs = io.inputs_for(&exe.info, &self.state)?;
            let outs = StepOutputs::of(&exe.info, exe.run(&inputs)?)?;
            let preds = outs
                .named("preds")?
                .to_i32_vec()
                .map_err(|e| format!("preds: {e}"))?;
            hits += preds
                .iter()
                .zip(labels.iter())
                .filter(|(p, l)| p == l)
                .count();
            total += labels.len();
        }
        Ok(MetricValue::Accuracy(hits as f64 / total.max(1) as f64))
    }

    fn eval_generation(&mut self, n_samples: usize) -> Result<MetricValue, String> {
        let exe = self
            .rt
            .borrow_mut()
            .load(&self.cfg.method.greedy_exe(&self.cfg.model))?;
        let (prompt_len, target_len) = self
            .task
            .gen_lens()
            .ok_or("task has no generation evaluation")?;
        let seq_len = self.task.seq_len().ok_or("task has no seq_len")?;
        // batch size is baked into the greedy executable's token shape
        let bdim = exe
            .info
            .inputs
            .iter()
            .find(|t| t.name == "batch/tokens")
            .ok_or("greedy exe missing batch/tokens")?
            .shape[0];
        let examples = self.task.gen_examples(TEST, n_samples);
        let mut pairs: Vec<(Vec<i32>, Vec<i32>)> = Vec::new();
        for chunk in examples.chunks(bdim) {
            let mut toks = vec![0i32; bdim * seq_len];
            for (b, ex) in chunk.iter().enumerate() {
                for (i, &t) in ex.prompt.iter().enumerate() {
                    toks[b * seq_len + i] = t;
                }
            }
            let mut batch = BTreeMap::new();
            batch.insert(
                "batch/tokens".to_string(),
                tensor_i32(&[bdim, seq_len], &toks)?,
            );
            let io = StepIo::new()
                .scalar(ScalarKey::PromptLen, scalar_i32(prompt_len as i32))
                .batch(batch);
            let inputs = io.inputs_for(&exe.info, &self.state)?;
            let outs = StepOutputs::of(&exe.info, exe.run(&inputs)?)?;
            let decoded = outs
                .named("tokens")?
                .to_i32_vec()
                .map_err(|e| format!("greedy tokens: {e}"))?;
            for (b, ex) in chunk.iter().enumerate() {
                let row = &decoded[b * seq_len..(b + 1) * seq_len];
                let hyp: Vec<i32> = row
                    [prompt_len..(prompt_len + target_len).min(seq_len)]
                    .to_vec();
                pairs.push((hyp, ex.reference.clone()));
            }
        }
        Ok(match self.task.kind() {
            TaskKind::Sum => MetricValue::Rouge(metrics::rouge_corpus(&pairs)),
            TaskKind::Mt => MetricValue::Bleu(metrics::bleu_corpus(&pairs).score),
            _ => unreachable!(),
        })
    }

    // ------------------------------------------------------------------
    // full run
    // ------------------------------------------------------------------

    /// Initialize, train for cfg.steps optimizer steps with periodic eval,
    /// score the final metric, and report.
    pub fn run(&mut self) -> Result<RunReport, String> {
        let timer = Timer::start();
        self.init()?;
        let mut accum = AccumSeeds::new(derive_seed(self.cfg.seed, 0xACC));
        let mut mom =
            MomentumSeeds::new(derive_seed(self.cfg.seed, 0xE3A), self.cfg.kappa);
        let mut train_losses = Vec::with_capacity(self.cfg.steps);
        let mut eval_losses = Vec::new();
        for s in 0..self.cfg.steps {
            let loss = self.train_step(&mut accum, &mut mom)?;
            train_losses.push(loss);
            if self.cfg.eval_every > 0 && (s + 1) % self.cfg.eval_every == 0 {
                let el = self.eval_loss(VAL, 4)?;
                eval_losses.push((s + 1, el));
                info!(
                    "[{}] step {}/{} train_loss={loss:.4} val_loss={el:.4}",
                    self.cfg.method.label(),
                    s + 1,
                    self.cfg.steps
                );
            }
        }
        let metric = Some(self.eval_metric(self.cfg.eval_samples)?);
        let wallclock = timer.elapsed_secs();
        Ok(RunReport {
            label: self.cfg.method.label(),
            steps_per_sec: self.cfg.steps as f64 / wallclock.max(1e-9),
            train_losses,
            eval_losses,
            metric,
            state_bytes: StateGroup::ALL
                .iter()
                .map(|g| (g.name().to_string(), self.state.group_bytes(*g)))
                .collect(),
            peak_state_bytes: self.rt.borrow().ledger.peak(),
            wallclock_secs: wallclock,
        })
    }

    /// Persist the full training state (params/opt/method groups + step and
    /// data-cursor counters) to `path` in the checkpoint format.
    pub fn save_checkpoint(&self, path: &str) -> Result<(), String> {
        let groups = self
            .state
            .snapshot()?
            .into_iter()
            .map(|(name, tensors)| super::checkpoint::GroupSnapshot {
                name,
                tensors,
            })
            .collect();
        super::checkpoint::Checkpoint {
            step: self.step as u64,
            cursor: self.cursor,
            groups,
        }
        .save(path)
    }

    /// Restore training state saved by `save_checkpoint`. Must be called
    /// instead of (not after) `init`.
    pub fn resume_from(&mut self, path: &str) -> Result<(), String> {
        let ck = super::checkpoint::Checkpoint::load(path)?;
        for (name, specs, vals) in ck.to_tensors()? {
            let group = StateGroup::parse(&name)
                .map_err(|e| format!("checkpoint {path}: {e}"))?;
            self.state.put(group, specs, vals);
        }
        self.step = ck.step as usize;
        self.cursor = ck.cursor;
        Ok(())
    }

    pub fn state(&self) -> &StateStore {
        &self.state
    }

    pub fn steps_done(&self) -> usize {
        self.step
    }

    /// Training loss of the most recent step (NaN before the first one).
    pub fn last_loss(&self) -> f32 {
        self.last_loss
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_derivation() {
        let mut cfg = TrainConfig {
            task: TaskKind::Sum,
            method: MethodSpec::Flora { rank: 8 },
            tau: 16,
            ..TrainConfig::default()
        };
        assert_eq!(Mode::of(&cfg), Mode::Accumulation);
        cfg.tau = 1;
        assert_eq!(Mode::of(&cfg), Mode::Momentum);
        cfg.method = MethodSpec::None;
        assert_eq!(Mode::of(&cfg), Mode::Plain);
        cfg.method = MethodSpec::Galore { rank: 8 };
        assert_eq!(Mode::of(&cfg), Mode::Galore);
        cfg.task = TaskKind::Sum;
        // the compressor-grid methods pin their mode regardless of tau
        cfg.method = MethodSpec::AltLora { rank: 8 };
        for tau in [1, 16] {
            cfg.tau = tau;
            assert_eq!(Mode::of(&cfg), Mode::Accumulation, "tau={tau}");
        }
        cfg.method = MethodSpec::AdaRank { rank: 8 };
        for tau in [1, 16] {
            cfg.tau = tau;
            assert_eq!(Mode::of(&cfg), Mode::Momentum, "tau={tau}");
        }
        cfg.task = TaskKind::Vit;
        cfg.method = MethodSpec::Flora { rank: 8 };
        assert_eq!(Mode::of(&cfg), Mode::VitStep);
    }

    #[test]
    fn main_exe_names_carry_the_optimizer() {
        let mut cfg = TrainConfig {
            model: "lm-tiny".into(),
            method: MethodSpec::None,
            optimizer: crate::opt::OptimizerKind::Adam,
            ..TrainConfig::default()
        };
        assert_eq!(
            Trainer::main_exe_name(&cfg, Mode::Plain).unwrap(),
            "lm-tiny/plain_step_adam"
        );
        cfg.method = MethodSpec::Flora { rank: 8 };
        cfg.optimizer = crate::opt::OptimizerKind::Adafactor;
        assert_eq!(
            Trainer::main_exe_name(&cfg, Mode::Momentum).unwrap(),
            "lm-tiny/mom_step_flora_r8_adafactor"
        );
        cfg.method = MethodSpec::AdaRank { rank: 8 };
        assert_eq!(
            Trainer::main_exe_name(&cfg, Mode::Momentum).unwrap(),
            "lm-tiny/mom_step_r8_adafactor_adarank"
        );
        cfg.method = MethodSpec::AltLora { rank: 4 };
        assert_eq!(
            Trainer::main_exe_name(&cfg, Mode::Accumulation).unwrap(),
            "lm-tiny/micro_r4_altlora"
        );
    }
}
