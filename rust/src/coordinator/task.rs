//! Task wrapper: binds a synthetic workload (data/) to the batch tensors an
//! executable expects, and knows which generation metric scores it.

use std::collections::BTreeMap;

use crate::config::TaskKind;
use crate::data::images::ImageTask;
use crate::data::{corpus::LmTask, seq2seq::{MtTask, SumTask}, GenExample, LmBatch};
use crate::runtime::{tensor_f32, tensor_i32, ModelInfo, Tensor};

pub enum Task {
    Sum(SumTask),
    Mt(MtTask),
    Lm(LmTask),
    Vit { task: ImageTask, side: usize, channels: usize, seed: u64 },
}

/// Split ids for deterministic data streams.
pub const TRAIN: u64 = 0;
pub const VAL: u64 = 1;
pub const TEST: u64 = 2;

impl Task {
    /// Build the right task for (kind, model) from manifest model info.
    pub fn new(kind: TaskKind, model: &ModelInfo, seed: u64) -> Result<Self, String> {
        match kind {
            TaskKind::Sum | TaskKind::Mt | TaskKind::Lm => {
                let vocab = model.get("vocab").ok_or("model missing vocab")?;
                let seq = model.get("seq_len").ok_or("model missing seq_len")?;
                Ok(match kind {
                    TaskKind::Sum => Task::Sum(SumTask::new(vocab, seq, seed)),
                    TaskKind::Mt => Task::Mt(MtTask::new(vocab, seq, seed)),
                    _ => Task::Lm(LmTask::new(vocab, seq, seed)),
                })
            }
            TaskKind::Vit => {
                let side = model.get("image_size").ok_or("model missing image_size")?;
                let channels = model.get("channels").unwrap_or(3);
                let classes = model.get("n_classes").ok_or("model missing n_classes")?;
                Ok(Task::Vit {
                    task: ImageTask::cifar_like(classes, side, channels, 0.25, seed),
                    side,
                    channels,
                    seed,
                })
            }
        }
    }

    /// Next training batch as named tensors keyed by manifest input names.
    pub fn next_batch(
        &self,
        batch: usize,
        split: u64,
        cursor: &mut u64,
    ) -> Result<BTreeMap<String, Tensor>, String> {
        let mut out = BTreeMap::new();
        match self {
            Task::Sum(t) => {
                let mut b = LmBatch::zeros(batch, t.seq_len);
                t.fill_batch(&mut b, split, cursor);
                insert_lm(&mut out, &b)?;
            }
            Task::Mt(t) => {
                let mut b = LmBatch::zeros(batch, t.seq_len);
                t.fill_batch(&mut b, split, cursor);
                insert_lm(&mut out, &b)?;
            }
            Task::Lm(t) => {
                let mut b = LmBatch::zeros(batch, t.seq_len);
                t.fill_batch(&mut b, split, cursor);
                insert_lm(&mut out, &b)?;
            }
            Task::Vit { task, side, channels, seed } => {
                let (images, labels) = task.fill_flat(batch, split, cursor, *seed);
                out.insert(
                    "batch/images".into(),
                    tensor_f32(&[batch, *side, *side, *channels], &images)?,
                );
                out.insert("batch/labels".into(), tensor_i32(&[batch], &labels)?);
            }
        }
        Ok(out)
    }

    /// Generation-eval examples (sequence tasks only).
    pub fn gen_examples(&self, split: u64, n: usize) -> Vec<GenExample> {
        match self {
            Task::Sum(t) => t.gen_examples(split, n),
            Task::Mt(t) => t.gen_examples(split, n),
            _ => Vec::new(),
        }
    }

    /// (prompt_len, target_len) for greedy decoding.
    pub fn gen_lens(&self) -> Option<(usize, usize)> {
        match self {
            Task::Sum(t) => Some((t.prompt_len(), t.target_len())),
            Task::Mt(t) => Some((t.prompt_len(), t.target_len())),
            _ => None,
        }
    }

    pub fn seq_len(&self) -> Option<usize> {
        match self {
            Task::Sum(t) => Some(t.seq_len),
            Task::Mt(t) => Some(t.seq_len),
            Task::Lm(t) => Some(t.seq_len),
            Task::Vit { .. } => None,
        }
    }

    pub fn kind(&self) -> TaskKind {
        match self {
            Task::Sum(_) => TaskKind::Sum,
            Task::Mt(_) => TaskKind::Mt,
            Task::Lm(_) => TaskKind::Lm,
            Task::Vit { .. } => TaskKind::Vit,
        }
    }
}

fn insert_lm(out: &mut BTreeMap<String, Tensor>, b: &LmBatch) -> Result<(), String> {
    out.insert(
        "batch/tokens".into(),
        tensor_i32(&[b.batch, b.seq_len], &b.tokens)?,
    );
    out.insert(
        "batch/mask".into(),
        tensor_f32(&[b.batch, b.seq_len], &b.mask)?,
    );
    Ok(())
}
