//! Checkpointing: serialize/restore a full training state (params, opt,
//! method state, step counters, seed schedules) to a single file in an own
//! binary format (serde isn't available offline; the format is versioned
//! and self-describing enough to fail loudly on mismatch).
//!
//! Layout (little-endian):
//!   magic "FLORAckp" | u32 version | u64 step | u64 cursor
//!   u32 n_groups × [ name | u32 n_tensors × [ name | u32 ndim × u64 dims
//!                                             | u64 nbytes | f32 data ] ]
//! Strings are u32-length-prefixed UTF-8.

use std::io::{Read, Write};
use std::path::Path;

use crate::runtime::{tensor_f32, Tensor, TensorSpec};

const MAGIC: &[u8; 8] = b"FLORAckp";
const VERSION: u32 = 1;

/// A host-side snapshot of one state group.
pub struct GroupSnapshot {
    pub name: String,
    pub tensors: Vec<(TensorSpec, Vec<f32>)>,
}

/// One restored group: name + manifest specs + tensors, in ABI order.
pub type GroupTensors = (String, Vec<TensorSpec>, Vec<Tensor>);

/// Everything needed to resume a run.
pub struct Checkpoint {
    pub step: u64,
    pub cursor: u64,
    pub groups: Vec<GroupSnapshot>,
}

fn write_str(w: &mut impl Write, s: &str) -> std::io::Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())
}

fn read_str(r: &mut impl Read) -> Result<String, String> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len).map_err(|e| e.to_string())?;
    let len = u32::from_le_bytes(len) as usize;
    if len > 1 << 20 {
        return Err(format!("implausible string length {len}"));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).map_err(|e| e.to_string())?;
    String::from_utf8(buf).map_err(|e| e.to_string())
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), String> {
        let f = std::fs::File::create(path.as_ref())
            .map_err(|e| format!("create checkpoint: {e}"))?;
        let mut w = std::io::BufWriter::new(f);
        let io = |e: std::io::Error| format!("write checkpoint: {e}");
        w.write_all(MAGIC).map_err(io)?;
        w.write_all(&VERSION.to_le_bytes()).map_err(io)?;
        w.write_all(&self.step.to_le_bytes()).map_err(io)?;
        w.write_all(&self.cursor.to_le_bytes()).map_err(io)?;
        w.write_all(&(self.groups.len() as u32).to_le_bytes()).map_err(io)?;
        for g in &self.groups {
            write_str(&mut w, &g.name).map_err(io)?;
            w.write_all(&(g.tensors.len() as u32).to_le_bytes()).map_err(io)?;
            for (spec, data) in &g.tensors {
                write_str(&mut w, &spec.name).map_err(io)?;
                w.write_all(&(spec.shape.len() as u32).to_le_bytes()).map_err(io)?;
                for &d in &spec.shape {
                    w.write_all(&(d as u64).to_le_bytes()).map_err(io)?;
                }
                w.write_all(&((data.len() * 4) as u64).to_le_bytes()).map_err(io)?;
                for &x in data {
                    w.write_all(&x.to_le_bytes()).map_err(io)?;
                }
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint, String> {
        let f = std::fs::File::open(path.as_ref())
            .map_err(|e| format!("open checkpoint: {e}"))?;
        let mut r = std::io::BufReader::new(f);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic).map_err(|e| e.to_string())?;
        if &magic != MAGIC {
            return Err("not a flora checkpoint (bad magic)".into());
        }
        let mut u32b = [0u8; 4];
        let mut u64b = [0u8; 8];
        r.read_exact(&mut u32b).map_err(|e| e.to_string())?;
        let version = u32::from_le_bytes(u32b);
        if version != VERSION {
            return Err(format!("checkpoint version {version}, want {VERSION}"));
        }
        r.read_exact(&mut u64b).map_err(|e| e.to_string())?;
        let step = u64::from_le_bytes(u64b);
        r.read_exact(&mut u64b).map_err(|e| e.to_string())?;
        let cursor = u64::from_le_bytes(u64b);
        r.read_exact(&mut u32b).map_err(|e| e.to_string())?;
        let n_groups = u32::from_le_bytes(u32b);
        let mut groups = Vec::with_capacity(n_groups as usize);
        for _ in 0..n_groups {
            let gname = read_str(&mut r)?;
            r.read_exact(&mut u32b).map_err(|e| e.to_string())?;
            let n_tensors = u32::from_le_bytes(u32b);
            let mut tensors = Vec::with_capacity(n_tensors as usize);
            for _ in 0..n_tensors {
                let tname = read_str(&mut r)?;
                r.read_exact(&mut u32b).map_err(|e| e.to_string())?;
                let ndim = u32::from_le_bytes(u32b) as usize;
                if ndim > 8 {
                    return Err(format!("{tname}: implausible ndim {ndim}"));
                }
                let mut shape = Vec::with_capacity(ndim);
                for _ in 0..ndim {
                    r.read_exact(&mut u64b).map_err(|e| e.to_string())?;
                    shape.push(u64::from_le_bytes(u64b) as usize);
                }
                r.read_exact(&mut u64b).map_err(|e| e.to_string())?;
                let nbytes = u64::from_le_bytes(u64b) as usize;
                let numel: usize = shape.iter().product::<usize>().max(1);
                if nbytes != numel * 4 {
                    return Err(format!(
                        "{tname}: byte count {nbytes} != 4*numel({numel})"
                    ));
                }
                let mut raw = vec![0u8; nbytes];
                r.read_exact(&mut raw).map_err(|e| e.to_string())?;
                let data: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                tensors.push((
                    TensorSpec { name: tname, shape, dtype: "float32".into() },
                    data,
                ));
            }
            groups.push(GroupSnapshot { name: gname, tensors });
        }
        Ok(Checkpoint { step, cursor, groups })
    }

    /// Rebuild tensor groups for a StateStore.
    pub fn to_tensors(&self) -> Result<Vec<GroupTensors>, String> {
        self.groups
            .iter()
            .map(|g| {
                let mut specs = Vec::new();
                let mut vals = Vec::new();
                for (spec, data) in &g.tensors {
                    vals.push(tensor_f32(&spec.shape, data)?);
                    specs.push(spec.clone());
                }
                Ok((g.name.clone(), specs, vals))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            step: 42,
            cursor: 1337,
            groups: vec![
                GroupSnapshot {
                    name: "params".into(),
                    tensors: vec![
                        (
                            TensorSpec {
                                name: "params/w".into(),
                                shape: vec![2, 3],
                                dtype: "float32".into(),
                            },
                            vec![1.0, -2.0, 3.5, 0.0, 1e-9, 7.0],
                        ),
                        (
                            TensorSpec {
                                name: "params/b".into(),
                                shape: vec![],
                                dtype: "float32".into(),
                            },
                            vec![0.25],
                        ),
                    ],
                },
                GroupSnapshot { name: "opt".into(), tensors: vec![] },
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let path = std::env::temp_dir().join("flora_ckpt_test.bin");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.cursor, 1337);
        assert_eq!(back.groups.len(), 2);
        assert_eq!(back.groups[0].tensors[0].1, ck.groups[0].tensors[0].1);
        assert_eq!(back.groups[0].tensors[1].0.shape, Vec::<usize>::new());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("flora_ckpt_bad.bin");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxx").unwrap();
        match Checkpoint::load(&path) {
            Err(e) => assert!(e.contains("magic"), "{e}"),
            Ok(_) => panic!("bad magic accepted"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_truncated() {
        let path = std::env::temp_dir().join("flora_ckpt_trunc.bin");
        let ck = sample();
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn to_tensors_shapes() {
        let ck = sample();
        let groups = ck.to_tensors().unwrap();
        assert_eq!(groups[0].2[0].element_count(), 6);
        assert_eq!(groups[0].2[1].element_count(), 1);
    }
}
