//! Checkpointing: serialize/restore a full training state (params, opt,
//! method state, step counters, seed schedules) to a single file in an own
//! binary format (serde isn't available offline; the format is versioned
//! and self-describing enough to fail loudly on mismatch).
//!
//! Layout (little-endian):
//!   magic "FLORAckp" | u32 version | u64 fnv1a(payload) | payload
//! where payload is:
//!   u64 step | u64 cursor
//!   u32 n_groups × [ name | u32 n_tensors × [ name | u32 ndim × u64 dims
//!                                             | u64 nbytes | f32 data ] ]
//! Strings are u32-length-prefixed UTF-8.
//!
//! Version 2 (PR 8) added the FNV-1a payload checksum: version-1 files
//! had no integrity check, so a single flipped bit in the f32 payload
//! loaded as silently-different weights — the worst possible failure
//! mode for a tier whose whole pitch is bit-exactness. The checksum is
//! verified over the raw payload BEFORE any field is parsed, so a
//! truncated or corrupted file can never half-load, and every error
//! carries the file path (`checkpoint <path>: ...`).

use std::io::{Read, Write};
use std::path::Path;

use crate::runtime::{tensor_f32, Tensor, TensorSpec};

const MAGIC: &[u8; 8] = b"FLORAckp";
const VERSION: u32 = 2;
/// magic + u32 version + u64 checksum
const HEADER_LEN: usize = 8 + 4 + 8;

/// 64-bit FNV-1a over the serialized payload. Not cryptographic — the
/// threat model is truncation and bit rot, not an adversary — but any
/// single-bit flip changes the digest.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A host-side snapshot of one state group.
pub struct GroupSnapshot {
    pub name: String,
    pub tensors: Vec<(TensorSpec, Vec<f32>)>,
}

/// One restored group: name + manifest specs + tensors, in ABI order.
pub type GroupTensors = (String, Vec<TensorSpec>, Vec<Tensor>);

/// Everything needed to resume a run.
pub struct Checkpoint {
    pub step: u64,
    pub cursor: u64,
    pub groups: Vec<GroupSnapshot>,
}

fn write_str(w: &mut impl Write, s: &str) -> std::io::Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())
}

fn read_str(r: &mut impl Read) -> Result<String, String> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len).map_err(|e| e.to_string())?;
    let len = u32::from_le_bytes(len) as usize;
    if len > 1 << 20 {
        return Err(format!("implausible string length {len}"));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).map_err(|e| e.to_string())?;
    String::from_utf8(buf).map_err(|e| e.to_string())
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), String> {
        let path = path.as_ref();
        let io = |e: std::io::Error| format!("checkpoint {}: serialize: {e}", path.display());
        let mut payload: Vec<u8> = Vec::new();
        {
            let w = &mut payload;
            w.write_all(&self.step.to_le_bytes()).map_err(io)?;
            w.write_all(&self.cursor.to_le_bytes()).map_err(io)?;
            w.write_all(&(self.groups.len() as u32).to_le_bytes()).map_err(io)?;
            for g in &self.groups {
                write_str(w, &g.name).map_err(io)?;
                w.write_all(&(g.tensors.len() as u32).to_le_bytes()).map_err(io)?;
                for (spec, data) in &g.tensors {
                    write_str(w, &spec.name).map_err(io)?;
                    w.write_all(&(spec.shape.len() as u32).to_le_bytes()).map_err(io)?;
                    for &d in &spec.shape {
                        w.write_all(&(d as u64).to_le_bytes()).map_err(io)?;
                    }
                    w.write_all(&((data.len() * 4) as u64).to_le_bytes()).map_err(io)?;
                    for &x in data {
                        w.write_all(&x.to_le_bytes()).map_err(io)?;
                    }
                }
            }
        }
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        std::fs::write(path, &out)
            .map_err(|e| format!("checkpoint {}: cannot write: {e}", path.display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint, String> {
        let path = path.as_ref();
        let shown = path.display();
        let bytes = std::fs::read(path)
            .map_err(|e| format!("checkpoint {shown}: cannot read: {e}"))?;
        if bytes.len() < HEADER_LEN {
            return Err(format!(
                "checkpoint {shown}: file is {} bytes — truncated before the header ends",
                bytes.len()
            ));
        }
        if &bytes[..MAGIC.len()] != MAGIC {
            return Err(format!("checkpoint {shown}: not a flora checkpoint (bad magic)"));
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version != VERSION {
            return Err(format!(
                "checkpoint {shown}: format version {version}, this build reads \
                 version {VERSION} (re-save with a current build)"
            ));
        }
        let want = u64::from_le_bytes(bytes[12..HEADER_LEN].try_into().unwrap());
        let payload = &bytes[HEADER_LEN..];
        let got = fnv1a(payload);
        if got != want {
            return Err(format!(
                "checkpoint {shown}: payload checksum mismatch \
                 ({got:016x} != recorded {want:016x}) — the file was truncated or \
                 corrupted after save; refusing to load garbage weights"
            ));
        }
        Self::parse_payload(payload).map_err(|e| format!("checkpoint {shown}: {e}"))
    }

    /// Parse the checksum-verified payload. Structural guards stay as a
    /// second line of defense (they also catch writer bugs, which a
    /// checksum cannot).
    fn parse_payload(payload: &[u8]) -> Result<Checkpoint, String> {
        let mut r = payload;
        let r = &mut r;
        let mut u32b = [0u8; 4];
        let mut u64b = [0u8; 8];
        r.read_exact(&mut u64b).map_err(|e| e.to_string())?;
        let step = u64::from_le_bytes(u64b);
        r.read_exact(&mut u64b).map_err(|e| e.to_string())?;
        let cursor = u64::from_le_bytes(u64b);
        r.read_exact(&mut u32b).map_err(|e| e.to_string())?;
        let n_groups = u32::from_le_bytes(u32b);
        let mut groups = Vec::with_capacity(n_groups as usize);
        for _ in 0..n_groups {
            let gname = read_str(r)?;
            r.read_exact(&mut u32b).map_err(|e| e.to_string())?;
            let n_tensors = u32::from_le_bytes(u32b);
            let mut tensors = Vec::with_capacity(n_tensors as usize);
            for _ in 0..n_tensors {
                let tname = read_str(r)?;
                r.read_exact(&mut u32b).map_err(|e| e.to_string())?;
                let ndim = u32::from_le_bytes(u32b) as usize;
                if ndim > 8 {
                    return Err(format!("{tname}: implausible ndim {ndim}"));
                }
                let mut shape = Vec::with_capacity(ndim);
                for _ in 0..ndim {
                    r.read_exact(&mut u64b).map_err(|e| e.to_string())?;
                    shape.push(u64::from_le_bytes(u64b) as usize);
                }
                r.read_exact(&mut u64b).map_err(|e| e.to_string())?;
                let nbytes = u64::from_le_bytes(u64b) as usize;
                let numel: usize = shape.iter().product::<usize>().max(1);
                if nbytes != numel * 4 {
                    return Err(format!(
                        "{tname}: byte count {nbytes} != 4*numel({numel})"
                    ));
                }
                let mut raw = vec![0u8; nbytes];
                r.read_exact(&mut raw).map_err(|e| e.to_string())?;
                let data: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                tensors.push((
                    TensorSpec { name: tname, shape, dtype: "float32".into() },
                    data,
                ));
            }
            groups.push(GroupSnapshot { name: gname, tensors });
        }
        Ok(Checkpoint { step, cursor, groups })
    }

    /// Rebuild tensor groups for a StateStore.
    pub fn to_tensors(&self) -> Result<Vec<GroupTensors>, String> {
        self.groups
            .iter()
            .map(|g| {
                let mut specs = Vec::new();
                let mut vals = Vec::new();
                for (spec, data) in &g.tensors {
                    vals.push(tensor_f32(&spec.shape, data)?);
                    specs.push(spec.clone());
                }
                Ok((g.name.clone(), specs, vals))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            step: 42,
            cursor: 1337,
            groups: vec![
                GroupSnapshot {
                    name: "params".into(),
                    tensors: vec![
                        (
                            TensorSpec {
                                name: "params/w".into(),
                                shape: vec![2, 3],
                                dtype: "float32".into(),
                            },
                            vec![1.0, -2.0, 3.5, 0.0, 1e-9, 7.0],
                        ),
                        (
                            TensorSpec {
                                name: "params/b".into(),
                                shape: vec![],
                                dtype: "float32".into(),
                            },
                            vec![0.25],
                        ),
                    ],
                },
                GroupSnapshot { name: "opt".into(), tensors: vec![] },
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let path = std::env::temp_dir().join("flora_ckpt_test.bin");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.cursor, 1337);
        assert_eq!(back.groups.len(), 2);
        assert_eq!(back.groups[0].tensors[0].1, ck.groups[0].tensors[0].1);
        assert_eq!(back.groups[0].tensors[1].0.shape, Vec::<usize>::new());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("flora_ckpt_bad.bin");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxx").unwrap();
        match Checkpoint::load(&path) {
            Err(e) => assert!(e.contains("magic"), "{e}"),
            Ok(_) => panic!("bad magic accepted"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_truncated_with_path_and_checksum() {
        let path = std::env::temp_dir().join("flora_ckpt_trunc.bin");
        let ck = sample();
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        let e = Checkpoint::load(&path).unwrap_err();
        assert!(e.contains("checksum mismatch"), "{e}");
        assert!(e.contains("flora_ckpt_trunc.bin"), "{e}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_single_bit_flip_in_weights() {
        let path = std::env::temp_dir().join("flora_ckpt_flip.bin");
        let ck = sample();
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // flip one bit deep inside the f32 payload — version 1 loaded
        // this as silently-different weights
        let mid = HEADER_LEN + (bytes.len() - HEADER_LEN) * 3 / 4;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let e = Checkpoint::load(&path).unwrap_err();
        assert!(e.contains("checksum mismatch"), "{e}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_old_format_version() {
        let path = std::env::temp_dir().join("flora_ckpt_v1.bin");
        let ck = sample();
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8..12].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let e = Checkpoint::load(&path).unwrap_err();
        assert!(e.contains("format version 1"), "{e}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn to_tensors_shapes() {
        let ck = sample();
        let groups = ck.to_tensors().unwrap();
        assert_eq!(groups[0].2[0].element_count(), 6);
        assert_eq!(groups[0].2[1].element_count(), 1);
    }
}
