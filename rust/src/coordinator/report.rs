//! Run reports: everything a bench needs to print one table row.

use crate::metrics::RougeScores;

/// The task-appropriate final quality metric.
#[derive(Clone, Copy, Debug)]
pub enum MetricValue {
    Rouge(RougeScores),
    Bleu(f64),
    Perplexity(f64),
    Accuracy(f64),
}

impl MetricValue {
    /// Render like the paper's tables (R1/R2/RL, BLEU, PPL, %).
    pub fn render(&self) -> String {
        match self {
            MetricValue::Rouge(r) => {
                format!("{:.1}/{:.2}/{:.1}", r.rouge1, r.rouge2, r.rouge_l)
            }
            MetricValue::Bleu(b) => format!("{b:.1}"),
            MetricValue::Perplexity(p) => format!("{p:.2}"),
            MetricValue::Accuracy(a) => format!("{:.2}", 100.0 * a),
        }
    }

    /// A scalar for "higher is better" comparisons in tests/benches.
    pub fn quality(&self) -> f64 {
        match self {
            MetricValue::Rouge(r) => r.rouge1 + r.rouge2 + r.rouge_l,
            MetricValue::Bleu(b) => *b,
            MetricValue::Perplexity(p) => -p,
            MetricValue::Accuracy(a) => *a,
        }
    }
}

/// Outcome of one training run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub label: String,
    /// per-step training loss
    pub train_losses: Vec<f32>,
    /// (step, val loss) at each eval point
    pub eval_losses: Vec<(usize, f32)>,
    pub metric: Option<MetricValue>,
    /// live state bytes by group at the end of the run
    pub state_bytes: Vec<(String, u64)>,
    /// peak tracked state bytes
    pub peak_state_bytes: u64,
    pub wallclock_secs: f64,
    pub steps_per_sec: f64,
}

impl RunReport {
    pub fn final_train_loss(&self) -> f32 {
        let tail = self.train_losses.len().saturating_sub(10);
        let window = &self.train_losses[tail..];
        if window.is_empty() {
            f32::NAN
        } else {
            window.iter().sum::<f32>() / window.len() as f32
        }
    }

    pub fn best_eval_loss(&self) -> f32 {
        self.eval_losses
            .iter()
            .map(|&(_, l)| l)
            .fold(f32::INFINITY, f32::min)
    }

    pub fn total_state_bytes(&self) -> u64 {
        self.state_bytes.iter().map(|(_, b)| b).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_rendering() {
        let m = MetricValue::Rouge(RougeScores {
            rouge1: 33.4,
            rouge2: 11.42,
            rouge_l: 26.4,
        });
        assert_eq!(m.render(), "33.4/11.42/26.4");
        assert_eq!(MetricValue::Bleu(17.94).render(), "17.9");
        assert_eq!(MetricValue::Perplexity(34.641).render(), "34.64");
        assert_eq!(MetricValue::Accuracy(0.9215).render(), "92.15");
    }

    #[test]
    fn quality_ordering() {
        assert!(
            MetricValue::Perplexity(20.0).quality()
                > MetricValue::Perplexity(30.0).quality()
        );
        assert!(MetricValue::Bleu(25.0).quality() > MetricValue::Bleu(10.0).quality());
    }

    #[test]
    fn report_summaries() {
        let r = RunReport {
            label: "x".into(),
            train_losses: (0..20).map(|i| 5.0 - 0.1 * i as f32).collect(),
            eval_losses: vec![(0, 4.0), (10, 3.0), (20, 3.5)],
            metric: None,
            state_bytes: vec![("params".into(), 100), ("opt".into(), 50)],
            peak_state_bytes: 160,
            wallclock_secs: 1.0,
            steps_per_sec: 20.0,
        };
        assert_eq!(r.best_eval_loss(), 3.0);
        assert_eq!(r.total_state_bytes(), 150);
        assert!(r.final_train_loss() < 4.0);
    }
}
