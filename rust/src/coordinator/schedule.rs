//! Learning-rate schedules.
//!
//! The paper deliberately trains with a constant LR ("we do not apply
//! learning rate schedules ... to rule out the influence of these
//! techniques", §3.1) — `Constant` is therefore what every bench uses.
//! Warmup/cosine/step are provided as first-class options for downstream
//! users (and exercised by unit tests), selectable via `Schedule::parse`.

#[derive(Clone, Debug, PartialEq)]
pub enum Schedule {
    Constant { lr: f32 },
    /// linear warmup to `lr` over `warmup` steps, then constant
    Warmup { lr: f32, warmup: usize },
    /// linear warmup then cosine decay to `min_lr` at `total` steps
    WarmupCosine { lr: f32, min_lr: f32, warmup: usize, total: usize },
    /// multiply by `gamma` every `every` steps
    StepDecay { lr: f32, gamma: f32, every: usize },
}

impl Schedule {
    pub fn at(&self, step: usize) -> f32 {
        match *self {
            Schedule::Constant { lr } => lr,
            Schedule::Warmup { lr, warmup } => {
                if warmup == 0 || step >= warmup {
                    lr
                } else {
                    lr * (step + 1) as f32 / warmup as f32
                }
            }
            Schedule::WarmupCosine { lr, min_lr, warmup, total } => {
                if step < warmup {
                    return lr * (step + 1) as f32 / warmup.max(1) as f32;
                }
                let t = (step - warmup) as f32
                    / (total.saturating_sub(warmup)).max(1) as f32;
                let t = t.clamp(0.0, 1.0);
                min_lr
                    + 0.5 * (lr - min_lr) * (1.0 + (std::f32::consts::PI * t).cos())
            }
            Schedule::StepDecay { lr, gamma, every } => {
                lr * gamma.powi((step / every.max(1)) as i32)
            }
        }
    }

    /// "constant", "warmup:100", "cosine:100:10000", "step:0.5:1000"
    pub fn parse(spec: &str, lr: f32) -> Result<Schedule, String> {
        let parts: Vec<&str> = spec.split(':').collect();
        match parts[0] {
            "constant" => Ok(Schedule::Constant { lr }),
            "warmup" => {
                let w = parts
                    .get(1)
                    .and_then(|s| s.parse().ok())
                    .ok_or("warmup:N")?;
                Ok(Schedule::Warmup { lr, warmup: w })
            }
            "cosine" => {
                let w = parts.get(1).and_then(|s| s.parse().ok()).ok_or("cosine:W:T")?;
                let t = parts.get(2).and_then(|s| s.parse().ok()).ok_or("cosine:W:T")?;
                Ok(Schedule::WarmupCosine { lr, min_lr: lr * 0.01, warmup: w, total: t })
            }
            "step" => {
                let g = parts.get(1).and_then(|s| s.parse().ok()).ok_or("step:G:N")?;
                let n = parts.get(2).and_then(|s| s.parse().ok()).ok_or("step:G:N")?;
                Ok(Schedule::StepDecay { lr, gamma: g, every: n })
            }
            other => Err(format!("unknown schedule {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant { lr: 0.05 };
        assert_eq!(s.at(0), 0.05);
        assert_eq!(s.at(10_000), 0.05);
    }

    #[test]
    fn warmup_ramps_then_flat() {
        let s = Schedule::Warmup { lr: 1.0, warmup: 10 };
        assert!((s.at(0) - 0.1).abs() < 1e-6);
        assert!((s.at(4) - 0.5).abs() < 1e-6);
        assert_eq!(s.at(10), 1.0);
        assert_eq!(s.at(99), 1.0);
    }

    #[test]
    fn cosine_decays_to_min() {
        let s = Schedule::WarmupCosine { lr: 1.0, min_lr: 0.0, warmup: 0, total: 100 };
        assert!((s.at(0) - 1.0).abs() < 1e-3);
        assert!((s.at(50) - 0.5).abs() < 0.02);
        assert!(s.at(100) < 0.01);
        assert!(s.at(500) < 0.01); // clamped past total
    }

    #[test]
    fn cosine_monotone_after_warmup() {
        let s = Schedule::WarmupCosine { lr: 1.0, min_lr: 0.0, warmup: 5, total: 50 };
        let mut last = f32::INFINITY;
        for t in 5..50 {
            let v = s.at(t);
            assert!(v <= last + 1e-6);
            last = v;
        }
    }

    #[test]
    fn step_decay_halves() {
        let s = Schedule::StepDecay { lr: 0.8, gamma: 0.5, every: 100 };
        assert_eq!(s.at(99), 0.8);
        assert_eq!(s.at(100), 0.4);
        assert_eq!(s.at(250), 0.2);
    }

    #[test]
    fn parse_forms() {
        assert_eq!(
            Schedule::parse("constant", 0.1).unwrap(),
            Schedule::Constant { lr: 0.1 }
        );
        assert!(matches!(
            Schedule::parse("warmup:50", 0.1).unwrap(),
            Schedule::Warmup { warmup: 50, .. }
        ));
        assert!(matches!(
            Schedule::parse("cosine:10:100", 0.1).unwrap(),
            Schedule::WarmupCosine { warmup: 10, total: 100, .. }
        ));
        assert!(Schedule::parse("exponential", 0.1).is_err());
    }
}
