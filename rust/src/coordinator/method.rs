//! Method specification + the manifest-name scheme binding the coordinator
//! to the AOT catalog (python/compile/aot.py is the other half of this
//! contract; test_steps_abi.py and rust/tests/integration.rs check both).
//! Optimizer-suffixed names take the typed [`OptimizerKind`], so a config
//! can only ever ask for executables a base optimizer actually exists for.

use crate::opt::{CompressorKind, OptimizerKind};

/// The optimizer-state compression method under test.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodSpec {
    /// no accumulation/momentum buffer at all
    None,
    /// full-size buffer
    Naive,
    /// FLORA compressed buffer of rank r (Algorithms 1–2)
    Flora { rank: usize },
    /// FLORA momentum WITHOUT the κ-resample subspace transfer (ablation
    /// of the paper's §2.4 remedy #2; see benches/ablation_transfer.rs)
    FloraNoTransfer { rank: usize },
    /// LoRA patches of rank r
    Lora { rank: usize },
    /// GaLore with projection rank r
    Galore { rank: usize },
    /// AltLoRA alternating-projection compressor of rank r (dual
    /// sketches + best rank-r reconstruction; `--compressor altlora`)
    AltLora { rank: usize },
    /// Flora Algorithm-2 momentum under an adaptive rank schedule
    /// (master rank r, shrink-and-migrate; `--compressor adarank`)
    AdaRank { rank: usize },
}

impl MethodSpec {
    pub fn parse(name: &str, rank: usize) -> Result<Self, String> {
        match name {
            "none" => Ok(MethodSpec::None),
            "naive" => Ok(MethodSpec::Naive),
            "flora" => Ok(MethodSpec::Flora { rank }),
            "flora_notransfer" => Ok(MethodSpec::FloraNoTransfer { rank }),
            "lora" => Ok(MethodSpec::Lora { rank }),
            "galore" => Ok(MethodSpec::Galore { rank }),
            "altlora" => Ok(MethodSpec::AltLora { rank }),
            "adarank" => Ok(MethodSpec::AdaRank { rank }),
            _ => Err(format!(
                "unknown method {name:?} (want \
                 none|naive|flora|lora|galore|altlora|adarank)"
            )),
        }
    }

    /// Re-route a flora-family method through another compressor algebra
    /// (`--compressor` / `[train] compressor`). Only the Flora baseline
    /// re-routes — every other method has no rank-r compressed
    /// accumulator for the compressor to act on.
    pub fn with_compressor(self, c: CompressorKind) -> Result<Self, String> {
        let rank = match self {
            MethodSpec::Flora { rank }
            | MethodSpec::AltLora { rank }
            | MethodSpec::AdaRank { rank } => rank,
            other => {
                return Err(format!(
                    "--compressor {c} requires a flora-family method \
                     (--method flora --rank R), got {}",
                    other.label()
                ))
            }
        };
        Ok(match c {
            CompressorKind::Flora => MethodSpec::Flora { rank },
            CompressorKind::AltLora => MethodSpec::AltLora { rank },
            CompressorKind::AdaRank => MethodSpec::AdaRank { rank },
        })
    }

    pub fn label(&self) -> String {
        match self {
            MethodSpec::None => "None".into(),
            MethodSpec::Naive => "Naive".into(),
            MethodSpec::Flora { rank } => format!("FLORA({rank})"),
            MethodSpec::FloraNoTransfer { rank } => {
                format!("FLORA-noT({rank})")
            }
            MethodSpec::Lora { rank } => format!("LoRA({rank})"),
            MethodSpec::Galore { rank } => format!("GaLore({rank})"),
            MethodSpec::AltLora { rank } => format!("AltLoRA({rank})"),
            MethodSpec::AdaRank { rank } => format!("AdaRank({rank})"),
        }
    }

    pub fn rank(&self) -> Option<usize> {
        match self {
            MethodSpec::Flora { rank }
            | MethodSpec::FloraNoTransfer { rank }
            | MethodSpec::Lora { rank }
            | MethodSpec::Galore { rank }
            | MethodSpec::AltLora { rank }
            | MethodSpec::AdaRank { rank } => Some(*rank),
            _ => None,
        }
    }

    pub fn is_lora(&self) -> bool {
        matches!(self, MethodSpec::Lora { .. })
    }

    /// memory-accountant mirror of this spec
    pub fn to_memory_method(&self) -> crate::memory::Method {
        match self {
            MethodSpec::None => crate::memory::Method::None,
            MethodSpec::Naive => crate::memory::Method::Naive,
            MethodSpec::Flora { rank }
            | MethodSpec::FloraNoTransfer { rank }
            // AdaRank allocates the Flora master-rank state and only
            // shrinks from there; AltLora's dual sketch is ~2x the Flora
            // accumulator on square-ish matrices — the accountant books
            // the allocation-time (master) footprint for both
            | MethodSpec::AdaRank { rank } => {
                crate::memory::Method::Flora(*rank as u64)
            }
            MethodSpec::AltLora { rank } => {
                crate::memory::Method::Flora(2 * *rank as u64)
            }
            MethodSpec::Lora { rank } => crate::memory::Method::Lora(*rank as u64),
            MethodSpec::Galore { rank } => crate::memory::Method::Galore(*rank as u64),
        }
    }

    // ----- manifest executable names (the ABI contract with aot.py) -----

    pub fn init_exe(&self, model: &str) -> String {
        format!("{model}/init")
    }

    pub fn lora_init_exe(&self, model: &str) -> Option<String> {
        self.rank()
            .filter(|_| self.is_lora())
            .map(|r| format!("{model}/lora_r{r}_init"))
    }

    /// Algorithm-1 micro step (None has no accumulation).
    pub fn micro_exe(&self, model: &str) -> Option<String> {
        match self {
            MethodSpec::None | MethodSpec::Galore { .. } => None,
            MethodSpec::FloraNoTransfer { .. } | MethodSpec::AdaRank { .. } => None,
            MethodSpec::Naive => Some(format!("{model}/micro_naive")),
            MethodSpec::Flora { rank } => {
                Some(format!("{model}/micro_flora_r{rank}"))
            }
            MethodSpec::AltLora { rank } => {
                Some(format!("{model}/micro_r{rank}_altlora"))
            }
            MethodSpec::Lora { rank } => {
                Some(format!("{model}/lora_r{rank}_micro"))
            }
        }
    }

    /// Algorithm-1 cycle-end update.
    pub fn update_exe(&self, model: &str, optimizer: OptimizerKind) -> Option<String> {
        match self {
            MethodSpec::None | MethodSpec::Galore { .. } => None,
            MethodSpec::FloraNoTransfer { .. } | MethodSpec::AdaRank { .. } => None,
            MethodSpec::Naive => {
                Some(format!("{model}/update_naive_{optimizer}"))
            }
            MethodSpec::Flora { rank } => {
                Some(format!("{model}/update_flora_r{rank}_{optimizer}"))
            }
            MethodSpec::AltLora { rank } => {
                Some(format!("{model}/update_r{rank}_{optimizer}_altlora"))
            }
            MethodSpec::Lora { rank } => {
                Some(format!("{model}/lora_r{rank}_update_{optimizer}"))
            }
        }
    }

    /// Fused plain step (method None / the "no accumulation" baseline).
    pub fn plain_step_exe(model: &str, optimizer: OptimizerKind) -> String {
        format!("{model}/plain_step_{optimizer}")
    }

    /// Algorithm-2 fused momentum step.
    pub fn momentum_exe(&self, model: &str, optimizer: OptimizerKind) -> Option<String> {
        match self {
            MethodSpec::None | MethodSpec::Galore { .. } => None,
            MethodSpec::AltLora { .. } => None,
            MethodSpec::FloraNoTransfer { rank } => Some(format!(
                "{model}/mom_step_flora_notransfer_r{rank}_{optimizer}"
            )),
            MethodSpec::Naive => {
                Some(format!("{model}/mom_step_naive_{optimizer}"))
            }
            MethodSpec::Flora { rank } => {
                Some(format!("{model}/mom_step_flora_r{rank}_{optimizer}"))
            }
            MethodSpec::AdaRank { rank } => {
                Some(format!("{model}/mom_step_r{rank}_{optimizer}_adarank"))
            }
            MethodSpec::Lora { rank } => {
                Some(format!("{model}/lora_r{rank}_mom_step_{optimizer}"))
            }
        }
    }

    pub fn galore_exe(&self, model: &str) -> Option<String> {
        match self {
            MethodSpec::Galore { rank } => {
                Some(format!("{model}/galore_step_r{rank}"))
            }
            _ => None,
        }
    }

    pub fn eval_exe(&self, model: &str) -> String {
        match self {
            MethodSpec::Lora { rank } => format!("{model}/lora_r{rank}_eval"),
            _ => format!("{model}/eval"),
        }
    }

    pub fn greedy_exe(&self, model: &str) -> String {
        match self {
            MethodSpec::Lora { rank } => format!("{model}/lora_r{rank}_greedy"),
            _ => format!("{model}/greedy"),
        }
    }

    /// ViT training-step name (Table 5 uses "none"+adam and flora+adafactor).
    pub fn vit_step_exe(&self, model: &str, optimizer: OptimizerKind) -> String {
        match self {
            MethodSpec::Flora { rank } => {
                format!("{model}/step_flora_r{rank}_{optimizer}")
            }
            MethodSpec::AltLora { rank } => {
                format!("{model}/step_r{rank}_{optimizer}_altlora")
            }
            MethodSpec::AdaRank { rank } => {
                format!("{model}/step_r{rank}_{optimizer}_adarank")
            }
            _ => format!("{model}/step_{optimizer}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_label_roundtrip() {
        assert_eq!(MethodSpec::parse("flora", 8).unwrap().label(), "FLORA(8)");
        assert_eq!(MethodSpec::parse("none", 0).unwrap(), MethodSpec::None);
        assert!(MethodSpec::parse("relora", 8).is_err());
    }

    #[test]
    fn exe_names_match_aot_catalog() {
        let af = OptimizerKind::Adafactor;
        let flora = MethodSpec::Flora { rank: 8 };
        assert_eq!(flora.micro_exe("lm-small").unwrap(), "lm-small/micro_flora_r8");
        assert_eq!(
            flora.update_exe("lm-small", af).unwrap(),
            "lm-small/update_flora_r8_adafactor"
        );
        assert_eq!(
            flora.momentum_exe("lm-small", af).unwrap(),
            "lm-small/mom_step_flora_r8_adafactor"
        );
        assert_eq!(
            flora.momentum_exe("lm-small", OptimizerKind::Sgd).unwrap(),
            "lm-small/mom_step_flora_r8_sgd"
        );
        let lora = MethodSpec::Lora { rank: 32 };
        assert_eq!(lora.micro_exe("lm-small").unwrap(), "lm-small/lora_r32_micro");
        assert_eq!(lora.eval_exe("lm-small"), "lm-small/lora_r32_eval");
        assert_eq!(
            MethodSpec::plain_step_exe("lm-small", af),
            "lm-small/plain_step_adafactor"
        );
        assert_eq!(
            MethodSpec::plain_step_exe("lm-small", OptimizerKind::AdafactorNoFactor),
            "lm-small/plain_step_adafactor_nofactor"
        );
        let ga = MethodSpec::Galore { rank: 16 };
        assert_eq!(ga.galore_exe("lm-small").unwrap(), "lm-small/galore_step_r16");
        assert!(ga.micro_exe("lm-small").is_none());
    }

    #[test]
    fn none_has_no_micro_or_update() {
        let none = MethodSpec::None;
        assert!(none.micro_exe("m").is_none());
        assert!(none.update_exe("m", OptimizerKind::Adafactor).is_none());
        assert!(none.momentum_exe("m", OptimizerKind::Adafactor).is_none());
    }

    #[test]
    fn compressor_exe_names_match_native_catalog() {
        let af = OptimizerKind::Adafactor;
        let alt = MethodSpec::AltLora { rank: 8 };
        assert_eq!(alt.micro_exe("lora-tiny").unwrap(), "lora-tiny/micro_r8_altlora");
        assert_eq!(
            alt.update_exe("lora-tiny", af).unwrap(),
            "lora-tiny/update_r8_adafactor_altlora"
        );
        assert!(alt.momentum_exe("lora-tiny", af).is_none());
        assert_eq!(
            alt.vit_step_exe("vit-tiny", OptimizerKind::Sgd),
            "vit-tiny/step_r8_sgd_altlora"
        );
        let ada = MethodSpec::AdaRank { rank: 8 };
        assert!(ada.micro_exe("lora-tiny").is_none());
        assert!(ada.update_exe("lora-tiny", af).is_none());
        assert_eq!(
            ada.momentum_exe("lora-tiny", af).unwrap(),
            "lora-tiny/mom_step_r8_adafactor_adarank"
        );
        assert_eq!(
            ada.vit_step_exe("vit-tiny", af),
            "vit-tiny/step_r8_adafactor_adarank"
        );
        assert_eq!(MethodSpec::parse("altlora", 8).unwrap(), alt);
        assert_eq!(MethodSpec::parse("adarank", 8).unwrap(), ada);
        assert_eq!(alt.label(), "AltLoRA(8)");
        assert_eq!(ada.label(), "AdaRank(8)");
        assert_eq!(alt.rank(), Some(8));
        assert_eq!(ada.rank(), Some(8));
    }

    #[test]
    fn with_compressor_reroutes_flora_family_only() {
        let flora = MethodSpec::Flora { rank: 16 };
        assert_eq!(
            flora.with_compressor(CompressorKind::AltLora).unwrap(),
            MethodSpec::AltLora { rank: 16 }
        );
        assert_eq!(
            flora.with_compressor(CompressorKind::AdaRank).unwrap(),
            MethodSpec::AdaRank { rank: 16 }
        );
        assert_eq!(
            MethodSpec::AltLora { rank: 4 }
                .with_compressor(CompressorKind::Flora)
                .unwrap(),
            MethodSpec::Flora { rank: 4 }
        );
        let err = MethodSpec::Lora { rank: 8 }
            .with_compressor(CompressorKind::AltLora)
            .unwrap_err();
        assert!(err.contains("flora-family"), "{err}");
        assert!(MethodSpec::None.with_compressor(CompressorKind::AdaRank).is_err());
    }

    #[test]
    fn vit_step_names() {
        assert_eq!(
            MethodSpec::None.vit_step_exe("vit-cifar", OptimizerKind::Adam),
            "vit-cifar/step_adam"
        );
        assert_eq!(
            MethodSpec::Flora { rank: 16 }
                .vit_step_exe("vit-cifar", OptimizerKind::Adafactor),
            "vit-cifar/step_flora_r16_adafactor"
        );
    }
}
