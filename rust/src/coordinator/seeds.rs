//! Seed lifecycles — the coordinator-side half of FLORA's "store the seed,
//! not the matrix" design.
//!
//! * `AccumSeeds` (Algorithm 1): one seed per accumulation cycle; all τ
//!   micro-steps AND the decompression share it; a new cycle resamples.
//! * `MomentumSeeds` (Algorithm 2): a current/next seed pair; every κ steps
//!   the resample flag is raised, the XLA step transfers the momentum into
//!   the next subspace, and the pair rotates.
//!
//! Pure logic — no XLA — so it's exhaustively testable.

use crate::util::rng::derive_seed;

/// Algorithm-1 seed schedule.
#[derive(Clone, Debug)]
pub struct AccumSeeds {
    base: u64,
    cycle: u64,
}

impl AccumSeeds {
    pub fn new(base: u64) -> Self {
        Self { base, cycle: 0 }
    }

    /// Seed for the current cycle (u32, the ABI's scalar width).
    pub fn current(&self) -> u32 {
        derive_seed(self.base, self.cycle) as u32
    }

    /// End the cycle: the caller has decompressed + updated + zeroed the
    /// accumulator; the next cycle gets a fresh projection.
    pub fn advance(&mut self) {
        self.cycle += 1;
    }

    pub fn cycle(&self) -> u64 {
        self.cycle
    }
}

/// Algorithm-2 seed schedule.
#[derive(Clone, Debug)]
pub struct MomentumSeeds {
    base: u64,
    kappa: usize,
    interval: u64,
    step: usize,
}

/// What the fused momentum step must be told this step.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MomentumTick {
    pub seed_cur: u32,
    pub seed_next: u32,
    /// 1.0 exactly on resample steps (the XLA graph blends by this flag)
    pub resample: f32,
}

impl MomentumSeeds {
    pub fn new(base: u64, kappa: usize) -> Self {
        assert!(kappa >= 1, "kappa must be >= 1");
        Self { base, kappa, interval: 0, step: 0 }
    }

    fn seed_of(&self, interval: u64) -> u32 {
        derive_seed(self.base.wrapping_add(0xA02), interval) as u32
    }

    /// Produce this step's seeds/flag and advance the schedule.
    pub fn tick(&mut self) -> MomentumTick {
        // resample at the START of each interval after the first
        let resample = self.step > 0 && self.step % self.kappa == 0;
        if resample {
            self.interval += 1;
        }
        let t = MomentumTick {
            // on a resample step, seed_cur is the OLD subspace (needed for
            // the transfer) and seed_next the new active one
            seed_cur: self.seed_of(if resample { self.interval - 1 } else { self.interval }),
            seed_next: self.seed_of(if resample { self.interval } else { self.interval + 1 }),
            resample: if resample { 1.0 } else { 0.0 },
        };
        self.step += 1;
        t
    }

    pub fn step(&self) -> usize {
        self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accum_seed_constant_within_cycle_changes_across() {
        let mut s = AccumSeeds::new(42);
        let a = s.current();
        let b = s.current();
        assert_eq!(a, b);
        s.advance();
        assert_ne!(s.current(), a);
        assert_eq!(s.cycle(), 1);
    }

    #[test]
    fn accum_seeds_deterministic() {
        let mut x = AccumSeeds::new(7);
        let mut y = AccumSeeds::new(7);
        for _ in 0..5 {
            assert_eq!(x.current(), y.current());
            x.advance();
            y.advance();
        }
    }

    #[test]
    fn momentum_resamples_exactly_every_kappa() {
        let mut s = MomentumSeeds::new(0, 3);
        let flags: Vec<f32> = (0..10).map(|_| s.tick().resample).collect();
        assert_eq!(flags, vec![0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn momentum_seed_continuity_across_resample() {
        // the seed that was `next` before a resample must be `cur` after:
        // that's what makes the transfer target the right subspace.
        let mut s = MomentumSeeds::new(5, 2);
        let t0 = s.tick(); // step 0, no resample
        let t1 = s.tick(); // step 1, no resample
        assert_eq!(t0.seed_cur, t1.seed_cur);
        let t2 = s.tick(); // step 2: resample
        assert_eq!(t2.resample, 1.0);
        assert_eq!(t2.seed_cur, t1.seed_cur, "transfer FROM the old subspace");
        assert_eq!(t2.seed_next, t1.seed_next, "transfer INTO the announced next");
        let t3 = s.tick();
        assert_eq!(t3.resample, 0.0);
        assert_eq!(t3.seed_cur, t2.seed_next, "new interval's active seed");
    }

    #[test]
    fn kappa_one_resamples_every_step_after_first() {
        let mut s = MomentumSeeds::new(1, 1);
        assert_eq!(s.tick().resample, 0.0);
        for _ in 0..5 {
            assert_eq!(s.tick().resample, 1.0);
        }
    }

    #[test]
    #[should_panic]
    fn kappa_zero_panics() {
        MomentumSeeds::new(0, 0);
    }
}
