//! Run registry: persists every RunReport as JSON under `runs/` so bench
//! outputs are machine-readable (plots, regression diffs) and the CLI can
//! list past runs. Writing uses a small hand-rolled JSON emitter (matching
//! util::json's parser — round-trip tested).

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use super::report::{MetricValue, RunReport};
use crate::util::json::{self, Json};

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Serialize a report to JSON text.
pub fn report_to_json(r: &RunReport, name: &str) -> String {
    let mut out = String::from("{");
    let _ = write!(out, "\"name\": \"{}\",", esc(name));
    let _ = write!(out, "\"label\": \"{}\",", esc(&r.label));
    let _ = write!(out, "\"final_train_loss\": {},", f(r.final_train_loss()));
    let _ = write!(out, "\"wallclock_secs\": {},", f64s(r.wallclock_secs));
    let _ = write!(out, "\"steps_per_sec\": {},", f64s(r.steps_per_sec));
    let _ = write!(out, "\"peak_state_bytes\": {},", r.peak_state_bytes);
    match &r.metric {
        Some(MetricValue::Rouge(s)) => {
            let _ = write!(
                out,
                "\"metric\": {{\"kind\": \"rouge\", \"r1\": {}, \"r2\": {}, \"rl\": {}}},",
                f64s(s.rouge1), f64s(s.rouge2), f64s(s.rouge_l)
            );
        }
        Some(MetricValue::Bleu(b)) => {
            let _ = write!(out, "\"metric\": {{\"kind\": \"bleu\", \"score\": {}}},", f64s(*b));
        }
        Some(MetricValue::Perplexity(p)) => {
            let _ = write!(out, "\"metric\": {{\"kind\": \"ppl\", \"score\": {}}},", f64s(*p));
        }
        Some(MetricValue::Accuracy(a)) => {
            let _ = write!(out, "\"metric\": {{\"kind\": \"acc\", \"score\": {}}},", f64s(*a));
        }
        None => {
            let _ = write!(out, "\"metric\": null,");
        }
    }
    let _ = write!(out, "\"state_bytes\": {{");
    let mut first = true;
    for (g, b) in &r.state_bytes {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(out, "\"{}\": {}", esc(g), b);
    }
    out.push_str("},");
    let _ = write!(out, "\"train_losses\": [");
    for (i, l) in r.train_losses.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{}", f(*l));
    }
    out.push_str("],");
    let _ = write!(out, "\"eval_losses\": [");
    for (i, (s, l)) in r.eval_losses.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "[{}, {}]", s, f(*l));
    }
    out.push_str("]}");
    out
}

fn f(x: f32) -> String {
    if x.is_finite() { format!("{x}") } else { "null".into() }
}

fn f64s(x: f64) -> String {
    if x.is_finite() { format!("{x}") } else { "null".into() }
}

/// Append a run to the registry directory; returns the file path.
pub fn record(dir: impl AsRef<Path>, name: &str, r: &RunReport) -> Result<PathBuf, String> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir).map_err(|e| format!("mkdir {dir:?}: {e}"))?;
    // timestamped-unique filename without a clock dependency: count entries
    let n = std::fs::read_dir(dir).map_err(|e| e.to_string())?.count();
    let path = dir.join(format!("{n:05}-{}.json", name.replace('/', "_")));
    std::fs::write(&path, report_to_json(r, name)).map_err(|e| e.to_string())?;
    Ok(path)
}

/// Load a recorded run back (used by tooling/tests).
pub fn load(path: impl AsRef<Path>) -> Result<Json, String> {
    let text = std::fs::read_to_string(path.as_ref()).map_err(|e| e.to_string())?;
    json::parse(&text).map_err(|e| e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RougeScores;

    fn sample() -> RunReport {
        RunReport {
            label: "FLORA(8)".into(),
            train_losses: vec![4.0, 3.5, 3.0],
            eval_losses: vec![(1, 3.8), (2, 3.2)],
            metric: Some(MetricValue::Rouge(RougeScores {
                rouge1: 30.0,
                rouge2: 10.5,
                rouge_l: 25.0,
            })),
            state_bytes: vec![("params".into(), 1000), ("method".into(), 64)],
            peak_state_bytes: 1100,
            wallclock_secs: 1.25,
            steps_per_sec: 2.4,
        }
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let text = report_to_json(&sample(), "test-run");
        let v = json::parse(&text).expect("emitted JSON must parse");
        assert_eq!(v.get("name").unwrap().as_str(), Some("test-run"));
        assert_eq!(v.get("label").unwrap().as_str(), Some("FLORA(8)"));
        let m = v.get("metric").unwrap();
        assert_eq!(m.get("kind").unwrap().as_str(), Some("rouge"));
        assert_eq!(m.get("r1").unwrap().as_f64(), Some(30.0));
        let losses = v.get("train_losses").unwrap().as_arr().unwrap();
        assert_eq!(losses.len(), 3);
        assert_eq!(
            v.get("state_bytes").unwrap().get("method").unwrap().as_f64(),
            Some(64.0)
        );
    }

    #[test]
    fn record_and_load() {
        let dir = std::env::temp_dir().join("flora_runs_test");
        std::fs::remove_dir_all(&dir).ok();
        let p1 = record(&dir, "a/b", &sample()).unwrap();
        let p2 = record(&dir, "c", &sample()).unwrap();
        assert_ne!(p1, p2);
        let v = load(&p1).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("a/b"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn nan_becomes_null() {
        let mut r = sample();
        r.train_losses = vec![f32::NAN];
        let text = report_to_json(&r, "x");
        assert!(json::parse(&text).is_ok());
        assert!(text.contains("null"));
    }
}
