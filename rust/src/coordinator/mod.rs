//! L3 coordinator — the training orchestrator.
//!
//! FLORA's system-level state lives HERE, not in the XLA graphs: the
//! τ-cycle of Algorithm 1 (when to decompress + update + zero the
//! accumulator + resample the seed), the κ-interval of Algorithm 2 (when to
//! raise the resample flag and rotate seeds), the GaLore refresh schedule,
//! LR schedule, evaluation cadence and generation-metric evaluation. The
//! XLA executables are pure functions; this module is the state machine
//! that drives them.

pub mod checkpoint;
pub mod method;
pub mod registry;
pub mod report;
pub mod schedule;
pub mod seeds;
pub mod task;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use method::MethodSpec;
pub use schedule::Schedule;
pub use report::{MetricValue, RunReport};
pub use seeds::{AccumSeeds, MomentumSeeds};
pub use task::Task;
pub use trainer::Trainer;
