//! `flora doctor` — the ops self-check behind ROADMAP item 5.
//!
//! One command answers "is this checkout healthy enough to trust a
//! bench number or a training run?" by walking the same paths CI
//! gates on:
//!
//! * environment — toolchain build info, kernel thread budget vs the
//!   process-wide [`crate::tensor::POOL_BUDGET`], pool liveness
//!   (`Parallelism::pool_workers` + a real fan-out through
//!   [`crate::tensor::pool_tasks`]), and the packed-GEMM raw-bits
//!   tripwire (pooled packed kernels vs the naive oracles on a ragged
//!   NaN/Inf-poisoned rectangle);
//! * catalog smokes — a short real training run per family (lm / lora /
//!   vit), the serving tier's batched-vs-sequential bit-identity oracle,
//!   and the dp tier's W∈{1,2} raw-bits invariance;
//! * artifacts — every committed `BENCH_*.json` must satisfy the
//!   versioned [`crate::bench::contract`], and `BENCH_BUDGETS.toml`
//!   must parse with all three gate sections present.
//!
//! [`run`] is a pure function over [`DoctorConfig`] returning a
//! [`DoctorReport`]; the CLI layer prints the human table plus a
//! machine-readable JSON receipt (schema in docs/OPS.md §4) and exits
//! nonzero if any check failed. `--quick` shortens the smokes for the
//! CI step; the checks themselves are identical.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use crate::bench::contract::{self, BenchFile};
use crate::config::{DpConfig, TaskKind, TrainConfig};
use crate::coordinator::{MethodSpec, Trainer};
use crate::model::TransformerConfig;
use crate::opt::OptimizerKind;
use crate::runtime::dp::DpTrainer;
use crate::runtime::serve::oracle_check;
use crate::runtime::AdapterRegistry;
use crate::tensor::{pool_tasks, Matrix, Parallelism, POOL_BUDGET};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Receipt schema version (`receipt_schema` in the JSON output).
pub const RECEIPT_SCHEMA: usize = 1;

/// What to check and how hard.
#[derive(Clone, Debug)]
pub struct DoctorConfig {
    /// Shorten the catalog smokes (CI uses this; checks are identical).
    pub quick: bool,
    /// Kernel thread budget for the smokes (installed process-wide).
    pub parallelism: Parallelism,
    /// Directory holding `BENCH_*.json` + `BENCH_BUDGETS.toml`
    /// (default "." — run from the repo root).
    pub bench_dir: String,
}

impl Default for DoctorConfig {
    fn default() -> Self {
        Self {
            quick: false,
            parallelism: Parallelism::new(2),
            bench_dir: ".".into(),
        }
    }
}

/// One check's outcome.
#[derive(Clone, Debug)]
pub struct CheckOutcome {
    pub name: String,
    pub passed: bool,
    /// Pass: what was verified. Fail: the error, path-bearing.
    pub detail: String,
    pub ms: f64,
}

/// Everything `doctor` found, renderable as a JSON receipt.
#[derive(Clone, Debug)]
pub struct DoctorReport {
    pub quick: bool,
    pub parallelism: usize,
    pub checks: Vec<CheckOutcome>,
}

impl DoctorReport {
    pub fn ok(&self) -> bool {
        self.checks.iter().all(|c| c.passed)
    }

    pub fn failed_names(&self) -> Vec<String> {
        self.checks
            .iter()
            .filter(|c| !c.passed)
            .map(|c| c.name.clone())
            .collect()
    }

    /// The machine-readable receipt (docs/OPS.md §4). `failed` repeats
    /// the failing check names so a harness can act without scanning
    /// the per-check list.
    pub fn receipt(&self) -> Json {
        let checks: Vec<Json> = self
            .checks
            .iter()
            .map(|c| {
                obj(vec![
                    ("name", Json::Str(c.name.clone())),
                    ("status", Json::Str(if c.passed { "ok" } else { "fail" }.into())),
                    ("detail", Json::Str(c.detail.clone())),
                    ("ms", Json::Num((c.ms * 10.0).round() / 10.0)),
                ])
            })
            .collect();
        let failed: Vec<Json> =
            self.failed_names().into_iter().map(Json::Str).collect();
        obj(vec![
            ("tool", Json::Str("flora-doctor".into())),
            ("receipt_schema", Json::Num(RECEIPT_SCHEMA as f64)),
            ("version", Json::Str(env!("CARGO_PKG_VERSION").into())),
            ("unix_time", Json::Num(contract::unix_time_now() as f64)),
            ("quick", Json::Bool(self.quick)),
            ("parallelism", Json::Num(self.parallelism as f64)),
            ("ok", Json::Bool(self.ok())),
            ("checks", Json::Arr(checks)),
            ("failed", Json::Arr(failed)),
        ])
    }
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Run every check. Never panics and never early-exits: a failed check
/// is recorded and the rest still run, so one receipt names every
/// problem at once.
pub fn run(cfg: &DoctorConfig) -> DoctorReport {
    let mut checks: Vec<CheckOutcome> = Vec::new();
    let mut check = |name: String, f: &dyn Fn() -> Result<String, String>| {
        let t0 = Instant::now();
        let (passed, detail) = match f() {
            Ok(d) => (true, d),
            Err(e) => (false, e),
        };
        checks.push(CheckOutcome {
            name,
            passed,
            detail,
            ms: t0.elapsed().as_secs_f64() * 1e3,
        });
    };

    let par = cfg.parallelism;
    let steps = if cfg.quick { 1 } else { 2 };
    let dp_steps = if cfg.quick { 2 } else { 3 };

    check("toolchain".into(), &check_toolchain);
    check("thread-budget".into(), &move || check_thread_budget(par));
    check("pool-health".into(), &move || check_pool_health(par));
    check("kernels".into(), &move || check_kernels(par));
    check("smoke:lm".into(), &move || {
        smoke_train("lm-tiny", TaskKind::Lm, MethodSpec::Flora { rank: 4 }, steps, par)
    });
    check("smoke:lora".into(), &move || {
        smoke_train("lora-tiny", TaskKind::Lm, MethodSpec::Lora { rank: 4 }, steps, par)
    });
    check("smoke:vit".into(), &move || {
        smoke_train("vit-tiny", TaskKind::Vit, MethodSpec::Flora { rank: 4 }, steps, par)
    });
    // the adaptive-rank compressor grid rides the same smoke: one tiny
    // run per compressor proves the catalog stamped out its variants
    check("smoke:altlora".into(), &move || {
        smoke_train("lora-tiny", TaskKind::Lm, MethodSpec::AltLora { rank: 4 }, steps, par)
    });
    check("smoke:adarank".into(), &move || {
        smoke_train("lora-tiny", TaskKind::Lm, MethodSpec::AdaRank { rank: 4 }, steps, par)
    });
    check("smoke:serve".into(), &smoke_serve);
    check("smoke:dp".into(), &move || smoke_dp(dp_steps, par));
    for (file, bench) in contract::COMMITTED_FILES {
        let dir = cfg.bench_dir.clone();
        check(format!("bench-contract:{file}"), &move || {
            check_bench_file(&dir, file, bench)
        });
    }
    let dir = cfg.bench_dir.clone();
    check("bench-budgets".into(), &move || check_budgets(&dir));

    DoctorReport {
        quick: cfg.quick,
        parallelism: cfg.parallelism.threads(),
        checks,
    }
}

fn check_toolchain() -> Result<String, String> {
    let profile = if cfg!(debug_assertions) { "debug" } else { "release" };
    let xla = if cfg!(feature = "xla") { "on" } else { "off" };
    Ok(format!(
        "flora {} ({profile} build, xla feature {xla}, {})",
        env!("CARGO_PKG_VERSION"),
        std::env::consts::ARCH
    ))
}

fn check_thread_budget(par: Parallelism) -> Result<String, String> {
    let threads = par.threads();
    if threads > POOL_BUDGET {
        return Err(format!(
            "requested parallelism {threads} exceeds the process pool budget \
             of {POOL_BUDGET} threads"
        ));
    }
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0);
    let note = if hw > 0 && threads > hw {
        format!(" — OVERSUBSCRIBED (host reports {hw} hardware threads)")
    } else {
        format!(" (host reports {hw} hardware threads)")
    };
    Ok(format!("parallelism {threads} within pool budget {POOL_BUDGET}{note}"))
}

/// Install the budget, then prove the persistent pool is both sized and
/// alive: `pool_workers` must report at least `threads - 1` workers and
/// a real `pool_tasks` fan-out must run every task exactly once.
fn check_pool_health(par: Parallelism) -> Result<String, String> {
    par.install();
    let threads = par.threads();
    let want = threads.saturating_sub(1);
    let workers = Parallelism::pool_workers();
    if workers < want {
        return Err(format!(
            "pool has {workers} live worker(s) after installing a budget of \
             {threads} (expected >= {want}) — the persistent pool failed to start"
        ));
    }
    let hits = AtomicUsize::new(0);
    pool_tasks(threads, |_| {
        hits.fetch_add(1, Ordering::Relaxed);
    });
    let ran = hits.load(Ordering::Relaxed);
    if ran != threads {
        return Err(format!(
            "pool fan-out ran {ran} of {threads} tasks — jobs are being dropped"
        ));
    }
    Ok(format!(
        "{workers} live worker(s) for budget {threads}; {ran}/{threads} \
         fan-out tasks ran"
    ))
}

/// The packed-GEMM tripwire (PR 9): the blocked kernels — which pack
/// the strided operand's panel into a reused thread-local scratch and
/// run pooled at the installed budget — must reproduce the retained
/// naive serial oracles **raw-bits** on a ragged random rectangle, for
/// all three transpose layouts, with NaN/Inf poison propagated (the
/// kernels never skip zero terms, so `0·NaN` must stay NaN).
fn check_kernels(par: Parallelism) -> Result<String, String> {
    par.install();
    let (n, k, m) = (37usize, 53usize, 41usize);
    let mut rng = Rng::new(0xd0c);
    let mut a = Matrix::zeros(n, k); // nn/nt left operand
    let mut b = Matrix::zeros(k, m); // nn right operand
    let mut c = Matrix::zeros(m, k); // nt right operand (row-major [m,k])
    let mut at = Matrix::zeros(k, n); // tn left operand (contraction-major)
    rng.fill_gaussian(&mut a.data, 1.0);
    rng.fill_gaussian(&mut b.data, 1.0);
    rng.fill_gaussian(&mut c.data, 1.0);
    rng.fill_gaussian(&mut at.data, 1.0);
    *a.at_mut(3, 5) = f32::NAN;
    *a.at_mut(7, 11) = f32::INFINITY;
    *b.at_mut(2, 9) = f32::NEG_INFINITY;
    *at.at_mut(1, 4) = f32::NAN;
    let pairs: [(&str, Matrix, Matrix); 3] = [
        ("nn", a.matmul(&b), a.matmul_naive(&b)),
        ("nt", a.matmul_nt(&c), a.matmul_nt_naive(&c)),
        ("tn", at.matmul_tn(&b), at.matmul_tn_naive(&b)),
    ];
    for (layout, got, want) in &pairs {
        if !got.data.iter().any(|v| !v.is_finite()) {
            return Err(format!(
                "{layout}: NaN/Inf poison vanished — a kernel is skipping terms"
            ));
        }
        for (i, (g, w)) in got.data.iter().zip(want.data.iter()).enumerate() {
            if g.to_bits() != w.to_bits() {
                return Err(format!(
                    "{layout}: packed kernel diverges from the naive oracle at \
                     flat index {i}: {g} vs {w} (raw bits {:#010x} vs {:#010x})",
                    g.to_bits(),
                    w.to_bits()
                ));
            }
        }
    }
    Ok(format!(
        "packed nn/nt/tn at threads {} bit-match the naive oracles on \
         {n}x{k}x{m} (NaN/Inf propagated)",
        par.threads()
    ))
}

/// A short real training run through the native catalog — the same
/// construction path as `flora train`.
fn smoke_train(
    model: &str,
    task: TaskKind,
    method: MethodSpec,
    steps: usize,
    par: Parallelism,
) -> Result<String, String> {
    let cfg = TrainConfig {
        model: model.into(),
        task,
        method,
        optimizer: OptimizerKind::Sgd,
        lr: 0.1,
        steps,
        tau: 1,
        kappa: 4,
        batch: 2,
        seed: 0,
        eval_every: 0,
        eval_samples: 4,
        parallelism: par,
        ..TrainConfig::default()
    };
    let report = Trainer::native(cfg)
        .and_then(|mut t| t.run())
        .map_err(|e| format!("{model}: {e}"))?;
    let loss = report.final_train_loss();
    if !loss.is_finite() {
        return Err(format!("{model}: non-finite final loss {loss}"));
    }
    Ok(format!("{model}: {steps} step(s), final loss {loss:.4}"))
}

/// The serving tier's tripwire: batched mixed-adapter decode must
/// bit-match the sequential single-adapter oracle.
fn smoke_serve() -> Result<String, String> {
    let (_, cfg) = TransformerConfig::catalog_grid()
        .into_iter()
        .find(|(name, _)| *name == "lora-tiny")
        .ok_or_else(|| "lora-tiny missing from the catalog grid".to_string())?;
    let base = cfg.init(0);
    let mut reg = AdapterRegistry::new(2);
    let names: Vec<String> = (0..2).map(|i| format!("doctor-{i}")).collect();
    for (i, n) in names.iter().enumerate() {
        reg.insert_synthetic(n, &cfg, &base, 4, 1 + i as u64)
            .map_err(|e| format!("synthetic adapter {n}: {e}"))?;
    }
    let adapters = reg.get_many(&names)?;
    let prompt_len = (cfg.seq_len / 2).max(1);
    let max_new = (cfg.seq_len / 4).max(1);
    let prompts: Vec<Vec<i32>> = (0..2)
        .map(|i| (0..prompt_len).map(|j| ((3 + i + 2 * j) % cfg.vocab) as i32).collect())
        .collect();
    oracle_check(&cfg, &base, &adapters, &prompts, max_new)
        .map_err(|e| format!("lora-tiny: oracle mismatch: {e}"))?;
    Ok(format!(
        "lora-tiny: batched b=2 decode bit-matches the sequential oracle \
         ({max_new} new tokens)"
    ))
}

/// The dp tier's tripwire: the same config at W=1 and W=2 must produce
/// raw-bits-identical loss curves and final parameters.
fn smoke_dp(steps: usize, par: Parallelism) -> Result<String, String> {
    let mk = |workers: usize| {
        let mut cfg = DpConfig::default();
        cfg.train.steps = steps;
        cfg.train.workers = workers;
        cfg.train.parallelism = par;
        cfg.shards = 2;
        cfg
    };
    let model = mk(1).train.model.clone();
    let run = |workers: usize| {
        let mut tr = DpTrainer::new(mk(workers))
            .map_err(|e| format!("{model}: dp trainer (W={workers}): {e}"))?;
        let report =
            tr.run().map_err(|e| format!("{model}: dp run (W={workers}): {e}"))?;
        Ok::<_, String>((report, tr))
    };
    let (ra, ta) = run(1)?;
    let (rb, tb) = run(2)?;
    let la: Vec<u32> = ra.train_losses.iter().map(|x| x.to_bits()).collect();
    let lb: Vec<u32> = rb.train_losses.iter().map(|x| x.to_bits()).collect();
    if la != lb {
        return Err(format!("{model}: W=2 loss curve diverges from W=1 (raw bits)"));
    }
    for (name, p) in ta.params() {
        let q = &tb.params()[name];
        let pb: Vec<u32> = p.data.iter().map(|x| x.to_bits()).collect();
        let qb: Vec<u32> = q.data.iter().map(|x| x.to_bits()).collect();
        if pb != qb {
            return Err(format!(
                "{model}: W=2 parameter {name} diverges from W=1 (raw bits)"
            ));
        }
    }
    Ok(format!(
        "{model}: W=2 bit-matches W=1 over {steps} step(s) ({} params)",
        ta.params().len()
    ))
}

fn bench_path(dir: &str, file: &str) -> String {
    format!("{}/{}", dir.trim_end_matches('/'), file)
}

/// Validate one committed trajectory against the versioned contract —
/// the exact code path CI and the bench binaries use.
fn check_bench_file(dir: &str, file: &str, bench: &str) -> Result<String, String> {
    let path = bench_path(dir, file);
    if !std::path::Path::new(&path).exists() {
        return Err(format!(
            "{path}: not found — run from the repo root or pass --bench-dir"
        ));
    }
    let f = BenchFile::load(&path).map_err(|e| e.to_string())?;
    if f.bench != bench {
        return Err(format!(
            "{path}: bench name {:?} does not match the expected {bench:?}",
            f.bench
        ));
    }
    let latest = f.trajectory.last().and_then(|s| s.provenance.clone());
    Ok(format!(
        "{path}: schema {} valid, {} snapshot(s), latest provenance {:?}",
        contract::SCHEMA_VERSION,
        f.trajectory.len(),
        latest.unwrap_or_default()
    ))
}

/// `BENCH_BUDGETS.toml` must parse under the zero-dep TOML subset and
/// carry a section per gated bench (the CI gate reads it with its own
/// mirror parser — this catches a broken edit before it reaches CI).
fn check_budgets(dir: &str) -> Result<String, String> {
    let path = bench_path(dir, "BENCH_BUDGETS.toml");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| format!("{path}: cannot read: {e}"))?;
    let map = crate::config::parse_toml(&text).map_err(|e| format!("{path}: {e}"))?;
    for section in ["kernels", "serving", "dp"] {
        let prefix = format!("{section}.");
        if !map.keys().any(|k| k.starts_with(&prefix)) {
            return Err(format!("{path}: no [{section}] budget section"));
        }
    }
    Ok(format!("{path}: parses; kernels/serving/dp sections present ({} keys)", map.len()))
}
