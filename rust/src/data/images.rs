//! Synthetic image classification: class templates + Gaussian noise.
//!
//! Stands in for Fashion-MNIST (the Figure-1 pilot, flat 784-dim vectors)
//! and CIFAR-100 (the Table-5 ViT run, H×W×C tensors). Each class has a
//! fixed smooth template; a sample is template + noise, so the Bayes error
//! is controlled by the noise scale and every optimizer sees the same
//! separable-but-nontrivial problem.

use crate::tensor::Matrix;
use crate::util::rng::{derive_seed, Rng};

#[derive(Clone)]
pub struct ImageTask {
    pub classes: usize,
    pub dim: usize,
    pub noise: f32,
    /// [classes][dim] templates
    templates: Vec<Vec<f32>>,
}

impl ImageTask {
    /// Flat-vector variant (pilot MLP): `dim`-dimensional inputs.
    pub fn fashion_like(classes: usize, dim: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Rng::new(derive_seed(seed, 0xF00D));
        let templates = (0..classes)
            .map(|_| {
                // smooth template: random walk, unit-normalized — images
                // have local correlation, this mimics it
                let mut t = vec![0.0f32; dim];
                let mut v = 0.0f32;
                for x in t.iter_mut() {
                    v = 0.9 * v + 0.45 * rng.next_gaussian_f32();
                    *x = v;
                }
                let norm = t.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
                for x in t.iter_mut() {
                    *x = *x / norm * (dim as f32).sqrt() * 0.5;
                }
                t
            })
            .collect();
        Self { classes, dim, noise, templates }
    }

    /// CIFAR-like variant for the ViT: side×side×channels flattened in
    /// HWC order (the layout `vit._patchify` expects).
    pub fn cifar_like(
        classes: usize,
        side: usize,
        channels: usize,
        noise: f32,
        seed: u64,
    ) -> Self {
        Self::fashion_like(classes, side * side * channels, noise, seed)
    }

    pub fn input_dim(&self) -> usize {
        self.dim
    }

    /// Fill a [batch × dim] matrix + labels (pilot MLP interface).
    pub fn fill_batch(&self, xs: &mut Matrix, ys: &mut [usize], rng: &mut Rng) {
        assert_eq!(xs.cols, self.dim);
        assert_eq!(xs.rows, ys.len());
        for b in 0..xs.rows {
            let y = rng.next_below(self.classes);
            ys[b] = y;
            let t = &self.templates[y];
            let row = &mut xs.data[b * self.dim..(b + 1) * self.dim];
            for (o, &tv) in row.iter_mut().zip(t.iter()) {
                *o = tv + self.noise * rng.next_gaussian_f32();
            }
        }
    }

    /// Flat f32 image batch + i32 labels (ViT runtime-literal interface).
    /// Deterministic per (split, cursor) like the sequence tasks.
    pub fn fill_flat(
        &self,
        batch: usize,
        split: u64,
        cursor: &mut u64,
        seed: u64,
    ) -> (Vec<f32>, Vec<i32>) {
        let mut images = Vec::with_capacity(batch * self.dim);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let mut rng = Rng::new(derive_seed(derive_seed(seed, split + 50), *cursor));
            let y = rng.next_below(self.classes);
            labels.push(y as i32);
            let t = &self.templates[y];
            for &tv in t.iter() {
                images.push(tv + self.noise * rng.next_gaussian_f32());
            }
            *cursor += 1;
        }
        (images, labels)
    }
}

/// Patchify a flat HWC image batch for the ViT: `[b, side, side, c]`
/// (row-major, channel fastest — the layout [`ImageTask::fill_flat`]
/// produces and `vit._patchify` expects) into a `[b * n_patches,
/// patch_size² · c]` matrix whose row `b·n_patches + p` is patch `p` of
/// image `b`, scanning patches row-major and pixels within a patch
/// row-major with channels interleaved.
pub fn patchify_hwc(
    images: &[f32],
    batch: usize,
    side: usize,
    patch: usize,
    channels: usize,
) -> Result<Matrix, String> {
    if patch == 0 || side % patch != 0 {
        return Err(format!("patch size {patch} does not divide image side {side}"));
    }
    if images.len() != batch * side * side * channels {
        return Err(format!(
            "image batch length {} != {batch}x{side}x{side}x{channels}",
            images.len()
        ));
    }
    let per_side = side / patch;
    let n_patches = per_side * per_side;
    let patch_dim = patch * patch * channels;
    let mut out = Matrix::zeros(batch * n_patches, patch_dim);
    for b in 0..batch {
        let img = &images[b * side * side * channels..(b + 1) * side * side * channels];
        for pi in 0..per_side {
            for pj in 0..per_side {
                let row = b * n_patches + pi * per_side + pj;
                let orow = &mut out.data[row * patch_dim..(row + 1) * patch_dim];
                let mut o = 0usize;
                for ii in 0..patch {
                    for jj in 0..patch {
                        let y = pi * patch + ii;
                        let x = pj * patch + jj;
                        let src = (y * side + x) * channels;
                        for c in 0..channels {
                            orow[o] = img[src + c];
                            o += 1;
                        }
                    }
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn templates_are_distinct() {
        let t = ImageTask::fashion_like(10, 128, 0.1, 0);
        for i in 0..10 {
            for j in (i + 1)..10 {
                let d: f32 = t.templates[i]
                    .iter()
                    .zip(t.templates[j].iter())
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f32>()
                    .sqrt();
                assert!(d > 1.0, "templates {i},{j} too close: {d}");
            }
        }
    }

    #[test]
    fn batch_labels_cover_classes() {
        let t = ImageTask::fashion_like(4, 32, 0.2, 1);
        let mut rng = Rng::new(2);
        let mut xs = Matrix::zeros(64, 32);
        let mut ys = vec![0usize; 64];
        t.fill_batch(&mut xs, &mut ys, &mut rng);
        let mut seen = [false; 4];
        for &y in &ys {
            seen[y] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn samples_cluster_around_template() {
        let t = ImageTask::fashion_like(2, 64, 0.05, 3);
        let mut rng = Rng::new(4);
        let mut xs = Matrix::zeros(8, 64);
        let mut ys = vec![0usize; 8];
        t.fill_batch(&mut xs, &mut ys, &mut rng);
        for b in 0..8 {
            let tmpl = &t.templates[ys[b]];
            let d: f32 = xs.row(b)
                .iter()
                .zip(tmpl.iter())
                .map(|(a, c)| (a - c) * (a - c))
                .sum::<f32>()
                / 64.0;
            assert!(d < 0.01, "sample {b} too far from its template: {d}");
        }
    }

    #[test]
    fn patchify_roundtrips_pixels() {
        // 4x4 image, 2x2 patches, 1 channel: values = linear index
        let side = 4usize;
        let images: Vec<f32> = (0..side * side).map(|i| i as f32).collect();
        let m = patchify_hwc(&images, 1, side, 2, 1).unwrap();
        assert_eq!(m.shape(), (4, 4));
        // patch (0,0) = pixels (0,0),(0,1),(1,0),(1,1) = 0,1,4,5
        assert_eq!(m.row(0).to_vec(), vec![0.0, 1.0, 4.0, 5.0]);
        // patch (1,1) = pixels (2,2),(2,3),(3,2),(3,3) = 10,11,14,15
        assert_eq!(m.row(3).to_vec(), vec![10.0, 11.0, 14.0, 15.0]);
    }

    #[test]
    fn patchify_validates_shapes() {
        assert!(patchify_hwc(&[0.0; 16], 1, 4, 3, 1).is_err());
        assert!(patchify_hwc(&[0.0; 15], 1, 4, 2, 1).is_err());
    }

    #[test]
    fn fill_flat_deterministic() {
        let t = ImageTask::cifar_like(20, 16, 3, 0.25, 5);
        assert_eq!(t.input_dim(), 16 * 16 * 3);
        let (mut c1, mut c2) = (0, 0);
        let (i1, l1) = t.fill_flat(4, 0, &mut c1, 5);
        let (i2, l2) = t.fill_flat(4, 0, &mut c2, 5);
        assert_eq!(i1, i2);
        assert_eq!(l1, l2);
        assert_eq!(i1.len(), 4 * 768);
        // next cursor position gives different data
        let (i3, _) = t.fill_flat(4, 0, &mut c1, 5);
        assert_ne!(i1, i3);
    }
}
