//! Synthetic conditional-generation tasks standing in for XSum (sum) and
//! IWSLT17 De-En (mt). Both are prefix-LM encodings:
//!
//!   [BOS, source..., SEP, target..., EOS, PAD...]
//!
//! with loss mask = 1 exactly on the target..EOS span (the positions whose
//! prediction is scored), matching `layers.lm_loss` on the python side.

use super::special::*;
use super::zipf::Zipf;
use super::{GenExample, LmBatch};
use crate::util::rng::{derive_seed, Rng};

/// Summarization-like task. An "article" is a stream of tokens from one of
/// `topics` topic vocabularies (Zipf within topic); its "summary" is the
/// first `summary_len` *salient* tokens — the lexically smallest tokens
/// that appear at least twice — a rule a prefix-LM can learn, so ROUGE
/// tracks optimization quality exactly like it does on XSum.
#[derive(Clone)]
pub struct SumTask {
    pub vocab: usize,
    pub seq_len: usize,
    pub topics: usize,
    pub article_len: usize,
    pub summary_len: usize,
    zipf: Zipf,
    seed: u64,
}

impl SumTask {
    pub fn new(vocab: usize, seq_len: usize, seed: u64) -> Self {
        assert!(vocab >= 32, "need room for specials + content");
        // layout: article | SEP | summary | EOS, under seq_len with BOS
        let article_len = (seq_len - 4) * 2 / 3;
        let summary_len = (seq_len - 4) - article_len;
        Self {
            vocab,
            seq_len,
            topics: 4,
            article_len,
            summary_len,
            zipf: Zipf::new(24, 1.05),
            seed,
        }
    }

    fn content_range(&self) -> i32 {
        self.vocab as i32 - CONTENT0
    }

    /// Deterministic article for example index `idx` of split `split`.
    fn article(&self, split: u64, idx: u64) -> Vec<i32> {
        let mut rng = Rng::new(derive_seed(derive_seed(self.seed, split), idx));
        let topic = rng.next_below(self.topics) as i32;
        let span = self.content_range() / self.topics as i32;
        let base = CONTENT0 + topic * span;
        (0..self.article_len)
            .map(|_| {
                let r = self.zipf.sample(&mut rng) as i32 % span;
                base + r
            })
            .collect()
    }

    /// The task's ground-truth extraction rule.
    pub fn summarize(&self, article: &[i32]) -> Vec<i32> {
        let mut counts = std::collections::BTreeMap::new();
        for &t in article {
            *counts.entry(t).or_insert(0usize) += 1;
        }
        let mut salient: Vec<i32> = counts
            .into_iter()
            .filter(|&(_, c)| c >= 2)
            .map(|(t, _)| t)
            .collect();
        salient.truncate(self.summary_len);
        // pad the rule's output to a fixed length with the most common token
        while salient.len() < self.summary_len {
            salient.push(*article.first().unwrap_or(&CONTENT0));
        }
        salient
    }

    fn encode(&self, article: &[i32], summary: &[i32]) -> (Vec<i32>, Vec<f32>) {
        let mut toks = Vec::with_capacity(self.seq_len);
        let mut mask = Vec::with_capacity(self.seq_len);
        toks.push(BOS);
        mask.push(0.0);
        for &t in article {
            toks.push(t);
            mask.push(0.0);
        }
        toks.push(SEP);
        mask.push(0.0);
        for &t in summary {
            toks.push(t);
            mask.push(1.0);
        }
        toks.push(EOS);
        mask.push(1.0);
        while toks.len() < self.seq_len {
            toks.push(PAD);
            mask.push(0.0);
        }
        toks.truncate(self.seq_len);
        mask.truncate(self.seq_len);
        (toks, mask)
    }

    /// Fill a training batch from split `split` (0=train, 1=val, 2=test).
    pub fn fill_batch(&self, out: &mut LmBatch, split: u64, cursor: &mut u64) {
        for b in 0..out.batch {
            let art = self.article(split, *cursor);
            let sum = self.summarize(&art);
            let (t, m) = self.encode(&art, &sum);
            let off = b * out.seq_len;
            out.tokens[off..off + out.seq_len].copy_from_slice(&t);
            out.mask[off..off + out.seq_len].copy_from_slice(&m);
            *cursor += 1;
        }
    }

    /// Generation-eval examples: prompt = [BOS, article, SEP], reference =
    /// the rule's summary.
    pub fn gen_examples(&self, split: u64, n: usize) -> Vec<GenExample> {
        (0..n as u64)
            .map(|i| {
                let art = self.article(split, i);
                let mut prompt = vec![BOS];
                prompt.extend_from_slice(&art);
                prompt.push(SEP);
                GenExample { prompt, reference: self.summarize(&art) }
            })
            .collect()
    }

    pub fn prompt_len(&self) -> usize {
        self.article_len + 2
    }

    pub fn target_len(&self) -> usize {
        self.summary_len
    }
}

/// Translation-like task: target = deterministic bijection of the source
/// tokens with adjacent-pair reordering (a "grammar"). BLEU then measures
/// how faithfully the model learned the mapping — the IWSLT analogue.
#[derive(Clone)]
pub struct MtTask {
    pub vocab: usize,
    pub seq_len: usize,
    pub src_len: usize,
    zipf: Zipf,
    seed: u64,
}

impl MtTask {
    pub fn new(vocab: usize, seq_len: usize, seed: u64) -> Self {
        assert!(vocab >= 32);
        let src_len = (seq_len - 4) / 2;
        Self { vocab, seq_len, src_len, zipf: Zipf::new(32, 1.05), seed }
    }

    fn half(&self) -> i32 {
        (self.vocab as i32 - CONTENT0) / 2
    }

    fn source(&self, split: u64, idx: u64) -> Vec<i32> {
        let mut rng = Rng::new(derive_seed(derive_seed(self.seed, split + 100), idx));
        let h = self.half();
        (0..self.src_len)
            .map(|_| CONTENT0 + (self.zipf.sample(&mut rng) as i32 % h))
            .collect()
    }

    /// Multiplier for the affine token map — picked coprime with `h` so the
    /// map is a bijection for any vocab size.
    fn multiplier(&self) -> i32 {
        let h = self.half();
        for a in [5i32, 7, 11, 13, 17, 19, 23] {
            if gcd(a, h) == 1 {
                return a;
            }
        }
        1
    }

    /// The deterministic "translation": map into the upper half of the
    /// vocab via an affine bijection, then swap adjacent pairs (word-order
    /// divergence, the interesting part of translation).
    pub fn translate(&self, src: &[i32]) -> Vec<i32> {
        let h = self.half();
        let a = self.multiplier();
        let mut tgt: Vec<i32> = src
            .iter()
            .map(|&t| {
                let x = t - CONTENT0;
                let mapped = (x * a + 3).rem_euclid(h);
                CONTENT0 + h + mapped
            })
            .collect();
        for pair in tgt.chunks_mut(2) {
            if pair.len() == 2 {
                pair.swap(0, 1);
            }
        }
        tgt
    }

    fn encode(&self, src: &[i32], tgt: &[i32]) -> (Vec<i32>, Vec<f32>) {
        let mut toks = Vec::with_capacity(self.seq_len);
        let mut mask = Vec::with_capacity(self.seq_len);
        toks.push(BOS);
        mask.push(0.0);
        for &t in src {
            toks.push(t);
            mask.push(0.0);
        }
        toks.push(SEP);
        mask.push(0.0);
        for &t in tgt {
            toks.push(t);
            mask.push(1.0);
        }
        toks.push(EOS);
        mask.push(1.0);
        while toks.len() < self.seq_len {
            toks.push(PAD);
            mask.push(0.0);
        }
        toks.truncate(self.seq_len);
        mask.truncate(self.seq_len);
        (toks, mask)
    }

    pub fn fill_batch(&self, out: &mut LmBatch, split: u64, cursor: &mut u64) {
        for b in 0..out.batch {
            let src = self.source(split, *cursor);
            let tgt = self.translate(&src);
            let (t, m) = self.encode(&src, &tgt);
            let off = b * out.seq_len;
            out.tokens[off..off + out.seq_len].copy_from_slice(&t);
            out.mask[off..off + out.seq_len].copy_from_slice(&m);
            *cursor += 1;
        }
    }

    pub fn gen_examples(&self, split: u64, n: usize) -> Vec<GenExample> {
        (0..n as u64)
            .map(|i| {
                let src = self.source(split, i);
                let mut prompt = vec![BOS];
                prompt.extend_from_slice(&src);
                prompt.push(SEP);
                GenExample { prompt, reference: self.translate(&src) }
            })
            .collect()
    }

    pub fn prompt_len(&self) -> usize {
        self.src_len + 2
    }

    pub fn target_len(&self) -> usize {
        self.src_len
    }
}

fn gcd(a: i32, b: i32) -> i32 {
    if b == 0 { a.abs() } else { gcd(b, a % b) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_batch_shape_and_mask() {
        let t = SumTask::new(256, 64, 0);
        let mut b = LmBatch::zeros(4, 64);
        let mut cur = 0;
        t.fill_batch(&mut b, 0, &mut cur);
        assert_eq!(cur, 4);
        assert_eq!(b.tokens.len(), 256);
        // every row starts with BOS, has exactly one SEP, mask covers
        // summary + EOS only
        for r in 0..4 {
            let toks = b.row_tokens(r);
            assert_eq!(toks[0], BOS);
            let seps = toks.iter().filter(|&&t| t == SEP).count();
            assert_eq!(seps, 1);
            let mask = &b.mask[r * 64..(r + 1) * 64];
            let n_masked = mask.iter().filter(|&&m| m > 0.0).count();
            assert_eq!(n_masked, t.summary_len + 1); // + EOS
        }
    }

    #[test]
    fn sum_deterministic_per_split_index() {
        let t = SumTask::new(256, 64, 5);
        let mut b1 = LmBatch::zeros(2, 64);
        let mut b2 = LmBatch::zeros(2, 64);
        let (mut c1, mut c2) = (0, 0);
        t.fill_batch(&mut b1, 0, &mut c1);
        t.fill_batch(&mut b2, 0, &mut c2);
        assert_eq!(b1.tokens, b2.tokens);
        // different split → different data
        let mut b3 = LmBatch::zeros(2, 64);
        let mut c3 = 0;
        t.fill_batch(&mut b3, 1, &mut c3);
        assert_ne!(b1.tokens, b3.tokens);
    }

    #[test]
    fn summary_rule_is_learnable_signal() {
        // the summary is a pure function of the article
        let t = SumTask::new(256, 64, 1);
        let art = t.article(0, 42);
        assert_eq!(t.summarize(&art), t.summarize(&art));
        assert_eq!(t.summarize(&art).len(), t.summary_len);
    }

    #[test]
    fn mt_translation_bijective_on_tokens() {
        let t = MtTask::new(256, 64, 2);
        let h = t.half();
        let mut seen = std::collections::HashSet::new();
        for x in 0..h {
            let tgt = t.translate(&[CONTENT0 + x]);
            assert!(tgt[0] >= CONTENT0 + h && tgt[0] < CONTENT0 + 2 * h);
            seen.insert(tgt[0]);
        }
        assert_eq!(seen.len() as i32, h, "affine map must be a bijection");
    }

    #[test]
    fn mt_pair_swap() {
        let t = MtTask::new(256, 64, 3);
        let src = vec![CONTENT0, CONTENT0 + 1, CONTENT0 + 2, CONTENT0 + 3];
        let tgt = t.translate(&src);
        let a = t.multiplier();
        let unswapped: Vec<i32> = src
            .iter()
            .map(|&s| {
                let x = s - CONTENT0;
                CONTENT0 + t.half() + (x * a + 3).rem_euclid(t.half())
            })
            .collect();
        assert_eq!(tgt[0], unswapped[1]);
        assert_eq!(tgt[1], unswapped[0]);
    }

    #[test]
    fn gen_examples_match_training_distribution() {
        let t = MtTask::new(256, 64, 4);
        let ex = t.gen_examples(2, 8);
        assert_eq!(ex.len(), 8);
        for e in &ex {
            assert_eq!(e.prompt.len(), t.prompt_len());
            assert_eq!(e.prompt[0], BOS);
            assert_eq!(*e.prompt.last().unwrap(), SEP);
            assert_eq!(e.reference.len(), t.target_len());
        }
    }

    #[test]
    fn fits_in_seq_len() {
        for seq in [32usize, 64, 128] {
            let t = SumTask::new(256, seq, 0);
            assert!(1 + t.article_len + 1 + t.summary_len + 1 <= seq);
            let t = MtTask::new(256, seq, 0);
            assert!(1 + t.src_len + 1 + t.src_len + 1 <= seq);
        }
    }
}
