//! Zipf-distributed token sampling — natural-language-like marginals for
//! the synthetic corpora (rank-frequency f(k) ∝ 1/k^s).

use crate::util::rng::Rng;

/// Precomputed Zipf sampler over `n` items with exponent `s`.
#[derive(Clone, Debug)]
pub struct Zipf {
    /// cumulative distribution, cdf[i] = P(X <= i)
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut total = 0.0;
        for k in 1..=n {
            total += 1.0 / (k as f64).powf(s);
            cdf.push(total);
        }
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Sample a rank in [0, n) — binary search over the CDF.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Probability of rank k.
    pub fn pmf(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pmf_sums_to_one() {
        let z = Zipf::new(100, 1.1);
        let total: f64 = (0..100).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn rank_frequency_shape() {
        let z = Zipf::new(50, 1.0);
        // f(0)/f(9) should be ~10 for s=1
        let ratio = z.pmf(0) / z.pmf(9);
        assert!((ratio - 10.0).abs() < 0.5, "ratio={ratio}");
    }

    #[test]
    fn sampling_matches_pmf() {
        let z = Zipf::new(20, 1.2);
        let mut rng = Rng::new(0);
        let n = 100_000;
        let mut counts = vec![0usize; 20];
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        for k in 0..5 {
            let emp = counts[k] as f64 / n as f64;
            let want = z.pmf(k);
            assert!(
                (emp - want).abs() < 0.01,
                "rank {k}: emp={emp:.4} want={want:.4}"
            );
        }
    }

    #[test]
    fn sampler_is_stateless_across_interleaved_streams() {
        // the sampler holds no mutable state (all randomness lives in the
        // caller's Rng), so concurrent per-shard document streams sharing
        // one Zipf can never couple — interleaving two streams yields
        // exactly what each yields alone. This is the property the dp
        // tier's per-shard corpus determinism rests on.
        let z = Zipf::new(30, 1.2);
        let solo = |seed: u64| -> Vec<usize> {
            let mut r = Rng::new(seed);
            (0..40).map(|_| z.sample(&mut r)).collect()
        };
        let (a_solo, b_solo) = (solo(3), solo(4));
        let mut ra = Rng::new(3);
        let mut rb = Rng::new(4);
        let mut a_mixed = Vec::new();
        let mut b_mixed = Vec::new();
        for i in 0..40 {
            // alternate which stream draws first
            if i % 2 == 0 {
                a_mixed.push(z.sample(&mut ra));
                b_mixed.push(z.sample(&mut rb));
            } else {
                b_mixed.push(z.sample(&mut rb));
                a_mixed.push(z.sample(&mut ra));
            }
        }
        assert_eq!(a_solo, a_mixed);
        assert_eq!(b_solo, b_mixed);
    }

    #[test]
    fn deterministic_given_seed() {
        let z = Zipf::new(30, 1.0);
        let a: Vec<usize> = {
            let mut r = Rng::new(7);
            (0..50).map(|_| z.sample(&mut r)).collect()
        };
        let b: Vec<usize> = {
            let mut r = Rng::new(7);
            (0..50).map(|_| z.sample(&mut r)).collect()
        };
        assert_eq!(a, b);
    }
}
