//! Synthetic workload substrate.
//!
//! The paper evaluates on XSum (summarization), IWSLT17 De-En (translation),
//! C4 (LM pre-training), Fashion-MNIST and CIFAR-100. None of those corpora
//! ship with this image, so each is replaced by a *generator* that preserves
//! the property the experiment actually measures (DESIGN.md §4 documents
//! each substitution):
//!
//!   * `seq2seq::SumTask` — article = topic-conditioned Zipf stream,
//!     summary = deterministic salient-token extraction → ROUGE measures
//!     how well the trained model learned the extraction rule;
//!   * `seq2seq::MtTask` — deterministic token bijection + local reorder →
//!     BLEU measures mapping fidelity;
//!   * `corpus::LmTask` — order-1 Markov chain with Zipf marginals → PPL;
//!   * `images::ImageTask` — class templates + Gaussian noise (pilot MLP
//!     and the ViT Table-5 run).
//!
//! Everything is deterministic given a seed, with disjoint train/val/test
//! streams derived from it.

pub mod corpus;
pub mod images;
pub mod seq2seq;
pub mod zipf;

/// A tokenized LM batch, ready to become PJRT literals.
#[derive(Clone, Debug)]
pub struct LmBatch {
    pub batch: usize,
    pub seq_len: usize,
    /// [batch * seq_len] row-major token ids
    pub tokens: Vec<i32>,
    /// [batch * seq_len] 1.0 where the loss counts
    pub mask: Vec<f32>,
}

impl LmBatch {
    pub fn zeros(batch: usize, seq_len: usize) -> Self {
        Self {
            batch,
            seq_len,
            tokens: vec![0; batch * seq_len],
            mask: vec![0.0; batch * seq_len],
        }
    }

    pub fn row_tokens(&self, b: usize) -> &[i32] {
        &self.tokens[b * self.seq_len..(b + 1) * self.seq_len]
    }
}

/// One evaluation example for generation metrics: the prompt to condition
/// on and the reference continuation to score against.
#[derive(Clone, Debug)]
pub struct GenExample {
    pub prompt: Vec<i32>,
    pub reference: Vec<i32>,
}

/// Special token ids shared by all sequence tasks.
pub mod special {
    pub const PAD: i32 = 0;
    pub const BOS: i32 = 1;
    pub const SEP: i32 = 2;
    pub const EOS: i32 = 3;
    /// first content token id
    pub const CONTENT0: i32 = 4;
}
