//! C4-sim: an order-1 Markov language corpus with Zipf-shaped marginals.
//!
//! Used by the Table-6 (FLORA vs GaLore) pre-training comparison, where the
//! metric is token perplexity. The chain has real learnable structure (each
//! token strongly predicts a small successor set), so a trained LM's PPL
//! drops well below the unigram entropy — enough signal to separate
//! optimizers, which is all Table 6 needs.

use super::special::*;
use super::zipf::Zipf;
use super::LmBatch;
use crate::util::rng::{derive_seed, Rng};

#[derive(Clone)]
pub struct LmTask {
    pub vocab: usize,
    pub seq_len: usize,
    /// successors per token (branching factor of the chain)
    pub branch: usize,
    /// successor table: token -> [branch] next-token candidates
    table: Vec<Vec<i32>>,
    zipf: Zipf,
    seed: u64,
}

impl LmTask {
    pub fn new(vocab: usize, seq_len: usize, seed: u64) -> Self {
        let branch = 6;
        let content = vocab as i32 - CONTENT0;
        let mut rng = Rng::new(derive_seed(seed, 0xC4));
        let table = (0..content)
            .map(|_| {
                (0..branch)
                    .map(|_| CONTENT0 + rng.next_below(content as usize) as i32)
                    .collect()
            })
            .collect();
        Self { vocab, seq_len, branch, table, zipf: Zipf::new(branch, 1.2), seed }
    }

    /// Deterministic document `idx` of split `split`.
    fn document(&self, split: u64, idx: u64) -> Vec<i32> {
        let mut rng = Rng::new(derive_seed(derive_seed(self.seed, split + 7), idx));
        let content = self.vocab as i32 - CONTENT0;
        let mut cur = CONTENT0 + rng.next_below(content as usize) as i32;
        let mut out = Vec::with_capacity(self.seq_len);
        out.push(BOS);
        for _ in 0..self.seq_len - 1 {
            out.push(cur);
            let succ = &self.table[(cur - CONTENT0) as usize];
            cur = succ[self.zipf.sample(&mut rng)];
        }
        out
    }

    pub fn fill_batch(&self, out: &mut LmBatch, split: u64, cursor: &mut u64) {
        for b in 0..out.batch {
            let doc = self.document(split, *cursor);
            let off = b * out.seq_len;
            out.tokens[off..off + out.seq_len].copy_from_slice(&doc);
            for (i, m) in out.mask[off..off + out.seq_len].iter_mut().enumerate() {
                // all next-token predictions count except the BOS position
                *m = if i == 0 { 0.0 } else { 1.0 };
            }
            *cursor += 1;
        }
    }

    /// Shard `shard` of `shards`'s batch for global data step `step`:
    /// documents `(step·S + shard)·batch ..+ batch` — contiguous blocks
    /// whose shard-order concatenation is EXACTLY the serial stream a
    /// single consumer sees through [`fill_batch`](Self::fill_batch)
    /// with a running cursor. Per-shard streams are therefore disjoint,
    /// reproducible, and independent of how many physical workers
    /// execute them (workers never appear in the addressing at all) —
    /// the data half of the dp tier's W-invariance contract, regression
    /// tested below and relied on by `runtime::dp::ShardPlan`.
    pub fn fill_shard_batch(
        &self,
        out: &mut LmBatch,
        split: u64,
        step: u64,
        shard: usize,
        shards: usize,
    ) {
        assert!(shard < shards, "shard {shard} out of range for {shards} shards");
        let mut cursor = (step * shards as u64 + shard as u64) * out.batch as u64;
        self.fill_batch(out, split, &mut cursor);
    }

    /// Entropy rate of the chain in nats — a floor for achievable loss,
    /// reported alongside PPL in the Table-6 bench.
    pub fn entropy_rate(&self) -> f64 {
        // H(next | cur) is identical for every cur: the successor draw is
        // Zipf(branch) (up to collisions in the table, which raise nothing)
        -(0..self.branch)
            .map(|k| {
                let p = self.zipf.pmf(k);
                p * p.ln()
            })
            .sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn documents_deterministic() {
        let t = LmTask::new(256, 64, 0);
        assert_eq!(t.document(0, 5), t.document(0, 5));
        assert_ne!(t.document(0, 5), t.document(0, 6));
        assert_ne!(t.document(0, 5), t.document(1, 5));
    }

    #[test]
    fn batch_layout() {
        let t = LmTask::new(256, 64, 1);
        let mut b = LmBatch::zeros(4, 64);
        let mut cur = 0;
        t.fill_batch(&mut b, 0, &mut cur);
        for r in 0..4 {
            assert_eq!(b.row_tokens(r)[0], BOS);
            assert_eq!(b.mask[r * 64], 0.0);
            assert!(b.mask[r * 64 + 1..(r + 1) * 64].iter().all(|&m| m == 1.0));
        }
    }

    #[test]
    fn chain_is_predictive() {
        // successors of a token are confined to its table row
        let t = LmTask::new(256, 64, 2);
        let doc = t.document(0, 0);
        for w in doc[1..].windows(2) {
            let succ = &t.table[(w[0] - CONTENT0) as usize];
            assert!(succ.contains(&w[1]));
        }
    }

    #[test]
    fn entropy_rate_below_uniform() {
        let t = LmTask::new(256, 64, 3);
        let h = t.entropy_rate();
        assert!(h > 0.0 && h < (t.branch as f64).ln() + 1e-9);
    }

    #[test]
    fn shard_union_equals_serial_stream_order_exact() {
        // concatenating the S shard batches of each step, in shard
        // order, reproduces the unsharded stream token-for-token and
        // mask-for-mask — the dp determinism regression
        let t = LmTask::new(128, 16, 9);
        let (batch, shards, steps) = (3usize, 4usize, 3u64);
        let mut serial = LmBatch::zeros(batch, 16);
        let mut cursor = 0u64;
        let mut serial_rows: Vec<(Vec<i32>, Vec<u32>)> = Vec::new();
        for _ in 0..steps * shards as u64 {
            t.fill_batch(&mut serial, 0, &mut cursor);
            for r in 0..batch {
                let off = r * 16;
                serial_rows.push((
                    serial.tokens[off..off + 16].to_vec(),
                    serial.mask[off..off + 16].iter().map(|m| m.to_bits()).collect(),
                ));
            }
        }
        let mut sharded_rows: Vec<(Vec<i32>, Vec<u32>)> = Vec::new();
        let mut b = LmBatch::zeros(batch, 16);
        for step in 0..steps {
            for shard in 0..shards {
                t.fill_shard_batch(&mut b, 0, step, shard, shards);
                for r in 0..batch {
                    let off = r * 16;
                    sharded_rows.push((
                        b.tokens[off..off + 16].to_vec(),
                        b.mask[off..off + 16].iter().map(|m| m.to_bits()).collect(),
                    ));
                }
            }
        }
        assert_eq!(serial_rows, sharded_rows);
    }

    #[test]
    fn shard_batches_reproducible_and_disjoint() {
        let t = LmTask::new(128, 16, 10);
        let mut a = LmBatch::zeros(2, 16);
        let mut b = LmBatch::zeros(2, 16);
        // reproducible: the same (step, shard, shards) twice
        t.fill_shard_batch(&mut a, 0, 5, 1, 4);
        t.fill_shard_batch(&mut b, 0, 5, 1, 4);
        assert_eq!(a.tokens, b.tokens);
        // disjoint document ranges: every (step, shard) cell addresses
        // its own cursor block, so no two cells within a step coincide
        t.fill_shard_batch(&mut b, 0, 5, 2, 4);
        assert_ne!(a.tokens, b.tokens);
        // and the shard grid, not the worker count, defines the stream:
        // shard 1 of 4 at step 0 (batch 2) is documents 2..4 — the same
        // rows the serial stream yields after shard 0's block
        t.fill_shard_batch(&mut a, 0, 0, 1, 4);
        let mut serial = LmBatch::zeros(2, 16);
        let mut cursor = 2u64; // skip shard 0's two documents
        t.fill_batch(&mut serial, 0, &mut cursor);
        assert_eq!(a.tokens, serial.tokens);
    }

    #[test]
    fn tokens_in_vocab_range() {
        let t = LmTask::new(64, 32, 4);
        let mut b = LmBatch::zeros(2, 32);
        let mut cur = 0;
        t.fill_batch(&mut b, 0, &mut cur);
        assert!(b.tokens.iter().all(|&x| x >= 0 && x < 64));
    }
}
