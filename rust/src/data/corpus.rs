//! C4-sim: an order-1 Markov language corpus with Zipf-shaped marginals.
//!
//! Used by the Table-6 (FLORA vs GaLore) pre-training comparison, where the
//! metric is token perplexity. The chain has real learnable structure (each
//! token strongly predicts a small successor set), so a trained LM's PPL
//! drops well below the unigram entropy — enough signal to separate
//! optimizers, which is all Table 6 needs.

use super::special::*;
use super::zipf::Zipf;
use super::LmBatch;
use crate::util::rng::{derive_seed, Rng};

#[derive(Clone)]
pub struct LmTask {
    pub vocab: usize,
    pub seq_len: usize,
    /// successors per token (branching factor of the chain)
    pub branch: usize,
    /// successor table: token -> [branch] next-token candidates
    table: Vec<Vec<i32>>,
    zipf: Zipf,
    seed: u64,
}

impl LmTask {
    pub fn new(vocab: usize, seq_len: usize, seed: u64) -> Self {
        let branch = 6;
        let content = vocab as i32 - CONTENT0;
        let mut rng = Rng::new(derive_seed(seed, 0xC4));
        let table = (0..content)
            .map(|_| {
                (0..branch)
                    .map(|_| CONTENT0 + rng.next_below(content as usize) as i32)
                    .collect()
            })
            .collect();
        Self { vocab, seq_len, branch, table, zipf: Zipf::new(branch, 1.2), seed }
    }

    /// Deterministic document `idx` of split `split`.
    fn document(&self, split: u64, idx: u64) -> Vec<i32> {
        let mut rng = Rng::new(derive_seed(derive_seed(self.seed, split + 7), idx));
        let content = self.vocab as i32 - CONTENT0;
        let mut cur = CONTENT0 + rng.next_below(content as usize) as i32;
        let mut out = Vec::with_capacity(self.seq_len);
        out.push(BOS);
        for _ in 0..self.seq_len - 1 {
            out.push(cur);
            let succ = &self.table[(cur - CONTENT0) as usize];
            cur = succ[self.zipf.sample(&mut rng)];
        }
        out
    }

    pub fn fill_batch(&self, out: &mut LmBatch, split: u64, cursor: &mut u64) {
        for b in 0..out.batch {
            let doc = self.document(split, *cursor);
            let off = b * out.seq_len;
            out.tokens[off..off + out.seq_len].copy_from_slice(&doc);
            for (i, m) in out.mask[off..off + out.seq_len].iter_mut().enumerate() {
                // all next-token predictions count except the BOS position
                *m = if i == 0 { 0.0 } else { 1.0 };
            }
            *cursor += 1;
        }
    }

    /// Entropy rate of the chain in nats — a floor for achievable loss,
    /// reported alongside PPL in the Table-6 bench.
    pub fn entropy_rate(&self) -> f64 {
        // H(next | cur) is identical for every cur: the successor draw is
        // Zipf(branch) (up to collisions in the table, which raise nothing)
        -(0..self.branch)
            .map(|k| {
                let p = self.zipf.pmf(k);
                p * p.ln()
            })
            .sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn documents_deterministic() {
        let t = LmTask::new(256, 64, 0);
        assert_eq!(t.document(0, 5), t.document(0, 5));
        assert_ne!(t.document(0, 5), t.document(0, 6));
        assert_ne!(t.document(0, 5), t.document(1, 5));
    }

    #[test]
    fn batch_layout() {
        let t = LmTask::new(256, 64, 1);
        let mut b = LmBatch::zeros(4, 64);
        let mut cur = 0;
        t.fill_batch(&mut b, 0, &mut cur);
        for r in 0..4 {
            assert_eq!(b.row_tokens(r)[0], BOS);
            assert_eq!(b.mask[r * 64], 0.0);
            assert!(b.mask[r * 64 + 1..(r + 1) * 64].iter().all(|&m| m == 1.0));
        }
    }

    #[test]
    fn chain_is_predictive() {
        // successors of a token are confined to its table row
        let t = LmTask::new(256, 64, 2);
        let doc = t.document(0, 0);
        for w in doc[1..].windows(2) {
            let succ = &t.table[(w[0] - CONTENT0) as usize];
            assert!(succ.contains(&w[1]));
        }
    }

    #[test]
    fn entropy_rate_below_uniform() {
        let t = LmTask::new(256, 64, 3);
        let h = t.entropy_rate();
        assert!(h > 0.0 && h < (t.branch as f64).ln() + 1e-9);
    }

    #[test]
    fn tokens_in_vocab_range() {
        let t = LmTask::new(64, 32, 4);
        let mut b = LmBatch::zeros(2, 32);
        let mut cur = 0;
        t.fill_batch(&mut b, 0, &mut cur);
        assert!(b.tokens.iter().all(|&x| x >= 0 && x < 64));
    }
}
