//! `flora` — the leader binary: CLI dispatch over the coordinator.

use flora::cli::{Args, USAGE};
use flora::config::{ExperimentConfig, TaskKind};
use flora::coordinator::{MethodSpec, Trainer};
use flora::data::images::ImageTask;
use flora::memory::{self, Dims, OptKind, StateRole};
use flora::opt::OptimizerKind;
use flora::pilot;
use flora::runtime::Manifest;
use flora::util::human;
use flora::util::log;

fn main() {
    log::level_from_env();
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = if args.has("list-catalog") {
        cmd_list_catalog()
    } else {
        match args.command.as_str() {
            "train" => cmd_train(&args),
            "eval" => cmd_eval(&args),
            "pilot" => cmd_pilot(&args),
            "memory" => cmd_memory(&args),
            "inspect" => cmd_inspect(&args),
            "help" | "" => {
                println!("{USAGE}");
                Ok(())
            }
            other => Err(format!("unknown command {other:?}\n\n{USAGE}")),
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn experiment_from_args(args: &Args) -> Result<ExperimentConfig, String> {
    let mut cfg = match args.flag("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(m) = args.flag("model") {
        cfg.train.model = m.to_string();
    }
    if let Some(t) = args.flag("task") {
        cfg.train.task = TaskKind::parse(t)?;
    } else if let Some(t) = TaskKind::implied_by_model(&cfg.train.model) {
        // `--model vit-tiny` without an explicit task trains on images
        cfg.train.task = t;
    }
    if let Some(m) = args.flag("method") {
        let rank = args.usize_flag("rank", cfg.train.method.rank().unwrap_or(16))?;
        cfg.train.method = MethodSpec::parse(m, rank)?;
    }
    if let Some(o) = args.flag("optimizer") {
        cfg.train.optimizer = OptimizerKind::parse(o)?;
    }
    cfg.train.lr = args.f32_flag("lr", cfg.train.lr)?;
    cfg.train.steps = args.usize_flag("steps", cfg.train.steps)?;
    cfg.train.tau = args.usize_flag("tau", cfg.train.tau)?;
    cfg.train.kappa = args.usize_flag("kappa", cfg.train.kappa)?;
    cfg.train.batch = args.usize_flag("batch", cfg.train.batch)?;
    cfg.train.seed = args.u64_flag("seed", cfg.train.seed)?;
    cfg.train.eval_every = args.usize_flag("eval-every", cfg.train.eval_every)?;
    cfg.train.eval_samples = args.usize_flag("eval-samples", cfg.train.eval_samples)?;
    let threads =
        args.usize_flag("parallelism", cfg.train.parallelism.threads())?;
    if threads == 0 {
        return Err("--parallelism: must be >= 1".into());
    }
    cfg.train.parallelism = flora::tensor::Parallelism::new(threads);
    // install the kernel thread budget process-wide; results are
    // bit-identical at every setting (tensor::Parallelism)
    cfg.train.parallelism.install();
    cfg.artifacts_dir = args.flag_or("artifacts", &cfg.artifacts_dir);
    // the backend spec rides in artifacts_dir ("native" is reserved —
    // Runtime::from_spec dispatches on it); the native catalog executes
    // every base optimizer, so --optimizer passes through unchanged
    match args.flag_or("backend", "xla").as_str() {
        "native" => cfg.artifacts_dir = "native".into(),
        "xla" => {}
        other => {
            return Err(format!("--backend: expected native|xla, got {other:?}"))
        }
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let cfg = experiment_from_args(args)?;
    println!(
        "training {} on task={} method={} optimizer={} steps={} tau={} kappa={}",
        cfg.train.model,
        cfg.train.task.name(),
        cfg.train.method.label(),
        cfg.train.optimizer,
        cfg.train.steps,
        cfg.train.tau,
        cfg.train.kappa,
    );
    let mut tr = Trainer::new(cfg.train.clone(), &cfg.artifacts_dir)?;
    let report = tr.run()?;
    if let Some(path) = args.flag("save-checkpoint") {
        tr.save_checkpoint(path)?;
        println!("checkpoint written to {path}");
    }
    if let Some(dir) = args.flag("record") {
        let p = flora::coordinator::registry::record(dir, &cfg.name, &report)?;
        println!("run recorded at {}", p.display());
    }
    println!(
        "done: final_train_loss={:.4} best_val_loss={:.4} metric={} \
         state={} peak_state={} ({:.1} steps/s)",
        report.final_train_loss(),
        report.best_eval_loss(),
        report
            .metric
            .map(|m| m.render())
            .unwrap_or_else(|| "-".into()),
        human::bytes(report.total_state_bytes()),
        human::bytes(report.peak_state_bytes),
        report.steps_per_sec,
    );
    for (g, b) in &report.state_bytes {
        if *b > 0 {
            println!("  state[{g}] = {}", human::bytes(*b));
        }
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<(), String> {
    let mut cfg = experiment_from_args(args)?;
    cfg.train.steps = 0;
    cfg.train.eval_every = 0;
    let mut tr = Trainer::new(cfg.train.clone(), &cfg.artifacts_dir)?;
    tr.init()?;
    let loss = tr.eval_loss(1, 4)?;
    let metric = tr.eval_metric(cfg.train.eval_samples)?;
    println!(
        "eval at init: val_loss={loss:.4} metric={}",
        metric.render()
    );
    Ok(())
}

fn cmd_pilot(args: &Args) -> Result<(), String> {
    let steps = args.usize_flag("steps", 400)?;
    let rank = args.usize_flag("rank", 8)?;
    let lr = args.f32_flag("lr", 0.01)?;
    let seed = args.u64_flag("seed", 0)?;
    println!("Figure-1 pilot: MLP 784->256->(256x256 patched)->10, r={rank}, lr={lr}");
    let task = ImageTask::fashion_like(10, 784, 0.3, seed);
    let curves = pilot::run_pilot(&task, steps, 32, rank, lr, seed, false, false);
    for c in &curves {
        let tail = &c.losses[c.losses.len().saturating_sub(20)..];
        let final_loss: f32 = tail.iter().sum::<f32>() / tail.len() as f32;
        println!(
            "{:<8} final_loss={final_loss:.4} acc={:.2} {}",
            c.updater.name(),
            c.final_train_acc,
            flora::bench::sparkline(&c.losses, 40)
        );
    }
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<(), String> {
    let model = args.flag_or("model", "t5-small");
    let dims = match model.as_str() {
        "t5-small" => Dims::t5_small_sim(),
        "t5-3b" => Dims::t5_3b_sim(),
        "gpt2-base" => Dims::gpt2_base_sim(),
        "gpt2-xl" => Dims::gpt2_xl_sim(),
        "lm-small" => Dims::lm_small(),
        other => return Err(format!("unknown model {other:?}")),
    };
    let opt = match args.flag_or("optimizer", "adafactor").as_str() {
        "adam" => OptKind::Adam,
        "adafactor" => OptKind::Adafactor,
        "adafactor_nofactor" => OptKind::AdafactorNoFactor,
        other => return Err(format!("unknown optimizer {other:?}")),
    };
    println!(
        "model {} ({} params), optimizer {:?}",
        model,
        human::params(dims.param_count()),
        opt
    );
    let mut table = flora::bench::Table::new(
        "analytic memory (accumulation role)",
        &["Method", "Params", "Grads", "OptState", "MethodState", "Extra", "ΔM"],
    );
    let methods = [
        memory::Method::None,
        memory::Method::Naive,
        memory::Method::Lora(256),
        memory::Method::Flora(256),
        memory::Method::Galore(256),
    ];
    for m in methods {
        let b = memory::breakdown(&dims, m, opt, StateRole::Accumulation, 1, false);
        let dm = memory::delta_m(&dims, m, opt, StateRole::Accumulation, 1);
        table.row(vec![
            m.label(),
            human::bytes(b.params),
            human::bytes(b.grads),
            human::bytes(b.opt_state),
            human::bytes(b.method_state),
            human::bytes(b.extra_params),
            format!("{:+.2} GiB", dm as f64 / (1u64 << 30) as f64),
        ]);
    }
    table.print();
    Ok(())
}

/// `flora --list-catalog` (with any or no command): the native catalog
/// inventory grouped by family and size, rank/optimizer variants
/// collapsed (`runtime::catalog_summary`) so the size grid stays
/// readable.
fn cmd_list_catalog() -> Result<(), String> {
    let manifest = flora::runtime::native_manifest();
    print!("{}", flora::runtime::catalog_summary(&manifest));
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<(), String> {
    let mut dir = args.flag_or("artifacts", "artifacts");
    let manifest = if args.flag("backend") == Some("native") {
        dir = "native catalog".into();
        flora::runtime::native_manifest()
    } else {
        Manifest::load(&dir)?
    };
    match args.flag("exe") {
        Some(name) => {
            let e = manifest.executable(name)?;
            println!("{name} (model {})", e.model);
            println!(" inputs:");
            for t in &e.inputs {
                println!("   {:<42} {:?} {}", t.name, t.shape, t.dtype);
            }
            println!(" outputs:");
            for t in &e.outputs {
                println!("   {:<42} {:?} {}", t.name, t.shape, t.dtype);
            }
        }
        None => {
            println!("{} executables in {dir}:", manifest.executables.len());
            for (name, e) in &manifest.executables {
                println!(
                    "  {name:<48} {:>3} in / {:>3} out",
                    e.inputs.len(),
                    e.outputs.len()
                );
            }
        }
    }
    Ok(())
}
