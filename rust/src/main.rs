//! `flora` — the leader binary: CLI dispatch over the coordinator.

use flora::cli::{Args, USAGE};
use flora::config::{ExperimentConfig, TaskKind};
use flora::coordinator::{MethodSpec, Trainer};
use flora::data::images::ImageTask;
use flora::memory::{self, Dims, OptKind, StateRole};
use flora::opt::{CompressorKind, OptimizerKind, RankSchedule};
use flora::pilot;
use flora::runtime::Manifest;
use flora::util::human;
use flora::util::log;

fn main() {
    log::level_from_env();
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = if args.has("list-catalog") {
        cmd_list_catalog()
    } else {
        match args.command.as_str() {
            "train" => cmd_train(&args),
            "eval" => cmd_eval(&args),
            "pilot" => cmd_pilot(&args),
            "memory" => cmd_memory(&args),
            "inspect" => cmd_inspect(&args),
            "serve" => cmd_serve(&args),
            "train-dp" => cmd_train_dp(&args),
            "doctor" => cmd_doctor(&args),
            "help" | "" => {
                println!("{USAGE}");
                Ok(())
            }
            other => Err(format!("unknown command {other:?}\n\n{USAGE}")),
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn experiment_from_args(args: &Args) -> Result<ExperimentConfig, String> {
    let mut cfg = match args.flag("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(m) = args.flag("model") {
        cfg.train.model = m.to_string();
    }
    if let Some(t) = args.flag("task") {
        cfg.train.task = TaskKind::parse(t)?;
    } else if let Some(t) = TaskKind::implied_by_model(&cfg.train.model) {
        // `--model vit-tiny` without an explicit task trains on images
        cfg.train.task = t;
    }
    if let Some(m) = args.flag("method") {
        let rank = args.usize_flag("rank", cfg.train.method.rank().unwrap_or(16))?;
        cfg.train.method = MethodSpec::parse(m, rank)?;
    }
    if let Some(c) = args.flag("compressor") {
        cfg.train.method =
            cfg.train.method.with_compressor(CompressorKind::parse(c)?)?;
    }
    if let Some(s) = args.flag("rank-schedule") {
        cfg.train.rank_schedule = RankSchedule::parse(s)?;
    }
    if let Some(o) = args.flag("optimizer") {
        cfg.train.optimizer = OptimizerKind::parse(o)?;
    }
    cfg.train.lr = args.f32_flag("lr", cfg.train.lr)?;
    cfg.train.steps = args.usize_flag("steps", cfg.train.steps)?;
    cfg.train.tau = args.usize_flag("tau", cfg.train.tau)?;
    cfg.train.kappa = args.usize_flag("kappa", cfg.train.kappa)?;
    cfg.train.batch = args.usize_flag("batch", cfg.train.batch)?;
    cfg.train.seed = args.u64_flag("seed", cfg.train.seed)?;
    cfg.train.workers = args.usize_flag("workers", cfg.train.workers)?;
    cfg.train.eval_every = args.usize_flag("eval-every", cfg.train.eval_every)?;
    cfg.train.eval_samples = args.usize_flag("eval-samples", cfg.train.eval_samples)?;
    let threads =
        args.usize_flag("parallelism", cfg.train.parallelism.threads())?;
    if threads == 0 {
        return Err("--parallelism: must be >= 1".into());
    }
    cfg.train.parallelism = flora::tensor::Parallelism::new(threads);
    // install the kernel thread budget process-wide; results are
    // bit-identical at every setting (tensor::Parallelism)
    cfg.train.parallelism.install();
    cfg.artifacts_dir = args.flag_or("artifacts", &cfg.artifacts_dir);
    // the backend spec rides in artifacts_dir ("native" is reserved —
    // Runtime::from_spec dispatches on it); the native catalog executes
    // every base optimizer, so --optimizer passes through unchanged
    match args.flag_or("backend", "xla").as_str() {
        "native" => cfg.artifacts_dir = "native".into(),
        "xla" => {}
        other => {
            return Err(format!("--backend: expected native|xla, got {other:?}"))
        }
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let cfg = experiment_from_args(args)?;
    cfg.train.reject_multi_worker()?;
    println!(
        "training {} on task={} method={} optimizer={} steps={} tau={} kappa={}",
        cfg.train.model,
        cfg.train.task.name(),
        cfg.train.method.label(),
        cfg.train.optimizer,
        cfg.train.steps,
        cfg.train.tau,
        cfg.train.kappa,
    );
    let mut tr = Trainer::new(cfg.train.clone(), &cfg.artifacts_dir)?;
    let report = tr.run()?;
    if let Some(path) = args.flag("save-checkpoint") {
        tr.save_checkpoint(path)?;
        println!("checkpoint written to {path}");
    }
    if let Some(dir) = args.flag("record") {
        let p = flora::coordinator::registry::record(dir, &cfg.name, &report)?;
        println!("run recorded at {}", p.display());
    }
    println!(
        "done: final_train_loss={:.4} best_val_loss={:.4} metric={} \
         state={} peak_state={} ({:.1} steps/s)",
        report.final_train_loss(),
        report.best_eval_loss(),
        report
            .metric
            .map(|m| m.render())
            .unwrap_or_else(|| "-".into()),
        human::bytes(report.total_state_bytes()),
        human::bytes(report.peak_state_bytes),
        report.steps_per_sec,
    );
    for (g, b) in &report.state_bytes {
        if *b > 0 {
            println!("  state[{g}] = {}", human::bytes(*b));
        }
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<(), String> {
    let mut cfg = experiment_from_args(args)?;
    cfg.train.steps = 0;
    cfg.train.eval_every = 0;
    let mut tr = Trainer::new(cfg.train.clone(), &cfg.artifacts_dir)?;
    tr.init()?;
    let loss = tr.eval_loss(1, 4)?;
    let metric = tr.eval_metric(cfg.train.eval_samples)?;
    println!(
        "eval at init: val_loss={loss:.4} metric={}",
        metric.render()
    );
    Ok(())
}

fn cmd_pilot(args: &Args) -> Result<(), String> {
    let steps = args.usize_flag("steps", 400)?;
    let rank = args.usize_flag("rank", 8)?;
    let lr = args.f32_flag("lr", 0.01)?;
    let seed = args.u64_flag("seed", 0)?;
    println!("Figure-1 pilot: MLP 784->256->(256x256 patched)->10, r={rank}, lr={lr}");
    let task = ImageTask::fashion_like(10, 784, 0.3, seed);
    let curves = pilot::run_pilot(&task, steps, 32, rank, lr, seed, false, false);
    for c in &curves {
        let tail = &c.losses[c.losses.len().saturating_sub(20)..];
        let final_loss: f32 = tail.iter().sum::<f32>() / tail.len() as f32;
        println!(
            "{:<8} final_loss={final_loss:.4} acc={:.2} {}",
            c.updater.name(),
            c.final_train_acc,
            flora::bench::sparkline(&c.losses, 40)
        );
    }
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<(), String> {
    let model = args.flag_or("model", "t5-small");
    let dims = match model.as_str() {
        "t5-small" => Dims::t5_small_sim(),
        "t5-3b" => Dims::t5_3b_sim(),
        "gpt2-base" => Dims::gpt2_base_sim(),
        "gpt2-xl" => Dims::gpt2_xl_sim(),
        "lm-small" => Dims::lm_small(),
        other => return Err(format!("unknown model {other:?}")),
    };
    let opt = match args.flag_or("optimizer", "adafactor").as_str() {
        "adam" => OptKind::Adam,
        "adafactor" => OptKind::Adafactor,
        "adafactor_nofactor" => OptKind::AdafactorNoFactor,
        other => return Err(format!("unknown optimizer {other:?}")),
    };
    println!(
        "model {} ({} params), optimizer {:?}",
        model,
        human::params(dims.param_count()),
        opt
    );
    let mut table = flora::bench::Table::new(
        "analytic memory (accumulation role)",
        &["Method", "Params", "Grads", "OptState", "MethodState", "Extra", "ΔM"],
    );
    let methods = [
        memory::Method::None,
        memory::Method::Naive,
        memory::Method::Lora(256),
        memory::Method::Flora(256),
        memory::Method::Galore(256),
    ];
    for m in methods {
        let b = memory::breakdown(&dims, m, opt, StateRole::Accumulation, 1, false);
        let dm = memory::delta_m(&dims, m, opt, StateRole::Accumulation, 1);
        table.row(vec![
            m.label(),
            human::bytes(b.params),
            human::bytes(b.grads),
            human::bytes(b.opt_state),
            human::bytes(b.method_state),
            human::bytes(b.extra_params),
            format!("{:+.2} GiB", dm as f64 / (1u64 << 30) as f64),
        ]);
    }
    table.print();
    Ok(())
}

/// `flora serve`: spin up the multi-adapter serving tier on a native
/// catalog LM, push a synthetic mixed-adapter workload through the
/// dynamic batcher, and report throughput + latency. With `--verify`,
/// every response is additionally bit-compared against the sequential
/// single-request oracle (`runtime::serve::oracle_check`) — the CI
/// smoke job runs exactly that. `docs/SERVING.md` is the handbook.
fn cmd_serve(args: &Args) -> Result<(), String> {
    use flora::config::ServeConfig;
    use flora::model::TransformerConfig;
    use flora::runtime::{AdapterRegistry, BatchPolicy, Server};
    use flora::util::timing::{Samples, Timer};

    let mut cfg = match args.flag("config") {
        Some(path) => ServeConfig::from_file(path)?,
        None => ServeConfig::default(),
    };
    if let Some(m) = args.flag("model") {
        cfg.model = m.to_string();
    }
    cfg.max_batch = args.usize_flag("max-batch", cfg.max_batch)?;
    cfg.max_wait_ms = args.u64_flag("max-wait-ms", cfg.max_wait_ms)?;
    cfg.adapters = args.usize_flag("adapters", cfg.adapters)?;
    cfg.capacity = args.usize_flag("capacity", cfg.capacity)?;
    cfg.rank = args.usize_flag("rank", cfg.rank)?;
    cfg.requests = args.usize_flag("requests", cfg.requests)?;
    // --synthetic N is an alias for --requests N (the smoke job's spelling)
    cfg.requests = args.usize_flag("synthetic", cfg.requests)?;
    cfg.prompt_len = args.usize_flag("prompt-len", cfg.prompt_len)?;
    cfg.max_new = args.usize_flag("max-new", cfg.max_new)?;
    cfg.seed = args.u64_flag("seed", cfg.seed)?;
    cfg.gap_ms = args.u64_flag("gap-ms", cfg.gap_ms)?;
    let threads = args.usize_flag("parallelism", cfg.parallelism.threads())?;
    if threads == 0 {
        return Err("--parallelism: must be >= 1".into());
    }
    if cfg.adapters == 0 || cfg.requests == 0 || cfg.max_batch == 0 || cfg.rank == 0 {
        return Err("adapters, requests, max-batch and rank must be >= 1".into());
    }
    cfg.parallelism = flora::tensor::Parallelism::new(threads);
    cfg.parallelism.install();

    let model = TransformerConfig::catalog_grid()
        .into_iter()
        .find(|(n, _)| *n == cfg.model)
        .map(|(_, c)| c)
        .ok_or_else(|| {
            format!(
                "--model: unknown serving model {:?} (want lora-tiny|lora-small|lora-base)",
                cfg.model
            )
        })?;
    let prompt_len = cfg.effective_prompt_len(model.seq_len);
    let max_new = cfg.effective_max_new(model.seq_len);
    if prompt_len + max_new > model.seq_len {
        return Err(format!(
            "prompt_len {prompt_len} + max_new {max_new} exceeds {} seq_len {}",
            cfg.model, model.seq_len
        ));
    }

    let base = model.init(cfg.seed);
    let mut registry = AdapterRegistry::new(cfg.effective_capacity());
    for i in 0..cfg.adapters {
        registry.insert_synthetic(
            &format!("adapter-{i}"),
            &model,
            &base,
            cfg.rank,
            cfg.seed.wrapping_add(1 + i as u64),
        )?;
    }
    if let Some(path) = args.flag("checkpoint") {
        let rank = registry.load_checkpoint("ckpt", path)?;
        println!("hot-loaded adapter \"ckpt\" (rank {rank}) from {path}");
    }
    let adapter_names = registry.names();
    println!(
        "serving {} | {} adapters (rank {}, {} resident) | policy max_batch={} max_wait={}ms",
        cfg.model,
        adapter_names.len(),
        registry.rank().unwrap_or(cfg.rank),
        human::bytes(registry.state_bytes() as u64),
        cfg.max_batch,
        cfg.max_wait_ms,
    );

    let policy = BatchPolicy { max_batch: cfg.max_batch, max_wait_ms: cfg.max_wait_ms };
    let mut srv = Server::new(model, base.clone(), registry, policy);
    // synthetic open-loop traffic: request i arrives at i*gap_ms under
    // adapter i % adapters, with a deterministic prompt
    let mut batch_lat = Samples::new();
    let mut batches = 0usize;
    for i in 0..cfg.requests {
        let now = i as u64 * cfg.gap_ms;
        let name = &adapter_names[i % adapter_names.len()];
        let prompt: Vec<i32> =
            (0..prompt_len).map(|j| ((3 + i + 2 * j) % model.vocab) as i32).collect();
        srv.submit(name, prompt, max_new, now)?;
        let t = Timer::start();
        if srv.step(now, false)?.is_some() {
            batch_lat.push(t.elapsed_secs());
            batches += 1;
        }
    }
    let close = cfg.requests as u64 * cfg.gap_ms + cfg.max_wait_ms;
    loop {
        let t = Timer::start();
        if srv.step(close, true)?.is_none() {
            break;
        }
        batch_lat.push(t.elapsed_secs());
        batches += 1;
    }
    let responses = srv.take_responses();
    if responses.len() != cfg.requests {
        return Err(format!(
            "served {} responses for {} requests",
            responses.len(),
            cfg.requests
        ));
    }
    let new_tokens: usize = responses.iter().map(|r| r.new_tokens).sum();
    let total_secs: f64 = batch_lat.mean() * batch_lat.len() as f64;
    println!(
        "{} responses in {batches} batches | {:.1} tok/s decode | batch latency p50={:.2}ms p95={:.2}ms",
        responses.len(),
        new_tokens as f64 / total_secs.max(1e-9),
        batch_lat.percentile(50.0) * 1e3,
        batch_lat.percentile(95.0) * 1e3,
    );
    let stats = srv.registry.stats();
    println!(
        "registry: loads={} hits={} misses={} evictions={}",
        stats.loads, stats.hits, stats.misses, stats.evictions
    );
    for r in responses.iter().take(4) {
        println!(
            "  req {} [{}] batch={} queue={}ms tokens {:?}",
            r.id,
            r.adapter,
            r.batch_size,
            r.queue_ms,
            &r.tokens[prompt_len..]
        );
    }

    if args.has("verify") {
        // re-run every served request through the bit-compare oracle and
        // require the SERVED tokens to match the sequential streams
        let names: Vec<String> = responses.iter().map(|r| r.adapter.clone()).collect();
        let adapters = srv.registry.get_many(&names)?;
        let prompts: Vec<Vec<i32>> =
            responses.iter().map(|r| r.tokens[..prompt_len].to_vec()).collect();
        let solo = flora::runtime::serve::oracle_check(
            &model,
            &base,
            &adapters,
            &prompts,
            max_new,
        )?;
        for (r, want) in responses.iter().zip(&solo) {
            if &r.tokens != want {
                return Err(format!(
                    "verify: served tokens for req {} diverge from the sequential oracle",
                    r.id
                ));
            }
        }
        println!(
            "verify: {} responses bit-match the sequential single-adapter oracle",
            responses.len()
        );
    }
    Ok(())
}

/// `flora train-dp`: data-parallel training with Flora-compressed
/// gradient exchange. Workers on the persistent kernel pool compute
/// shard gradients, project them to rank r, and a fixed-order reduce
/// sums the compressed states before one decompress-and-step — so the
/// parameter trajectory is bit-identical at every `--workers`. With
/// `--verify`, the whole run is re-executed at `workers=1` and the loss
/// curve plus final parameters are raw-bits-compared — the CI smoke job
/// runs exactly that. `docs/DISTRIBUTED.md` is the handbook.
fn cmd_train_dp(args: &Args) -> Result<(), String> {
    use flora::config::DpConfig;
    use flora::runtime::dp::{DpTrainer, ReduceMode};

    let mut cfg = match args.flag("config") {
        Some(path) => DpConfig::from_file(path)?,
        None => DpConfig::default(),
    };
    if let Some(m) = args.flag("model") {
        cfg.train.model = m.to_string();
    }
    if let Some(o) = args.flag("optimizer") {
        cfg.train.optimizer = OptimizerKind::parse(o)?;
    }
    // dp is always flora — --rank adjusts the method in place, and any
    // --compressor routes through validate(), which rejects the
    // single-process grid (altlora/adarank) with the tier hint
    cfg.train.method =
        MethodSpec::Flora { rank: args.usize_flag("rank", cfg.rank())? };
    if let Some(c) = args.flag("compressor") {
        cfg.train.method =
            cfg.train.method.with_compressor(CompressorKind::parse(c)?)?;
    }
    cfg.train.lr = args.f32_flag("lr", cfg.train.lr)?;
    cfg.train.steps = args.usize_flag("steps", cfg.train.steps)?;
    cfg.train.tau = args.usize_flag("tau", cfg.train.tau)?;
    cfg.train.kappa = args.usize_flag("kappa", cfg.train.kappa)?;
    cfg.train.batch = args.usize_flag("batch", cfg.train.batch)?;
    cfg.train.seed = args.u64_flag("seed", cfg.train.seed)?;
    cfg.train.workers = args.usize_flag("workers", cfg.train.workers)?;
    cfg.shards = args.usize_flag("shards", cfg.shards)?;
    if let Some(r) = args.flag("reduce") {
        cfg.reduce = ReduceMode::parse(r)?;
    }
    let threads =
        args.usize_flag("parallelism", cfg.train.parallelism.threads())?;
    if threads == 0 {
        return Err("--parallelism: must be >= 1".into());
    }
    cfg.train.parallelism = flora::tensor::Parallelism::new(threads);
    if cfg.train.workers == 0 {
        return Err("--workers: must be >= 1".into());
    }
    cfg.validate()?;

    println!(
        "dp training {} | workers={} shards={} reduce={} | optimizer={} rank={} steps={} tau={} kappa={}",
        cfg.train.model,
        cfg.train.workers,
        cfg.shards,
        cfg.reduce,
        cfg.train.optimizer,
        cfg.rank(),
        cfg.train.steps,
        cfg.train.tau,
        cfg.train.kappa,
    );
    let mut tr = DpTrainer::new(cfg.clone())?;
    let report = tr.run()?;
    let ledger = report.ledger;
    println!(
        "done: final_train_loss={:.4} ({:.1} steps/s over {} data steps)",
        report.train_losses.last().copied().unwrap_or(f32::NAN),
        report.steps_per_sec,
        ledger.steps,
    );
    println!(
        "comms: {}/step on the wire vs {}/step full-gradient — ratio {:.4} ({:.1}x compression)",
        human::bytes(ledger.per_step_sent()),
        human::bytes(ledger.per_step_full()),
        ledger.ratio(),
        1.0 / ledger.ratio().max(1e-12),
    );

    if args.has("verify") {
        // re-run the identical config single-worker and demand raw-bits
        // equality of the loss curve and every final parameter
        let mut solo_cfg = cfg.clone();
        solo_cfg.train.workers = 1;
        let mut solo = DpTrainer::new(solo_cfg)?;
        let solo_report = solo.run()?;
        let got: Vec<u32> =
            report.train_losses.iter().map(|x| x.to_bits()).collect();
        let want: Vec<u32> =
            solo_report.train_losses.iter().map(|x| x.to_bits()).collect();
        if got != want {
            return Err(format!(
                "verify: loss curve at workers={} diverges from the workers=1 oracle",
                cfg.train.workers
            ));
        }
        for (name, p) in tr.params() {
            let q = &solo.params()[name];
            let pb: Vec<u32> = p.data.iter().map(|x| x.to_bits()).collect();
            let qb: Vec<u32> = q.data.iter().map(|x| x.to_bits()).collect();
            if pb != qb {
                return Err(format!(
                    "verify: parameter {name} at workers={} diverges from the workers=1 oracle",
                    cfg.train.workers
                ));
            }
        }
        println!(
            "verify: workers={} run bit-matches the workers=1 oracle ({} params, {} steps)",
            cfg.train.workers,
            tr.params().len(),
            report.train_losses.len(),
        );
    }
    Ok(())
}

/// `flora doctor`: run every ops self-check (flora::doctor), print the
/// human table + the machine-readable JSON receipt, exit non-zero if
/// any check failed. docs/OPS.md documents the receipt schema.
fn cmd_doctor(args: &Args) -> Result<(), String> {
    let threads = args.usize_flag("parallelism", 2)?;
    if threads == 0 {
        return Err("--parallelism: must be >= 1".into());
    }
    let cfg = flora::doctor::DoctorConfig {
        quick: args.has("quick"),
        parallelism: flora::tensor::Parallelism::new(threads),
        bench_dir: args.flag_or("bench-dir", "."),
    };
    let report = flora::doctor::run(&cfg);
    println!(
        "flora doctor ({} mode, parallelism {})",
        if report.quick { "quick" } else { "full" },
        report.parallelism
    );
    for c in &report.checks {
        println!(
            "  {} {:<32} {} ({:.0} ms)",
            if c.passed { "ok  " } else { "FAIL" },
            c.name,
            c.detail,
            c.ms
        );
    }
    let receipt = report.receipt().render();
    match args.flag("receipt") {
        Some(path) => {
            std::fs::write(path, &receipt)
                .map_err(|e| format!("writing receipt {path}: {e}"))?;
            println!("receipt written to {path}");
        }
        None => println!("{receipt}"),
    }
    if !report.ok() {
        let failed = report.failed_names();
        return Err(format!(
            "doctor: {} of {} checks failed: {}",
            failed.len(),
            report.checks.len(),
            failed.join(", ")
        ));
    }
    println!("doctor: all {} checks passed", report.checks.len());
    Ok(())
}

/// `flora --list-catalog` (with any or no command): the native catalog
/// inventory grouped by family and size, rank/optimizer variants
/// collapsed (`runtime::catalog_summary`) so the size grid stays
/// readable.
fn cmd_list_catalog() -> Result<(), String> {
    let manifest = flora::runtime::native_manifest();
    print!("{}", flora::runtime::catalog_summary(&manifest));
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<(), String> {
    let mut dir = args.flag_or("artifacts", "artifacts");
    let manifest = if args.flag("backend") == Some("native") {
        dir = "native catalog".into();
        flora::runtime::native_manifest()
    } else {
        Manifest::load(&dir)?
    };
    match args.flag("exe") {
        Some(name) => {
            let e = manifest.executable(name)?;
            println!("{name} (model {})", e.model);
            println!(" inputs:");
            for t in &e.inputs {
                println!("   {:<42} {:?} {}", t.name, t.shape, t.dtype);
            }
            println!(" outputs:");
            for t in &e.outputs {
                println!("   {:<42} {:?} {}", t.name, t.shape, t.dtype);
            }
        }
        None => {
            println!("{} executables in {dir}:", manifest.executables.len());
            for (name, e) in &manifest.executables {
                println!(
                    "  {name:<48} {:>3} in / {:>3} out",
                    e.inputs.len(),
                    e.outputs.len()
                );
            }
        }
    }
    Ok(())
}
