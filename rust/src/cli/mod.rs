//! Hand-rolled CLI (clap is not in the offline vendor set).
//!
//! Grammar: `flora <command> [--flag value]... [--switch]...`
//! Commands are dispatched in main.rs; this module provides the parser and
//! help rendering.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. The first non-flag token is the command.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("empty flag name".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.command.is_empty() {
                out.command = tok;
            } else {
                return Err(format!("unexpected positional argument {tok:?}"));
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got {v:?}")),
        }
    }

    pub fn f32_flag(&self, name: &str, default: f32) -> Result<f32, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected number, got {v:?}")),
        }
    }

    pub fn u64_flag(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got {v:?}")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

pub const USAGE: &str = "\
flora — FLORA (ICML 2024) reproduction: rust coordinator over AOT JAX/Pallas

USAGE:
    flora <COMMAND> [OPTIONS]

COMMANDS:
    train       train a model with a chosen method
                  --model lm-small --task sum|mt|lm|vit --method none|naive|flora|lora|galore
                  --rank N --optimizer sgd|adam|adafactor|adafactor_nofactor
                  --compressor flora|altlora|adarank (flora-family methods
                  only; picks the accumulate/apply algebra)
                  --rank-schedule fixed|linear-decay:N|halve-at:N (adarank
                  shrink schedule, in kappa-cycle units)
                  --lr F --steps N --tau N
                  --kappa N --batch N --seed N --config file.toml
                  --parallelism N (kernel thread budget; results are
                  bit-identical at every N — see docs/ARCHITECTURE.md)
                  --backend native|xla (native = pure rust, no artifacts)
                  (--workers N > 1 is rejected here — that is train-dp)
    eval        evaluate a fresh init (loss + generation metric)
                  --model lm-small --task sum --samples N --backend native|xla
    pilot       run the Figure-1 pilot study in pure rust
                  --steps N --rank N --lr F
    memory      print the analytic memory table for paper-scale models
                  --model t5-small|t5-3b|gpt2-base|gpt2-xl --optimizer ...
    inspect     list manifest executables and their ABI
                  --artifacts DIR [--exe NAME] [--backend native]
    train-dp    data-parallel training with Flora-compressed gradient
                exchange: workers ship rank-r projected gradients into a
                fixed-order reduce (bit-identical at every --workers)
                  --model lora-tiny|lora-small|lora-base --config file.toml
                  --workers N (threads executing shards; must be <= shards)
                  --shards N (logical gradient shards — the determinism
                  grain; per-step documents = shards x batch)
                  --reduce compressed|full (what goes on the wire)
                  --rank N --optimizer sgd|adam|adafactor|adafactor_nofactor
                  --lr F --steps N --tau N --kappa N --batch N --seed N
                  --parallelism N (kernel threads per worker; workers x
                  parallelism must fit the pool budget)
                  --verify (re-run at workers=1 and raw-bits-compare the
                  loss curve + final params; non-zero exit on divergence)
                  See docs/DISTRIBUTED.md for the architecture and math.
    serve       batched multi-adapter inference on the native LM catalog
                  --model lora-tiny|lora-small|lora-base --config file.toml
                  --adapters N (synthetic adapters) --rank N --capacity N
                  --checkpoint PATH (hot-load a trained adapter too)
                  --requests N --prompt-len N --max-new N --gap-ms MS
                  --max-batch N --max-wait-ms MS --seed N --parallelism N
                  --verify (bit-compare every batch vs the sequential
                  single-request oracle; non-zero exit on any mismatch)
                  See docs/SERVING.md for the architecture and policy.
    doctor      ops self-check: toolchain/thread-budget/pool health, the
                packed-kernel raw-bits tripwire (pooled packed GEMMs vs
                the naive oracles, NaN/Inf included), a catalog smoke
                per family (lm/lora/vit, serve oracle, dp W=2
                raw-bits), and contract validation of every committed
                BENCH_*.json + BENCH_BUDGETS.toml
                  --quick (shorten the smokes; same checks — CI uses this)
                  --parallelism N (thread budget for the smokes)
                  --bench-dir DIR (where BENCH_*.json live; default .)
                  --receipt PATH (write the JSON receipt there instead
                  of stdout)
                  Exits non-zero if any check fails; the receipt names
                  the failing checks. See docs/OPS.md.
    help        show this message

Switches: `--list-catalog` (with any command) prints the native catalog
inventory grouped by model family and size, with rank/optimizer
variants collapsed into `r{N}`/`{opt}` patterns.

Backends: `--backend native` runs the generated pure-rust catalog — the
bigram LMs (lm-tiny/lm-small/lm-base) PLUS the native transformer size
grids: `lora-tiny`/`lora-small`/`lora-base` (causal LMs; full-tune,
LoRA-adapter and GaLore entries) and `vit-tiny`/`vit-small` (ViTs;
`--model vit-*` implies `--task vit`) — every base optimizer in
plain/accumulation/momentum modes, no artifacts or XLA needed. The
default `xla` backend loads AOT artifacts via PJRT and needs a build
with `--features xla`.

Benches reproducing each paper table/figure: `cargo bench --bench <name>`
(figure1_pilot, table1_accumulation, table2_momentum, table3_kappa,
 table4_linear_memory, table5_vit, table6_galore, figure2_profile, micro_rp);
the table benches accept `-- --backend native` too.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn command_and_flags() {
        let a = parse("train --model lm-small --steps 100 --verbose");
        assert_eq!(a.command, "train");
        assert_eq!(a.flag("model"), Some("lm-small"));
        assert_eq!(a.usize_flag("steps", 1).unwrap(), 100);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("train --lr=0.05 --method=flora");
        assert_eq!(a.f32_flag("lr", 0.0).unwrap(), 0.05);
        assert_eq!(a.flag("method"), Some("flora"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("eval");
        assert_eq!(a.usize_flag("steps", 7).unwrap(), 7);
        assert_eq!(a.flag_or("model", "lm-small"), "lm-small");
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("train --steps abc");
        assert!(a.usize_flag("steps", 1).is_err());
    }

    #[test]
    fn trailing_switch_not_eaten_as_value() {
        let a = parse("train --verbose --steps 5");
        assert!(a.has("verbose"));
        assert_eq!(a.usize_flag("steps", 0).unwrap(), 5);
    }

    #[test]
    fn positional_after_command_rejected() {
        assert!(Args::parse(
            ["train", "extra"].iter().map(|s| s.to_string())
        )
        .is_err());
    }
}
