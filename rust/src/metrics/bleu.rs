//! Corpus BLEU-4 (Papineni et al.) in the SacreBLEU style the paper cites:
//! clipped modified n-gram precision up to 4-grams, geometric mean, brevity
//! penalty, with add-1 smoothing on the higher orders (smoothing method
//! "add-k", k=1 — sacreBLEU's `smooth_method=exp` differs slightly; the
//! ranking behaviour, which Tables 1b/2 rely on, is identical).

use std::collections::HashMap;

#[derive(Clone, Copy, Debug, Default)]
pub struct Bleu {
    /// corpus score scaled to 0-100
    pub score: f64,
    pub precisions: [f64; 4],
    pub brevity_penalty: f64,
    pub hyp_len: usize,
    pub ref_len: usize,
}

fn ngrams(xs: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut m = HashMap::new();
    if xs.len() >= n {
        for w in xs.windows(n) {
            *m.entry(w).or_insert(0) += 1;
        }
    }
    m
}

/// Corpus-level BLEU over (hypothesis, reference) pairs.
pub fn bleu_corpus(pairs: &[(Vec<i32>, Vec<i32>)]) -> Bleu {
    let mut matches = [0usize; 4];
    let mut totals = [0usize; 4];
    let (mut hyp_len, mut ref_len) = (0usize, 0usize);

    for (hyp, rf) in pairs {
        hyp_len += hyp.len();
        ref_len += rf.len();
        for n in 1..=4 {
            let h = ngrams(hyp, n);
            let r = ngrams(rf, n);
            totals[n - 1] += h.values().sum::<usize>();
            matches[n - 1] += h
                .iter()
                .map(|(g, &hc)| hc.min(r.get(g).copied().unwrap_or(0)))
                .sum::<usize>();
        }
    }

    let mut precisions = [0.0f64; 4];
    let mut log_sum = 0.0f64;
    for n in 0..4 {
        // add-1 smoothing above unigrams (standard for short corpora)
        let (m, t) = if n == 0 {
            (matches[0] as f64, totals[0] as f64)
        } else {
            (matches[n] as f64 + 1.0, totals[n] as f64 + 1.0)
        };
        let p = if t > 0.0 { m / t } else { 0.0 };
        precisions[n] = p;
        log_sum += if p > 0.0 { p.ln() } else { f64::NEG_INFINITY };
    }

    let bp = if hyp_len == 0 {
        0.0
    } else if hyp_len >= ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len as f64).exp()
    };

    let score = if log_sum.is_finite() {
        100.0 * bp * (log_sum / 4.0).exp()
    } else {
        0.0
    };
    Bleu { score, precisions, brevity_penalty: bp, hyp_len, ref_len }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_match_scores_100() {
        let pairs = vec![(vec![1, 2, 3, 4, 5, 6], vec![1, 2, 3, 4, 5, 6])];
        let b = bleu_corpus(&pairs);
        assert!(b.score > 90.0, "score={}", b.score); // smoothing shaves a bit
        assert_eq!(b.brevity_penalty, 1.0);
        assert_eq!(b.precisions[0], 1.0);
    }

    #[test]
    fn disjoint_scores_zero() {
        let pairs = vec![(vec![1, 1, 1, 1], vec![2, 2, 2, 2])];
        let b = bleu_corpus(&pairs);
        assert_eq!(b.score, 0.0); // unigram precision 0 (unsmoothed) → 0
    }

    #[test]
    fn brevity_penalty_applies() {
        // hypothesis shorter than reference
        let pairs = vec![(vec![1, 2, 3], vec![1, 2, 3, 4, 5, 6])];
        let b = bleu_corpus(&pairs);
        assert!(b.brevity_penalty < 1.0);
        let want = (1.0f64 - 6.0 / 3.0).exp();
        assert!((b.brevity_penalty - want).abs() < 1e-12);
    }

    #[test]
    fn clipping_prevents_ngram_stuffing() {
        // hyp repeats a matching token; clipped count caps the precision
        let pairs = vec![(vec![7, 7, 7, 7], vec![7, 8, 9, 10])];
        let b = bleu_corpus(&pairs);
        assert!((b.precisions[0] - 0.25).abs() < 1e-12);
    }

    #[test]
    fn partial_overlap_between_zero_and_hundred() {
        let pairs = vec![(vec![1, 2, 3, 9, 5, 6], vec![1, 2, 3, 4, 5, 6])];
        let b = bleu_corpus(&pairs);
        assert!(b.score > 5.0 && b.score < 90.0, "score={}", b.score);
    }

    #[test]
    fn corpus_pools_statistics() {
        // corpus BLEU is not the mean of sentence BLEUs: check pooling
        let pairs = vec![
            (vec![1, 2, 3, 4], vec![1, 2, 3, 4]),
            (vec![5, 6, 7, 8], vec![9, 10, 11, 12]),
        ];
        let b = bleu_corpus(&pairs);
        assert!((b.precisions[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_corpus_safe() {
        let b = bleu_corpus(&[]);
        assert_eq!(b.score, 0.0);
    }
}
