//! ROUGE-N and ROUGE-L (Lin, 2004) over token ids, reported as F1 — the
//! convention behind the paper's R1/R2/RL columns.

use std::collections::HashMap;

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RougeScores {
    pub rouge1: f64,
    pub rouge2: f64,
    pub rouge_l: f64,
}

fn ngram_counts(xs: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut m = HashMap::new();
    if xs.len() >= n {
        for w in xs.windows(n) {
            *m.entry(w).or_insert(0) += 1;
        }
    }
    m
}

/// F1 of clipped n-gram overlap.
fn rouge_n(hyp: &[i32], reference: &[i32], n: usize) -> f64 {
    let h = ngram_counts(hyp, n);
    let r = ngram_counts(reference, n);
    let h_total: usize = h.values().sum();
    let r_total: usize = r.values().sum();
    if h_total == 0 || r_total == 0 {
        return 0.0;
    }
    let overlap: usize = r
        .iter()
        .map(|(g, &rc)| rc.min(h.get(g).copied().unwrap_or(0)))
        .sum();
    let p = overlap as f64 / h_total as f64;
    let rec = overlap as f64 / r_total as f64;
    if p + rec == 0.0 {
        0.0
    } else {
        2.0 * p * rec / (p + rec)
    }
}

/// Longest common subsequence length (O(|a|·|b|) DP, rolling row).
fn lcs_len(a: &[i32], b: &[i32]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for &x in a {
        for (j, &y) in b.iter().enumerate() {
            cur[j + 1] = if x == y {
                prev[j] + 1
            } else {
                prev[j + 1].max(cur[j])
            };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

fn rouge_l(hyp: &[i32], reference: &[i32]) -> f64 {
    if hyp.is_empty() || reference.is_empty() {
        return 0.0;
    }
    let l = lcs_len(hyp, reference) as f64;
    let p = l / hyp.len() as f64;
    let r = l / reference.len() as f64;
    if p + r == 0.0 {
        0.0
    } else {
        2.0 * p * r / (p + r)
    }
}

/// Sentence-level scores.
pub fn rouge_sentence(hyp: &[i32], reference: &[i32]) -> RougeScores {
    RougeScores {
        rouge1: rouge_n(hyp, reference, 1),
        rouge2: rouge_n(hyp, reference, 2),
        rouge_l: rouge_l(hyp, reference),
    }
}

/// Corpus scores: macro-average of sentence F1s (the common reporting for
/// summarization; scaled to 0-100 like the paper's tables).
pub fn rouge_corpus(pairs: &[(Vec<i32>, Vec<i32>)]) -> RougeScores {
    if pairs.is_empty() {
        return RougeScores::default();
    }
    let mut acc = RougeScores::default();
    for (h, r) in pairs {
        let s = rouge_sentence(h, r);
        acc.rouge1 += s.rouge1;
        acc.rouge2 += s.rouge2;
        acc.rouge_l += s.rouge_l;
    }
    let n = pairs.len() as f64;
    RougeScores {
        rouge1: 100.0 * acc.rouge1 / n,
        rouge2: 100.0 * acc.rouge2 / n,
        rouge_l: 100.0 * acc.rouge_l / n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_sequences_score_one() {
        let s = rouge_sentence(&[1, 2, 3, 4], &[1, 2, 3, 4]);
        assert_eq!(s.rouge1, 1.0);
        assert_eq!(s.rouge2, 1.0);
        assert_eq!(s.rouge_l, 1.0);
    }

    #[test]
    fn disjoint_sequences_score_zero() {
        let s = rouge_sentence(&[1, 2, 3], &[4, 5, 6]);
        assert_eq!(s, RougeScores { rouge1: 0.0, rouge2: 0.0, rouge_l: 0.0 });
    }

    #[test]
    fn rouge1_hand_computed() {
        // hyp {1,2,2,3}, ref {2,3,4}: overlap = min counts: 2→1? ref has one
        // 2, hyp has two → clipped 1; 3 → 1. overlap=2, P=2/4, R=2/3
        let h = [1, 2, 2, 3];
        let r = [2, 3, 4];
        let p: f64 = 2.0 / 4.0;
        let rec: f64 = 2.0 / 3.0;
        let want = 2.0 * p * rec / (p + rec);
        assert!((rouge_n(&h, &r, 1) - want).abs() < 1e-12);
    }

    #[test]
    fn rouge2_counts_bigrams() {
        let h = [1, 2, 3];
        let r = [1, 2, 4];
        // bigrams hyp: (1,2),(2,3); ref: (1,2),(2,4); overlap 1
        let p: f64 = 0.5;
        let rec: f64 = 0.5;
        assert!((rouge_n(&h, &r, 2) - 2.0 * p * rec / (p + rec)).abs() < 1e-12);
    }

    #[test]
    fn lcs_classic() {
        assert_eq!(lcs_len(&[1, 3, 2, 4], &[1, 2, 3, 4]), 3); // 1,3,4 or 1,2,4
        assert_eq!(lcs_len(&[1, 2], &[3, 4]), 0);
        assert_eq!(lcs_len(&[], &[1]), 0);
    }

    #[test]
    fn rouge_l_respects_order() {
        // same unigrams, scrambled order: R1 stays 1, RL drops
        let r = [1, 2, 3, 4, 5];
        let h = [5, 4, 3, 2, 1];
        let s = rouge_sentence(&h, &r);
        assert_eq!(s.rouge1, 1.0);
        assert!(s.rouge_l < 0.5);
    }

    #[test]
    fn corpus_scales_to_100() {
        let pairs = vec![(vec![1, 2], vec![1, 2]), (vec![3], vec![4])];
        let s = rouge_corpus(&pairs);
        assert!((s.rouge1 - 50.0).abs() < 1e-9);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(rouge_sentence(&[], &[1, 2]).rouge1, 0.0);
        assert_eq!(rouge_corpus(&[]).rouge_l, 0.0);
    }
}
