//! Evaluation metrics, implemented from scratch over token-id sequences:
//! ROUGE-1/2/L (F1, as the paper's R1/R2/RL columns), BLEU-4 with brevity
//! penalty and add-1 smoothing (sacreBLEU's default smoothing for short
//! segments), token accuracy and perplexity.

mod bleu;
mod rouge;

pub use bleu::{bleu_corpus, Bleu};
pub use rouge::{rouge_corpus, RougeScores};

/// Perplexity from a mean token NLL in nats.
pub fn perplexity(mean_nll: f64) -> f64 {
    mean_nll.exp()
}

/// Exact-match token accuracy between two equal-role sequences (truncates
/// to the shorter length; empty pairs count as 0).
pub fn token_accuracy(hyp: &[i32], reference: &[i32]) -> f64 {
    let n = hyp.len().min(reference.len());
    if n == 0 {
        return 0.0;
    }
    let hits = hyp
        .iter()
        .zip(reference.iter())
        .filter(|(a, b)| a == b)
        .count();
    hits as f64 / reference.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perplexity_of_uniform() {
        let v = 256.0f64;
        assert!((perplexity(v.ln()) - v).abs() < 1e-6);
    }

    #[test]
    fn token_accuracy_basics() {
        assert_eq!(token_accuracy(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(token_accuracy(&[1, 0, 3], &[1, 2, 3]), 2.0 / 3.0);
        assert_eq!(token_accuracy(&[], &[1]), 0.0);
    }
}
