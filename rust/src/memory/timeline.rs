//! Figure-2 memory timeline: per-category memory during training steps.
//!
//! The paper's Figure 2 profiles four training iterations and plots memory
//! by category (parameter, optimizer state, gradient, activation) for Adam
//! vs LoRA vs FLORA, with and without activation checkpointing + LOMO.
//! This module generates that series analytically from the accountant: each
//! step is expanded into forward / backward / update phases with the exact
//! byte deltas each phase allocates and frees.

use super::{activation_bytes, breakdown, Breakdown, Dims, Method, OptKind, StateRole};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Idle,
    Forward,
    Backward,
    Update,
}

#[derive(Clone, Debug)]
pub struct TimelineEvent {
    /// abstract time (monotone event counter)
    pub t: usize,
    pub step: usize,
    pub phase: Phase,
    pub params: u64,
    pub opt_state: u64,
    pub grads: u64,
    pub activations: u64,
    pub method_state: u64,
}

impl TimelineEvent {
    pub fn total(&self) -> u64 {
        self.params + self.opt_state + self.grads + self.activations + self.method_state
    }
}

/// Generate the Figure-2 series: `steps` iterations of (fwd, bwd, update).
///
/// `lomo`: layer-by-layer updating — gradients never materialize all at
/// once; the gradient category is capped at one layer's worth.
/// `checkpointing`: activations retain only per-layer residuals.
pub fn figure2_timeline(
    dims: &Dims,
    method: Method,
    opt: OptKind,
    batch: u64,
    steps: usize,
    checkpointing: bool,
    lomo: bool,
) -> Vec<TimelineEvent> {
    let bd: Breakdown = breakdown(dims, method, opt, StateRole::Momentum, batch, checkpointing);
    let act_full = activation_bytes(dims, batch, checkpointing);
    let grads_full = if lomo {
        // one layer of gradients at a time
        bd.grads / dims.n_layers.max(1)
    } else {
        bd.grads
    };
    let params = bd.params + bd.extra_params / 2; // LoRA patch values
    let mut out = Vec::new();
    let mut t = 0usize;
    let mut push = |t: &mut usize, step, phase, grads, acts, method_state| {
        out.push(TimelineEvent {
            t: *t,
            step,
            phase,
            params,
            opt_state: bd.opt_state,
            grads,
            activations: acts,
            method_state,
        });
        *t += 1;
    };

    push(&mut t, 0, Phase::Idle, 0, 0, bd.method_state);
    for step in 0..steps {
        // forward: activations ramp up
        push(&mut t, step, Phase::Forward, 0, act_full / 2, bd.method_state);
        push(&mut t, step, Phase::Forward, 0, act_full, bd.method_state);
        // backward: grads appear while activations are consumed
        push(&mut t, step, Phase::Backward, grads_full, act_full / 2, bd.method_state);
        push(&mut t, step, Phase::Backward, grads_full, 0, bd.method_state);
        // update: optimizer reads grads + method state
        push(&mut t, step, Phase::Update, if lomo { 0 } else { grads_full }, 0, bd.method_state);
        push(&mut t, step, Phase::Idle, 0, 0, bd.method_state);
    }
    out
}

/// Peak total across a timeline.
pub fn timeline_peak(events: &[TimelineEvent]) -> u64 {
    events.iter().map(|e| e.total()).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> Dims {
        Dims::gpt2_base_sim()
    }

    #[test]
    fn timeline_has_expected_length() {
        let tl = figure2_timeline(&dims(), Method::Naive, OptKind::Adam, 4, 4, false, false);
        assert_eq!(tl.len(), 1 + 4 * 6);
        // monotone event counter
        for w in tl.windows(2) {
            assert_eq!(w[1].t, w[0].t + 1);
        }
    }

    #[test]
    fn peak_occurs_in_forward_backward_boundary() {
        let tl = figure2_timeline(&dims(), Method::Naive, OptKind::Adam, 4, 2, false, false);
        let peak = timeline_peak(&tl);
        let at_peak: Vec<Phase> = tl
            .iter()
            .filter(|e| e.total() == peak)
            .map(|e| e.phase)
            .collect();
        assert!(at_peak
            .iter()
            .all(|p| matches!(p, Phase::Forward | Phase::Backward)));
    }

    #[test]
    fn flora_and_lora_shrink_state_not_peak_under_adam_activations() {
        // Figure 2a: with full activations, peak is activation-dominated,
        // so Adam vs FLORA peaks are close while the state categories differ
        let adam =
            figure2_timeline(&dims(), Method::None, OptKind::Adam, 4, 2, false, false);
        let flora = figure2_timeline(
            &dims(), Method::Flora(128), OptKind::Adafactor, 4, 2, false, false,
        );
        let p_adam = timeline_peak(&adam);
        let p_flora = timeline_peak(&flora);
        assert!(p_flora < p_adam);
        // but the optimizer-state category shrinks dramatically
        assert!(flora[0].opt_state < adam[0].opt_state / 10);
    }

    #[test]
    fn ac_plus_lomo_cuts_peak() {
        // Figure 2b: AC+LOMO removes the activation/grad bulk
        let plain = figure2_timeline(
            &dims(), Method::Flora(128), OptKind::Adafactor, 4, 2, false, false,
        );
        let lean = figure2_timeline(
            &dims(), Method::Flora(128), OptKind::Adafactor, 4, 2, true, true,
        );
        assert!(timeline_peak(&lean) < timeline_peak(&plain) / 3);
    }
}
