//! Live memory ledger: tracks the actual bytes held in PJRT device buffers
//! by the runtime's state store, plus a /proc RSS probe. Used to validate
//! the analytic accountant on the small configs (rust/tests/) and to report
//! real peaks in EXPERIMENTS.md.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Thread-safe running ledger of allocated buffer bytes with a peak tracker.
#[derive(Clone, Default)]
pub struct BufferLedger {
    inner: Arc<Inner>,
}

#[derive(Default)]
struct Inner {
    current: AtomicU64,
    peak: AtomicU64,
}

impl BufferLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&self, bytes: u64) {
        let cur = self.inner.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.inner.peak.fetch_max(cur, Ordering::Relaxed);
    }

    pub fn free(&self, bytes: u64) {
        self.inner.current.fetch_sub(bytes, Ordering::Relaxed);
    }

    pub fn current(&self) -> u64 {
        self.inner.current.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> u64 {
        self.inner.peak.load(Ordering::Relaxed)
    }

    pub fn reset_peak(&self) {
        self.inner
            .peak
            .store(self.current(), Ordering::Relaxed);
    }
}

/// Resident set size of this process in bytes (linux /proc/self/statm).
pub fn rss_bytes() -> Option<u64> {
    let statm = std::fs::read_to_string("/proc/self/statm").ok()?;
    let resident_pages: u64 = statm.split_whitespace().nth(1)?.parse().ok()?;
    Some(resident_pages * 4096)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_tracks_current_and_peak() {
        let l = BufferLedger::new();
        l.alloc(100);
        l.alloc(50);
        assert_eq!(l.current(), 150);
        l.free(120);
        assert_eq!(l.current(), 30);
        assert_eq!(l.peak(), 150);
        l.alloc(40);
        assert_eq!(l.peak(), 150); // 70 < 150
        l.reset_peak();
        assert_eq!(l.peak(), 70);
    }

    #[test]
    fn ledger_clones_share_state() {
        let a = BufferLedger::new();
        let b = a.clone();
        a.alloc(10);
        assert_eq!(b.current(), 10);
    }

    #[test]
    fn rss_readable_on_linux() {
        let rss = rss_bytes().expect("statm readable");
        assert!(rss > 1024 * 1024, "rss={rss}");
    }
}
