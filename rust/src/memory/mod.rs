//! Analytic memory accountant.
//!
//! The paper's Mem/ΔM columns measure *peak device memory*, dominated by
//! (a) parameters, (b) gradients, (c) optimizer state, (d) the method's
//! accumulation/momentum state, (e) activations. (a), (b), (e) are identical
//! across methods (§2.4: "neither LoRA nor FLORA saves the memory for
//! back-propagation"), so the method ranking is decided by (c)+(d) — which
//! this module computes *exactly*, per parameter tensor, for any model size.
//! That's how the 3B/1.5B rows of Tables 1–2 are reproduced on a small
//! machine: byte accounting is exact at any scale (validated against the
//! live PJRT buffer ledger on the small configs in rust/tests/).

pub mod ledger;
pub mod timeline;

pub use ledger::BufferLedger;
pub use timeline::{figure2_timeline, Phase, TimelineEvent};

pub const F32: u64 = 4;

/// One weight tensor of the model, as the accountant sees it.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub rows: u64,
    /// 0 for vectors
    pub cols: u64,
    /// gets the projection treatment (attention/ffn matrices, §3.1)
    pub projectable: bool,
}

impl ParamEntry {
    pub fn numel(&self) -> u64 {
        if self.cols == 0 {
            self.rows
        } else {
            self.rows * self.cols
        }
    }
}

/// Decoder-only transformer dimensions (mirrors python LMConfig shapes).
#[derive(Clone, Copy, Debug)]
pub struct Dims {
    pub vocab: u64,
    pub d_model: u64,
    pub n_layers: u64,
    pub d_ff: u64,
    pub seq_len: u64,
    pub n_heads: u64,
}

impl Dims {
    /// The exact parameter inventory of `layers.py::LMConfig.param_shapes`.
    pub fn params(&self) -> Vec<ParamEntry> {
        let mut out = vec![
            ParamEntry {
                name: "embed/tok".into(),
                rows: self.vocab,
                cols: self.d_model,
                projectable: false,
            },
            ParamEntry {
                name: "embed/pos".into(),
                rows: self.seq_len,
                cols: self.d_model,
                projectable: false,
            },
            ParamEntry {
                name: "final_ln/scale".into(),
                rows: self.d_model,
                cols: 0,
                projectable: false,
            },
        ];
        for l in 0..self.n_layers {
            let d = self.d_model;
            let f = self.d_ff;
            for (suffix, r, c, proj) in [
                ("attn/wq", d, d, true),
                ("attn/wk", d, d, true),
                ("attn/wv", d, d, true),
                ("attn/wo", d, d, true),
                ("ffn/w1", d, f, true),
                ("ffn/w2", f, d, true),
                ("ln1/scale", d, 0, false),
                ("ln2/scale", d, 0, false),
            ] {
                out.push(ParamEntry {
                    name: format!("layer{l}/{suffix}"),
                    rows: r,
                    cols: c,
                    projectable: proj,
                });
            }
        }
        out
    }

    pub fn param_count(&self) -> u64 {
        self.params().iter().map(|p| p.numel()).sum()
    }

    // -- paper-scale presets (sized so param_count lands on the paper's
    //    Size column under THIS architecture; documented substitution) --

    /// "T5-small" row: ~60M params.
    pub fn t5_small_sim() -> Dims {
        Dims { vocab: 32128, d_model: 512, n_layers: 14, d_ff: 2048, seq_len: 512, n_heads: 8 }
    }

    /// "T5-3B" row: ~3B params.
    pub fn t5_3b_sim() -> Dims {
        Dims { vocab: 32128, d_model: 1024, n_layers: 78, d_ff: 16384, seq_len: 512, n_heads: 32 }
    }

    /// "GPT-2 base" row: ~110M params.
    pub fn gpt2_base_sim() -> Dims {
        Dims { vocab: 50257, d_model: 768, n_layers: 12, d_ff: 3072, seq_len: 1024, n_heads: 12 }
    }

    /// "GPT-2-XL" row: ~1.5B params.
    pub fn gpt2_xl_sim() -> Dims {
        Dims { vocab: 50257, d_model: 1600, n_layers: 48, d_ff: 6400, seq_len: 1024, n_heads: 25 }
    }

    /// The small bench model actually trained on this machine (lm-small).
    pub fn lm_small() -> Dims {
        Dims { vocab: 256, d_model: 64, n_layers: 2, d_ff: 256, seq_len: 64, n_heads: 4 }
    }

    /// lm-tiny test model.
    pub fn lm_tiny() -> Dims {
        Dims { vocab: 64, d_model: 32, n_layers: 2, d_ff: 64, seq_len: 32, n_heads: 2 }
    }
}

/// The compression method applied to optimizer-adjacent state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// no accumulation / momentum at all
    None,
    /// full-size accumulator / momentum
    Naive,
    /// LoRA patches of rank r (trainable A, B; frozen base)
    Lora(u64),
    /// FLORA compressed state of rank r
    Flora(u64),
    /// GaLore: stored projection + projected Adam moments
    Galore(u64),
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::None => "None".into(),
            Method::Naive => "Naive".into(),
            Method::Lora(r) => format!("LoRA({r})"),
            Method::Flora(r) => format!("FLORA({r})"),
            Method::Galore(r) => format!("GaLore({r})"),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptKind {
    Adam,
    Adafactor,
    AdafactorNoFactor,
}

/// Whether the method state is a gradient accumulator (Algorithm 1, one
/// buffer) or a momentum (Algorithm 2, one buffer) — same byte shape, named
/// for clarity in reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateRole {
    Accumulation,
    Momentum,
}

/// Full byte breakdown for one (model, method, optimizer) cell.
#[derive(Clone, Copy, Debug, Default)]
pub struct Breakdown {
    pub params: u64,
    pub grads: u64,
    pub opt_state: u64,
    pub method_state: u64,
    /// LoRA only: the patch parameters themselves + their gradients
    pub extra_params: u64,
    pub activations: u64,
}

impl Breakdown {
    pub fn total(&self) -> u64 {
        self.params
            + self.grads
            + self.opt_state
            + self.method_state
            + self.extra_params
            + self.activations
    }
}

/// Optimizer state bytes for one tensor under `opt`.
fn opt_bytes_for(entry_rows: u64, entry_cols: u64, opt: OptKind) -> u64 {
    let numel = if entry_cols == 0 { entry_rows } else { entry_rows * entry_cols };
    match opt {
        OptKind::Adam => 2 * numel * F32,
        OptKind::AdafactorNoFactor => numel * F32,
        OptKind::Adafactor => {
            if entry_cols == 0 {
                entry_rows * F32
            } else {
                (entry_rows + entry_cols) * F32
            }
        }
    }
}

/// Activation bytes for one training step (batch × transformer), the
/// method-independent component. Counts the standard retained set:
/// per layer: block input, normed input, qkv, attn probs (b·h·s·s),
/// context, ffn hidden; plus logits.
pub fn activation_bytes(d: &Dims, batch: u64, checkpointing: bool) -> u64 {
    let b = batch;
    let s = d.seq_len;
    let dm = d.d_model;
    let per_layer = b * s * dm * 6 + b * d.n_heads * s * s + b * s * d.d_ff;
    let logits = b * s * d.vocab;
    if checkpointing {
        // AC retains one residual per layer, recomputes the rest
        (d.n_layers * b * s * dm + logits) * F32
    } else {
        (d.n_layers * per_layer + logits) * F32
    }
}

/// The central accounting function: byte breakdown for one table cell.
pub fn breakdown(
    dims: &Dims,
    method: Method,
    opt: OptKind,
    role: StateRole,
    batch: u64,
    checkpointing: bool,
) -> Breakdown {
    let entries = dims.params();
    let n_params: u64 = entries.iter().map(|p| p.numel()).sum();
    let mut out = Breakdown {
        params: n_params * F32,
        grads: n_params * F32, // §2.4: full gradient exists under every method
        activations: activation_bytes(dims, batch, checkpointing),
        ..Default::default()
    };
    let _ = role;

    match method {
        Method::None | Method::Naive | Method::Flora(_) => {
            // base optimizer state covers ALL model params
            for e in &entries {
                out.opt_state += opt_bytes_for(e.rows, e.cols, opt);
            }
            match method {
                Method::None => {}
                Method::Naive => {
                    out.method_state = n_params * F32;
                }
                Method::Flora(r) => {
                    for e in &entries {
                        out.method_state += if e.projectable {
                            e.rows * r * F32
                        } else {
                            e.numel() * F32
                        };
                    }
                }
                _ => unreachable!(),
            }
        }
        Method::Lora(r) => {
            // trainable set = A,B patches + non-projectable params; the
            // base matrices are frozen (no grads/opt state) but the FULL
            // gradient still materializes on the Jacobian path (§3.2) —
            // kept in out.grads above.
            for e in &entries {
                if e.projectable {
                    let patch = r * (e.rows + e.cols);
                    out.extra_params += patch * F32; // A and B values
                    out.extra_params += patch * F32; // their gradients
                    // opt state on A [r, cols] and B [rows, r]
                    out.opt_state += opt_bytes_for(r, e.cols, opt);
                    out.opt_state += opt_bytes_for(e.rows, r, opt);
                    // accumulation/momentum state on A and B (naive, small)
                    out.method_state += patch * F32;
                } else {
                    out.opt_state += opt_bytes_for(e.rows, e.cols, opt);
                    out.method_state += e.numel() * F32;
                }
            }
        }
        Method::Galore(r) => {
            for e in &entries {
                if e.projectable {
                    // stored projection P [rows, r] + Adam moments [r, cols]
                    out.method_state += e.rows * r * F32;
                    out.opt_state += 2 * r * e.cols * F32;
                } else {
                    out.opt_state += opt_bytes_for(e.rows, e.cols, OptKind::Adam);
                }
            }
        }
    }
    out
}

/// The ΔM column: total minus the method-"None" total of the same row.
pub fn delta_m(dims: &Dims, method: Method, opt: OptKind, role: StateRole, batch: u64) -> i64 {
    let with = breakdown(dims, method, opt, role, batch, false).total() as i64;
    let none = breakdown(dims, Method::None, opt, role, batch, false).total() as i64;
    with - none
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_sizes_match_paper_rows() {
        // within 15% of the paper's Size column
        let checks = [
            (Dims::t5_small_sim().param_count(), 60_000_000u64),
            (Dims::t5_3b_sim().param_count(), 3_000_000_000),
            (Dims::gpt2_base_sim().param_count(), 110_000_000),
            (Dims::gpt2_xl_sim().param_count(), 1_500_000_000),
        ];
        for (got, want) in checks {
            let rel = (got as f64 - want as f64).abs() / want as f64;
            assert!(rel < 0.15, "got {got}, want ~{want} (rel {rel:.2})");
        }
    }

    #[test]
    fn flora_state_sublinear_naive_linear() {
        let d = Dims::t5_small_sim();
        let naive =
            breakdown(&d, Method::Naive, OptKind::Adafactor, StateRole::Accumulation, 1, false);
        // T5-small's embedding (handled naively, §3.1) is ~27% of params,
        // so the clear sublinear win shows at moderate ranks
        let flora =
            breakdown(&d, Method::Flora(64), OptKind::Adafactor, StateRole::Accumulation, 1, false);
        assert_eq!(naive.method_state, d.param_count() * F32);
        assert!(flora.method_state < naive.method_state / 2);
    }

    #[test]
    fn flora_cheaper_than_lora_at_same_rank() {
        // the paper's "same asymptotic rate but smaller constant" claim:
        // LoRA stores A+B+their grads+opt+accum state; FLORA stores C only.
        let d = Dims::t5_small_sim();
        for r in [8, 32, 128, 256] {
            let lora = breakdown(
                &d, Method::Lora(r), OptKind::Adafactor, StateRole::Accumulation, 1, false,
            );
            let flora = breakdown(
                &d, Method::Flora(r), OptKind::Adafactor, StateRole::Accumulation, 1, false,
            );
            let lora_delta = lora.method_state + lora.extra_params;
            // compare the *method-induced* extra state on projectable params
            let flora_proj: u64 = d
                .params()
                .iter()
                .filter(|e| e.projectable)
                .map(|e| e.rows * r * F32)
                .sum();
            assert!(flora_proj < lora_delta, "r={r}");
            let _ = flora;
        }
    }

    #[test]
    fn adafactor_is_sublinear_adam_linear() {
        let d = Dims::gpt2_base_sim();
        let af = breakdown(&d, Method::None, OptKind::Adafactor, StateRole::Momentum, 1, false);
        let adam = breakdown(&d, Method::None, OptKind::Adam, StateRole::Momentum, 1, false);
        assert_eq!(adam.opt_state, 2 * d.param_count() * F32);
        assert!(af.opt_state < adam.opt_state / 10);
    }

    #[test]
    fn delta_m_none_is_zero() {
        let d = Dims::lm_small();
        assert_eq!(delta_m(&d, Method::None, OptKind::Adafactor, StateRole::Accumulation, 1), 0);
    }

    #[test]
    fn delta_m_ordering_matches_table1() {
        // Table 1: ΔM(Flora(r)) < ΔM(LoRA(r)) < ... < ΔM(Naive) for large
        // models at the paper's ranks.
        let d = Dims::t5_3b_sim();
        let role = StateRole::Accumulation;
        let naive = delta_m(&d, Method::Naive, OptKind::Adafactor, role, 1);
        let lora = delta_m(&d, Method::Lora(256), OptKind::Adafactor, role, 1);
        let flora = delta_m(&d, Method::Flora(256), OptKind::Adafactor, role, 1);
        assert!(flora < lora, "flora={flora} lora={lora}");
        assert!(flora < naive, "flora={flora} naive={naive}");
        // paper: FLORA(256) overhead ≈ 30% of naive on 3B
        let frac = flora as f64 / naive as f64;
        assert!(frac < 0.5, "frac={frac}");
    }

    #[test]
    fn lora_can_beat_flora_under_linear_optimizer_small_rank() {
        // Table 4's observation: with an unfactored (linear-memory) base
        // optimizer, LoRA's tiny trainable set wins at small r ...
        let d = Dims::t5_small_sim();
        let role = StateRole::Accumulation;
        let lora8 = breakdown(&d, Method::Lora(8), OptKind::AdafactorNoFactor, role, 1, false);
        let flora8 = breakdown(&d, Method::Flora(8), OptKind::AdafactorNoFactor, role, 1, false);
        let lora_state = lora8.opt_state + lora8.method_state + lora8.extra_params;
        let flora_state = flora8.opt_state + flora8.method_state;
        assert!(lora_state < flora_state);
        // ... and FLORA wins at r=256 (the crossover the paper reports)
        let lora256 =
            breakdown(&d, Method::Lora(256), OptKind::AdafactorNoFactor, role, 1, false);
        let flora256 =
            breakdown(&d, Method::Flora(256), OptKind::AdafactorNoFactor, role, 1, false);
        let l = lora256.opt_state + lora256.method_state + lora256.extra_params;
        let f = flora256.opt_state + flora256.method_state;
        assert!(f < l, "flora={f} lora={l}");
    }

    #[test]
    fn galore_stores_more_than_flora() {
        // Table 6: GaLore keeps P on device; FLORA only a seed
        let d = Dims::t5_small_sim();
        let ga =
            breakdown(&d, Method::Galore(128), OptKind::Adam, StateRole::Momentum, 16, false);
        let fl = breakdown(
            &d, Method::Flora(128), OptKind::Adafactor, StateRole::Momentum, 16, false,
        );
        assert!(
            fl.opt_state + fl.method_state < ga.opt_state + ga.method_state
        );
    }

    #[test]
    fn checkpointing_reduces_activations() {
        let d = Dims::gpt2_base_sim();
        let full = activation_bytes(&d, 4, false);
        let ac = activation_bytes(&d, 4, true);
        // logits (b·s·vocab) are retained in both modes and dominate the AC
        // residuals; the win is still >4x on this config
        assert!(ac < full / 4);
    }

    #[test]
    fn gpt3_future_work_estimate() {
        // paper §5: "for GPT-3 we estimate the compressed optimization
        // state of r=256 is only 2.08% of its original memory"
        let gpt3 = Dims {
            vocab: 50257,
            d_model: 12288,
            n_layers: 96,
            d_ff: 49152,
            seq_len: 2048,
            n_heads: 96,
        };
        let entries = gpt3.params();
        let full: u64 = entries.iter().map(|e| e.numel() * F32).sum();
        let compressed: u64 = entries
            .iter()
            .map(|e| {
                if e.projectable { e.rows * 256 * F32 } else { e.numel() * F32 }
            })
            .sum();
        let pct = 100.0 * compressed as f64 / full as f64;
        assert!(pct < 6.0, "compressed state {pct:.2}% of full");
    }
}
