//! Cache-blocked GEMM kernels over raw row-major slices, plus the opt-in
//! row-parallel driver behind the global [`Parallelism`] config.
//!
//! These are the slice-level engines behind `Matrix::{matmul, matmul_nt,
//! matmul_tn}` and the batched attention primitives in
//! [`super::batched`]. Three properties are load-bearing and tested:
//!
//!   1. **Bit-equality with the retained naive kernels.** Every output
//!      element accumulates its contraction terms in strictly ascending
//!      `k` order with a single f32 accumulator, exactly like the naive
//!      triple loop — blocking only reorders *which element is computed
//!      when*, never the per-element summation order. The property tests
//!      in `rust/tests/properties.rs` bit-compare blocked against naive
//!      on random rectangular shapes.
//!   2. **Bit-equality across thread counts.** The parallel path splits
//!      the *output rows* into disjoint bands; each band is computed by
//!      exactly one thread running the identical serial kernel, so the
//!      result is bit-identical for every `Parallelism` setting (the
//!      `--parallelism 1` vs `2` CI matrix exercises this end-to-end).
//!   3. **No zero-skips.** As in PR 1, `0.0 * NaN` must stay NaN —
//!      non-finite gradients may not be laundered by a fast path.
//!
//! Zero new dependencies: threading is `std::thread::scope` only.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Rows of the shared (`B`) operand kept hot per k-panel. With the j-tile
/// below, one panel is `K_BLOCK * J_BLOCK * 4` bytes = 32 KiB — L1-sized.
const K_BLOCK: usize = 64;
/// Output-column tile width (f32 elements).
const J_BLOCK: usize = 128;
/// Minimum multiply count before the parallel path engages; below this
/// the `thread::scope` spawn cost dominates any speedup.
const PAR_MIN_FLOPS: usize = 1 << 15;

static PARALLELISM: AtomicUsize = AtomicUsize::new(1);

/// Thread budget for the tensor kernels. `Parallelism::new(1)` (the
/// default) is fully serial; higher values let the big GEMMs split their
/// output rows across `std::thread::scope` workers.
///
/// Determinism guarantee: results are **bit-identical for every thread
/// count** — each output row is owned by exactly one thread running the
/// same serial kernel, so no floating-point reassociation ever happens.
/// The setting is a process-wide tuning knob, not part of any model's
/// semantics, which is why it lives in a global rather than threading
/// through every call site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    threads: usize,
}

impl Parallelism {
    /// A budget of `threads` worker threads (clamped to >= 1).
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    /// The serial default.
    pub fn single() -> Self {
        Self::new(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Install this budget as the process-wide kernel setting.
    pub fn install(self) {
        PARALLELISM.store(self.threads, Ordering::Relaxed);
    }

    /// The currently-installed budget.
    pub fn current() -> Self {
        Self::new(PARALLELISM.load(Ordering::Relaxed))
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Self::single()
    }
}

/// Split `out` (owning `rows` rows of `row_width` f32s) into per-thread
/// row bands and run `kernel(band, first_row, n_rows)` on each. Serial
/// when the installed budget is 1, the work is below [`PAR_MIN_FLOPS`]
/// multiplies, or there is only one row.
pub(crate) fn par_rows<F>(out: &mut [f32], rows: usize, row_width: usize, flops: usize, kernel: F)
where
    F: Fn(&mut [f32], usize, usize) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_width);
    let budget = Parallelism::current().threads();
    let threads = if flops < PAR_MIN_FLOPS { 1 } else { budget.min(rows).max(1) };
    if threads <= 1 {
        kernel(out, 0, rows);
        return;
    }
    let chunk = rows.div_ceil(threads);
    let kernel = &kernel;
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut row0 = 0usize;
        while row0 < rows {
            let take = chunk.min(rows - row0);
            let (band, tail) = std::mem::take(&mut rest).split_at_mut(take * row_width);
            rest = tail;
            let first = row0;
            scope.spawn(move || kernel(band, first, take));
            row0 += take;
        }
    });
}

// ---------------------------------------------------------------------
// serial blocked kernels (the per-band bodies)
// ---------------------------------------------------------------------

/// `C += A @ B` on a band of `n` output rows: blocked ikj. `a` is the
/// band's rows of A (`n x k`), `b` the full B (`k x m`), `c` the band's
/// rows of C (`n x m`, pre-zeroed by the caller).
pub(crate) fn matmul_band(c: &mut [f32], a: &[f32], b: &[f32], n: usize, k: usize, m: usize) {
    for j0 in (0..m).step_by(J_BLOCK) {
        let j1 = (j0 + J_BLOCK).min(m);
        for k0 in (0..k).step_by(K_BLOCK) {
            let k1 = (k0 + K_BLOCK).min(k);
            for i in 0..n {
                let arow = &a[i * k..(i + 1) * k];
                let ctile = &mut c[i * m + j0..i * m + j1];
                for (kk, &aik) in arow[k0..k1].iter().enumerate() {
                    let brow = &b[(k0 + kk) * m + j0..(k0 + kk) * m + j1];
                    for (o, &bkj) in ctile.iter_mut().zip(brow.iter()) {
                        *o += aik * bkj;
                    }
                }
            }
        }
    }
}

/// `C = alpha * (A @ B^T)` on a band of `n` output rows: dot-product
/// kernel with a B-row tile kept hot across the band. `a` is the band's
/// rows of A (`n x k`), `b` the full B (`m x k`), `c` the band (`n x m`).
/// `alpha` multiplies each finished dot (the attention score scale);
/// pass 1.0 for a plain product.
pub(crate) fn matmul_nt_band(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
    alpha: f32,
) {
    for j0 in (0..m).step_by(K_BLOCK) {
        let j1 = (j0 + K_BLOCK).min(m);
        for i in 0..n {
            let arow = &a[i * k..(i + 1) * k];
            for j in j0..j1 {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (x, y) in arow.iter().zip(brow.iter()) {
                    acc += x * y;
                }
                c[i * m + j] = acc * alpha;
            }
        }
    }
}

/// `C += A^T @ B` on a band of C rows `[i0, i0+n)` (columns of A): for
/// every contraction row `k`, the band's C rows accumulate
/// `A[k][i] * B[k][j]` in ascending `k` order. `a` is the FULL A
/// (`rows x acols`), `b` the full B (`rows x m`), `c` the band
/// (`n x m`, pre-zeroed).
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul_tn_band(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    rows: usize,
    acols: usize,
    m: usize,
    i0: usize,
    n: usize,
) {
    for k in 0..rows {
        let arow = &a[k * acols..(k + 1) * acols];
        let brow = &b[k * m..(k + 1) * m];
        for i in 0..n {
            let aki = arow[i0 + i];
            let crow = &mut c[i * m..(i + 1) * m];
            for (o, &bkj) in crow.iter_mut().zip(brow.iter()) {
                *o += aki * bkj;
            }
        }
    }
}

// ---------------------------------------------------------------------
// parallel entry points (row-banded over the output)
// ---------------------------------------------------------------------

/// `C = A @ B` into a pre-zeroed `c` (`n x m`), row-parallel.
pub(crate) fn matmul_into(c: &mut [f32], a: &[f32], b: &[f32], n: usize, k: usize, m: usize) {
    par_rows(c, n, m, n * k * m, |band, first, rows| {
        matmul_band(band, &a[first * k..(first + rows) * k], b, rows, k, m);
    });
}

/// `C = alpha * (A @ B^T)` into `c` (`n x m`), row-parallel.
pub(crate) fn matmul_nt_into(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
    alpha: f32,
) {
    par_rows(c, n, m, n * k * m, |band, first, rows| {
        matmul_nt_band(band, &a[first * k..(first + rows) * k], b, rows, k, m, alpha);
    });
}

/// `C = A^T @ B` into a pre-zeroed `c` (`acols x m`), parallel over C's
/// rows (= A's columns); every thread streams the full A and B.
pub(crate) fn matmul_tn_into(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    rows: usize,
    acols: usize,
    m: usize,
) {
    par_rows(c, acols, m, rows * acols * m, |band, first, n| {
        matmul_tn_band(band, a, b, rows, acols, m, first, n);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallelism_clamps() {
        assert_eq!(Parallelism::new(0).threads(), 1);
        assert_eq!(Parallelism::default(), Parallelism::single());
    }

    // NOTE: this is the only test in the lib binary that installs a
    // non-default Parallelism, so the install/assert pair cannot race
    // with a concurrent test (and even if it could, kernel RESULTS are
    // bit-identical at every setting — only `current()` would wobble).
    #[test]
    fn install_and_par_rows_cover_every_row_once() {
        let before = Parallelism::current();
        Parallelism::new(4).install();
        assert_eq!(Parallelism::current().threads(), 4);
        // rows * width big enough to clear PAR_MIN_FLOPS via the fake
        // flops argument; each band stamps its rows with first+i
        let (rows, width) = (17usize, 8usize);
        let mut out = vec![-1.0f32; rows * width];
        par_rows(&mut out, rows, width, PAR_MIN_FLOPS * 2, |band, first, n| {
            for i in 0..n {
                for x in band[i * width..(i + 1) * width].iter_mut() {
                    *x = (first + i) as f32;
                }
            }
        });
        before.install();
        for r in 0..rows {
            let row = &out[r * width..(r + 1) * width];
            assert!(row.iter().all(|&x| x == r as f32), "row {r}");
        }
    }
}
