//! Cache-blocked GEMM kernels over raw row-major slices, plus the opt-in
//! row-parallel driver behind the global [`Parallelism`] config.
//!
//! These are the slice-level engines behind `Matrix::{matmul, matmul_nt,
//! matmul_tn}` and the batched attention primitives in
//! [`super::batched`]. Three properties are load-bearing and tested:
//!
//!   1. **Bit-equality with the retained naive kernels.** Every output
//!      element accumulates its contraction terms in strictly ascending
//!      `k` order with a single f32 accumulator, exactly like the naive
//!      triple loop — blocking, unrolling, and (since PR 9) packing the
//!      strided operand's panel into a reused thread-local scratch only
//!      reorder *which element is computed when* and *where its operand
//!      bytes are read from* (packing is a pure copy; partial dots chain
//!      through C via an exact f32 store/load round-trip), never one
//!      element's summation order. The property tests in
//!      `rust/tests/properties.rs` bit-compare blocked against naive on
//!      random rectangular shapes, ragged vs the block sizes, NaN/Inf
//!      included.
//!   2. **Bit-equality across thread counts and drivers.** The parallel
//!      path splits the *output rows* into disjoint bands; each band is
//!      computed by exactly one thread running the identical serial
//!      kernel, so the result is bit-identical for every `Parallelism`
//!      setting and for both parallel drivers (the persistent
//!      [worker pool](#the-worker-pool) and the retained
//!      `std::thread::scope` oracle). The `--parallelism 1` vs `2` CI
//!      matrix exercises this end-to-end.
//!   3. **No zero-skips.** As in PR 1, `0.0 * NaN` must stay NaN —
//!      non-finite gradients may not be laundered by a fast path.
//!
//! # The worker pool
//!
//! Since PR 5 the default parallel driver is a **persistent, lazily
//! started worker pool** (`std::sync` channels + condvar only, zero new
//! dependencies). The PR-4 driver spawned OS threads via
//! `std::thread::scope` on *every* GEMM call; at catalog sizes a
//! transformer step issues hundreds of kernel calls, so per-call spawn
//! and join dominated the win from threading. The pool starts its
//! workers once — eagerly on [`Parallelism::install`] (the path
//! `Trainer::with_runtime` drives) or lazily on the first parallel
//! kernel call — and every subsequent call only enqueues band jobs and
//! waits on a latch.
//!
//! The scope driver survives as [`Parallelism::scoped`]: it is the A/B
//! baseline for `benches/micro_kernels.rs --runtime scope` and the
//! bit-exactness oracle the pool is tested against (band splits and band
//! bodies are shared, so results are bit-identical by construction; the
//! tests verify it anyway).
//!
//! Zero new dependencies: threading is `std::thread` + `std::sync` only.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

/// Rows of the shared (`B`) operand packed per k-panel. With the j-tile
/// below, one packed panel is `K_BLOCK * J_BLOCK * 4` bytes = 32 KiB —
/// L1-sized. Re-swept for the packed kernels (docs/PERFORMANCE.md §1):
/// 64/128 stayed optimal under the vectorizing release profile.
const K_BLOCK: usize = 64;
/// Output-column tile width (f32 elements).
const J_BLOCK: usize = 128;
/// Minimum multiply count before the parallel path engages; below this
/// even pool dispatch (an enqueue + latch wait) costs more than it saves.
/// Shared with the batched/elementwise passes so every parallel surface
/// uses one engagement rule.
pub(crate) const PAR_MIN_FLOPS: usize = 1 << 15;
/// Cost weight of one softmax/norm/gather element against
/// [`PAR_MIN_FLOPS`]'s multiply budget: an exp or rsqrt plus several row
/// passes is worth roughly 8 multiplies. Conservative, so tiny
/// decode-step rows stay serial.
pub(crate) const ELEMWISE_FLOP_WEIGHT: usize = 8;

static PARALLELISM: AtomicUsize = AtomicUsize::new(1);
static DRIVER: AtomicU8 = AtomicU8::new(DRIVER_POOL);

const DRIVER_POOL: u8 = 0;
const DRIVER_SCOPE: u8 = 1;

/// Which mechanism fans band jobs out to OS threads. Selected through
/// [`Parallelism`]; results are bit-identical either way (same band
/// splits, same serial band bodies), so this only moves time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelDriver {
    /// The persistent worker pool (default): threads start once and are
    /// reused by every subsequent kernel call.
    Pool,
    /// The PR-4 `std::thread::scope` per-call spawn, retained as the A/B
    /// benchmark baseline (`--runtime scope`) and pool test oracle.
    Scope,
}

/// Thread budget (and parallel driver) for the tensor kernels.
/// `Parallelism::new(1)` (the default) is fully serial; higher values let
/// the big GEMMs split their output rows across worker threads.
///
/// Determinism guarantee: results are **bit-identical for every thread
/// count and either driver** — each output row is owned by exactly one
/// thread running the same serial kernel, so no floating-point
/// reassociation ever happens. The setting is a process-wide tuning knob,
/// not part of any model's semantics, which is why it lives in a global
/// rather than threading through every call site.
///
/// ```
/// use flora::tensor::{Matrix, Parallelism};
///
/// // install() puts the budget into effect process-wide and (for the
/// // pool driver) makes sure budget-1 workers are running
/// Parallelism::new(2).install();
/// assert_eq!(Parallelism::current().threads(), 2);
///
/// let a = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
/// let b = Matrix::from_vec(2, 1, vec![3.0, 4.0]);
/// assert_eq!(a.matmul(&b).data, vec![11.0]);
///
/// // back to serial: the pool workers stay parked (no teardown cost,
/// // no further fan-out) until a bigger budget is installed again
/// Parallelism::single().install();
/// assert_eq!(Parallelism::current().threads(), 1);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    threads: usize,
    driver: KernelDriver,
}

impl Parallelism {
    /// A budget of `threads` worker threads (clamped to >= 1) on the
    /// default pool driver.
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1), driver: KernelDriver::Pool }
    }

    /// The serial default.
    pub fn single() -> Self {
        Self::new(1)
    }

    /// A budget of `threads` on the retained `std::thread::scope`
    /// per-call driver — the pre-pool (PR-4) code path, kept as the A/B
    /// benchmark baseline and as the pool's bit-exactness oracle.
    pub fn scoped(threads: usize) -> Self {
        Self { threads: threads.max(1), driver: KernelDriver::Scope }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn driver(&self) -> KernelDriver {
        self.driver
    }

    /// Install this budget as the process-wide kernel setting and, on the
    /// pool driver with `threads > 1`, eagerly make sure the pool has
    /// `threads - 1` workers running (the calling thread is the remaining
    /// band owner). `Trainer::with_runtime` funnels every training run
    /// through here, so the spawn cost is paid at trainer construction,
    /// never inside a timed step.
    ///
    /// Semantics when the pool is already running (**resize, not
    /// rebuild**): the per-call fan-out follows the newly installed
    /// budget immediately; the pool itself only *grows* — installing a
    /// larger budget spawns the missing workers, installing a smaller
    /// one parks the surplus on the idle job queue (a blocked `recv`,
    /// no CPU cost) rather than tearing threads down. Repeated
    /// trainer lifecycles therefore reuse one warm pool instead of
    /// re-spawning threads per run — see `pool_workers` and the
    /// pool-reuse regression test in `rust/tests/integration.rs`.
    pub fn install(self) {
        PARALLELISM.store(self.threads, Ordering::Relaxed);
        DRIVER.store(
            match self.driver {
                KernelDriver::Pool => DRIVER_POOL,
                KernelDriver::Scope => DRIVER_SCOPE,
            },
            Ordering::Relaxed,
        );
        if self.driver == KernelDriver::Pool && self.threads > 1 {
            ensure_pool(self.threads - 1);
        }
    }

    /// The currently-installed budget.
    pub fn current() -> Self {
        let driver = match DRIVER.load(Ordering::Relaxed) {
            DRIVER_SCOPE => KernelDriver::Scope,
            _ => KernelDriver::Pool,
        };
        Self {
            threads: PARALLELISM.load(Ordering::Relaxed).max(1),
            driver,
        }
    }

    /// Number of live pool workers (0 when the pool has never started or
    /// was shut down). Observability hook for the pool-reuse regression
    /// test: two trainer lifecycles must not grow this past
    /// `max_budget - 1`.
    pub fn pool_workers() -> usize {
        match POOL.lock() {
            Ok(g) => g.as_ref().map_or(0, |p| p.workers.len()),
            Err(p) => p.into_inner().as_ref().map_or(0, |p| p.workers.len()),
        }
    }

    /// Stop and join every pool worker. Only needed by tests that assert
    /// clean teardown/restart — a long-lived process keeps the warm pool
    /// for its whole life, and process exit reaps the (parked) workers
    /// without joining. The next parallel kernel call or `install`
    /// lazily restarts the pool.
    pub fn shutdown_pool() {
        let pool = match POOL.lock() {
            Ok(mut g) => g.take(),
            Err(p) => p.into_inner().take(),
        };
        if let Some(pool) = pool {
            drop(pool.sender); // disconnects every worker's recv()
            for h in pool.workers {
                let _ = h.join();
            }
        }
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Self::single()
    }
}

/// Ceiling on the total thread fan-out one process may configure:
/// `workers × parallelism` (data-parallel worker tasks times the kernel
/// band budget each may use) must stay within this. The config/CLI
/// layers reject violations loudly BEFORE any pool growth happens —
/// the pool is grow-only, so an absurd budget would otherwise pin
/// threads for the process lifetime.
pub const POOL_BUDGET: usize = 64;

// ---------------------------------------------------------------------
// the persistent worker pool
// ---------------------------------------------------------------------

thread_local! {
    /// Set inside pool workers so a kernel that (transitively) calls
    /// `par_rows` from a band body degrades to serial instead of
    /// deadlocking on its own queue. No current kernel nests, but the
    /// guard makes that a perf question rather than a correctness one.
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Completion latch for one `par_rows` call: counts outstanding band
/// jobs; `wait` blocks until every one has finished (normally or by
/// panic).
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    remaining: usize,
    panicked: bool,
}

impl Latch {
    fn new(remaining: usize) -> Self {
        Self {
            state: Mutex::new(LatchState { remaining, panicked: false }),
            cv: Condvar::new(),
        }
    }

    fn complete(&self, panicked: bool) {
        let mut st = match self.state.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        st.remaining -= 1;
        st.panicked |= panicked;
        if st.remaining == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every job completed; true if any band panicked.
    fn wait(&self) -> bool {
        let mut st = match self.state.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        while st.remaining > 0 {
            st = match self.cv.wait(st) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
        st.panicked
    }
}

/// One lifetime-erased band job.
///
/// Safety contract: `par_rows` does not return (not even by unwinding)
/// until the job's latch has counted every band down, so the raw kernel,
/// band, and latch pointers outlive every worker access; bands are
/// disjoint `split_at_mut` slices, so no two jobs alias.
struct Job {
    /// Monomorphized trampoline that re-types `ctx` back to the caller's
    /// kernel closure — sidesteps `dyn` trait-object lifetime defaults.
    call: unsafe fn(*const (), &mut [f32], usize, usize),
    ctx: *const (),
    band: *mut f32,
    band_len: usize,
    first: usize,
    rows: usize,
    latch: *const Latch,
}

// Safety: see the Job doc — all pointees are kept alive by the
// wait-before-return invariant of `par_rows`, the band is an exclusive
// disjoint slice, and `ctx` points at a `Sync` closure.
unsafe impl Send for Job {}

impl Job {
    fn run(self) {
        // a panicking band must still count down (otherwise the caller
        // deadlocks and the borrow-liveness argument collapses); the
        // panic is re-raised on the calling thread by par_rows_pool
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // Safety: the par_rows wait-before-return invariant
            unsafe {
                let band = std::slice::from_raw_parts_mut(self.band, self.band_len);
                (self.call)(self.ctx, band, self.first, self.rows);
            }
        }));
        // Safety: the latch lives on the caller's stack until wait() sees 0
        unsafe { (*self.latch).complete(result.is_err()) };
    }
}

unsafe fn call_kernel<F>(ctx: *const (), band: &mut [f32], first: usize, rows: usize)
where
    F: Fn(&mut [f32], usize, usize) + Sync,
{
    let kernel = &*(ctx as *const F);
    kernel(band, first, rows);
}

struct Pool {
    sender: Sender<Job>,
    /// Shared by every worker (the textbook `Mutex<Receiver>` fan-out);
    /// kept here so `ensure_pool` can grow the pool onto the same queue.
    receiver: Arc<Mutex<Receiver<Job>>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

static POOL: Mutex<Option<Pool>> = Mutex::new(None);

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>) {
    IS_POOL_WORKER.with(|w| w.set(true));
    loop {
        // hold the queue lock only for the blocking recv; job bodies run
        // unlocked so workers drain bands concurrently
        let job = {
            let guard = match rx.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            guard.recv()
        };
        match job {
            Ok(job) => job.run(),
            Err(_) => return, // sender dropped: pool shut down
        }
    }
}

/// Make sure the pool exists and has at least `workers` threads; grows
/// (never shrinks) so the warm pool is reused across trainer lifecycles.
/// Returns a cheap clone of the job sender.
fn ensure_pool(workers: usize) -> Sender<Job> {
    let mut guard = match POOL.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    let pool = guard.get_or_insert_with(|| {
        let (sender, receiver) = channel::<Job>();
        Pool {
            sender,
            receiver: Arc::new(Mutex::new(receiver)),
            workers: Vec::new(),
        }
    });
    while pool.workers.len() < workers {
        let rx = Arc::clone(&pool.receiver);
        let idx = pool.workers.len();
        let handle = std::thread::Builder::new()
            .name(format!("flora-kernel-{idx}"))
            .spawn(move || worker_loop(rx))
            .expect("spawning kernel pool worker");
        pool.workers.push(handle);
    }
    pool.sender.clone()
}

/// Split `out` (owning `rows` rows of `row_width` f32s) into per-thread
/// row bands and run `kernel(band, first_row, n_rows)` on each. Serial
/// when the installed budget is 1, the work is below [`PAR_MIN_FLOPS`]
/// multiplies, there is only one row, or the caller is itself a pool
/// worker. Band splits depend only on the thread budget — never on the
/// driver — and per-element summation order does not depend on bands at
/// all, so every (budget, driver) combination is bit-identical.
pub(crate) fn par_rows<F>(out: &mut [f32], rows: usize, row_width: usize, flops: usize, kernel: F)
where
    F: Fn(&mut [f32], usize, usize) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_width);
    let cfg = Parallelism::current();
    let nested = IS_POOL_WORKER.with(|w| w.get());
    let threads = if flops < PAR_MIN_FLOPS || nested {
        1
    } else {
        cfg.threads().min(rows).max(1)
    };
    if threads <= 1 {
        kernel(out, 0, rows);
        return;
    }
    match cfg.driver() {
        KernelDriver::Scope => par_rows_scope(out, rows, row_width, threads, &kernel),
        KernelDriver::Pool => par_rows_pool(out, rows, row_width, threads, &kernel),
    }
}

/// The PR-4 driver: spawn one scoped OS thread per band, implicitly join
/// at scope exit. Retained verbatim as the pool's oracle and the
/// `--runtime scope` benchmark baseline.
fn par_rows_scope<F>(out: &mut [f32], rows: usize, row_width: usize, threads: usize, kernel: &F)
where
    F: Fn(&mut [f32], usize, usize) + Sync,
{
    let chunk = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut row0 = 0usize;
        while row0 < rows {
            let take = chunk.min(rows - row0);
            let (band, tail) = std::mem::take(&mut rest).split_at_mut(take * row_width);
            rest = tail;
            let first = row0;
            scope.spawn(move || kernel(band, first, take));
            row0 += take;
        }
    });
}

/// The pool driver: identical band split to the scope driver, but bands
/// after the first are enqueued on the persistent pool while the calling
/// thread computes band 0 itself; a latch then joins the call.
fn par_rows_pool<F>(out: &mut [f32], rows: usize, row_width: usize, threads: usize, kernel: &F)
where
    F: Fn(&mut [f32], usize, usize) + Sync,
{
    let chunk = rows.div_ceil(threads);
    let own = chunk.min(rows);
    let (own_band, mut rest) = out.split_at_mut(own * row_width);
    // collect the worker bands up front so the latch knows its count
    let mut bands: Vec<(&mut [f32], usize, usize)> = Vec::new();
    let mut row0 = own;
    while row0 < rows {
        let take = chunk.min(rows - row0);
        let (band, tail) = std::mem::take(&mut rest).split_at_mut(take * row_width);
        rest = tail;
        bands.push((band, row0, take));
        row0 += take;
    }
    if bands.is_empty() {
        kernel(own_band, 0, own);
        return;
    }

    let latch = Latch::new(bands.len());
    let sender = ensure_pool(threads - 1);
    for (band, first, take) in bands {
        let job = Job {
            call: call_kernel::<F>,
            ctx: kernel as *const F as *const (),
            band: band.as_mut_ptr(),
            band_len: band.len(),
            first,
            rows: take,
            latch: &latch as *const Latch,
        };
        if let Err(err) = sender.send(job) {
            // pool shut down between ensure and send: run the band here
            err.0.run();
        }
    }

    // even if our own band panics below, the guard's Drop waits for the
    // outstanding jobs first — the raw pointers in flight must not
    // outlive this frame
    struct WaitGuard<'a>(&'a Latch);
    impl Drop for WaitGuard<'_> {
        fn drop(&mut self) {
            self.0.wait();
        }
    }
    let guard = WaitGuard(&latch);
    kernel(own_band, 0, own);
    drop(guard);

    let panicked = {
        let st = match latch.state.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        st.panicked
    };
    if panicked {
        panic!("a parallel kernel band panicked on a pool worker");
    }
}

// ---------------------------------------------------------------------
// task fan-out (the dp worker tier rides the same pool)
// ---------------------------------------------------------------------

unsafe fn call_task<F>(ctx: *const (), _band: &mut [f32], index: usize, _rows: usize)
where
    F: Fn(usize) + Sync,
{
    let task = &*(ctx as *const F);
    task(index);
}

/// Run `task(0) .. task(n-1)` concurrently on the persistent pool and
/// return once every index has completed exactly once. This is the
/// fan-out primitive under the data-parallel worker tier
/// (`runtime::dp`): each index is one dp worker's slice of a step.
///
/// Task 0 runs on the calling thread while 1..n are enqueued as pool
/// jobs (reusing [`Job`] with an empty band — the trampoline carries
/// the task index in the `first` slot). Serial (a plain in-order loop)
/// when `n <= 1` or the caller is itself a pool worker.
///
/// Scheduling is intentionally allowed to vary run-to-run; nothing a
/// task computes may depend on *which thread* ran it. The dp tier keeps
/// its bit-identity contract because each task writes only its own
/// result slot and all cross-task reduction happens in fixed index
/// order on the calling thread afterwards.
///
/// No deadlock with nested kernels: a task's own `par_rows` calls may
/// enqueue band jobs behind busy workers, but pool workers never wait
/// on the pool (their nested kernels degrade to serial via
/// `IS_POOL_WORKER`), so every queued job is eventually drained.
pub fn pool_tasks<F>(n: usize, task: F)
where
    F: Fn(usize) + Sync,
{
    if n <= 1 || IS_POOL_WORKER.with(|w| w.get()) {
        for i in 0..n {
            task(i);
        }
        return;
    }

    let latch = Latch::new(n - 1);
    let sender = ensure_pool(n - 1);
    for i in 1..n {
        let job = Job {
            call: call_task::<F>,
            ctx: &task as *const F as *const (),
            band: std::ptr::NonNull::<f32>::dangling().as_ptr(),
            band_len: 0,
            first: i,
            rows: 0,
            latch: &latch as *const Latch,
        };
        if let Err(err) = sender.send(job) {
            // pool shut down between ensure and send: run the task here
            err.0.run();
        }
    }

    // mirror par_rows_pool: even if task 0 panics, wait for in-flight
    // jobs before the frame (and the raw latch/ctx pointers) dies
    struct WaitGuard<'a>(&'a Latch);
    impl Drop for WaitGuard<'_> {
        fn drop(&mut self) {
            self.0.wait();
        }
    }
    let guard = WaitGuard(&latch);
    task(0);
    drop(guard);

    let panicked = {
        let st = match latch.state.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        st.panicked
    };
    if panicked {
        panic!("a data-parallel worker task panicked on a pool worker");
    }
}

/// Panel-local fixed-order reduction: `dst[e] += Σ_s srcs[s][e]` with
/// every element's additions in ascending source order. Row bands may
/// run on the pool, but banding never changes an element's summation
/// order (each element belongs to exactly one band and accumulates
/// source-by-source with one f32 accumulator), so the reduction is
/// bit-identical at every thread budget — the same argument as the
/// GEMM kernels'. This is the dp tier's all-reduce core.
pub(crate) fn reduce_rows_in_order(
    dst: &mut [f32],
    rows: usize,
    row_width: usize,
    srcs: &[&[f32]],
) {
    debug_assert_eq!(dst.len(), rows * row_width);
    for s in srcs {
        debug_assert_eq!(s.len(), dst.len());
    }
    let flops = rows * row_width * srcs.len();
    par_rows(dst, rows, row_width, flops, |band, first, n| {
        let lo = first * row_width;
        let hi = lo + n * row_width;
        for src in srcs {
            for (d, s) in band.iter_mut().zip(&src[lo..hi]) {
                *d += *s;
            }
        }
    });
}

// ---------------------------------------------------------------------
// the pack scratch (BLIS-style operand panel packing)
// ---------------------------------------------------------------------

thread_local! {
    /// Per-thread packed-panel scratch for the blocked kernels. Grow-only
    /// and reused across every kernel call on this thread (band bodies run
    /// on exactly one thread, so each pool worker and the caller each own
    /// one buffer — no sharing, no locks). Packing is a pure memory copy:
    /// it never changes which terms an output element sums or in what
    /// order, so the packed kernels stay bit-identical to the naive ones.
    static PACK: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Counts pack-scratch *growth* events across all threads. After warmup
/// (one growth per thread per high-water panel size) this stays flat —
/// the steady-state hot loop never allocates. The two-trainer-lifecycle
/// regression test in `rust/tests/integration.rs` pins this.
static PACK_ALLOCS: AtomicUsize = AtomicUsize::new(0);

/// Number of times any thread's pack scratch had to grow. Observability
/// hook for the scratch-reuse regression test; not a perf metric.
pub fn pack_scratch_allocs() -> usize {
    PACK_ALLOCS.load(Ordering::Relaxed)
}

/// Run `f` with this thread's pack scratch, grown (never shrunk) to at
/// least `min_len` f32s. The slice passed to `f` is exactly `min_len`
/// long; its contents are whatever the previous pack left (callers fully
/// overwrite the region they read).
fn with_pack_scratch<R>(min_len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    PACK.with(|cell| {
        let mut buf = cell.borrow_mut();
        if buf.len() < min_len {
            buf.resize(min_len, 0.0);
            PACK_ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        f(&mut buf[..min_len])
    })
}

// ---------------------------------------------------------------------
// serial blocked kernels (the per-band bodies)
// ---------------------------------------------------------------------

/// `C += A @ B` on a band of `n` output rows: blocked ikj with the K×J
/// panel of B **packed** into the thread-local scratch. `a` is the
/// band's rows of A (`n x k`), `b` the full B (`k x m`), `c` the band's
/// rows of C (`n x m`, pre-zeroed by the caller).
///
/// Packing copies each block row of B into a contiguous `kw x jw` panel
/// once per (j-tile, k-block) and reuses it across every band row, so
/// the inner loop is stride-1 on both operands (the classic BLIS win).
/// The k-loop then advances four packed rows per pass over the C tile:
/// each `C[i][j]` still receives its k-terms one at a time in ascending
/// k (four chained `+=` on one accumulator), so results stay
/// bit-identical to the naive ikj loop — packing only moves bytes,
/// never a summation.
pub(crate) fn matmul_band(c: &mut [f32], a: &[f32], b: &[f32], n: usize, k: usize, m: usize) {
    for j0 in (0..m).step_by(J_BLOCK) {
        let j1 = (j0 + J_BLOCK).min(m);
        let jw = j1 - j0;
        for k0 in (0..k).step_by(K_BLOCK) {
            let k1 = (k0 + K_BLOCK).min(k);
            let kw = k1 - k0;
            with_pack_scratch(kw * jw, |pack| {
                for kk in 0..kw {
                    pack[kk * jw..(kk + 1) * jw]
                        .copy_from_slice(&b[(k0 + kk) * m + j0..(k0 + kk) * m + j1]);
                }
                for i in 0..n {
                    let arow = &a[i * k + k0..i * k + k1];
                    let ctile = &mut c[i * m + j0..i * m + j1];
                    let mut kk = 0usize;
                    while kk + 4 <= kw {
                        let (a0, a1) = (arow[kk], arow[kk + 1]);
                        let (a2, a3) = (arow[kk + 2], arow[kk + 3]);
                        let b0 = &pack[kk * jw..(kk + 1) * jw];
                        let b1 = &pack[(kk + 1) * jw..(kk + 2) * jw];
                        let b2 = &pack[(kk + 2) * jw..(kk + 3) * jw];
                        let b3 = &pack[(kk + 3) * jw..(kk + 4) * jw];
                        for ((((o, &x0), &x1), &x2), &x3) in
                            ctile.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3)
                        {
                            // ascending k, one rounding per term — naive order
                            let mut acc = *o;
                            acc += a0 * x0;
                            acc += a1 * x1;
                            acc += a2 * x2;
                            acc += a3 * x3;
                            *o = acc;
                        }
                        kk += 4;
                    }
                    while kk < kw {
                        let aik = arow[kk];
                        let brow = &pack[kk * jw..(kk + 1) * jw];
                        for (o, &bkj) in ctile.iter_mut().zip(brow.iter()) {
                            *o += aik * bkj;
                        }
                        kk += 1;
                    }
                }
            });
        }
    }
}

/// `C = alpha * (A @ B^T)` on a band of `n` output rows: dot-product
/// kernel with a B-row tile kept hot across the band. `a` is the band's
/// rows of A (`n x k`), `b` the full B (`m x k`), `c` the band (`n x m`).
/// `alpha` multiplies each finished dot (the attention score scale);
/// pass 1.0 for a plain product.
///
/// Four output columns advance together: four *independent* single-
/// accumulator dots over the same contiguous `a` row, reading four
/// **packed** rows of B — a contiguous `jw x kw` panel copied into the
/// thread-local scratch once per (j-tile, k-chunk) and reused across the
/// band. Long contractions are chunked by `J_BLOCK` along k; partial dots
/// chain through C (an exact f32 store/load round-trip, no rounding), and
/// `alpha` multiplies each *finished* dot in one pass per j-tile — the
/// identical `acc * alpha` the naive kernel performs. No element's
/// ascending-k summation order ever changes, so bit-identity with
/// `matmul_nt_naive` holds.
pub(crate) fn matmul_nt_band(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
    alpha: f32,
) {
    if k == 0 {
        // naive writes `acc * alpha` with acc = 0.0 even for an empty
        // contraction — preserve that (alpha may be NaN or negative)
        for o in c[..n * m].iter_mut() {
            *o = 0.0 * alpha;
        }
        return;
    }
    for j0 in (0..m).step_by(K_BLOCK) {
        let j1 = (j0 + K_BLOCK).min(m);
        let jw = j1 - j0;
        for k0 in (0..k).step_by(J_BLOCK) {
            let k1 = (k0 + J_BLOCK).min(k);
            let kw = k1 - k0;
            with_pack_scratch(jw * kw, |pack| {
                for jj in 0..jw {
                    pack[jj * kw..(jj + 1) * kw]
                        .copy_from_slice(&b[(j0 + jj) * k + k0..(j0 + jj) * k + k1]);
                }
                for i in 0..n {
                    let arow = &a[i * k + k0..i * k + k1];
                    let crow = &mut c[i * m + j0..i * m + j1];
                    let mut j = 0usize;
                    while j + 4 <= jw {
                        let b0 = &pack[j * kw..(j + 1) * kw];
                        let b1 = &pack[(j + 1) * kw..(j + 2) * kw];
                        let b2 = &pack[(j + 2) * kw..(j + 3) * kw];
                        let b3 = &pack[(j + 3) * kw..(j + 4) * kw];
                        let (mut acc0, mut acc1, mut acc2, mut acc3) = if k0 == 0 {
                            (0.0f32, 0.0f32, 0.0f32, 0.0f32)
                        } else {
                            (crow[j], crow[j + 1], crow[j + 2], crow[j + 3])
                        };
                        for ((((&x, &y0), &y1), &y2), &y3) in
                            arow.iter().zip(b0).zip(b1).zip(b2).zip(b3)
                        {
                            acc0 += x * y0;
                            acc1 += x * y1;
                            acc2 += x * y2;
                            acc3 += x * y3;
                        }
                        crow[j] = acc0;
                        crow[j + 1] = acc1;
                        crow[j + 2] = acc2;
                        crow[j + 3] = acc3;
                        j += 4;
                    }
                    while j < jw {
                        let brow = &pack[j * kw..(j + 1) * kw];
                        let mut acc = if k0 == 0 { 0.0f32 } else { crow[j] };
                        for (x, y) in arow.iter().zip(brow.iter()) {
                            acc += x * y;
                        }
                        crow[j] = acc;
                        j += 1;
                    }
                }
            });
        }
        // one alpha pass per j-tile, over the finished raw dots
        for i in 0..n {
            for o in c[i * m + j0..i * m + j1].iter_mut() {
                *o *= alpha;
            }
        }
    }
}

/// `C += A^T @ B` on a band of C rows `[i0, i0+n)` (columns of A): for
/// every contraction row `k`, the band's C rows accumulate
/// `A[k][i] * B[k][j]` in ascending `k` order. `a` is the FULL A
/// (`rows x acols`), `b` the full B (`rows x m`), `c` the band
/// (`n x m`, pre-zeroed).
///
/// The strided operand here is A (read down a column), so the packing
/// targets A: each `K_BLOCK`-row contraction chunk's band columns are
/// copied into a contiguous `rw x iw` scratch panel, turning the strided
/// column walks into dense panel reads. Two contraction rows advance per
/// pass (chained `+=`, ascending k, chunk partials chained through C via
/// an exact f32 store/load round-trip, so bit-identity with
/// `matmul_tn_naive` holds) — the inner loop stays a contiguous
/// independent-lane axpy over B rows.
#[allow(clippy::too_many_arguments)]
pub(crate) fn matmul_tn_band(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    rows: usize,
    acols: usize,
    m: usize,
    i0: usize,
    n: usize,
) {
    for r0 in (0..rows).step_by(K_BLOCK) {
        let r1 = (r0 + K_BLOCK).min(rows);
        let rw = r1 - r0;
        for it in (0..n).step_by(K_BLOCK) {
            let i1 = (it + K_BLOCK).min(n);
            let iw = i1 - it;
            with_pack_scratch(rw * iw, |pack| {
                for rr in 0..rw {
                    pack[rr * iw..(rr + 1) * iw].copy_from_slice(
                        &a[(r0 + rr) * acols + i0 + it..(r0 + rr) * acols + i0 + i1],
                    );
                }
                for j0 in (0..m).step_by(J_BLOCK) {
                    let j1 = (j0 + J_BLOCK).min(m);
                    for i in it..i1 {
                        let crow = &mut c[i * m + j0..i * m + j1];
                        let mut rr = 0usize;
                        while rr + 2 <= rw {
                            let a0 = pack[rr * iw + (i - it)];
                            let a1 = pack[(rr + 1) * iw + (i - it)];
                            let br0 = &b[(r0 + rr) * m + j0..(r0 + rr) * m + j1];
                            let br1 = &b[(r0 + rr + 1) * m + j0..(r0 + rr + 1) * m + j1];
                            for ((o, &x0), &x1) in crow.iter_mut().zip(br0).zip(br1) {
                                let mut acc = *o;
                                acc += a0 * x0;
                                acc += a1 * x1;
                                *o = acc;
                            }
                            rr += 2;
                        }
                        if rr < rw {
                            let aki = pack[rr * iw + (i - it)];
                            let brow = &b[(r0 + rr) * m + j0..(r0 + rr) * m + j1];
                            for (o, &bkj) in crow.iter_mut().zip(brow.iter()) {
                                *o += aki * bkj;
                            }
                        }
                    }
                }
            });
        }
    }
}

// ---------------------------------------------------------------------
// parallel entry points (row-banded over the output)
// ---------------------------------------------------------------------

/// `C = A @ B` into a pre-zeroed `c` (`n x m`), row-parallel.
pub(crate) fn matmul_into(c: &mut [f32], a: &[f32], b: &[f32], n: usize, k: usize, m: usize) {
    par_rows(c, n, m, n * k * m, |band, first, rows| {
        matmul_band(band, &a[first * k..(first + rows) * k], b, rows, k, m);
    });
}

/// `C = alpha * (A @ B^T)` into `c` (`n x m`), row-parallel.
pub(crate) fn matmul_nt_into(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
    alpha: f32,
) {
    par_rows(c, n, m, n * k * m, |band, first, rows| {
        matmul_nt_band(band, &a[first * k..(first + rows) * k], b, rows, k, m, alpha);
    });
}

/// `C = A^T @ B` into a pre-zeroed `c` (`acols x m`), parallel over C's
/// rows (= A's columns); every thread streams the full A and B.
pub(crate) fn matmul_tn_into(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    rows: usize,
    acols: usize,
    m: usize,
) {
    par_rows(c, acols, m, rows * acols * m, |band, first, n| {
        matmul_tn_band(band, a, b, rows, acols, m, first, n);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests that install a non-default Parallelism or poke the global
    /// pool serialize on this lock so concurrent lib tests can't observe
    /// each other's settings. (Kernel RESULTS are bit-identical at every
    /// setting, so only the `current()`/`pool_workers()` assertions need
    /// the discipline.)
    static INSTALL_LOCK: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        match INSTALL_LOCK.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    #[test]
    fn parallelism_clamps() {
        assert_eq!(Parallelism::new(0).threads(), 1);
        assert_eq!(Parallelism::default(), Parallelism::single());
        assert_eq!(Parallelism::scoped(0).threads(), 1);
        assert_eq!(Parallelism::scoped(3).driver(), KernelDriver::Scope);
        assert_eq!(Parallelism::new(3).driver(), KernelDriver::Pool);
    }

    fn stamp_rows(driver: Parallelism, rows: usize, width: usize) -> Vec<f32> {
        let before = Parallelism::current();
        driver.install();
        let mut out = vec![-1.0f32; rows * width];
        par_rows(&mut out, rows, width, PAR_MIN_FLOPS * 2, |band, first, n| {
            for i in 0..n {
                for x in band[i * width..(i + 1) * width].iter_mut() {
                    *x = (first + i) as f32;
                }
            }
        });
        before.install();
        out
    }

    #[test]
    fn install_and_par_rows_cover_every_row_once() {
        let _g = lock();
        for driver in [Parallelism::new(4), Parallelism::scoped(4)] {
            let (rows, width) = (17usize, 8usize);
            let out = stamp_rows(driver, rows, width);
            for r in 0..rows {
                let row = &out[r * width..(r + 1) * width];
                assert!(row.iter().all(|&x| x == r as f32), "{driver:?} row {r}");
            }
        }
    }

    #[test]
    fn pool_is_reused_and_grows_monotonically() {
        let _g = lock();
        Parallelism::shutdown_pool();
        assert_eq!(Parallelism::pool_workers(), 0);
        // install starts budget-1 workers eagerly
        Parallelism::new(3).install();
        assert_eq!(Parallelism::pool_workers(), 2);
        // repeated installs at the same or smaller budget REUSE the pool
        Parallelism::new(3).install();
        Parallelism::new(2).install();
        assert_eq!(Parallelism::pool_workers(), 2);
        // a larger budget grows it
        Parallelism::new(4).install();
        assert_eq!(Parallelism::pool_workers(), 3);
        // many parallel calls never add workers
        for _ in 0..8 {
            let _ = stamp_rows(Parallelism::new(4), 23, 8);
        }
        assert_eq!(Parallelism::pool_workers(), 3);
        // teardown + restart is clean (drop to a serial budget first so
        // stamp_rows' save/restore cannot eagerly regrow the pool)
        Parallelism::single().install();
        Parallelism::shutdown_pool();
        assert_eq!(Parallelism::pool_workers(), 0);
        let out = stamp_rows(Parallelism::new(2), 9, 4);
        assert!(out.iter().all(|&x| x >= 0.0), "lazy restart failed");
        assert_eq!(Parallelism::pool_workers(), 1);
        Parallelism::single().install();
    }

    #[test]
    fn pool_and_scope_drivers_stamp_identically() {
        let _g = lock();
        let (rows, width) = (31usize, 5usize);
        let a = stamp_rows(Parallelism::new(4), rows, width);
        let b = stamp_rows(Parallelism::scoped(4), rows, width);
        assert_eq!(a, b);
    }

    #[test]
    fn pool_band_panic_propagates_without_deadlock() {
        let _g = lock();
        let before = Parallelism::current();
        Parallelism::new(4).install();
        let caught = std::panic::catch_unwind(|| {
            let (rows, width) = (16usize, 4usize);
            let mut out = vec![0.0f32; rows * width];
            par_rows(&mut out, rows, width, PAR_MIN_FLOPS * 2, |_, first, _| {
                if first > 0 {
                    panic!("boom in band {first}");
                }
            });
        });
        before.install();
        assert!(caught.is_err(), "worker panic must surface on the caller");
        // the pool survives a panicked job and still runs work
        let out = stamp_rows(Parallelism::new(4), 12, 3);
        for r in 0..12 {
            assert!(out[r * 3..(r + 1) * 3].iter().all(|&x| x == r as f32));
        }
    }

    #[test]
    fn pool_tasks_runs_every_index_exactly_once() {
        let _g = lock();
        for n in [1usize, 2, 3, 4] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool_tasks(n, |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "n={n} task {i}");
            }
        }
    }

    #[test]
    fn pool_tasks_nested_kernels_complete() {
        let _g = lock();
        let before = Parallelism::current();
        Parallelism::new(2).install();
        // each task runs a pool-eligible kernel of its own; tasks on pool
        // workers degrade those to serial, task 0 may fan out — results
        // must be identical either way
        let outs: Vec<Mutex<Vec<f32>>> = (0..3).map(|_| Mutex::new(Vec::new())).collect();
        pool_tasks(3, |i| {
            let (rows, width) = (16usize, 4usize);
            let mut out = vec![0.0f32; rows * width];
            par_rows(&mut out, rows, width, PAR_MIN_FLOPS * 2, |band, first, n| {
                for r in 0..n {
                    for x in band[r * width..(r + 1) * width].iter_mut() {
                        *x = (first + r) as f32;
                    }
                }
            });
            *outs[i].lock().unwrap() = out;
        });
        before.install();
        let first = outs[0].lock().unwrap().clone();
        assert!(!first.is_empty());
        for o in &outs {
            assert_eq!(*o.lock().unwrap(), first);
        }
    }

    #[test]
    fn pool_tasks_panic_propagates_without_deadlock() {
        let _g = lock();
        let caught = std::panic::catch_unwind(|| {
            pool_tasks(3, |i| {
                if i == 2 {
                    panic!("boom in task {i}");
                }
            });
        });
        assert!(caught.is_err(), "task panic must surface on the caller");
    }

    #[test]
    fn reduce_rows_in_order_is_serial_left_to_right_sum_at_any_budget() {
        let _g = lock();
        let (rows, width, nsrc) = (13usize, 7, 5);
        let srcs: Vec<Vec<f32>> = (0..nsrc)
            .map(|s| {
                (0..rows * width)
                    .map(|e| ((s * 31 + e * 17) % 97) as f32 * 0.13 - 6.0)
                    .collect()
            })
            .collect();
        let refs: Vec<&[f32]> = srcs.iter().map(|s| s.as_slice()).collect();
        // oracle: plain in-order loop, one accumulator per element
        let mut oracle = vec![0.0f32; rows * width];
        for s in &srcs {
            for (d, x) in oracle.iter_mut().zip(s) {
                *d += *x;
            }
        }
        let before = Parallelism::current();
        for budget in [1usize, 2, 4] {
            Parallelism::new(budget).install();
            let mut dst = vec![0.0f32; rows * width];
            reduce_rows_in_order(&mut dst, rows, width, &refs);
            let a: Vec<u32> = dst.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = oracle.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b, "budget {budget}");
        }
        before.install();
    }

    #[test]
    fn reduce_rows_in_order_preserves_non_finite() {
        let _g = lock();
        let mut dst = vec![0.0f32; 4];
        let a = [1.0f32, f32::NAN, f32::INFINITY, -1.0];
        let b = [2.0f32, 1.0, f32::NEG_INFINITY, 3.0];
        reduce_rows_in_order(&mut dst, 1, 4, &[&a, &b]);
        assert_eq!(dst[0], 3.0);
        assert!(dst[1].is_nan(), "NaN must not be laundered by the reduce");
        assert!(dst[2].is_nan(), "inf + -inf is NaN");
        assert_eq!(dst[3], 2.0);
    }
}
