//! Row-major f32 matrix with the small op set the pilot and rp modules need.

use crate::util::rng::Rng;
use std::fmt;
use std::ops::{Add, Mul, Sub};

#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix[{}x{}]", self.rows, self.cols)
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    /// N(0, sigma^2) entries from the given RNG.
    pub fn gaussian(rows: usize, cols: usize, sigma: f32, rng: &mut Rng) -> Self {
        let mut m = Self::zeros(rows, cols);
        rng.fill_gaussian(&mut m.data, sigma);
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// C = A @ B via the cache-blocked, ikj-ordered kernel
    /// (`tensor::kernels`), row-parallel under the installed
    /// [`crate::tensor::Parallelism`]. Bit-identical to [`Self::matmul_naive`]
    /// for every block size and thread count: each output element
    /// accumulates its k-terms in the same ascending order.
    ///
    /// No zero-skip on `aik`: skipping would drop IEEE NaN/Inf propagation
    /// (0.0 * NaN is NaN) and silently launder non-finite gradients — see
    /// the `matmul_propagates_nan` regression test.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul {:?} x {:?}", self, b);
        let mut out = Matrix::zeros(self.rows, b.cols);
        super::kernels::matmul_into(
            &mut out.data, &self.data, &b.data, self.rows, self.cols, b.cols,
        );
        out
    }

    /// C = A @ B^T (the rp "compress" GEMM shape), blocked + row-parallel.
    /// Bit-identical to [`Self::matmul_nt_naive`].
    pub fn matmul_nt(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.cols, "matmul_nt {:?} x {:?}", self, b);
        let mut out = Matrix::zeros(self.rows, b.rows);
        super::kernels::matmul_nt_into(
            &mut out.data, &self.data, &b.data, self.rows, self.cols, b.rows, 1.0,
        );
        out
    }

    /// C = A^T @ B, blocked + parallel over C rows. Bit-identical to
    /// [`Self::matmul_tn_naive`]; like `matmul`, no zero-skip — NaN/Inf
    /// must propagate.
    pub fn matmul_tn(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.rows, b.rows, "matmul_tn {:?} x {:?}", self, b);
        let mut out = Matrix::zeros(self.cols, b.cols);
        super::kernels::matmul_tn_into(
            &mut out.data, &self.data, &b.data, self.rows, self.cols, b.cols,
        );
        out
    }

    /// The pre-refactor textbook ikj matmul, retained verbatim as the
    /// bit-exactness oracle for the blocked/parallel kernel (see the
    /// `prop_matmul_*` tests) and as the microbench baseline.
    pub fn matmul_naive(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul {:?} x {:?}", self, b);
        let mut out = Matrix::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = &mut out.data[i * b.cols..(i + 1) * b.cols];
            for (k, &aik) in arow.iter().enumerate() {
                let brow = &b.data[k * b.cols..(k + 1) * b.cols];
                for (o, &bkj) in orow.iter_mut().zip(brow.iter()) {
                    *o += aik * bkj;
                }
            }
        }
        out
    }

    /// Pre-refactor dot-product A @ B^T, retained as the oracle for
    /// [`Self::matmul_nt`].
    pub fn matmul_nt_naive(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.cols, "matmul_nt {:?} x {:?}", self, b);
        let mut out = Matrix::zeros(self.rows, b.rows);
        for i in 0..self.rows {
            let arow = self.row(i);
            for j in 0..b.rows {
                let brow = b.row(j);
                let mut acc = 0.0f32;
                for (x, y) in arow.iter().zip(brow.iter()) {
                    acc += x * y;
                }
                *out.at_mut(i, j) = acc;
            }
        }
        out
    }

    /// Pre-refactor A^T @ B, retained as the oracle for [`Self::matmul_tn`].
    pub fn matmul_tn_naive(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.rows, b.rows, "matmul_tn {:?} x {:?}", self, b);
        let mut out = Matrix::zeros(self.cols, b.cols);
        for k in 0..self.rows {
            let arow = self.row(k);
            let brow = b.row(k);
            for (i, &aki) in arow.iter().enumerate() {
                let orow = &mut out.data[i * b.cols..(i + 1) * b.cols];
                for (o, &bkj) in orow.iter_mut().zip(brow.iter()) {
                    *o += aki * bkj;
                }
            }
        }
        out
    }

    /// Horizontal concatenation `[A | B | ...]` (same row count). This is
    /// how the fused-QKV path packs `wq|wk|wv` into one `[d, 3d]` GEMM
    /// operand: column blocks of a row-major matrix contract
    /// independently, so `X @ concat_cols([Wq, Wk, Wv])` is bit-identical
    /// to the three separate products written side by side.
    pub fn concat_cols(parts: &[&Matrix]) -> Matrix {
        assert!(!parts.is_empty(), "concat_cols of nothing");
        let rows = parts[0].rows;
        assert!(
            parts.iter().all(|p| p.rows == rows),
            "concat_cols row mismatch: {:?}",
            parts.iter().map(|p| p.shape()).collect::<Vec<_>>()
        );
        let cols: usize = parts.iter().map(|p| p.cols).sum();
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..rows {
            let orow = &mut out.data[i * cols..(i + 1) * cols];
            let mut off = 0usize;
            for p in parts {
                orow[off..off + p.cols].copy_from_slice(p.row(i));
                off += p.cols;
            }
        }
        out
    }

    /// Inverse of [`Self::concat_cols`]: split into column blocks of the
    /// given widths (must sum to `self.cols`).
    pub fn split_cols(&self, widths: &[usize]) -> Vec<Matrix> {
        assert_eq!(
            widths.iter().sum::<usize>(),
            self.cols,
            "split_cols widths {widths:?} for {self:?}"
        );
        let mut outs: Vec<Matrix> =
            widths.iter().map(|&w| Matrix::zeros(self.rows, w)).collect();
        for i in 0..self.rows {
            let row = self.row(i);
            let mut off = 0usize;
            for (o, &w) in outs.iter_mut().zip(widths) {
                o.data[i * w..(i + 1) * w].copy_from_slice(&row[off..off + w]);
                off += w;
            }
        }
        outs
    }

    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self.at(j, i))
    }

    pub fn scale(&self, s: f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    /// Fixed-order sum of equally-shaped matrices — the dp tier's
    /// reduction core. Every element accumulates its terms in ascending
    /// `srcs` order with one f32 accumulator, independent of how the
    /// row bands land on the kernel pool, so the result is bit-identical
    /// at every thread budget and for every physical worker layout that
    /// produced the sources (see `tensor::kernels::reduce_rows_in_order`).
    pub fn reduce_sum(srcs: &[&Matrix]) -> Matrix {
        assert!(!srcs.is_empty(), "reduce_sum needs at least one source");
        let (rows, cols) = srcs[0].shape();
        for s in srcs {
            assert_eq!(s.shape(), (rows, cols), "reduce_sum shape mismatch");
        }
        let mut out = Matrix::zeros(rows, cols);
        let slices: Vec<&[f32]> = srcs.iter().map(|s| s.data.as_slice()).collect();
        super::kernels::reduce_rows_in_order(&mut out.data, rows, cols, &slices);
        out
    }

    /// self += other * s (fused update used by the pilot's SGD rules).
    pub fn add_scaled_inplace(&mut self, other: &Matrix, s: f32) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b * s;
        }
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise product.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(a, b)| a * b)
                .collect(),
        }
    }

    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Approximate equality for tests.
    pub fn allclose(&self, other: &Matrix, atol: f32) -> bool {
        self.shape() == other.shape()
            && self
                .data
                .iter()
                .zip(other.data.iter())
                .all(|(a, b)| (a - b).abs() <= atol)
    }
}

impl Add for &Matrix {
    type Output = Matrix;

    fn add(self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(a, b)| a + b)
                .collect(),
        }
    }
}

impl Sub for &Matrix {
    type Output = Matrix;

    fn sub(self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(a, b)| a - b)
                .collect(),
        }
    }
}

impl Mul<f32> for &Matrix {
    type Output = Matrix;

    fn mul(self, s: f32) -> Matrix {
        self.scale(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, v: &[f32]) -> Matrix {
        Matrix::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn reduce_sum_is_fixed_order_elementwise() {
        let a = m(2, 2, &[1., 2., 3., 4.]);
        let b = m(2, 2, &[10., 20., 30., 40.]);
        let c = m(2, 2, &[100., 200., 300., 400.]);
        let r = Matrix::reduce_sum(&[&a, &b, &c]);
        // oracle: explicit left-to-right accumulation
        let mut oracle = Matrix::zeros(2, 2);
        for src in [&a, &b, &c] {
            oracle.add_scaled_inplace(src, 1.0);
        }
        let rb: Vec<u32> = r.data.iter().map(|x| x.to_bits()).collect();
        let ob: Vec<u32> = oracle.data.iter().map(|x| x.to_bits()).collect();
        assert_eq!(rb, ob);
    }

    #[test]
    fn matmul_known_values() {
        let a = m(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = m(3, 2, &[7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_nt_equals_matmul_with_transpose() {
        let mut rng = Rng::new(0);
        let a = Matrix::gaussian(5, 7, 1.0, &mut rng);
        let b = Matrix::gaussian(3, 7, 1.0, &mut rng);
        let c1 = a.matmul_nt(&b);
        let c2 = a.matmul(&b.transpose());
        assert!(c1.allclose(&c2, 1e-5));
    }

    #[test]
    fn matmul_tn_equals_transpose_matmul() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(6, 4, 1.0, &mut rng);
        let b = Matrix::gaussian(6, 5, 1.0, &mut rng);
        let c1 = a.matmul_tn(&b);
        let c2 = a.transpose().matmul(&b);
        assert!(c1.allclose(&c2, 1e-5));
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Matrix::gaussian(4, 9, 1.0, &mut rng);
        assert!(a.transpose().transpose().allclose(&a, 0.0));
    }

    #[test]
    fn add_sub_scale() {
        let a = m(1, 3, &[1., 2., 3.]);
        let b = m(1, 3, &[4., 5., 6.]);
        assert_eq!((&a + &b).data, vec![5., 7., 9.]);
        assert_eq!((&b - &a).data, vec![3., 3., 3.]);
        assert_eq!((&a * 2.0).data, vec![2., 4., 6.]);
    }

    #[test]
    fn add_scaled_inplace_matches_ops() {
        let mut rng = Rng::new(3);
        let mut a = Matrix::gaussian(3, 3, 1.0, &mut rng);
        let b = Matrix::gaussian(3, 3, 1.0, &mut rng);
        let want = &a + &(&b * -0.5);
        a.add_scaled_inplace(&b, -0.5);
        assert!(a.allclose(&want, 1e-6));
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::new(4);
        let a = Matrix::gaussian(100, 100, 2.0, &mut rng);
        let mean: f32 = a.data.iter().sum::<f32>() / 10_000.0;
        let var: f32 =
            a.data.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / 10_000.0;
        assert!(mean.abs() < 0.1);
        assert!((var - 4.0).abs() < 0.3);
    }

    #[test]
    fn frobenius() {
        let a = m(2, 2, &[3., 0., 0., 4.]);
        assert!((a.frobenius_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    fn matmul_propagates_nan() {
        // regression: the old `aik == 0.0` skip dropped the 0*NaN product,
        // so a NaN gradient row vanished whenever the left factor had a
        // structural zero (e.g. a LoRA B at init). IEEE says 0*NaN = NaN.
        let a = m(1, 2, &[0.0, 1.0]);
        let b = m(2, 2, &[f32::NAN, f32::NAN, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert!(c.data.iter().all(|x| x.is_nan()), "{:?}", c.data);

        let at = m(2, 1, &[0.0, 1.0]); // same contraction through A^T
        let ct = at.matmul_tn(&b);
        assert!(ct.data.iter().all(|x| x.is_nan()), "{:?}", ct.data);
    }

    #[test]
    fn blocked_kernels_bit_match_naive() {
        // shapes straddling the kernel block sizes; the full randomized
        // sweep lives in tests/properties.rs
        let mut rng = Rng::new(9);
        for (n, k, m) in [(3usize, 5usize, 4usize), (70, 130, 65), (129, 64, 200)] {
            let a = Matrix::gaussian(n, k, 1.0, &mut rng);
            let b = Matrix::gaussian(k, m, 1.0, &mut rng);
            assert!(a.matmul(&b).allclose(&a.matmul_naive(&b), 0.0), "({n},{k},{m})");
            let bt = Matrix::gaussian(m, k, 1.0, &mut rng);
            assert!(
                a.matmul_nt(&bt).allclose(&a.matmul_nt_naive(&bt), 0.0),
                "nt ({n},{k},{m})"
            );
            let b2 = Matrix::gaussian(n, m, 1.0, &mut rng);
            assert!(
                a.matmul_tn(&b2).allclose(&a.matmul_tn_naive(&b2), 0.0),
                "tn ({n},{k},{m})"
            );
        }
    }

    #[test]
    fn concat_split_cols_roundtrip_and_gemm_equivalence() {
        let mut rng = Rng::new(12);
        let a = Matrix::gaussian(4, 6, 1.0, &mut rng);
        let wq = Matrix::gaussian(6, 3, 1.0, &mut rng);
        let wk = Matrix::gaussian(6, 5, 1.0, &mut rng);
        let packed = Matrix::concat_cols(&[&wq, &wk]);
        assert_eq!(packed.shape(), (6, 8));
        let parts = packed.split_cols(&[3, 5]);
        assert!(parts[0].allclose(&wq, 0.0));
        assert!(parts[1].allclose(&wk, 0.0));
        // column blocks contract independently: the fused product's
        // blocks are BIT-identical to the separate products
        let fused = a.matmul(&packed);
        let blocks = fused.split_cols(&[3, 5]);
        assert!(blocks[0].allclose(&a.matmul(&wq), 0.0));
        assert!(blocks[1].allclose(&a.matmul(&wk), 0.0));
    }

    #[test]
    fn matmul_propagates_inf() {
        let a = m(1, 2, &[0.0, 1.0]);
        let b = m(2, 2, &[f32::INFINITY, 2.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        // 0*inf = NaN in column 0; column 1 stays finite (0*2 + 1*1)
        assert!(c.at(0, 0).is_nan(), "{:?}", c.data);
        assert_eq!(c.at(0, 1), 1.0);
    }
}
