//! Pure-rust f32 tensor substrate.
//!
//! Used by the Figure-1 pilot study (MLP + LoRA/RP/RRP updaters with
//! hand-derived gradients), by the rust-side random-projection reference
//! (`rp`), by the native transformer models (`crate::model` — forward AND
//! manual backward, so the ops here carry their VJPs), and by the
//! metrics/memory machinery.
//!
//! The GEMM hot path lives in `kernels`: cache-blocked, ikj-ordered
//! kernels over row slices — since PR 9 with the strided operand's
//! panel **packed** into a reused thread-local scratch so the inner
//! loops are stride-1 on both operands — with an opt-in row-parallel
//! path behind the process-wide [`Parallelism`] config
//! (`--parallelism N` on the CLI and benches). Parallel band jobs run on a **persistent worker pool**
//! (started lazily or by `Parallelism::install`; `std::sync` only) — the
//! PR-4 per-call `std::thread::scope` driver survives as
//! [`Parallelism::scoped`], the A/B baseline and pool oracle. The
//! pre-refactor naive kernels are retained as `Matrix::*_naive`
//! bit-exactness oracles, and `batched` packs head-strided attention
//! views into contiguous panels so QKᵀ/probs·V run on the same kernels.
//! Blocked, pooled, and scoped paths are all bit-identical to the naive
//! serial ones (see `kernels` for why), so `Parallelism` never changes
//! any result. `docs/PERFORMANCE.md` is the tuning guide.

mod batched;
mod kernels;
mod matrix;
mod ops;

pub use batched::{
    add_panels_at, attention_backward_fused, batched_matmul, batched_matmul_nt,
    batched_matmul_ops, batched_matmul_tn, gather_heads, gather_heads_at,
    scatter_heads, scatter_heads_at, softmax_rows_masked,
    softmax_rows_masked_offset, softmax_rows_vjp_batched, BatchedMatrix,
};
pub use kernels::{
    pack_scratch_allocs, pool_tasks, KernelDriver, Parallelism, POOL_BUDGET,
};
// the model layer's row-local elementwise passes (embedding gathers,
// per-request norms) band themselves onto the same pool + threshold
pub(crate) use kernels::{par_rows, ELEMWISE_FLOP_WEIGHT};
pub use matrix::Matrix;
pub use ops::{
    gelu, gelu_grad, relu, rms_norm_rows, rms_norm_rows_vjp, softmax_rows,
    softmax_rows_vjp, RMS_EPS,
};
