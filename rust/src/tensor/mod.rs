//! Pure-rust f32 matrix substrate.
//!
//! Used by the Figure-1 pilot study (MLP + LoRA/RP/RRP updaters with
//! hand-derived gradients), by the rust-side random-projection reference
//! (`rp`), and by the metrics/memory machinery. This is NOT on the training
//! hot path of the big experiments — those run inside AOT-compiled XLA — so
//! clarity beats vectorization tricks here; the micro_rp bench still tracks
//! its GEMM against the XLA kernel for the §Perf log.

mod matrix;
mod ops;

pub use matrix::Matrix;
pub use ops::{gelu, relu, softmax_rows};
