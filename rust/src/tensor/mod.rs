//! Pure-rust f32 matrix substrate.
//!
//! Used by the Figure-1 pilot study (MLP + LoRA/RP/RRP updaters with
//! hand-derived gradients), by the rust-side random-projection reference
//! (`rp`), by the native transformer models (`crate::model` — forward AND
//! manual backward, so the ops here carry their VJPs), and by the
//! metrics/memory machinery. Clarity beats vectorization tricks here; the
//! micro_rp bench still tracks the GEMM against the XLA kernel for the
//! §Perf log.

mod matrix;
mod ops;

pub use matrix::Matrix;
pub use ops::{
    gelu, gelu_grad, relu, rms_norm_rows, rms_norm_rows_vjp, softmax_rows,
    softmax_rows_vjp, RMS_EPS,
};
