//! Elementwise nonlinearities, row-softmax and row RMS-norm, plus their
//! VJPs — the op set behind the pilot MLP and the native transformer's
//! manual backward pass (`crate::model`). Every VJP here is checked
//! against central finite differences in this file's tests.

use super::kernels::{par_rows, ELEMWISE_FLOP_WEIGHT};
use super::Matrix;

/// eps added to the mean square in the RMS-norm denominator.
pub const RMS_EPS: f32 = 1e-6;

pub fn relu(x: &Matrix) -> Matrix {
    x.map(|v| v.max(0.0))
}

/// tanh-approximation GELU (matches jax.nn.gelu's default).
pub fn gelu(x: &Matrix) -> Matrix {
    x.map(|v| {
        let c = (2.0f32 / std::f32::consts::PI).sqrt();
        0.5 * v * (1.0 + (c * (v + 0.044715 * v * v * v)).tanh())
    })
}

/// Numerically-stable softmax over each row. Row-local, so the row-banded
/// parallel path (engaged past the shared flop threshold) is
/// bit-identical to the serial loop at every thread budget.
pub fn softmax_rows(x: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    let cols = x.cols;
    let flops = x.rows * cols * ELEMWISE_FLOP_WEIGHT;
    par_rows(&mut out.data, x.rows, cols, flops, |band, first, n| {
        for r in 0..n {
            let row = x.row(first + r);
            let orow = &mut band[r * cols..(r + 1) * cols];
            let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let mut denom = 0.0f32;
            for (o, &v) in orow.iter_mut().zip(row.iter()) {
                *o = (v - mx).exp();
                denom += *o;
            }
            for o in orow.iter_mut() {
                *o /= denom;
            }
        }
    });
    out
}

/// Pointwise derivative of the tanh-approximation [`gelu`].
pub fn gelu_grad(x: &Matrix) -> Matrix {
    x.map(|v| {
        let c = (2.0f32 / std::f32::consts::PI).sqrt();
        let u = c * (v + 0.044715 * v * v * v);
        let t = u.tanh();
        0.5 * (1.0 + t) + 0.5 * v * (1.0 - t * t) * c * (1.0 + 3.0 * 0.044715 * v * v)
    })
}

/// VJP of [`softmax_rows`]: given the forward probabilities `p` and the
/// cotangent `dp`, returns `dz` with `dz_j = p_j (dp_j - Σ_k dp_k p_k)`
/// per row. Rows whose probability mass is exactly zero (masked-out
/// attention targets) get a zero gradient automatically.
pub fn softmax_rows_vjp(probs: &Matrix, dprobs: &Matrix) -> Matrix {
    assert_eq!(probs.shape(), dprobs.shape());
    let mut out = Matrix::zeros(probs.rows, probs.cols);
    for i in 0..probs.rows {
        let p = probs.row(i);
        let dp = dprobs.row(i);
        let dot: f32 = p.iter().zip(dp.iter()).map(|(a, b)| a * b).sum();
        let orow = &mut out.data[i * probs.cols..(i + 1) * probs.cols];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = p[j] * (dp[j] - dot);
        }
    }
    out
}

/// RMS-norm over each row with a learned `[1, d]` scale — the T5-style
/// layer normalization (no mean subtraction) used by the transformer's
/// `ln*` layers, mirroring `layers.rms_norm` on the python side:
/// `y = x / sqrt(mean(x^2) + eps) * scale`.
pub fn rms_norm_rows(x: &Matrix, scale: &Matrix) -> Matrix {
    assert_eq!(scale.shape(), (1, x.cols), "rms_norm scale must be [1, d]");
    let d = x.cols as f32;
    let cols = x.cols;
    let mut out = Matrix::zeros(x.rows, x.cols);
    let flops = x.rows * cols * ELEMWISE_FLOP_WEIGHT;
    // row-local: banding onto the pool is bit-identical at every budget
    par_rows(&mut out.data, x.rows, cols, flops, |band, first, n| {
        for r in 0..n {
            let row = x.row(first + r);
            let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d;
            let inv = 1.0 / (ms + RMS_EPS).sqrt();
            let orow = &mut band[r * cols..(r + 1) * cols];
            for (j, o) in orow.iter_mut().enumerate() {
                *o = row[j] * inv * scale.at(0, j);
            }
        }
    });
    out
}

/// VJP of [`rms_norm_rows`]: returns `(dx, dscale)`. The inverse RMS is
/// recomputed from `x` (cheaper than caching it through the layer stack).
///
/// `dx` is row-local, so it row-bands onto the pool (recomputing each
/// row's `inv` and `dot` with the identical ascending-`j` arithmetic —
/// bit-identical to the serial loop). `dscale` accumulates **across**
/// rows into one `[1, d]` vector, so it stays a serial ascending-row
/// pass — parallelizing it would need a reduction tree and re-associate
/// the sum.
pub fn rms_norm_rows_vjp(x: &Matrix, scale: &Matrix, dy: &Matrix) -> (Matrix, Matrix) {
    assert_eq!(scale.shape(), (1, x.cols), "rms_norm scale must be [1, d]");
    assert_eq!(x.shape(), dy.shape());
    let d = x.cols as f32;
    let cols = x.cols;
    let mut dx = Matrix::zeros(x.rows, x.cols);
    let mut dscale = Matrix::zeros(1, x.cols);
    let flops = x.rows * cols * ELEMWISE_FLOP_WEIGHT;
    par_rows(&mut dx.data, x.rows, cols, flops, |band, first, n| {
        for r in 0..n {
            let row = x.row(first + r);
            let dyrow = dy.row(first + r);
            let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d;
            let inv = 1.0 / (ms + RMS_EPS).sqrt();
            // dot = Σ_j dy_j s_j x_j drives the d(inv)/dx term
            let mut dot = 0.0f32;
            for j in 0..cols {
                dot += dyrow[j] * scale.at(0, j) * row[j];
            }
            let k = inv * inv * inv / d;
            let dxrow = &mut band[r * cols..(r + 1) * cols];
            for (j, o) in dxrow.iter_mut().enumerate() {
                *o = inv * scale.at(0, j) * dyrow[j] - k * row[j] * dot;
            }
        }
    });
    for i in 0..x.rows {
        let row = x.row(i);
        let dyrow = dy.row(i);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        for j in 0..cols {
            *dscale.at_mut(0, j) += dyrow[j] * row[j] * inv;
        }
    }
    (dx, dscale)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_clamps() {
        let x = Matrix::from_vec(1, 4, vec![-1.0, 0.0, 0.5, 2.0]);
        assert_eq!(relu(&x).data, vec![0.0, 0.0, 0.5, 2.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Matrix::from_vec(2, 3, vec![1., 2., 3., -1., 0., 1.]);
        let s = softmax_rows(&x);
        for i in 0..2 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
        // monotone in logits
        assert!(s.at(0, 2) > s.at(0, 1) && s.at(0, 1) > s.at(0, 0));
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let x = Matrix::from_vec(1, 2, vec![1000.0, 1001.0]);
        let s = softmax_rows(&x);
        assert!(s.data.iter().all(|v| v.is_finite()));
        assert!((s.at(0, 1) - 0.731).abs() < 0.01);
    }

    #[test]
    fn gelu_known_points() {
        let x = Matrix::from_vec(1, 3, vec![-10.0, 0.0, 10.0]);
        let g = gelu(&x);
        assert!(g.at(0, 0).abs() < 1e-3);
        assert_eq!(g.at(0, 1), 0.0);
        assert!((g.at(0, 2) - 10.0).abs() < 1e-3);
    }

    use crate::util::rng::Rng;

    fn randn(seed: u64, n: usize, m: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::gaussian(n, m, 1.0, &mut rng)
    }

    fn close(fd: f32, an: f32, who: &str) {
        assert!(
            (fd - an).abs() < 1e-3 + 1e-2 * fd.abs().max(an.abs()),
            "{who}: fd={fd} analytic={an}"
        );
    }

    #[test]
    fn gelu_grad_matches_finite_differences() {
        let x = Matrix::from_vec(1, 7, vec![-3.0, -1.0, -0.2, 0.0, 0.3, 1.5, 4.0]);
        let g = gelu_grad(&x);
        let eps = 1e-3f32;
        for j in 0..x.cols {
            let mut xp = x.clone();
            *xp.at_mut(0, j) += eps;
            let mut xm = x.clone();
            *xm.at_mut(0, j) -= eps;
            let fd = (gelu(&xp).at(0, j) - gelu(&xm).at(0, j)) / (2.0 * eps);
            close(fd, g.at(0, j), "gelu'");
        }
    }

    #[test]
    fn softmax_vjp_matches_finite_differences() {
        // scalar objective: f(z) = <softmax(z), c> for a fixed cotangent c
        let z = randn(10, 3, 5);
        let c = randn(11, 3, 5);
        let probs = softmax_rows(&z);
        let dz = softmax_rows_vjp(&probs, &c);
        let f = |z: &Matrix| -> f32 {
            softmax_rows(z)
                .data
                .iter()
                .zip(c.data.iter())
                .map(|(a, b)| a * b)
                .sum()
        };
        let eps = 1e-3f32;
        for &(i, j) in &[(0usize, 0usize), (1, 2), (2, 4)] {
            let mut zp = z.clone();
            *zp.at_mut(i, j) += eps;
            let mut zm = z.clone();
            *zm.at_mut(i, j) -= eps;
            let fd = (f(&zp) - f(&zm)) / (2.0 * eps);
            close(fd, dz.at(i, j), "softmax vjp");
        }
    }

    #[test]
    fn softmax_vjp_zero_on_masked_targets() {
        // a -1e30 score yields probability 0, so the VJP must be exactly 0
        let z = Matrix::from_vec(1, 3, vec![0.5, -1e30, 1.0]);
        let probs = softmax_rows(&z);
        assert_eq!(probs.at(0, 1), 0.0);
        let dz = softmax_rows_vjp(&probs, &randn(12, 1, 3));
        assert_eq!(dz.at(0, 1), 0.0);
    }

    #[test]
    fn rms_norm_rows_scales_to_unit_rms() {
        let x = randn(13, 4, 16);
        let ones = Matrix::from_fn(1, 16, |_, _| 1.0);
        let y = rms_norm_rows(&x, &ones);
        for i in 0..4 {
            let rms: f32 =
                (y.row(i).iter().map(|v| v * v).sum::<f32>() / 16.0).sqrt();
            assert!((rms - 1.0).abs() < 1e-3, "row {i}: rms={rms}");
        }
    }

    #[test]
    fn rms_norm_vjp_matches_finite_differences() {
        // scalar objective: f(x, s) = <rms_norm(x, s), c>
        let x = randn(14, 3, 8);
        let s = randn(15, 1, 8).map(|v| 1.0 + 0.3 * v);
        let c = randn(16, 3, 8);
        let (dx, ds) = rms_norm_rows_vjp(&x, &s, &c);
        let f = |x: &Matrix, s: &Matrix| -> f32 {
            rms_norm_rows(x, s)
                .data
                .iter()
                .zip(c.data.iter())
                .map(|(a, b)| a * b)
                .sum()
        };
        let eps = 1e-3f32;
        for &(i, j) in &[(0usize, 0usize), (1, 3), (2, 7)] {
            let mut xp = x.clone();
            *xp.at_mut(i, j) += eps;
            let mut xm = x.clone();
            *xm.at_mut(i, j) -= eps;
            let fd = (f(&xp, &s) - f(&xm, &s)) / (2.0 * eps);
            close(fd, dx.at(i, j), "rms dx");
        }
        for j in [0usize, 4, 7] {
            let mut sp = s.clone();
            *sp.at_mut(0, j) += eps;
            let mut sm = s.clone();
            *sm.at_mut(0, j) -= eps;
            let fd = (f(&x, &sp) - f(&x, &sm)) / (2.0 * eps);
            close(fd, ds.at(0, j), "rms dscale");
        }
    }
}
