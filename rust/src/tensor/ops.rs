//! Elementwise nonlinearities and row-softmax for the pilot MLP.

use super::Matrix;

pub fn relu(x: &Matrix) -> Matrix {
    x.map(|v| v.max(0.0))
}

/// tanh-approximation GELU (matches jax.nn.gelu's default).
pub fn gelu(x: &Matrix) -> Matrix {
    x.map(|v| {
        let c = (2.0f32 / std::f32::consts::PI).sqrt();
        0.5 * v * (1.0 + (c * (v + 0.044715 * v * v * v)).tanh())
    })
}

/// Numerically-stable softmax over each row.
pub fn softmax_rows(x: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        let row = x.row(i);
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut denom = 0.0f32;
        for (j, &v) in row.iter().enumerate() {
            let e = (v - mx).exp();
            *out.at_mut(i, j) = e;
            denom += e;
        }
        for j in 0..x.cols {
            *out.at_mut(i, j) /= denom;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_clamps() {
        let x = Matrix::from_vec(1, 4, vec![-1.0, 0.0, 0.5, 2.0]);
        assert_eq!(relu(&x).data, vec![0.0, 0.0, 0.5, 2.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Matrix::from_vec(2, 3, vec![1., 2., 3., -1., 0., 1.]);
        let s = softmax_rows(&x);
        for i in 0..2 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
        // monotone in logits
        assert!(s.at(0, 2) > s.at(0, 1) && s.at(0, 1) > s.at(0, 0));
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let x = Matrix::from_vec(1, 2, vec![1000.0, 1001.0]);
        let s = softmax_rows(&x);
        assert!(s.data.iter().all(|v| v.is_finite()));
        assert!((s.at(0, 1) - 0.731).abs() < 0.01);
    }

    #[test]
    fn gelu_known_points() {
        let x = Matrix::from_vec(1, 3, vec![-10.0, 0.0, 10.0]);
        let g = gelu(&x);
        assert!(g.at(0, 0).abs() < 1e-3);
        assert_eq!(g.at(0, 1), 0.0);
        assert!((g.at(0, 2) - 10.0).abs() < 1e-3);
    }
}
