//! Batched tensor primitives for multi-head attention: a contiguous
//! `[batch, rows, cols]` panel type, batched GEMMs over it, and the
//! masked row-softmax (+ VJP) that attention applies to score panels.
//!
//! Head-strided activations (`[b*s, n_heads*head_dim]` matrices where
//! head `h` owns columns `[h*dh, (h+1)*dh)`) are packed into contiguous
//! per-(batch, head) panels by [`gather_heads`] — the BLIS-style pack —
//! so every attention contraction (QKᵀ, probs·V and their transposed
//! backward forms) runs on the cache-blocked kernels of
//! `tensor::kernels` instead of scalar index arithmetic.
//!
//! Numerics: each batched op calls the same serial per-panel kernel
//! bodies the `Matrix` GEMMs use, with the batch dimension as the
//! parallel split — results are bit-identical to the per-panel `Matrix`
//! ops and to the retained scalar attention reference
//! (`model::blocks::reference`) for every thread count.

use super::kernels::{
    matmul_band, matmul_nt_band, matmul_tn_band, par_rows, pool_tasks, Parallelism,
    ELEMWISE_FLOP_WEIGHT, PAR_MIN_FLOPS,
};
use super::Matrix;

/// A dense stack of `batch` equally-shaped row-major matrices.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchedMatrix {
    pub batch: usize,
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl BatchedMatrix {
    pub fn zeros(batch: usize, rows: usize, cols: usize) -> Self {
        Self { batch, rows, cols, data: vec![0.0; batch * rows * cols] }
    }

    /// One panel's `rows * cols` slice.
    pub fn panel(&self, b: usize) -> &[f32] {
        let n = self.rows * self.cols;
        &self.data[b * n..(b + 1) * n]
    }

    pub fn panel_mut(&mut self, b: usize) -> &mut [f32] {
        let n = self.rows * self.cols;
        &mut self.data[b * n..(b + 1) * n]
    }

    /// Copy one panel out as a standalone [`Matrix`] (tests, debugging).
    pub fn to_matrix(&self, b: usize) -> Matrix {
        Matrix::from_vec(self.rows, self.cols, self.panel(b).to_vec())
    }

    /// Reinterpret a `[batch*rows, cols]` activation matrix as `batch`
    /// contiguous `[rows, cols]` panels (request `p` owns rows
    /// `[p*rows, (p+1)*rows)`). Row-major layout makes this a pure copy
    /// with no reindexing — the serving tier uses it to turn one stacked
    /// activation into the per-request panels that
    /// [`batched_matmul_ops`] contracts against per-request adapters.
    pub fn from_matrix(x: &Matrix, batch: usize) -> Self {
        assert!(batch > 0 && x.rows % batch == 0, "from_matrix: {} rows not divisible by batch {}", x.rows, batch);
        Self { batch, rows: x.rows / batch, cols: x.cols, data: x.data.clone() }
    }

    /// In-place elementwise scale (e.g. folding the attention score scale
    /// into a cotangent before the backward GEMMs).
    pub fn scale_inplace(&mut self, s: f32) {
        for x in self.data.iter_mut() {
            *x *= s;
        }
    }
}

/// Pack head-strided activations `x: [b*s, heads*dh]` into contiguous
/// `[b*heads, s, dh]` panels (panel `bi*heads + hi` is batch `bi`, head
/// `hi`).
pub fn gather_heads(x: &Matrix, b: usize, s: usize, heads: usize, dh: usize) -> BatchedMatrix {
    debug_assert_eq!(x.shape(), (b * s, heads * dh));
    gather_heads_at(x, b, s, heads, dh, 0)
}

/// [`gather_heads`] on a column window: pack the head-strided view that
/// starts at column `col0` of a wider activation matrix. This is how the
/// fused-QKV path slices the q/k/v thirds of one packed `[b*s, 3d]`
/// projection without materializing three intermediate matrices.
pub fn gather_heads_at(
    x: &Matrix,
    b: usize,
    s: usize,
    heads: usize,
    dh: usize,
    col0: usize,
) -> BatchedMatrix {
    debug_assert_eq!(x.rows, b * s);
    debug_assert!(col0 + heads * dh <= x.cols, "gather_heads_at window oob");
    let mut out = BatchedMatrix::zeros(b * heads, s, dh);
    for bi in 0..b {
        for hi in 0..heads {
            let panel = out.panel_mut(bi * heads + hi);
            for i in 0..s {
                let src = &x.row(bi * s + i)[col0 + hi * dh..col0 + (hi + 1) * dh];
                panel[i * dh..(i + 1) * dh].copy_from_slice(src);
            }
        }
    }
    out
}

/// Unpack `[b*heads, s, dh]` panels back into a head-strided
/// `[b*s, heads*dh]` matrix — the inverse of [`gather_heads`].
pub fn scatter_heads(src: &BatchedMatrix, b: usize, s: usize, heads: usize, dh: usize) -> Matrix {
    let mut out = Matrix::zeros(b * s, heads * dh);
    scatter_heads_at(&mut out, src, b, s, heads, dh, 0);
    out
}

/// [`scatter_heads`] into a column window of an existing (wider) matrix:
/// writes panel `(bi, hi)` row `i` into `dst` row `bi*s + i`, columns
/// `[col0 + hi*dh, col0 + (hi+1)*dh)`. The fused-QKV backward packs the
/// three attention cotangents into one `[b*s, 3d]` matrix this way so a
/// single GEMM produces all of `dWq|dWk|dWv` (and one more, `dn1`).
pub fn scatter_heads_at(
    dst: &mut Matrix,
    src: &BatchedMatrix,
    b: usize,
    s: usize,
    heads: usize,
    dh: usize,
    col0: usize,
) {
    debug_assert_eq!((src.batch, src.rows, src.cols), (b * heads, s, dh));
    debug_assert_eq!(dst.rows, b * s);
    debug_assert!(col0 + heads * dh <= dst.cols, "scatter_heads_at window oob");
    let w = dst.cols;
    for bi in 0..b {
        for hi in 0..heads {
            let panel = src.panel(bi * heads + hi);
            for i in 0..s {
                let r = bi * s + i;
                let out =
                    &mut dst.data[r * w + col0 + hi * dh..r * w + col0 + (hi + 1) * dh];
                out.copy_from_slice(&panel[i * dh..(i + 1) * dh]);
            }
        }
    }
}

/// `C[p] = A[p] @ B[p]` per panel, parallel over panels.
pub fn batched_matmul(a: &BatchedMatrix, b: &BatchedMatrix) -> BatchedMatrix {
    assert_eq!(a.batch, b.batch, "batched_matmul batch mismatch");
    assert_eq!(a.cols, b.rows, "batched_matmul [{},{}] @ [{},{}]", a.rows, a.cols, b.rows, b.cols);
    let mut out = BatchedMatrix::zeros(a.batch, a.rows, b.cols);
    let (n, k, m) = (a.rows, a.cols, b.cols);
    let flops = a.batch * n * k * m;
    par_rows(&mut out.data, a.batch, n * m, flops, |chunk, first, count| {
        for p in 0..count {
            matmul_band(
                &mut chunk[p * n * m..(p + 1) * n * m],
                &a.data[(first + p) * n * k..(first + p + 1) * n * k],
                &b.data[(first + p) * k * m..(first + p + 1) * k * m],
                n,
                k,
                m,
            );
        }
    });
    out
}

/// `C[p] = alpha * (A[p] @ B[p]^T)` per panel (the QKᵀ shape; `alpha`
/// is the `1/sqrt(dh)` attention scale, applied to each finished dot
/// exactly like the scalar reference), parallel over panels.
pub fn batched_matmul_nt(a: &BatchedMatrix, b: &BatchedMatrix, alpha: f32) -> BatchedMatrix {
    assert_eq!(a.batch, b.batch, "batched_matmul_nt batch mismatch");
    assert_eq!(a.cols, b.cols, "batched_matmul_nt cols {} vs {}", a.cols, b.cols);
    let mut out = BatchedMatrix::zeros(a.batch, a.rows, b.rows);
    let (n, k, m) = (a.rows, a.cols, b.rows);
    let flops = a.batch * n * k * m;
    par_rows(&mut out.data, a.batch, n * m, flops, |chunk, first, count| {
        for p in 0..count {
            matmul_nt_band(
                &mut chunk[p * n * m..(p + 1) * n * m],
                &a.data[(first + p) * n * k..(first + p + 1) * n * k],
                &b.data[(first + p) * m * k..(first + p + 1) * m * k],
                n,
                k,
                m,
                alpha,
            );
        }
    });
    out
}

/// `C[p] = A[p]^T @ B[p]` per panel (the `probsᵀ·dctx` backward shape),
/// parallel over panels.
pub fn batched_matmul_tn(a: &BatchedMatrix, b: &BatchedMatrix) -> BatchedMatrix {
    assert_eq!(a.batch, b.batch, "batched_matmul_tn batch mismatch");
    assert_eq!(a.rows, b.rows, "batched_matmul_tn rows {} vs {}", a.rows, b.rows);
    let mut out = BatchedMatrix::zeros(a.batch, a.cols, b.cols);
    let (rows, acols, m) = (a.rows, a.cols, b.cols);
    let flops = a.batch * rows * acols * m;
    par_rows(&mut out.data, a.batch, acols * m, flops, |chunk, first, count| {
        for p in 0..count {
            matmul_tn_band(
                &mut chunk[p * acols * m..(p + 1) * acols * m],
                &a.data[(first + p) * rows * acols..(first + p + 1) * rows * acols],
                &b.data[(first + p) * rows * m..(first + p + 1) * rows * m],
                rows,
                acols,
                m,
                0,
                acols,
            );
        }
    });
    out
}

/// `C[p] = A[p] @ ops[p]` — one batched GEMM where every panel contracts
/// against its **own** right-hand operand. This is the serving-tier
/// primitive: with `A = [batch, s, n]` request activations and
/// `ops[p]` request `p`'s adapter factor, one call applies `batch`
/// *distinct* adapters in the `(xB)A` contraction order without ever
/// materializing any `B·A` product. All operands must share one
/// `[k, m]` shape (the batcher guarantees rank-homogeneous batches).
///
/// Numerics: each panel runs the same serial `matmul_band` body the
/// per-panel `Matrix::matmul` uses, so panel `p` is bit-identical to
/// `a.to_matrix(p).matmul(ops[p])` — including NaN/Inf propagation.
pub fn batched_matmul_ops(a: &BatchedMatrix, ops: &[&Matrix]) -> BatchedMatrix {
    assert_eq!(a.batch, ops.len(), "batched_matmul_ops: {} panels vs {} operands", a.batch, ops.len());
    let (k, m) = ops[0].shape();
    for (p, op) in ops.iter().enumerate() {
        assert_eq!(op.shape(), (k, m), "batched_matmul_ops: operand {p} shape mismatch");
    }
    assert_eq!(a.cols, k, "batched_matmul_ops [{},{}] @ [{},{}]", a.rows, a.cols, k, m);
    let mut out = BatchedMatrix::zeros(a.batch, a.rows, m);
    let n = a.rows;
    let flops = a.batch * n * k * m;
    par_rows(&mut out.data, a.batch, n * m, flops, |chunk, first, count| {
        for p in 0..count {
            matmul_band(
                &mut chunk[p * n * m..(p + 1) * n * m],
                &a.data[(first + p) * n * k..(first + p + 1) * n * k],
                &ops[first + p].data,
                n,
                k,
                m,
            );
        }
    });
    out
}

/// Add panel `p` of `src: [batch, rows, w]` into the column window
/// `[col0, col0+w)` of rows `[p*rows, (p+1)*rows)` of `dst`. The serving
/// forward uses this to accumulate per-request `(xB)A` adapter
/// corrections into the q/k/v thirds of the fused base projection.
pub fn add_panels_at(dst: &mut Matrix, src: &BatchedMatrix, col0: usize) {
    assert_eq!(dst.rows, src.batch * src.rows, "add_panels_at row mismatch");
    assert!(col0 + src.cols <= dst.cols, "add_panels_at window oob");
    let w = dst.cols;
    for p in 0..src.batch {
        let panel = src.panel(p);
        for i in 0..src.rows {
            let r = p * src.rows + i;
            let out = &mut dst.data[r * w + col0..r * w + col0 + src.cols];
            for (o, s) in out.iter_mut().zip(&panel[i * src.cols..(i + 1) * src.cols]) {
                *o += *s;
            }
        }
    }
}

/// In-place numerically-stable softmax over every panel row. With
/// `causal`, row `i` only attends to columns `0..=i`; masked columns get
/// **exactly** zero probability — bit-identical to softmaxing a row whose
/// masked scores were set to -1e30 (their exps underflow to +0 and add
/// nothing to the denominator), which is what the scalar reference does.
pub fn softmax_rows_masked(x: &mut BatchedMatrix, causal: bool) {
    if causal {
        return softmax_rows_masked_offset(x, 0);
    }
    let (batch, rows, cols) = (x.batch, x.rows, x.cols);
    let total = batch * rows;
    let flops = total * cols * ELEMWISE_FLOP_WEIGHT;
    // row-banded onto the pool: softmax is row-local, so banding cannot
    // change any element's arithmetic — bit-identical at every budget
    par_rows(&mut x.data, total, cols, flops, |band, _first, n| {
        for r in 0..n {
            let row = &mut band[r * cols..(r + 1) * cols];
            softmax_row_in_place(row, cols);
        }
    });
}

/// The shared serial softmax row body: exp-normalize `row[..valid]`,
/// zero the rest. Extracted so the parallel row bands and the serial
/// fallback are the same code (the oracle property is structural).
fn softmax_row_in_place(row: &mut [f32], valid: usize) {
    let mx = row[..valid].iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut denom = 0.0f32;
    for v in row[..valid].iter_mut() {
        *v = (*v - mx).exp();
        denom += *v;
    }
    for v in row[..valid].iter_mut() {
        *v /= denom;
    }
    for v in row[valid..].iter_mut() {
        *v = 0.0;
    }
}

/// Causal row-softmax for an **offset** score chunk: panel row `i`
/// holds the scores of global position `t0 + i` against key columns
/// `[0, cols)`, so it may attend to columns `0..=t0+i`. `t0 = 0` with
/// `cols == rows` is exactly the [`softmax_rows_masked`] causal case
/// (which delegates here); `rows = 1, t0 = t, cols = t+1` is one
/// KV-cache decode step, where the whole row is valid. Masked columns
/// get exactly zero probability, same convention as the full-recompute
/// path.
pub fn softmax_rows_masked_offset(x: &mut BatchedMatrix, t0: usize) {
    let (batch, rows, cols) = (x.batch, x.rows, x.cols);
    let total = batch * rows;
    let flops = total * cols * ELEMWISE_FLOP_WEIGHT;
    // global row gr is panel row gr % rows — the causal bound depends
    // only on the within-panel position, so the banded kernel recovers it
    par_rows(&mut x.data, total, cols, flops, |band, first, n| {
        for r in 0..n {
            let i = (first + r) % rows;
            let valid = (t0 + i + 1).min(cols);
            let row = &mut band[r * cols..(r + 1) * cols];
            softmax_row_in_place(row, valid);
        }
    });
}

/// VJP of [`softmax_rows_masked`] per panel row:
/// `dz_j = p_j (dp_j - Σ_k dp_k p_k)`. Masked columns carry zero
/// probability, so their score gradients vanish without special-casing —
/// the batched mirror of `tensor::ops::softmax_rows_vjp`.
pub fn softmax_rows_vjp_batched(probs: &BatchedMatrix, dprobs: &BatchedMatrix) -> BatchedMatrix {
    assert_eq!(
        (probs.batch, probs.rows, probs.cols),
        (dprobs.batch, dprobs.rows, dprobs.cols),
        "softmax_rows_vjp_batched shape mismatch"
    );
    let mut out = BatchedMatrix::zeros(probs.batch, probs.rows, probs.cols);
    let cols = probs.cols;
    let total = probs.batch * probs.rows;
    let flops = total * cols * ELEMWISE_FLOP_WEIGHT;
    // row-local (one dot + one elementwise pass per row): banding onto
    // the pool is bit-identical at every budget
    par_rows(&mut out.data, total, cols, flops, |band, first, n| {
        for r in 0..n {
            let gr = first + r;
            let prow = &probs.data[gr * cols..(gr + 1) * cols];
            let dprow = &dprobs.data[gr * cols..(gr + 1) * cols];
            let dot: f32 = prow.iter().zip(dprow.iter()).map(|(a, b)| a * b).sum();
            for (j, v) in band[r * cols..(r + 1) * cols].iter_mut().enumerate() {
                *v = prow[j] * (dprow[j] - dot);
            }
        }
    });
    out
}

/// All four backward-attention contractions — `dprobs = dctx·Vᵀ`, the
/// softmax VJP (+ score-scale fold), `dQ = dS·K`, `dK = dSᵀ·Q`,
/// `dV = probsᵀ·dctx` — in **one** pool submission. The unfused path
/// pays four enqueue-and-latch round trips per layer per step (one per
/// batched GEMM); here the panels are split into contiguous bands once
/// and each band runs the whole per-panel backward chain, so the step
/// pays a single latch. Returns `(dqh, dkh, dvh)` panels.
///
/// Numerics: each panel runs the identical serial kernel bodies and the
/// identical VJP-then-scale element order the unfused four-call path
/// uses, and every output panel is written by exactly one band — so the
/// result is bit-identical to the unfused sequence by construction, at
/// every thread budget. `model::blocks` keeps the unfused path as the
/// oracle and bit-compares the two.
pub fn attention_backward_fused(
    dctxh: &BatchedMatrix,
    probs: &BatchedMatrix,
    qh: &BatchedMatrix,
    kh: &BatchedMatrix,
    vh: &BatchedMatrix,
    scale: f32,
) -> (BatchedMatrix, BatchedMatrix, BatchedMatrix) {
    let (batch, s, dh) = (dctxh.batch, dctxh.rows, dctxh.cols);
    assert_eq!((probs.batch, probs.rows, probs.cols), (batch, s, s), "probs shape");
    for (name, m) in [("qh", qh), ("kh", kh), ("vh", vh)] {
        assert_eq!((m.batch, m.rows, m.cols), (batch, s, dh), "{name} shape");
    }
    let mut dscores = BatchedMatrix::zeros(batch, s, s);
    let mut dq = BatchedMatrix::zeros(batch, s, dh);
    let mut dk = BatchedMatrix::zeros(batch, s, dh);
    let mut dv = BatchedMatrix::zeros(batch, s, dh);

    // one band = a contiguous panel range; same split rule as par_rows
    let flops = 4 * batch * s * s * dh;
    let threads = if flops < PAR_MIN_FLOPS {
        1
    } else {
        Parallelism::current().threads().min(batch).max(1)
    };
    let chunk = batch.div_ceil(threads);
    let n_bands = batch.div_ceil(chunk);

    // raw panel pointers so one Fn closure can write all four outputs;
    // panels are disjoint per band, see the Safety comment below
    struct SendPtr(*mut f32);
    unsafe impl Send for SendPtr {}
    unsafe impl Sync for SendPtr {}
    let psc = SendPtr(dscores.data.as_mut_ptr());
    let pq = SendPtr(dq.data.as_mut_ptr());
    let pk = SendPtr(dk.data.as_mut_ptr());
    let pv = SendPtr(dv.data.as_mut_ptr());

    pool_tasks(n_bands, |t| {
        let p0 = t * chunk;
        let p1 = (p0 + chunk).min(batch);
        for p in p0..p1 {
            // Safety: bands are disjoint contiguous panel ranges and
            // `pool_tasks` does not return until every task completed,
            // so each panel slice is exclusively owned by this task for
            // the duration of the borrow and never outlives the buffers.
            let dsc = unsafe {
                std::slice::from_raw_parts_mut(psc.0.add(p * s * s), s * s)
            };
            let dqp = unsafe {
                std::slice::from_raw_parts_mut(pq.0.add(p * s * dh), s * dh)
            };
            let dkp = unsafe {
                std::slice::from_raw_parts_mut(pk.0.add(p * s * dh), s * dh)
            };
            let dvp = unsafe {
                std::slice::from_raw_parts_mut(pv.0.add(p * s * dh), s * dh)
            };
            let dctxp = dctxh.panel(p);
            let probsp = probs.panel(p);
            // dprobs = dctx · vᵀ (overwrites dsc — nt semantics)
            matmul_nt_band(dsc, dctxp, vh.panel(p), s, dh, s, 1.0);
            // softmax VJP in place, then the scale fold as a SEPARATE
            // pass — the exact element-op order of
            // softmax_rows_vjp_batched + scale_inplace
            for i in 0..s {
                let prow = &probsp[i * s..(i + 1) * s];
                let dsrow = &mut dsc[i * s..(i + 1) * s];
                let dot: f32 =
                    prow.iter().zip(dsrow.iter()).map(|(a, b)| a * b).sum();
                for (o, &pj) in dsrow.iter_mut().zip(prow.iter()) {
                    *o = pj * (*o - dot);
                }
            }
            for o in dsc.iter_mut() {
                *o *= scale;
            }
            // dq = dscores · k ; dk = dscoresᵀ · q ; dv = probsᵀ · dctx
            matmul_band(dqp, dsc, kh.panel(p), s, s, dh);
            matmul_tn_band(dkp, dsc, qh.panel(p), s, s, dh, 0, s);
            matmul_tn_band(dvp, probsp, dctxp, s, s, dh, 0, s);
        }
    });
    (dq, dk, dv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{softmax_rows, softmax_rows_vjp};
    use crate::util::rng::Rng;

    fn randb(seed: u64, batch: usize, rows: usize, cols: usize) -> BatchedMatrix {
        let mut rng = Rng::new(seed);
        let mut out = BatchedMatrix::zeros(batch, rows, cols);
        rng.fill_gaussian(&mut out.data, 1.0);
        out
    }

    #[test]
    fn gather_scatter_heads_roundtrip() {
        let mut rng = Rng::new(0);
        let (b, s, h, dh) = (2usize, 5usize, 3usize, 4usize);
        let x = Matrix::gaussian(b * s, h * dh, 1.0, &mut rng);
        let packed = gather_heads(&x, b, s, h, dh);
        assert_eq!((packed.batch, packed.rows, packed.cols), (b * h, s, dh));
        // panel (bi, hi) row i is x row bi*s+i, columns hi*dh..
        let (bi, hi, i) = (1usize, 2usize, 3usize);
        assert_eq!(
            packed.panel(bi * h + hi)[i * dh..(i + 1) * dh],
            x.row(bi * s + i)[hi * dh..(hi + 1) * dh]
        );
        let back = scatter_heads(&packed, b, s, h, dh);
        assert!(back.allclose(&x, 0.0));
    }

    #[test]
    fn offset_gather_scatter_window_a_wider_matrix() {
        // a [b*s, 3d]-style packed activation: the q/k/v thirds gathered
        // with col0 offsets must equal gathering pre-split copies
        let mut rng = Rng::new(11);
        let (b, s, h, dh) = (2usize, 3usize, 2usize, 2usize);
        let d = h * dh;
        let wide = Matrix::gaussian(b * s, 3 * d, 1.0, &mut rng);
        for (third, col0) in [(0usize, 0usize), (1, d), (2, 2 * d)] {
            let split = Matrix::from_fn(b * s, d, |i, j| wide.at(i, col0 + j));
            let direct = gather_heads_at(&wide, b, s, h, dh, col0);
            let via_split = gather_heads(&split, b, s, h, dh);
            assert_eq!(direct.data, via_split.data, "third {third}");
        }
        // scatter back into a fresh wide matrix reassembles it exactly
        let mut back = Matrix::zeros(b * s, 3 * d);
        for col0 in [0, d, 2 * d] {
            let panels = gather_heads_at(&wide, b, s, h, dh, col0);
            scatter_heads_at(&mut back, &panels, b, s, h, dh, col0);
        }
        assert!(back.allclose(&wide, 0.0));
    }

    #[test]
    fn batched_matmuls_bit_match_per_panel_matrix_ops() {
        let a = randb(1, 3, 6, 5);
        let b = randb(2, 3, 5, 7);
        let c = batched_matmul(&a, &b);
        for p in 0..3 {
            let want = a.to_matrix(p).matmul(&b.to_matrix(p));
            assert!(c.to_matrix(p).allclose(&want, 0.0), "panel {p}");
        }
        let bt = randb(3, 3, 7, 5);
        let cnt = batched_matmul_nt(&a, &bt, 1.0);
        for p in 0..3 {
            let want = a.to_matrix(p).matmul_nt(&bt.to_matrix(p));
            assert!(cnt.to_matrix(p).allclose(&want, 0.0), "nt panel {p}");
        }
        let b2 = randb(4, 3, 6, 4);
        let ctn = batched_matmul_tn(&a, &b2);
        for p in 0..3 {
            let want = a.to_matrix(p).matmul_tn(&b2.to_matrix(p));
            assert!(ctn.to_matrix(p).allclose(&want, 0.0), "tn panel {p}");
        }
    }

    #[test]
    fn batched_matmul_ops_bit_matches_per_panel_matmul() {
        // three panels, three *different* right operands — incl. one
        // poisoned with NaN/Inf, per the kernel-oracle convention
        let a = randb(21, 3, 4, 6);
        let mut rng = Rng::new(22);
        let mut ops: Vec<Matrix> = (0..3).map(|_| Matrix::gaussian(6, 5, 1.0, &mut rng)).collect();
        *ops[1].at_mut(2, 3) = f32::NAN;
        *ops[1].at_mut(0, 0) = f32::INFINITY;
        let refs: Vec<&Matrix> = ops.iter().collect();
        let c = batched_matmul_ops(&a, &refs);
        assert_eq!((c.batch, c.rows, c.cols), (3, 4, 5));
        for p in 0..3 {
            let want = a.to_matrix(p).matmul(&ops[p]);
            let got = c.to_matrix(p);
            for (g, w) in got.data.iter().zip(want.data.iter()) {
                assert_eq!(g.to_bits(), w.to_bits(), "panel {p}");
            }
        }
    }

    #[test]
    fn from_matrix_panels_are_row_bands() {
        let mut rng = Rng::new(23);
        let x = Matrix::gaussian(6, 4, 1.0, &mut rng);
        let panels = BatchedMatrix::from_matrix(&x, 3);
        assert_eq!((panels.batch, panels.rows, panels.cols), (3, 2, 4));
        for p in 0..3 {
            for i in 0..2 {
                assert_eq!(&panels.panel(p)[i * 4..(i + 1) * 4], x.row(p * 2 + i));
            }
        }
    }

    #[test]
    fn add_panels_at_accumulates_into_column_window() {
        let mut rng = Rng::new(24);
        let base = Matrix::gaussian(4, 9, 1.0, &mut rng);
        let corr = randb(25, 2, 2, 3);
        let mut dst = base.clone();
        add_panels_at(&mut dst, &corr, 3);
        for p in 0..2 {
            for i in 0..2 {
                for j in 0..9 {
                    let r = p * 2 + i;
                    let want = if (3..6).contains(&j) {
                        base.at(r, j) + corr.panel(p)[i * 3 + (j - 3)]
                    } else {
                        base.at(r, j)
                    };
                    assert_eq!(dst.at(r, j), want);
                }
            }
        }
    }

    #[test]
    fn offset_softmax_matches_full_causal_window() {
        // decode chunk [t0, t0+m) scored against all t0+m keys must
        // reproduce rows t0.. of the full causal softmax bit-for-bit
        let (b, s, t0) = (2usize, 6usize, 4usize);
        let m = s - t0;
        let full = randb(26, b, s, s);
        let mut want = full.clone();
        softmax_rows_masked(&mut want, true);
        let mut chunk = BatchedMatrix::zeros(b, m, s);
        for p in 0..b {
            chunk.panel_mut(p).copy_from_slice(&full.panel(p)[t0 * s..]);
        }
        softmax_rows_masked_offset(&mut chunk, t0);
        for p in 0..b {
            assert_eq!(chunk.panel(p), &want.panel(p)[t0 * s..], "panel {p}");
        }
    }

    #[test]
    fn batched_matmul_nt_applies_alpha_after_the_dot() {
        let a = randb(5, 2, 3, 4);
        let b = randb(6, 2, 3, 4);
        let scaled = batched_matmul_nt(&a, &b, 0.25);
        let plain = batched_matmul_nt(&a, &b, 1.0);
        for (s, p) in scaled.data.iter().zip(plain.data.iter()) {
            assert_eq!(*s, p * 0.25);
        }
    }

    #[test]
    fn masked_softmax_matches_minus_1e30_scores() {
        // the old scalar path wrote -1e30 into masked slots then softmaxed
        // the full row; the masked kernel must be bit-identical
        let mut x = randb(7, 2, 6, 6);
        let mut reference = BatchedMatrix::zeros(2, 6, 6);
        for p in 0..2 {
            let mut m = x.to_matrix(p);
            for i in 0..6 {
                for j in (i + 1)..6 {
                    *m.at_mut(i, j) = -1e30;
                }
            }
            let sm = softmax_rows(&m);
            reference.panel_mut(p).copy_from_slice(&sm.data);
        }
        softmax_rows_masked(&mut x, true);
        assert_eq!(x.data, reference.data);
        // masked entries are exactly zero
        for p in 0..2 {
            for i in 0..6 {
                for j in (i + 1)..6 {
                    assert_eq!(x.panel(p)[i * 6 + j], 0.0);
                }
            }
        }
    }

    #[test]
    fn unmasked_softmax_rows_sum_to_one() {
        let mut x = randb(8, 3, 4, 5);
        softmax_rows_masked(&mut x, false);
        for p in 0..3 {
            for i in 0..4 {
                let sum: f32 = x.panel(p)[i * 5..(i + 1) * 5].iter().sum();
                assert!((sum - 1.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn batched_softmax_vjp_matches_matrix_vjp() {
        let mut z = randb(9, 2, 4, 4);
        softmax_rows_masked(&mut z, true);
        let dp = randb(10, 2, 4, 4);
        let dz = softmax_rows_vjp_batched(&z, &dp);
        for p in 0..2 {
            let want = softmax_rows_vjp(&z.to_matrix(p), &dp.to_matrix(p));
            assert!(dz.to_matrix(p).allclose(&want, 0.0), "panel {p}");
        }
    }
}
