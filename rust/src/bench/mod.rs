//! Bench kit: a small criterion-style harness (criterion is not in the
//! offline vendor set) plus table rendering shared by the per-table bench
//! binaries in benches/.

pub mod contract;
pub mod paper;

use crate::util::human;
use crate::util::timing::Samples;
use std::time::Instant;

/// Time a closure: `warmup` unmeasured runs, then `iters` measured ones.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Samples {
    for _ in 0..warmup {
        f();
    }
    let mut s = Samples::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    s
}

/// Print a criterion-like summary line.
pub fn report(name: &str, s: &Samples) {
    println!(
        "{name:<44} mean {:>10}  p50 {:>10}  p99 {:>10}  (n={})",
        human::duration(s.mean()),
        human::duration(s.percentile(50.0)),
        human::duration(s.percentile(99.0)),
        s.len()
    );
}

/// Plain-text table renderer for the paper-table benches.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths.iter()) {
                line.push_str(&format!("{c:<w$} | ", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let sep: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        out.push_str(&format!("{}\n", "-".repeat(sep)));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// ASCII sparkline of a loss curve for figure benches.
pub fn sparkline(values: &[f32], width: usize) -> String {
    if values.is_empty() {
        return String::new();
    }
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let lo = values.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = values.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let span = (hi - lo).max(1e-9);
    // bucket the series down to `width` points
    let stride = (values.len() as f64 / width as f64).max(1.0);
    (0..width.min(values.len()))
        .map(|i| {
            let idx = ((i as f64) * stride) as usize;
            let v = values[idx.min(values.len() - 1)];
            let g = (((v - lo) / span) * 7.0).round() as usize;
            GLYPHS[g.min(7)]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_collects_samples() {
        let s = time_it(1, 5, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(s.len(), 5);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Method", "Mem"]);
        t.row(vec!["FLORA(8)".into(), "0.75".into()]);
        t.row(vec!["Naive".into(), "0.87".into()]);
        let r = t.render();
        assert!(r.contains("== Demo =="));
        assert!(r.contains("FLORA(8)"));
        // all data lines have the same width
        let lines: Vec<&str> =
            r.lines().filter(|l| l.starts_with('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn sparkline_monotone_series() {
        let v: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let s = sparkline(&v, 8);
        assert_eq!(s.chars().count(), 8);
        let first = s.chars().next().unwrap();
        let last = s.chars().last().unwrap();
        assert!(first < last);
    }
}
