//! Versioned contract for the bench trajectory files
//! (`BENCH_kernels.json`, `BENCH_serving.json`, `BENCH_dp.json`).
//!
//! PRs 4–7 grew three append-only "schema 2" JSON trajectories, but the
//! format lived as an unspoken convention duplicated across the three
//! bench binaries: each re-implemented `append_snapshot` and — worse —
//! silently started a FRESH trajectory whenever the existing file
//! failed to parse, so a corrupted history could be overwritten without
//! anyone noticing. This module promotes the convention to a typed,
//! validated contract:
//!
//! * [`BenchFile`] / [`Snapshot`] / [`SizeRow`] — typed deserialization
//!   over the zero-dep [`crate::util::json`] values;
//! * [`BenchFile::validate`] — rejects unknown schema versions, missing
//!   provenance tags, non-monotonic `pr`/`unix_time` stamps, and
//!   NaN/negative metrics, each with a distinct path-bearing message;
//! * [`append_to_file`] — the single append path shared by all three
//!   bench binaries: the existing file must already satisfy the
//!   contract (no silent fresh-start) and the assembled document is
//!   re-validated *before* the file is touched, so a bench that
//!   produced a NaN metric can never land it on disk (the JSON
//!   renderer would downgrade it to `null` and hide the bug).
//!
//! `flora doctor` and the contract test suite (`rust/tests/ops.rs`)
//! validate the committed files through the same code path CI gates
//! on. Versioning policy lives in docs/OPS.md §1.

use std::collections::BTreeMap;
use std::fmt;

use crate::util::json::{self, Json};

/// The one trajectory schema this build reads and writes. Additive
/// snapshot fields do NOT bump this; breaking shape changes do (and
/// must ship a migration for the committed files — docs/OPS.md §1).
pub const SCHEMA_VERSION: usize = 2;

/// The committed trajectory files and the `bench` name each must carry.
pub const COMMITTED_FILES: [(&str, &str); 4] = [
    ("BENCH_kernels.json", "micro_kernels"),
    ("BENCH_serving.json", "serving"),
    ("BENCH_dp.json", "dp"),
    ("BENCH_ablation.json", "ablation"),
];

/// What is wrong with a metric value ([`ContractError::BadMetric`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricFault {
    /// NaN or ±Inf — only constructible in memory; the JSON renderer
    /// would have silently written `null`, which is why appends
    /// validate the typed document *before* rendering.
    NonFinite,
    /// All trajectory metrics are magnitudes (tok/s, bytes, ratios,
    /// losses on these tasks); a negative value is a harness bug.
    Negative,
}

/// A contract violation. Every variant renders a distinct message and
/// carries the file path (or a caller-chosen label for in-memory
/// documents) so CI logs and doctor receipts name the offender.
#[derive(Clone, Debug, PartialEq)]
pub enum ContractError {
    /// The file could not be read at all.
    Io { path: String, msg: String },
    /// The bytes are not valid JSON (truncation, corruption).
    Parse { path: String, msg: String },
    /// Valid JSON with the wrong shape (missing/mistyped fields).
    Shape { path: String, msg: String },
    /// The file's `bench` name is not the one the caller expected.
    WrongBench {
        path: String,
        want: String,
        found: String,
    },
    /// `schema` is absent or not [`SCHEMA_VERSION`].
    UnknownSchema { path: String, found: Option<usize> },
    /// A contract-valid file carries at least one snapshot.
    EmptyTrajectory { path: String },
    /// Snapshot `index` has no provenance tag.
    MissingProvenance { path: String, index: usize },
    /// `pr` or `unix_time` decreased between consecutive snapshots.
    NonMonotonic {
        path: String,
        field: &'static str,
        index: usize,
        prev: u64,
        found: u64,
    },
    /// A metric value is NaN/Inf or negative.
    BadMetric {
        path: String,
        index: usize,
        model: String,
        key: String,
        fault: MetricFault,
    },
}

impl fmt::Display for ContractError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ContractError::Io { path, msg } => write!(f, "{path}: cannot read: {msg}"),
            ContractError::Parse { path, msg } => {
                write!(f, "{path}: invalid JSON (truncated or corrupt): {msg}")
            }
            ContractError::Shape { path, msg } => write!(f, "{path}: {msg}"),
            ContractError::WrongBench { path, want, found } => write!(
                f,
                "{path}: bench name {found:?} does not match the expected {want:?}"
            ),
            ContractError::UnknownSchema { path, found } => {
                let found = match found {
                    Some(v) => v.to_string(),
                    None => "none".to_string(),
                };
                write!(
                    f,
                    "{path}: unsupported schema version {found} — this build reads \
                     schema {SCHEMA_VERSION} only (versioning policy: docs/OPS.md)"
                )
            }
            ContractError::EmptyTrajectory { path } => write!(
                f,
                "{path}: trajectory is empty — a contract-valid bench file \
                 carries at least one snapshot"
            ),
            ContractError::MissingProvenance { path, index } => write!(
                f,
                "{path}: trajectory[{index}] has no provenance tag — every \
                 snapshot must say how it was measured (cargo-bench vs c-mirror)"
            ),
            ContractError::NonMonotonic {
                path,
                field,
                index,
                prev,
                found,
            } => write!(
                f,
                "{path}: trajectory[{index}] {field} {found} goes backwards \
                 from {prev} — trajectories are append-only"
            ),
            ContractError::BadMetric {
                path,
                index,
                model,
                key,
                fault,
            } => {
                let what = match fault {
                    MetricFault::NonFinite => "NaN/non-finite",
                    MetricFault::Negative => "negative",
                };
                write!(
                    f,
                    "{path}: trajectory[{index}] size {model:?} metric {key:?} is {what}"
                )
            }
        }
    }
}

impl std::error::Error for ContractError {}

/// One measured size inside a snapshot. Numeric fields become
/// `metrics` (JSON `null` → `None`, e.g. the dp seed's unmeasured
/// `final_loss`); string fields become `tags` (family, reduce mode…).
#[derive(Clone, Debug, PartialEq)]
pub struct SizeRow {
    pub model: String,
    pub metrics: BTreeMap<String, Option<f64>>,
    pub tags: BTreeMap<String, String>,
}

/// One appended bench run. All fields except `sizes` are optional at
/// *parse* time; [`BenchFile::validate`] additionally demands
/// provenance and monotone `pr`/`unix_time` stamps.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub pr: Option<u64>,
    pub unix_time: Option<u64>,
    pub label: Option<String>,
    pub runtime: Option<String>,
    pub parallelism: Option<u64>,
    pub quick: Option<bool>,
    pub provenance: Option<String>,
    pub sizes: Vec<SizeRow>,
}

/// A whole trajectory file, typed.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchFile {
    pub bench: String,
    pub schema: Option<usize>,
    pub comment: Option<String>,
    pub trajectory: Vec<Snapshot>,
}

fn shape(path: &str, msg: String) -> ContractError {
    ContractError::Shape {
        path: path.to_string(),
        msg,
    }
}

fn opt_u64(doc: &Json, key: &str, path: &str, ctx: &str) -> Result<Option<u64>, ContractError> {
    match doc.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Num(v)) if v.is_finite() && *v >= 0.0 && v.fract() == 0.0 => Ok(Some(*v as u64)),
        Some(_) => Err(shape(
            path,
            format!("{ctx} field {key:?} is not a non-negative integer"),
        )),
    }
}

fn opt_str(doc: &Json, key: &str, path: &str, ctx: &str) -> Result<Option<String>, ContractError> {
    match doc.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Str(s)) => Ok(Some(s.clone())),
        Some(_) => Err(shape(path, format!("{ctx} field {key:?} is not a string"))),
    }
}

impl BenchFile {
    /// Parse JSON text into a typed file. `path` only labels errors.
    pub fn parse(path: &str, text: &str) -> Result<Self, ContractError> {
        let doc = json::parse(text).map_err(|e| ContractError::Parse {
            path: path.to_string(),
            msg: e.to_string(),
        })?;
        Self::from_json(path, &doc)
    }

    /// Type an already-parsed JSON document (shape checks only — run
    /// [`BenchFile::validate`] for the semantic contract).
    pub fn from_json(path: &str, doc: &Json) -> Result<Self, ContractError> {
        let root = doc
            .as_obj()
            .ok_or_else(|| shape(path, "top level is not a JSON object".into()))?;
        let bench = match root.get("bench") {
            Some(Json::Str(s)) => s.clone(),
            _ => return Err(shape(path, "missing or non-string \"bench\" name".into())),
        };
        let schema = match root.get("schema") {
            None | Some(Json::Null) => None,
            Some(j) => j.as_usize(), // non-integer numbers read as "unknown version"
        };
        let comment = opt_str(doc, "comment", path, "top-level")?;
        let entries = match root.get("trajectory") {
            Some(Json::Arr(a)) => a,
            _ => return Err(shape(path, "missing \"trajectory\" array".into())),
        };
        let mut trajectory = Vec::with_capacity(entries.len());
        for (i, entry) in entries.iter().enumerate() {
            trajectory.push(Snapshot::from_json(path, i, entry)?);
        }
        Ok(BenchFile {
            bench,
            schema,
            comment,
            trajectory,
        })
    }

    /// Read + parse + validate a file on disk.
    pub fn load(path: &str) -> Result<Self, ContractError> {
        let text = std::fs::read_to_string(path).map_err(|e| ContractError::Io {
            path: path.to_string(),
            msg: e.to_string(),
        })?;
        let file = Self::parse(path, &text)?;
        file.validate(path)?;
        Ok(file)
    }

    /// Enforce the semantic contract: known schema version, non-empty
    /// trajectory, provenance on every snapshot, monotone `pr` /
    /// `unix_time` stamps, and finite non-negative metrics.
    pub fn validate(&self, path: &str) -> Result<(), ContractError> {
        if self.bench.is_empty() {
            return Err(shape(path, "\"bench\" name is empty".into()));
        }
        if self.schema != Some(SCHEMA_VERSION) {
            return Err(ContractError::UnknownSchema {
                path: path.to_string(),
                found: self.schema,
            });
        }
        if self.trajectory.is_empty() {
            return Err(ContractError::EmptyTrajectory {
                path: path.to_string(),
            });
        }
        let mut last_pr: Option<u64> = None;
        let mut last_time: Option<u64> = None;
        for (i, snap) in self.trajectory.iter().enumerate() {
            if snap.provenance.as_deref().unwrap_or("").is_empty() {
                return Err(ContractError::MissingProvenance {
                    path: path.to_string(),
                    index: i,
                });
            }
            if snap.sizes.is_empty() {
                return Err(shape(
                    path,
                    format!("trajectory[{i}] has no size rows — nothing was measured"),
                ));
            }
            for (field, value, last) in [
                ("pr", snap.pr, &mut last_pr),
                ("unix_time", snap.unix_time, &mut last_time),
            ] {
                if let Some(v) = value {
                    if let Some(prev) = *last {
                        if v < prev {
                            return Err(ContractError::NonMonotonic {
                                path: path.to_string(),
                                field,
                                index: i,
                                prev,
                                found: v,
                            });
                        }
                    }
                    *last = Some(v);
                }
            }
            for row in &snap.sizes {
                for (key, value) in &row.metrics {
                    let Some(v) = value else { continue }; // null = unmeasured, fine
                    let fault = if !v.is_finite() {
                        Some(MetricFault::NonFinite)
                    } else if *v < 0.0 {
                        Some(MetricFault::Negative)
                    } else {
                        None
                    };
                    if let Some(fault) = fault {
                        return Err(ContractError::BadMetric {
                            path: path.to_string(),
                            index: i,
                            model: row.model.clone(),
                            key: key.clone(),
                            fault,
                        });
                    }
                }
            }
        }
        Ok(())
    }
}

impl Snapshot {
    fn from_json(path: &str, index: usize, doc: &Json) -> Result<Self, ContractError> {
        let ctx = format!("trajectory[{index}]");
        if doc.as_obj().is_none() {
            return Err(shape(path, format!("{ctx} is not an object")));
        }
        let sizes_json = match doc.get("sizes") {
            Some(Json::Arr(a)) => a.as_slice(),
            None => &[],
            Some(_) => return Err(shape(path, format!("{ctx} field \"sizes\" is not an array"))),
        };
        let mut sizes = Vec::with_capacity(sizes_json.len());
        for (j, row) in sizes_json.iter().enumerate() {
            sizes.push(SizeRow::from_json(path, &format!("{ctx} sizes[{j}]"), row)?);
        }
        Ok(Snapshot {
            pr: opt_u64(doc, "pr", path, &ctx)?,
            unix_time: opt_u64(doc, "unix_time", path, &ctx)?,
            label: opt_str(doc, "label", path, &ctx)?,
            runtime: opt_str(doc, "runtime", path, &ctx)?,
            parallelism: opt_u64(doc, "parallelism", path, &ctx)?,
            quick: match doc.get("quick") {
                None | Some(Json::Null) => None,
                Some(Json::Bool(b)) => Some(*b),
                Some(_) => {
                    return Err(shape(path, format!("{ctx} field \"quick\" is not a bool")))
                }
            },
            provenance: opt_str(doc, "provenance", path, &ctx)?,
            sizes,
        })
    }
}

impl SizeRow {
    fn from_json(path: &str, ctx: &str, doc: &Json) -> Result<Self, ContractError> {
        let obj = doc
            .as_obj()
            .ok_or_else(|| shape(path, format!("{ctx} is not an object")))?;
        let model = match obj.get("model") {
            Some(Json::Str(s)) => s.clone(),
            _ => return Err(shape(path, format!("{ctx} has no string \"model\" key"))),
        };
        let mut metrics = BTreeMap::new();
        let mut tags = BTreeMap::new();
        for (key, value) in obj {
            if key == "model" {
                continue;
            }
            match value {
                Json::Num(v) => {
                    metrics.insert(key.clone(), Some(*v));
                }
                Json::Null => {
                    metrics.insert(key.clone(), None);
                }
                Json::Str(s) => {
                    tags.insert(key.clone(), s.clone());
                }
                Json::Bool(b) => {
                    tags.insert(key.clone(), b.to_string());
                }
                Json::Arr(_) | Json::Obj(_) => {
                    return Err(shape(
                        path,
                        format!("{ctx} key {key:?} nests an array/object — sizes are flat"),
                    ));
                }
            }
        }
        Ok(SizeRow {
            model,
            metrics,
            tags,
        })
    }
}

/// Seconds since the Unix epoch, for stamping appended snapshots.
/// Exact to well under f64 precision, so round-trips through JSON.
pub fn unix_time_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Append `snapshot` to the schema-2 trajectory at `path` — the single
/// append path for all three bench binaries.
///
/// * missing file → a fresh one-snapshot trajectory (first run in a
///   scratch checkout);
/// * existing file → must parse AND validate under the contract with
///   the expected `bench` name. This replaces the old per-bench
///   behaviour of silently starting over on a corrupt file.
/// * the assembled document is validated again before rendering, so a
///   NaN/negative fresh metric fails the bench here instead of being
///   laundered to `null` by the renderer.
///
/// Existing trajectory entries are carried over as raw JSON — appends
/// never reformat history.
pub fn append_to_file(
    path: &str,
    bench: &str,
    comment: &str,
    snapshot: Json,
) -> Result<(), String> {
    let mut trajectory: Vec<Json> = Vec::new();
    match std::fs::read_to_string(path) {
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
        Err(e) => return Err(format!("{path}: cannot read: {e}")),
        Ok(text) => {
            let existing = BenchFile::parse(path, &text).map_err(|e| e.to_string())?;
            existing.validate(path).map_err(|e| e.to_string())?;
            if existing.bench != bench {
                return Err(ContractError::WrongBench {
                    path: path.to_string(),
                    want: bench.to_string(),
                    found: existing.bench,
                }
                .to_string());
            }
            // parse succeeded above; keep the raw entries untouched
            if let Some(arr) = json::parse(&text)
                .ok()
                .as_ref()
                .and_then(|d| d.get("trajectory"))
                .and_then(Json::as_arr)
            {
                trajectory = arr.to_vec();
            }
        }
    }
    trajectory.push(snapshot);

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str(bench.to_string()));
    root.insert("schema".to_string(), Json::Num(SCHEMA_VERSION as f64));
    root.insert("comment".to_string(), Json::Str(comment.to_string()));
    root.insert("trajectory".to_string(), Json::Arr(trajectory));
    let doc = Json::Obj(root);

    let typed = BenchFile::from_json(path, &doc).map_err(|e| e.to_string())?;
    typed.validate(path).map_err(|e| e.to_string())?;

    std::fs::write(path, doc.render()).map_err(|e| format!("{path}: cannot write: {e}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn valid_text() -> String {
        r#"{
  "bench": "micro_kernels",
  "schema": 2,
  "comment": "t",
  "trajectory": [
    {
      "pr": 4,
      "provenance": "c-mirror/gemm-path (gcc -O2)",
      "sizes": [{"model": "lora-tiny", "forward_tok_s": 100.5, "family": "lm"}]
    },
    {
      "pr": 5,
      "unix_time": 1700000000,
      "provenance": "cargo-bench micro_kernels",
      "quick": true,
      "sizes": [{"model": "lora-tiny", "forward_tok_s": 120.0, "final_loss": null}]
    }
  ]
}"#
        .to_string()
    }

    #[test]
    fn parses_and_validates_a_healthy_file() {
        let f = BenchFile::parse("t.json", &valid_text()).expect("parse");
        f.validate("t.json").expect("validate");
        assert_eq!(f.bench, "micro_kernels");
        assert_eq!(f.schema, Some(2));
        assert_eq!(f.trajectory.len(), 2);
        let row = &f.trajectory[1].sizes[0];
        assert_eq!(row.metrics["forward_tok_s"], Some(120.0));
        assert_eq!(row.metrics["final_loss"], None); // null = unmeasured
        assert_eq!(f.trajectory[0].sizes[0].tags["family"], "lm");
    }

    #[test]
    fn append_creates_then_extends_and_refuses_corruption() {
        let dir = std::env::temp_dir().join(format!("flora-contract-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_t.json");
        let path = path.to_str().unwrap();
        let snap = |tok: f64| {
            json::parse(&format!(
                r#"{{"provenance": "cargo-bench t", "unix_time": 10,
                     "sizes": [{{"model": "m", "tok_s": {tok}}}]}}"#
            ))
            .unwrap()
        };
        append_to_file(path, "t", "c", snap(1.0)).expect("fresh append");
        append_to_file(path, "t", "c", snap(2.0)).expect("second append");
        let f = BenchFile::load(path).expect("load");
        assert_eq!(f.trajectory.len(), 2);

        let err = append_to_file(path, "other", "c", snap(3.0)).unwrap_err();
        assert!(err.contains("does not match"), "{err}");

        // corrupt the file: appends must refuse, not silently restart
        let text = std::fs::read_to_string(path).unwrap();
        std::fs::write(path, &text[..text.len() / 2]).unwrap();
        let err = append_to_file(path, "t", "c", snap(3.0)).unwrap_err();
        assert!(err.contains("invalid JSON"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn append_rejects_nan_before_the_renderer_can_launder_it() {
        let dir = std::env::temp_dir().join(format!("flora-contract-nan-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_nan.json");
        let path = path.to_str().unwrap();
        let mut row = BTreeMap::new();
        row.insert("model".to_string(), Json::Str("m".into()));
        row.insert("tok_s".to_string(), Json::Num(f64::NAN));
        let mut snap = BTreeMap::new();
        snap.insert("provenance".to_string(), Json::Str("cargo-bench t".into()));
        snap.insert("sizes".to_string(), Json::Arr(vec![Json::Obj(row)]));
        let err = append_to_file(path, "t", "c", Json::Obj(snap)).unwrap_err();
        assert!(err.contains("NaN"), "{err}");
        assert!(!std::path::Path::new(path).exists(), "file must not be written");
        std::fs::remove_dir_all(&dir).ok();
    }
}
