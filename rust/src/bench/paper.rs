//! Shared harness for the paper-table benches (benches/table*.rs).
//!
//! Each bench binary declares a method grid; this module trains every cell
//! on the real artifacts, pulls the measured metric, pairs it with the
//! analytic paper-scale memory numbers (memory::breakdown at the paper's
//! model sizes — DESIGN.md §4 explains why byte-accounting scales exactly),
//! and renders rows shaped like the paper's tables.

use std::cell::RefCell;
use std::rc::Rc;

use crate::bench::Table;
use crate::config::{TaskKind, TrainConfig};
use crate::coordinator::{MethodSpec, RunReport, Trainer};
use crate::memory::{self, Dims, OptKind, StateRole};
use crate::opt::OptimizerKind;
use crate::runtime::Runtime;
use crate::util::human;

/// One bench cell: a method at paper rank + the scaled local rank.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    pub method: MethodSpec,
    /// rank in the PAPER's scale (e.g. 256 on d=512) for the memory column
    pub paper_rank: u64,
}

/// The paper↔local rank mapping: the paper sweeps r ∈ {8..256} on d=512
/// (ratio 1/64..1/2); lm-small has d=64, so local ranks {4..32} cover
/// ratios 1/16..1/2 (we skip the degenerate r<4).
pub fn rank_pairs() -> [(usize, u64); 4] {
    [(4, 8), (8, 32), (16, 128), (32, 256)]
}

/// Standard method grid of Tables 1/2/4: None, Naive, LoRA(r)×4, FLORA(r)×4.
pub fn table_grid() -> Vec<Cell> {
    let mut cells = vec![
        Cell { method: MethodSpec::None, paper_rank: 0 },
        Cell { method: MethodSpec::Naive, paper_rank: 0 },
    ];
    for (local, paper) in rank_pairs() {
        cells.push(Cell { method: MethodSpec::Lora { rank: local }, paper_rank: paper });
    }
    for (local, paper) in rank_pairs() {
        cells.push(Cell { method: MethodSpec::Flora { rank: local }, paper_rank: paper });
    }
    cells
}

/// Train one cell and return its report. Failures become Err strings so a
/// bench can report and continue.
pub fn run_cell(
    base: &TrainConfig,
    cell: &Cell,
    rt: &Rc<RefCell<Runtime>>,
) -> Result<RunReport, String> {
    let mut cfg = base.clone();
    cfg.method = cell.method;
    // LoRA gets its own (higher) LR, as the paper tunes it separately
    if cell.method.is_lora() {
        cfg.lr = (cfg.lr * 4.0).min(0.2);
    }
    // Every row gets the same number of OPTIMIZER STEPS. The paper instead
    // equalizes epochs (its "None" updates per physical batch at batch=1,
    // where accumulation's variance reduction decides None < Naive); our
    // artifacts train at batch=4 where that noise effect is not binding,
    // so equal-steps keeps the rows comparable and the table's point — the
    // FLORA-vs-LoRA-vs-Naive compression comparison — intact (see
    // EXPERIMENTS.md §Table 1 for the discussion).
    let mut tr = Trainer::with_runtime(cfg, rt.clone())?;
    tr.run()
}

/// One shared runtime for a whole bench grid (one backend + prepare cache).
/// `spec` is `"native"` or an artifacts directory (see `BenchArgs::spec`).
pub fn shared_runtime(spec: &str) -> Result<Rc<RefCell<Runtime>>, String> {
    Ok(Rc::new(RefCell::new(Runtime::from_spec(spec)?)))
}

/// The paper-scale memory method mirroring a cell (paper ranks).
fn paper_method(cell: &Cell) -> memory::Method {
    match cell.method {
        MethodSpec::None => memory::Method::None,
        MethodSpec::Naive => memory::Method::Naive,
        MethodSpec::Lora { .. } => memory::Method::Lora(cell.paper_rank),
        MethodSpec::Flora { .. } | MethodSpec::FloraNoTransfer { .. } => {
            memory::Method::Flora(cell.paper_rank)
        }
        MethodSpec::Galore { .. } => memory::Method::Galore(cell.paper_rank),
    }
}

/// Label like the paper: method name with the PAPER-scale rank.
pub fn paper_label(cell: &Cell) -> String {
    match cell.method {
        MethodSpec::Lora { .. } => format!("LoRA({})", cell.paper_rank),
        MethodSpec::Flora { .. } => format!("FLORA({})", cell.paper_rank),
        MethodSpec::FloraNoTransfer { .. } => {
            format!("FLORA-noT({})", cell.paper_rank)
        }
        MethodSpec::Galore { .. } => format!("GaLore({})", cell.paper_rank),
        m => m.label(),
    }
}

/// Render one paper-style table: analytic Mem/ΔM at `paper_dims` + measured
/// quality/state from the local runs.
#[allow(clippy::too_many_arguments)]
pub fn render_table(
    title: &str,
    size_label: &str,
    paper_dims: &Dims,
    opt: OptKind,
    role: StateRole,
    cells: &[Cell],
    reports: &[Result<RunReport, String>],
    metric_header: &str,
) -> Table {
    let mut t = Table::new(
        title,
        &[
            "Size", "Method", "Mem(GiB)", "ΔM(GiB)", metric_header,
            "loss", "state(local)", "steps/s",
        ],
    );
    let none_total =
        memory::breakdown(paper_dims, memory::Method::None, opt, role, 1, false)
            .total();
    for (cell, rep) in cells.iter().zip(reports.iter()) {
        let b = memory::breakdown(paper_dims, paper_method(cell), opt, role, 1, false);
        let dm = b.total() as i64 - none_total as i64;
        let (metric, loss, state, sps) = match rep {
            Ok(r) => (
                r.metric.map(|m| m.render()).unwrap_or_else(|| "-".into()),
                format!("{:.3}", r.final_train_loss()),
                human::bytes(r.total_state_bytes()),
                format!("{:.2}", r.steps_per_sec),
            ),
            Err(e) => (format!("ERR {e}"), "-".into(), "-".into(), "-".into()),
        };
        t.row(vec![
            size_label.to_string(),
            paper_label(cell),
            format!("{:.2}", human::gib(b.total())),
            if cell.method == MethodSpec::None {
                "-".into()
            } else {
                format!("{:.2}", human::gib(dm.max(0) as u64))
            },
            metric,
            loss,
            state,
            sps,
        ]);
    }
    t
}

/// Also render the large-model analytic rows the paper reports but which we
/// cannot train locally (T5-3B, GPT-2-XL): memory columns only.
pub fn render_analytic_only(
    title: &str,
    size_label: &str,
    paper_dims: &Dims,
    opt: OptKind,
    role: StateRole,
    cells: &[Cell],
) -> Table {
    let mut t = Table::new(title, &["Size", "Method", "Mem(GiB)", "ΔM(GiB)"]);
    let none_total =
        memory::breakdown(paper_dims, memory::Method::None, opt, role, 1, false)
            .total();
    for cell in cells {
        let b = memory::breakdown(paper_dims, paper_method(cell), opt, role, 1, false);
        let dm = b.total() as i64 - none_total as i64;
        t.row(vec![
            size_label.to_string(),
            paper_label(cell),
            format!("{:.2}", human::gib(b.total())),
            if cell.method == MethodSpec::None {
                "-".into()
            } else {
                format!("{:.2}", human::gib(dm.max(0) as u64))
            },
        ]);
    }
    t
}

/// Bench-binary arg parsing: `--quick` (fewer steps), `--steps N`,
/// `--artifacts DIR`, `--backend native|xla`,
/// `--optimizer sgd|adam|adafactor|adafactor_nofactor`,
/// `--model NAME` (e.g. `lora-small` to run a table on a different
/// native-catalog size than its default), `--parallelism N` (kernel
/// thread budget, installed process-wide; results are bit-identical at
/// every N), `--workers N` (dp worker count for `--bench dp`; results
/// are bit-identical at every N), `--runtime pool|scope` (parallel
/// driver: the persistent
/// worker pool, or the retained per-call `thread::scope` baseline for
/// A/B perf comparisons — results are bit-identical either way).
/// cargo bench passes `--bench`; ignore unknown flags.
pub struct BenchArgs {
    pub quick: bool,
    pub steps: Option<usize>,
    pub artifacts: String,
    /// `"xla"` (artifacts via PJRT) or `"native"` (pure-rust executor).
    pub backend: String,
    /// Base-optimizer override for every measured cell (tables default to
    /// the paper's Adafactor; both backends execute all of them).
    pub optimizer: Option<OptimizerKind>,
    /// Model override for every measured cell (tables default to
    /// lm-small; `lora-tiny`/`lora-small`/... sweep the native
    /// transformer size grid).
    pub model: Option<String>,
    /// Kernel thread budget (`tensor::Parallelism`), already installed
    /// by `parse()`.
    pub parallelism: crate::tensor::Parallelism,
    /// dp worker count (`--bench dp` only; other benches ignore it).
    pub workers: usize,
}

impl BenchArgs {
    pub fn parse() -> Self {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let mut out = Self {
            quick: false,
            steps: None,
            artifacts: "artifacts".into(),
            backend: "xla".into(),
            optimizer: None,
            model: None,
            parallelism: crate::tensor::Parallelism::single(),
            workers: 1,
        };
        // --runtime is order-independent of --parallelism: remember the
        // driver choice, apply it to the final thread budget below
        let mut scope_driver = false;
        let mut i = 0;
        while i < argv.len() {
            match argv[i].as_str() {
                "--quick" => out.quick = true,
                "--steps" if i + 1 < argv.len() => {
                    out.steps = argv[i + 1].parse().ok();
                    i += 1;
                }
                "--parallelism" if i + 1 < argv.len() => {
                    match argv[i + 1].parse::<usize>() {
                        Ok(n) if n >= 1 => {
                            out.parallelism = crate::tensor::Parallelism::new(n)
                        }
                        _ => {
                            eprintln!(
                                "--parallelism: expected integer >= 1, got {:?}",
                                argv[i + 1]
                            );
                            std::process::exit(2);
                        }
                    }
                    i += 1;
                }
                "--workers" if i + 1 < argv.len() => {
                    match argv[i + 1].parse::<usize>() {
                        Ok(n) if n >= 1 => out.workers = n,
                        _ => {
                            eprintln!(
                                "--workers: expected integer >= 1, got {:?}",
                                argv[i + 1]
                            );
                            std::process::exit(2);
                        }
                    }
                    i += 1;
                }
                "--runtime" if i + 1 < argv.len() => {
                    match argv[i + 1].as_str() {
                        "pool" => scope_driver = false,
                        "scope" => scope_driver = true,
                        other => {
                            eprintln!("--runtime: expected pool|scope, got {other:?}");
                            std::process::exit(2);
                        }
                    }
                    i += 1;
                }
                "--artifacts" if i + 1 < argv.len() => {
                    out.artifacts = argv[i + 1].clone();
                    i += 1;
                }
                "--model" if i + 1 < argv.len() => {
                    out.model = Some(argv[i + 1].clone());
                    i += 1;
                }
                "--optimizer" if i + 1 < argv.len() => {
                    match OptimizerKind::parse(&argv[i + 1]) {
                        Ok(o) => out.optimizer = Some(o),
                        Err(e) => {
                            eprintln!("--optimizer: {e}");
                            std::process::exit(2);
                        }
                    }
                    i += 1;
                }
                "--backend" if i + 1 < argv.len() => {
                    out.backend = argv[i + 1].clone();
                    i += 1;
                    if out.backend != "native" && out.backend != "xla" {
                        eprintln!(
                            "--backend: expected native|xla, got {:?}",
                            out.backend
                        );
                        std::process::exit(2);
                    }
                }
                _ => {}
            }
            i += 1;
        }
        if scope_driver {
            out.parallelism =
                crate::tensor::Parallelism::scoped(out.parallelism.threads());
        }
        // install the thread budget for every kernel this bench runs;
        // bit-identical results at any setting/driver, so this only
        // moves time
        out.parallelism.install();
        out
    }

    /// The `Runtime::from_spec` argument for this invocation.
    pub fn spec(&self) -> &str {
        if self.backend == "native" {
            "native"
        } else {
            &self.artifacts
        }
    }

    /// Apply the CLI overrides a bench honors per cell: the `--optimizer`
    /// selector, the `--model` override (the native backend executes
    /// every base optimizer, so no per-backend remap is needed anymore)
    /// and the `--parallelism` thread budget (Trainer installs it from
    /// the config, so it must ride along per cell).
    pub fn adjust(&self, cfg: &mut TrainConfig) {
        if let Some(opt) = self.optimizer {
            cfg.optimizer = opt;
        }
        if let Some(model) = &self.model {
            cfg.model = model.clone();
        }
        cfg.parallelism = self.parallelism;
        cfg.workers = self.workers;
    }

    /// True when the selected backend can run the measured cells: always
    /// for the native backend, artifacts-present for the PJRT one.
    pub fn require_artifacts(&self) -> bool {
        if self.backend == "native" {
            return true;
        }
        let ok = std::path::Path::new(&self.artifacts)
            .join("manifest.json")
            .exists();
        if !ok {
            println!(
                "artifacts/manifest.json not found — run `make artifacts` \
                 first or pass `--backend native`; printing analytic-only \
                 tables."
            );
        }
        ok
    }
}

/// Base config shared by the table benches.
pub fn base_config(task: TaskKind, steps: usize, tau: usize) -> TrainConfig {
    TrainConfig {
        model: "lm-small".into(),
        task,
        method: MethodSpec::Naive,
        optimizer: OptimizerKind::Adafactor,
        lr: 0.05,
        steps,
        tau,
        kappa: 50,
        batch: 4,
        seed: 0,
        eval_every: 0,
        eval_samples: 32,
        ..Default::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_has_ten_methods() {
        let g = table_grid();
        assert_eq!(g.len(), 10);
        assert_eq!(g[0].method, MethodSpec::None);
        assert_eq!(g[1].method, MethodSpec::Naive);
    }

    #[test]
    fn rank_mapping_monotone() {
        let pairs = rank_pairs();
        for w in pairs.windows(2) {
            assert!(w[0].0 < w[1].0 && w[0].1 < w[1].1);
        }
    }

    #[test]
    fn paper_labels_use_paper_ranks() {
        let c = Cell { method: MethodSpec::Flora { rank: 16 }, paper_rank: 128 };
        assert_eq!(paper_label(&c), "FLORA(128)");
    }

    #[test]
    fn bench_args_native_backend() {
        let args = BenchArgs {
            quick: false,
            steps: None,
            artifacts: "artifacts".into(),
            backend: "native".into(),
            optimizer: None,
            model: None,
            parallelism: crate::tensor::Parallelism::single(),
            workers: 1,
        };
        assert_eq!(args.spec(), "native");
        assert!(args.require_artifacts(), "native never needs artifacts");
        // no override: the paper's Adafactor base runs natively as-is
        let mut cfg = base_config(TaskKind::Sum, 1, 1);
        args.adjust(&mut cfg);
        assert_eq!(cfg.optimizer, OptimizerKind::Adafactor);
        // explicit --optimizer / --model flow into every cell
        let args = BenchArgs {
            optimizer: Some(OptimizerKind::Adam),
            model: Some("lora-tiny".into()),
            ..args
        };
        args.adjust(&mut cfg);
        assert_eq!(cfg.optimizer, OptimizerKind::Adam);
        assert_eq!(cfg.model, "lora-tiny");
    }

    #[test]
    fn analytic_table_renders_flora_below_naive() {
        let dims = Dims::t5_small_sim();
        let cells = table_grid();
        let t = render_analytic_only(
            "x", "60M", &dims, OptKind::Adafactor, StateRole::Accumulation, &cells,
        );
        assert_eq!(t.rows.len(), 10);
        // FLORA(256) ΔM < Naive ΔM
        let naive_dm: f64 = t.rows[1][3].parse().unwrap();
        let flora256_dm: f64 = t.rows[9][3].parse().unwrap();
        assert!(flora256_dm < naive_dm);
    }
}
