//! Shared utilities: seeded RNG, a minimal JSON parser (serde is not
//! available in the offline vendor set), logging, humanized formatting and
//! wall-clock timing.

pub mod human;
pub mod json;
pub mod log;
pub mod rng;
pub mod timing;
