//! Human-readable formatting for the bench tables (GiB memory columns,
//! parameter counts, durations) — the output format mirrors the paper's.

/// Bytes -> "X.XX GiB" / "X.X MiB" / "X KiB", paper-style (1024^3 GiB).
pub fn bytes(n: u64) -> String {
    const K: f64 = 1024.0;
    let x = n as f64;
    if x >= K * K * K {
        format!("{:.2} GiB", x / (K * K * K))
    } else if x >= K * K {
        format!("{:.1} MiB", x / (K * K))
    } else if x >= K {
        format!("{:.0} KiB", x / K)
    } else {
        format!("{n} B")
    }
}

/// Bytes as a fractional GiB number (the unit used in Tables 1-4).
pub fn gib(n: u64) -> f64 {
    n as f64 / (1024.0 * 1024.0 * 1024.0)
}

/// Parameter counts: "60M", "1.5B", matching the paper's Size column.
pub fn params(n: u64) -> String {
    if n >= 1_000_000_000 {
        let b = n as f64 / 1e9;
        if b.fract() < 0.05 {
            format!("{:.0}B", b)
        } else {
            format!("{:.1}B", b)
        }
    } else if n >= 1_000_000 {
        format!("{:.0}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.0}K", n as f64 / 1e3)
    } else {
        n.to_string()
    }
}

pub fn duration(secs: f64) -> String {
    if secs >= 60.0 {
        format!("{:.0}m{:02.0}s", (secs / 60.0).floor(), secs % 60.0)
    } else if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.1}us", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2048), "2 KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.0 MiB");
        assert_eq!(bytes(5_368_709_120), "5.00 GiB");
    }

    #[test]
    fn param_units() {
        assert_eq!(params(60_000_000), "60M");
        assert_eq!(params(1_500_000_000), "1.5B");
        assert_eq!(params(3_000_000_000), "3B");
        assert_eq!(params(900), "900");
    }

    #[test]
    fn durations() {
        assert_eq!(duration(0.0005), "500.0us");
        assert_eq!(duration(0.25), "250.00ms");
        assert_eq!(duration(2.5), "2.50s");
        assert_eq!(duration(90.0), "1m30s");
    }

    #[test]
    fn gib_roundtrip() {
        assert!((gib(1_073_741_824) - 1.0).abs() < 1e-9);
    }
}
