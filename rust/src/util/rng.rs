//! Deterministic, seedable RNG for the pure-rust substrate (pilot study,
//! synthetic data, rust-side random projections).
//!
//! xorshift64* core + Box–Muller Gaussians. This is intentionally an
//! *independent* generator from JAX's threefry: the rust side validates the
//! FLORA *algorithm* (distributional properties), not bitwise parity with
//! the XLA graphs — seeds that cross the AOT boundary are consumed by
//! threefry inside the graph.

/// xorshift64* (Vigna 2016). Passes BigCrush for our purposes; tiny state.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    /// cached second Gaussian from Box–Muller
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point; splitmix the seed once so small
        // consecutive seeds produce uncorrelated streams.
        let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        Self { state: z.max(1), spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // take the top 53 bits for a dyadic uniform
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard Gaussian via Box–Muller (polar-free form).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        // u in (0,1] to avoid ln(0)
        let u = 1.0 - self.next_f64();
        let v = self.next_f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    #[inline]
    pub fn next_gaussian_f32(&mut self) -> f32 {
        self.next_gaussian() as f32
    }

    /// Fill a slice with N(0, sigma^2) samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32], sigma: f32) {
        for x in out.iter_mut() {
            *x = self.next_gaussian_f32() * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Derive a sub-seed: same role as flora.derive_seed on the python side
/// (independent streams per (base, index)), different constants are fine.
pub fn derive_seed(base: u64, index: u64) -> u64 {
    base.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(index.wrapping_mul(0xD1B5_4A32_D192_ED03))
        .wrapping_add(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gaussian();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.next_below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn derive_seed_distinct() {
        let mut set = std::collections::HashSet::new();
        for i in 0..1000 {
            set.insert(derive_seed(42, i));
        }
        assert_eq!(set.len(), 1000);
    }

    #[test]
    fn weighted_sampling_distribution() {
        let mut r = Rng::new(9);
        let w = [1.0, 3.0];
        let mut c = [0usize; 2];
        for _ in 0..40_000 {
            c[r.sample_weighted(&w)] += 1;
        }
        let frac = c[1] as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }
}
