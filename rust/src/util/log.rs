//! Tiny leveled logger writing to stderr. The `log` crate facade is in the
//! vendor set, but a zero-dep built-in keeps initialization trivial and the
//! output format uniform across bins/benches/examples.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

#[derive(Clone, Copy, Debug, PartialEq, PartialOrd)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level_from_env() {
    match std::env::var("FLORA_LOG").as_deref() {
        Ok("debug") => set_level(Level::Debug),
        Ok("warn") => set_level(Level::Warn),
        Ok("error") => set_level(Level::Error),
        _ => set_level(Level::Info),
    }
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if (level as u8) < LEVEL.load(Ordering::Relaxed) {
        return;
    }
    let t0 = START.get_or_init(Instant::now);
    let secs = t0.elapsed().as_secs_f64();
    let tag = match level {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{secs:8.3} {tag}] {args}");
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*))
    };
}
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*))
    };
}
#[macro_export]
macro_rules! warn {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*))
    };
}
#[macro_export]
macro_rules! error {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, format_args!($($t)*))
    };
}
