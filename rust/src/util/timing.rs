//! Wall-clock timing helpers used by the coordinator's metrics and the
//! bench kit.

use std::time::Instant;

/// Simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Streaming mean/min/max/percentile summary over f64 samples.
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Nearest-rank percentile; p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut v = self.xs.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
        v[rank.min(v.len() - 1)]
    }

    pub fn stddev(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.xs.len() - 1) as f64;
        var.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_stats() {
        let mut s = Samples::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.percentile(50.0), 3.0);
        assert!((s.stddev() - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn empty_safe() {
        let s = Samples::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
    }
}
