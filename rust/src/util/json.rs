//! Minimal JSON parser + renderer for artifacts/manifest.json and the
//! BENCH_kernels.json perf trajectory.
//!
//! serde is not in the offline vendor set, and these are the only JSON
//! documents this binary touches, so a small recursive-descent parser is
//! the right-sized dependency. Supports the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, bool, null); errors carry byte
//! offsets. [`Json::render`] is the write side — the kernel microbench
//! parses the committed trajectory, appends a snapshot, and re-renders.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chained access with a helpful error.
    pub fn expect(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing key {key:?}"),
            offset: 0,
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Pretty-print with 2-space indentation. Integers render without a
    /// fractional part; other finite numbers use f64's shortest-roundtrip
    /// form, so parse → render → parse is value-preserving for every
    /// document the parser accepts. Non-finite numbers (which JSON cannot
    /// represent and the parser would reject on re-read) render as
    /// `null` — a lossy but always-parsable downgrade.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out, 0);
        out.push('\n');
        out
    }

    fn render_into(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent + 1);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Inf; "NaN" would fail the next
                    // parse and (for the bench trajectory) torch the
                    // whole committed history on re-append
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => render_string(s, out),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, v) in items.iter().enumerate() {
                    out.push_str(&pad);
                    v.render_into(out, indent + 1);
                    out.push_str(if i + 1 < items.len() { ",\n" } else { "\n" });
                }
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&pad);
                    render_string(k, out);
                    out.push_str(": ");
                    v.render_into(out, indent + 1);
                    out.push_str(if i + 1 < m.len() { ",\n" } else { "\n" });
                }
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn render_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // surrogate pairs not needed for the manifest;
                            // map lone surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn manifest_shaped_document() {
        let doc = r#"{
          "version": 1,
          "executables": {
            "lm/init": {"file": "lm__init.hlo.txt",
                        "inputs": [{"name": "seed", "shape": [], "dtype": "uint32"}]}
          }
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let ex = v.get("executables").unwrap().as_obj().unwrap();
        let init = &ex["lm/init"];
        assert_eq!(
            init.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("dtype")
                .unwrap()
                .as_str(),
            Some("uint32")
        );
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn render_roundtrips() {
        let doc = r#"{"a": [1, 2.5, {"b": "c\nd"}], "d": false,
                      "e": null, "f": [], "g": {}, "n": -31556.25}"#;
        let v = parse(doc).unwrap();
        let rendered = v.render();
        assert_eq!(parse(&rendered).unwrap(), v, "{rendered}");
        // integers stay integers, floats stay shortest-roundtrip
        assert!(rendered.contains("2.5"));
        assert!(!rendered.contains("1.0"));
        assert!(rendered.contains("-31556.25"));
    }

    #[test]
    fn render_escapes_control_characters() {
        let v = Json::Str("a\"b\\c\u{1}\n".into());
        let r = v.render();
        assert_eq!(parse(r.trim()).unwrap(), v);
    }

    #[test]
    fn render_downgrades_non_finite_numbers_to_null() {
        // JSON cannot carry NaN/Inf; rendering them raw would make the
        // output unparsable by this module's own parser
        let v = Json::Arr(vec![
            Json::Num(f64::NAN),
            Json::Num(f64::INFINITY),
            Json::Num(1.5),
        ]);
        let reparsed = parse(&v.render()).unwrap();
        assert_eq!(
            reparsed,
            Json::Arr(vec![Json::Null, Json::Null, Json::Num(1.5)])
        );
    }
}
