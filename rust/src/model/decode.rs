//! KV-cache incremental decode and the multi-adapter serving forward.
//!
//! Two entry points share one engine:
//!
//! - [`greedy_kv`]: the plain-weights KV-cache greedy decode. Each step
//!   embeds ONE new position per request, runs it through the stack
//!   against cached keys/values, and argmaxes the tied head — turning
//!   the O(s) full recomputes of `TransformerConfig::greedy` into
//!   O(1)-per-token GEMM-shaped work. Token-for-token equal to the
//!   full-recompute path (see *Numerics* below).
//! - [`serve_greedy`]: the same loop over a heterogeneous batch where
//!   request `bi` carries its **own** [`AdapterParams`]. Base weights
//!   run as ordinary stacked GEMMs; per-request low-rank corrections run
//!   through [`batched_matmul_ops`] — one batched GEMM whose panel `bi`
//!   contracts against request `bi`'s factor — in the contraction order
//!   [`xba_cheaper`] picks per call site. At every catalog shape that is
//!   `(x·B)·A`, which never materializes `B·A` and keeps adapter cost
//!   O(s·d·r) per weight; the `x·(B·A)` fallback covers tall-`x` /
//!   near-full-rank regimes. LoRA also
//!   trains the passthrough parameters (embedding tables, norm scales),
//!   so those are applied per request too.
//!
//! # Numerics
//!
//! **Batched vs. sequential is bit-identical.** Every op in this path is
//! row-local (GEMM rows, RMS-norm rows, softmax rows, embeds, the tied
//! head) or panel-local (attention panels `[bi*h, (bi+1)*h)`), and the
//! kernels' parallel row-band split never re-associates a sum — so the
//! batched forward over B requests reproduces B single-request forwards
//! bit-for-bit, NaN/Inf included. `runtime::serve::oracle_check` and the
//! integration suite assert this exactly.
//!
//! **KV-cache vs. full recompute is token-identical.** The
//! full-recompute path scores *future* positions too, zeroes them in the
//! masked softmax, and accumulates their `0.0 · v` terms trailing the
//! real ones. With finite activations those terms only perturb the SIGN
//! of exact zeros (`-0.0 + 0.0 = +0.0`), never a nonzero value, and
//! `argmax_rows` compares with `>` where `+0.0 > -0.0` is false — so the
//! emitted token streams match exactly even where activation bit
//! patterns drift in zero sign. The regression test walks the whole lora
//! size grid on this claim.

use super::head::argmax_rows;
use super::lora::{xba_cheaper, AdapterParams};
use super::transformer::TransformerConfig;
use super::{pget, ParamSet};
use crate::tensor::{
    add_panels_at, batched_matmul, batched_matmul_nt, batched_matmul_ops,
    gather_heads_at, gelu, par_rows, scatter_heads,
    softmax_rows_masked_offset, BatchedMatrix, Matrix, ELEMWISE_FLOP_WEIGHT,
    RMS_EPS,
};

/// The weight view one decode runs under: a single merged/plain
/// parameter set, or a frozen base plus one adapter per request.
enum Weights<'a> {
    Plain(&'a ParamSet),
    Adapted { base: &'a ParamSet, adapters: &'a [&'a AdapterParams] },
}

impl<'a> Weights<'a> {
    fn base(&self) -> &'a ParamSet {
        match self {
            Weights::Plain(p) => p,
            Weights::Adapted { base, .. } => base,
        }
    }

    /// Request `bi`'s value for a passthrough parameter (embedding
    /// table, norm scale). Plain: the shared set. Adapted: the
    /// adapter's trained copy, falling back to base if absent.
    fn pass(&self, bi: usize, name: &str) -> &'a Matrix {
        match self {
            Weights::Plain(p) => pget(p, name),
            Weights::Adapted { base, adapters } => adapters[bi]
                .passthrough(name)
                .unwrap_or_else(|| pget(base, name)),
        }
    }

    /// Accumulate per-request low-rank corrections for projected
    /// weight `name` into columns `[col0, col0 + A.cols)` of `into`
    /// (`xp` = the GEMM input as per-request panels). No-op on the
    /// plain path or when the weight is not adapted.
    ///
    /// The contraction order is chosen per call by [`xba_cheaper`]:
    /// the default `(x·B)·A` never materializes `B·A` and wins at every
    /// catalog shape; the `x·(B·A)` fallback exists for tall-`x` /
    /// near-full-rank regimes. The rule sees only panel shapes, which a
    /// batched request shares with its solo run, so order choice can
    /// never break batched-vs-sequential bit-identity.
    fn add_low_rank(&self, xp: &BatchedMatrix, name: &str, into: &mut Matrix, col0: usize) {
        let Weights::Adapted { adapters, .. } = self else { return };
        let mut bs = Vec::with_capacity(adapters.len());
        let mut avs = Vec::with_capacity(adapters.len());
        for ad in adapters.iter() {
            // adapters share one trainable ABI, so either every request
            // adapts this weight or none does
            match ad.low_rank(name) {
                Some((b, a)) => {
                    bs.push(b);
                    avs.push(a);
                }
                None => return,
            }
        }
        let corr = if xba_cheaper(xp.rows, bs[0].rows, bs[0].cols, avs[0].cols) {
            let xb = batched_matmul_ops(xp, &bs);
            batched_matmul_ops(&xb, &avs)
        } else {
            let bas: Vec<Matrix> =
                bs.iter().zip(avs.iter()).map(|(b, a)| b.matmul(a)).collect();
            let ba_refs: Vec<&Matrix> = bas.iter().collect();
            batched_matmul_ops(xp, &ba_refs)
        };
        add_panels_at(into, &corr, col0);
    }
}

/// `tensor::ops::rms_norm_rows` with a per-request scale vector: rows
/// `[bi*m, (bi+1)*m)` normalize against request `bi`'s scale. The inner
/// loop mirrors the shared op exactly, so with equal scales the output
/// is bit-identical to one `rms_norm_rows` call.
fn rms_norm_per_request(w: &Weights, x: &Matrix, b: usize, name: &str) -> Matrix {
    let m = x.rows / b;
    let d = x.cols as f32;
    let cols = x.cols;
    let mut out = Matrix::zeros(x.rows, x.cols);
    // resolve each request's scale once, then band the row-local norm
    // onto the shared pool — row `r` belongs to request `r / m`, and the
    // per-row arithmetic order is unchanged, so banding stays
    // bit-identical to the serial loop
    let scales: Vec<&Matrix> = (0..b).map(|bi| w.pass(bi, name)).collect();
    for scale in &scales {
        debug_assert_eq!(scale.shape(), (1, x.cols));
    }
    par_rows(
        &mut out.data,
        x.rows,
        cols,
        x.rows * cols * ELEMWISE_FLOP_WEIGHT,
        |band, first, take| {
            for ri in 0..take {
                let r = first + ri;
                let scale = scales[r / m];
                let row = x.row(r);
                let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d;
                let inv = 1.0 / (ms + RMS_EPS).sqrt();
                let orow = &mut band[ri * cols..(ri + 1) * cols];
                for (j, o) in orow.iter_mut().enumerate() {
                    *o = row[j] * inv * scale.at(0, j);
                }
            }
        },
    );
    out
}

/// Per-layer key/value panels, `[b*h, capacity, dh]` with the first
/// `len` rows live. Appends are contiguous row copies; attention views
/// pack the live prefix into compact panels for the batched GEMMs.
struct KvCache {
    k: Vec<BatchedMatrix>,
    v: Vec<BatchedMatrix>,
    len: usize,
}

impl KvCache {
    fn new(layers: usize, bh: usize, capacity: usize, dh: usize) -> Self {
        Self {
            k: (0..layers).map(|_| BatchedMatrix::zeros(bh, capacity, dh)).collect(),
            v: (0..layers).map(|_| BatchedMatrix::zeros(bh, capacity, dh)).collect(),
            len: 0,
        }
    }

    /// Write a chunk's new keys/values at rows `[len, len + kh.rows)` of
    /// layer `l`. `len` itself advances once per chunk via [`advance`],
    /// after every layer has appended.
    ///
    /// [`advance`]: KvCache::advance
    fn append(&mut self, l: usize, kh: &BatchedMatrix, vh: &BatchedMatrix) {
        let dh = kh.cols;
        let t0 = self.len;
        for p in 0..kh.batch {
            self.k[l].panel_mut(p)[t0 * dh..(t0 + kh.rows) * dh]
                .copy_from_slice(kh.panel(p));
            self.v[l].panel_mut(p)[t0 * dh..(t0 + vh.rows) * dh]
                .copy_from_slice(vh.panel(p));
        }
    }

    fn advance(&mut self, m: usize) {
        self.len += m;
    }

    /// Compact copies of the first `t` live rows of layer `l`'s panels.
    fn view(&self, l: usize, t: usize) -> (BatchedMatrix, BatchedMatrix) {
        let pack = |full: &BatchedMatrix| {
            let dh = full.cols;
            let mut out = BatchedMatrix::zeros(full.batch, t, dh);
            for p in 0..full.batch {
                out.panel_mut(p).copy_from_slice(&full.panel(p)[..t * dh]);
            }
            out
        };
        (pack(&self.k[l]), pack(&self.v[l]))
    }
}

/// The fused `[d, 3d]` base `wq|wk|wv` panels, packed once per decode
/// (`blocks::pack_wqkv`'s layout) instead of once per step.
fn pack_all_wqkv(base: &ParamSet, layers: usize) -> Vec<Matrix> {
    (0..layers)
        .map(|l| {
            Matrix::concat_cols(&[
                pget(base, &format!("layer{l}/attn/wq")),
                pget(base, &format!("layer{l}/attn/wk")),
                pget(base, &format!("layer{l}/attn/wv")),
            ])
        })
        .collect()
}

/// Run positions `[t0, t0 + m)` of every request through the stack,
/// extending `cache` (which must hold exactly the first `t0` positions),
/// and return the final-normed activations `[b*m, d]`.
#[allow(clippy::too_many_arguments)]
fn forward_chunk(
    w: &Weights,
    cfg: &TransformerConfig,
    wqkv: &[Matrix],
    cache: &mut KvCache,
    tokens: &[i32],
    b: usize,
    s_total: usize,
    t0: usize,
    m: usize,
) -> Matrix {
    debug_assert_eq!(cache.len, t0);
    let dims = cfg.dims;
    let d = dims.d_model;
    let h = dims.n_heads;
    let dh = dims.head_dim();
    let mut x = Matrix::zeros(b * m, d);
    // per-request embedding gather, banded onto the shared pool: each
    // output row reads only its own request's tables, so the split is
    // row-local and bit-identical to the serial loop
    let embeds: Vec<(&Matrix, &Matrix)> = (0..b)
        .map(|bi| (w.pass(bi, "embed/tok"), w.pass(bi, "embed/pos")))
        .collect();
    let total = b * m;
    par_rows(
        &mut x.data,
        total,
        d,
        total * d * ELEMWISE_FLOP_WEIGHT,
        |band, first, take| {
            for r in 0..take {
                let gr = first + r;
                let (bi, i) = (gr / m, gr % m);
                let (tok, pos) = embeds[bi];
                let trow = tok.row(tokens[bi * s_total + t0 + i] as usize);
                let prow = pos.row(t0 + i);
                let xrow = &mut band[r * d..(r + 1) * d];
                for j in 0..d {
                    xrow[j] = trow[j] + prow[j];
                }
            }
        },
    );
    let scale = 1.0 / (dh as f32).sqrt();
    for l in 0..dims.n_layers {
        let p = |suffix: &str| format!("layer{l}/{suffix}");
        let n1 = rms_norm_per_request(w, &x, b, &p("ln1/scale"));
        let mut qkv = n1.matmul(&wqkv[l]);
        let n1p = BatchedMatrix::from_matrix(&n1, b);
        w.add_low_rank(&n1p, &p("attn/wq"), &mut qkv, 0);
        w.add_low_rank(&n1p, &p("attn/wk"), &mut qkv, d);
        w.add_low_rank(&n1p, &p("attn/wv"), &mut qkv, 2 * d);
        let qh = gather_heads_at(&qkv, b, m, h, dh, 0);
        let kh = gather_heads_at(&qkv, b, m, h, dh, d);
        let vh = gather_heads_at(&qkv, b, m, h, dh, 2 * d);
        cache.append(l, &kh, &vh);
        let (kv, vv) = cache.view(l, t0 + m);
        let mut probs = batched_matmul_nt(&qh, &kv, scale);
        softmax_rows_masked_offset(&mut probs, t0);
        let ctxh = batched_matmul(&probs, &vv);
        let ctx = scatter_heads(&ctxh, b, m, h, dh);
        let mut attn_out = ctx.matmul(pget(w.base(), &p("attn/wo")));
        let ctxp = BatchedMatrix::from_matrix(&ctx, b);
        w.add_low_rank(&ctxp, &p("attn/wo"), &mut attn_out, 0);
        let x_mid = &x + &attn_out;
        let n2 = rms_norm_per_request(w, &x_mid, b, &p("ln2/scale"));
        let mut h1 = n2.matmul(pget(w.base(), &p("ffn/w1")));
        let n2p = BatchedMatrix::from_matrix(&n2, b);
        w.add_low_rank(&n2p, &p("ffn/w1"), &mut h1, 0);
        let g = gelu(&h1);
        let mut ff = g.matmul(pget(w.base(), &p("ffn/w2")));
        let gp = BatchedMatrix::from_matrix(&g, b);
        w.add_low_rank(&gp, &p("ffn/w2"), &mut ff, 0);
        x = &x_mid + &ff;
    }
    cache.advance(m);
    rms_norm_per_request(w, &x, b, "final_ln/scale")
}

/// `TransformerConfig::check_batch`'s rules, restated here because the
/// serving tier validates before the config's private check would run.
fn check(cfg: &TransformerConfig, tokens: &[i32], rows: usize, s: usize) -> Result<(), String> {
    if rows == 0 {
        return Err("decode needs at least one request".into());
    }
    if s == 0 || s > cfg.seq_len {
        return Err(format!(
            "decode seq {s} outside the model's positional table (seq_len {})",
            cfg.seq_len
        ));
    }
    if tokens.len() != rows * s {
        return Err(format!("tokens length {} != rows {rows} * seq {s}", tokens.len()));
    }
    for &t in tokens {
        if t < 0 || t as usize >= cfg.vocab {
            return Err(format!("token id {t} out of range for vocab {}", cfg.vocab));
        }
    }
    Ok(())
}

fn drive(
    w: &Weights,
    cfg: &TransformerConfig,
    tokens: &mut [i32],
    b: usize,
    s: usize,
    prompt_len: usize,
) -> Result<(), String> {
    check(cfg, tokens, b, s)?;
    let p0 = prompt_len.max(1);
    if p0 >= s {
        return Ok(());
    }
    let wqkv = pack_all_wqkv(w.base(), cfg.dims.n_layers);
    let mut cache =
        KvCache::new(cfg.dims.n_layers, b * cfg.dims.n_heads, s, cfg.dims.head_dim());
    // prefill the prompt in one chunk, then one position per step
    let mut last = forward_chunk(w, cfg, &wqkv, &mut cache, tokens, b, s, 0, p0);
    let d = cfg.dims.d_model;
    for i in p0..s {
        let m_prev = last.rows / b;
        for bi in 0..b {
            let r = bi * m_prev + m_prev - 1;
            let feats = Matrix::from_vec(1, d, last.row(r).to_vec());
            // tied head, per request: logits = feats · embᵀ
            let logits = feats.matmul_nt(w.pass(bi, "embed/tok"));
            tokens[bi * s + i] = argmax_rows(&logits)[0] as i32;
        }
        if i + 1 < s {
            last = forward_chunk(w, cfg, &wqkv, &mut cache, tokens, b, s, i, 1);
        }
    }
    Ok(())
}

/// KV-cache greedy decode with plain (merged or base) weights: the
/// incremental counterpart of `TransformerConfig::greedy`, emitting
/// token-for-token the same continuation.
pub fn greedy_kv(
    cfg: &TransformerConfig,
    params: &ParamSet,
    tokens: &mut [i32],
    rows: usize,
    s: usize,
    prompt_len: usize,
) -> Result<(), String> {
    drive(&Weights::Plain(params), cfg, tokens, rows, s, prompt_len)
}

/// KV-cache greedy decode over a heterogeneous batch: request `bi` (rows
/// `[bi*s, (bi+1)*s)` of `tokens`) decodes under `base` patched by
/// `adapters[bi]`. Bit-identical to running each request alone — the
/// batched low-rank corrections are panel-local, see the module docs.
pub fn serve_greedy(
    cfg: &TransformerConfig,
    base: &ParamSet,
    adapters: &[&AdapterParams],
    tokens: &mut [i32],
    s: usize,
    prompt_len: usize,
) -> Result<(), String> {
    drive(
        &Weights::Adapted { base, adapters },
        cfg,
        tokens,
        adapters.len(),
        s,
        prompt_len,
    )
}

/// One full causal adapted forward (no decode loop): the final-normed
/// activations `[b*s, d]` for `b = adapters.len()` requests. This is the
/// serving tier's bit-compare surface — the batched result must equal
/// per-request calls at batch 1 byte-for-byte.
pub fn serve_prefill(
    cfg: &TransformerConfig,
    base: &ParamSet,
    adapters: &[&AdapterParams],
    tokens: &[i32],
    s: usize,
) -> Result<Matrix, String> {
    let b = adapters.len();
    check(cfg, tokens, b, s)?;
    let w = Weights::Adapted { base, adapters };
    let wqkv = pack_all_wqkv(base, cfg.dims.n_layers);
    let mut cache =
        KvCache::new(cfg.dims.n_layers, b * cfg.dims.n_heads, s, cfg.dims.head_dim());
    Ok(forward_chunk(&w, cfg, &wqkv, &mut cache, tokens, b, s, 0, s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lora::LoraAdapter;
    use crate::util::rng::{derive_seed, Rng};

    fn prompt_tokens(cfg: &TransformerConfig, rows: usize, salt: usize) -> Vec<i32> {
        let s = cfg.seq_len;
        (0..rows * s)
            .map(|r| ((3 + salt + (r % s) * 2 + r / s) % cfg.vocab) as i32)
            .collect()
    }

    fn synthetic_adapter(
        cfg: &TransformerConfig,
        base: &ParamSet,
        rank: usize,
        seed: u64,
    ) -> AdapterParams {
        let ad = LoraAdapter::new(cfg.param_shapes(), rank);
        let mut train = ad.init_trainable(base, seed);
        // B = 0 at init would make every adapter collapse onto the base;
        // give each a small distinct B so outputs diverge
        let names: Vec<String> =
            train.keys().filter(|n| n.starts_with("lora_B/")).cloned().collect();
        for (i, name) in names.iter().enumerate() {
            let m = train.get_mut(name).unwrap();
            let mut rng = Rng::new(derive_seed(seed ^ 0x5e21, i as u64));
            rng.fill_gaussian(&mut m.data, 0.05);
        }
        AdapterParams::from_trainable(&train).unwrap()
    }

    #[test]
    fn kv_greedy_matches_full_recompute_on_tiny() {
        let cfg = TransformerConfig::tiny();
        let params = cfg.init(4);
        let s = cfg.seq_len;
        let toks = prompt_tokens(&cfg, 2, 0);
        let mut full = toks.clone();
        cfg.greedy(&params, &mut full, 2, s, 4).unwrap();
        let mut kv = toks;
        greedy_kv(&cfg, &params, &mut kv, 2, s, 4).unwrap();
        assert_eq!(kv, full);
    }

    #[test]
    fn batched_serve_bit_matches_sequential_requests() {
        let cfg = TransformerConfig::tiny();
        let base = cfg.init(5);
        let adapters: Vec<AdapterParams> =
            (0..3).map(|i| synthetic_adapter(&cfg, &base, 4, 100 + i)).collect();
        let refs: Vec<&AdapterParams> = adapters.iter().collect();
        let s = cfg.seq_len;
        let mut toks: Vec<i32> = Vec::new();
        for bi in 0..3 {
            toks.extend(prompt_tokens(&cfg, 1, bi));
        }
        // batched prefill activations vs per-request at batch 1: exact bits
        let batched = serve_prefill(&cfg, &base, &refs, &toks, s).unwrap();
        for bi in 0..3 {
            let solo =
                serve_prefill(&cfg, &base, &refs[bi..bi + 1], &toks[bi * s..(bi + 1) * s], s)
                    .unwrap();
            for (g, w) in batched.data[bi * s * cfg.dims.d_model..(bi + 1) * s * cfg.dims.d_model]
                .iter()
                .zip(solo.data.iter())
            {
                assert_eq!(g.to_bits(), w.to_bits(), "request {bi}");
            }
        }
        // and the decoded token streams agree
        let mut batch_toks = toks.clone();
        serve_greedy(&cfg, &base, &refs, &mut batch_toks, s, 6).unwrap();
        for bi in 0..3 {
            let mut solo = toks[bi * s..(bi + 1) * s].to_vec();
            serve_greedy(&cfg, &base, &refs[bi..bi + 1], &mut solo, s, 6).unwrap();
            assert_eq!(&batch_toks[bi * s..(bi + 1) * s], &solo[..], "request {bi}");
        }
        // distinct adapters actually produce distinct continuations
        let mut a0 = toks[..s].to_vec();
        let mut a1 = toks[..s].to_vec();
        serve_greedy(&cfg, &base, &refs[0..1], &mut a0, s, 6).unwrap();
        serve_greedy(&cfg, &base, &refs[1..2], &mut a1, s, 6).unwrap();
        assert_ne!(a0, a1, "adapters 0 and 1 decoded identically");
    }

    #[test]
    fn nan_inf_poisoned_adapter_stays_bit_identical() {
        // kernel-oracle convention: non-finite values must propagate the
        // same way through the batched and sequential paths
        let cfg = TransformerConfig::tiny();
        let base = cfg.init(6);
        let mut adapters: Vec<AdapterParams> =
            (0..2).map(|i| synthetic_adapter(&cfg, &base, 4, 200 + i)).collect();
        {
            let ad = LoraAdapter::new(cfg.param_shapes(), 4);
            let mut train = ad.init_trainable(&base, 300);
            let bname = "lora_B/layer0/attn/wq";
            *train.get_mut(bname).unwrap().at_mut(0, 0) = f32::NAN;
            *train.get_mut(bname).unwrap().at_mut(1, 1) = f32::INFINITY;
            adapters.push(AdapterParams::from_trainable(&train).unwrap());
        }
        let refs: Vec<&AdapterParams> = adapters.iter().collect();
        let s = cfg.seq_len;
        let mut toks: Vec<i32> = Vec::new();
        for bi in 0..3 {
            toks.extend(prompt_tokens(&cfg, 1, bi));
        }
        let batched = serve_prefill(&cfg, &base, &refs, &toks, s).unwrap();
        // the poisoned request's activations are non-finite...
        let d = cfg.dims.d_model;
        assert!(batched.data[2 * s * d..].iter().any(|v| !v.is_finite()));
        // ...the clean requests' are not (panel isolation)...
        assert!(batched.data[..2 * s * d].iter().all(|v| v.is_finite()));
        // ...and all three panels bit-match their sequential runs
        for bi in 0..3 {
            let solo =
                serve_prefill(&cfg, &base, &refs[bi..bi + 1], &toks[bi * s..(bi + 1) * s], s)
                    .unwrap();
            for (g, w) in batched.data[bi * s * d..(bi + 1) * s * d].iter().zip(solo.data.iter())
            {
                assert_eq!(g.to_bits(), w.to_bits(), "request {bi}");
            }
        }
    }

    #[test]
    fn contraction_order_fallback_bit_matches_naive() {
        // a tall x against a full-rank 4x4 adapter flips xba_cheaper to
        // the materialized x·(B·A) branch; its output must bit-match the
        // same-order naive computation (packed kernels are naive-exact),
        // propagate non-finite factor entries, and agree with the
        // factored order to tolerance (different association)
        let rows = 1024usize;
        let mut rng = Rng::new(9001);
        let mut x = Matrix::zeros(rows, 4);
        rng.fill_gaussian(&mut x.data, 1.0);
        let mut bmat = Matrix::zeros(4, 4);
        let mut amat = Matrix::zeros(4, 4);
        rng.fill_gaussian(&mut bmat.data, 0.5);
        rng.fill_gaussian(&mut amat.data, 0.5);
        *bmat.at_mut(3, 3) = f32::NAN;
        let mut train = ParamSet::new();
        train.insert("lora_B/w".into(), bmat.clone());
        train.insert("lora_A/w".into(), amat.clone());
        let ap = AdapterParams::from_trainable(&train).unwrap();
        assert!(!xba_cheaper(rows, 4, 4, 4), "test shape must flip the rule");
        let base = ParamSet::new();
        let refs = [&ap];
        let w = Weights::Adapted { base: &base, adapters: &refs };
        let xp = BatchedMatrix::from_matrix(&x, 1);
        let mut got = Matrix::zeros(rows, 4);
        w.add_low_rank(&xp, "w", &mut got, 0);
        let want = x.matmul_naive(&bmat.matmul_naive(&amat));
        assert!(want.data.iter().any(|v| v.is_nan()), "poison must reach out");
        for (g, e) in got.data.iter().zip(want.data.iter()) {
            assert_eq!(g.to_bits(), e.to_bits());
        }
        let fact = x.matmul(&bmat).matmul(&amat);
        for (g, f) in got.data.iter().zip(fact.data.iter()) {
            assert!(
                (g - f).abs() <= 1e-4 * f.abs().max(1.0)
                    || (g.is_nan() && f.is_nan()),
                "{g} vs {f}"
            );
        }
    }

    #[test]
    fn decode_validates_inputs() {
        let cfg = TransformerConfig::tiny();
        let params = cfg.init(0);
        let mut toks = vec![0i32; cfg.seq_len];
        assert!(greedy_kv(&cfg, &params, &mut toks, 0, cfg.seq_len, 2).is_err());
        assert!(greedy_kv(&cfg, &params, &mut toks, 1, cfg.seq_len + 9, 2).is_err());
        let mut bad = vec![99i32; cfg.seq_len];
        assert!(greedy_kv(&cfg, &params, &mut bad, 1, cfg.seq_len, 2).is_err());
        // prompt covering the whole window is a no-op, not an error
        let mut full = vec![1i32; cfg.seq_len];
        let before = full.clone();
        greedy_kv(&cfg, &params, &mut full, 1, cfg.seq_len, cfg.seq_len).unwrap();
        assert_eq!(full, before);
    }
}
