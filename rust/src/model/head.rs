//! Shared fused softmax cross-entropy head.
//!
//! Both native model families end in the same block: gather the feature
//! rows that carry a prediction, multiply by a class matrix, take a
//! numerically-stable softmax cross-entropy, and (in training) scatter
//! `dlogits`-driven gradients back. The LM (`transformer.rs`, tied
//! embedding head, mask-weighted positions) and the ViT (`vit.rs`,
//! `head/w`, uniform weights over the batch) used to carry two copies of
//! the forward+gradient block; this module is the single shared one —
//! and, because the logits/backward contractions are now whole-matrix
//! GEMMs on the blocked kernels, it is also the fast path.

use crate::tensor::Matrix;

/// Fused masked softmax cross-entropy over precomputed `logits`
/// (`[n_examples, n_classes]`). Example `e` has target class
/// `targets[e]` and weight `weights[e]` (> 0; zero-weight examples are
/// the caller's to filter out). Returns the weighted-mean loss
/// `Σ_e w_e · CE_e / Σ_e w_e` (accumulated in f64, like both former
/// copies) and — with `want_grad` — `dlogits` with
/// `dlogits[e][c] = w_e/Σw · (p_c − 1{c = target_e})`, i.e. the exact
/// cotangent of the mean loss. Without `want_grad` the gradient matrix
/// is empty (`0×0`).
pub(crate) fn fused_softmax_xent(
    logits: &Matrix,
    targets: &[usize],
    weights: &[f32],
    want_grad: bool,
) -> (f32, Matrix) {
    let (n, c) = logits.shape();
    assert_eq!(targets.len(), n, "one target per logits row");
    assert_eq!(weights.len(), n, "one weight per logits row");
    let total_w: f64 = weights.iter().map(|&w| w as f64).sum();
    let mut dlogits = if want_grad {
        Matrix::zeros(n, c)
    } else {
        Matrix::zeros(0, 0)
    };
    if total_w <= 0.0 {
        return (0.0, dlogits);
    }
    let inv_w = (1.0 / total_w) as f32;
    let mut loss = 0.0f64;
    let mut expd = vec![0.0f32; c];
    for e in 0..n {
        let row = logits.row(e);
        let tgt = targets[e];
        debug_assert!(tgt < c, "target {tgt} out of range for {c} classes");
        let wt = weights[e];
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let mut denom = 0.0f32;
        for (ex, &x) in expd.iter_mut().zip(row.iter()) {
            *ex = (x - mx).exp();
            denom += *ex;
        }
        loss += wt as f64 * (denom.ln() + mx - row[tgt]) as f64;
        if want_grad {
            let drow = &mut dlogits.data[e * c..(e + 1) * c];
            for (t, (dl, &ex)) in drow.iter_mut().zip(expd.iter()).enumerate() {
                let p = ex / denom;
                *dl = wt * inv_w * (p - if t == tgt { 1.0 } else { 0.0 });
            }
        }
    }
    ((loss / total_w) as f32, dlogits)
}

/// Row-wise argmax with first-max tie-breaking (strict `>`), matching
/// the scalar argmax loops the eval paths used.
pub(crate) fn argmax_rows(m: &Matrix) -> Vec<usize> {
    (0..m.rows)
        .map(|i| {
            let row = m.row(i);
            let mut best = 0usize;
            for (j, &x) in row.iter().enumerate() {
                if x > row[best] {
                    best = j;
                }
            }
            best
        })
        .collect()
}

/// Pack the listed `rows` of `x` into a dense `[rows.len(), x.cols]`
/// matrix (the prediction-carrying feature rows).
pub(crate) fn gather_rows(x: &Matrix, rows: &[usize]) -> Matrix {
    let mut out = Matrix::zeros(rows.len(), x.cols);
    for (e, &r) in rows.iter().enumerate() {
        out.data[e * x.cols..(e + 1) * x.cols].copy_from_slice(x.row(r));
    }
    out
}

/// Scatter-accumulate `src` row `e` into `dst` row `rows[e]`
/// (`dst[rows[e]] += src[e]`) — the inverse of [`gather_rows`] for
/// cotangents. Accumulating (not assigning) keeps repeated target rows
/// correct, though the current callers' row lists are disjoint.
pub(crate) fn scatter_rows_add(dst: &mut Matrix, rows: &[usize], src: &Matrix) {
    assert_eq!(src.rows, rows.len());
    assert_eq!(src.cols, dst.cols);
    for (e, &r) in rows.iter().enumerate() {
        let drow = &mut dst.data[r * dst.cols..(r + 1) * dst.cols];
        for (d, &s) in drow.iter_mut().zip(src.row(e).iter()) {
            *d += s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(seed: u64, n: usize, m: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::gaussian(n, m, 1.0, &mut rng)
    }

    #[test]
    fn uniform_logits_give_ln_c_loss() {
        let logits = Matrix::zeros(3, 8);
        let (loss, _) = fused_softmax_xent(&logits, &[0, 3, 7], &[1.0; 3], false);
        assert!((loss - (8f32).ln()).abs() < 1e-6);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let logits = randn(1, 4, 6);
        let targets = [2usize, 0, 5, 3];
        let weights = [1.0f32, 0.5, 2.0, 1.0];
        let (_, d) = fused_softmax_xent(&logits, &targets, &weights, true);
        let eps = 1e-3f32;
        for &(e, c) in &[(0usize, 2usize), (1, 1), (2, 5), (3, 0)] {
            let mut lp = logits.clone();
            *lp.at_mut(e, c) += eps;
            let mut lm = logits.clone();
            *lm.at_mut(e, c) -= eps;
            let fp = fused_softmax_xent(&lp, &targets, &weights, false).0;
            let fm = fused_softmax_xent(&lm, &targets, &weights, false).0;
            let fd = (fp - fm) / (2.0 * eps);
            let an = d.at(e, c);
            assert!(
                (fd - an).abs() < 1e-3 + 1e-2 * fd.abs().max(an.abs()),
                "({e},{c}): fd={fd} analytic={an}"
            );
        }
    }

    #[test]
    fn dlogits_rows_sum_to_zero() {
        // softmax probabilities sum to 1 and the one-hot subtracts 1
        let logits = randn(2, 3, 5);
        let (_, d) = fused_softmax_xent(&logits, &[1, 4, 0], &[1.0, 3.0, 0.5], true);
        for e in 0..3 {
            let s: f32 = d.row(e).iter().sum();
            assert!(s.abs() < 1e-6, "row {e} sums to {s}");
        }
    }

    #[test]
    fn zero_total_weight_is_a_zero_loss() {
        let logits = randn(3, 2, 4);
        let (loss, d) = fused_softmax_xent(&logits, &[0, 1], &[0.0, 0.0], true);
        assert_eq!(loss, 0.0);
        assert_eq!(d.shape(), (2, 4));
        assert!(d.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn argmax_rows_first_max_wins_ties() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 3.0, 3.0, -1.0, -5.0, -1.0]);
        assert_eq!(argmax_rows(&m), vec![1, 0]);
    }

    #[test]
    fn gather_scatter_roundtrip_accumulates() {
        let x = randn(4, 6, 3);
        let rows = [4usize, 1, 4];
        let g = gather_rows(&x, &rows);
        assert!(g.row(0) == x.row(4) && g.row(1) == x.row(1));
        let mut dst = Matrix::zeros(6, 3);
        scatter_rows_add(&mut dst, &rows, &g);
        // row 4 was scattered twice
        for j in 0..3 {
            assert_eq!(dst.at(4, j), 2.0 * x.at(4, j));
            assert_eq!(dst.at(1, j), x.at(1, j));
            assert_eq!(dst.at(0, j), 0.0);
        }
    }
}
