//! LoRA parameterization of a base model (Hu et al., 2022), as the paper
//! runs it in §3: every projectable weight `W ∈ R^{n×m}` gets trainable
//! `B ∈ R^{n×r}` (zero-init) and `A ∈ R^{r×m}` (Gaussian-init); the
//! forward uses `W + (α/r)·B·A` and only {A, B} plus the naively-handled
//! vectors/embeddings receive gradients and optimizer state. Mirrors
//! `python/compile/lora.py` (α defaults to r, so the scale is 1 — the
//! setting the paper's Theorem 2.1 dynamics analysis assumes).

use super::{is_projectable, pget, ParamSet};
use crate::tensor::Matrix;
use crate::util::rng::{derive_seed, Rng};

/// Bookkeeping for the LoRA parameterization of one base parameter set.
pub struct LoraAdapter {
    base_shapes: Vec<(String, [usize; 2])>,
    pub rank: usize,
}

impl LoraAdapter {
    pub fn new(base_shapes: Vec<(String, [usize; 2])>, rank: usize) -> Self {
        assert!(rank > 0, "lora rank must be >= 1");
        Self { base_shapes, rank }
    }

    fn projected(&self) -> impl Iterator<Item = &(String, [usize; 2])> {
        self.base_shapes.iter().filter(|(n, _)| is_projectable(n))
    }

    /// Shapes of the trainable parameter set, sorted by name (the ABI
    /// order of the `train/` state group).
    pub fn trainable_shapes(&self) -> Vec<(String, [usize; 2])> {
        let mut out = Vec::new();
        for (name, sh) in &self.base_shapes {
            if is_projectable(name) {
                out.push((format!("lora_A/{name}"), [self.rank, sh[1]]));
                out.push((format!("lora_B/{name}"), [sh[0], self.rank]));
            } else {
                out.push((name.clone(), *sh));
            }
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Number of additional scalars LoRA introduces (patches on top of
    /// the frozen model) — the accountant's Δ for LoRA.
    pub fn extra_param_count(&self) -> usize {
        self.projected()
            .map(|(_, sh)| self.rank * (sh[0] + sh[1]))
            .sum()
    }

    /// `B = 0`, `A ~ N(0, 1/r)`; passthrough parameters start at the base
    /// value (they continue training from the checkpoint).
    pub fn init_trainable(&self, base: &ParamSet, seed: u64) -> ParamSet {
        let mut out = ParamSet::new();
        let mut idx = 0u64;
        for (name, sh) in &self.base_shapes {
            if is_projectable(name) {
                let mut rng = Rng::new(derive_seed(seed, idx));
                idx += 1;
                out.insert(
                    format!("lora_B/{name}"),
                    Matrix::zeros(sh[0], self.rank),
                );
                out.insert(
                    format!("lora_A/{name}"),
                    Matrix::gaussian(
                        self.rank,
                        sh[1],
                        (1.0 / self.rank as f32).sqrt(),
                        &mut rng,
                    ),
                );
            } else {
                out.insert(name.clone(), pget(base, name).clone());
            }
        }
        out
    }

    /// Effective full parameter set: `W + B·A` on projected weights
    /// (α = r ⇒ scale 1), trainable values on passthrough ones.
    pub fn merge(&self, base: &ParamSet, train: &ParamSet) -> ParamSet {
        let mut out = ParamSet::new();
        for (name, _) in &self.base_shapes {
            if is_projectable(name) {
                let b = pget(train, &format!("lora_B/{name}"));
                let a = pget(train, &format!("lora_A/{name}"));
                let mut w = pget(base, name).clone();
                w.add_scaled_inplace(&b.matmul(a), 1.0);
                out.insert(name.clone(), w);
            } else {
                out.insert(name.clone(), pget(train, name).clone());
            }
        }
        out
    }

    /// Map the merged-model gradients to trainable gradients:
    /// `dB = dW·Aᵀ`, `dA = Bᵀ·dW`, passthrough gradients verbatim.
    pub fn train_grads(&self, train: &ParamSet, dmerged: &ParamSet) -> ParamSet {
        let mut out = ParamSet::new();
        for (name, _) in &self.base_shapes {
            let dw = pget(dmerged, name);
            if is_projectable(name) {
                let a = pget(train, &format!("lora_A/{name}"));
                let b = pget(train, &format!("lora_B/{name}"));
                out.insert(format!("lora_B/{name}"), dw.matmul_nt(a));
                out.insert(format!("lora_A/{name}"), b.matmul_tn(dw));
            } else {
                out.insert(name.clone(), dw.clone());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TransformerConfig;

    fn adapter(rank: usize) -> (TransformerConfig, LoraAdapter) {
        let cfg = TransformerConfig::tiny();
        let ad = LoraAdapter::new(cfg.param_shapes(), rank);
        (cfg, ad)
    }

    #[test]
    fn trainable_set_splits_projected_and_passthrough() {
        let (cfg, ad) = adapter(4);
        let shapes = ad.trainable_shapes();
        // 1 layer: 6 projectable matrices -> 12 lora halves; 5 passthrough
        let lora_n = shapes.iter().filter(|(n, _)| n.starts_with("lora_")).count();
        assert_eq!(lora_n, 12);
        assert_eq!(shapes.len(), 12 + 5);
        let a = shapes
            .iter()
            .find(|(n, _)| n == "lora_A/layer0/ffn/w1")
            .unwrap();
        assert_eq!(a.1, [4, cfg.dims.d_ff]);
        let b = shapes
            .iter()
            .find(|(n, _)| n == "lora_B/layer0/ffn/w1")
            .unwrap();
        assert_eq!(b.1, [cfg.dims.d_model, 4]);
        // sorted
        for w in shapes.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn init_merge_is_identity_at_b_zero() {
        // B = 0 at init, so the merged model equals the base exactly
        let (cfg, ad) = adapter(4);
        let base = cfg.init(0);
        let train = ad.init_trainable(&base, 1);
        let merged = ad.merge(&base, &train);
        for (name, w) in &base {
            assert!(merged[name].allclose(w, 0.0), "{name}");
        }
    }

    #[test]
    fn train_grads_match_chain_rule() {
        let (cfg, ad) = adapter(2);
        let base = cfg.init(2);
        let mut train = ad.init_trainable(&base, 3);
        // make B nonzero so dA has signal
        let bname = "lora_B/layer0/attn/wq";
        let b0 = train[bname].clone();
        train.insert(
            bname.to_string(),
            Matrix::from_fn(b0.rows, b0.cols, |i, j| 0.1 * (i + j) as f32),
        );
        // fake merged gradient: ones on wq only
        let mut dmerged = ParamSet::new();
        for (name, sh) in cfg.param_shapes() {
            let g = if name == "layer0/attn/wq" {
                Matrix::from_fn(sh[0], sh[1], |_, _| 1.0)
            } else {
                Matrix::zeros(sh[0], sh[1])
            };
            dmerged.insert(name, g);
        }
        let tg = ad.train_grads(&train, &dmerged);
        let a = &train["lora_A/layer0/attn/wq"];
        let b = &train[bname];
        let dw = &dmerged["layer0/attn/wq"];
        assert!(tg[bname].allclose(&dw.matmul_nt(a), 1e-6));
        assert!(tg["lora_A/layer0/attn/wq"].allclose(&b.matmul_tn(dw), 1e-6));
        // passthrough gradients flow verbatim
        assert!(tg["embed/tok"].allclose(&dmerged["embed/tok"], 0.0));
    }

    #[test]
    fn extra_params_scale_with_rank() {
        let (_, ad4) = adapter(4);
        let (_, ad8) = adapter(8);
        assert_eq!(ad8.extra_param_count(), 2 * ad4.extra_param_count());
        // 1 layer, d=32, f=64: 4x(32+32) + (32+64) + (64+32) = 448 per rank
        assert_eq!(ad4.extra_param_count(), 4 * 448);
    }
}
