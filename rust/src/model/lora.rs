//! LoRA parameterization of a base model (Hu et al., 2022), as the paper
//! runs it in §3: every projectable weight `W ∈ R^{n×m}` gets trainable
//! `B ∈ R^{n×r}` (zero-init) and `A ∈ R^{r×m}` (Gaussian-init); the
//! forward uses `W + (α/r)·B·A` and only {A, B} plus the naively-handled
//! vectors/embeddings receive gradients and optimizer state. Mirrors
//! `python/compile/lora.py` (α defaults to r, so the scale is 1 — the
//! setting the paper's Theorem 2.1 dynamics analysis assumes).

use super::{is_projectable, pget, ParamSet};
use crate::tensor::Matrix;
use crate::util::rng::{derive_seed, Rng};
use std::collections::BTreeMap;

/// Bookkeeping for the LoRA parameterization of one base parameter set.
pub struct LoraAdapter {
    base_shapes: Vec<(String, [usize; 2])>,
    pub rank: usize,
}

impl LoraAdapter {
    pub fn new(base_shapes: Vec<(String, [usize; 2])>, rank: usize) -> Self {
        assert!(rank > 0, "lora rank must be >= 1");
        Self { base_shapes, rank }
    }

    fn projected(&self) -> impl Iterator<Item = &(String, [usize; 2])> {
        self.base_shapes.iter().filter(|(n, _)| is_projectable(n))
    }

    /// Shapes of the trainable parameter set, sorted by name (the ABI
    /// order of the `train/` state group).
    pub fn trainable_shapes(&self) -> Vec<(String, [usize; 2])> {
        let mut out = Vec::new();
        for (name, sh) in &self.base_shapes {
            if is_projectable(name) {
                out.push((format!("lora_A/{name}"), [self.rank, sh[1]]));
                out.push((format!("lora_B/{name}"), [sh[0], self.rank]));
            } else {
                out.push((name.clone(), *sh));
            }
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Number of additional scalars LoRA introduces (patches on top of
    /// the frozen model) — the accountant's Δ for LoRA.
    pub fn extra_param_count(&self) -> usize {
        self.projected()
            .map(|(_, sh)| self.rank * (sh[0] + sh[1]))
            .sum()
    }

    /// `B = 0`, `A ~ N(0, 1/r)`; passthrough parameters start at the base
    /// value (they continue training from the checkpoint).
    pub fn init_trainable(&self, base: &ParamSet, seed: u64) -> ParamSet {
        let mut out = ParamSet::new();
        let mut idx = 0u64;
        for (name, sh) in &self.base_shapes {
            if is_projectable(name) {
                let mut rng = Rng::new(derive_seed(seed, idx));
                idx += 1;
                out.insert(
                    format!("lora_B/{name}"),
                    Matrix::zeros(sh[0], self.rank),
                );
                out.insert(
                    format!("lora_A/{name}"),
                    Matrix::gaussian(
                        self.rank,
                        sh[1],
                        (1.0 / self.rank as f32).sqrt(),
                        &mut rng,
                    ),
                );
            } else {
                out.insert(name.clone(), pget(base, name).clone());
            }
        }
        out
    }

    /// Effective full parameter set: `W + B·A` on projected weights
    /// (α = r ⇒ scale 1), trainable values on passthrough ones.
    pub fn merge(&self, base: &ParamSet, train: &ParamSet) -> ParamSet {
        let mut out = ParamSet::new();
        for (name, _) in &self.base_shapes {
            if is_projectable(name) {
                let b = pget(train, &format!("lora_B/{name}"));
                let a = pget(train, &format!("lora_A/{name}"));
                let mut w = pget(base, name).clone();
                w.add_scaled_inplace(&b.matmul(a), 1.0);
                out.insert(name.clone(), w);
            } else {
                out.insert(name.clone(), pget(train, name).clone());
            }
        }
        out
    }

    /// Map the merged-model gradients to trainable gradients:
    /// `dB = dW·Aᵀ`, `dA = Bᵀ·dW`, passthrough gradients verbatim.
    pub fn train_grads(&self, train: &ParamSet, dmerged: &ParamSet) -> ParamSet {
        let mut out = ParamSet::new();
        for (name, _) in &self.base_shapes {
            let dw = pget(dmerged, name);
            if is_projectable(name) {
                let a = pget(train, &format!("lora_A/{name}"));
                let b = pget(train, &format!("lora_B/{name}"));
                out.insert(format!("lora_B/{name}"), dw.matmul_nt(a));
                out.insert(format!("lora_A/{name}"), b.matmul_tn(dw));
            } else {
                out.insert(name.clone(), dw.clone());
            }
        }
        out
    }
}

/// Shape-aware contraction order for a low-rank correction
/// `x·B·A` with `x ∈ R^{rows×n}`, `B ∈ R^{n×r}`, `A ∈ R^{r×m}`:
/// `true` when the factored order `(x·B)·A` does no more multiply-adds
/// than materializing `B·A` first and applying it as one GEMM.
/// `(x·B)·A` costs `rows·r·(n+m)` MACs; `x·(B·A)` costs
/// `n·r·m + rows·n·m`. At every catalog shape (r ≪ n, m) the factored
/// order wins — the materialized order only pays off when the row count
/// dwarfs the weight dims AND the rank is near full (see the unit
/// tests) — but the serve forward consults this rule per call site
/// rather than hard-coding the order. The rule depends only on shapes,
/// which are identical between a batched panel and the same request
/// served alone, so both paths always pick the same order and the
/// batched-vs-sequential bit-identity guarantee is untouched.
pub(crate) fn xba_cheaper(rows: usize, n: usize, r: usize, m: usize) -> bool {
    rows * r * (n + m) <= n * r * m + rows * n * m
}

/// One adapter's state in the form the serving tier consumes: the
/// low-rank factors kept **split** (`B ∈ R^{n×r}`, `A ∈ R^{r×m}` per
/// projected weight, keyed by the base parameter name) plus the
/// passthrough parameters (embeddings, norm scales) that LoRA trains
/// directly. The split form is the whole point: the serve forward
/// contracts `(x·B)·A` per request and never materializes `B·A`, so a
/// rank-8 adapter for lora-base stays ~292 KiB of state instead of a
/// full merged weight copy — cheap enough to hot-load and evict.
#[derive(Clone, Debug)]
pub struct AdapterParams {
    pub rank: usize,
    low_rank: BTreeMap<String, (Matrix, Matrix)>,
    passthrough: ParamSet,
}

impl AdapterParams {
    /// Split a trainable parameter set (the `train/` state-group layout:
    /// `lora_B/{name}` + `lora_A/{name}` pairs plus passthrough tensors,
    /// as produced by [`LoraAdapter::init_trainable`] or restored from a
    /// checkpoint) into serving form. The rank is inferred from the `A`
    /// factors; mismatched or unpaired factors are an error.
    pub fn from_trainable(train: &ParamSet) -> Result<Self, String> {
        let mut low_rank: BTreeMap<String, (Matrix, Matrix)> = BTreeMap::new();
        let mut passthrough = ParamSet::new();
        let mut rank = None;
        for (name, value) in train {
            if let Some(base_name) = name.strip_prefix("lora_A/") {
                let bname = format!("lora_B/{base_name}");
                let b = train
                    .get(&bname)
                    .ok_or_else(|| format!("adapter: {name} has no paired {bname}"))?;
                if b.cols != value.rows {
                    return Err(format!(
                        "adapter: {base_name} factor shapes B[{},{}] / A[{},{}] do not chain",
                        b.rows, b.cols, value.rows, value.cols
                    ));
                }
                match rank {
                    None => rank = Some(value.rows),
                    Some(r) if r != value.rows => {
                        return Err(format!(
                            "adapter: mixed ranks {r} and {} (at {base_name})",
                            value.rows
                        ))
                    }
                    _ => {}
                }
                low_rank.insert(base_name.to_string(), (b.clone(), value.clone()));
            } else if let Some(base_name) = name.strip_prefix("lora_B/") {
                if !train.contains_key(&format!("lora_A/{base_name}")) {
                    return Err(format!("adapter: {name} has no paired lora_A/{base_name}"));
                }
            } else {
                passthrough.insert(name.clone(), value.clone());
            }
        }
        let rank = rank.ok_or_else(|| "adapter: no lora_A/* factors found".to_string())?;
        Ok(Self { rank, low_rank, passthrough })
    }

    /// The split `(B, A)` factors for base parameter `name`, if it is a
    /// projected (adapted) weight.
    pub fn low_rank(&self, name: &str) -> Option<(&Matrix, &Matrix)> {
        self.low_rank.get(name).map(|(b, a)| (b, a))
    }

    /// The adapter's own value for a passthrough parameter (embedding
    /// table, norm scale) — serving uses these per request, because LoRA
    /// trains them directly.
    pub fn passthrough(&self, name: &str) -> Option<&Matrix> {
        self.passthrough.get(name)
    }

    /// Number of projected weights this adapter patches.
    pub fn num_projected(&self) -> usize {
        self.low_rank.len()
    }

    /// Total scalars of adapter state (factors + passthrough).
    pub fn param_count(&self) -> usize {
        let lr: usize = self
            .low_rank
            .values()
            .map(|(b, a)| b.rows * b.cols + a.rows * a.cols)
            .sum();
        let pt: usize = self.passthrough.values().map(|m| m.rows * m.cols).sum();
        lr + pt
    }

    /// Resident bytes of adapter state (f32 payload only) — the number
    /// the registry's capacity accounting and `docs/SERVING.md`'s
    /// lifecycle math quote.
    pub fn state_bytes(&self) -> usize {
        4 * self.param_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TransformerConfig;

    fn adapter(rank: usize) -> (TransformerConfig, LoraAdapter) {
        let cfg = TransformerConfig::tiny();
        let ad = LoraAdapter::new(cfg.param_shapes(), rank);
        (cfg, ad)
    }

    #[test]
    fn trainable_set_splits_projected_and_passthrough() {
        let (cfg, ad) = adapter(4);
        let shapes = ad.trainable_shapes();
        // 1 layer: 6 projectable matrices -> 12 lora halves; 5 passthrough
        let lora_n = shapes.iter().filter(|(n, _)| n.starts_with("lora_")).count();
        assert_eq!(lora_n, 12);
        assert_eq!(shapes.len(), 12 + 5);
        let a = shapes
            .iter()
            .find(|(n, _)| n == "lora_A/layer0/ffn/w1")
            .unwrap();
        assert_eq!(a.1, [4, cfg.dims.d_ff]);
        let b = shapes
            .iter()
            .find(|(n, _)| n == "lora_B/layer0/ffn/w1")
            .unwrap();
        assert_eq!(b.1, [cfg.dims.d_model, 4]);
        // sorted
        for w in shapes.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn init_merge_is_identity_at_b_zero() {
        // B = 0 at init, so the merged model equals the base exactly
        let (cfg, ad) = adapter(4);
        let base = cfg.init(0);
        let train = ad.init_trainable(&base, 1);
        let merged = ad.merge(&base, &train);
        for (name, w) in &base {
            assert!(merged[name].allclose(w, 0.0), "{name}");
        }
    }

    #[test]
    fn train_grads_match_chain_rule() {
        let (cfg, ad) = adapter(2);
        let base = cfg.init(2);
        let mut train = ad.init_trainable(&base, 3);
        // make B nonzero so dA has signal
        let bname = "lora_B/layer0/attn/wq";
        let b0 = train[bname].clone();
        train.insert(
            bname.to_string(),
            Matrix::from_fn(b0.rows, b0.cols, |i, j| 0.1 * (i + j) as f32),
        );
        // fake merged gradient: ones on wq only
        let mut dmerged = ParamSet::new();
        for (name, sh) in cfg.param_shapes() {
            let g = if name == "layer0/attn/wq" {
                Matrix::from_fn(sh[0], sh[1], |_, _| 1.0)
            } else {
                Matrix::zeros(sh[0], sh[1])
            };
            dmerged.insert(name, g);
        }
        let tg = ad.train_grads(&train, &dmerged);
        let a = &train["lora_A/layer0/attn/wq"];
        let b = &train[bname];
        let dw = &dmerged["layer0/attn/wq"];
        assert!(tg[bname].allclose(&dw.matmul_nt(a), 1e-6));
        assert!(tg["lora_A/layer0/attn/wq"].allclose(&b.matmul_tn(dw), 1e-6));
        // passthrough gradients flow verbatim
        assert!(tg["embed/tok"].allclose(&dmerged["embed/tok"], 0.0));
    }

    #[test]
    fn adapter_params_split_roundtrips_the_trainable_set() {
        let (cfg, ad) = adapter(4);
        let base = cfg.init(0);
        let train = ad.init_trainable(&base, 7);
        let ap = AdapterParams::from_trainable(&train).unwrap();
        assert_eq!(ap.rank, 4);
        assert_eq!(ap.num_projected(), 6); // 1 layer: wq wk wv wo w1 w2
        let (b, a) = ap.low_rank("layer0/attn/wq").unwrap();
        assert!(b.allclose(&train["lora_B/layer0/attn/wq"], 0.0));
        assert!(a.allclose(&train["lora_A/layer0/attn/wq"], 0.0));
        assert!(ap.low_rank("embed/tok").is_none());
        assert!(ap.passthrough("embed/tok").unwrap().allclose(&train["embed/tok"], 0.0));
        let want: usize = train.values().map(|m| m.rows * m.cols).sum();
        assert_eq!(ap.param_count(), want);
        assert_eq!(ap.state_bytes(), 4 * want);
    }

    #[test]
    fn adapter_params_rejects_malformed_sets() {
        let (cfg, ad) = adapter(4);
        let base = cfg.init(0);
        let train = ad.init_trainable(&base, 7);
        // unpaired A
        let mut broken = train.clone();
        broken.remove("lora_B/layer0/attn/wq");
        assert!(AdapterParams::from_trainable(&broken).is_err());
        // unpaired B
        let mut broken = train.clone();
        broken.remove("lora_A/layer0/attn/wq");
        assert!(AdapterParams::from_trainable(&broken).is_err());
        // no factors at all
        let mut none = ParamSet::new();
        none.insert("embed/tok".into(), Matrix::zeros(2, 2));
        assert!(AdapterParams::from_trainable(&none).is_err());
    }

    #[test]
    fn contraction_order_rule_matches_mac_counts() {
        // the rule IS the FLOP comparison — check it against explicit
        // counts on a mixed grid, including both winners
        for (rows, n, r, m) in [
            (64usize, 128usize, 8usize, 128usize), // catalog-ish: factored wins
            (1usize, 128usize, 8usize, 384usize),  // single decode row
            (16usize, 32usize, 4usize, 96usize),
            (1024usize, 4usize, 4usize, 4usize), // tall x, full rank: materialize wins
            (4096usize, 8usize, 8usize, 8usize),
        ] {
            let factored = rows * r * (n + m);
            let materialized = n * r * m + rows * n * m;
            assert_eq!(
                xba_cheaper(rows, n, r, m),
                factored <= materialized,
                "rows={rows} n={n} r={r} m={m}"
            );
        }
    }

    #[test]
    fn factored_order_wins_at_every_catalog_shape() {
        // r ≪ n, m across the whole lora size grid ⇒ the serve forward's
        // default (x·B)·A order is always the cheaper one there
        for (_, cfg) in TransformerConfig::catalog_grid() {
            for (name, sh) in cfg.param_shapes() {
                if !is_projectable(&name) {
                    continue;
                }
                for rows in [1usize, cfg.seq_len] {
                    for r in [4usize, 8, 16] {
                        assert!(
                            xba_cheaper(rows, sh[0], r, sh[1]),
                            "{name} rows={rows} r={r}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn materialized_order_exists_and_is_detected() {
        // rows ≫ n=m and r = n (full rank): (x·B)·A does rows·n·2n MACs
        // while x·(B·A) does n³ + rows·n² — half the work as rows → ∞
        assert!(!xba_cheaper(1024, 4, 4, 4));
        assert!(!xba_cheaper(4096, 8, 8, 8));
        // shrink the rank back down and the factored order wins again
        assert!(xba_cheaper(1024, 4, 1, 4));
    }

    #[test]
    fn extra_params_scale_with_rank() {
        let (_, ad4) = adapter(4);
        let (_, ad8) = adapter(8);
        assert_eq!(ad8.extra_param_count(), 2 * ad4.extra_param_count());
        // 1 layer, d=32, f=64: 4x(32+32) + (32+64) + (64+32) = 448 per rank
        assert_eq!(ad4.extra_param_count(), 4 * 448);
    }
}
