//! Decoder-only prefix-LM transformer: token/position embeddings, the
//! shared pre-norm encoder stack with a causal mask, final RMS-norm and a
//! TIED LM head (logits = x · embed/tokᵀ), with a fully manual backward
//! pass. Mirrors `python/compile/layers.py` (`LMConfig` / `lm_forward` /
//! `lm_loss` / `lm_greedy_decode`) shape-for-shape and name-for-name.
//!
//! Per-layer attention projections run fused (one `[d, 3d]` QKV GEMM,
//! see `blocks`): the parameters stay the separate `attn/wq|wk|wv`
//! matrices of the manifest ABI — fusion is a kernel-layout choice
//! whose packed panels live in the per-forward `LayerCache`, not a
//! model-surface change, so checkpoints, state routing and the
//! projectable-parameter rule are untouched.

use super::blocks::{stack_backward, stack_forward, BlockDims};
use super::head::{argmax_rows, fused_softmax_xent, gather_rows, scatter_rows_add};
use super::{add_grad, pget, zero_grads, ParamSet};
use crate::tensor::{
    par_rows, rms_norm_rows, rms_norm_rows_vjp, Matrix, ELEMWISE_FLOP_WEIGHT,
};
use crate::util::rng::{derive_seed, Rng};

/// Configuration of the native LM transformer.
#[derive(Clone, Copy, Debug)]
pub struct TransformerConfig {
    pub vocab: usize,
    pub seq_len: usize,
    pub dims: BlockDims,
}

impl TransformerConfig {
    /// The `lora-tiny` catalog model: the smallest transformer whose
    /// attention/MLP gradients exercise the full multi-matrix projection
    /// path.
    pub fn tiny() -> Self {
        Self {
            vocab: 64,
            seq_len: 16,
            dims: BlockDims { d_model: 32, n_layers: 1, n_heads: 2, d_ff: 64 },
        }
    }

    /// The `lora-small` catalog model: 2 layers at d=64, the first rung
    /// of the native size grid.
    pub fn small() -> Self {
        Self {
            vocab: 128,
            seq_len: 32,
            dims: BlockDims { d_model: 64, n_layers: 2, n_heads: 4, d_ff: 128 },
        }
    }

    /// The `lora-base` catalog model: 2 layers at d=128, the largest
    /// native LM size.
    pub fn base() -> Self {
        Self {
            vocab: 256,
            seq_len: 64,
            dims: BlockDims { d_model: 128, n_layers: 2, n_heads: 4, d_ff: 256 },
        }
    }

    /// The (name, config) grid the native catalog registers — one source
    /// of truth shared by `runtime/native.rs` and the kernel microbench.
    pub fn catalog_grid() -> Vec<(&'static str, TransformerConfig)> {
        vec![
            ("lora-tiny", Self::tiny()),
            ("lora-small", Self::small()),
            ("lora-base", Self::base()),
        ]
    }

    /// (name, shape) of every parameter, sorted by name (the ABI order).
    pub fn param_shapes(&self) -> Vec<(String, [usize; 2])> {
        let d = self.dims.d_model;
        let mut shapes = vec![
            ("embed/pos".to_string(), [self.seq_len, d]),
            ("embed/tok".to_string(), [self.vocab, d]),
            ("final_ln/scale".to_string(), [1, d]),
        ];
        for l in 0..self.dims.n_layers {
            shapes.extend(self.dims.layer_shapes(l));
        }
        shapes.sort_by(|a, b| a.0.cmp(&b.0));
        shapes
    }

    pub fn param_count(&self) -> usize {
        self.param_shapes().iter().map(|(_, s)| s[0] * s[1]).sum()
    }

    /// Seeded init: norm scales at 1, embeddings N(0, 0.02), dense
    /// matrices LeCun-normal — the `layers.init_lm` recipe.
    pub fn init(&self, seed: u64) -> ParamSet {
        let mut params = ParamSet::new();
        for (idx, (name, sh)) in self.param_shapes().into_iter().enumerate() {
            let mut rng = Rng::new(derive_seed(seed, idx as u64));
            let m = if name.ends_with("/scale") {
                Matrix::from_fn(sh[0], sh[1], |_, _| 1.0)
            } else if name.starts_with("embed/") {
                Matrix::gaussian(sh[0], sh[1], 0.02, &mut rng)
            } else {
                Matrix::gaussian(sh[0], sh[1], 1.0 / (sh[0] as f32).sqrt(), &mut rng)
            };
            params.insert(name, m);
        }
        params
    }

    fn check_batch(
        &self,
        tokens: &[i32],
        rows: usize,
        s: usize,
    ) -> Result<(), String> {
        if s == 0 || s > self.seq_len {
            return Err(format!(
                "batch seq {s} outside the model's positional table (seq_len {})",
                self.seq_len
            ));
        }
        if tokens.len() != rows * s {
            return Err(format!(
                "tokens length {} != rows {rows} * seq {s}",
                tokens.len()
            ));
        }
        for &t in tokens {
            if t < 0 || t as usize >= self.vocab {
                return Err(format!(
                    "token id {t} out of range for vocab {}",
                    self.vocab
                ));
            }
        }
        Ok(())
    }

    /// Embed tokens, run the stack + final norm. Returns the normed
    /// activations `[rows*s, d]` (the tied head multiplies them by
    /// `embed/tok`ᵀ on demand) plus the backward intermediates when asked.
    #[allow(clippy::type_complexity)]
    fn forward(
        &self,
        params: &ParamSet,
        tokens: &[i32],
        rows: usize,
        s: usize,
        keep: bool,
    ) -> (Matrix, Option<(Matrix, Vec<super::blocks::LayerCache>)>) {
        let d = self.dims.d_model;
        let tok = pget(params, "embed/tok");
        let pos = pget(params, "embed/pos");
        let mut x0 = Matrix::zeros(rows * s, d);
        // row-local gather (each output row reads only its own token/pos
        // rows), so it bands onto the shared pool; banding cannot change
        // any element's arithmetic, so 1-vs-N parallelism stays
        // bit-identical
        let total = rows * s;
        par_rows(
            &mut x0.data,
            total,
            d,
            total * d * ELEMWISE_FLOP_WEIGHT,
            |band, first, take| {
                for r in 0..take {
                    let gr = first + r;
                    let trow = tok.row(tokens[gr] as usize);
                    let prow = pos.row(gr % s);
                    let xrow = &mut band[r * d..(r + 1) * d];
                    for j in 0..d {
                        xrow[j] = trow[j] + prow[j];
                    }
                }
            },
        );
        let (x_out, caches) =
            stack_forward(params, self.dims, x0, rows, s, true);
        let n_f = rms_norm_rows(&x_out, pget(params, "final_ln/scale"));
        if keep {
            (n_f, Some((x_out, caches)))
        } else {
            (n_f, None)
        }
    }

    /// Masked next-token cross-entropy (position `i-1` predicts token `i`,
    /// weighted by `mask[i]`), normalized by the total mask weight —
    /// `layers.lm_loss` exactly. With `want_grad`, also the full gradient
    /// set (every parameter present, zeros where untouched).
    ///
    /// The head is the shared fused CE block (`model::head`): gather the
    /// masked-in feature rows, one `F·embᵀ` GEMM for the logits, fused
    /// softmax-CE forward+gradient, then GEMMs back for `dnf`/`demb`.
    pub fn loss_and_grad(
        &self,
        params: &ParamSet,
        tokens: &[i32],
        mask: &[f32],
        rows: usize,
        s: usize,
        want_grad: bool,
    ) -> Result<(f32, ParamSet), String> {
        self.check_batch(tokens, rows, s)?;
        if mask.len() != tokens.len() {
            return Err("mask/tokens length mismatch".into());
        }
        let d = self.dims.d_model;
        let mut grads = if want_grad {
            zero_grads(&self.param_shapes())
        } else {
            ParamSet::new()
        };
        // prediction-carrying positions: feature row bi*s+i-1 predicts
        // token i with weight mask[i]
        let mut frows = Vec::new();
        let mut targets = Vec::new();
        let mut weights = Vec::new();
        for bi in 0..rows {
            for i in 1..s {
                let wt = mask[bi * s + i];
                if wt <= 0.0 {
                    continue;
                }
                frows.push(bi * s + i - 1);
                targets.push(tokens[bi * s + i] as usize);
                weights.push(wt);
            }
        }
        if frows.is_empty() {
            return Ok((0.0, grads));
        }

        let (n_f, cache) = self.forward(params, tokens, rows, s, want_grad);
        let emb = pget(params, "embed/tok");
        let feats = gather_rows(&n_f, &frows);
        let logits = feats.matmul_nt(emb); // tied head: [n_ex, v]
        let (loss, dlogits) =
            fused_softmax_xent(&logits, &targets, &weights, want_grad);
        if !want_grad {
            return Ok((loss, grads));
        }
        let mut dnf = Matrix::zeros(rows * s, d);
        scatter_rows_add(&mut dnf, &frows, &dlogits.matmul(emb));
        // tied head: the embedding gradient collects BOTH the head term
        // and (later) the input-embedding term
        let mut demb = dlogits.matmul_tn(&feats);

        let (x_out, caches) = cache.expect("forward kept no caches");
        let (dx_out, dfinal) =
            rms_norm_rows_vjp(&x_out, pget(params, "final_ln/scale"), &dnf);
        add_grad(&mut grads, "final_ln/scale", dfinal);
        let dx0 = stack_backward(
            params, self.dims, caches, dx_out, rows, s, true, &mut grads,
        );
        // embedding backward: x0[r] = tok[tokens[r]] + pos[i]. This
        // scatter stays SERIAL: distinct input rows r can hit the same
        // demb/dpos row (repeated tokens, shared positions across the
        // batch), so banding it would race and reorder the += chains.
        let mut dpos = Matrix::zeros(self.seq_len, d);
        for bi in 0..rows {
            for i in 0..s {
                let r = bi * s + i;
                let dxrow = dx0.row(r);
                let trow =
                    &mut demb.data[tokens[r] as usize * d..(tokens[r] as usize + 1) * d];
                for j in 0..d {
                    trow[j] += dxrow[j];
                }
                let prow = &mut dpos.data[i * d..(i + 1) * d];
                for j in 0..d {
                    prow[j] += dxrow[j];
                }
            }
        }
        add_grad(&mut grads, "embed/tok", demb);
        add_grad(&mut grads, "embed/pos", dpos);
        Ok((loss, grads))
    }

    /// Greedy autoregressive decode in place: positions `>= prompt_len`
    /// are overwritten with the argmax continuation (full forward per
    /// position — seq lengths in the native catalog are tiny).
    pub fn greedy(
        &self,
        params: &ParamSet,
        tokens: &mut [i32],
        rows: usize,
        s: usize,
        prompt_len: usize,
    ) -> Result<(), String> {
        self.check_batch(tokens, rows, s)?;
        let emb_shape = pget(params, "embed/tok").shape();
        debug_assert_eq!(emb_shape, (self.vocab, self.dims.d_model));
        for i in prompt_len.max(1)..s {
            let (n_f, _) = self.forward(params, tokens, rows, s, false);
            let emb = pget(params, "embed/tok");
            // one logits GEMM over every row's predecessor position;
            // argmax_rows keeps the scalar loop's first-max tie-breaking
            let frows: Vec<usize> = (0..rows).map(|bi| bi * s + i - 1).collect();
            let logits = gather_rows(&n_f, &frows).matmul_nt(emb);
            for (bi, &cls) in argmax_rows(&logits).iter().enumerate() {
                tokens[bi * s + i] = cls as i32;
            }
        }
        Ok(())
    }

    /// [`greedy`](Self::greedy) on the KV-cache incremental engine
    /// (`model::decode`): one position per step against cached
    /// keys/values instead of a full recompute. Emits token-for-token
    /// the same continuation — see `decode`'s module docs for why the
    /// equality is token-level, not activation-bit-level.
    pub fn greedy_kv(
        &self,
        params: &ParamSet,
        tokens: &mut [i32],
        rows: usize,
        s: usize,
        prompt_len: usize,
    ) -> Result<(), String> {
        super::decode::greedy_kv(self, params, tokens, rows, s, prompt_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_batch(cfg: &TransformerConfig, rows: usize) -> (Vec<i32>, Vec<f32>) {
        let s = cfg.seq_len;
        let mut toks = vec![0i32; rows * s];
        let mut mask = vec![0.0f32; rows * s];
        for bi in 0..rows {
            for i in 0..s {
                toks[bi * s + i] = (3 + (bi + 2 * i) % (cfg.vocab - 3)) as i32;
                if i >= s / 2 {
                    mask[bi * s + i] = 1.0;
                }
            }
        }
        (toks, mask)
    }

    #[test]
    fn init_is_deterministic_and_complete() {
        let cfg = TransformerConfig::tiny();
        let a = cfg.init(7);
        let b = cfg.init(7);
        let c = cfg.init(8);
        assert_eq!(a.len(), cfg.param_shapes().len());
        for (name, sh) in cfg.param_shapes() {
            assert_eq!(a[&name].shape(), (sh[0], sh[1]), "{name}");
            assert!(a[&name].allclose(&b[&name], 0.0), "{name}");
        }
        assert!(!a["embed/tok"].allclose(&c["embed/tok"], 1e-6));
        // norm scales start at exactly 1
        assert!(a["final_ln/scale"].data.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn init_loss_is_near_uniform() {
        let cfg = TransformerConfig::tiny();
        let params = cfg.init(0);
        let (toks, mask) = toy_batch(&cfg, 2);
        let (loss, _) = cfg
            .loss_and_grad(&params, &toks, &mask, 2, cfg.seq_len, false)
            .unwrap();
        assert!(
            (loss - (cfg.vocab as f32).ln()).abs() < 0.5,
            "init loss {loss} far from ln(v)"
        );
    }

    #[test]
    fn gradient_matches_directional_finite_difference() {
        let cfg = TransformerConfig {
            vocab: 24,
            seq_len: 6,
            dims: BlockDims { d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 },
        };
        let params = cfg.init(1);
        let rows = 2usize;
        let s = cfg.seq_len;
        let mut toks = vec![0i32; rows * s];
        let mut mask = vec![0.0f32; rows * s];
        for (r, t) in toks.iter_mut().enumerate() {
            *t = ((5 * r + 3) % cfg.vocab) as i32;
        }
        for (r, m) in mask.iter_mut().enumerate() {
            if r % s >= 2 {
                *m = 1.0;
            }
        }
        let (_, grads) = cfg
            .loss_and_grad(&params, &toks, &mask, rows, s, true)
            .unwrap();
        crate::model::testutil::assert_directional_fd(
            &params,
            &grads,
            |p| cfg.loss_and_grad(p, &toks, &mask, rows, s, false).unwrap().0,
            1e-2,
            3e-2,
            5,
        );
    }

    #[test]
    fn pointwise_gradients_match_finite_differences() {
        // spot-check single entries across parameter kinds (attention,
        // MLP, tied embedding, norm scale, positions)
        let cfg = TransformerConfig {
            vocab: 16,
            seq_len: 5,
            dims: BlockDims { d_model: 8, n_layers: 1, n_heads: 2, d_ff: 16 },
        };
        let params = cfg.init(2);
        let toks: Vec<i32> = (0..10).map(|r| (r * 3 % 16) as i32).collect();
        let mask = vec![1.0f32; 10];
        let (_, grads) = cfg
            .loss_and_grad(&params, &toks, &mask, 2, 5, true)
            .unwrap();
        let eps = 1e-2f32;
        for (name, i, j) in [
            ("layer0/attn/wq", 1usize, 2usize),
            ("layer0/ffn/w1", 3, 5),
            ("embed/tok", 3, 1),
            ("embed/pos", 2, 4),
            ("layer0/ln1/scale", 0, 3),
            ("final_ln/scale", 0, 1),
        ] {
            let perturb = |sign: f32| -> f32 {
                let mut p2 = params.clone();
                *p2.get_mut(name).unwrap().at_mut(i, j) += sign * eps;
                cfg.loss_and_grad(&p2, &toks, &mask, 2, 5, false).unwrap().0
            };
            let fd = (perturb(1.0) - perturb(-1.0)) / (2.0 * eps);
            let an = grads[name].at(i, j);
            assert!(
                (fd - an).abs() < 2e-3 + 3e-2 * fd.abs().max(an.abs()),
                "{name}[{i},{j}]: fd={fd} analytic={an}"
            );
        }
    }

    #[test]
    fn catalog_grid_sizes_are_monotone_and_valid() {
        let grid = TransformerConfig::catalog_grid();
        assert_eq!(grid[0].0, "lora-tiny");
        for w in grid.windows(2) {
            assert!(w[0].1.param_count() < w[1].1.param_count());
        }
        for (name, cfg) in &grid {
            assert_eq!(cfg.dims.d_model % cfg.dims.n_heads, 0, "{name}");
            assert!(cfg.vocab > 0 && cfg.seq_len > 0, "{name}");
        }
    }

    #[test]
    fn small_config_gradient_matches_directional_fd() {
        // the acceptance gate for the size grid: FD gradient checks pass
        // on the batched attention path at lora-small scale (short batch
        // slice — check_batch allows s <= seq_len)
        let cfg = TransformerConfig::small();
        let params = cfg.init(11);
        let (rows, s) = (1usize, 8usize);
        let toks: Vec<i32> = (0..rows * s)
            .map(|r| ((7 * r + 3) % cfg.vocab) as i32)
            .collect();
        let mask = vec![1.0f32; rows * s];
        let (_, grads) = cfg
            .loss_and_grad(&params, &toks, &mask, rows, s, true)
            .unwrap();
        crate::model::testutil::assert_directional_fd(
            &params,
            &grads,
            |p| cfg.loss_and_grad(p, &toks, &mask, rows, s, false).unwrap().0,
            1e-2,
            3e-2,
            12,
        );
    }

    #[test]
    fn sgd_on_repeated_batch_overfits() {
        let cfg = TransformerConfig::tiny();
        let mut params = cfg.init(3);
        let (toks, mask) = toy_batch(&cfg, 2);
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for step in 0..40 {
            let (loss, grads) = cfg
                .loss_and_grad(&params, &toks, &mask, 2, cfg.seq_len, true)
                .unwrap();
            if step == 0 {
                first = loss;
            }
            last = loss;
            for (name, g) in &grads {
                params.get_mut(name).unwrap().add_scaled_inplace(g, -0.5);
            }
        }
        assert!(first.is_finite() && last.is_finite());
        assert!(last < first - 0.3, "no overfit: {first} -> {last}");
    }

    #[test]
    fn greedy_is_deterministic_and_respects_prompt() {
        let cfg = TransformerConfig::tiny();
        let params = cfg.init(4);
        let (toks, _) = toy_batch(&cfg, 2);
        let mut a = toks.clone();
        let mut b = toks.clone();
        cfg.greedy(&params, &mut a, 2, cfg.seq_len, 4).unwrap();
        cfg.greedy(&params, &mut b, 2, cfg.seq_len, 4).unwrap();
        assert_eq!(a, b);
        // the prompt region is untouched
        for bi in 0..2 {
            for i in 0..4 {
                assert_eq!(a[bi * cfg.seq_len + i], toks[bi * cfg.seq_len + i]);
            }
        }
    }

    #[test]
    fn rejects_bad_tokens_and_lengths() {
        let cfg = TransformerConfig::tiny();
        let params = cfg.init(0);
        let bad = vec![99i32; 2 * cfg.seq_len];
        let mask = vec![1.0f32; 2 * cfg.seq_len];
        assert!(cfg
            .loss_and_grad(&params, &bad, &mask, 2, cfg.seq_len, false)
            .is_err());
        let toks = vec![1i32; 2 * 40];
        assert!(cfg.loss_and_grad(&params, &toks, &mask, 2, 40, false).is_err());
    }
}
