//! Compact Vision Transformer: patchify → linear patch embedding →
//! [CLS] + learned positions → BIDIRECTIONAL pre-norm encoder blocks →
//! final RMS-norm → classification head on the CLS token, with a manual
//! backward pass. Mirrors `python/compile/vit.py` name-for-name; the
//! patchification itself lives with the image data
//! ([`crate::data::images::patchify_hwc`]). Like the LM, the encoder's
//! attention projections execute as one fused `[d, 3d]` QKV GEMM with
//! the packed panels cached per forward in `blocks::LayerCache` — the
//! `attn/wq|wk|wv` parameter surface (and so every checkpoint and
//! compression rule) is unchanged.

use super::blocks::{stack_backward, stack_forward, BlockDims};
use super::head::{argmax_rows, fused_softmax_xent, gather_rows, scatter_rows_add};
use super::{add_grad, pget, zero_grads, ParamSet};
use crate::data::images::patchify_hwc;
use crate::tensor::{rms_norm_rows, rms_norm_rows_vjp, Matrix};
use crate::util::rng::{derive_seed, Rng};

/// Configuration of the native ViT.
#[derive(Clone, Copy, Debug)]
pub struct VitConfig {
    pub image_size: usize,
    pub patch_size: usize,
    pub channels: usize,
    pub n_classes: usize,
    pub dims: BlockDims,
}

impl VitConfig {
    /// The `vit-tiny` catalog model (Table-5 workload, CIFAR-sim scale).
    pub fn tiny() -> Self {
        Self {
            image_size: 8,
            patch_size: 4,
            channels: 3,
            n_classes: 10,
            dims: BlockDims { d_model: 32, n_layers: 1, n_heads: 2, d_ff: 64 },
        }
    }

    /// The `vit-small` catalog model: 16×16 images (17-token sequences)
    /// through a 2-layer d=64 encoder — the ViT rung of the size grid.
    pub fn small() -> Self {
        Self {
            image_size: 16,
            patch_size: 4,
            channels: 3,
            n_classes: 10,
            dims: BlockDims { d_model: 64, n_layers: 2, n_heads: 4, d_ff: 128 },
        }
    }

    /// The (name, config) grid the native catalog registers — shared
    /// with `runtime/native.rs` and the kernel microbench.
    pub fn catalog_grid() -> Vec<(&'static str, VitConfig)> {
        vec![("vit-tiny", Self::tiny()), ("vit-small", Self::small())]
    }

    pub fn n_patches(&self) -> usize {
        let per_side = self.image_size / self.patch_size;
        per_side * per_side
    }

    pub fn patch_dim(&self) -> usize {
        self.channels * self.patch_size * self.patch_size
    }

    /// Sequence length of the encoder: [CLS] + one position per patch.
    pub fn seq(&self) -> usize {
        self.n_patches() + 1
    }

    /// (name, shape) of every parameter, sorted by name (the ABI order).
    pub fn param_shapes(&self) -> Vec<(String, [usize; 2])> {
        let d = self.dims.d_model;
        let mut shapes = vec![
            ("embed/cls".to_string(), [1, d]),
            ("embed/patch".to_string(), [self.patch_dim(), d]),
            ("embed/pos".to_string(), [self.seq(), d]),
            ("final_ln/scale".to_string(), [1, d]),
            ("head/w".to_string(), [d, self.n_classes]),
        ];
        for l in 0..self.dims.n_layers {
            shapes.extend(self.dims.layer_shapes(l));
        }
        shapes.sort_by(|a, b| a.0.cmp(&b.0));
        shapes
    }

    pub fn param_count(&self) -> usize {
        self.param_shapes().iter().map(|(_, s)| s[0] * s[1]).sum()
    }

    /// Seeded init mirroring `vit.init_vit`: norm scales at 1, cls/pos
    /// N(0, 0.02), dense matrices (patch embedding, head, blocks)
    /// LeCun-normal.
    pub fn init(&self, seed: u64) -> ParamSet {
        let mut params = ParamSet::new();
        for (idx, (name, sh)) in self.param_shapes().into_iter().enumerate() {
            let mut rng = Rng::new(derive_seed(seed, idx as u64));
            let m = if name.ends_with("/scale") {
                Matrix::from_fn(sh[0], sh[1], |_, _| 1.0)
            } else if name == "embed/pos" || name == "embed/cls" {
                Matrix::gaussian(sh[0], sh[1], 0.02, &mut rng)
            } else {
                Matrix::gaussian(sh[0], sh[1], 1.0 / (sh[0] as f32).sqrt(), &mut rng)
            };
            params.insert(name, m);
        }
        params
    }

    fn check_batch(&self, images: &[f32], labels: &[i32]) -> Result<usize, String> {
        let per_image = self.image_size * self.image_size * self.channels;
        let b = labels.len();
        if b == 0 || images.len() != b * per_image {
            return Err(format!(
                "image batch length {} != batch {b} x {per_image}",
                images.len()
            ));
        }
        for &l in labels {
            if l < 0 || l as usize >= self.n_classes {
                return Err(format!(
                    "label {l} out of range for {} classes",
                    self.n_classes
                ));
            }
        }
        Ok(b)
    }

    /// Cross-entropy over classes (mean over the batch), the class
    /// predictions, and — with `want_grad` — the full gradient set.
    /// One fused entry point so the eval executable gets loss AND preds
    /// from a single forward.
    pub fn loss_preds_grad(
        &self,
        params: &ParamSet,
        images: &[f32],
        labels: &[i32],
        want_grad: bool,
    ) -> Result<(f32, Vec<i32>, ParamSet), String> {
        let b = self.check_batch(images, labels)?;
        let d = self.dims.d_model;
        let s = self.seq();
        let np = self.n_patches();
        let patches =
            patchify_hwc(images, b, self.image_size, self.patch_size, self.channels)?;
        let pe = patches.matmul(pget(params, "embed/patch")); // [b*np, d]
        let cls = pget(params, "embed/cls");
        let pos = pget(params, "embed/pos");
        let mut x0 = Matrix::zeros(b * s, d);
        for bi in 0..b {
            for i in 0..s {
                let r = bi * s + i;
                let base = if i == 0 { cls.row(0) } else { pe.row(bi * np + i - 1) };
                let prow = pos.row(i);
                let xrow = &mut x0.data[r * d..(r + 1) * d];
                for j in 0..d {
                    xrow[j] = base[j] + prow[j];
                }
            }
        }
        let (x_out, caches) = stack_forward(params, self.dims, x0, b, s, false);
        let n_f = rms_norm_rows(&x_out, pget(params, "final_ln/scale"));
        let head = pget(params, "head/w"); // [d, n_classes]

        let mut grads = if want_grad {
            zero_grads(&self.param_shapes())
        } else {
            ParamSet::new()
        };
        // the shared fused CE head (`model::head`): one CLS-rows GEMM for
        // the logits, fused softmax-CE forward+gradient, GEMMs back for
        // dhead / dnf — the same block the LM's tied head uses
        let frows: Vec<usize> = (0..b).map(|bi| bi * s).collect();
        let feats = gather_rows(&n_f, &frows); // the CLS positions
        let logits = feats.matmul(head); // [b, n_classes]
        let preds: Vec<i32> =
            argmax_rows(&logits).iter().map(|&c| c as i32).collect();
        let targets: Vec<usize> = labels.iter().map(|&l| l as usize).collect();
        let (loss, dlogits) =
            fused_softmax_xent(&logits, &targets, &vec![1.0f32; b], want_grad);
        if !want_grad {
            return Ok((loss, preds, grads));
        }

        add_grad(&mut grads, "head/w", feats.matmul_tn(&dlogits));
        let mut dnf = Matrix::zeros(b * s, d);
        scatter_rows_add(&mut dnf, &frows, &dlogits.matmul_nt(head));
        let (dx_out, dfinal) =
            rms_norm_rows_vjp(&x_out, pget(params, "final_ln/scale"), &dnf);
        add_grad(&mut grads, "final_ln/scale", dfinal);
        let dx0 =
            stack_backward(params, self.dims, caches, dx_out, b, s, false, &mut grads);
        // embedding backward: cls/pos sums + patch-embedding GEMM
        let mut dcls = Matrix::zeros(1, d);
        let mut dpos = Matrix::zeros(s, d);
        let mut dpe = Matrix::zeros(b * np, d);
        for bi in 0..b {
            for i in 0..s {
                let dxrow = dx0.row(bi * s + i);
                for j in 0..d {
                    *dpos.at_mut(i, j) += dxrow[j];
                }
                if i == 0 {
                    for j in 0..d {
                        *dcls.at_mut(0, j) += dxrow[j];
                    }
                } else {
                    let perow =
                        &mut dpe.data[(bi * np + i - 1) * d..(bi * np + i) * d];
                    for j in 0..d {
                        perow[j] += dxrow[j];
                    }
                }
            }
        }
        add_grad(&mut grads, "embed/patch", patches.matmul_tn(&dpe));
        add_grad(&mut grads, "embed/cls", dcls);
        add_grad(&mut grads, "embed/pos", dpos);
        Ok((loss, preds, grads))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::images::ImageTask;

    fn batch(cfg: &VitConfig, b: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let task = ImageTask::cifar_like(
            cfg.n_classes,
            cfg.image_size,
            cfg.channels,
            0.25,
            seed,
        );
        let mut cursor = 0u64;
        task.fill_flat(b, 0, &mut cursor, seed)
    }

    #[test]
    fn init_shapes_and_determinism() {
        let cfg = VitConfig::tiny();
        assert_eq!(cfg.n_patches(), 4);
        assert_eq!(cfg.patch_dim(), 48);
        assert_eq!(cfg.seq(), 5);
        let a = cfg.init(1);
        let b = cfg.init(1);
        for (name, sh) in cfg.param_shapes() {
            assert_eq!(a[&name].shape(), (sh[0], sh[1]), "{name}");
            assert!(a[&name].allclose(&b[&name], 0.0), "{name}");
        }
    }

    #[test]
    fn loss_and_preds_have_sane_ranges() {
        let cfg = VitConfig::tiny();
        let params = cfg.init(0);
        let (images, labels) = batch(&cfg, 8, 3);
        let (loss, preds, _) = cfg
            .loss_preds_grad(&params, &images, &labels, false)
            .unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((loss - (cfg.n_classes as f32).ln()).abs() < 2.0);
        assert_eq!(preds.len(), 8);
        assert!(preds.iter().all(|&p| p >= 0 && (p as usize) < cfg.n_classes));
    }

    #[test]
    fn gradient_matches_directional_finite_difference() {
        let cfg = VitConfig {
            image_size: 4,
            patch_size: 2,
            channels: 2,
            n_classes: 5,
            dims: BlockDims { d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 },
        };
        let params = cfg.init(2);
        let (images, labels) = batch(&cfg, 3, 4);
        let (_, _, grads) = cfg
            .loss_preds_grad(&params, &images, &labels, true)
            .unwrap();
        crate::model::testutil::assert_directional_fd(
            &params,
            &grads,
            |p| cfg.loss_preds_grad(p, &images, &labels, false).unwrap().0,
            1e-2,
            3e-2,
            5,
        );
    }

    #[test]
    fn small_config_gradient_matches_directional_fd() {
        // size-grid acceptance: FD check on the batched attention path at
        // vit-small scale
        let cfg = VitConfig::small();
        assert_eq!(cfg.n_patches(), 16);
        assert_eq!(cfg.seq(), 17);
        let params = cfg.init(9);
        let (images, labels) = batch(&cfg, 2, 10);
        let (_, _, grads) = cfg
            .loss_preds_grad(&params, &images, &labels, true)
            .unwrap();
        crate::model::testutil::assert_directional_fd(
            &params,
            &grads,
            |p| cfg.loss_preds_grad(p, &images, &labels, false).unwrap().0,
            1e-2,
            3e-2,
            13,
        );
    }

    #[test]
    fn sgd_on_fixed_batch_learns_the_templates() {
        // plain SGD on a fixed batch must drive the loss down — the
        // synthetic classes are separable templates
        let cfg = VitConfig::tiny();
        let mut params = cfg.init(6);
        let (images, labels) = batch(&cfg, 8, 7);
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for step in 0..40 {
            let (loss, _, grads) = cfg
                .loss_preds_grad(&params, &images, &labels, true)
                .unwrap();
            if step == 0 {
                first = loss;
            }
            last = loss;
            for (name, g) in &grads {
                params.get_mut(name).unwrap().add_scaled_inplace(g, -0.1);
            }
        }
        assert!(last < first - 0.3, "no descent: {first} -> {last}");
    }

    #[test]
    fn rejects_bad_labels() {
        let cfg = VitConfig::tiny();
        let params = cfg.init(0);
        let (images, mut labels) = batch(&cfg, 2, 0);
        labels[0] = 99;
        assert!(cfg
            .loss_preds_grad(&params, &images, &labels, false)
            .is_err());
    }
}
