//! Pure-rust transformer models with manual backward passes.
//!
//! This module is the native counterpart of `python/compile/layers.py` /
//! `vit.py` / `lora.py`: a decoder-only prefix LM ([`TransformerConfig`]),
//! a compact ViT ([`VitConfig`]) and the LoRA parameterization
//! ([`LoraAdapter`]), all written directly on [`crate::tensor::Matrix`]
//! with hand-derived gradients (no autodiff, no XLA). The shared pre-norm
//! encoder stack lives in [`blocks`]; every VJP it composes
//! (softmax / RMS-norm / GELU) is finite-difference-checked in
//! `tensor::ops`, and the full model gradients are checked against
//! directional finite differences in this module's tests.
//!
//! Parameters travel as a [`ParamSet`] — a name→matrix map whose SORTED
//! iteration order is the manifest ABI order, exactly like the python
//! `Packer`. Naming matches `layers.py` (`embed/tok`, `layer0/attn/wq`,
//! `layer0/ffn/w1`, `ln*/scale`, ...), so [`is_projectable`] encodes the
//! paper's §3.1 rule ("projections on attention and feed-forward layers
//! only") in one place for the native catalog too.
//!
//! Both families share the fused softmax cross-entropy head (`head`) and
//! ship a size grid (`TransformerConfig::catalog_grid`,
//! `VitConfig::catalog_grid`) that `runtime/native.rs` registers
//! wholesale.

pub mod blocks;
pub mod decode;
pub(crate) mod head;
pub mod lora;
pub mod transformer;
pub mod vit;

pub use blocks::BlockDims;
pub use lora::{AdapterParams, LoraAdapter};
pub use transformer::TransformerConfig;
pub use vit::VitConfig;

use std::collections::BTreeMap;

use crate::tensor::Matrix;

/// A named set of 2-D parameters. Sorted iteration = the ABI order the
/// native catalog advertises (the python side sorts its dicts the same
/// way), so zipping a `ParamSet` against generated specs is stable.
pub type ParamSet = BTreeMap<String, Matrix>;

/// True if this parameter gets the random-projection treatment (paper
/// §3.1: attention and feed-forward matrices; embeddings, norm scales and
/// heads follow the "naive procedure" with full-size state). Mirrors
/// `layers.is_projectable`.
pub fn is_projectable(name: &str) -> bool {
    name.contains("attn/") || name.contains("ffn/")
}

/// Fetch a parameter or panic naming the offender (the catalogs generate
/// both the shapes and the lookups, so a miss is a bug, not bad input).
pub(crate) fn pget<'a>(params: &'a ParamSet, name: &str) -> &'a Matrix {
    params
        .get(name)
        .unwrap_or_else(|| panic!("missing model parameter {name:?}"))
}

/// Accumulate a gradient contribution into the set.
pub(crate) fn add_grad(grads: &mut ParamSet, name: &str, g: Matrix) {
    match grads.get_mut(name) {
        Some(acc) => acc.add_scaled_inplace(&g, 1.0),
        None => {
            grads.insert(name.to_string(), g);
        }
    }
}

/// Zero gradients for every parameter in `shapes` — loss functions return
/// a COMPLETE gradient set so optimizer loops never need missing-key
/// handling.
pub(crate) fn zero_grads(shapes: &[(String, [usize; 2])]) -> ParamSet {
    shapes
        .iter()
        .map(|(n, s)| (n.clone(), Matrix::zeros(s[0], s[1])))
        .collect()
}

/// Shared scaffolding for the model-family gradient tests and the
/// compressor conformance harness (rust/tests/compressors.rs) — public
/// so integration tests can drive it, compiled into the library either
/// way (it is a handful of small helpers).
pub mod testutil {
    use super::ParamSet;
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    /// Directional finite-difference check shared by the transformer and
    /// ViT tests: draws a random direction `u` over EVERY parameter and
    /// compares `<grads, u>` against `(f(θ+εu) − f(θ−εu)) / 2ε`.
    pub fn assert_directional_fd(
        params: &ParamSet,
        grads: &ParamSet,
        loss: impl Fn(&ParamSet) -> f32,
        eps: f32,
        rtol: f32,
        seed: u64,
    ) {
        let mut rng = Rng::new(seed);
        let u: ParamSet = params
            .iter()
            .map(|(k, m)| {
                (k.clone(), Matrix::gaussian(m.rows, m.cols, 1.0, &mut rng))
            })
            .collect();
        let shifted = |sign: f32| -> ParamSet {
            params
                .iter()
                .map(|(k, m)| {
                    let mut m2 = m.clone();
                    m2.add_scaled_inplace(&u[k], sign * eps);
                    (k.clone(), m2)
                })
                .collect()
        };
        let fd = (loss(&shifted(1.0)) - loss(&shifted(-1.0))) / (2.0 * eps);
        let analytic: f32 = grads
            .iter()
            .map(|(k, g)| {
                g.data
                    .iter()
                    .zip(u[k].data.iter())
                    .map(|(a, b)| a * b)
                    .sum::<f32>()
            })
            .sum();
        assert!(
            (fd - analytic).abs() < rtol * (1.0 + fd.abs().max(analytic.abs())),
            "fd={fd} analytic={analytic}"
        );
    }

    /// Smoothed descent statistic shared by the integration matrix and
    /// the compressor conformance harness: mean of the first `k` losses
    /// and the drop from that head to the mean of the last `k`.
    pub fn smoothed_drop(losses: &[f32], k: usize) -> (f32, f32) {
        assert!(losses.len() >= k && k > 0, "need >= {k} losses");
        let head: f32 = losses[..k].iter().sum::<f32>() / k as f32;
        let tail: f32 = losses[losses.len() - k..].iter().sum::<f32>() / k as f32;
        (head, head - tail)
    }

    /// Raw-bits equality over two loss curves — the determinism
    /// assertion every compressor must pass (`==` on f32 would accept
    /// -0.0 vs 0.0 and reject NaN == NaN; bits do neither).
    pub fn assert_bits_equal(label: &str, a: &[f32], b: &[f32]) {
        let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
        let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
        assert_eq!(ab, bb, "{label}: loss curves differ in raw bits");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn projectable_rule_matches_paper() {
        assert!(is_projectable("layer0/attn/wq"));
        assert!(is_projectable("layer1/ffn/w2"));
        assert!(!is_projectable("embed/tok"));
        assert!(!is_projectable("layer0/ln1/scale"));
        assert!(!is_projectable("head/w"));
        assert!(!is_projectable("final_ln/scale"));
    }

    #[test]
    fn add_grad_accumulates() {
        let mut g = ParamSet::new();
        add_grad(&mut g, "w", Matrix::from_vec(1, 2, vec![1.0, 2.0]));
        add_grad(&mut g, "w", Matrix::from_vec(1, 2, vec![0.5, 0.5]));
        assert_eq!(g["w"].data, vec![1.5, 2.5]);
    }
}
